"""Format-grid tests: Table I exactness + per-format invariants."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import formats as F

ALL_FMT_BITS = [(f, n) for f in F.FORMATS for n in (2, 3, 4, 5, 6, 7, 8)
                if not (f in ("adaptivfloat", "flint") and n == 2)]


class TestTable1:
    def test_paper_table1_exact(self):
        expect = [0.0, 0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.875,
                  1.0, 1.25, 1.5, 1.75, 2.0, 3.0, 4.0, 8.0]
        assert F.dybit_grid_unsigned(4).tolist() == expect

    def test_paper_decoder_example(self):
        # Sec. III-B2: unsigned 11001010 -> exp 001, mantissa 10101000
        assert F.dybit_magnitude(0b11001010, 8) == 2.0 * (1.0 + 10.0 / 32.0)

    def test_subnormal_region_linear(self):
        for m in range(2, 8):
            step = 1.0 / (1 << (m - 1))
            for x in range(1 << (m - 1)):
                assert F.dybit_magnitude(x, m) == x * step

    def test_all_ones_is_max(self):
        for m in range(1, 8):
            assert F.dybit_magnitude((1 << m) - 1, m) == float(1 << (m - 1))


class TestGrids:
    @pytest.mark.parametrize("fmt,n", ALL_FMT_BITS)
    def test_sorted_unique(self, fmt, n):
        g = F.grid(fmt, n)
        assert np.all(np.diff(g) > 0), (fmt, n)

    @pytest.mark.parametrize("fmt,n", ALL_FMT_BITS)
    def test_symmetric_with_zero(self, fmt, n):
        g = F.grid(fmt, n)
        assert 0.0 in g
        np.testing.assert_array_equal(g, -g[::-1])

    @pytest.mark.parametrize("fmt,n", ALL_FMT_BITS)
    def test_fits_lut(self, fmt, n):
        g = F.grid(fmt, n)
        assert len(g) <= F.LUT_SIZE
        lut = F.padded_lut(fmt, n)
        assert lut.shape == (F.LUT_SIZE,)
        assert np.all(np.diff(lut) >= 0)

    def test_dybit_int_coincide_at_2bit(self):
        np.testing.assert_array_equal(F.grid("dybit", 2), F.grid("int", 2))

    def test_grid_cardinality(self):
        # signed n-bit formats represent 2^n - 1 distinct values
        for fmt in ("dybit", "int", "posit", "adaptivfloat", "flint"):
            for n in (4, 8):
                assert len(F.grid(fmt, n)) == 2 ** n - 1, (fmt, n)


class TestCodec:
    def test_roundtrip_all_codes(self):
        for n in (2, 4, 8):
            for c in range(1 << n):
                v = F.dybit_decode_code(c, n)
                c2 = F.dybit_encode_code(v, n)
                assert F.dybit_decode_code(c2, n) == v, (n, c)

    def test_negative_zero_remap(self):
        # sign=1 mag=0 -> -max (DESIGN.md §5)
        for n in (2, 4, 8):
            assert F.dybit_decode_code(1 << (n - 1), n) == -float(
                1 << (n - 2))

    @given(st.floats(-20, 20), st.sampled_from([2, 4, 8]))
    @settings(max_examples=200, deadline=None)
    def test_encode_is_nearest(self, v, n):
        c = F.dybit_encode_code(v, n)
        got = abs(F.dybit_decode_code(c, n) - v)
        best = min(abs(F.dybit_decode_code(cc, n) - v)
                   for cc in range(1 << n))
        assert got == pytest.approx(best, abs=1e-12)


class TestQuantizer:
    @given(st.integers(0, 2 ** 31), st.sampled_from(["dybit", "int", "flint"]),
           st.sampled_from([2, 4, 8]))
    @settings(max_examples=60, deadline=None)
    def test_quantized_values_on_grid(self, seed, fmt, n):
        if fmt == "flint" and n == 2:
            n = 3  # flint needs >=1 mantissa bit
        rs = np.random.RandomState(seed % (2 ** 31))
        x = rs.randn(257).astype(np.float32) * rs.uniform(0.01, 100)
        xq, s = F.fake_quant(x, fmt, n)
        g = F.grid(fmt, n) * s
        dmin = np.abs(xq[:, None] - g[None, :].astype(np.float32)).min(1)
        assert dmin.max() < 1e-5 * max(1.0, np.abs(g).max())

    def test_quantize_idempotent(self):
        rs = np.random.RandomState(0)
        x = rs.randn(500)
        g = F.grid("dybit", 4)
        q1 = F.quantize_to_grid(x, g, 0.5)
        q2 = F.quantize_to_grid(q1, g, 0.5)
        np.testing.assert_array_equal(q1, q2)

    def test_rmse_normalized_by_sigma(self):
        rs = np.random.RandomState(1)
        x = rs.randn(1000)
        # scaling the tensor leaves the sigma-normalized RMSE invariant
        xq1, _ = F.fake_quant(x, "dybit", 4)
        xq2, _ = F.fake_quant(10 * x, "dybit", 4)
        assert F.rmse(x, xq1) == pytest.approx(F.rmse(10 * x, xq2), rel=1e-6)

    def test_more_bits_lower_rmse(self):
        rs = np.random.RandomState(2)
        x = rs.randn(2000)
        for fmt, bits in [("dybit", (2, 4, 8)), ("int", (2, 4, 8)),
                          ("flint", (3, 4, 8))]:
            e = [F.rmse(x, F.fake_quant(x, fmt, n)[0]) for n in bits]
            assert e[0] > e[1] > e[2], (fmt, e)

    def test_dybit_beats_int_on_heavy_tails(self):
        rs = np.random.RandomState(3)
        x = rs.standard_t(3, size=5000)
        ed = F.rmse(x, F.fake_quant(x, "dybit", 4)[0])
        ei = F.rmse(x, F.fake_quant(x, "int", 4)[0])
        assert ed < ei

    def test_calibrated_no_worse_than_maxabs(self):
        rs = np.random.RandomState(4)
        x = rs.laplace(size=3000)
        for fmt in F.FORMATS:
            g = F.grid(fmt, 4)
            s_max = F.maxabs_scale(x, g)
            e_max = F.rmse(x, F.quantize_to_grid(x, g, s_max))
            e_cal = F.rmse(x, F.fake_quant(x, fmt, 4)[0])
            assert e_cal <= e_max + 1e-12, fmt


class TestGolden:
    def test_golden_dump_complete(self):
        d = F.golden_dump()
        assert len(d["grids"]) >= 30
        assert set(d["dybit_codes"]) == {"2", "4", "8"}
        assert len(d["table1_unsigned4"]) == 16
