"""Model-level tests: shapes, quant-hook wiring, dataset, train step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import formats as F
from compile import model as M
from compile import train as T


@pytest.fixture(scope="module")
def batch():
    rs = np.random.RandomState(0)
    return jnp.asarray(rs.randn(M.BATCH, M.IMG, M.IMG, 3).astype(np.float32))


@pytest.mark.parametrize("name", list(M.MODELS))
def test_shapes_and_param_specs(name, batch):
    params, pspecs, lspecs = M.build(name)
    assert len(params) == len(pspecs)
    for p, s in zip(params, pspecs):
        assert tuple(p.shape) == tuple(s.shape), s.name
    logits = M.apply(name, params, batch)
    assert logits.shape == (M.BATCH, M.NCLASS)
    assert np.all(np.isfinite(np.asarray(logits)))
    # every quantizable layer has a weight leaf "<layer>.w"
    pnames = {s.name for s in pspecs}
    for ls in lspecs:
        assert f"{ls.name}.w" in pnames


@pytest.mark.parametrize("name", ["mlp", "microconvnext"])
def test_disabled_qcfg_is_identity(name, batch):
    params, _, lspecs = M.build(name)
    a = M.apply(name, params, batch)
    b = M.apply(name, params, batch, qcfg=M.make_qcfg(len(lspecs)))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_enabled_quant_changes_output(batch):
    params, _, lspecs = M.build("mlp")
    nl = len(lspecs)
    q = M.make_qcfg(nl)
    lut = jnp.asarray(np.tile(F.padded_lut("dybit", 2), (nl, 1)))
    q["wluts"] = lut
    q["wq_en"] = jnp.ones((nl,), jnp.float32)
    a = M.apply("mlp", params, batch)
    b = M.apply("mlp", params, batch, qcfg=q)
    assert not np.allclose(np.asarray(a), np.asarray(b), atol=1e-3)


def test_act_taps_shape_and_content(batch):
    params, _, lspecs = M.build("miniresnet18")
    _, taps = M.apply("miniresnet18", params, batch,
                      qcfg=M.make_qcfg(len(lspecs)), with_acts=True)
    assert taps.shape == (len(lspecs), 2048)
    # first tap row samples the normalized input image
    assert np.all(np.isfinite(np.asarray(taps)))
    assert float(jnp.abs(taps).max()) > 0


def test_layer_specs_gemm_dims():
    _, _, lspecs = M.build("micromobilenet")
    kinds = {ls.kind for ls in lspecs}
    assert "dwconv" in kinds and "conv" in kinds
    for ls in lspecs:
        assert ls.m > 0 and ls.k > 0 and ls.n > 0
        if ls.kind == "dwconv":
            assert ls.groups == ls.n


class TestDataset:
    def test_deterministic(self):
        x1, y1 = T.synth_batch(jnp.int32(3))
        x2, y2 = T.synth_batch(jnp.int32(3))
        np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))

    def test_seeds_differ(self):
        x1, _ = T.synth_batch(jnp.int32(3))
        x2, _ = T.synth_batch(jnp.int32(4))
        assert not np.array_equal(np.asarray(x1), np.asarray(x2))

    def test_label_range_and_shape(self):
        x, y = T.synth_batch(jnp.int32(0))
        assert x.shape == (M.BATCH, M.IMG, M.IMG, 3)
        y = np.asarray(y)
        assert y.min() >= 0 and y.max() < M.NCLASS

    def test_eval_split_disjoint(self):
        # eval seeds live in a disjoint seed space
        xt, _ = T.synth_batch(jnp.int32(5))
        xe, _ = T.synth_batch(jnp.int32(T.EVAL_SEED_BASE + 5))
        assert not np.array_equal(np.asarray(xt), np.asarray(xe))


class TestTrainStep:
    def test_loss_decreases_fp32(self):
        params, _, lspecs = M.build("mlp", seed=1)
        moms = [jnp.zeros_like(p) for p in params]
        q = M.make_qcfg(len(lspecs))
        step = jax.jit(T.make_train_step("mlp"))
        first = None
        for i in range(30):
            params, moms, loss, _ = step(params, moms, jnp.int32(i), q,
                                         jnp.float32(0.05))
            if first is None:
                first = float(loss)
        assert float(loss) < first

    def test_qat_trains_with_quant_enabled(self):
        params, _, lspecs = M.build("mlp", seed=2)
        nl = len(lspecs)
        moms = [jnp.zeros_like(p) for p in params]
        q = M.make_qcfg(nl)
        q["wluts"] = jnp.asarray(np.tile(F.padded_lut("dybit", 4), (nl, 1)))
        q["wq_en"] = jnp.ones((nl,), jnp.float32)
        step = jax.jit(T.make_train_step("mlp"))
        losses = []
        for i in range(30):
            params, moms, loss, _ = step(params, moms, jnp.int32(i), q,
                                         jnp.float32(0.05))
            losses.append(float(loss))
        assert losses[-1] < losses[0]
        assert all(np.isfinite(l) for l in losses)

    def test_eval_step_runs(self):
        params, _, lspecs = M.build("mlp")
        q = M.make_qcfg(len(lspecs))
        ev = jax.jit(T.make_eval_step("mlp"))
        loss, acc = ev(params, jnp.int32(0), q)
        assert np.isfinite(float(loss))
        assert 0.0 <= float(acc) <= 1.0
