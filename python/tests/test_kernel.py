"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

hypothesis sweeps shapes, scales, formats and bitwidths; every case must
match `ref.py` to float tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import formats as F
from compile.kernels import ref
from compile.kernels.fake_quant import fake_quant_pallas
from compile.kernels.qgemm import qgemm_pallas


def lut_for(fmt, n):
    return jnp.asarray(F.padded_lut(fmt, n))


class TestFakeQuantKernel:
    @given(
        shape=st.sampled_from([(7,), (64,), (33, 9), (8, 128), (3, 5, 7),
                               (1, 1), (257,), (2, 2, 2, 2)]),
        fmt=st.sampled_from(list(F.FORMATS)),
        bits=st.sampled_from([2, 3, 4, 8]),
        scale=st.floats(1e-3, 50.0),
        seed=st.integers(0, 2 ** 20),
    )
    @settings(max_examples=40, deadline=None)
    def test_matches_ref_over_shapes_formats(self, shape, fmt, bits, scale,
                                             seed):
        if fmt in ("adaptivfloat", "flint") and bits == 2:
            bits = 3
        rs = np.random.RandomState(seed)
        x = jnp.asarray(rs.randn(*shape).astype(np.float32) * 3)
        lut = lut_for(fmt, bits)
        s = jnp.float32(scale)
        got = fake_quant_pallas(x, lut, s)
        want = ref.quantize_to_lut(x, lut, s)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
        assert got.shape == x.shape

    def test_values_land_on_scaled_grid(self):
        rs = np.random.RandomState(0)
        x = jnp.asarray(rs.randn(500).astype(np.float32))
        lut = lut_for("dybit", 4)
        y = np.asarray(fake_quant_pallas(x, lut, jnp.float32(0.5)))
        grid = F.grid("dybit", 4) * 0.5
        d = np.abs(y[:, None] - grid[None, :]).min(1)
        assert d.max() < 1e-6

    def test_idempotent(self):
        rs = np.random.RandomState(1)
        x = jnp.asarray(rs.randn(100).astype(np.float32))
        lut = lut_for("dybit", 4)
        y1 = fake_quant_pallas(x, lut, jnp.float32(1.0))
        y2 = fake_quant_pallas(y1, lut, jnp.float32(1.0))
        np.testing.assert_allclose(y1, y2, rtol=0, atol=0)

    def test_zero_maps_to_zero(self):
        x = jnp.zeros((16,), jnp.float32)
        for fmt in F.FORMATS:
            y = fake_quant_pallas(x, lut_for(fmt, 4), jnp.float32(2.0))
            np.testing.assert_array_equal(np.asarray(y), 0.0)

    def test_matches_numpy_formats_reference(self):
        # three-way agreement: pallas kernel == jnp ref == numpy formats.py
        rs = np.random.RandomState(2)
        x = rs.randn(300).astype(np.float32)
        g = F.grid("dybit", 4)
        s = 0.7
        want_np = F.quantize_to_grid(x, g, s)
        got = np.asarray(fake_quant_pallas(jnp.asarray(x), lut_for("dybit", 4),
                                           jnp.float32(s)))
        np.testing.assert_allclose(got, want_np, rtol=1e-6, atol=1e-6)


class TestQGemmKernel:
    @given(
        m=st.integers(1, 70),
        k=st.integers(1, 90),
        n=st.integers(1, 70),
        bits=st.sampled_from([2, 4, 8]),
        seed=st.integers(0, 2 ** 20),
    )
    @settings(max_examples=25, deadline=None)
    def test_matches_ref(self, m, k, n, bits, seed):
        rs = np.random.RandomState(seed)
        x = jnp.asarray(rs.randn(m, k).astype(np.float32))
        codes = jnp.asarray(rs.randint(0, 1 << bits, size=(k, n)),
                            dtype=jnp.int32)
        lc = np.zeros(F.LUT_SIZE, np.float32)
        for c in range(1 << bits):
            lc[c] = F.dybit_decode_code(c, bits)
        lc = jnp.asarray(lc)
        s = jnp.float32(0.3)
        got = qgemm_pallas(x, codes, lc, s)
        want = ref.qgemm_ref(x, codes, lc, s)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_zero_codes_give_zero_output(self):
        x = jnp.ones((8, 16), jnp.float32)
        codes = jnp.zeros((16, 8), jnp.int32)
        lc = jnp.asarray(np.zeros(F.LUT_SIZE, np.float32))
        y = qgemm_pallas(x, codes, lc, jnp.float32(1.0))
        np.testing.assert_array_equal(np.asarray(y), 0.0)

    def test_mxu_sized_blocks(self):
        # a 256x256x256 problem exercises multi-tile grid accumulation
        rs = np.random.RandomState(3)
        x = jnp.asarray(rs.randn(256, 256).astype(np.float32))
        codes = jnp.asarray(rs.randint(0, 16, size=(256, 256)), jnp.int32)
        lc = np.zeros(F.LUT_SIZE, np.float32)
        for c in range(16):
            lc[c] = F.dybit_decode_code(c, 4)
        got = qgemm_pallas(x, codes, jnp.asarray(lc), jnp.float32(0.1))
        want = ref.qgemm_ref(x, codes, jnp.asarray(lc), jnp.float32(0.1))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


class TestSTE:
    def test_fake_quant_gradient_is_masked_identity(self):
        lut = lut_for("dybit", 4)
        s = jnp.float32(0.5)  # representable range: ±4*0.5 = ±2

        def f(x):
            return jnp.sum(ref.fake_quant_ref(x, lut, s))

        x = jnp.asarray([0.3, -1.5, 5.0, -7.0, 1.9], jnp.float32)
        g = jax.grad(f)(x)
        np.testing.assert_array_equal(np.asarray(g),
                                      [1.0, 1.0, 0.0, 0.0, 1.0])

    def test_weight_fq_enable_flag(self):
        lut = lut_for("dybit", 4)
        w = jnp.asarray(np.random.RandomState(4).randn(32).astype(np.float32))
        off = ref.weight_fake_quant_ref(w, lut, jnp.float32(0.0))
        np.testing.assert_array_equal(np.asarray(off), np.asarray(w))
        on = ref.weight_fake_quant_ref(w, lut, jnp.float32(1.0))
        assert not np.array_equal(np.asarray(on), np.asarray(w))
