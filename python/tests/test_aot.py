"""AOT path tests: HLO text emission + manifest consistency.

Kept light (one small model) — the full emission is exercised by
`make artifacts`; the heavyweight contract checks live on the rust side
(tests/golden.rs, tests/runtime_integration.rs).
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M


def test_to_hlo_text_emits_parseable_module():
    lowered = jax.jit(lambda a, b: (a @ b,)).lower(
        jax.ShapeDtypeStruct((4, 4), jnp.float32),
        jax.ShapeDtypeStruct((4, 4), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ROOT" in text
    # text (not proto) is the 0.5.1-safe interchange — must be pure ASCII
    text.encode("ascii")


def test_lower_model_manifest_entry(tmp_path):
    entry = aot.lower_model("mlp", str(tmp_path), pallas_fwd=False)
    # all four artifacts present with I/O specs
    assert set(entry["artifacts"]) == {"fwd", "fwd_acts", "train", "eval"}
    for tag, art in entry["artifacts"].items():
        path = tmp_path / art["file"]
        assert path.exists(), tag
        assert path.stat().st_size > 1000
        assert art["inputs"] and art["outputs"]
    # params.bin is exactly the concatenation of the leaves
    total = entry["params_total_elems"]
    assert (tmp_path / entry["params_file"]).stat().st_size == total * 4
    # layer count consistent
    assert entry["n_quant_layers"] == len(entry["layers"])


def test_train_io_signature_matches_convention(tmp_path):
    entry = aot.lower_model("mlp", str(tmp_path), pallas_fwd=False)
    ins = [i["name"] for i in entry["artifacts"]["train"]["inputs"]]
    np_ = len(entry["params"])
    # params, moms, seed, qcfg (5), lr
    assert len(ins) == 2 * np_ + 7
    assert ins[2 * np_] == "seed"
    assert ins[2 * np_ + 1:2 * np_ + 6] == [
        "wluts", "aluts", "ascales", "wq_en", "aq_en"]
    assert ins[-1] == "lr"


def test_existing_artifacts_dir_consistent():
    """If `make artifacts` has run, the manifest must match the models."""
    path = os.path.join(os.path.dirname(__file__), "..", "..",
                        "artifacts", "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    with open(path) as f:
        manifest = json.load(f)
    assert manifest["lut_size"] == 256
    for name, entry in manifest["models"].items():
        assert name in M.MODELS
        _, _, lspecs = M.build(name)
        assert entry["n_quant_layers"] == len(lspecs), name
        got = [(l["name"], l["m"], l["k"], l["n"]) for l in entry["layers"]]
        want = [(l.name, l.m, l.k, l.n) for l in lspecs]
        assert got == want, f"{name}: layer specs drifted — re-run make artifacts"
    # data_batch artifact registered
    assert manifest["data_batch"]["outputs"] == ["x", "y"]
