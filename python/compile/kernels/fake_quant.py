"""Pallas LUT fake-quantization kernel (L1).

The TPU re-expression of the paper's mixed-precision decoder (Fig. 3b):
instead of a leading-one detector + shifter at the systolic-array edge, the
nonuniform DyBit grid lives in VMEM as a 256-entry LUT and decoding is a
branchless binary search + gather.  One kernel serves every format and
bitwidth because the grid is *data* (see DESIGN.md §2).

Kernel contract (must match ``ref.quantize_to_lut``):
    out = lut[searchsorted(midpoints(lut), x, side="right")]

Scale handling lives in the wrapper: q(x, lut, s) = s * q(x/s, lut, 1), so
the kernel body stays scale-free and the scalar never enters VMEM.

interpret=True everywhere (CPU PJRT cannot run Mosaic custom-calls); the
BlockSpec structure is still written for TPU: (8,128) f32 tiles = one VPU
register row, LUT replicated per-block in VMEM (1 KiB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import quantize_to_lut

LUT_SIZE = 256
_BLOCK_R = 8     # sublane dimension of a f32 VPU tile
_BLOCK_C = 128   # lane dimension


def _fq_kernel(x_ref, lut_ref, o_ref):
    """Branchless binary search of each element into the LUT midpoints."""
    x = x_ref[...]
    lut = lut_ref[...]
    mids = (lut[:-1] + lut[1:]) * 0.5                      # [255]
    big = jnp.full((1,), jnp.inf, dtype=mids.dtype)
    mids = jnp.concatenate([mids, big])                    # [256] guard
    # searchsorted(mids, x, "right") = count(mids <= x), via 8 halving steps
    pos = jnp.zeros(x.shape, dtype=jnp.int32)
    for step in (128, 64, 32, 16, 8, 4, 2, 1):
        cand = pos + step
        m = jnp.take(mids, cand - 1)
        pos = jnp.where(m <= x, cand, pos)
    o_ref[...] = jnp.take(lut, pos)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fake_quant_pallas(x: jnp.ndarray, lut: jnp.ndarray, scale: jnp.ndarray,
                      interpret: bool = True) -> jnp.ndarray:
    """Fake-quantize ``x`` onto ``scale*lut`` using the Pallas kernel.

    Accepts any shape/f32 input; pads to (8,128) tile multiples, runs the
    grid, and slices back.  Matches ``ref.quantize_to_lut`` exactly.
    """
    assert lut.shape == (LUT_SIZE,), lut.shape
    orig_shape = x.shape
    s = jnp.maximum(scale, 1e-12).astype(x.dtype)
    flat = (x / s).reshape(-1)
    n = flat.shape[0]
    cols = _BLOCK_C
    rows = -(-n // cols)
    rows_p = -(-rows // _BLOCK_R) * _BLOCK_R
    pad = rows_p * cols - n
    flat = jnp.pad(flat, (0, pad))
    grid_in = flat.reshape(rows_p, cols)

    out = pl.pallas_call(
        _fq_kernel,
        grid=(rows_p // _BLOCK_R,),
        in_specs=[
            pl.BlockSpec((_BLOCK_R, _BLOCK_C), lambda i: (i, 0)),
            pl.BlockSpec((LUT_SIZE,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((_BLOCK_R, _BLOCK_C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows_p, cols), x.dtype),
        interpret=interpret,
    )(grid_in, lut.astype(x.dtype))

    return (out.reshape(-1)[:n] * s).reshape(orig_shape)


def fake_quant_check(x, lut, scale):
    """Convenience: (pallas, ref) pair for tests."""
    return fake_quant_pallas(x, lut, scale), quantize_to_lut(x, lut, scale)
