"""Pallas fused decode-GEMM kernel (L1).

The paper's accelerator keeps weights in low-bit DyBit codes in external
memory and decodes them at the edge of the systolic array (Fig. 3a); MACs
run on decoded values with FP partial sums.  On TPU the same insight is:
codes travel HBM→VMEM at 2/4/8 bits (bandwidth win), a VMEM LUT gather
decodes them, and the MXU consumes the decoded tile — partial sums stay
f32 in the accumulator.  This kernel fuses decode + matmul per tile so the
decoded weights never round-trip to HBM.

Contract (must match ``ref.qgemm_ref``):
    y[M,N] = x[M,K] @ (scale * lut_codes[codes[K,N]])

``lut_codes`` is code-indexed (code -> value), not the sorted quantization
LUT.  Block sizes follow MXU geometry (128-multiples); interpret=True for
CPU execution, structure written for TPU (see DESIGN.md §8).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LUT_SIZE = 256


def _qgemm_kernel(x_ref, codes_ref, lut_ref, o_ref):
    """One (i, j, k) grid step: decode the weight tile, MAC into o_ref."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = jnp.take(lut_ref[...], codes_ref[...])  # VMEM decode (Fig. 3b analogue)
    o_ref[...] += jnp.dot(
        x_ref[...], w, preferred_element_type=jnp.float32
    )


def _pad_to(a: jnp.ndarray, mults: tuple[int, ...]) -> jnp.ndarray:
    pads = [(0, -dim % m) for dim, m in zip(a.shape, mults)]
    return jnp.pad(a, pads)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def qgemm_pallas(x: jnp.ndarray, codes: jnp.ndarray, lut_codes: jnp.ndarray,
                 scale: jnp.ndarray, bm: int = 128, bn: int = 128,
                 bk: int = 128, interpret: bool = True) -> jnp.ndarray:
    """y = x @ (scale * lut_codes[codes]) with tile-fused decode.

    x: [M, K] f32; codes: [K, N] int (any width, values < 256);
    lut_codes: [256] f32; scale: scalar.  Pads to block multiples.
    """
    assert lut_codes.shape == (LUT_SIZE,), lut_codes.shape
    m, k = x.shape
    k2, n = codes.shape
    assert k == k2, (x.shape, codes.shape)
    bm, bn, bk = min(bm, -(-m // 8) * 8), min(bn, -(-n // 128) * 128), min(bk, -(-k // 128) * 128)

    xp = _pad_to(x, (bm, bk))
    cp = _pad_to(codes.astype(jnp.int32), (bk, bn))
    mp, kp = xp.shape
    _, np_ = cp.shape

    out = pl.pallas_call(
        _qgemm_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((LUT_SIZE,), lambda i, j, kk: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(xp, cp, lut_codes.astype(jnp.float32))

    return out[:m, :n] * scale
