"""L1 Pallas kernels + pure-jnp oracles for the DyBit hot paths."""
from . import ref  # noqa: F401
from .fake_quant import fake_quant_pallas  # noqa: F401
from .qgemm import qgemm_pallas  # noqa: F401
