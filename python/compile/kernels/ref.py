"""Pure-jnp oracles for the Pallas kernels (L1 correctness reference).

These functions define the *semantics*; ``fake_quant.py`` / ``qgemm.py``
must match them to float tolerance (pytest + hypothesis enforce this).
They are also what the L2 model uses on the fast XLA path (the Pallas
variants are exercised by the ``*_pallas`` artifacts — see aot.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def lut_midpoints(lut: jnp.ndarray) -> jnp.ndarray:
    """Decision boundaries of an ascending LUT (duplicates collapse)."""
    return (lut[:-1] + lut[1:]) * 0.5


def quantize_to_lut(x: jnp.ndarray, lut: jnp.ndarray,
                    scale) -> jnp.ndarray:
    """Nearest-value projection of x onto scale*lut (no gradient defined)."""
    mids = lut_midpoints(lut) * scale
    idx = jnp.searchsorted(mids, x, side="right")
    return jnp.take(lut, idx) * scale


@jax.custom_vjp
def fake_quant_ref(x: jnp.ndarray, lut: jnp.ndarray,
                   scale: jnp.ndarray) -> jnp.ndarray:
    """Fake-quantize x onto scale*lut with an STE backward.

    Forward: nearest grid point.  Backward: identity inside the grid's
    representable range, zero outside (standard QAT straight-through
    estimator; the clip mask is what keeps weights from drifting past
    the format's max — cf. paper Sec. III-C).
    """
    return quantize_to_lut(x, lut, scale)


def _fq_fwd(x, lut, scale):
    lim = jnp.max(jnp.abs(lut)) * scale
    return quantize_to_lut(x, lut, scale), (x, lim)


def _fq_bwd(res, g):
    x, lim = res
    mask = (jnp.abs(x) <= lim).astype(g.dtype)
    return (g * mask, None, None)


fake_quant_ref.defvjp(_fq_fwd, _fq_bwd)


def weight_fake_quant_ref(w: jnp.ndarray, lut: jnp.ndarray,
                          enable: jnp.ndarray) -> jnp.ndarray:
    """Weight path: per-tensor scale derived in-graph (max-abs onto grid max).

    ``enable`` is a scalar {0,1} runtime switch so one HLO serves both the
    FP32 baseline and every quantized config.
    """
    gmax = jnp.max(jnp.abs(lut))
    s = jnp.max(jnp.abs(w)) / jnp.maximum(gmax, 1e-12)
    s = jnp.maximum(s, 1e-12)
    wq = fake_quant_ref(w, lut, s)
    return enable * wq + (1.0 - enable) * w


def act_fake_quant_ref(x: jnp.ndarray, lut: jnp.ndarray,
                       scale: jnp.ndarray, enable: jnp.ndarray) -> jnp.ndarray:
    """Activation path: calibrated per-tensor scale supplied at runtime."""
    xq = fake_quant_ref(x, lut, jnp.maximum(scale, 1e-12))
    return enable * xq + (1.0 - enable) * x


def qgemm_ref(x: jnp.ndarray, codes: jnp.ndarray, lut_codes: jnp.ndarray,
              scale: jnp.ndarray) -> jnp.ndarray:
    """Decode-and-GEMM oracle: y = x @ (scale * lut_codes[codes]).

    ``codes`` are integer format codes (e.g. signed DyBit codes) of shape
    [K, N]; ``lut_codes`` maps code -> value (code-indexed, NOT the sorted
    quantization LUT).  This is the accelerator's decoder-feeds-MACs path
    (paper Fig. 3) as one fused op.
    """
    w = jnp.take(lut_codes, codes.astype(jnp.int32)) * scale
    return jnp.dot(x, w.astype(x.dtype), precision=jax.lax.Precision.HIGHEST)
