"""Minimal functional NN library with per-layer quantization hooks (L2).

Every quantizable op (conv / depthwise / grouped conv / dense) is assigned a
layer index in construction order.  At apply time, layer ``l`` fake-quantizes
its weight with ``wluts[l]`` (scale derived in-graph from max-abs) and its
input activation with ``(aluts[l], ascales[l])``; per-layer enable flags let
one HLO serve FP32 and every quantized config (DESIGN.md §2).

The same construction pass records each layer's GEMM geometry after im2col
(M = OH·OW per image, K = kh·kw·Cin/groups, N = Cout) — this is the layer
descriptor list the rust cycle-accurate simulator consumes via
``artifacts/manifest.json``, so python and rust can never disagree about
layer shapes.

Params are a flat *list* of arrays in creation order (the HLO boundary and
the ``*_params.bin`` interchange format both use this order).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import ref as kref
from .kernels.fake_quant import fake_quant_pallas

LUT_SIZE = 256


@dataclasses.dataclass
class LayerSpec:
    """Descriptor of one quantizable layer (simulator interchange unit)."""
    name: str
    kind: str      # conv | dwconv | gconv | dense
    m: int         # GEMM rows per image (OH*OW, or 1 for dense-on-vector)
    k: int         # GEMM reduction (kh*kw*cin/groups)
    n: int         # GEMM cols (cout)
    groups: int    # 1 for conv/dense; cin for dwconv; >1 for gconv
    macs: int      # per-image multiply-accumulates
    act_elems: int  # per-image input-activation element count

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ParamSpec:
    name: str
    shape: tuple[int, ...]

    def to_json(self) -> dict:
        return {"name": self.name, "shape": list(self.shape)}


class Ctx:
    """Build/apply context.

    mode="init": records ParamSpec/LayerSpec and materializes initial params.
    mode="apply": consumes ``params`` sequentially and applies quantization
    from ``qcfg`` = dict(wluts, aluts, ascales, wq_en, aq_en).
    """

    def __init__(self, mode: str, key=None, params=None, qcfg=None,
                 pallas: bool = False):
        assert mode in ("init", "apply")
        self.mode = mode
        self.key = key
        self.params_in = list(params) if params is not None else None
        self.pi = 0
        self.qcfg = qcfg
        self.qi = 0                      # quantizable-layer cursor
        self.pallas = pallas
        self.param_specs: list[ParamSpec] = []
        self.layer_specs: list[LayerSpec] = []
        self.init_params: list[jnp.ndarray] = []
        self.act_taps: list[jnp.ndarray] = []  # per-layer input acts (fwd_acts)

    # -- parameters ---------------------------------------------------------

    def param(self, name: str, shape: tuple[int, ...],
              init_fn: Callable) -> jnp.ndarray:
        if self.mode == "init":
            self.key, sub = jax.random.split(self.key)
            p = init_fn(sub, shape).astype(jnp.float32)
            self.param_specs.append(ParamSpec(name, tuple(shape)))
            self.init_params.append(p)
            return p
        p = self.params_in[self.pi]
        self.pi += 1
        return p

    # -- quantization hooks -------------------------------------------------

    def _quant_idx(self) -> int:
        qi = self.qi
        self.qi += 1
        return qi

    def _fq_weight(self, w: jnp.ndarray, qi: int) -> jnp.ndarray:
        if self.qcfg is None:
            return w
        lut = self.qcfg["wluts"][qi]
        en = self.qcfg["wq_en"][qi]
        if self.pallas:
            gmax = jnp.max(jnp.abs(lut))
            s = jnp.maximum(jnp.max(jnp.abs(w)) / jnp.maximum(gmax, 1e-12),
                            1e-12)
            wq = fake_quant_pallas(w, lut, s)
            return en * wq + (1.0 - en) * w
        return kref.weight_fake_quant_ref(w, lut, en)

    def _fq_act(self, x: jnp.ndarray, qi: int) -> jnp.ndarray:
        if self.qcfg is None:
            return x
        lut = self.qcfg["aluts"][qi]
        s = self.qcfg["ascales"][qi]
        en = self.qcfg["aq_en"][qi]
        if self.pallas:
            xq = fake_quant_pallas(x, lut, jnp.maximum(s, 1e-12))
            return en * xq + (1.0 - en) * x
        return kref.act_fake_quant_ref(x, lut, s, en)

    def _tap(self, x: jnp.ndarray):
        """Record a strided ≤2048-element sample of the pre-quant activation.

        fwd_acts exposes these so the rust side can calibrate activation
        scales and estimate per-layer activation RMSE for the search engine
        without shipping full feature maps across the boundary.
        """
        flat = x.reshape(-1)
        n = flat.shape[0]
        if n >= 2048:
            stride = n // 2048
            samp = jax.lax.slice(flat, (0,), (2048 * stride,), (stride,))
        else:
            samp = jnp.pad(flat, (0, 2048 - n), mode="wrap")
        self.act_taps.append(samp)

    # -- layers ---------------------------------------------------------

    def conv(self, x: jnp.ndarray, name: str, cout: int, ksize: int,
             stride: int = 1, groups: int = 1, use_bias: bool = True,
             padding: str = "SAME") -> jnp.ndarray:
        """NHWC conv with weight+activation fake-quant. Returns pre-act."""
        cin = x.shape[-1]
        assert cin % groups == 0 and cout % groups == 0
        fan_in = ksize * ksize * cin // groups
        w = self.param(
            f"{name}.w", (ksize, ksize, cin // groups, cout),
            lambda k, s: jax.random.normal(k, s) * math.sqrt(2.0 / fan_in))
        b = self.param(f"{name}.b", (cout,),
                       lambda k, s: jnp.zeros(s)) if use_bias else None
        qi = self._quant_idx()
        if self.mode == "init":
            hw = x.shape[1]
            ohw = hw // stride if padding == "SAME" else (hw - ksize) // stride + 1
            kind = ("dwconv" if groups == cin and groups == cout
                    else ("gconv" if groups > 1 else "conv"))
            m, kk, n = ohw * ohw, fan_in, cout
            self.layer_specs.append(LayerSpec(
                name, kind, m, kk, n, groups,
                macs=m * kk * n,  # per-image; groups already folded into K
                act_elems=int(x.shape[1] * x.shape[2] * cin)))
        else:
            self._tap(x)
        xq = self._fq_act(x, qi)
        wq = self._fq_weight(w, qi)
        y = jax.lax.conv_general_dilated(
            xq, wq, window_strides=(stride, stride), padding=padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=groups)
        if b is not None:
            y = y + b
        return y

    def dense(self, x: jnp.ndarray, name: str, cout: int,
              use_bias: bool = True) -> jnp.ndarray:
        cin = x.shape[-1]
        w = self.param(
            f"{name}.w", (cin, cout),
            lambda k, s: jax.random.normal(k, s) * math.sqrt(2.0 / cin))
        b = self.param(f"{name}.b", (cout,),
                       lambda k, s: jnp.zeros(s)) if use_bias else None
        qi = self._quant_idx()
        if self.mode == "init":
            m = math.prod(x.shape[1:-1]) if x.ndim > 2 else 1
            self.layer_specs.append(LayerSpec(
                name, "dense", m, cin, cout, 1,
                macs=m * cin * cout,
                act_elems=math.prod(x.shape[1:])))
        else:
            self._tap(x)
        xq = self._fq_act(x, qi)
        wq = self._fq_weight(w, qi)
        y = xq @ wq
        if b is not None:
            y = y + b
        return y

    # -- norms / misc (not quantized; scale/shift stay FP as in the paper's
    #    accelerator, which keeps partial sums and norms in FP) -------------

    def groupnorm(self, x: jnp.ndarray, name: str, groups: int = 8,
                  eps: float = 1e-5) -> jnp.ndarray:
        c = x.shape[-1]
        g = min(groups, c)
        while c % g:
            g -= 1
        gamma = self.param(f"{name}.g", (c,), lambda k, s: jnp.ones(s))
        beta = self.param(f"{name}.b", (c,), lambda k, s: jnp.zeros(s))
        shp = x.shape[:-1] + (g, c // g)
        xg = x.reshape(shp)
        mu = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
        var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
        xn = ((xg - mu) / jnp.sqrt(var + eps)).reshape(x.shape)
        return xn * gamma + beta

    def layernorm(self, x: jnp.ndarray, name: str,
                  eps: float = 1e-5) -> jnp.ndarray:
        c = x.shape[-1]
        gamma = self.param(f"{name}.g", (c,), lambda k, s: jnp.ones(s))
        beta = self.param(f"{name}.b", (c,), lambda k, s: jnp.zeros(s))
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True)
        return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def relu(x):
    return jax.nn.relu(x)


def gelu(x):
    return jax.nn.gelu(x)


def avgpool_global(x):
    """NHWC global average pool -> [B, C]."""
    return jnp.mean(x, axis=(1, 2))
