"""L2 models: scaled-down stand-ins for the paper's benchmark networks.

Table II models — MobileNetV2, ResNet18, ResNet50 — and Table III models —
RegNet-3.2GF, ConvNext-Tiny, ViT-Base — are reproduced as ~0.1–1M-parameter
versions with the same *layer vocabulary* (residual convs, bottlenecks,
inverted residuals + depthwise, grouped convs, LN+dw7×7 ConvNext blocks,
MHSA) so that (a) weight/activation distributions exercise each format the
same way and (b) the simulator sees the same layer-kind mix (depthwise
layers are what caps MobileNet speedup in the paper's Fig. 6).
Substitution rationale: DESIGN.md §6.

All models consume NHWC f32 [B, 24, 24, 3] and emit 10-class logits.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import nn
from .nn import Ctx, avgpool_global, gelu, relu

IMG = 24
NCLASS = 10
BATCH = 32


# ---------------------------------------------------------------------------
# model bodies (shared between init and apply via Ctx)
# ---------------------------------------------------------------------------

def _mlp(ctx: Ctx, x: jnp.ndarray) -> jnp.ndarray:
    h = x.reshape(x.shape[0], -1)
    h = relu(ctx.dense(h, "fc1", 256))
    h = relu(ctx.dense(h, "fc2", 128))
    return ctx.dense(h, "head", NCLASS)


def _basic_block(ctx: Ctx, x, name: str, cout: int, stride: int):
    """ResNet-18-style basic block with GroupNorm."""
    h = ctx.conv(x, f"{name}.c1", cout, 3, stride=stride)
    h = relu(ctx.groupnorm(h, f"{name}.n1"))
    h = ctx.conv(h, f"{name}.c2", cout, 3)
    h = ctx.groupnorm(h, f"{name}.n2")
    if stride != 1 or x.shape[-1] != cout:
        x = ctx.conv(x, f"{name}.sc", cout, 1, stride=stride)
    return relu(h + x)


def _miniresnet18(ctx: Ctx, x: jnp.ndarray) -> jnp.ndarray:
    h = relu(ctx.groupnorm(ctx.conv(x, "stem", 16, 3), "stem.n"))
    for si, (c, s) in enumerate([(16, 1), (32, 2), (64, 2)]):
        for bi in range(2):
            h = _basic_block(ctx, h, f"s{si}b{bi}", c, s if bi == 0 else 1)
    return ctx.dense(avgpool_global(h), "head", NCLASS)


def _bottleneck(ctx: Ctx, x, name: str, cmid: int, cout: int, stride: int):
    """ResNet-50-style bottleneck (1x1 -> 3x3 -> 1x1, expansion 2)."""
    h = relu(ctx.groupnorm(ctx.conv(x, f"{name}.c1", cmid, 1), f"{name}.n1"))
    h = relu(ctx.groupnorm(ctx.conv(h, f"{name}.c2", cmid, 3, stride=stride),
                           f"{name}.n2"))
    h = ctx.groupnorm(ctx.conv(h, f"{name}.c3", cout, 1), f"{name}.n3")
    if stride != 1 or x.shape[-1] != cout:
        x = ctx.conv(x, f"{name}.sc", cout, 1, stride=stride)
    return relu(h + x)


def _miniresnet50(ctx: Ctx, x: jnp.ndarray) -> jnp.ndarray:
    h = relu(ctx.groupnorm(ctx.conv(x, "stem", 16, 3), "stem.n"))
    for si, (cm, c, s) in enumerate([(8, 32, 1), (16, 64, 2), (32, 128, 2)]):
        for bi in range(2):
            h = _bottleneck(ctx, h, f"s{si}b{bi}", cm, c, s if bi == 0 else 1)
    return ctx.dense(avgpool_global(h), "head", NCLASS)


def _inverted_residual(ctx: Ctx, x, name: str, cout: int, stride: int,
                       expand: int = 4):
    """MobileNetV2 inverted residual: expand 1x1 -> dw 3x3 -> project 1x1."""
    cin = x.shape[-1]
    cmid = cin * expand
    h = relu(ctx.groupnorm(ctx.conv(x, f"{name}.exp", cmid, 1), f"{name}.n1"))
    h = ctx.conv(h, f"{name}.dw", cmid, 3, stride=stride, groups=cmid)
    h = relu(ctx.groupnorm(h, f"{name}.n2"))
    h = ctx.groupnorm(ctx.conv(h, f"{name}.proj", cout, 1), f"{name}.n3")
    if stride == 1 and cin == cout:
        h = h + x
    return h


def _micromobilenet(ctx: Ctx, x: jnp.ndarray) -> jnp.ndarray:
    h = relu(ctx.groupnorm(ctx.conv(x, "stem", 16, 3, stride=1), "stem.n"))
    for bi, (c, s) in enumerate([(16, 1), (24, 2), (24, 1), (32, 2), (32, 1)]):
        h = _inverted_residual(ctx, h, f"ir{bi}", c, s)
    h = relu(ctx.groupnorm(ctx.conv(h, "headconv", 64, 1), "head.n"))
    return ctx.dense(avgpool_global(h), "head", NCLASS)


def _mhsa(ctx: Ctx, x, name: str, dim: int, heads: int):
    """Multi-head self-attention; qkv/proj are quantizable dense layers."""
    b, t, _ = x.shape
    qkv = ctx.dense(x, f"{name}.qkv", dim * 3, use_bias=True)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    hd = dim // heads

    def heads_split(a):
        return a.reshape(b, t, heads, hd).transpose(0, 2, 1, 3)

    q, k, v = heads_split(q), heads_split(k), heads_split(v)
    att = jax.nn.softmax(q @ k.transpose(0, 1, 3, 2) / jnp.sqrt(hd), axis=-1)
    o = (att @ v).transpose(0, 2, 1, 3).reshape(b, t, dim)
    return ctx.dense(o, f"{name}.proj", dim)


def _vit_block(ctx: Ctx, x, name: str, dim: int, heads: int, mlp_ratio: int):
    h = x + _mhsa(ctx, ctx.layernorm(x, f"{name}.ln1"), name, dim, heads)
    m = ctx.layernorm(h, f"{name}.ln2")
    m = gelu(ctx.dense(m, f"{name}.fc1", dim * mlp_ratio))
    m = ctx.dense(m, f"{name}.fc2", dim)
    return h + m


def _tinyvit(ctx: Ctx, x: jnp.ndarray) -> jnp.ndarray:
    dim, heads, depth = 64, 4, 4
    h = ctx.conv(x, "patch", dim, 4, stride=4, padding="VALID")  # 6x6 tokens
    b = h.shape[0]
    h = h.reshape(b, -1, dim)
    pos = ctx.param("pos", (1, h.shape[1], dim),
                    lambda k, s: 0.02 * jax.random.normal(k, s))
    h = h + pos
    for d in range(depth):
        h = _vit_block(ctx, h, f"blk{d}", dim, heads, 2)
    h = ctx.layernorm(h, "ln_f")
    return ctx.dense(jnp.mean(h, axis=1), "head", NCLASS)


def _regnet_block(ctx: Ctx, x, name: str, cout: int, stride: int,
                  groups: int):
    """RegNet X block: 1x1 -> grouped 3x3 -> 1x1 with residual."""
    h = relu(ctx.groupnorm(ctx.conv(x, f"{name}.c1", cout, 1), f"{name}.n1"))
    h = relu(ctx.groupnorm(
        ctx.conv(h, f"{name}.c2", cout, 3, stride=stride, groups=groups),
        f"{name}.n2"))
    h = ctx.groupnorm(ctx.conv(h, f"{name}.c3", cout, 1), f"{name}.n3")
    if stride != 1 or x.shape[-1] != cout:
        x = ctx.conv(x, f"{name}.sc", cout, 1, stride=stride)
    return relu(h + x)


def _microregnet(ctx: Ctx, x: jnp.ndarray) -> jnp.ndarray:
    h = relu(ctx.groupnorm(ctx.conv(x, "stem", 16, 3), "stem.n"))
    for si, (c, s) in enumerate([(24, 1), (48, 2), (96, 2)]):
        h = _regnet_block(ctx, h, f"s{si}", c, s, groups=8)
    return ctx.dense(avgpool_global(h), "head", NCLASS)


def _convnext_block(ctx: Ctx, x, name: str, dim: int):
    """ConvNext block: dw7x7 -> LN -> pw expand 2x -> GELU -> pw project."""
    h = ctx.conv(x, f"{name}.dw", dim, 7, groups=dim)
    h = ctx.layernorm(h, f"{name}.ln")
    h = gelu(ctx.conv(h, f"{name}.pw1", dim * 2, 1))
    h = ctx.conv(h, f"{name}.pw2", dim, 1)
    return x + h


def _microconvnext(ctx: Ctx, x: jnp.ndarray) -> jnp.ndarray:
    dim = 48
    h = ctx.conv(x, "stem", dim, 4, stride=4, padding="VALID")  # 6x6
    h = ctx.layernorm(h, "stem.ln")
    for d in range(3):
        h = _convnext_block(ctx, h, f"blk{d}", dim)
    h = ctx.layernorm(h, "ln_f")
    return ctx.dense(avgpool_global(h), "head", NCLASS)


# ---------------------------------------------------------------------------
# registry + public API
# ---------------------------------------------------------------------------

# model name -> (body fn, paper model it stands in for)
MODELS = {
    "mlp": (_mlp, "quickstart MLP"),
    "miniresnet18": (_miniresnet18, "ResNet18"),
    "miniresnet50": (_miniresnet50, "ResNet50"),
    "micromobilenet": (_micromobilenet, "MobileNetV2"),
    "tinyvit": (_tinyvit, "ViT-Base"),
    "microregnet": (_microregnet, "RegNet-3.2GF"),
    "microconvnext": (_microconvnext, "ConvNext-Tiny"),
}


def build(name: str, seed: int = 0, batch: int = BATCH):
    """Initialize a model: returns (params, param_specs, layer_specs)."""
    body, _ = MODELS[name]
    ctx = Ctx("init", key=jax.random.PRNGKey(seed))
    x = jnp.zeros((batch, IMG, IMG, 3), jnp.float32)
    body(ctx, x)
    return ctx.init_params, ctx.param_specs, ctx.layer_specs


def num_quant_layers(name: str) -> int:
    return len(build(name)[2])


def apply(name: str, params, x, qcfg=None, pallas: bool = False,
          with_acts: bool = False):
    """Forward pass.  qcfg=None means pure FP32.

    with_acts=True also returns the [L, 2048] matrix of strided pre-quant
    activation samples (calibration/RMSE taps for the rust search engine).
    """
    body, _ = MODELS[name]
    ctx = Ctx("apply", params=params, qcfg=qcfg, pallas=pallas)
    logits = body(ctx, x)
    if with_acts:
        taps = (jnp.stack(ctx.act_taps) if ctx.act_taps
                else jnp.zeros((0, 2048), jnp.float32))
        return logits, taps
    return logits


def make_qcfg(n_layers: int, lut_size: int = nn.LUT_SIZE):
    """All-FP32 (disabled) quantization config of the right shapes."""
    return {
        "wluts": jnp.zeros((n_layers, lut_size), jnp.float32),
        "aluts": jnp.zeros((n_layers, lut_size), jnp.float32),
        "ascales": jnp.ones((n_layers,), jnp.float32),
        "wq_en": jnp.zeros((n_layers,), jnp.float32),
        "aq_en": jnp.zeros((n_layers,), jnp.float32),
    }
