"""AOT compiler: lower every model/step to HLO text + write the manifest.

This is the only python that ever needs to run; after ``make artifacts`` the
rust binary is self-contained.  Per model we emit:

  <model>_fwd.hlo.txt        (params.., x, qcfg..)           -> logits
  <model>_fwd_acts.hlo.txt   (params.., x, qcfg..)           -> logits, taps
  <model>_train.hlo.txt      (params.., moms.., seed, qcfg.., lr)
                              -> new_params.., new_moms.., loss, acc
  <model>_eval.hlo.txt       (params.., seed, qcfg..)        -> loss, acc
  <model>_params.bin          initial parameters (f32 LE, leaf order)

plus ``mlp_fwd_pallas.hlo.txt`` / ``miniresnet18_fwd_pallas.hlo.txt`` (the
L1 Pallas fake-quant path lowered into the model), two standalone kernel
artifacts for rust-side kernel tests/benches, ``formats_golden.json`` (grid
+ codec vectors for the bit-exact rust cross-check) and ``manifest.json``
describing every artifact's I/O signature, parameter leaves and layer
geometry.  qcfg input order is always: wluts, aluts, ascales, wq_en, aq_en.

Interchange is HLO *text*: the image's xla_extension 0.5.1 rejects jax>=0.5
serialized protos (64-bit instruction ids); the text parser reassigns ids.
Lowered with return_tuple=True; rust unwraps the tuple.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import formats as F
from . import model as M
from . import train as T
from .kernels.fake_quant import fake_quant_pallas
from .kernels.qgemm import qgemm_pallas

LUT = F.LUT_SIZE


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(name, arr):
    return {"name": name, "shape": list(arr.shape),
            "dtype": str(arr.dtype)}


def _qcfg_args(nl):
    """Example qcfg arrays in canonical order."""
    return [
        ("wluts", jnp.zeros((nl, LUT), jnp.float32)),
        ("aluts", jnp.zeros((nl, LUT), jnp.float32)),
        ("ascales", jnp.ones((nl,), jnp.float32)),
        ("wq_en", jnp.zeros((nl,), jnp.float32)),
        ("aq_en", jnp.zeros((nl,), jnp.float32)),
    ]


def _qcfg_dict(args):
    return {k: v for k, v in args}


def lower_model(name: str, outdir: str, pallas_fwd: bool) -> dict:
    """Lower all artifacts for one model; returns its manifest entry."""
    params, pspecs, lspecs = M.build(name)
    nl = len(lspecs)
    entry = {
        "stands_for": M.MODELS[name][1],
        "batch": M.BATCH,
        "input": [M.BATCH, M.IMG, M.IMG, 3],
        "classes": M.NCLASS,
        "n_quant_layers": nl,
        "layers": [ls.to_json() for ls in lspecs],
        "params": [], "artifacts": {},
    }

    # ---- params.bin (f32 LE, leaf order) --------------------------------
    off = 0
    blob = bytearray()
    for spec, p in zip(pspecs, params):
        a = np.asarray(p, dtype=np.float32)
        entry["params"].append({"name": spec.name, "shape": list(spec.shape),
                                "offset": off, "nelems": int(a.size)})
        blob += a.tobytes()
        off += int(a.size)
    pfile = f"{name}_params.bin"
    with open(os.path.join(outdir, pfile), "wb") as f:
        f.write(bytes(blob))
    entry["params_file"] = pfile
    entry["params_total_elems"] = off

    x = jnp.zeros((M.BATCH, M.IMG, M.IMG, 3), jnp.float32)
    qargs = _qcfg_args(nl)
    qvals = [v for _, v in qargs]
    seed = jnp.zeros((), jnp.int32)
    lr = jnp.zeros((), jnp.float32)
    moms = [jnp.zeros_like(p) for p in params]
    np_ = len(params)

    def emit(tag, fn, example_args, in_names, out_names):
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}_{tag}.hlo.txt"
        with open(os.path.join(outdir, fname), "w") as f:
            f.write(text)
        entry["artifacts"][tag] = {
            "file": fname,
            "inputs": [_spec(n, a) for n, a in zip(in_names, example_args)],
            "outputs": out_names,
        }
        print(f"  {fname}: {len(text)} chars, "
              f"{len(example_args)} inputs")

    pnames = [f"p:{s.name}" for s in pspecs]
    mnames = [f"m:{s.name}" for s in pspecs]
    qnames = [k for k, _ in qargs]

    # fwd
    def fwd_flat(*args):
        ps, xx, qv = list(args[:np_]), args[np_], args[np_ + 1:]
        return (M.apply(name, ps, xx, qcfg=_qcfg_dict(zip(qnames, qv))),)

    emit("fwd", fwd_flat, [*params, x, *qvals],
         [*pnames, "x", *qnames], ["logits"])

    # fwd_acts
    def fwd_acts_flat(*args):
        ps, xx, qv = list(args[:np_]), args[np_], args[np_ + 1:]
        return M.apply(name, ps, xx, qcfg=_qcfg_dict(zip(qnames, qv)),
                       with_acts=True)

    emit("fwd_acts", fwd_acts_flat, [*params, x, *qvals],
         [*pnames, "x", *qnames], ["logits", "act_taps"])

    # train step
    tstep = T.make_train_step(name)

    def train_flat(*args):
        ps = list(args[:np_])
        ms = list(args[np_:2 * np_])
        sd = args[2 * np_]
        qv = args[2 * np_ + 1:2 * np_ + 6]
        lr_ = args[2 * np_ + 6]
        nps, nms, loss, acc = tstep(ps, ms, sd, _qcfg_dict(zip(qnames, qv)),
                                    lr_)
        return (*nps, *nms, loss, acc)

    emit("train", train_flat, [*params, *moms, seed, *qvals, lr],
         [*pnames, *mnames, "seed", *qnames, "lr"],
         [*pnames, *mnames, "loss", "acc"])

    # eval step
    estep = T.make_eval_step(name)

    def eval_flat(*args):
        ps = list(args[:np_])
        sd = args[np_]
        qv = args[np_ + 1:]
        loss, acc = estep(ps, sd, _qcfg_dict(zip(qnames, qv)))
        return (loss, acc)

    emit("eval", eval_flat, [*params, seed, *qvals],
         [*pnames, "seed", *qnames], ["loss", "acc"])

    # Pallas-kernel fwd variant (L1 on the inference path)
    if pallas_fwd:
        def fwd_pallas_flat(*args):
            ps, xx, qv = list(args[:np_]), args[np_], args[np_ + 1:]
            return (M.apply(name, ps, xx,
                            qcfg=_qcfg_dict(zip(qnames, qv)), pallas=True),)

        emit("fwd_pallas", fwd_pallas_flat, [*params, x, *qvals],
             [*pnames, "x", *qnames], ["logits"])

    return entry


def lower_data(outdir: str) -> dict:
    """`data_batch.hlo.txt`: seed -> (x, y) — the synthshapes generator as
    a standalone artifact so rust can materialize batches for calibration
    and serving without porting the RNG."""
    seed = jnp.zeros((), jnp.int32)
    lowered = jax.jit(lambda s: T.synth_batch(s)).lower(seed)
    with open(os.path.join(outdir, "data_batch.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    return {"file": "data_batch.hlo.txt",
            "inputs": [_spec("seed", seed)], "outputs": ["x", "y"]}


def lower_kernels(outdir: str) -> dict:
    """Standalone L1 kernel artifacts for rust kernel tests + benches."""
    out = {}
    xk = jnp.zeros((M.BATCH, 4096), jnp.float32)
    lut = jnp.zeros((LUT,), jnp.float32)
    s = jnp.ones((), jnp.float32)
    lowered = jax.jit(
        lambda a, l, sc: (fake_quant_pallas(a, l, sc),)).lower(xk, lut, s)
    with open(os.path.join(outdir, "kernel_fake_quant.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    out["fake_quant"] = {
        "file": "kernel_fake_quant.hlo.txt",
        "inputs": [_spec("x", xk), _spec("lut", lut), _spec("scale", s)],
        "outputs": ["y"]}

    xg = jnp.zeros((64, 256), jnp.float32)
    codes = jnp.zeros((256, 128), jnp.int32)
    lc = jnp.zeros((LUT,), jnp.float32)
    lowered = jax.jit(
        lambda a, c, l, sc: (qgemm_pallas(a, c, l, sc),)).lower(
            xg, codes, lc, s)
    with open(os.path.join(outdir, "kernel_qgemm.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    out["qgemm"] = {
        "file": "kernel_qgemm.hlo.txt",
        "inputs": [_spec("x", xg), _spec("codes", codes),
                   _spec("lut_codes", lc), _spec("scale", s)],
        "outputs": ["y"]}
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/manifest.json",
                    help="manifest path; artifacts land beside it")
    ap.add_argument("--models", default=",".join(M.MODELS),
                    help="comma-separated subset")
    args = ap.parse_args()
    outdir = os.path.dirname(os.path.abspath(args.out))
    os.makedirs(outdir, exist_ok=True)

    manifest = {"lut_size": LUT, "batch": M.BATCH,
                "img": M.IMG, "classes": M.NCLASS,
                "eval_seed_base": T.EVAL_SEED_BASE,
                "momentum": T.MOMENTUM,
                "models": {}, "kernels": {}}

    with open(os.path.join(outdir, "formats_golden.json"), "w") as f:
        json.dump(F.golden_dump(), f)
    print("wrote formats_golden.json")

    print("lowering standalone kernels…")
    manifest["kernels"] = lower_kernels(outdir)
    manifest["data_batch"] = lower_data(outdir)

    for name in args.models.split(","):
        print(f"lowering {name}…")
        manifest["models"][name] = lower_model(
            name, outdir, pallas_fwd=(name in ("mlp", "miniresnet18")))

    with open(args.out, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
