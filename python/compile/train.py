"""QAT training step + synthetic dataset, both lowered into the HLO (L2).

ImageNet substitution (DESIGN.md §6): "synthshapes", a procedurally
generated 10-class oriented-texture dataset.  The generator is *inside* the
lowered computation (jax.random / threefry lowers to plain HLO), so the rust
driver and the python tests see bit-identical batches by construction —
no cross-language RNG porting, and python stays off the request path.

Class signal: orientation + spatial frequency + RGB tint of a Gabor-like
sinusoid, plus per-sample jitter and additive Gaussian noise.  Small conv
nets reach >90% top-1 in a few hundred steps; formats then separate through
QAT exactly as in the paper's protocol (same schedule for every format).

The train step is plain SGD with momentum 0.9 and an STE through every
fake-quant (kernels/ref.py).  Seeds are i32 inputs: train uses seed space
[0, 2^30), eval uses [2^30, ...) — disjoint by construction.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import model as M

MOMENTUM = 0.9
EVAL_SEED_BASE = 1 << 30


def synth_batch(seed: jnp.ndarray, batch: int = M.BATCH):
    """Deterministic batch from an i32 seed: (x [B,24,24,3], y [B] i32)."""
    key = jax.random.PRNGKey(seed)
    ky, kjit, kphase, knoise, ktint = jax.random.split(key, 5)
    y = jax.random.randint(ky, (batch,), 0, M.NCLASS)

    yf = y.astype(jnp.float32)
    theta = yf * (jnp.pi / M.NCLASS) + \
        0.12 * jax.random.normal(kjit, (batch,))
    freq = 2.0 + jnp.mod(yf, 3.0) + \
        0.25 * jax.random.normal(kjit, (batch,))
    phase = jax.random.uniform(kphase, (batch,), minval=0.0,
                               maxval=2.0 * jnp.pi)

    r = jnp.linspace(-1.0, 1.0, M.IMG)
    u, v = jnp.meshgrid(r, r, indexing="ij")              # [H, W]
    ang = (u[None] * jnp.cos(theta)[:, None, None] +
           v[None] * jnp.sin(theta)[:, None, None])       # [B, H, W]
    pattern = jnp.sin(2.0 * jnp.pi * freq[:, None, None] * ang +
                      phase[:, None, None])

    # class-conditioned RGB tint with mild per-sample jitter
    ch = jnp.arange(3, dtype=jnp.float32)
    tint = 0.6 + 0.4 * jnp.cos(yf[:, None] * 0.7 + ch[None, :] * 2.1)
    tint = tint + 0.05 * jax.random.normal(ktint, (batch, 3))

    x = pattern[..., None] * tint[:, None, None, :]
    x = x + 0.8 * jax.random.normal(knoise, x.shape)
    return x.astype(jnp.float32), y


def cross_entropy(logits: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def accuracy(logits: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))


def make_train_step(name: str):
    """(params, moms, seed, qcfg, lr) -> (new_params, new_moms, loss, acc)."""

    def loss_fn(params, x, y, qcfg):
        logits = M.apply(name, params, x, qcfg=qcfg)
        return cross_entropy(logits, y), logits

    def train_step(params, moms, seed, qcfg, lr):
        x, y = synth_batch(seed)
        (loss, logits), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, x, y, qcfg)
        new_moms = [MOMENTUM * m + g for m, g in zip(moms, grads)]
        new_params = [p - lr * m for p, m in zip(params, new_moms)]
        return new_params, new_moms, loss, accuracy(logits, y)

    return train_step


def make_eval_step(name: str):
    """(params, seed, qcfg) -> (loss, acc) on a held-out batch."""

    def eval_step(params, seed, qcfg):
        x, y = synth_batch(EVAL_SEED_BASE + seed)
        logits = M.apply(name, params, x, qcfg=qcfg)
        return cross_entropy(logits, y), accuracy(logits, y)

    return eval_step


def make_fwd(name: str, with_acts: bool = False, pallas: bool = False):
    """(params, x, qcfg) -> logits [, act taps]."""

    def fwd(params, x, qcfg):
        return M.apply(name, params, x, qcfg=qcfg, pallas=pallas,
                       with_acts=with_acts)

    return fwd
