"""Numeric-format value grids for DyBit and the paper's baselines.

This is the build-time (python) mirror of ``rust/src/formats/``.  Every
format is reduced to a *sorted value grid*: the finite set of representable
reals at scale 1.0.  Per-tensor adaptation (Fig. 2 of the paper) multiplies
the grid by a scale ``s``; fake-quantization rounds ``x / s`` to the nearest
grid point.  The grids generated here are exported to
``artifacts/formats_golden.json`` by ``aot.py`` and cross-checked bit-exactly
by the rust test-suite, so the two halves of the system can never drift.

DyBit definition (paper Eqn. 1 + Table I): an n-bit signed DyBit is one sign
bit plus an m = n-1 bit magnitude field.  Let ``i`` be the number of leading
1s in the magnitude field (terminated by the first 0, which is consumed, or
by the end of the field):

* all-zero field            -> 0
* i = 0 (starts with 0)     -> subnormal: remaining m-1 bits are a fraction
                               x, value = x / 2^(m-1)         (linear [0,1))
* i >= 1                    -> k = m - i - 1 fraction bits remain
                               value = 2^(i-1) * (1 + x / 2^k)
* all-ones field            -> i = m, k = 0, value = 2^(m-1)  (Eqn.1 "max")

The 4-bit *unsigned* table (m = 4) reproduces the paper's Table I exactly;
the 8-bit decoder example ``11001010 -> exp 001, mantissa 10101000`` is the
i=2 case.  See ``python/tests/test_formats.py``.
"""

from __future__ import annotations

import math

import numpy as np

LUT_SIZE = 256  # max grid cardinality across supported formats (<= 8 bits)


# ---------------------------------------------------------------------------
# magnitude-field decoders (one per format family)
# ---------------------------------------------------------------------------

def dybit_magnitude(code: int, m: int) -> float:
    """Decode an m-bit DyBit magnitude field (paper Eqn. 1)."""
    if code == 0:
        return 0.0
    # i = number of leading ones in the m-bit field
    i = 0
    for b in range(m - 1, -1, -1):
        if (code >> b) & 1:
            i += 1
        else:
            break
    if i == 0:
        # subnormal: low m-1 bits are the fraction over 2^(m-1)
        x = code & ((1 << (m - 1)) - 1)
        return x / float(1 << (m - 1))
    if i == m:
        return float(1 << (m - 1))  # all-ones: max = 2^(m-1)
    k = m - i - 1  # fraction bits after the consumed terminating zero
    x = code & ((1 << k) - 1)
    return (2.0 ** (i - 1)) * (1.0 + x / float(1 << k)) if k > 0 else 2.0 ** (i - 1)


def dybit_encode_magnitude(value: float, m: int) -> int:
    """Nearest-value encode |value| into an m-bit DyBit magnitude code."""
    grid = [dybit_magnitude(c, m) for c in range(1 << m)]
    order = sorted(range(1 << m), key=lambda c: grid[c])
    best, bestc = None, 0
    for c in order:
        d = abs(grid[c] - value)
        if best is None or d < best:
            best, bestc = d, c
    return bestc


def flint_magnitudes(n: int) -> list[float]:
    """Flint [ANT, Guo et al. 2022] positive grid — our reconstruction.

    ANT's flint is a tapered float-int hybrid.  A literal leading-zero
    unary-exponent reading degenerates to a *uniform* grid at 4 bits, which
    contradicts ANT's own Table results, so we reconstruct flint as the
    nearest well-defined member of the same family: a minifloat with
    subnormals, es = ceil((n-1)/2) exponent bits and n-1-es mantissa bits.
    At n=4 this is E2M1: ±{0.5,1,1.5,2,3,4,6} ∪ {0, ±0.25-denorm} — tapered
    like flint, with a smaller dynamic range and no dense linear segment
    compared to DyBit, which reproduces the paper's DyBit>Flint ordering.
    Documented in DESIGN.md §6 (substitutions).
    """
    es = (n - 1 + 1) // 2
    mb = n - 1 - es
    assert mb >= 1, "flint reconstruction needs >=1 mantissa bit"
    vals = []
    for f in range(1, 1 << mb):  # subnormals: (f/2^mb) * 2^1  (E=0)
        vals.append((f / float(1 << mb)) * 2.0)
    for E in range(1, 1 << es):  # normals, bias 0: 2^E * (1+f/2^mb)
        for f in range(1 << mb):
            vals.append((2.0 ** E) * (1.0 + f / float(1 << mb)))
    return vals


def posit_value(code: int, n: int, es: int) -> float | None:
    """Decode an n-bit posit (two's complement); None for NaR."""
    mask = (1 << n) - 1
    if code == 0:
        return 0.0
    if code == (1 << (n - 1)):
        return None  # NaR
    sign = -1.0 if code >> (n - 1) else 1.0
    if sign < 0:
        code = (-code) & mask  # two's complement magnitude
    bits = code & ((1 << (n - 1)) - 1)  # strip sign
    nb = n - 1
    first = (bits >> (nb - 1)) & 1
    run = 0
    for b in range(nb - 1, -1, -1):
        if ((bits >> b) & 1) == first:
            run += 1
        else:
            break
    k = run - 1 if first == 1 else -run
    rest_len = max(nb - run - 1, 0)  # regime terminator consumed
    rest = bits & ((1 << rest_len) - 1) if rest_len > 0 else 0
    e_len = min(es, rest_len)
    e = (rest >> (rest_len - e_len)) if e_len > 0 else 0
    e <<= es - e_len  # pad truncated exponent bits with zeros
    f_len = rest_len - e_len
    f = rest & ((1 << f_len) - 1) if f_len > 0 else 0
    frac = 1.0 + (f / float(1 << f_len) if f_len > 0 else 0.0)
    useed = 2.0 ** (2 ** es)
    return sign * (useed ** k) * (2.0 ** e) * frac


def adaptivfloat_magnitudes(n: int, e: int) -> list[float]:
    """AdaptivFloat [Tambe et al. 2020] positive grid at exponent bias 0.

    sign + e exponent bits + (n-1-e) mantissa bits, no subnormals; the
    per-tensor exponent bias is absorbed by the quantizer scale.
    """
    mb = n - 1 - e
    assert mb >= 1, "adaptivfloat needs >=1 mantissa bit"
    vals = []
    for E in range(1 << e):
        for f in range(1 << mb):
            if E == 0 and f == 0:
                continue  # the all-zero code is sacrificed to represent 0
            vals.append((2.0 ** E) * (1.0 + f / float(1 << mb)))
    return vals


# ---------------------------------------------------------------------------
# grid constructors (public API)
# ---------------------------------------------------------------------------

def _signed_grid(mags: list[float]) -> np.ndarray:
    """Mirror positive magnitudes, add zero, sort, dedupe."""
    pos = sorted(set(m for m in mags if m > 0))
    grid = [-v for v in reversed(pos)] + [0.0] + pos
    return np.asarray(grid, dtype=np.float64)


def dybit_grid(n: int) -> np.ndarray:
    """Signed n-bit DyBit grid (1 sign + n-1 magnitude bits), scale 1.0."""
    assert 2 <= n <= 8
    m = n - 1
    return _signed_grid([dybit_magnitude(c, m) for c in range(1 << m)])


def dybit_grid_unsigned(m: int) -> np.ndarray:
    """Unsigned m-bit DyBit grid (Table I uses m = 4)."""
    return np.asarray(sorted(dybit_magnitude(c, m) for c in range(1 << m)),
                      dtype=np.float64)


def int_grid(n: int) -> np.ndarray:
    """Symmetric uniform INT grid: {-(2^(n-1)-1) .. 2^(n-1)-1}."""
    q = (1 << (n - 1)) - 1
    return np.arange(-q, q + 1, dtype=np.float64)


def posit_grid(n: int, es: int = 1) -> np.ndarray:
    vals = [posit_value(c, n, es) for c in range(1 << n)]
    vals = sorted(set(v for v in vals if v is not None))
    return np.asarray(vals, dtype=np.float64)


def adaptivfloat_grid(n: int, e: int | None = None) -> np.ndarray:
    if e is None:
        e = {2: 1, 3: 1, 4: 2, 5: 2, 6: 3, 7: 3, 8: 3}[n]
    return _signed_grid(adaptivfloat_magnitudes(n, e))


def flint_grid(n: int) -> np.ndarray:
    return _signed_grid(flint_magnitudes(n))


FORMATS = {
    "dybit": dybit_grid,
    "int": int_grid,
    "posit": lambda n: posit_grid(n, es=1),
    "adaptivfloat": adaptivfloat_grid,
    "flint": flint_grid,
}


def grid(fmt: str, n: int) -> np.ndarray:
    """Sorted value grid for format ``fmt`` at bitwidth ``n`` (scale 1.0)."""
    return FORMATS[fmt](n)


def padded_lut(fmt: str, n: int) -> np.ndarray:
    """Fixed-size (LUT_SIZE) ascending LUT: the runtime interchange unit.

    Grids smaller than LUT_SIZE are right-padded by repeating the maximum,
    which is a no-op for nearest-value quantization (duplicate midpoints
    collapse).  This is the tensor rust feeds to the fwd/train HLO.
    """
    g = grid(fmt, n).astype(np.float32)
    assert g.size <= LUT_SIZE, (fmt, n, g.size)
    return np.pad(g, (0, LUT_SIZE - g.size), mode="edge")


def midpoints(lut: np.ndarray) -> np.ndarray:
    """Decision boundaries between adjacent LUT entries."""
    return (lut[:-1] + lut[1:]) / 2.0


# ---------------------------------------------------------------------------
# quantizer: per-tensor scale calibration + fake-quant + RMSE (Eqn. 2)
# ---------------------------------------------------------------------------

def quantize_to_grid(x: np.ndarray, g: np.ndarray, scale: float) -> np.ndarray:
    """Round x to the nearest point of scale*g (numpy reference)."""
    mids = midpoints(g.astype(np.float64)) * scale
    idx = np.searchsorted(mids, x.astype(np.float64), side="right")
    return (g[idx] * scale).astype(x.dtype)


def maxabs_scale(x: np.ndarray, g: np.ndarray) -> float:
    """Map the tensor's max magnitude onto the grid's max value."""
    gm = float(np.max(np.abs(g)))
    xm = float(np.max(np.abs(x)))
    return (xm / gm) if xm > 0 and gm > 0 else 1.0


def rmse(x: np.ndarray, xq: np.ndarray) -> float:
    """Paper Eqn. 2: RMSE normalized by the tensor's standard deviation."""
    sigma = float(np.std(x))
    if sigma == 0.0:
        sigma = 1.0
    return float(np.sqrt(np.mean(((x - xq) / sigma) ** 2)))


def calibrate_scale(x: np.ndarray, g: np.ndarray) -> float:
    """RMSE-optimal per-tensor scale search (Fig. 2 adaptation).

    Scans power-of-two multiples of the max-abs scale in BOTH directions
    (tapered grids like DyBit often prefer scales above max-abs, trading a
    coarser far tail for a finer dense region) plus a fine multiplier
    sweep — the same candidate ladder the rust quantizer uses bit-exactly.
    """
    base = maxabs_scale(x, g)
    if base == 0.0:
        return 1.0
    best_s, best_e = base, math.inf
    for j in range(-6, 12):
        for mult in (1.0, 0.75, 0.5):
            s = base * mult * (2.0 ** -j)
            xq = quantize_to_grid(x, g, s)
            e = rmse(x, xq)
            if e < best_e:
                best_s, best_e = s, e
    return best_s


def fake_quant(x: np.ndarray, fmt: str, n: int,
               scale: float | None = None) -> tuple[np.ndarray, float]:
    """Quantize-dequantize x in format (fmt, n); returns (xq, scale)."""
    g = grid(fmt, n)
    s = calibrate_scale(x, g) if scale is None else scale
    return quantize_to_grid(x, g, s), s


# ---------------------------------------------------------------------------
# DyBit codec on integer codes (bit-exact mirror of rust formats/dybit.rs)
# ---------------------------------------------------------------------------

def dybit_decode_code(code: int, n: int) -> float:
    """Signed n-bit DyBit code -> value.  MSB is the sign bit.

    The negative-zero code (sign=1, magnitude=0) is remapped to -2^(m-1)
    (i.e. -max) so all 2^n codes are meaningful; documented in DESIGN.md §5.
    """
    m = n - 1
    sign = (code >> m) & 1
    mag = code & ((1 << m) - 1)
    if sign and mag == 0:
        return -float(1 << (m - 1))
    v = dybit_magnitude(mag, m)
    return -v if sign else v


def dybit_encode_code(value: float, n: int) -> int:
    """Nearest-value encode into a signed n-bit DyBit code."""
    m = n - 1
    grid_codes = [(dybit_decode_code(c, n), c) for c in range(1 << n)]
    best = min(grid_codes, key=lambda vc: (abs(vc[0] - value), vc[1]))
    return best[1]


def golden_dump() -> dict:
    """All grids + codec vectors for the rust cross-check (JSON-able)."""
    out = {"grids": {}, "dybit_codes": {}, "table1_unsigned4":
           dybit_grid_unsigned(4).tolist()}
    for fmt in FORMATS:
        for n in (2, 3, 4, 5, 6, 7, 8):
            if fmt == "adaptivfloat" and n == 2:
                continue  # needs >=1 mantissa + >=1 exponent bit
            try:
                out["grids"][f"{fmt}{n}"] = grid(fmt, n).tolist()
            except AssertionError:
                continue
    for n in (2, 4, 8):
        out["dybit_codes"][str(n)] = [dybit_decode_code(c, n)
                                      for c in range(1 << n)]
    return out
