#!/usr/bin/env python3
"""Certify the Python lint mirror against the shared fixture oracle.

`rust/tests/fixtures/lint/EXPECTED.json` lists, per fixture file, the
exact (lint-id, line) pairs the analyzer must report (unsuppressed and
suppressed separately).  `rust/tests/analysis_lint.rs` certifies the
authoritative Rust analyzer against that same file; this script
certifies the transliterated mirror (`lint_mirror.py`) — so a rule
change that lands in only one implementation fails one of the two
gates.

Usage: python3 python/tools/certify_fixtures.py
Exit 0 when every fixture matches, 1 with a diff otherwise.
"""

import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import lint_mirror as lm  # noqa: E402

FIXTURES = os.path.join("rust", "tests", "fixtures", "lint")


def main():
    with open(os.path.join(FIXTURES, "EXPECTED.json"), encoding="utf-8") as fh:
        expected = json.load(fh)["files"]
    failures = []
    seen = set()
    for f in lm.rust_files([FIXTURES]):
        rel = os.path.relpath(f, FIXTURES).replace(os.sep, "/")
        seen.add(rel)
        if rel not in expected:
            failures.append(f"{rel}: fixture has no EXPECTED.json entry")
            continue
        with open(f, encoding="utf-8") as fh:
            src = fh.read()
        quota = set()
        lm.collect_annotations(f, lm.tokenize(src), quota)
        unsup, sup = lm.lint_file(f, src, quota, None)
        got = {
            "unsuppressed": [[lid, line] for (_p, line, lid, _m) in sorted(unsup)],
            "suppressed": [[lid, line] for (_p, line, lid, _m) in sorted(sup)],
        }
        for key in ("unsuppressed", "suppressed"):
            if got[key] != expected[rel][key]:
                failures.append(
                    f"{rel}: {key} mismatch\n"
                    f"  expected: {expected[rel][key]}\n"
                    f"  got:      {got[key]}")
    for rel in sorted(set(expected) - seen):
        failures.append(f"{rel}: EXPECTED.json entry has no fixture file")
    if failures:
        print("fixture certification FAILED:")
        for f in failures:
            print(f"  {f}")
        return 1
    n = sum(len(v["unsuppressed"]) + len(v["suppressed"]) for v in expected.values())
    print(f"fixture certification OK: {len(expected)} fixtures, "
          f"{n} expected findings all matched")
    return 0


if __name__ == "__main__":
    sys.exit(main())
