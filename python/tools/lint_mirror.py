#!/usr/bin/env python3
"""Validation mirror of the in-tree static analyzer (`dybit-lint`).

The AUTHORITATIVE implementation is `rust/src/analysis/` (+ the
`dybit-lint` bin target); this file is a 1:1 transliteration kept so the
lint gate can be exercised on boxes without a Rust toolchain (the repo's
authoring containers have none — see CHANGES.md).  Rule changes must
land in the Rust analyzer first and be mirrored here; the fixture suite
under `rust/tests/fixtures/lint/` certifies both the same way.

Usage:
    python3 python/tools/lint_mirror.py [--verbose] [paths...]

Default path: rust/src (relative to the repo root).  Exit code 1 if any
unsuppressed finding is reported, 0 otherwise — the same contract
`ci.sh` relies on for the Rust bin.

Lint catalog (ids + the DESIGN.md invariant each guards): see
DESIGN.md §14.  In short:

  raw-lock          .lock()/.wait()/.wait_timeout() outside util::lock
                    helpers (poison policy, DESIGN.md §9/§11)
  lock-order        board-then-shard acquisition, park-not-alone, or a
                    quota-table touch under an intake guard, from
                    `// lock-order:` field annotations (§11/§12)
  condvar-loop      a condvar wait outside a while/loop predicate
                    re-check (spurious wakeups)
  time-checked      bare +/- on Instant/Duration (PR 2's underflow
                    panic class; use checked_*/saturating_*)
  float-total-cmp   partial_cmp on floats in sorts/maxes (PR 4's NaN
                    hang class; use total_cmp)
  no-unwrap         unwrap()/expect() in non-test coordinator code
  metrics-recorder  raw atomic ops on the four accounting buckets
                    outside metrics.rs (§12 invariant)
  spawn-guard       detached thread::spawn bodies with no catch_unwind/
                    DeathWatch and no `// spawn-guard:` annotation
  suppression       malformed lint:allow / spawn-guard annotations

Suppression grammar: `// lint:allow(<id>): <justification >= 8 chars>`
on the finding's line or the line above it.
"""

import os
import re
import sys

# --------------------------------------------------------------------
# Tokenizer
# --------------------------------------------------------------------

IDENT = "ident"
LIFETIME = "lifetime"
CHAR = "char"
STR = "str"
NUM = "num"
COMMENT = "comment"
PUNCT = "punct"

MULTI_PUNCT = [
    "<<=", ">>=", "..=", "...", "::", "->", "=>", "==", "!=", "<=", ">=",
    "&&", "||", "+=", "-=", "*=", "/=", "%=", "^=", "&=", "|=", "<<",
    ">>", "..",
]


class Token:
    __slots__ = ("kind", "text", "line")

    def __init__(self, kind, text, line):
        self.kind = kind
        self.text = text
        self.line = line

    def __repr__(self):
        return f"{self.kind}({self.text!r}@{self.line})"


def tokenize(src):
    """Tokenize Rust source.  Mirrors analysis::lexer exactly:
    raw/byte strings, char-vs-lifetime, nested block comments, numeric
    literals with underscores/suffixes, multi-char operators."""
    toks = []
    i, n, line = 0, len(src), 1

    def peek(k=0):
        j = i + k
        return src[j] if j < n else ""

    while i < n:
        c = src[i]
        if c == "\n":
            line += 1
            i += 1
            continue
        if c in " \t\r":
            i += 1
            continue
        # comments
        if c == "/" and peek(1) == "/":
            j = src.find("\n", i)
            j = n if j < 0 else j
            toks.append(Token(COMMENT, src[i:j], line))
            i = j
            continue
        if c == "/" and peek(1) == "*":
            start, startline, depth = i, line, 1
            i += 2
            while i < n and depth > 0:
                if src[i] == "/" and peek(1) == "*":
                    depth += 1
                    i += 2
                elif src[i] == "*" and peek(1) == "/":
                    depth -= 1
                    i += 2
                else:
                    if src[i] == "\n":
                        line += 1
                    i += 1
            toks.append(Token(COMMENT, src[start:i], startline))
            continue
        # raw / byte strings: r"", r#""#, b"", br#""#
        if c in "rb":
            m = re.match(r'(?:r(#*)"|br(#*)"|b"|r"(?!#))', src[i:])
            if (c == "r" and re.match(r'r#*"', src[i:])) or (
                c == "b" and re.match(r'b?r?#*"', src[i:]) and re.match(r'(?:br#*"|b")', src[i:])
            ):
                m2 = re.match(r'(?:b?r(#*)")', src[i:])
                if m2:  # raw (possibly byte) string
                    hashes = m2.group(1)
                    close = '"' + hashes
                    j = src.find(close, i + len(m2.group(0)))
                    j = n if j < 0 else j + len(close)
                    text = src[i:j]
                    toks.append(Token(STR, text, line))
                    line += text.count("\n")
                    i = j
                    continue
                if re.match(r'b"', src[i:]):  # byte string
                    j = i + 2
                    while j < n and src[j] != '"':
                        j += 2 if src[j] == "\\" else 1
                    j = min(j + 1, n)
                    text = src[i:j]
                    toks.append(Token(STR, text, line))
                    line += text.count("\n")
                    i = j
                    continue
        if c == '"':
            j = i + 1
            while j < n and src[j] != '"':
                j += 2 if src[j] == "\\" else 1
            j = min(j + 1, n)
            text = src[i:j]
            toks.append(Token(STR, text, line))
            line += text.count("\n")
            i = j
            continue
        # char literal vs lifetime
        if c == "'":
            if peek(1) == "\\":
                j = i + 2
                if peek(2) in "xuU":
                    while j < n and src[j] != "'":
                        j += 1
                else:
                    j += 1
                j = min(j + 1, n)
                toks.append(Token(CHAR, src[i:j], line))
                i = j
                continue
            if (peek(1).isalpha() or peek(1) == "_") and peek(2) != "'":
                j = i + 1
                while j < n and (src[j].isalnum() or src[j] == "_"):
                    j += 1
                toks.append(Token(LIFETIME, src[i:j], line))
                i = j
                continue
            # 'a' style (incl 'a' where a is any single char)
            j = i + 2
            if j < n and src[j] == "'":
                j += 1
            toks.append(Token(CHAR, src[i:j], line))
            i = j
            continue
        if c.isdigit():
            j = i + 1
            while j < n and (src[j].isalnum() or src[j] == "_"):
                j += 1
            # float part: '.' only when followed by a digit (never eat ..)
            if j < n and src[j] == "." and j + 1 < n and src[j + 1].isdigit():
                j += 1
                while j < n and (src[j].isalnum() or src[j] == "_"):
                    j += 1
                # exponent sign
                if j < n and src[j - 1] in "eE" and src[j] in "+-":
                    j += 1
                    while j < n and (src[j].isalnum() or src[j] == "_"):
                        j += 1
            elif j < n and src[j - 1] in "eE" and src[j] in "+-" and "0x" not in src[i:j]:
                j += 1
                while j < n and (src[j].isalnum() or src[j] == "_"):
                    j += 1
            toks.append(Token(NUM, src[i:j], line))
            i = j
            continue
        if c.isalpha() or c == "_":
            j = i + 1
            while j < n and (src[j].isalnum() or src[j] == "_"):
                j += 1
            toks.append(Token(IDENT, src[i:j], line))
            i = j
            continue
        matched = False
        for op in MULTI_PUNCT:
            if src.startswith(op, i):
                toks.append(Token(PUNCT, op, line))
                i += len(op)
                matched = True
                break
        if not matched:
            toks.append(Token(PUNCT, c, line))
            i += 1
    return toks


def code_tokens(toks):
    """The comment-free view most lints run on."""
    return [t for t in toks if t.kind != COMMENT]


# --------------------------------------------------------------------
# Test-region detection (lints skip #[cfg(test)] / #[test] items)
# --------------------------------------------------------------------


def test_lines(toks):
    """Set of lines covered by items under #[cfg(test)]-ish or #[test]
    attributes (the attribute line through the item body's close)."""
    lines = set()
    ct = code_tokens(toks)
    i = 0
    while i < len(ct):
        if ct[i].text == "#" and i + 1 < len(ct) and ct[i + 1].text == "[":
            # span the attribute
            depth, j, has_test = 0, i + 1, False
            while j < len(ct):
                if ct[j].text == "[":
                    depth += 1
                elif ct[j].text == "]":
                    depth -= 1
                    if depth == 0:
                        break
                elif ct[j].kind == IDENT and ct[j].text == "test":
                    has_test = True
                j += 1
            attr_end = j
            if has_test:
                start_line = ct[i].line
                # skip any further attributes to the item head
                k = attr_end + 1
                while k + 1 < len(ct) and ct[k].text == "#" and ct[k + 1].text == "[":
                    d = 0
                    while k < len(ct):
                        if ct[k].text == "[":
                            d += 1
                        elif ct[k].text == "]":
                            d -= 1
                            if d == 0:
                                break
                        k += 1
                    k += 1
                # item body: first top-level '{' .. matching '}', or ';'
                d = 0
                end_line = start_line
                while k < len(ct):
                    t = ct[k]
                    if t.text == ";" and d == 0:
                        end_line = t.line
                        break
                    if t.text in "({[":
                        d += 1
                    elif t.text in ")}]":
                        d -= 1
                        if d == 0 and t.text == "}":
                            end_line = t.line
                            break
                    k += 1
                for ln in range(start_line, end_line + 1):
                    lines.add(ln)
                i = k + 1
                continue
            i = attr_end + 1
            continue
        i += 1
    return lines


# --------------------------------------------------------------------
# Annotations + suppressions
# --------------------------------------------------------------------

ALLOW_RE = re.compile(r"^//\s*lint:allow\(([a-z-]+)\)(?::\s*(.*))?$")
LOCK_ORDER_RE = re.compile(
    r"^//\s*lock-order:\s*(?:([A-Za-z_][\w-]*)\s+level\s+(\d+)(\s+alone)?|quota-touch)\s*$"
)
SPAWN_GUARD_RE = re.compile(r"^//\s*spawn-guard:\s*(.*)$")

LINT_IDS = {
    "raw-lock", "lock-order", "condvar-loop", "time-checked",
    "float-total-cmp", "no-unwrap", "metrics-recorder", "spawn-guard",
    "suppression",
}

MIN_JUSTIFICATION = 8


class FileAnnotations:
    def __init__(self):
        self.lock_fields = {}      # field name -> (group, level, alone)
        self.spawn_guard_lines = set()
        self.allow = {}            # line -> set(ids)
        self.findings = []         # malformed-annotation findings


def next_code_line_tokens(ct, after_line):
    """Code tokens on the first line with code strictly after `after_line`."""
    for idx, t in enumerate(ct):
        if t.line > after_line:
            ln = t.line
            return [u for u in ct[idx:] if u.line == ln]
    return []


def collect_annotations(path, toks, quota_methods):
    ann = FileAnnotations()
    ct = code_tokens(toks)
    for t in toks:
        if t.kind != COMMENT or not t.text.startswith("//"):
            continue
        text = t.text.strip()
        m = ALLOW_RE.match(text)
        if m:
            lint_id, just = m.group(1), (m.group(2) or "").strip()
            if lint_id not in LINT_IDS:
                ann.findings.append(
                    (path, t.line, "suppression",
                     f"lint:allow names unknown lint '{lint_id}'"))
                continue
            if len(just) < MIN_JUSTIFICATION:
                ann.findings.append(
                    (path, t.line, "suppression",
                     f"lint:allow({lint_id}) needs a justification "
                     f"(>= {MIN_JUSTIFICATION} chars after a colon)"))
                continue
            ann.allow.setdefault(t.line, set()).add(lint_id)
            nxt = next_code_line_tokens(ct, t.line)
            if nxt:
                ann.allow.setdefault(nxt[0].line, set()).add(lint_id)
            continue
        m = LOCK_ORDER_RE.match(text)
        if m:
            nxt = next_code_line_tokens(ct, t.line)
            if m.group(1) is None:  # quota-touch: attach to next fn name
                name = None
                for k, u in enumerate(nxt):
                    if u.kind == IDENT and u.text == "fn" and k + 1 < len(nxt):
                        name = nxt[k + 1].text
                        break
                if name is None:
                    ann.findings.append(
                        (path, t.line, "suppression",
                         "lock-order: quota-touch must precede an fn"))
                else:
                    quota_methods.add(name)
            else:
                field = nxt[0].text if nxt and nxt[0].kind == IDENT else None
                if field is None:
                    ann.findings.append(
                        (path, t.line, "suppression",
                         "lock-order annotation must precede a field"))
                else:
                    spec = (m.group(1), int(m.group(2)), bool(m.group(3)))
                    prev = ann.lock_fields.get(field)
                    if prev is not None and prev != spec:
                        ann.findings.append(
                            (path, t.line, "suppression",
                             f"conflicting lock-order annotations for "
                             f"field '{field}'"))
                    ann.lock_fields[field] = spec
            continue
        elif text.startswith("// lock-order:") or text.startswith("//lock-order:"):
            ann.findings.append(
                (path, t.line, "suppression",
                 "malformed lock-order annotation (want '<group> level "
                 "<n> [alone]' or 'quota-touch')"))
            continue
        m = SPAWN_GUARD_RE.match(text)
        if m:
            if len(m.group(1).strip()) < MIN_JUSTIFICATION:
                ann.findings.append(
                    (path, t.line, "suppression",
                     f"spawn-guard needs a justification (>= "
                     f"{MIN_JUSTIFICATION} chars)"))
            else:
                ann.spawn_guard_lines.add(t.line)
    return ann


# --------------------------------------------------------------------
# Lint passes (per file, over code tokens, skipping test lines)
# --------------------------------------------------------------------

BUCKETS = {"requests", "failed_requests", "rejected", "deadline_drops"}
ATOMIC_OPS = {
    "fetch_add", "fetch_sub", "fetch_update", "store", "swap",
    "compare_exchange", "compare_exchange_weak",
}
TIME_CALLEES = {
    "elapsed", "duration_since", "saturating_duration_since",
    "from_secs", "from_millis", "from_micros", "from_nanos",
    "from_secs_f64", "from_secs_f32",
}
TIME_ESCAPES = {
    "as_secs", "as_secs_f64", "as_secs_f32", "as_millis", "as_micros",
    "as_nanos", "subsec_nanos", "subsec_millis", "subsec_micros",
    # calls whose result leaves the time domain: a let binding through
    # one of these does NOT produce a time-typed variable
    "len", "is_empty", "count", "partition", "map_or", "position",
}
TIME_MARKERS = {"Instant", "Duration", "elapsed", "duration_since"}


def match_forward(ct, i, opens="([{", closes=")]}"):
    """Index of the token closing the bracket at ct[i]."""
    depth = 0
    while i < len(ct):
        if ct[i].text in opens:
            depth += 1
        elif ct[i].text in closes:
            depth -= 1
            if depth == 0:
                return i
        i += 1
    return len(ct) - 1


def match_back(ct, i, opens="([{", closes=")]}"):
    depth = 0
    while i >= 0:
        if ct[i].text in closes:
            depth += 1
        elif ct[i].text in opens:
            depth -= 1
            if depth == 0:
                return i
        i -= 1
    return 0


def is_coordinator(path):
    return "coordinator" in path.replace("\\", "/").split("/")


def is_util_helpers(path):
    p = path.replace("\\", "/")
    return p.endswith("util/mod.rs")


def lint_file(path, src, quota_methods, lock_fields_by_file):
    """Run every pass over one file; returns (findings, annotations)."""
    toks = tokenize(src)
    tlines = test_lines(toks)
    ann = collect_annotations(path, toks, quota_methods)
    ct = code_tokens(toks)
    findings = list(ann.findings)

    def emit(line, lint_id, msg):
        if line not in tlines:
            findings.append((path, line, lint_id, msg))

    # ---- raw-lock + simple token scans -----------------------------
    fname = os.path.basename(path)
    for i, t in enumerate(ct):
        if t.line in tlines:
            continue
        nxt = ct[i + 1] if i + 1 < len(ct) else None
        prv = ct[i - 1] if i > 0 else None
        # raw-lock: method-call forms of lock/wait/wait_timeout
        if (t.kind == IDENT and t.text in ("lock", "wait", "wait_timeout")
                and prv is not None and prv.text == "."
                and nxt is not None and nxt.text == "("
                and not is_util_helpers(path)):
            emit(t.line, "raw-lock",
                 f".{t.text}() bypasses the poison-recovering "
                 f"util::{t.text} helper (DESIGN.md §9/§11)")
        # float-total-cmp
        if t.kind == IDENT and t.text == "partial_cmp":
            emit(t.line, "float-total-cmp",
                 "partial_cmp in a sort/max position hangs or panics on "
                 "NaN — use total_cmp (DESIGN.md §14, PR 4 bug class)")
        # no-unwrap (coordinator only)
        if (is_coordinator(path) and t.kind == IDENT
                and t.text in ("unwrap", "expect")
                and prv is not None and prv.text == "."
                and nxt is not None and nxt.text == "("):
            emit(t.line, "no-unwrap",
                 f".{t.text}() in non-test coordinator code can kill a "
                 f"worker and strand its clients — return an Err")
        # metrics-recorder
        if (t.kind == IDENT and t.text in BUCKETS and fname != "metrics.rs"
                and nxt is not None and nxt.text == "."
                and i + 2 < len(ct) and ct[i + 2].text in ATOMIC_OPS
                and i + 3 < len(ct) and ct[i + 3].text == "("):
            emit(t.line, "metrics-recorder",
                 f"raw {ct[i+2].text} on accounting bucket '{t.text}' — "
                 f"the four-bucket invariant is maintained only by "
                 f"Metrics recorder methods (DESIGN.md §12)")
        # spawn-guard: thread::spawn( or Builder chain .spawn(
        is_spawn = (t.text == "spawn" and nxt is not None and nxt.text == "("
                    and prv is not None and prv.text == "::"
                    and i >= 2 and ct[i - 2].text == "thread")
        if is_spawn:
            close = match_forward(ct, i + 1)
            body = ct[i + 1:close + 1]
            guarded = any(
                u.kind == IDENT and u.text in ("catch_unwind", "DeathWatch")
                for u in body)
            if not guarded:
                near = any(
                    ln in ann.spawn_guard_lines
                    for ln in range(t.line - 3, body[-1].line + 1))
                if not near:
                    emit(t.line, "spawn-guard",
                         "detached thread body has no catch_unwind/"
                         "DeathWatch guard and no `// spawn-guard:` "
                         "justification (DESIGN.md §13)")

    # ---- per-function passes ---------------------------------------
    findings.extend(
        function_passes(path, ct, tlines, ann, quota_methods))

    # filter suppressed
    unsuppressed, suppressed = [], []
    for f in findings:
        _, line, lint_id, _ = f
        if lint_id in ann.allow.get(line, ()) and lint_id != "suppression":
            suppressed.append(f)
        else:
            unsuppressed.append(f)
    return unsuppressed, suppressed


def function_passes(path, ct, tlines, ann, quota_methods):
    """lock-order, condvar-loop, time-checked: need fn bodies + blocks."""
    out = []

    def emit(line, lint_id, msg):
        if line not in tlines:
            out.append((path, line, lint_id, msg))

    i = 0
    while i < len(ct):
        if ct[i].kind == IDENT and ct[i].text == "fn" and i + 1 < len(ct):
            # signature: up to the body '{' (or ';' for trait decls)
            j = i + 1
            sig = []
            while j < len(ct) and ct[j].text not in ("{", ";"):
                sig.append(ct[j])
                j += 1
            if j >= len(ct) or ct[j].text == ";":
                i = j + 1
                continue
            body_open = j
            body_close = match_forward(ct, body_open, opens="{", closes="}")
            analyze_fn(path, ct, sig, body_open, body_close, ann,
                       quota_methods, emit)
            # NOTE: nested fns/closures are analyzed as part of the
            # enclosing body (same held-guard scope rules)
            i = body_close + 1
        else:
            i += 1
    return out


def stmt_time_tokens(ct, i):
    """Tokens of the statement starting at ct[i] (through ';' at depth 0)."""
    depth, j = 0, i
    stmt = []
    while j < len(ct):
        t = ct[j]
        if t.text in "([{":
            depth += 1
        elif t.text in ")]}":
            if depth == 0:
                break
            depth -= 1
        elif t.text == ";" and depth == 0:
            break
        stmt.append(t)
        j += 1
    return stmt, j


def analyze_fn(path, ct, sig, body_open, body_close, ann, quota_methods,
               emit):
    lock_fields = ann.lock_fields
    # --- time vars from the signature ---
    time_vars = set()
    k = 0
    # params live between the first '(' and its match within sig
    try:
        p0 = next(ix for ix, t in enumerate(sig) if t.text == "(")
    except StopIteration:
        p0 = None
    if p0 is not None:
        depth = 0
        px = p0
        pend = None
        while px < len(sig):
            if sig[px].text == "(":
                depth += 1
            elif sig[px].text == ")":
                depth -= 1
                if depth == 0:
                    pend = px
                    break
            px += 1
        pend = pend if pend is not None else len(sig) - 1
        params = sig[p0 + 1:pend]
        # split on top-level commas; mark `name: ...Instant/Duration...`
        groups, cur, d = [], [], 0
        for t in params:
            if t.text in "([{<":
                d += 1
            elif t.text in ")]}>":
                d -= 1
            if t.text == "," and d == 0:
                groups.append(cur)
                cur = []
            else:
                cur.append(t)
        if cur:
            groups.append(cur)
        for g in groups:
            if not g:
                continue
            names = [t.text for t in g]
            if ("Instant" in names or "Duration" in names) and g[0].kind == IDENT:
                time_vars.add(g[0].text)

    # --- walk the body ---
    held = []          # list of (name_or_None, group, level, alone, depth)
    bind_depth = {}    # guard var -> depth
    depth = 0
    block_kinds = []   # kind per open block
    pending_kind = None
    match_time_depths = []  # depths of match-blocks over time scrutinees

    i = body_open
    while i <= body_close:
        t = ct[i]
        txt = t.text

        if t.kind == IDENT and txt in ("loop", "while", "for", "if", "else",
                                       "match", "unsafe", "move"):
            if txt == "match":
                # time scrutinee? tokens up to the match '{'
                j, scrut = i + 1, []
                d2 = 0
                while j <= body_close:
                    if ct[j].text in "([":
                        d2 += 1
                    elif ct[j].text in ")]":
                        d2 -= 1
                    elif ct[j].text == "{" and d2 == 0:
                        break
                    scrut.append(ct[j])
                    j += 1
                names = {u.text for u in scrut if u.kind == IDENT}
                if names & (time_vars | {"Instant", "Duration"}):
                    match_time_depths.append(depth + 1)
            if txt != "move":
                pending_kind = txt
            i += 1
            continue

        if txt == "{":
            depth += 1
            block_kinds.append(pending_kind or "block")
            pending_kind = None
            i += 1
            continue
        if txt == "}":
            held = [h for h in held if h[4] < depth]
            bind_depth = {k2: v for k2, v in bind_depth.items() if v < depth}
            if match_time_depths and match_time_depths[-1] == depth:
                match_time_depths.pop()
            if block_kinds:
                block_kinds.pop()
            depth -= 1
            i += 1
            continue
        if txt == ";":
            pending_kind = None
            i += 1
            continue

        # Some(x)/Ok(x) arm bindings inside a time-typed match
        if (t.kind == IDENT and txt in ("Some", "Ok")
                and match_time_depths and depth >= match_time_depths[-1]
                and i + 2 <= body_close and ct[i + 1].text == "("
                and ct[i + 2].kind == IDENT):
            # only when this is an arm pattern: ')' then '=>' follows
            j = match_forward(ct, i + 1)
            if j + 1 <= body_close and ct[j + 1].text == "=>":
                time_vars.add(ct[i + 2].text)

        # let statements: collect time vars
        if t.kind == IDENT and txt == "let":
            stmt, _ = stmt_time_tokens(ct, i)
            names = [u.text for u in stmt if u.kind == IDENT]
            if (set(names) & (TIME_MARKERS | time_vars)
                    and not (set(names) & TIME_ESCAPES)):
                # pattern idents: between let and '='
                for u in stmt[1:]:
                    if u.text == "=":
                        break
                    if u.kind == IDENT and u.text not in ("mut", "ref"):
                        time_vars.add(u.text)
                        break
            # fall through: the lock()-acquisition scan below still
            # sees this statement's tokens

        # drop(guard) releases
        if (t.kind == IDENT and txt == "drop" and i + 2 <= body_close
                and ct[i + 1].text == "(" and ct[i + 2].kind == IDENT):
            name = ct[i + 2].text
            held = [h for h in held if h[0] != name]
            bind_depth.pop(name, None)

        # quota-touch call under an intake guard
        if (t.kind == IDENT and txt in quota_methods
                and i + 1 <= body_close and ct[i + 1].text == "("
                and i > 0 and ct[i - 1].text in (".", "::") and held):
            emit(t.line, "lock-order",
                 f"tenant-occupancy touch '{txt}()' while holding an "
                 f"intake guard — the quota table must never nest "
                 f"inside intake locks (DESIGN.md §12)")

        # lock acquisitions: free `lock(&...field)` or raw `.lock()`
        acquired = None
        if (t.kind == IDENT and txt == "lock" and i + 1 <= body_close
                and ct[i + 1].text == "("
                and (i == 0 or ct[i - 1].text != ".")):
            close = match_forward(ct, i + 1)
            inner = [u for u in ct[i + 2:close] if u.kind == IDENT]
            if inner:
                acquired = inner[-1].text
        elif (t.kind == IDENT and txt == "lock" and i > 0
              and ct[i - 1].text == "." and i + 1 <= body_close
              and ct[i + 1].text == "("):
            back = [u for u in ct[max(0, i - 8):i - 1] if u.kind == IDENT]
            if back:
                acquired = back[-1].text
        if acquired is not None and acquired in lock_fields:
            group, level, alone = lock_fields[acquired]
            for (hname, hgroup, hlevel, halone, _hd) in held:
                if alone or halone:
                    emit(t.line, "lock-order",
                         f"'{acquired}' and '{hname or hgroup}' held "
                         f"together but one is annotated `alone` "
                         f"(DESIGN.md §11: the park lock is only ever "
                         f"held alone)")
                    break
                if hgroup == group and level <= hlevel:
                    emit(t.line, "lock-order",
                         f"acquiring '{acquired}' (level {level}) while "
                         f"holding '{hname or hgroup}' (level {hlevel}) "
                         f"violates the {group} lock order "
                         f"(DESIGN.md §11: shard → board only)")
                    break
            # bound or transient?  A guard binding is `<ident> = lock(..);`
            # — a method chain after the call (`lock(..).clone()`) means
            # the guard is a temporary dropped at statement end.
            name = None
            if i >= 2 and ct[i - 1].text == "=" and ct[i - 2].kind == IDENT:
                close = match_forward(ct, i + 1)
                after = ct[close + 1] if close + 1 < len(ct) else None
                if after is not None and after.text == ";":
                    name = ct[i - 2].text
            if name is not None:
                held.append((name, group, level, alone, depth))
                bind_depth[name] = depth

        # condvar-loop: free wait()/wait_timeout() calls
        if (t.kind == IDENT and txt in ("wait", "wait_timeout")
                and i + 1 <= body_close and ct[i + 1].text == "("
                and (i == 0 or ct[i - 1].text != ".")
                and not is_util_helpers(path)):
            if not any(k2 in ("loop", "while") for k2 in block_kinds):
                emit(t.line, "condvar-loop",
                     f"condvar {txt}() outside a while/loop predicate "
                     f"re-check — spurious wakeups break an `if` guard "
                     f"(DESIGN.md §14)")

        # time-checked: binary +/- or +=/-= with a time-typed operand
        if txt in ("+", "-", "+=", "-="):
            prv = ct[i - 1] if i > 0 else None
            binary = prv is not None and (
                prv.kind in (IDENT, NUM, STR, CHAR) or prv.text in (")", "]"))
            if binary:
                left_time = operand_is_time(ct, i - 1, time_vars, back=True)
                right_time = operand_is_time(ct, i + 1, time_vars, back=False)
                if left_time or right_time:
                    emit(t.line, "time-checked",
                         f"bare `{txt}` on Instant/Duration can panic on "
                         f"underflow/overflow — use checked_add/"
                         f"checked_sub/saturating_duration_since "
                         f"(DESIGN.md §9, PR 2 bug class)")
        i += 1


def operand_is_time(ct, i, time_vars, back):
    if i < 0 or i >= len(ct):
        return False
    t = ct[i]
    if back:
        if t.kind == IDENT:
            return t.text in time_vars
        if t.text == ")":
            op = match_back(ct, i)
            callee = ct[op - 1] if op >= 1 else None
            if callee is not None and callee.kind == IDENT:
                if callee.text == "now" and op >= 3 and \
                        ct[op - 2].text == "::" and ct[op - 3].text == "Instant":
                    return True
                return callee.text in TIME_CALLEES
        return False
    # forward: Instant::now(...), Duration::from_*(...), time var, or
    # a unary-parenthesized time expr
    if t.kind == IDENT:
        if t.text in time_vars:
            return True
        if t.text in ("Instant", "Duration") and i + 2 < len(ct) and \
                ct[i + 1].text == "::":
            nxt = ct[i + 2]
            return nxt.text == "now" or nxt.text in TIME_CALLEES
    return False


# --------------------------------------------------------------------
# Driver
# --------------------------------------------------------------------


def rust_files(paths):
    files = []
    for p in paths:
        if os.path.isfile(p):
            files.append(p)
            continue
        for root, _dirs, names in os.walk(p):
            for nm in sorted(names):
                if nm.endswith(".rs"):
                    files.append(os.path.join(root, nm))
    return sorted(files)


def main(argv):
    verbose = "--verbose" in argv
    paths = [a for a in argv if not a.startswith("--")] or ["rust/src"]
    quota_methods = set()
    sources = {}
    for f in rust_files(paths):
        with open(f, encoding="utf-8") as fh:
            sources[f] = fh.read()
    # pass A: collect cross-file annotations (quota-touch methods)
    for f, src in sources.items():
        collect_annotations(f, tokenize(src), quota_methods)
    # pass B: lint
    all_unsup, all_sup = [], []
    for f, src in sources.items():
        unsup, sup = lint_file(f, src, quota_methods, None)
        all_unsup.extend(unsup)
        all_sup.extend(sup)
    for (f, line, lint_id, msg) in sorted(all_unsup):
        print(f"{f}:{line}: [{lint_id}] {msg}")
    if verbose:
        counts = {}
        for (_f, _l, lid, _m) in all_unsup:
            counts[lid] = counts.get(lid, 0) + 1
        print(f"-- {len(all_unsup)} unsuppressed finding(s), "
              f"{len(all_sup)} suppressed --")
        for lid in sorted(LINT_IDS):
            print(f"   {lid}: {counts.get(lid, 0)}")
        for (f, line, lid, msg) in sorted(all_sup):
            print(f"   suppressed {f}:{line}: [{lid}] {msg}")
    return 1 if all_unsup else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
