#!/usr/bin/env bash
# Local CI gate for the DyBit workspace (see README.md).
#
#   ./ci.sh               # fmt + clippy + tier-1 (build + bench build +
#                         # tests + dybit-lint + docs)
#   ./ci.sh --fast        # tier-1 only
#   ./ci.sh --analyze     # run the in-tree static analyzer verbose
#                         # (per-lint counts + the justified-suppression
#                         # list) and exit; see DESIGN.md §14
#   ./ci.sh --bench-smoke # additionally run the perf_search bench on tiny
#                         # layer stacks, perf_calib on tiny tensors, and
#                         # perf_serve/perf_route on tiny SimBackend pools
#                         # (quick end-to-end bench smoke); fails if any
#                         # bench result JSON is missing or empty, or if
#                         # perf_route persisted a failed goodput/PI/
#                         # refinement gate or perf_serve a failed
#                         # scaling/recovery gate (full-size runs write
#                         # goodput_pass / controller_pass /
#                         # recovery_pass; smoke writes null — except
#                         # refine_pass, which is real on smoke too,
#                         # DESIGN.md §15)
#   ./ci.sh --stress      # additionally run the full coordinator_stress
#                         # sweep (8 seeds x {4,16,64} shards + tiny-cap
#                         # shutdown runs + seeded §12 overload scenarios
#                         # with deadline-drop conservation + seeded §13
#                         # chaos schedules — kill/flap/failover with
#                         # restart conservation) against both intake
#                         # implementations (DESIGN.md §11–§13)
#   ./ci.sh --sanitize    # additionally run the stress suite under
#                         # ThreadSanitizer (-Zsanitizer=thread) when a
#                         # nightly toolchain is installed; skipped with
#                         # a loud note otherwise (same gating style as
#                         # the PJRT runtime tests)
#
# Note tier-1's `cargo test -q` already runs coordinator_stress with its
# small default seed set, so the concurrency interleavings are exercised
# on every CI run; --stress widens the sweep via STRESS_FULL=1.
#
# Tier-1 must stay green; fmt/clippy keep the tree reviewable.  Benches
# are built (not run) as part of tier-1 so bench bit-rot fails CI, and
# `cargo doc --no-deps` runs with warnings denied so doc rot does too.
set -euo pipefail
cd "$(dirname "$0")"

fast=0
bench_smoke=0
stress=0
analyze=0
sanitize=0
for arg in "$@"; do
  case "$arg" in
    --fast) fast=1 ;;
    --bench-smoke) bench_smoke=1 ;;
    --stress) stress=1 ;;
    --analyze) analyze=1 ;;
    --sanitize) sanitize=1 ;;
    *) echo "ci.sh: unknown flag '$arg'" >&2; exit 2 ;;
  esac
done

if [[ $analyze -eq 1 ]]; then
  echo "==> dybit-lint --verbose (static analysis, DESIGN.md §14)"
  cargo run --release --bin dybit-lint -- --verbose rust/src
  exit 0
fi

if [[ $fast -eq 0 ]]; then
  echo "==> cargo fmt --check"
  cargo fmt --all -- --check

  echo "==> cargo clippy (deny warnings)"
  cargo clippy --workspace --all-targets -- -D warnings
fi

echo "==> tier-1: cargo build --release && cargo build --benches --release && cargo test -q"
cargo build --release
cargo build --benches --release
cargo test -q

echo "==> tier-1: dybit-lint (zero unsuppressed findings, DESIGN.md §14)"
cargo run --release --bin dybit-lint -- rust/src

echo "==> tier-1: cargo doc --no-deps (warnings denied)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps -p dybit --quiet

if [[ $stress -eq 1 ]]; then
  echo "==> stress: coordinator_stress full sweep (8 seeds x {4,16,64} shards)"
  STRESS_FULL=1 cargo test --release --test coordinator_stress -- --nocapture
fi

if [[ $sanitize -eq 1 ]]; then
  if cargo +nightly --version >/dev/null 2>&1; then
    host="$(rustc -vV | sed -n 's/^host: //p')"
    echo "==> sanitize: coordinator_stress under ThreadSanitizer (nightly, ${host})"
    RUSTFLAGS="-Zsanitizer=thread" RUSTDOCFLAGS="-Zsanitizer=thread" \
      cargo +nightly test -Zbuild-std --target "${host}" \
      --test coordinator_stress -- --nocapture
  else
    echo "ci.sh: SKIPPING --sanitize tier: no nightly toolchain installed" >&2
    echo "ci.sh: (install with 'rustup toolchain install nightly --component rust-src')" >&2
  fi
fi

if [[ $bench_smoke -eq 1 ]]; then
  echo "==> bench smoke: perf_search on tiny layer stacks"
  cargo bench --bench perf_search -- --smoke

  echo "==> bench smoke: perf_calib on tiny tensors"
  cargo bench --bench perf_calib -- --smoke

  echo "==> bench smoke: perf_serve on a tiny SimBackend pool"
  cargo bench --bench perf_serve -- --smoke

  echo "==> bench smoke: perf_route on a tiny mixed-precision pool"
  cargo bench --bench perf_route -- --smoke

  # the smoke gate is only meaningful if the benches actually persisted
  # their results: a missing/empty JSON means a silently broken run
  for name in perf_search perf_calib perf_serve perf_route; do
    out="artifacts/results/${name}.json"
    if [[ ! -s "$out" ]]; then
      echo "ci.sh: bench smoke produced no usable $out" >&2
      exit 1
    fi
  done

  # perf_route persists its gate verdicts (goodput_pass /
  # controller_pass / floor_pass: bool on full-size runs, null on
  # smoke; refine_pass is a real bool even on smoke because the §15
  # refinement gate reads the deterministic SimCostMeter, not wall
  # time).  Gate on the JSON, not just the exit code, so a run that
  # records a failed verdict can never slip through as green
  for gate in goodput_pass controller_pass floor_pass refine_pass; do
    if grep -q "\"${gate}\": false" artifacts/results/perf_route.json; then
      echo "ci.sh: perf_route persisted ${gate}=false (SLA/overload gate)" >&2
      exit 1
    fi
  done

  # perf_serve persists its own verdicts the same way, including the
  # §13 kill-one-replica recovery gate (recovery_pass: bool on
  # full-size runs, null on smoke)
  for gate in floor_pass sched_flat_pass recovery_pass; do
    if grep -q "\"${gate}\": false" artifacts/results/perf_serve.json; then
      echo "ci.sh: perf_serve persisted ${gate}=false (serving perf/recovery gate)" >&2
      exit 1
    fi
  done
fi

echo "ci.sh: all green"
