#!/usr/bin/env bash
# Local CI gate for the DyBit workspace (see README.md).
#
#   ./ci.sh          # fmt + clippy + tier-1 (build + tests)
#   ./ci.sh --fast   # tier-1 only
#
# Tier-1 must stay green; fmt/clippy keep the tree reviewable.
set -euo pipefail
cd "$(dirname "$0")"

fast=0
[[ "${1:-}" == "--fast" ]] && fast=1

if [[ $fast -eq 0 ]]; then
  echo "==> cargo fmt --check"
  cargo fmt --all -- --check

  echo "==> cargo clippy (deny warnings)"
  cargo clippy --workspace --all-targets -- -D warnings
fi

echo "==> tier-1: cargo build --release && cargo test -q"
cargo build --release
cargo test -q

echo "ci.sh: all green"
