//! Serving demo: the coordinator under closed-loop load.
//!
//! Starts the batching server with a DyBit-quantized model and drives it
//! with concurrent clients sending synthetic images; reports throughput,
//! batch-formation quality and latency percentiles — the deployment-side
//! view of the paper's accelerator.
//!
//! Run: cargo run --release --example serve -- --model mlp --clients 8 \
//!        --requests 64 [--wbits 4 --abits 8] [--pallas]

use std::time::Duration;

use anyhow::Result;

use dybit::coordinator::{load_test, Policy, Server, ServerConfig};
use dybit::formats::Format;
use dybit::qat::QuantConfig;
use dybit::runtime::Manifest;
use dybit::util::argparse::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let model = args.get_or("model", "mlp");
    let clients = args.get_usize("clients", 8);
    let requests = args.get_usize("requests", 64);
    let wbits = args.get_usize("wbits", 4) as u32;
    let abits = args.get_usize("abits", 8) as u32;
    let wait_ms = args.get_usize("max-wait-ms", 5) as u64;

    let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
    let entry = manifest
        .models
        .get(&model)
        .ok_or_else(|| anyhow::anyhow!("unknown model {model}"))?;
    let img_elems: usize = entry.input.iter().skip(1).product();

    let cfg = ServerConfig {
        model: model.clone(),
        qcfg: QuantConfig::uniform(entry.n_quant_layers, Format::DyBit, wbits, abits),
        policy: Policy {
            max_batch: entry.batch,
            max_wait: Duration::from_millis(wait_ms),
        },
        queue_cap: 512,
        pallas: args.has("pallas"),
    };

    println!(
        "serving {model} as DyBit({wbits}/{abits}), batch<= {}, wait {}ms, {} clients x {} reqs",
        entry.batch, wait_ms, clients, requests
    );
    let server = Server::start(&manifest, cfg)?;

    // one warm-up request so compile time doesn't pollute the measurement
    let _ = server.infer(vec![0.0; img_elems])?;

    let t0 = std::time::Instant::now();
    load_test(&server, clients, requests, img_elems)?;
    let wall = t0.elapsed().as_secs_f64();

    let snap = server.shutdown();
    println!("\n== results ==");
    println!("requests          {}", snap.requests);
    println!("batches           {} (mean size {:.1}, padded slots {}, errors {})",
             snap.batches, snap.mean_batch, snap.padded_slots, snap.errors);
    println!("batch latency     p50 {:.1}ms  p95 {:.1}ms  mean {:.1}ms",
             snap.lat_p50_ms, snap.lat_p95_ms, snap.lat_mean_ms);
    println!("throughput        {:.1} req/s (load-test wall {:.1}s)",
             (clients * requests) as f64 / wall, wall);
    Ok(())
}
