//! Serving demo: the (optionally heterogeneous) replica pool under
//! closed-loop load.
//!
//! Starts the batching server with a DyBit-quantized model and drives it
//! with concurrent clients sending synthetic images; reports throughput,
//! batch-formation quality, per-replica balance (routing, stealing,
//! escalations) and latency percentiles — the deployment-side view of
//! the paper's accelerator (DESIGN.md §9–§10).
//!
//! Run: cargo run --release --example serve -- --model mlp --clients 8 \
//!        --requests 64 [--replicas 4] [--wbits 4 --abits 8] [--pallas]
//!
//! With `--sim` the pool serves the artifact-free simulator backend
//! (DESIGN.md §9) — no PJRT runtime or compiled artifacts needed — and
//! `--precision-mix 4,4,4,8 --router escalate` makes it a heterogeneous
//! pool: three DyBit-4 replicas plus an 8-bit accurate replica with
//! low-margin replies escalated to the accurate tier (DESIGN.md §10).
//! Add `--bitplane` to serve the nested-precision backend, where those
//! escalations refine cached partial sums instead of re-running
//! (DESIGN.md §15; `--router escalate+refine:off` restores the re-run).

use std::time::Duration;

use anyhow::Result;

use dybit::coordinator::{
    load_test, parse_precision_mix, resolve_precision_mix, router_and_refine_from_spec,
    BitplaneBackend, Policy, PoolConfig, ReplicaPrecision, Server, ServerConfig,
    SimBackend, SimBackendCfg,
};
use dybit::formats::Format;
use dybit::qat::QuantConfig;
use dybit::runtime::Manifest;
use dybit::util::argparse::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let model = args.get_or("model", "mlp");
    let clients = args.get_usize("clients", 8);
    let requests = args.get_usize("requests", 64);
    let wbits = args.get_usize("wbits", 4) as u32;
    let abits = args.get_usize("abits", 8) as u32;
    let wait_ms = args.get_usize("max-wait-ms", 5) as u64;
    let mix: Vec<ReplicaPrecision> = match args.get("precision-mix") {
        Some(s) => parse_precision_mix(s)?,
        None => Vec::new(),
    };
    let had_mix = !mix.is_empty();
    let precisions = resolve_precision_mix(mix, wbits, abits, args.get_usize("replicas", 1));
    let replicas = precisions.len();
    // `+refine:off` on the router spec preserves the pre-§15 full
    // re-run escalation path (only meaningful with --bitplane)
    let (router, refine) = router_and_refine_from_spec(&args.get_or("router", "fastest"))?;

    let server = if args.has("sim") {
        let cfg = SimBackendCfg {
            wbits,
            abits,
            // --time-scale > 0 turns simulated cycles into wall time so
            // replica scaling, routing effects and latency percentiles
            // become visible
            time_scale: args.get_f64("time-scale", 0.0),
            ..SimBackendCfg::tiny(17)
        };
        println!(
            "serving sim backend (precision mix [{}]), batch<= {}, wait {wait_ms}ms, \
             {replicas} replica(s), router {}, {clients} clients x {requests} reqs",
            precisions.iter().map(|p| p.to_string()).collect::<Vec<_>>().join(", "),
            cfg.batch,
            router.name()
        );
        // mixed_factory with a uniform mix IS the homogeneous pool, and
        // the results table always labels replicas with their real bits;
        // --bitplane swaps in the §15 nested-precision backend so
        // escalations refine cached partial sums instead of re-running
        let factory = if args.has("bitplane") {
            BitplaneBackend::mixed_factory(cfg.clone(), precisions.clone())
        } else {
            SimBackend::mixed_factory(cfg.clone(), precisions.clone())
        };
        Server::start_pool(
            PoolConfig {
                policy: Policy {
                    max_batch: cfg.batch,
                    max_wait: Duration::from_millis(wait_ms),
                },
                queue_cap: 512,
                replicas,
                precisions,
                router,
                work_stealing: !args.has("no-steal"),
                refine,
                ..PoolConfig::default()
            },
            factory,
        )?
    } else {
        // this demo keeps the PJRT path homogeneous; the `dybit serve`
        // CLI implements the heterogeneous PJRT pool (per-replica
        // QuantConfigs over one artifact, DESIGN.md §2/§10) — reject the
        // flags rather than half-apply them
        if had_mix || args.get("router").is_some() || args.has("no-steal") {
            anyhow::bail!(
                "--precision-mix/--router/--no-steal need --sim in this example; \
                 for a heterogeneous PJRT pool use `dybit serve --precision-mix …`"
            );
        }
        let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
        let entry = manifest.model(&model)?;
        let cfg = ServerConfig {
            model: model.clone(),
            qcfg: QuantConfig::uniform(entry.n_quant_layers, Format::DyBit, wbits, abits),
            policy: Policy {
                max_batch: entry.batch,
                max_wait: Duration::from_millis(wait_ms),
            },
            queue_cap: 512,
            pallas: args.has("pallas"),
            replicas,
        };
        println!(
            "serving {model} as DyBit({wbits}/{abits}), batch<= {}, wait {wait_ms}ms, \
             {replicas} replica(s), {clients} clients x {requests} reqs",
            entry.batch
        );
        Server::start(&manifest, cfg)?
    };
    let img_elems = server.img_elems();
    let precisions = server.precisions().to_vec();

    // one warm-up request so compile time doesn't pollute the measurement
    let _ = server.infer(vec![0.0; img_elems])?;

    let t0 = std::time::Instant::now();
    load_test(&server, clients, requests, img_elems)?;
    let wall = t0.elapsed().as_secs_f64();

    let snap = server.shutdown()?;
    println!("\n== results ==");
    println!("requests          {}", snap.requests);
    println!(
        "batches           {} (mean size {:.1}, padded slots {}, errors {}, \
         rejected {}, escalations {}, refined {})",
        snap.batches, snap.mean_batch, snap.padded_slots, snap.errors, snap.rejected,
        snap.escalations, snap.refinements
    );
    print!("{}", snap.replica_report(&precisions));
    println!("batch latency     p50 {:.1}ms  p95 {:.1}ms  mean {:.1}ms",
             snap.lat_p50_ms, snap.lat_p95_ms, snap.lat_mean_ms);
    println!("throughput        {:.1} req/s (load-test wall {:.1}s)",
             (clients * requests) as f64 / wall, wall);
    Ok(())
}
