//! Hardware-aware quantization search (paper Fig. 4 + Algorithm 1).
//!
//! Runs both strategies on a real model's weights/activations:
//!   * speedup-constrained (Eqn. 3): hit a target speedup, minimize ΣRMSE;
//!   * RMSE-constrained   (Eqn. 4): stay under an error budget, minimize
//!     latency;
//! then verifies the chosen assignment on the cycle-accurate simulator and
//! evaluates its model accuracy through the AOT runtime.
//!
//! Run: cargo run --release --example hw_search -- --model miniresnet18 --alpha 4 --beta 2

use anyhow::Result;

use dybit::formats::Format;
use dybit::qat::{QuantConfig, Session};
use dybit::runtime::{Executor, Manifest};
use dybit::search::{run_search, Strategy};
use dybit::sim::{HwConfig, Simulator};
use dybit::util::argparse::Args;
use dybit::util::stats::Table;

fn main() -> Result<()> {
    let args = Args::from_env();
    let model = args.get_or("model", "miniresnet18");
    let alpha = args.get_f64("alpha", 4.0);
    let beta = args.get_f64("beta", 2.0);
    let top_k = args.get_usize("topk", 3);

    let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
    let mut exec = Executor::new(&manifest.dir)?;
    let mut session = Session::new(&manifest, &model)?;

    // metric inputs: real weights + a calibration batch of activations
    let weights = session.layer_weights();
    let acts = session.layer_acts(&mut exec, 17)?;
    let layers = session.model.layers.clone();

    for strategy in [
        Strategy::SpeedupConstrained { alpha },
        Strategy::RmseConstrained { beta },
    ] {
        let sim = Simulator::new(HwConfig::zcu102(), layers.clone(), 1);
        let r = run_search(&sim, &weights, &acts, Format::DyBit, strategy, top_k);
        println!("\n== {strategy:?} on {model} ==");
        println!(
            "speedup {:.2}x | rmse ratio {:.3} | satisfied {} | {} iterations",
            r.speedup, r.rmse_ratio, r.satisfied, r.iterations
        );

        let mut t = Table::new(&["layer", "kind", "W", "A"]);
        for (l, (pw, pa)) in layers.iter().zip(r.assignment.iter()) {
            t.row(vec![
                l.name.clone(),
                format!("{:?}", l.kind),
                pw.bits().to_string(),
                pa.bits().to_string(),
            ]);
        }
        t.print();

        // accuracy of the found config through the real runtime
        let mut q = QuantConfig::from_assignment(Format::DyBit, &r.assignment);
        session.calibrate(&mut exec, &mut q, 55)?;
        let ev = session.evaluate(&mut exec, &q, 8)?;
        println!("model eval under this assignment: loss {:.4} top-1 {:.3}", ev.loss, ev.acc);
    }
    Ok(())
}
