//! Quickstart: the whole DyBit pipeline on one small model in ~a minute.
//!
//!   1. inspect the DyBit format (Table I);
//!   2. quantize a tensor with per-tensor scale adaptation + RMSE (Eqn. 2);
//!   3. simulate the mixed-precision accelerator on a layer;
//!   4. load the AOT-compiled MLP, quantize it to DyBit(4/8), and compare
//!      top-1 accuracy against FP32 on held-out data.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use anyhow::Result;

use dybit::formats::dybit as dybit_fmt;
use dybit::formats::{quantizer, Format};
use dybit::qat::{QuantConfig, Session};
use dybit::runtime::{Executor, Manifest};
use dybit::sim::{HwConfig, LayerShape, Prec, Simulator};
use dybit::util::rng::Rng;

fn main() -> Result<()> {
    // ---- 1. the format itself (paper Table I) ---------------------------
    println!("== DyBit 4-bit unsigned value table (paper Table I) ==");
    for (code, v) in dybit_fmt::grid_unsigned(4).iter().enumerate() {
        print!("{code:04b}->{v:<5} ");
        if code % 8 == 7 {
            println!();
        }
    }

    // ---- 2. tensor-level adaptive quantization (Fig. 2) ----------------
    println!("\n== per-tensor adaptive quantization ==");
    let mut rng = Rng::new(7);
    // heavy-tailed weights, the distribution DNNs actually have
    let w: Vec<f32> = (0..4096)
        .map(|_| (rng.normal() * (1.0 + 4.0 * rng.uniform().powi(6))) as f32)
        .collect();
    for fmt in [Format::DyBit, Format::Int, Format::Flint] {
        let (_, r) = quantizer::fake_quant(&w, fmt, 4, None);
        println!("  {:>6} 4-bit: scale {:.4}  RMSE {:.4}", fmt.name(), r.scale, r.rmse);
    }

    // ---- 3. accelerator simulation --------------------------------------
    println!("\n== mixed-precision systolic array (ZCU102) ==");
    let layer = LayerShape::gemm("conv-as-gemm", 576, 144, 64);
    let mut sim = Simulator::new(HwConfig::zcu102(), vec![layer], 1);
    for (pw, pa) in [(Prec::B8, Prec::B8), (Prec::B4, Prec::B8), (Prec::B4, Prec::B4), (Prec::B2, Prec::B2)] {
        let c = sim.layer_cycles(0, pw, pa);
        println!(
            "  {}W{}A: {:>7} cycles  (util {:.2}, {:>6} bytes)",
            pw.bits(), pa.bits(), c.total, c.utilization, c.bytes
        );
    }

    // ---- 4. end-to-end: quantize the compiled MLP ----------------------
    println!("\n== AOT model: FP32 vs DyBit(4/8) ==");
    let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
    let mut exec = Executor::new(&manifest.dir)?;
    let mut session = Session::new(&manifest, "mlp")?;
    let nl = session.model.n_quant_layers;

    // brief FP32 pre-train so accuracy is meaningful
    let fp = QuantConfig::fp32(nl);
    session.train(&mut exec, &fp, 60, 0.05, 0)?;
    let acc_fp = session.evaluate(&mut exec, &fp, 8)?;

    let mut q = QuantConfig::uniform(nl, Format::DyBit, 4, 8);
    session.calibrate(&mut exec, &mut q, 1234)?;
    session.train(&mut exec, &q, 30, 0.01, 60)?; // QAT fine-tune
    let acc_q = session.evaluate(&mut exec, &q, 8)?;

    println!("  FP32       top-1: {:.3}", acc_fp.acc);
    println!("  DyBit(4/8) top-1: {:.3}  (after 30 QAT steps)", acc_q.acc);
    println!("\nquickstart OK");
    Ok(())
}
