//! End-to-end driver (the repo's headline validation run).
//!
//! Trains a real model from scratch through the AOT train-step (FP32),
//! logging the loss curve; then runs the full paper pipeline on it:
//! calibrate → QAT fine-tune at DyBit(4/4) and INT(4/4) → evaluate top-1 →
//! hardware-aware search (both strategies) → simulated speedup.  All three
//! layers compose: rust drives, XLA executes the JAX graph, the fake-quant
//! semantics are the Pallas kernel's (verified equal in the test suite).
//!
//! Results are printed in EXPERIMENTS.md format.
//!
//! Run: cargo run --release --example qat_e2e -- --model miniresnet18 \
//!        [--pretrain 300] [--qat 80] [--eval-batches 16]

use anyhow::Result;

use dybit::formats::Format;
use dybit::qat::{QuantConfig, Session};
use dybit::runtime::{Executor, Manifest};
use dybit::search::{run_search, Strategy};
use dybit::sim::{HwConfig, Simulator};
use dybit::util::argparse::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let model = args.get_or("model", "miniresnet18");
    let pretrain = args.get_usize("pretrain", 300);
    let qat_steps = args.get_usize("qat", 80);
    let eval_batches = args.get_usize("eval-batches", 16);
    let lr = args.get_f32("lr", 0.05);

    let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
    let mut exec = Executor::new(&manifest.dir)?;
    let mut session = Session::new(&manifest, &model)?;
    let nl = session.model.n_quant_layers;
    println!(
        "model {model} (stands in for {}), {} quant layers, {} params",
        session.model.stands_for,
        nl,
        session.params.iter().map(|p| p.numel()).sum::<usize>()
    );

    // ---- phase 1: FP32 training from scratch, loss curve ----------------
    let fp = QuantConfig::fp32(nl);
    let t0 = std::time::Instant::now();
    println!("\n== FP32 pre-train: {pretrain} steps, lr {lr} ==");
    let chunk = 25;
    for c in 0..pretrain.div_ceil(chunk) {
        let s0 = c * chunk;
        let n = chunk.min(pretrain - s0);
        let ms = session.train(&mut exec, &fp, n, lr, s0 as i32)?;
        let last = ms.last().unwrap();
        println!(
            "step {:4}  loss {:.4}  batch-acc {:.3}  [{:.0}s]",
            s0 + n, last.loss, last.acc, t0.elapsed().as_secs_f64()
        );
    }
    let fp_eval = session.evaluate(&mut exec, &fp, eval_batches)?;
    println!("FP32 eval: loss {:.4} top-1 {:.4}", fp_eval.loss, fp_eval.acc);
    let fp_snapshot = session.snapshot();

    // ---- phase 2: QAT at 4/4 for DyBit vs INT ---------------------------
    println!("\n== QAT fine-tune ({qat_steps} steps, lr {}) ==", lr * 0.2);
    let mut rows = Vec::new();
    for fmt in [Format::DyBit, Format::Int] {
        session.restore(&fp_snapshot);
        let mut q = QuantConfig::uniform(nl, fmt, 4, 4);
        session.calibrate(&mut exec, &mut q, 777)?;
        session.train(&mut exec, &q, qat_steps, lr * 0.2, pretrain as i32)?;
        let ev = session.evaluate(&mut exec, &q, eval_batches)?;
        println!("{:>6}(4/4) top-1 {:.4}", fmt.name(), ev.acc);
        rows.push((fmt, ev.acc));
    }

    // ---- phase 3: hardware-aware search on the trained weights ----------
    session.restore(&fp_snapshot);
    let weights = session.layer_weights();
    let acts = session.layer_acts(&mut exec, 99)?;
    println!("\n== hardware-aware search (Algorithm 1) ==");
    for strategy in [
        Strategy::SpeedupConstrained { alpha: 4.0 },
        Strategy::RmseConstrained { beta: 2.0 },
    ] {
        let sim = Simulator::new(HwConfig::zcu102(), session.model.layers.clone(), 1);
        let r = run_search(&sim, &weights, &acts, Format::DyBit, strategy, 3);
        let mut q = QuantConfig::from_assignment(Format::DyBit, &r.assignment);
        session.restore(&fp_snapshot);
        session.calibrate(&mut exec, &mut q, 778)?;
        session.train(&mut exec, &q, qat_steps / 2, lr * 0.2, (pretrain + 500) as i32)?;
        let ev = session.evaluate(&mut exec, &q, eval_batches)?;
        println!(
            "{strategy:?}: speedup {:.2}x rmse-ratio {:.2} -> top-1 {:.4} (drop {:.2}%)",
            r.speedup,
            r.rmse_ratio,
            ev.acc,
            (fp_eval.acc - ev.acc) * 100.0
        );
    }

    println!("\n== summary (EXPERIMENTS.md format) ==");
    println!("| config | top-1 |");
    println!("|--------|-------|");
    println!("| FP32 | {:.4} |", fp_eval.acc);
    for (fmt, acc) in rows {
        println!("| {}(4/4) | {:.4} |", fmt.name(), acc);
    }
    println!("\nqat_e2e OK ({:.0}s total)", t0.elapsed().as_secs_f64());
    Ok(())
}
