//! Bench: §Perf — serving throughput, 1 vs N replicas (DESIGN.md §9).
//!
//! Closed-loop load over the artifact-free [`SimBackend`]: each batch
//! costs a fixed wall time derived from the cycle-accurate simulator
//! (scaled so a batch is a few ms), so throughput is dominated by how
//! many batches the pool keeps in flight — exactly the quantity the
//! multi-replica rework buys.  Replies are checked for completeness and
//! determinism before any timing is trusted.
//!
//! Run: cargo bench --bench perf_serve [-- --smoke]
//! `--smoke` shrinks the model/load for CI smoke runs
//! (`ci.sh --bench-smoke`); the 2.5× acceptance floor (4 replicas vs 1)
//! only applies to the full-size run.
//!
//! A second phase measures *scheduling* overhead at pool scale
//! (DESIGN.md §11): `time_scale = 0` makes batches free, so wall time is
//! pure submit/route/queue/batch/reply bookkeeping.  A fixed offered
//! load is driven through 4/16/32/64-replica pools — mostly-idle wide
//! pools are exactly the regime where the pre-§11 `notify_all` intake
//! drowned in wakeups — and per-item overhead must stay flat (within 2×
//! of the 4-replica pool, full-size runs only).
//!
//! A third phase measures *recovery* (DESIGN.md §13): chaos kills 1 of
//! 4 replicas mid-load (`die@N:r1`), the supervisor must detect and
//! respawn it within the heartbeat + backoff budget, every receiver
//! must resolve with the four-bucket accounting exact, and the healed
//! pool's goodput must return to ≥ 90% of the pre-kill baseline
//! (full-size runs only; the `recovery_pass` verdict is persisted and
//! gated by `ci.sh --bench-smoke`).

#[path = "common/mod.rs"]
mod common;

use std::collections::HashSet;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use dybit::coordinator::{load_test, BackendFactory, ChaosBackend, ChaosSpec,
                         InferenceBackend, Policy, PoolConfig, Server, SimBackend,
                         SimBackendCfg, SupervisionCfg};
use dybit::models::synthetic_resnet;
use dybit::util::argparse::Args;
use dybit::util::json::Json;
use dybit::util::stats::Table;

const FLOOR: f64 = 2.5;

struct Run {
    wall_s: f64,
    rps: f64,
    p50_ms: f64,
    mean_batch: f64,
    warm_class: usize,
}

/// One closed-loop trial: start a pool, warm it, drive `clients ×
/// per_client` requests, and return throughput + reply bookkeeping.
fn trial(cfg: &SimBackendCfg, replicas: usize, clients: usize, per_client: usize) -> Run {
    let pool = PoolConfig {
        policy: Policy {
            max_batch: cfg.batch,
            max_wait: Duration::from_micros(300),
        },
        queue_cap: 1024,
        replicas,
        ..PoolConfig::default()
    };
    let server = Server::start_pool(pool, SimBackend::factory(cfg.clone()))
        .expect("pool start");
    assert_eq!(server.replicas(), replicas);
    assert_eq!(server.max_batch(), cfg.batch);
    // fixed warm-up payload: also the cross-config determinism probe
    let warm: Vec<f32> = (0..cfg.img_elems).map(|i| (i as f32).sin()).collect();
    let warm_class = server.infer(warm).expect("warm inference");

    let t0 = Instant::now();
    load_test(&server, clients, per_client, cfg.img_elems).expect("load test");
    let wall_s = t0.elapsed().as_secs_f64();
    let snap = server.shutdown().expect("clean shutdown");

    let submitted = (clients * per_client + 1) as u64; // +1 warm-up
    assert_eq!(
        snap.requests + snap.failed_requests + snap.rejected,
        submitted,
        "every submitted request must be accounted for"
    );
    assert_eq!(snap.errors, 0, "sim backend must not fail batches");
    assert_eq!(snap.queue_depth, 0, "queue must drain");
    let replica_batches: u64 = snap.per_replica.iter().map(|r| r.batches).sum();
    assert_eq!(replica_batches, snap.batches);
    Run {
        wall_s,
        rps: (clients * per_client) as f64 / wall_s,
        p50_ms: snap.lat_p50_ms,
        mean_batch: snap.mean_batch,
        warm_class,
    }
}

fn main() {
    let args = Args::from_env();
    let smoke = args.has("smoke");

    // simulator-costed model: resnet-like stack; time_scale turns its
    // simulated batch latency into a target wall cost per batch so the
    // bench is load-bound, not compute-bound
    let (depth, batch, target_batch_s) =
        if smoke { (4, 4, 0.0005) } else { (8, 8, 0.002) };
    let mut cfg = SimBackendCfg {
        layers: synthetic_resnet(depth),
        batch,
        img_elems: 128,
        classes: 10,
        wbits: 4,
        abits: 8,
        seed: 13,
        time_scale: 0.0,
        fail_on: None,
    };
    let probe = SimBackend::new(cfg.clone()).expect("probe backend");
    cfg.time_scale = target_batch_s / probe.sim_latency_s();

    let (clients, per_client, trials) = if smoke { (8, 6, 1) } else { (32, 60, 3) };
    let replica_counts = [1usize, 2, 4];

    let mut t = Table::new(&[
        "replicas", "wall", "req/s", "p50 batch lat", "mean batch", "speedup vs 1",
    ]);
    let mut rows: Vec<Json> = Vec::new();
    let mut best: Vec<(usize, Run)> = Vec::new();
    for &r in &replica_counts {
        // best-of-N absorbs scheduler noise on shared CI boxes
        let mut runs: Vec<Run> = (0..trials)
            .map(|_| trial(&cfg, r, clients, per_client))
            .collect();
        runs.sort_by(|a, b| a.rps.total_cmp(&b.rps));
        best.push((r, runs.pop().expect("at least one trial")));
    }
    // the scorer is seeded per config, not per replica: every pool size
    // must answer the warm-up payload identically
    let warm0 = best[0].1.warm_class;
    assert!(
        best.iter().all(|(_, run)| run.warm_class == warm0),
        "replica pools diverged on the same payload"
    );

    let rps1 = best[0].1.rps;
    let mut speedup_at_4 = 0.0;
    for (r, run) in &best {
        let sp = run.rps / rps1;
        if *r == 4 {
            speedup_at_4 = sp;
        }
        t.row(vec![
            r.to_string(),
            format!("{:.3}s", run.wall_s),
            format!("{:.0}", run.rps),
            format!("{:.2}ms", run.p50_ms),
            format!("{:.1}", run.mean_batch),
            format!("{sp:.2}x"),
        ]);
        rows.push(Json::obj(vec![
            ("replicas", Json::num(*r as f64)),
            ("clients", Json::num(clients as f64)),
            ("per_client", Json::num(per_client as f64)),
            ("wall_s", Json::num(run.wall_s)),
            ("rps", Json::num(run.rps)),
            ("p50_ms", Json::num(run.p50_ms)),
            ("mean_batch", Json::num(run.mean_batch)),
            ("speedup_vs_1", Json::num(sp)),
        ]));
    }
    t.print();

    // ---- phase 2: scheduling overhead at pool scale (DESIGN.md §11)
    // free batches (time_scale 0) + fixed offered load across growing
    // pools: wall time is pure scheduler bookkeeping, and per-item
    // overhead must not grow with the replica count
    let sched_cfg = SimBackendCfg { time_scale: 0.0, ..cfg.clone() };
    let sched_counts: &[usize] = if smoke { &[4, 16] } else { &[4, 16, 32, 64] };
    let (s_clients, s_per_client) = if smoke { (6, 10) } else { (16, 400) };
    let mut st = Table::new(&["replicas", "wall", "req/s", "overhead/item", "vs 4"]);
    let mut sched_rows: Vec<Json> = Vec::new();
    let mut overheads: Vec<(usize, Run, f64)> = Vec::new();
    for &r in sched_counts {
        let mut runs: Vec<Run> = (0..trials)
            .map(|_| trial(&sched_cfg, r, s_clients, s_per_client))
            .collect();
        runs.sort_by(|a, b| a.rps.total_cmp(&b.rps));
        let run = runs.pop().expect("at least one trial");
        let us_item = run.wall_s * 1e6 / (s_clients * s_per_client) as f64;
        overheads.push((r, run, us_item));
    }
    let base = overheads[0].2;
    for (r, run, us_item) in &overheads {
        let ratio = us_item / base;
        st.row(vec![
            r.to_string(),
            format!("{:.3}s", run.wall_s),
            format!("{:.0}", run.rps),
            format!("{us_item:.1}us"),
            format!("{ratio:.2}x"),
        ]);
        sched_rows.push(Json::obj(vec![
            ("replicas", Json::num(*r as f64)),
            ("clients", Json::num(s_clients as f64)),
            ("per_client", Json::num(s_per_client as f64)),
            ("wall_s", Json::num(run.wall_s)),
            ("us_per_item", Json::num(*us_item)),
            ("ratio_vs_4", Json::num(ratio)),
        ]));
    }
    println!("\nscheduling overhead (free batches, fixed load, growing pool):");
    st.print();
    let worst_ratio = overheads.iter().map(|(_, _, o)| o / base).fold(0.0, f64::max);
    let sched_ok = smoke || worst_ratio <= 2.0;
    println!(
        "per-item scheduling overhead 4 -> {} replicas; acceptance: within \
         2.00x of the 4-replica pool: {}",
        sched_counts.last().unwrap(),
        if smoke {
            "n/a (smoke load)".to_string()
        } else {
            format!("{} (worst {worst_ratio:.2}x)", if sched_ok { "PASS" } else { "FAIL" })
        }
    );

    let floor_ok = smoke || speedup_at_4 >= FLOOR;
    println!(
        "\nserving throughput scaling over SimBackend (batch cost {:.1}ms \
         simulated-cycle-derived); acceptance floor {FLOOR:.2}x at 4 replicas \
         vs 1: {}",
        target_batch_s * 1e3,
        if smoke {
            "n/a (smoke load)".to_string()
        } else {
            format!("{} ({speedup_at_4:.2}x)", if floor_ok { "PASS" } else { "FAIL" })
        }
    );
    // ---- phase 3: kill-one-replica recovery (DESIGN.md §13)
    // measure goodput on a healthy 4-replica pool, then run the same
    // load while chaos kills replica 1 mid-flight: the supervisor must
    // detect the death and respawn within the watchdog+backoff budget,
    // every receiver must resolve with the four-bucket accounting
    // exact, and the healed pool must recover to >= 90% of the pre-kill
    // goodput (full-size runs only)
    // the kill is a clean death (detected in one heartbeat tick, not by
    // the watchdog), so the watchdog can sit far above any batch wall
    // time — loaded CI boxes must not spuriously supersede a busy worker
    let sup = SupervisionCfg {
        heartbeat: Duration::from_millis(5),
        watchdog: Duration::from_millis(500),
        max_restarts: 3,
        backoff: Duration::from_millis(10),
        backoff_cap: Duration::from_millis(50),
    };
    let heal_budget =
        sup.watchdog + sup.backoff_for(1) + sup.heartbeat * 2 + Duration::from_millis(500);
    let die_at = if smoke { 1 } else { 5 };
    let (h_clients, h_per_client) = if smoke { (4, 6) } else { (16, 60) };
    let heal_pool = |chaos: bool| -> Server {
        let inner = SimBackend::factory(cfg.clone());
        let factory: BackendFactory = if chaos {
            // only the FIRST incarnation of replica 1 carries the fault:
            // the respawn is clean, so the pool heals instead of flapping
            // its way to retirement
            let spec = ChaosSpec::parse(&format!("die@{die_at}:r1")).expect("chaos spec");
            let seen = Mutex::new(HashSet::new());
            Arc::new(move |r| {
                let first = dybit::util::lock(&seen).insert(r);
                let backend = inner(r)?;
                if first {
                    Ok(Box::new(ChaosBackend::new(backend, &spec, r))
                        as Box<dyn InferenceBackend>)
                } else {
                    Ok(backend)
                }
            })
        } else {
            inner
        };
        let pool = PoolConfig {
            policy: Policy {
                max_batch: cfg.batch,
                max_wait: Duration::from_micros(300),
            },
            queue_cap: 1024,
            replicas: 4,
            supervision: Some(sup.clone()),
            ..PoolConfig::default()
        };
        Server::start_pool(pool, factory).expect("pool start")
    };

    // pre-kill baseline
    let server = heal_pool(false);
    let t0 = Instant::now();
    load_test(&server, h_clients, h_per_client, cfg.img_elems).expect("baseline load");
    let rps_pre = (h_clients * h_per_client) as f64 / t0.elapsed().as_secs_f64();
    let base_snap = server.shutdown().expect("baseline shutdown");
    assert_eq!(base_snap.restarts, 0, "healthy baseline must not restart anything");

    // kill run: replica 1 of 4 dies cleanly after its Nth forward call
    // while the load is in flight
    let server = heal_pool(true);
    load_test(&server, h_clients, h_per_client, cfg.img_elems).expect("kill-phase load");
    // respawn must land within the supervision budget once the replica
    // is dead; the nudge load covers small smoke runs where the main
    // load may finish before replica 1 has served its fatal call
    let tb = Instant::now();
    let deadline = if smoke { Duration::from_secs(10) } else { heal_budget };
    let mut extra = 0u64;
    loop {
        let snap = server.snapshot();
        if snap.restarts >= 1 {
            break;
        }
        assert!(
            tb.elapsed() < deadline,
            "replica 1 was not respawned within the recovery budget {deadline:?}"
        );
        if snap.per_replica[1].batches < die_at as u64 {
            load_test(&server, 1, 4, cfg.img_elems).expect("nudge load");
            extra += 4;
        } else {
            std::thread::sleep(Duration::from_millis(2));
        }
    }
    let respawn_ms = tb.elapsed().as_secs_f64() * 1e3;

    // post-recovery goodput on the healed pool
    let t0 = Instant::now();
    load_test(&server, h_clients, h_per_client, cfg.img_elems).expect("post-recovery load");
    let rps_post = (h_clients * h_per_client) as f64 / t0.elapsed().as_secs_f64();
    let faults = server.fault_log();
    let heal_snap = server.shutdown().expect("supervised shutdown");
    let submitted = (2 * h_clients * h_per_client) as u64 + extra;
    assert_eq!(
        heal_snap.requests
            + heal_snap.failed_requests
            + heal_snap.rejected
            + heal_snap.deadline_drops,
        submitted,
        "four-bucket accounting must stay exact through the kill"
    );
    assert_eq!(heal_snap.queue_depth, 0, "queue must drain after the kill run");
    assert!(heal_snap.restarts >= 1, "the kill must show up as a restart");
    assert_eq!(heal_snap.retired, 0, "one clean death must not exhaust the budget");
    let recovery_ratio = rps_post / rps_pre;
    let recovery_ok = smoke || recovery_ratio >= 0.9;
    println!(
        "\nrecovery: killed 1 of 4 replicas mid-load (die@{die_at}:r1), respawned \
         in {respawn_ms:.0}ms ({} restart(s), {} fault-log line(s)); goodput \
         {rps_pre:.0} -> {rps_post:.0} req/s; acceptance >= 90% of baseline: {}",
        heal_snap.restarts,
        faults.len(),
        if smoke {
            "n/a (smoke load)".to_string()
        } else {
            format!(
                "{} ({:.0}%)",
                if recovery_ok { "PASS" } else { "FAIL" },
                recovery_ratio * 100.0
            )
        }
    );

    common::save_results(
        "perf_serve",
        Json::obj(vec![
            ("smoke", Json::Bool(smoke)),
            ("floor", Json::num(FLOOR)),
            // null on smoke runs: the floor was never evaluated, and a
            // persisted `true` would read as a gate that passed
            ("floor_pass", if smoke { Json::Null } else { Json::Bool(floor_ok) }),
            ("target_batch_s", Json::num(target_batch_s)),
            ("rows", Json::Arr(rows)),
            // null on smoke runs, same contract as floor_pass
            ("sched_flat_pass", if smoke { Json::Null } else { Json::Bool(sched_ok) }),
            ("sched_rows", Json::Arr(sched_rows)),
            // null on smoke runs, same contract as floor_pass
            ("recovery_pass", if smoke { Json::Null } else { Json::Bool(recovery_ok) }),
            (
                "recovery",
                Json::obj(vec![
                    ("rps_pre", Json::num(rps_pre)),
                    ("rps_post", Json::num(rps_post)),
                    ("ratio", Json::num(recovery_ratio)),
                    ("respawn_ms", Json::num(respawn_ms)),
                    ("restarts", Json::num(heal_snap.restarts as f64)),
                ]),
            ),
        ]),
    )
    .expect("save perf results");
    println!("perf_serve done");
    if !floor_ok || !sched_ok || !recovery_ok {
        // make the floors real gates: scripted full-size runs must fail
        std::process::exit(1);
    }
}
