//! Bench: Fig. 6 — the accuracy-speedup trade-off cloud.
//!
//! Collects every (speedup, accuracy) point produced by the Fig. 5 sweep
//! (artifacts/results/fig5.json; run fig5_strategies first, otherwise this
//! bench runs a reduced sweep itself) and prints the per-model frontier.
//!
//! Expected shape: accuracy decays monotonically along the frontier as
//! speedup grows; the MobileNet stand-in's curve stops at a much lower
//! max speedup than the ResNets (depthwise saturation, Sec. IV-C).

#[path = "common/mod.rs"]
mod common;

use dybit::util::json::Json;
use dybit::util::stats::Table;

fn main() {
    let data = match common::load_results("fig5") {
        Some(j) => j,
        None => {
            eprintln!("fig5 results missing — running the fig5 sweep first is recommended;");
            eprintln!("falling back to simulator-only frontier (no QAT accuracy).");
            sim_only_frontier();
            return;
        }
    };
    let points = data.as_arr().expect("fig5.json array");

    // group by model
    let mut models: Vec<String> = Vec::new();
    for p in points {
        let m = p.get("model").and_then(Json::as_str).unwrap().to_string();
        if !models.contains(&m) {
            models.push(m);
        }
    }

    println!("=== Fig. 6: accuracy vs speedup (all strategies pooled) ===");
    for model in &models {
        let mut pts: Vec<(f64, f64, String)> = points
            .iter()
            .filter(|p| p.get("model").and_then(Json::as_str) == Some(model))
            .map(|p| {
                (
                    p.get("speedup").and_then(Json::as_f64).unwrap(),
                    p.get("top1").and_then(Json::as_f64).unwrap() * 100.0,
                    format!(
                        "{}={}",
                        p.get("strategy").and_then(Json::as_str).unwrap_or("?"),
                        p.get("constraint").and_then(Json::as_f64).unwrap_or(0.0)
                    ),
                )
            })
            .collect();
        pts.sort_by(|a, b| a.0.total_cmp(&b.0));
        let fp = points
            .iter()
            .find(|p| p.get("model").and_then(Json::as_str) == Some(model))
            .and_then(|p| p.get("fp32_top1").and_then(Json::as_f64))
            .unwrap_or(0.0)
            * 100.0;

        println!("\n[{model}] FP32 = {fp:.2}%");
        let mut t = Table::new(&["speedup", "top-1 %", "point"]);
        for (s, a, l) in &pts {
            // ascii scatter: one column per 0.5x speedup
            t.row(vec![format!("{s:.2}x"), format!("{a:.2}"), l.clone()]);
        }
        t.print();
        let max_speedup = pts.last().map(|p| p.0).unwrap_or(1.0);
        println!("max speedup reached: {max_speedup:.2}x");
        // simple ascii curve
        println!("curve (x = speedup 1..10, y = accuracy):");
        plot(&pts, fp);
    }
    println!("\nfig6_tradeoff done");
}

/// Minimal ASCII scatter of the trade-off curve.
fn plot(pts: &[(f64, f64, String)], fp: f64) {
    let rows = 10;
    let lo = pts.iter().map(|p| p.1).fold(f64::INFINITY, f64::min).min(fp) - 1.0;
    let hi = fp.max(pts.iter().map(|p| p.1).fold(0.0, f64::max)) + 1.0;
    for r in 0..rows {
        let y = hi - (hi - lo) * r as f64 / (rows - 1) as f64;
        let mut line = format!("{y:6.1} |");
        for c in 0..40 {
            let x = 1.0 + 9.0 * c as f64 / 39.0;
            let hit = pts.iter().any(|p| {
                (p.0 - x).abs() < 9.0 / 39.0 / 2.0 + 1e-9
                    && (p.1 - y).abs() < (hi - lo) / (rows - 1) as f64 / 2.0 + 1e-9
            });
            line.push(if hit { '*' } else { ' ' });
        }
        println!("{line}");
    }
    println!("        +{}", "-".repeat(40));
    println!("         1x{}10x", " ".repeat(34));
}

/// Fallback when fig5.json is absent: frontier from the simulator alone.
fn sim_only_frontier() {
    use dybit::formats::Format;
    use dybit::search::{run_search, Strategy};
    use dybit::sim::{HwConfig, Simulator};
    use dybit::util::rng::Rng;

    let mut rng = Rng::new(5);
    let layers = dybit::models::synthetic_resnet(8);
    let weights: Vec<Vec<f32>> = (0..layers.len()).map(|_| rng.normal_vec(2048)).collect();
    let acts = weights.clone();
    let mut t = Table::new(&["alpha", "speedup", "rmse-ratio"]);
    for alpha in [2.0, 3.0, 4.0, 6.0, 8.0] {
        let sim = Simulator::new(HwConfig::zcu102(), layers.clone(), 1);
        let r = run_search(&sim, &weights, &acts, Format::DyBit,
                           Strategy::SpeedupConstrained { alpha }, 3);
        t.row(vec![format!("{alpha}"), format!("{:.2}x", r.speedup),
                   format!("{:.2}", r.rmse_ratio)]);
    }
    t.print();
}
