//! Bench: §Perf — calibration ladder, old vs new (DESIGN.md §8).
//!
//! Old: the pre-§8 batched ladder (`quantizer::calibrate_scale_projected`)
//! — 54 full GridLut projection + RMSE passes over the tensor per
//! `(format, bits)` query.
//! New: `CalibView` — one radix sort + prefix-sum pass per tensor, then
//! 54 table-sized candidate evaluations per query; the view is reusable
//! across every `(format, bits)` queried on the same tensor (the
//! "shared view" rows sweep all 9 combos through one view).
//!
//! Before timing, every benched (tensor, format, bits) combo asserts
//! that all three ladders — per-element reference
//! (`quantizer::calibrate_scale`), projected, and view — select the
//! *identical* scale.
//!
//! Run: cargo bench --bench perf_calib [-- --smoke]
//! `--smoke` shrinks tensors + iteration counts for CI smoke runs
//! (`ci.sh --bench-smoke`); the 4× acceptance floor only applies to the
//! full-size 1M-element DyBit-4 case.

#[path = "common/mod.rs"]
mod common;

use std::hint::black_box;

use dybit::formats::{quantizer, CalibView, Format};
use dybit::util::argparse::Args;
use dybit::util::json::Json;
use dybit::util::proptest::gen::heavy_tail;
use dybit::util::rng::Rng;
use dybit::util::stats::{fmt_time, Bench, Table};

const FLOOR: f64 = 4.0;

fn main() {
    let args = Args::from_env();
    let smoke = args.has("smoke");
    let sizes: &[usize] = if smoke { &[1024, 4096] } else { &[4096, 65536, 1 << 20] };
    let formats = [Format::DyBit, Format::Int, Format::Posit];
    let bits_set = [2u32, 4, 8];

    let mut t = Table::new(&[
        "n", "format", "bits", "old (projected ladder)", "new (CalibView)", "speedup",
    ]);
    let mut rows: Vec<Json> = Vec::new();
    let mut floor_ok = true;
    let mut rng = Rng::new(2302);

    for &n in sizes {
        let x = heavy_tail(&mut rng, n);
        let bench = if n >= 65536 { Bench::new(1, 3) } else { Bench::new(2, 8) };
        for fmt in formats {
            for bits in bits_set {
                // identical-scale gate first (acceptance criterion), on
                // all three ladders, then wall time
                let grid = fmt.grid(bits);
                let s_ref = quantizer::calibrate_scale(&x, &grid);
                let mut buf = Vec::new();
                let s_old = quantizer::calibrate_scale_projected(&x, fmt, bits, &mut buf);
                let s_new = CalibView::new(&x).calibrate(fmt, bits);
                assert_eq!(
                    s_ref, s_old,
                    "projected ladder diverged from reference: n={n} {fmt:?} b{bits}"
                );
                assert_eq!(
                    s_ref, s_new,
                    "CalibView ladder diverged from reference: n={n} {fmt:?} b{bits}"
                );

                let s_o = bench.run(|| {
                    black_box(quantizer::calibrate_scale_projected(
                        &x, fmt, bits, &mut buf,
                    ));
                });
                // fresh view per iteration: the honest single-query cost
                let s_n = bench.run(|| {
                    black_box(CalibView::new(&x).calibrate(fmt, bits));
                });
                let sp = s_o.mean / s_n.mean;
                if !smoke && n == (1 << 20) && fmt == Format::DyBit && bits == 4
                    && sp < FLOOR
                {
                    floor_ok = false;
                }
                t.row(vec![
                    format!("{n}"),
                    fmt.name().into(),
                    format!("{bits}"),
                    fmt_time(s_o.mean),
                    fmt_time(s_n.mean),
                    format!("{sp:.2}x"),
                ]);
                rows.push(Json::obj(vec![
                    ("n", Json::num(n as f64)),
                    ("format", Json::str(fmt.name())),
                    ("bits", Json::num(bits as f64)),
                    ("old_s", Json::num(s_o.mean)),
                    ("new_s", Json::num(s_n.mean)),
                    ("speedup", Json::num(sp)),
                ]));
            }
        }

        // amortization: all 9 (format, bits) queries on ONE tensor —
        // the cost-table-fill / format-sweep shape — share a single view
        let mut buf = Vec::new();
        let s_o = bench.run(|| {
            for fmt in formats {
                for bits in bits_set {
                    black_box(quantizer::calibrate_scale_projected(
                        &x, fmt, bits, &mut buf,
                    ));
                }
            }
        });
        let s_n = bench.run(|| {
            let view = CalibView::new(&x);
            for fmt in formats {
                for bits in bits_set {
                    black_box(view.calibrate(fmt, bits));
                }
            }
        });
        let sp = s_o.mean / s_n.mean;
        t.row(vec![
            format!("{n}"),
            "all-3".into(),
            "2/4/8 (shared view)".into(),
            fmt_time(s_o.mean),
            fmt_time(s_n.mean),
            format!("{sp:.2}x"),
        ]);
        rows.push(Json::obj(vec![
            ("n", Json::num(n as f64)),
            ("format", Json::str("all-3-shared-view")),
            ("bits", Json::num(0.0)),
            ("old_s", Json::num(s_o.mean)),
            ("new_s", Json::num(s_n.mean)),
            ("speedup", Json::num(sp)),
        ]));
    }

    t.print();
    println!(
        "\nCalibration-ladder speedup (sorted prefix-sum cell evaluation vs \
         54 full projection+RMSE passes); acceptance floor {FLOOR:.2}x on \
         the 1M-element DyBit-4 single query: {}",
        if smoke {
            "n/a (smoke tensors)"
        } else if floor_ok {
            "PASS"
        } else {
            "FAIL"
        }
    );
    common::save_results(
        "perf_calib",
        Json::obj(vec![
            ("smoke", Json::Bool(smoke)),
            ("floor", Json::num(FLOOR)),
            ("floor_pass", Json::Bool(floor_ok)),
            ("rows", Json::Arr(rows)),
        ]),
    )
    .expect("save perf results");
    println!("perf_calib done");
    if !smoke && !floor_ok {
        // make the floor a real gate: scripted full-size runs must fail
        std::process::exit(1);
    }
}
