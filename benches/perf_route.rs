//! Bench: §Perf — heterogeneous-precision routing, mixed pool vs the
//! all-8-bit pool at equal replica count (DESIGN.md §10).
//!
//! Closed-loop load over the artifact-free [`SimBackend`] where each
//! replica's batch cost comes from the §3 cycle simulator *at its own
//! precision*: three DyBit-4 replicas cost ~2.6× less per batch than an
//! 8-bit one on the ResNet-like stack, so a 3×(4,4) + 1×(8,8) pool
//! should beat 4×(8,8) by ~(3·2.6 + 1)/4 ≈ 2.2× — the Fig. 6
//! accuracy/speedup trade-off moved to the serving tier.  A second
//! phase drives a seeded low-margin workload through the
//! confidence-escalation router and asserts the escalation accounting.
//! A third phase widens the mixed pool to 16 replicas (12×4b + 4×8b)
//! over the §11 intake: weighted round-robin must still feed every
//! replica, the accounting must stay exact, and the wide pool must beat
//! the 4-replica all-8 baseline.
//!
//! Two §12 phases close the overload story.  The *overload* phase
//! offers open-loop arrival at ~1.5× the pool's simulated capacity with
//! a per-request SLA, once with SLA-aware admission and once with plain
//! blocking submits: admission must convert the queue-delay collapse
//! into cheap typed rejects and hold goodput (on-time replies/s) at
//! ≥1.3× the admission-off run.  The *controller* phase runs
//! `Escalate::auto_tuned()` under a margin-uniform workload and asserts
//! the PI-tuned escalation rate settles within ±20% of its budget.
//!
//! A §15 *refinement* phase drives the same escalate-everything
//! workload through a [`BitplaneBackend`] pool twice — refinement on
//! (escalations add only the residual planes to cached partial sums)
//! vs `refine: false` (the pre-§15 full re-run) — and gates the
//! simulated cycle cost: the refinement run must be ≥1.3× cheaper
//! (ideal (4+8)/(4+4) = 1.5× on the 3×4b+1×8b mix), with identical
//! answers.
//!
//! Run: cargo bench --bench perf_route [-- --smoke]
//! `--smoke` shrinks the model/load for CI smoke runs
//! (`ci.sh --bench-smoke`); the 1.8× routing floor, the 1.3× goodput
//! floor, and the ±20% controller band only gate the full-size run.
//! The §15 refinement gate reads the deterministic [`SimCostMeter`],
//! not wall time, so it gates smoke runs too.

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;
use std::time::{Duration, Instant};

use dybit::coordinator::{
    load_test, AdmissionCfg, BitplaneBackend, Escalate, EscalationController, Fastest,
    InferenceBackend, Policy, PoolConfig, Reject, ReplicaPrecision, Router, Server,
    SimBackend, SimBackendCfg, SimCostMeter, SubmitOpts,
};
use dybit::models::synthetic_resnet;
use dybit::tensor::Tensor;
use dybit::util::argparse::Args;
use dybit::util::json::Json;
use dybit::util::rng::Rng;
use dybit::util::stats::Table;

const FLOOR: f64 = 1.8;
/// Goodput-under-SLA floor: admission-on must beat admission-off by
/// this factor in the overload phase (full-size runs only).
const GOODPUT_FLOOR: f64 = 1.3;
/// §15 refinement floor: on the escalate-everything workload the
/// refinement pool's *simulated* cycle cost must beat the full-re-run
/// pool by this factor (gates smoke runs too — the meter is
/// deterministic).
const REFINE_FLOOR: f64 = 1.3;

struct Run {
    wall_s: f64,
    rps: f64,
    p50_ms: f64,
    warm_class: usize,
}

/// One closed-loop trial of a pool with the given per-replica precision
/// mix under the Fastest router; panics on any accounting violation.
fn trial(cfg: &SimBackendCfg, mix: &[ReplicaPrecision], clients: usize,
         per_client: usize) -> Run {
    let pool = PoolConfig {
        policy: Policy {
            max_batch: cfg.batch,
            max_wait: Duration::from_micros(300),
        },
        queue_cap: 1024,
        replicas: mix.len(),
        precisions: mix.to_vec(),
        router: Arc::new(Fastest::new()),
        work_stealing: true,
        ..PoolConfig::default()
    };
    let server = Server::start_pool(pool, SimBackend::mixed_factory(cfg.clone(), mix.to_vec()))
        .expect("pool start");
    assert_eq!(server.replicas(), mix.len());
    // fixed warm-up payload: also the cross-pool determinism probe
    let warm: Vec<f32> = (0..cfg.img_elems).map(|i| (i as f32).sin()).collect();
    let warm_class = server.infer(warm).expect("warm inference");

    let t0 = Instant::now();
    load_test(&server, clients, per_client, cfg.img_elems).expect("load test");
    let wall_s = t0.elapsed().as_secs_f64();
    let snap = server.shutdown().expect("clean shutdown");

    let submitted = (clients * per_client + 1) as u64; // +1 warm-up
    assert_eq!(
        snap.requests + snap.failed_requests + snap.rejected + snap.deadline_drops,
        submitted,
        "every submitted request must be accounted for"
    );
    assert_eq!(snap.errors, 0, "sim backend must not fail batches");
    assert_eq!(snap.escalations, 0, "the Fastest router never escalates");
    assert_eq!(snap.queue_depth, 0, "queues must drain");
    let routed: u64 = snap.per_replica.iter().map(|r| r.routed).sum();
    assert_eq!(routed, submitted, "every request is routed exactly once");
    assert!(
        snap.per_replica.iter().all(|r| r.routed > 0),
        "weighted round-robin must feed every replica: {:?}",
        snap.per_replica
    );
    Run {
        wall_s,
        rps: (clients * per_client) as f64 / wall_s,
        p50_ms: snap.lat_p50_ms,
        warm_class,
    }
}

/// Escalation phase: a mixed pool under the confidence-escalation
/// router.  `scale` controls the payload norm and thereby the argmax
/// margin — near-zero payloads have near-zero margins and must all
/// escalate; large payloads almost never do.  Stealing is off so the
/// accurate tier cannot absorb primary traffic before it escalates.
fn escalation_rate(cfg: &SimBackendCfg, mix: &[ReplicaPrecision], n: usize,
                   scale: f32) -> (f64, u64) {
    let pool = PoolConfig {
        policy: Policy {
            max_batch: cfg.batch,
            max_wait: Duration::from_micros(200),
        },
        queue_cap: 1024,
        replicas: mix.len(),
        precisions: mix.to_vec(),
        router: Arc::new(Escalate::new(0.05)),
        work_stealing: false,
        ..PoolConfig::default()
    };
    let server = Server::start_pool(pool, SimBackend::mixed_factory(cfg.clone(), mix.to_vec()))
        .expect("pool start");
    let mut rng = dybit::util::rng::Rng::new(4242);
    let rxs: Vec<_> = (0..n)
        .map(|_| {
            let img: Vec<f32> =
                rng.normal_vec(cfg.img_elems).iter().map(|v| v * scale).collect();
            server.submit(img).expect("submit")
        })
        .collect();
    for rx in &rxs {
        rx.recv_timeout(Duration::from_secs(60))
            .expect("reply")
            .expect("class");
    }
    let snap = server.shutdown().expect("clean shutdown");
    assert_eq!(
        snap.requests + snap.failed_requests + snap.rejected + snap.deadline_drops,
        n as u64,
        "escalated requests must still be answered exactly once"
    );
    let initiated: u64 = snap.per_replica.iter().map(|r| r.escalations).sum();
    assert_eq!(initiated, snap.escalations, "per-replica escalations must sum to global");
    (snap.escalations as f64 / n as f64, snap.escalations)
}

struct Overload {
    submitted: u64,
    rejected: u64,
    on_time: u64,
    goodput: f64,
    deadline_drops: u64,
}

/// §12 overload phase: open-loop arrival at `arrival_rps` with a
/// per-request SLA.  With admission off every request is accepted and
/// queue delay alone blows the deadline; with SLA-aware admission the
/// infeasible tail is rejected at submit (a cheap typed `Err`) and the
/// accepted stream stays inside its deadline.  Eight paced generators
/// each feed a paired consumer so submission cadence never blocks on
/// `recv`; a reply counts toward goodput only if it arrives `Ok` before
/// the deadline measured from the submit attempt.
fn overload_trial(cfg: &SimBackendCfg, mix: &[ReplicaPrecision], admission_on: bool,
                  deadline: Duration, arrival_rps: f64, dur: Duration) -> Overload {
    let admission = if admission_on {
        AdmissionCfg {
            batch_cost: cfg.projected_batch_costs(mix).expect("cost projection"),
            tenants: 4,
            // headroom: admit only when the projection clears the
            // deadline with 50% margin, so admitted ≈ on-time
            slack: 1.5,
        }
    } else {
        AdmissionCfg::default()
    };
    let pool = PoolConfig {
        policy: Policy {
            max_batch: cfg.batch,
            max_wait: Duration::from_micros(300),
        },
        queue_cap: 256,
        replicas: mix.len(),
        precisions: mix.to_vec(),
        router: Arc::new(Fastest::new()),
        work_stealing: true,
        admission,
        ..PoolConfig::default()
    };
    let server = Server::start_pool(pool, SimBackend::mixed_factory(cfg.clone(), mix.to_vec()))
        .expect("pool start");
    let gens = 8usize;
    let interval = Duration::from_secs_f64(gens as f64 / arrival_rps);
    let t0 = Instant::now();
    let (submitted, rejected, on_time) = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..gens)
            .map(|g| {
                let server = &server;
                scope.spawn(move || {
                    type Reply = std::result::Result<usize, String>;
                    let (tx, feed) =
                        std::sync::mpsc::channel::<(std::sync::mpsc::Receiver<Reply>, Instant)>();
                    let consumer = std::thread::spawn(move || {
                        let mut on_time = 0u64;
                        for (rx, dl) in feed {
                            let reply = rx
                                .recv_timeout(Duration::from_secs(60))
                                .expect("every accepted receiver must resolve");
                            if reply.is_ok() && Instant::now() <= dl {
                                on_time += 1;
                            }
                        }
                        on_time
                    });
                    let mut rng = Rng::new(900 + g as u64);
                    let (mut submitted, mut rejected) = (0u64, 0u64);
                    let phase = interval.mul_f64(g as f64 / gens as f64);
                    for i in 0u64.. {
                        let due = t0 + phase + interval.mul_f64(i as f64);
                        if due.duration_since(t0) >= dur {
                            break;
                        }
                        let now = Instant::now();
                        if due > now {
                            std::thread::sleep(due - now);
                        }
                        let img = rng.normal_vec(cfg.img_elems);
                        submitted += 1;
                        // the SLA clock starts at the submit *attempt*:
                        // a blocking submit spends it in the queue's stead
                        let dl = Instant::now() + deadline;
                        if admission_on {
                            let opts = SubmitOpts { deadline: Some(deadline), tenant: g as u32 };
                            match server.submit_with(img, opts) {
                                Ok(rx) => tx.send((rx, dl)).expect("feed consumer"),
                                Err(
                                    Reject::QueueFull { .. }
                                    | Reject::DeadlineInfeasible { .. }
                                    | Reject::TenantThrottled { .. },
                                ) => rejected += 1,
                                Err(other) => panic!("unexpected reject: {other}"),
                            }
                        } else {
                            let rx = server.submit(img).expect("plain submit");
                            tx.send((rx, dl)).expect("feed consumer");
                        }
                    }
                    drop(tx);
                    let on_time = consumer.join().expect("consumer thread");
                    (submitted, rejected, on_time)
                })
            })
            .collect();
        handles.into_iter().fold((0, 0, 0), |acc, h| {
            let (s, r, o) = h.join().expect("generator thread");
            (acc.0 + s, acc.1 + r, acc.2 + o)
        })
    });
    let wall_s = t0.elapsed().as_secs_f64();
    let snap = server.shutdown().expect("clean shutdown");
    assert_eq!(
        snap.requests + snap.failed_requests + snap.rejected + snap.deadline_drops,
        submitted,
        "overload accounting must cover every submit attempt"
    );
    assert_eq!(snap.rejected, rejected, "admission rejects must all be counted");
    Overload {
        submitted,
        rejected,
        on_time,
        goodput: on_time as f64 / wall_s,
        deadline_drops: snap.deadline_drops,
    }
}

/// §12 controller phase: `Escalate::auto_tuned()` with the PI margin
/// tuner steering the escalation rate onto `budget`.  Payload norms are
/// drawn so the argmax margin is ~uniform on [0, 2] (normalized by a
/// probed unit-payload margin): the rate is then a smooth, near-linear
/// function of the margin knob and a converged controller sits at the
/// budget.  Runs the load twice; returns (rate over the settled second
/// half, final knob margin).
fn controller_trial(cfg: &SimBackendCfg, mix: &[ReplicaPrecision], budget: f64,
                    clients: usize, per_half: usize) -> (f64, f64) {
    // probe the argmax margin of a unit-normal payload so the workload
    // can be normalized to margin ≈ scale, model- and seed-independent
    let mut probe = SimBackend::new(SimBackendCfg { time_scale: 0.0, ..cfg.clone() })
        .expect("margin probe");
    let mut rng = Rng::new(321);
    let rows = cfg.batch;
    let mut xdata = Vec::with_capacity(rows * cfg.img_elems);
    for _ in 0..rows {
        xdata.extend(rng.normal_vec(cfg.img_elems));
    }
    let logits = probe
        .forward(Tensor::new(vec![rows, cfg.img_elems], xdata).expect("probe tensor"))
        .expect("probe forward");
    let mut margins: Vec<f32> = logits.argmax_margin_rows().iter().map(|&(_, m)| m).collect();
    margins.sort_by(f32::total_cmp);
    let unit_margin = margins[rows / 2].max(1e-6);

    let router = Arc::new(Escalate::auto_tuned());
    let knob = router.margin_knob().expect("auto-tuned escalate exposes its knob");
    let mut ctl = EscalationController::with_budget(budget);
    ctl.interval = Duration::from_millis(5);
    ctl.min_samples = 64;
    // the margin-uniform workload has a gentle rate-vs-margin slope
    // (~0.5 per margin unit), so a stiffer integral still converges in
    // well under one load half while staying far from instability
    ctl.ki = 12.0;
    let pool = PoolConfig {
        policy: Policy {
            max_batch: cfg.batch,
            max_wait: Duration::from_micros(200),
        },
        queue_cap: 1024,
        replicas: mix.len(),
        precisions: mix.to_vec(),
        router: router.clone(),
        work_stealing: false, // fast tiers must make the first-run decisions
        escalation: Some(ctl),
        ..PoolConfig::default()
    };
    let server = Server::start_pool(pool, SimBackend::mixed_factory(cfg.clone(), mix.to_vec()))
        .expect("pool start");
    let load = |half: u64| {
        std::thread::scope(|scope| {
            for c in 0..clients {
                let server = &server;
                scope.spawn(move || {
                    let mut rng = Rng::new(1 + half * 1000 + c as u64);
                    for _ in 0..per_half {
                        let scale = rng.uniform_in(0.0, 2.0) / unit_margin;
                        let img: Vec<f32> =
                            rng.normal_vec(cfg.img_elems).iter().map(|v| v * scale).collect();
                        let rx = server.submit(img).expect("submit");
                        rx.recv_timeout(Duration::from_secs(60))
                            .expect("reply")
                            .expect("class");
                    }
                });
            }
        });
    };
    load(0); // settle: the controller walks the knob onto the budget
    let snap0 = server.snapshot();
    load(1); // measure: rate over the settled half only
    let snap1 = server.snapshot();
    let firsts = (snap1.first_runs - snap0.first_runs).max(1);
    let rate = (snap1.escalations - snap0.escalations) as f64 / firsts as f64;
    let margin = f64::from(knob.get());
    let snap = server.shutdown().expect("clean shutdown");
    let total = (2 * clients * per_half) as u64;
    assert_eq!(
        snap.requests + snap.failed_requests + snap.rejected + snap.deadline_drops,
        total,
        "controller phase accounting"
    );
    (rate, margin)
}

/// §15 refinement phase: one escalation-heavy run over a
/// [`BitplaneBackend`] pool.  Near-zero payloads give near-zero argmax
/// margins, so every request escalates off the fast tier; with `refine`
/// on the accurate tier completes the cached partial sums (residual
/// planes only), with it off it re-runs from scratch.  Every replica
/// shares one [`SimCostMeter`], so the returned cost is the §3 cycle
/// model's — deterministic, immune to CI scheduler noise.
fn refinement_trial(cfg: &SimBackendCfg, mix: &[ReplicaPrecision], n: usize,
                    refine: bool) -> (f64, Vec<usize>) {
    let meter = Arc::new(SimCostMeter::new());
    let pool = PoolConfig {
        policy: Policy {
            max_batch: cfg.batch,
            max_wait: Duration::from_micros(300),
        },
        queue_cap: 1024,
        replicas: mix.len(),
        precisions: mix.to_vec(),
        router: Arc::new(Escalate::new(0.05)),
        work_stealing: false, // the accurate tier must not pre-steal the probe
        refine,
        ..PoolConfig::default()
    };
    let server = Server::start_pool(
        pool,
        BitplaneBackend::metered_mixed_factory(cfg.clone(), mix.to_vec(),
                                               Some(Arc::clone(&meter))),
    )
    .expect("pool start");
    let mut rng = Rng::new(777);
    let rxs: Vec<_> = (0..n)
        .map(|_| {
            let img: Vec<f32> =
                rng.normal_vec(cfg.img_elems).iter().map(|v| v * 1e-6).collect();
            server.submit(img).expect("submit")
        })
        .collect();
    let answers: Vec<usize> = rxs
        .iter()
        .map(|rx| rx.recv_timeout(Duration::from_secs(60)).expect("reply").expect("class"))
        .collect();
    let snap = server.shutdown().expect("clean shutdown");
    assert_eq!(
        snap.requests + snap.failed_requests + snap.rejected + snap.deadline_drops,
        n as u64,
        "refinement phase accounting"
    );
    assert_eq!(snap.escalations, n as u64, "every near-zero-margin request must escalate");
    match refine {
        true => assert_eq!(
            snap.refinements, n as u64,
            "refine:on must serve every escalation from cached planes"
        ),
        false => assert_eq!(
            snap.refinements, 0,
            "refine:off must never touch the plane cache"
        ),
    }
    (meter.total_s(), answers)
}

fn main() {
    let args = Args::from_env();
    let smoke = args.has("smoke");

    // simulator-costed model: resnet-like stack; time_scale pins the
    // *8-bit* batch cost to a target wall time, and every other tier
    // scales by its own simulated cycle count — the per-precision cost
    // ratio is the simulator's, not hand-picked.  16 ms (vs perf_serve's
    // 2 ms) amortizes the per-batch scheduling overhead that compresses
    // the tier ratio on small CI boxes: a C/pthreads transliteration of
    // the pool dynamics on a loaded 2-core box measured 1.3–1.85×
    // single-run at 8 ms batches but 1.7–2.1× at 16 ms (ideal 2.23×);
    // the best-of-`trials` pairing below is what gates — closed-loop
    // noise only lowers rps below pool capacity, never above
    let (depth, batch, target_batch8_s) =
        if smoke { (4, 4, 0.0005) } else { (8, 8, 0.016) };
    let mut cfg = SimBackendCfg {
        layers: synthetic_resnet(depth),
        batch,
        img_elems: 128,
        classes: 10,
        wbits: 8,
        abits: 8,
        seed: 13,
        time_scale: 0.0,
        fail_on: None,
    };
    let probe8 = SimBackend::new(cfg.clone()).expect("8-bit probe");
    cfg.time_scale = target_batch8_s / probe8.sim_latency_s();
    let probe4 = SimBackend::new(SimBackendCfg { wbits: 4, abits: 4, ..cfg.clone() })
        .expect("4-bit probe");
    let tier_ratio = probe8.sim_latency_s() / probe4.sim_latency_s();

    let mixed: Vec<ReplicaPrecision> = vec![
        ReplicaPrecision::uniform(4),
        ReplicaPrecision::uniform(4),
        ReplicaPrecision::uniform(4),
        ReplicaPrecision::uniform(8),
    ];
    let all8: Vec<ReplicaPrecision> = vec![ReplicaPrecision::uniform(8); 4];

    // enough closed-loop clients to saturate BOTH pools: the mixed
    // pool's capacity is ~2.2× the all-8 one's, and an under-offered
    // comparison is client-latency-bound and shows no routing effect
    let (clients, per_client, trials) = if smoke { (8, 6, 1) } else { (64, 40, 3) };

    let mut t = Table::new(&["pool", "wall", "req/s", "p50 batch lat", "speedup vs all-8"]);
    let mut rows: Vec<Json> = Vec::new();
    let mut best: Vec<(&str, Run)> = Vec::new();
    for (name, mix) in [("all-8bit", &all8), ("mixed 3x4b+1x8b", &mixed)] {
        // best-of-N absorbs scheduler noise on shared CI boxes
        let mut runs: Vec<Run> = (0..trials)
            .map(|_| trial(&cfg, mix, clients, per_client))
            .collect();
        runs.sort_by(|a, b| a.rps.total_cmp(&b.rps));
        best.push((name, runs.pop().expect("at least one trial")));
    }
    // the scorer is seeded per config, not per precision tier: both
    // pools must answer the warm-up payload identically
    assert_eq!(
        best[0].1.warm_class, best[1].1.warm_class,
        "heterogeneous pool diverged on the same payload"
    );

    let rps8 = best[0].1.rps;
    let mut speedup = 0.0;
    for (name, run) in &best {
        let sp = run.rps / rps8;
        if *name != "all-8bit" {
            speedup = sp;
        }
        t.row(vec![
            name.to_string(),
            format!("{:.3}s", run.wall_s),
            format!("{:.0}", run.rps),
            format!("{:.2}ms", run.p50_ms),
            format!("{sp:.2}x"),
        ]);
        rows.push(Json::obj(vec![
            ("pool", Json::str(name)),
            ("clients", Json::num(clients as f64)),
            ("per_client", Json::num(per_client as f64)),
            ("wall_s", Json::num(run.wall_s)),
            ("rps", Json::num(run.rps)),
            ("p50_ms", Json::num(run.p50_ms)),
            ("speedup_vs_all8", Json::num(sp)),
        ]));
    }
    t.print();

    // ---- wide mixed pool over the §11 intake: 16 replicas, 12 fast +
    // 4 accurate.  trial() asserts WRR feeds every replica and the
    // accounting stays exact at this width; throughput must clearly
    // beat the 4-replica all-8 baseline
    let wide: Vec<ReplicaPrecision> = (0..16)
        .map(|i| ReplicaPrecision::uniform(if i % 4 == 3 { 8 } else { 4 }))
        .collect();
    let (w_clients, w_per_client) = if smoke { (12, 4) } else { (128, 16) };
    let mut wide_runs: Vec<Run> = (0..trials)
        .map(|_| trial(&cfg, &wide, w_clients, w_per_client))
        .collect();
    wide_runs.sort_by(|a, b| a.rps.total_cmp(&b.rps));
    let wide_run = wide_runs.pop().expect("at least one trial");
    let wide_sp = wide_run.rps / rps8;
    println!(
        "\nwide mixed pool 12x4b+4x8b (16 replicas): {:.0} req/s, {wide_sp:.2}x \
         vs all-8bit at 4 replicas",
        wide_run.rps
    );
    assert_eq!(wide_run.warm_class, best[0].1.warm_class, "wide pool diverged");
    assert!(
        smoke || wide_run.rps > rps8,
        "a 16-replica mixed pool must beat the 4-replica all-8 pool \
         ({:.0} vs {rps8:.0} req/s)",
        wide_run.rps
    );
    rows.push(Json::obj(vec![
        ("pool", Json::str("mixed 12x4b+4x8b (16r)")),
        ("clients", Json::num(w_clients as f64)),
        ("per_client", Json::num(w_per_client as f64)),
        ("wall_s", Json::num(wide_run.wall_s)),
        ("rps", Json::num(wide_run.rps)),
        ("p50_ms", Json::num(wide_run.p50_ms)),
        ("speedup_vs_all8", Json::num(wide_sp)),
    ]));

    // escalation accounting under the confidence router: near-zero
    // payloads have near-zero argmax margins — every one served by a
    // fast replica must re-run on the accurate tier; large payloads
    // have O(1)-margin logits and must (almost) never escalate
    let esc_n = if smoke { 40 } else { 200 };
    let (low_rate, low_escalations) = escalation_rate(&cfg, &mixed, esc_n, 1e-6);
    let (high_rate, _) = escalation_rate(&cfg, &mixed, esc_n, 100.0);
    println!(
        "\nescalation rate (margin 0.05): low-margin workload {:.0}% ({low_escalations} \
         re-runs / {esc_n}), high-margin workload {:.1}%",
        low_rate * 100.0,
        high_rate * 100.0
    );
    assert!(
        (low_rate - 1.0).abs() < 1e-12,
        "every low-margin request lands on a fast replica (escalate routes primary \
         traffic there, stealing off) and must escalate; rate {low_rate}"
    );
    assert!(
        high_rate < 0.05,
        "high-margin workload must (almost) never escalate; rate {high_rate}"
    );

    // ---- §12 overload: open-loop arrival at ~1.5× the simulated pool
    // capacity with a per-request SLA; SLA-aware admission must turn
    // the queue-delay collapse into typed rejects and hold goodput
    let costs = cfg.projected_batch_costs(&mixed).expect("cost projection");
    let capacity: f64 = costs
        .iter()
        .map(|c| cfg.batch as f64 / c.as_secs_f64().max(1e-12))
        .sum();
    let arrival = 1.5 * capacity;
    let (deadline, dur) = if smoke {
        (Duration::from_millis(8), Duration::from_millis(250))
    } else {
        (Duration::from_millis(50), Duration::from_secs(2))
    };
    let on = overload_trial(&cfg, &mixed, true, deadline, arrival, dur);
    let off = overload_trial(&cfg, &mixed, false, deadline, arrival, dur);
    let goodput_ratio = on.goodput / off.goodput.max(1e-9);
    let goodput_ok = smoke || goodput_ratio >= GOODPUT_FLOOR;
    println!(
        "\noverload: {}ms SLA at {arrival:.0}/s offered (~1.5x capacity {capacity:.0}/s)\n  \
         admission on : {} on-time of {} submitted ({} rejected, {} dropped) -> \
         {:.0} good/s\n  admission off: {} on-time of {} submitted -> {:.0} good/s\n  \
         goodput ratio {goodput_ratio:.2}x (floor {GOODPUT_FLOOR:.2}x): {}",
        deadline.as_millis(),
        on.on_time,
        on.submitted,
        on.rejected,
        on.deadline_drops,
        on.goodput,
        off.on_time,
        off.submitted,
        off.goodput,
        if smoke {
            "n/a (smoke load)".to_string()
        } else if goodput_ok {
            "PASS".to_string()
        } else {
            "FAIL".to_string()
        }
    );

    // ---- §12 closed-loop margin tuning, run at a fast time scale: the
    // controller steers decision *counts*, not batch wall time
    let mut pi_cfg = cfg.clone();
    pi_cfg.time_scale = 0.0005 / probe8.sim_latency_s();
    let budget = 0.25;
    let (pi_clients, per_half) = if smoke { (4, 100) } else { (16, 1500) };
    let (pi_rate, pi_margin) = controller_trial(&pi_cfg, &mixed, budget, pi_clients, per_half);
    let controller_ok = smoke || (pi_rate - budget).abs() <= 0.2 * budget;
    println!(
        "escalation budget {budget:.2}: settled rate {pi_rate:.3} \
         (tuned margin {pi_margin:.4}): {}",
        if smoke {
            "n/a (smoke load)".to_string()
        } else if controller_ok {
            "PASS (within +/-20%)".to_string()
        } else {
            "FAIL".to_string()
        }
    );

    // ---- §15 refinement vs full re-run, gated on the deterministic
    // simulated cycle cost (so it gates smoke runs too): the fast pass
    // spends wbits/8 of the full batch cost and a refinement only the
    // residual planes, so escalate-everything should cost ~(4+4)/(4+8)
    // of the re-run pool — ideal 1.5×, floor 1.3×
    let mut refine_cfg = cfg.clone();
    refine_cfg.time_scale = 0.0002 / probe8.sim_latency_s();
    let refine_n = if smoke { 48 } else { 240 };
    let (cost_on, ans_on) = refinement_trial(&refine_cfg, &mixed, refine_n, true);
    let (cost_off, ans_off) = refinement_trial(&refine_cfg, &mixed, refine_n, false);
    assert_eq!(ans_on, ans_off, "refinement changed a deterministic answer");
    let refine_ratio = cost_off / cost_on.max(1e-12);
    let refine_ok = refine_ratio >= REFINE_FLOOR;
    println!(
        "refinement vs full re-run ({refine_n} escalations): simulated cost \
         {:.4}s refined vs {:.4}s re-run -> {refine_ratio:.2}x \
         (floor {REFINE_FLOOR:.2}x): {}",
        cost_on,
        cost_off,
        if refine_ok { "PASS" } else { "FAIL" }
    );

    let floor_ok = smoke || speedup >= FLOOR;
    println!(
        "\nheterogeneous routing over SimBackend (8-bit batch cost {:.1}ms, \
         simulated 8b/4b tier ratio {tier_ratio:.2}x); acceptance floor \
         {FLOOR:.2}x mixed vs all-8 at 4 replicas: {}",
        target_batch8_s * 1e3,
        if smoke {
            "n/a (smoke load)".to_string()
        } else {
            format!("{} ({speedup:.2}x)", if floor_ok { "PASS" } else { "FAIL" })
        }
    );
    common::save_results(
        "perf_route",
        Json::obj(vec![
            ("smoke", Json::Bool(smoke)),
            ("floor", Json::num(FLOOR)),
            // null on smoke runs: the gates were never evaluated, and a
            // persisted `true` would read as a gate that passed
            ("floor_pass", if smoke { Json::Null } else { Json::Bool(floor_ok) }),
            ("goodput_floor", Json::num(GOODPUT_FLOOR)),
            ("goodput_pass", if smoke { Json::Null } else { Json::Bool(goodput_ok) }),
            (
                "controller_pass",
                if smoke { Json::Null } else { Json::Bool(controller_ok) },
            ),
            ("refine_floor", Json::num(REFINE_FLOOR)),
            // a real boolean even on smoke: the refinement gate reads
            // the deterministic SimCostMeter, never wall time
            ("refine_pass", Json::Bool(refine_ok)),
            ("target_batch8_s", Json::num(target_batch8_s)),
            ("tier_ratio", Json::num(tier_ratio)),
            ("rows", Json::Arr(rows)),
            (
                "escalation",
                Json::obj(vec![
                    ("margin", Json::num(0.05)),
                    ("submitted", Json::num(esc_n as f64)),
                    ("low_margin_rate", Json::num(low_rate)),
                    ("high_margin_rate", Json::num(high_rate)),
                ]),
            ),
            (
                "overload",
                Json::obj(vec![
                    ("deadline_ms", Json::num(deadline.as_secs_f64() * 1e3)),
                    ("capacity_rps", Json::num(capacity)),
                    ("arrival_rps", Json::num(arrival)),
                    ("goodput_on", Json::num(on.goodput)),
                    ("goodput_off", Json::num(off.goodput)),
                    ("goodput_ratio", Json::num(goodput_ratio)),
                    ("on_time_on", Json::num(on.on_time as f64)),
                    ("on_time_off", Json::num(off.on_time as f64)),
                    ("submitted_on", Json::num(on.submitted as f64)),
                    ("submitted_off", Json::num(off.submitted as f64)),
                    ("rejected_on", Json::num(on.rejected as f64)),
                    ("deadline_drops_on", Json::num(on.deadline_drops as f64)),
                ]),
            ),
            (
                "controller",
                Json::obj(vec![
                    ("budget", Json::num(budget)),
                    ("settled_rate", Json::num(pi_rate)),
                    ("tuned_margin", Json::num(pi_margin)),
                ]),
            ),
            (
                "refinement",
                Json::obj(vec![
                    ("submitted", Json::num(refine_n as f64)),
                    ("sim_cost_refine_s", Json::num(cost_on)),
                    ("sim_cost_rerun_s", Json::num(cost_off)),
                    ("ratio", Json::num(refine_ratio)),
                ]),
            ),
        ]),
    )
    .expect("save perf results");
    println!("perf_route done");
    if !(floor_ok && goodput_ok && controller_ok && refine_ok) {
        // make the floors real gates: scripted full-size runs must fail
        // (and the deterministic refinement gate fails smoke runs too)
        std::process::exit(1);
    }
}
