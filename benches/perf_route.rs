//! Bench: §Perf — heterogeneous-precision routing, mixed pool vs the
//! all-8-bit pool at equal replica count (DESIGN.md §10).
//!
//! Closed-loop load over the artifact-free [`SimBackend`] where each
//! replica's batch cost comes from the §3 cycle simulator *at its own
//! precision*: three DyBit-4 replicas cost ~2.6× less per batch than an
//! 8-bit one on the ResNet-like stack, so a 3×(4,4) + 1×(8,8) pool
//! should beat 4×(8,8) by ~(3·2.6 + 1)/4 ≈ 2.2× — the Fig. 6
//! accuracy/speedup trade-off moved to the serving tier.  A second
//! phase drives a seeded low-margin workload through the
//! confidence-escalation router and asserts the escalation accounting.
//! A third phase widens the mixed pool to 16 replicas (12×4b + 4×8b)
//! over the §11 intake: weighted round-robin must still feed every
//! replica, the accounting must stay exact, and the wide pool must beat
//! the 4-replica all-8 baseline.
//!
//! Run: cargo bench --bench perf_route [-- --smoke]
//! `--smoke` shrinks the model/load for CI smoke runs
//! (`ci.sh --bench-smoke`); the 1.8× acceptance floor (mixed vs all-8)
//! only applies to the full-size run.

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;
use std::time::{Duration, Instant};

use dybit::coordinator::{
    load_test, Escalate, Fastest, Policy, PoolConfig, ReplicaPrecision, Server, SimBackend,
    SimBackendCfg,
};
use dybit::models::synthetic_resnet;
use dybit::util::argparse::Args;
use dybit::util::json::Json;
use dybit::util::stats::Table;

const FLOOR: f64 = 1.8;

struct Run {
    wall_s: f64,
    rps: f64,
    p50_ms: f64,
    warm_class: usize,
}

/// One closed-loop trial of a pool with the given per-replica precision
/// mix under the Fastest router; panics on any accounting violation.
fn trial(cfg: &SimBackendCfg, mix: &[ReplicaPrecision], clients: usize,
         per_client: usize) -> Run {
    let pool = PoolConfig {
        policy: Policy {
            max_batch: cfg.batch,
            max_wait: Duration::from_micros(300),
        },
        queue_cap: 1024,
        replicas: mix.len(),
        precisions: mix.to_vec(),
        router: Arc::new(Fastest::new()),
        work_stealing: true,
    };
    let server = Server::start_pool(pool, SimBackend::mixed_factory(cfg.clone(), mix.to_vec()))
        .expect("pool start");
    assert_eq!(server.replicas(), mix.len());
    // fixed warm-up payload: also the cross-pool determinism probe
    let warm: Vec<f32> = (0..cfg.img_elems).map(|i| (i as f32).sin()).collect();
    let warm_class = server.infer(warm).expect("warm inference");

    let t0 = Instant::now();
    load_test(&server, clients, per_client, cfg.img_elems).expect("load test");
    let wall_s = t0.elapsed().as_secs_f64();
    let snap = server.shutdown().expect("clean shutdown");

    let submitted = (clients * per_client + 1) as u64; // +1 warm-up
    assert_eq!(
        snap.requests + snap.failed_requests + snap.rejected,
        submitted,
        "every submitted request must be accounted for"
    );
    assert_eq!(snap.errors, 0, "sim backend must not fail batches");
    assert_eq!(snap.escalations, 0, "the Fastest router never escalates");
    assert_eq!(snap.queue_depth, 0, "queues must drain");
    let routed: u64 = snap.per_replica.iter().map(|r| r.routed).sum();
    assert_eq!(routed, submitted, "every request is routed exactly once");
    assert!(
        snap.per_replica.iter().all(|r| r.routed > 0),
        "weighted round-robin must feed every replica: {:?}",
        snap.per_replica
    );
    Run {
        wall_s,
        rps: (clients * per_client) as f64 / wall_s,
        p50_ms: snap.lat_p50_ms,
        warm_class,
    }
}

/// Escalation phase: a mixed pool under the confidence-escalation
/// router.  `scale` controls the payload norm and thereby the argmax
/// margin — near-zero payloads have near-zero margins and must all
/// escalate; large payloads almost never do.  Stealing is off so the
/// accurate tier cannot absorb primary traffic before it escalates.
fn escalation_rate(cfg: &SimBackendCfg, mix: &[ReplicaPrecision], n: usize,
                   scale: f32) -> (f64, u64) {
    let pool = PoolConfig {
        policy: Policy {
            max_batch: cfg.batch,
            max_wait: Duration::from_micros(200),
        },
        queue_cap: 1024,
        replicas: mix.len(),
        precisions: mix.to_vec(),
        router: Arc::new(Escalate::new(0.05)),
        work_stealing: false,
    };
    let server = Server::start_pool(pool, SimBackend::mixed_factory(cfg.clone(), mix.to_vec()))
        .expect("pool start");
    let mut rng = dybit::util::rng::Rng::new(4242);
    let rxs: Vec<_> = (0..n)
        .map(|_| {
            let img: Vec<f32> =
                rng.normal_vec(cfg.img_elems).iter().map(|v| v * scale).collect();
            server.submit(img).expect("submit")
        })
        .collect();
    for rx in &rxs {
        rx.recv_timeout(Duration::from_secs(60))
            .expect("reply")
            .expect("class");
    }
    let snap = server.shutdown().expect("clean shutdown");
    assert_eq!(
        snap.requests + snap.failed_requests + snap.rejected,
        n as u64,
        "escalated requests must still be answered exactly once"
    );
    let initiated: u64 = snap.per_replica.iter().map(|r| r.escalations).sum();
    assert_eq!(initiated, snap.escalations, "per-replica escalations must sum to global");
    (snap.escalations as f64 / n as f64, snap.escalations)
}

fn main() {
    let args = Args::from_env();
    let smoke = args.has("smoke");

    // simulator-costed model: resnet-like stack; time_scale pins the
    // *8-bit* batch cost to a target wall time, and every other tier
    // scales by its own simulated cycle count — the per-precision cost
    // ratio is the simulator's, not hand-picked.  16 ms (vs perf_serve's
    // 2 ms) amortizes the per-batch scheduling overhead that compresses
    // the tier ratio on small CI boxes: a C/pthreads transliteration of
    // the pool dynamics on a loaded 2-core box measured 1.3–1.85×
    // single-run at 8 ms batches but 1.7–2.1× at 16 ms (ideal 2.23×);
    // the best-of-`trials` pairing below is what gates — closed-loop
    // noise only lowers rps below pool capacity, never above
    let (depth, batch, target_batch8_s) =
        if smoke { (4, 4, 0.0005) } else { (8, 8, 0.016) };
    let mut cfg = SimBackendCfg {
        layers: synthetic_resnet(depth),
        batch,
        img_elems: 128,
        classes: 10,
        wbits: 8,
        abits: 8,
        seed: 13,
        time_scale: 0.0,
        fail_on: None,
    };
    let probe8 = SimBackend::new(cfg.clone()).expect("8-bit probe");
    cfg.time_scale = target_batch8_s / probe8.sim_latency_s();
    let probe4 = SimBackend::new(SimBackendCfg { wbits: 4, abits: 4, ..cfg.clone() })
        .expect("4-bit probe");
    let tier_ratio = probe8.sim_latency_s() / probe4.sim_latency_s();

    let mixed: Vec<ReplicaPrecision> = vec![
        ReplicaPrecision::uniform(4),
        ReplicaPrecision::uniform(4),
        ReplicaPrecision::uniform(4),
        ReplicaPrecision::uniform(8),
    ];
    let all8: Vec<ReplicaPrecision> = vec![ReplicaPrecision::uniform(8); 4];

    // enough closed-loop clients to saturate BOTH pools: the mixed
    // pool's capacity is ~2.2× the all-8 one's, and an under-offered
    // comparison is client-latency-bound and shows no routing effect
    let (clients, per_client, trials) = if smoke { (8, 6, 1) } else { (64, 40, 3) };

    let mut t = Table::new(&["pool", "wall", "req/s", "p50 batch lat", "speedup vs all-8"]);
    let mut rows: Vec<Json> = Vec::new();
    let mut best: Vec<(&str, Run)> = Vec::new();
    for (name, mix) in [("all-8bit", &all8), ("mixed 3x4b+1x8b", &mixed)] {
        // best-of-N absorbs scheduler noise on shared CI boxes
        let mut runs: Vec<Run> = (0..trials)
            .map(|_| trial(&cfg, mix, clients, per_client))
            .collect();
        runs.sort_by(|a, b| a.rps.total_cmp(&b.rps));
        best.push((name, runs.pop().expect("at least one trial")));
    }
    // the scorer is seeded per config, not per precision tier: both
    // pools must answer the warm-up payload identically
    assert_eq!(
        best[0].1.warm_class, best[1].1.warm_class,
        "heterogeneous pool diverged on the same payload"
    );

    let rps8 = best[0].1.rps;
    let mut speedup = 0.0;
    for (name, run) in &best {
        let sp = run.rps / rps8;
        if *name != "all-8bit" {
            speedup = sp;
        }
        t.row(vec![
            name.to_string(),
            format!("{:.3}s", run.wall_s),
            format!("{:.0}", run.rps),
            format!("{:.2}ms", run.p50_ms),
            format!("{sp:.2}x"),
        ]);
        rows.push(Json::obj(vec![
            ("pool", Json::str(name)),
            ("clients", Json::num(clients as f64)),
            ("per_client", Json::num(per_client as f64)),
            ("wall_s", Json::num(run.wall_s)),
            ("rps", Json::num(run.rps)),
            ("p50_ms", Json::num(run.p50_ms)),
            ("speedup_vs_all8", Json::num(sp)),
        ]));
    }
    t.print();

    // ---- wide mixed pool over the §11 intake: 16 replicas, 12 fast +
    // 4 accurate.  trial() asserts WRR feeds every replica and the
    // accounting stays exact at this width; throughput must clearly
    // beat the 4-replica all-8 baseline
    let wide: Vec<ReplicaPrecision> = (0..16)
        .map(|i| ReplicaPrecision::uniform(if i % 4 == 3 { 8 } else { 4 }))
        .collect();
    let (w_clients, w_per_client) = if smoke { (12, 4) } else { (128, 16) };
    let mut wide_runs: Vec<Run> = (0..trials)
        .map(|_| trial(&cfg, &wide, w_clients, w_per_client))
        .collect();
    wide_runs.sort_by(|a, b| a.rps.total_cmp(&b.rps));
    let wide_run = wide_runs.pop().expect("at least one trial");
    let wide_sp = wide_run.rps / rps8;
    println!(
        "\nwide mixed pool 12x4b+4x8b (16 replicas): {:.0} req/s, {wide_sp:.2}x \
         vs all-8bit at 4 replicas",
        wide_run.rps
    );
    assert_eq!(wide_run.warm_class, best[0].1.warm_class, "wide pool diverged");
    assert!(
        smoke || wide_run.rps > rps8,
        "a 16-replica mixed pool must beat the 4-replica all-8 pool \
         ({:.0} vs {rps8:.0} req/s)",
        wide_run.rps
    );
    rows.push(Json::obj(vec![
        ("pool", Json::str("mixed 12x4b+4x8b (16r)")),
        ("clients", Json::num(w_clients as f64)),
        ("per_client", Json::num(w_per_client as f64)),
        ("wall_s", Json::num(wide_run.wall_s)),
        ("rps", Json::num(wide_run.rps)),
        ("p50_ms", Json::num(wide_run.p50_ms)),
        ("speedup_vs_all8", Json::num(wide_sp)),
    ]));

    // escalation accounting under the confidence router: near-zero
    // payloads have near-zero argmax margins — every one served by a
    // fast replica must re-run on the accurate tier; large payloads
    // have O(1)-margin logits and must (almost) never escalate
    let esc_n = if smoke { 40 } else { 200 };
    let (low_rate, low_escalations) = escalation_rate(&cfg, &mixed, esc_n, 1e-6);
    let (high_rate, _) = escalation_rate(&cfg, &mixed, esc_n, 100.0);
    println!(
        "\nescalation rate (margin 0.05): low-margin workload {:.0}% ({low_escalations} \
         re-runs / {esc_n}), high-margin workload {:.1}%",
        low_rate * 100.0,
        high_rate * 100.0
    );
    assert!(
        (low_rate - 1.0).abs() < 1e-12,
        "every low-margin request lands on a fast replica (escalate routes primary \
         traffic there, stealing off) and must escalate; rate {low_rate}"
    );
    assert!(
        high_rate < 0.05,
        "high-margin workload must (almost) never escalate; rate {high_rate}"
    );

    let floor_ok = smoke || speedup >= FLOOR;
    println!(
        "\nheterogeneous routing over SimBackend (8-bit batch cost {:.1}ms, \
         simulated 8b/4b tier ratio {tier_ratio:.2}x); acceptance floor \
         {FLOOR:.2}x mixed vs all-8 at 4 replicas: {}",
        target_batch8_s * 1e3,
        if smoke {
            "n/a (smoke load)".to_string()
        } else {
            format!("{} ({speedup:.2}x)", if floor_ok { "PASS" } else { "FAIL" })
        }
    );
    common::save_results(
        "perf_route",
        Json::obj(vec![
            ("smoke", Json::Bool(smoke)),
            ("floor", Json::num(FLOOR)),
            // null on smoke runs: the floor was never evaluated, and a
            // persisted `true` would read as a gate that passed
            ("floor_pass", if smoke { Json::Null } else { Json::Bool(floor_ok) }),
            ("target_batch8_s", Json::num(target_batch8_s)),
            ("tier_ratio", Json::num(tier_ratio)),
            ("rows", Json::Arr(rows)),
            (
                "escalation",
                Json::obj(vec![
                    ("margin", Json::num(0.05)),
                    ("submitted", Json::num(esc_n as f64)),
                    ("low_margin_rate", Json::num(low_rate)),
                    ("high_margin_rate", Json::num(high_rate)),
                ]),
            ),
        ]),
    )
    .expect("save perf results");
    println!("perf_route done");
    if !floor_ok {
        // make the floor a real gate: scripted full-size runs must fail
        std::process::exit(1);
    }
}
