//! Bench: §Perf hot paths across all three layers.
//!
//! L3: quantizer, simulator queries, Algorithm-1 search, JSON, batcher;
//! L2/L1 (through PJRT): fwd latency (ref vs pallas artifact), train-step
//! latency, serving throughput under closed-loop load.
//!
//! Run: cargo bench --bench perf_hotpath

#[path = "common/mod.rs"]
mod common;

use std::time::Duration;

use dybit::coordinator::{load_test, Policy, Server, ServerConfig};
use dybit::formats::{quantizer, Format, GridLut};
use dybit::qat::{QuantConfig, Session};
use dybit::runtime::Executor;
use dybit::search::{run_search, Strategy};
use dybit::sim::{HwConfig, Prec, Simulator};
use dybit::util::json::Json;
use dybit::util::rng::Rng;
use dybit::util::stats::{fmt_time, Bench, Table};

fn main() {
    let mut rng = Rng::new(1);
    let bench = Bench::new(3, 12);
    let mut t = Table::new(&["path", "layer", "time/iter", "rate"]);

    // ---- L3: quantizer — per-element baseline vs batched GridLut --------
    let x: Vec<f32> = rng.normal_vec(1 << 20);
    let grid = Format::DyBit.grid(4);
    let mut out = vec![0.0f32; x.len()];
    let s_base = bench.run(|| quantizer::quantize_to_grid(&x, &grid, 0.5, &mut out));
    t.row(vec!["quantize 1M (dybit4, per-element baseline)".into(), "L3".into(),
               fmt_time(s_base.mean),
               format!("{:.0} Melem/s", x.len() as f64 / s_base.mean / 1e6)]);

    let lut = GridLut::from_format(Format::DyBit, 4, 0.5);
    let s_lut = bench.run(|| lut.quantize_batch(&x, &mut out));
    t.row(vec!["quantize 1M (dybit4, GridLut batched)".into(), "L3".into(),
               fmt_time(s_lut.mean),
               format!("{:.0} Melem/s", x.len() as f64 / s_lut.mean / 1e6)]);

    let mut codes = vec![0u8; x.len()];
    let s_enc = bench.run(|| lut.encode_batch(&x, &mut codes));
    t.row(vec!["encode_batch 1M -> u8 codes".into(), "L3".into(), fmt_time(s_enc.mean),
               format!("{:.0} Melem/s", x.len() as f64 / s_enc.mean / 1e6)]);
    let s_dec = bench.run(|| lut.dequantize_batch(&codes, &mut out));
    t.row(vec!["dequantize_batch 1M codes".into(), "L3".into(), fmt_time(s_dec.mean),
               format!("{:.0} Melem/s", x.len() as f64 / s_dec.mean / 1e6)]);

    let quantize_speedup = s_base.mean / s_lut.mean;

    let s_cal_base = bench.run(|| {
        std::hint::black_box(quantizer::calibrate_scale(&x[..32768], &grid));
    });
    t.row(vec!["calibrate_scale 32k (baseline ladder)".into(), "L3".into(),
               fmt_time(s_cal_base.mean), "-".into()]);
    let s_cal_lut = bench.run(|| {
        std::hint::black_box(quantizer::calibrate_scale_lut(&x[..32768], Format::DyBit, 4));
    });
    t.row(vec!["calibrate_scale 32k (CalibView ladder, §8)".into(), "L3".into(),
               fmt_time(s_cal_lut.mean), "-".into()]);
    let calibrate_speedup = s_cal_base.mean / s_cal_lut.mean;

    // ---- L3: simulator -------------------------------------------------
    let layers = dybit::models::synthetic_resnet(16);
    let nl = layers.len();
    let s = bench.run(|| {
        let mut sim = Simulator::new(HwConfig::zcu102(), layers.clone(), 1);
        for i in 0..nl {
            for pw in Prec::ALL {
                for pa in Prec::ALL {
                    std::hint::black_box(sim.layer_cycles(i, pw, pa));
                }
            }
        }
    });
    t.row(vec![format!("simulator full sweep ({nl} layers x 9 modes)"), "L3".into(),
               fmt_time(s.mean), format!("{:.0} queries/s", (nl * 9) as f64 / s.mean)]);

    // ---- L3: Algorithm 1 end to end -------------------------------------
    let weights: Vec<Vec<f32>> = (0..nl).map(|_| rng.normal_vec(4096)).collect();
    let acts: Vec<Vec<f32>> = (0..nl).map(|_| rng.normal_vec(2048)).collect();
    let s = bench.run(|| {
        let sim = Simulator::new(HwConfig::zcu102(), layers.clone(), 1);
        std::hint::black_box(run_search(&sim, &weights, &acts, Format::DyBit,
                                        Strategy::SpeedupConstrained { alpha: 4.0 }, 3));
    });
    t.row(vec!["Algorithm 1 search (alpha=4)".into(), "L3".into(), fmt_time(s.mean), "-".into()]);

    // ---- L3: manifest JSON parse ----------------------------------------
    if let Ok(text) = std::fs::read_to_string("artifacts/manifest.json") {
        let s = bench.run(|| {
            std::hint::black_box(dybit::util::json::parse(&text).unwrap());
        });
        t.row(vec![format!("manifest.json parse ({} KB)", text.len() / 1024), "L3".into(),
                   fmt_time(s.mean), format!("{:.0} MB/s", text.len() as f64 / s.mean / 1e6)]);
    }

    // ---- L2/L1 via PJRT --------------------------------------------------
    if let Ok(manifest) = common::load_manifest() {
        let mut exec = Executor::new(&manifest.dir).expect("pjrt");
        let mut session = Session::new(&manifest, "mlp").expect("mlp");
        let nl = session.model.n_quant_layers;
        let mut q = QuantConfig::uniform(nl, Format::DyBit, 4, 8);
        session.calibrate(&mut exec, &mut q, 3).expect("calib");
        let (x, _) = dybit::qat::materialize_batch(&mut exec, &manifest.dir, 0).unwrap();

        let fwd_bench = Bench::new(3, 15);
        let s = fwd_bench.run(|| {
            std::hint::black_box(session.forward(&mut exec, &q, &x, false).unwrap());
        });
        t.row(vec!["mlp fwd batch32 (ref fake-quant)".into(), "L2".into(), fmt_time(s.mean),
                   format!("{:.0} img/s", 32.0 / s.mean)]);
        let s = fwd_bench.run(|| {
            std::hint::black_box(session.forward(&mut exec, &q, &x, true).unwrap());
        });
        t.row(vec!["mlp fwd batch32 (pallas kernel)".into(), "L1".into(), fmt_time(s.mean),
                   format!("{:.0} img/s", 32.0 / s.mean)]);
        let s = Bench::new(2, 8).run(|| {
            session.train_step(&mut exec, &q, 17, 0.01).unwrap();
        });
        t.row(vec!["mlp train step batch32".into(), "L2".into(), fmt_time(s.mean),
                   format!("{:.0} img/s", 32.0 / s.mean)]);

        // serving throughput (closed loop, 4 clients)
        let cfg = ServerConfig {
            model: "mlp".into(),
            qcfg: q.clone(),
            policy: Policy { max_batch: 32, max_wait: Duration::from_millis(2) },
            queue_cap: 256,
            pallas: false,
            replicas: 1,
        };
        let server = Server::start(&manifest, cfg).expect("server");
        let img_elems: usize = manifest.models["mlp"].input.iter().skip(1).product();
        let _ = server.infer(vec![0.0; img_elems]); // warm
        let t0 = std::time::Instant::now();
        let (clients, per) = (4, 128);
        load_test(&server, clients, per, img_elems).unwrap();
        let wall = t0.elapsed().as_secs_f64();
        let snap = server.shutdown().expect("clean shutdown");
        t.row(vec!["serve mlp closed-loop (4 clients)".into(), "L3+L2".into(),
                   format!("p50 {:.1}ms", snap.lat_p50_ms),
                   format!("{:.0} req/s (batch avg {:.1})",
                           (clients * per) as f64 / wall, snap.mean_batch)]);
    } else {
        eprintln!("artifacts missing: skipping PJRT rows");
    }

    t.print();
    println!(
        "\nhot-path speedup (GridLut batched vs per-element baseline): \
         quantize {quantize_speedup:.2}x, calibrate {calibrate_speedup:.2}x \
         (acceptance floor: 2.00x on quantize)"
    );
    common::save_results(
        "perf_hotpath",
        Json::obj(vec![
            ("quantize_baseline_s", Json::num(s_base.mean)),
            ("quantize_gridlut_s", Json::num(s_lut.mean)),
            ("encode_batch_s", Json::num(s_enc.mean)),
            ("dequantize_batch_s", Json::num(s_dec.mean)),
            ("calibrate_baseline_s", Json::num(s_cal_base.mean)),
            ("calibrate_gridlut_s", Json::num(s_cal_lut.mean)),
            ("quantize_speedup", Json::num(quantize_speedup)),
            ("calibrate_speedup", Json::num(calibrate_speedup)),
        ]),
    )
    .expect("save perf results");
    println!("perf_hotpath done");
}
