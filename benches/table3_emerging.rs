//! Bench: Table III — Top-1 accuracy with QAT on emerging models.
//!
//! Paper: RegNet-3.2GF / ConvNext-Tiny / ViT-Base.
//! Here:  microregnet / microconvnext / tinyvit (DESIGN.md §6).
//!
//! Expected shape: INT(4/4) collapses on the ConvNext stand-in (the paper
//! reports 0.1%); DyBit(4/4) recovers most of FP32; DyBit(8/8) ≈ FP32.
//!
//! Run: cargo bench --bench table3_emerging [-- --models a,b --full]

#[path = "common/mod.rs"]
mod common;

use common::{ensure_pretrained, load_manifest, pct, qat_eval, Protocol};
use dybit::formats::Format;
use dybit::runtime::Executor;
use dybit::util::argparse::Args;
use dybit::util::json::Json;
use dybit::util::stats::Table;

fn main() {
    let args = Args::from_env();
    let p = Protocol::from_args(&args);
    let models = args.get_list("models", "microregnet,microconvnext,tinyvit");
    let configs: Vec<(&str, Format, u32, u32)> = vec![
        ("INT(4/4)", Format::Int, 4, 4),
        ("Flint(4/4)", Format::Flint, 4, 4),
        ("DyBit(4/4)", Format::DyBit, 4, 4),
        ("DyBit(8/8)", Format::DyBit, 8, 8),
    ];

    let manifest = load_manifest().expect("run `make artifacts` first");
    let mut exec = Executor::new(&manifest.dir).expect("pjrt");

    println!("=== Table III: emerging models, Top-1 with QAT ({} pretrain / {} QAT steps) ===",
             p.pretrain_steps, p.qat_steps);
    let mut cols: Vec<Vec<(String, f32)>> = Vec::new();
    for model in &models {
        let (mut session, fp_acc) =
            ensure_pretrained(&manifest, &mut exec, model, p).expect("pretrain");
        let snap = session.snapshot();
        let mut col = vec![("FP32".to_string(), fp_acc)];
        for (label, fmt, w, a) in &configs {
            let acc = qat_eval(&mut session, &mut exec, &snap, *fmt, *w, *a, p, 20_000)
                .expect("qat");
            eprintln!("[{model}] {label}: {}", pct(acc));
            col.push((label.to_string(), acc));
        }
        cols.push(col);
    }

    let mut table = Table::new(&{
        let mut h = vec!["Methods (W/A)"];
        h.extend(models.iter().map(|s| s.as_str()));
        h
    });
    let mut results = Vec::new();
    for ri in 0..cols[0].len() {
        let mut row = vec![cols[0][ri].0.clone()];
        for (mi, col) in cols.iter().enumerate() {
            row.push(pct(col[ri].1));
            results.push(Json::obj(vec![
                ("model", Json::str(&models[mi])),
                ("config", Json::str(&col[ri].0)),
                ("top1", Json::num(col[ri].1 as f64)),
            ]));
        }
        table.row(row);
    }
    table.print();

    common::save_results("table3", Json::Arr(results)).expect("save");
    println!("table3_emerging done (protocol: {p:?})");
}
