//! Bench: Table II — Top-1 accuracy with QAT, main models.
//!
//! Paper: MobileNetV2 / ResNet18 / ResNet50 on ImageNet.
//! Here:  micromobilenet / miniresnet18 / miniresnet50 on synthshapes
//! (DESIGN.md §6 substitution), identical QAT schedule for every format.
//!
//! Expected shape (not absolute numbers): INT(4/4) collapses on the
//! mobilenet stand-in but DyBit(4/4) stays near FP32; DyBit(8/8) ≈ FP32;
//! DyBit(4/4) ≥ Flint(4/4) ≥ INT(4/4).
//!
//! Run: cargo bench --bench table2_accuracy [-- --models a,b --qat N --full]

#[path = "common/mod.rs"]
mod common;

use common::{ensure_pretrained, load_manifest, pct, qat_eval, Protocol};
use dybit::formats::Format;
use dybit::runtime::Executor;
use dybit::util::argparse::Args;
use dybit::util::json::Json;
use dybit::util::stats::Table;

fn main() {
    let args = Args::from_env();
    let p = Protocol::from_args(&args);
    let models = args.get_list("models", "micromobilenet,miniresnet18,miniresnet50");
    // (label, format, wbits, abits) — the paper's Table II rows
    let configs: Vec<(&str, Format, u32, u32)> = vec![
        ("INT(4/4)", Format::Int, 4, 4),
        ("INT(8/8)", Format::Int, 8, 8),
        ("AdaFloat(4/4)", Format::AdaptivFloat, 4, 4),
        ("Flint(4/4)", Format::Flint, 4, 4),
        ("Posit(8/8)", Format::Posit, 8, 8),
        ("DyBit(4/4)", Format::DyBit, 4, 4),
        ("DyBit(4/8)", Format::DyBit, 4, 8),
        ("DyBit(8/8)", Format::DyBit, 8, 8),
    ];

    let manifest = load_manifest().expect("run `make artifacts` first");
    let mut exec = Executor::new(&manifest.dir).expect("pjrt");

    println!("=== Table II: Top-1 accuracy with QAT (synthshapes; {} pretrain / {} QAT steps) ===",
             p.pretrain_steps, p.qat_steps);
    let mut table = Table::new(&{
        let mut h = vec!["Methods (W/A)"];
        h.extend(models.iter().map(|s| s.as_str()));
        h
    });

    let mut cols: Vec<Vec<(String, f32)>> = Vec::new();
    for model in &models {
        let t0 = std::time::Instant::now();
        let (mut session, fp_acc) =
            ensure_pretrained(&manifest, &mut exec, model, p).expect("pretrain");
        let snap = session.snapshot();
        let mut col = vec![("FP32".to_string(), fp_acc)];
        for (label, fmt, w, a) in &configs {
            let acc = qat_eval(&mut session, &mut exec, &snap, *fmt, *w, *a, p, 10_000)
                .expect("qat");
            eprintln!("[{model}] {label}: {}", pct(acc));
            col.push((label.to_string(), acc));
        }
        eprintln!("[{model}] done in {:.0}s", t0.elapsed().as_secs_f64());
        cols.push(col);
    }

    let mut results = Vec::new();
    for (ri, (label, _)) in cols[0].iter().enumerate() {
        let mut row = vec![label.clone()];
        for (mi, col) in cols.iter().enumerate() {
            row.push(pct(col[ri].1));
            results.push(Json::obj(vec![
                ("model", Json::str(&models[mi])),
                ("config", Json::str(label)),
                ("top1", Json::num(col[ri].1 as f64)),
            ]));
        }
        table.row(row);
    }
    table.print();

    // the paper's headline check: DyBit(4/4) vs best non-DyBit 4-bit
    for (mi, model) in models.iter().enumerate() {
        let get = |l: &str| cols[mi].iter().find(|(k, _)| k == l).map(|(_, v)| *v);
        if let (Some(dy), Some(int4)) = (get("DyBit(4/4)"), get("INT(4/4)")) {
            println!("[{model}] DyBit(4/4) - INT(4/4) = {:+.2}%", (dy - int4) * 100.0);
        }
        if let (Some(dy), Some(fl)) = (get("DyBit(4/4)"), get("Flint(4/4)")) {
            println!("[{model}] DyBit(4/4) - Flint(4/4) = {:+.2}%", (dy - fl) * 100.0);
        }
    }

    common::save_results("table2", Json::Arr(results)).expect("save");
    println!("table2_accuracy done (protocol: {p:?})");
}
