//! Bench: §Perf — Algorithm-1 search, old vs new (DESIGN.md §7).
//!
//! Old: the pre-refactor oracle-driven walk (`search::reference` over
//! `EngineMetrics`) — two full-model re-walks after every degrade, metric
//! oracles invoked inside sort comparators, per-query HashMap memoization.
//! New: `run_search` — parallel dense cost-table fill + incremental O(1)
//! accounting.  Both sides are also checked to return identical results
//! (assignment, iterations, satisfied) before timing.
//!
//! Run: cargo bench --bench perf_search [-- --smoke]
//! `--smoke` shrinks the layer stacks + iteration counts for CI smoke
//! runs (`ci.sh --bench-smoke`); the 5× acceptance floor only applies to
//! the full-size resnet-50-like stack.

#[path = "common/mod.rs"]
mod common;

use std::hint::black_box;

use dybit::formats::Format;
use dybit::models::{synthetic_mobilenet, synthetic_resnet};
use dybit::search::{reference, run_search, EngineMetrics, SearchResult, Strategy};
use dybit::sim::{HwConfig, Simulator};
use dybit::util::argparse::Args;
use dybit::util::json::Json;
use dybit::util::rng::Rng;
use dybit::util::stats::{fmt_time, Bench, Table};

const FLOOR: f64 = 5.0;

fn strat_name(s: Strategy) -> &'static str {
    match s {
        Strategy::SpeedupConstrained { .. } => "speedup(alpha=4)",
        Strategy::RmseConstrained { .. } => "rmse(beta=4)",
    }
}

fn same_outcome(a: &SearchResult, b: &SearchResult) -> bool {
    a.assignment == b.assignment && a.iterations == b.iterations && a.satisfied == b.satisfied
}

fn main() {
    let args = Args::from_env();
    let smoke = args.has("smoke");
    let (depth, blocks) = if smoke { (6, 2) } else { (50, 16) };
    let bench = if smoke { Bench::new(1, 3) } else { Bench::new(2, 10) };

    let mut t = Table::new(&[
        "model", "layers", "strategy", "old (oracle walk)", "new (cost table)", "speedup",
    ]);
    let mut rows: Vec<Json> = Vec::new();
    let mut floor_ok = true;
    let mut rng = Rng::new(42);

    let stacks = [
        (format!("synthetic_resnet({depth})"), synthetic_resnet(depth), true),
        (format!("synthetic_mobilenet({blocks})"), synthetic_mobilenet(blocks), false),
    ];
    for (name, layers, gated) in &stacks {
        let nl = layers.len();
        let weights: Vec<Vec<f32>> = (0..nl).map(|_| rng.normal_vec(4096)).collect();
        let acts: Vec<Vec<f32>> = (0..nl)
            .map(|_| rng.normal_vec(2048).iter().map(|x| x.abs()).collect())
            .collect();
        for strategy in [
            Strategy::SpeedupConstrained { alpha: 4.0 },
            Strategy::RmseConstrained { beta: 4.0 },
        ] {
            // bit-identical outcomes first (the property tests' claim,
            // re-checked here on the bench inputs), then wall time
            let r_old = {
                let mut sim = Simulator::new(HwConfig::zcu102(), layers.clone(), 1);
                let mut m = EngineMetrics::new(&mut sim, &weights, &acts, Format::DyBit);
                reference::search(&mut m, strategy, 3)
            };
            let r_new = {
                let sim = Simulator::new(HwConfig::zcu102(), layers.clone(), 1);
                run_search(&sim, &weights, &acts, Format::DyBit, strategy, 3)
            };
            assert!(
                same_outcome(&r_old, &r_new),
                "table-driven search diverged from reference on {name} {strategy:?}"
            );

            // each timed iteration is a cold deployment decision: fresh
            // simulator + fresh metric caches on both sides
            let s_old = bench.run(|| {
                let mut sim = Simulator::new(HwConfig::zcu102(), layers.clone(), 1);
                let mut m = EngineMetrics::new(&mut sim, &weights, &acts, Format::DyBit);
                black_box(reference::search(&mut m, strategy, 3));
            });
            let s_new = bench.run(|| {
                let sim = Simulator::new(HwConfig::zcu102(), layers.clone(), 1);
                black_box(run_search(&sim, &weights, &acts, Format::DyBit, strategy, 3));
            });
            let sp = s_old.mean / s_new.mean;
            if *gated && !smoke && sp < FLOOR {
                floor_ok = false;
            }
            t.row(vec![
                name.clone(),
                format!("{nl}"),
                strat_name(strategy).into(),
                fmt_time(s_old.mean),
                fmt_time(s_new.mean),
                format!("{sp:.2}x"),
            ]);
            rows.push(Json::obj(vec![
                ("model", Json::str(name)),
                ("layers", Json::num(nl as f64)),
                ("strategy", Json::str(strat_name(strategy))),
                ("old_s", Json::num(s_old.mean)),
                ("new_s", Json::num(s_new.mean)),
                ("speedup", Json::num(sp)),
            ]));
        }
    }

    t.print();
    println!(
        "\nAlgorithm-1 search speedup (precomputed cost table + incremental \
         accounting vs per-degrade oracle walk); acceptance floor {FLOOR:.2}x \
         on the resnet-50-like stack, both strategies: {}",
        if smoke {
            "n/a (smoke stacks)"
        } else if floor_ok {
            "PASS"
        } else {
            "FAIL"
        }
    );
    common::save_results(
        "perf_search",
        Json::obj(vec![
            ("smoke", Json::Bool(smoke)),
            ("floor", Json::num(FLOOR)),
            ("floor_pass", Json::Bool(floor_ok)),
            ("rows", Json::Arr(rows)),
        ]),
    )
    .expect("save perf results");
    println!("perf_search done");
    if !smoke && !floor_ok {
        // make the floor a real gate: scripted full-size runs must fail
        std::process::exit(1);
    }
}
