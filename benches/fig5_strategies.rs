//! Bench: Fig. 5 — speedup & accuracy under both search strategies.
//!
//! For each model the paper sweeps the constraint and reports the achieved
//! speedup (cycle-accurate simulator, ZCU102) and post-QAT accuracy:
//! row 1 = speedup-constrained (α), row 2 = RMSE-constrained (β).
//!
//! Expected shape: speedup grows with α up to ~8x on the ResNet50 stand-in
//! while accuracy decays; the β strategy keeps accuracy near FP32 at a
//! decent speedup; the MobileNet stand-in saturates early (depthwise).
//!
//! Run: cargo bench --bench fig5_strategies [-- --alphas 2,4,6 --betas 1.5,2,4]

#[path = "common/mod.rs"]
mod common;

use common::{ensure_pretrained, load_manifest, pct, Protocol};
use dybit::formats::Format;
use dybit::qat::QuantConfig;
use dybit::runtime::Executor;
use dybit::search::{run_search, Strategy};
use dybit::sim::{HwConfig, Simulator};
use dybit::util::argparse::Args;
use dybit::util::json::Json;
use dybit::util::stats::Table;

fn main() {
    let args = Args::from_env();
    let p = Protocol::from_args(&args);
    let models = args.get_list("models", "micromobilenet,miniresnet18,miniresnet50");
    let defaults = if args.has("full") { ("2,3,4,6,8", "1.25,1.5,2,4") } else { ("2,4,8", "1.5,4") };
    let alphas: Vec<f64> = args.get_list("alphas", defaults.0)
        .iter().map(|s| s.parse().unwrap()).collect();
    let betas: Vec<f64> = args.get_list("betas", defaults.1)
        .iter().map(|s| s.parse().unwrap()).collect();
    let qat_steps = p.qat_steps / 2; // many points; shorter fine-tune

    let manifest = load_manifest().expect("run `make artifacts` first");
    let mut exec = Executor::new(&manifest.dir).expect("pjrt");
    let mut results = Vec::new();

    for model in &models {
        let (mut session, fp_acc) =
            ensure_pretrained(&manifest, &mut exec, model, p).expect("pretrain");
        let snap = session.snapshot();
        let weights = session.layer_weights();
        let acts = session.layer_acts(&mut exec, 31).expect("acts");
        let layers = session.model.layers.clone();

        println!("\n=== Fig. 5 [{model}] (FP32 top-1 {}) ===", pct(fp_acc));
        let mut table = Table::new(&["strategy", "constraint", "speedup", "rmse-ratio", "top-1", "drop%"]);

        let mut points: Vec<(Strategy, String, f64)> = alphas
            .iter()
            .map(|&a| (Strategy::SpeedupConstrained { alpha: a }, "alpha".to_string(), a))
            .collect();
        points.extend(betas.iter().map(|&b| {
            (Strategy::RmseConstrained { beta: b }, "beta".to_string(), b)
        }));

        for (strategy, kind, val) in points {
            let sim = Simulator::new(HwConfig::zcu102(), layers.clone(), 1);
            let r = run_search(&sim, &weights, &acts, Format::DyBit, strategy, 3);
            // QAT at the found assignment, then evaluate
            session.restore(&snap);
            let mut q = QuantConfig::from_assignment(Format::DyBit, &r.assignment);
            session.calibrate(&mut exec, &mut q, 909).expect("calibrate");
            session
                .train(&mut exec, &q, qat_steps, p.qat_lr, 30_000 + (val * 100.0) as i32)
                .expect("qat");
            let ev = session.evaluate(&mut exec, &q, p.eval_batches).expect("eval");
            let drop = (fp_acc - ev.acc) * 100.0;
            table.row(vec![
                kind.clone(),
                format!("{val}"),
                format!("{:.2}x", r.speedup),
                format!("{:.2}", r.rmse_ratio),
                pct(ev.acc),
                format!("{drop:+.2}"),
            ]);
            results.push(Json::obj(vec![
                ("model", Json::str(model)),
                ("strategy", Json::str(&kind)),
                ("constraint", Json::num(val)),
                ("speedup", Json::num(r.speedup)),
                ("rmse_ratio", Json::num(r.rmse_ratio)),
                ("top1", Json::num(ev.acc as f64)),
                ("fp32_top1", Json::num(fp_acc as f64)),
            ]));
        }
        table.print();
    }

    common::save_results("fig5", Json::Arr(results)).expect("save");
    println!("\nfig5_strategies done (qat steps per point: {qat_steps})");
}
