//! Shared bench scaffolding: FP32 checkpoint reuse, QAT protocol, result
//! persistence.  Every bench binary is harness=false (no criterion in the
//! offline vendor set) and prints paper-shaped tables via util::stats.

#![allow(dead_code)]

use std::path::{Path, PathBuf};

use anyhow::Result;

use dybit::formats::Format;
use dybit::qat::{QuantConfig, Session};
use dybit::runtime::{Executor, Manifest};
use dybit::util::json::Json;

/// Per-model training hyperparameters shared by all accuracy benches
/// (same schedule for every format — the paper's fairness protocol).
#[derive(Clone, Copy, Debug)]
pub struct Protocol {
    pub pretrain_steps: usize,
    pub pretrain_lr: f32,
    pub qat_steps: usize,
    pub qat_lr: f32,
    pub eval_batches: usize,
}

impl Protocol {
    /// Defaults sized for the 1-core CI box; `--full` runs the deeper
    /// schedule (recommended on anything with real cores).
    pub fn from_args(args: &dybit::util::argparse::Args) -> Self {
        let full = args.has("full");
        Protocol {
            pretrain_steps: args.get_usize("pretrain", if full { 500 } else { 250 }),
            pretrain_lr: args.get_f32("lr", 0.03),
            qat_steps: args.get_usize("qat", if full { 80 } else { 25 }),
            qat_lr: args.get_f32("qat-lr", 0.008),
            eval_batches: args.get_usize("eval-batches", if full { 24 } else { 6 }),
        }
    }
}

pub fn load_manifest() -> Result<Manifest> {
    Manifest::load(Path::new("artifacts"))
}

fn ckpt_path(model: &str) -> PathBuf {
    Path::new("artifacts/checkpoints").join(format!("{model}_fp32.bin"))
}

/// FP32-pretrain `model` (or reuse the cached checkpoint) and return the
/// session positioned at the FP32 weights + its eval accuracy.
pub fn ensure_pretrained(manifest: &Manifest, exec: &mut Executor, model: &str,
                         p: Protocol) -> Result<(Session, f32)> {
    let mut session = Session::new(manifest, model)?;
    let nl = session.model.n_quant_layers;
    let fp = QuantConfig::fp32(nl);
    let path = ckpt_path(model);
    if session.load_checkpoint(&path).is_ok() {
        eprintln!("[{model}] reusing FP32 checkpoint {}", path.display());
    } else {
        eprintln!("[{model}] FP32 pre-train {} steps…", p.pretrain_steps);
        let t0 = std::time::Instant::now();
        session.train(exec, &fp, p.pretrain_steps, p.pretrain_lr, 0)?;
        eprintln!("[{model}] trained in {:.0}s", t0.elapsed().as_secs_f64());
        session.save_checkpoint(&path)?;
    }
    let ev = session.evaluate(exec, &fp, p.eval_batches)?;
    Ok((session, ev.acc))
}

/// The paper's QAT protocol: restore FP32 weights, calibrate, fine-tune at
/// (fmt, w/a), evaluate top-1.
pub fn qat_eval(session: &mut Session, exec: &mut Executor,
                fp_snapshot: &[dybit::tensor::Tensor], fmt: Format,
                wbits: u32, abits: u32, p: Protocol, seed0: i32) -> Result<f32> {
    session.restore(fp_snapshot);
    let nl = session.model.n_quant_layers;
    let mut q = QuantConfig::uniform(nl, fmt, wbits, abits);
    session.calibrate(exec, &mut q, 4242)?;
    session.train(exec, &q, p.qat_steps, p.qat_lr, seed0)?;
    let ev = session.evaluate(exec, &q, p.eval_batches)?;
    Ok(ev.acc)
}

/// Persist a bench result table as JSON under artifacts/results/ so later
/// benches (fig6) and EXPERIMENTS.md can consume it.
pub fn save_results(name: &str, value: Json) -> Result<()> {
    let dir = Path::new("artifacts/results");
    std::fs::create_dir_all(dir)?;
    std::fs::write(dir.join(format!("{name}.json")), value.to_string())?;
    Ok(())
}

pub fn load_results(name: &str) -> Option<Json> {
    let text = std::fs::read_to_string(
        Path::new("artifacts/results").join(format!("{name}.json"))).ok()?;
    dybit::util::json::parse(&text).ok()
}

/// Percentage formatting used in all tables (top-1 as the paper prints it).
pub fn pct(x: f32) -> String {
    format!("{:.2}", x * 100.0)
}
