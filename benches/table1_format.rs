//! Bench: Table I + Fig. 1 + Fig. 2 — the number formats themselves.
//!
//! Regenerates (a) the 4-bit DyBit value table, (b) grid shape/density
//! comparisons across formats (Fig. 1's story), and (c) the RMSE of every
//! format on the tensor distributions DNNs exhibit (Fig. 2's adaptive-
//! representation story), plus codec micro-benchmarks.
//!
//! Run: cargo bench --bench table1_format

#[path = "common/mod.rs"]
mod common;

use dybit::formats::dybit as dy;
use dybit::formats::{quantizer, Format};
use dybit::util::json::Json;
use dybit::util::rng::Rng;
use dybit::util::stats::{Bench, Table};

fn main() {
    println!("=== Table I: 4-bit unsigned DyBit value table ===");
    let mut t = Table::new(&["binary", "value", "binary", "value", "binary", "value", "binary", "value"]);
    let g = dy::grid_unsigned(4);
    for r in 0..4 {
        let mut row = Vec::new();
        for c in 0..4 {
            let code = c * 4 + r;
            row.push(format!("{code:04b}"));
            row.push(format!("{}", g[code]));
        }
        t.row(row);
    }
    t.print();

    println!("\n=== Fig. 1: grid structure at 8 bits (positive side) ===");
    let mut t = Table::new(&["format", "values", "min>0", "max", "vals<=1", "vals>max/4"]);
    for fmt in Format::ALL {
        let g = fmt.grid(8);
        let pos: Vec<f64> = g.iter().copied().filter(|&v| v > 0.0).collect();
        let max = pos.last().copied().unwrap_or(0.0);
        t.row(vec![
            fmt.name().into(),
            g.len().to_string(),
            format!("{:.3e}", pos.first().copied().unwrap_or(0.0)),
            format!("{max}"),
            pos.iter().filter(|&&v| v <= 1.0).count().to_string(),
            pos.iter().filter(|&&v| v > max / 4.0).count().to_string(),
        ]);
    }
    t.print();
    println!("(DyBit: dense linear sub-1 region + long exponential tail — the Fig. 1 taper)");

    println!("\n=== Fig. 2: RMSE (Eqn. 2) by tensor distribution, 4-bit ===");
    let mut rng = Rng::new(2023);
    let n = 4096;
    let dists: Vec<(&str, Vec<f32>)> = vec![
        ("gaussian", (0..n).map(|_| rng.normal() as f32).collect()),
        ("laplace", (0..n).map(|_| rng.laplace() as f32).collect()),
        ("heavy-tail", (0..n)
            .map(|_| (rng.normal() * (1.0 + 5.0 * rng.uniform().powi(6))) as f32)
            .collect()),
        ("relu-acts", (0..n).map(|_| (rng.normal() * 1.2 + 0.3).max(0.0) as f32).collect()),
    ];
    let mut t = Table::new(&["distribution", "dybit", "int", "flint", "adaptivfloat", "posit"]);
    let mut results = Vec::new();
    for (dn, x) in &dists {
        let mut row = vec![dn.to_string()];
        for fmt in [Format::DyBit, Format::Int, Format::Flint, Format::AdaptivFloat, Format::Posit] {
            let e = quantizer::quant_rmse(x, fmt, 4);
            row.push(format!("{e:.4}"));
            results.push(Json::obj(vec![
                ("dist", Json::str(dn)),
                ("format", Json::str(fmt.name())),
                ("bits", Json::num(4.0)),
                ("rmse", Json::num(e)),
            ]));
        }
        t.row(row);
    }
    t.print();
    println!("(expected shape: DyBit lowest on heavy-tail/laplace; INT only competitive on pure gaussian)");

    println!("\n=== codec micro-benchmarks ===");
    let bench = Bench::new(3, 15);
    let x: Vec<f32> = (0..262_144).map(|_| rng.normal() as f32).collect();
    let grid = Format::DyBit.grid(4);
    let mut out = vec![0.0f32; x.len()];
    let s = bench.run(|| {
        quantizer::quantize_to_grid(&x, &grid, 0.5, &mut out);
    });
    println!(
        "quantize_to_grid dybit4, 256k elems: {} /iter ({:.1} Melem/s)",
        dybit::util::stats::fmt_time(s.mean),
        x.len() as f64 / s.mean / 1e6
    );
    let s = bench.run(|| {
        std::hint::black_box(quantizer::calibrate_scale(&x[..16384], &grid));
    });
    println!(
        "calibrate_scale (54 candidates, 16k elems): {} /iter",
        dybit::util::stats::fmt_time(s.mean)
    );
    let s = bench.run(|| {
        for c in 0..=255u8 {
            std::hint::black_box(dy::decode(c, 8));
        }
    });
    println!("dybit8 decode, all 256 codes: {} /iter", dybit::util::stats::fmt_time(s.mean));

    common::save_results("table1_fig2", Json::Arr(results)).expect("save");
    println!("\ntable1_format done");
}
