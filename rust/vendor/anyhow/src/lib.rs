//! Offline stand-in for the `anyhow` crate (DESIGN.md §2).
//!
//! The build environment for this repository is fully offline, so external
//! crates cannot be fetched from crates.io.  This in-tree crate implements
//! the exact `anyhow` API subset the workspace uses — [`Error`], [`Result`],
//! the [`Context`] trait, and the `anyhow!` / `bail!` / `ensure!` macros —
//! with identical call-site semantics, so swapping in the real `anyhow`
//! later is a one-line `Cargo.toml` change.
//!
//! Design notes (mirroring the real crate where it matters):
//!
//! * `Error` deliberately does **not** implement `std::error::Error`; that
//!   is what allows the blanket `impl<E: std::error::Error> From<E> for
//!   Error` to coexist with the reflexive `From<Error> for Error`.
//! * `{e}` displays the outermost context; `{e:#}` displays the whole
//!   context chain joined by `": "` — the formatting the binaries rely on.

#![deny(rustdoc::broken_intra_doc_links)]

use std::fmt;

/// `Result` alias defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A lightweight error: a chain of context strings, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (most recent first).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The root (innermost) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to results
/// and options, mirroring `anyhow::Context`.
pub trait Context<T> {
    /// Attach a context message to the error case.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Attach a lazily-built context message to the error case.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::concat!(
                "condition failed: ",
                ::std::stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        std::fs::read("/definitely/not/a/path/xyz")
            .with_context(|| "reading config".to_string())?;
        Ok(())
    }

    #[test]
    fn io_error_converts_and_gains_context() {
        let e = fails_io().unwrap_err();
        assert_eq!(e.chain().next(), Some("reading config"));
        assert!(e.chain.len() == 2);
    }

    #[test]
    fn display_plain_vs_alternate() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
        assert_eq!(format!("{e:?}"), "outer: inner");
    }

    #[test]
    fn macros_build_errors() {
        let who = "grid";
        let e = anyhow!("bad {who} at {}", 3);
        assert_eq!(format!("{e}"), "bad grid at 3");
        let e2 = anyhow!(String::from("plain"));
        assert_eq!(format!("{e2}"), "plain");

        fn guard(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("x too big: {x}");
            }
            Ok(x)
        }
        assert!(guard(5).is_ok());
        assert_eq!(format!("{}", guard(-1).unwrap_err()), "x must be positive, got -1");
        assert_eq!(format!("{}", guard(101).unwrap_err()), "x too big: 101");
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
    }
}
