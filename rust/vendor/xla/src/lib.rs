//! Offline stub of the `xla-rs` PJRT bindings (DESIGN.md §2).
//!
//! The real runtime layer executes AOT-compiled HLO through a PJRT plugin;
//! that native library is not part of this offline build environment.  This
//! stub keeps the whole workspace compiling and keeps every *host-side*
//! data-marshalling path fully functional:
//!
//! * [`Literal`] is a real implementation — shaped f32/i32 buffers with
//!   `vec1` / `scalar` / `reshape` / `convert` / `to_vec`, exactly the
//!   subset `runtime::executor` marshals tensors through.  Unit tests of
//!   tensor⇄literal round-trips pass against this stub.
//! * The device-side types ([`PjRtClient`], [`PjRtLoadedExecutable`],
//!   [`HloModuleProto`], [`XlaComputation`]) carry the same signatures but
//!   return [`Error`] at runtime.  Every caller in the workspace already
//!   gates on `PjRtClient::cpu()` / `Manifest::load` succeeding and skips
//!   gracefully, so tests and benches degrade to their artifact-free paths.
//!
//! Swapping the real `xla` crate back in is a one-line `Cargo.toml` change;
//! no call-site changes are required.

#![deny(rustdoc::broken_intra_doc_links)]

use std::fmt;

/// Stub error: all device-side entry points return this.
pub struct Error(pub String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what} requires the PJRT runtime, which is unavailable in this \
         offline build (in-tree stub crate)"
    )))
}

/// Element type of a (non-tuple) literal.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Primitive type selector used by [`Literal::convert`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
    S32,
}

#[derive(Clone, Debug, PartialEq)]
enum Payload {
    F32(Vec<f32>),
    S32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// A shaped host-side value: an f32/i32 array or a tuple of literals.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    payload: Payload,
}

/// Array shape (dims only; the element type lives on the literal).
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Scalar element types storable in a [`Literal`].
pub trait NativeType: Copy + Sized {
    const ELEMENT_TYPE: ElementType;
    fn scalar_literal(self) -> Literal;
    fn extract(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    const ELEMENT_TYPE: ElementType = ElementType::F32;

    fn scalar_literal(self) -> Literal {
        Literal { dims: Vec::new(), payload: Payload::F32(vec![self]) }
    }

    fn extract(lit: &Literal) -> Result<Vec<f32>> {
        match &lit.payload {
            Payload::F32(v) => Ok(v.clone()),
            _ => Err(Error("to_vec::<f32> on a non-f32 literal".into())),
        }
    }
}

impl NativeType for i32 {
    const ELEMENT_TYPE: ElementType = ElementType::S32;

    fn scalar_literal(self) -> Literal {
        Literal { dims: Vec::new(), payload: Payload::S32(vec![self]) }
    }

    fn extract(lit: &Literal) -> Result<Vec<i32>> {
        match &lit.payload {
            Payload::S32(v) => Ok(v.clone()),
            _ => Err(Error("to_vec::<i32> on a non-i32 literal".into())),
        }
    }
}

impl Literal {
    /// Rank-1 f32 literal.
    pub fn vec1(xs: &[f32]) -> Literal {
        Literal { dims: vec![xs.len() as i64], payload: Payload::F32(xs.to_vec()) }
    }

    /// Scalar literal of any supported native type.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        v.scalar_literal()
    }

    fn numel(&self) -> usize {
        match &self.payload {
            Payload::F32(v) => v.len(),
            Payload::S32(v) => v.len(),
            Payload::Tuple(v) => v.len(),
        }
    }

    /// Reinterpret the dims (element count must be preserved).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if matches!(self.payload, Payload::Tuple(_)) {
            return Err(Error("reshape on a tuple literal".into()));
        }
        if want as usize != self.numel() {
            return Err(Error(format!(
                "reshape {:?} -> {:?}: element count mismatch",
                self.dims, dims
            )));
        }
        Ok(Literal { dims: dims.to_vec(), payload: self.payload.clone() })
    }

    /// Shape of a non-tuple literal.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self.payload {
            Payload::Tuple(_) => Err(Error("array_shape on a tuple literal".into())),
            _ => Ok(ArrayShape { dims: self.dims.clone() }),
        }
    }

    /// Element type of a non-tuple literal.
    pub fn ty(&self) -> Result<ElementType> {
        match self.payload {
            Payload::F32(_) => Ok(ElementType::F32),
            Payload::S32(_) => Ok(ElementType::S32),
            Payload::Tuple(_) => Err(Error("ty on a tuple literal".into())),
        }
    }

    /// Copy the elements out as `T`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(self)
    }

    /// Element-type conversion (numeric cast, shape preserved).
    pub fn convert(&self, ty: PrimitiveType) -> Result<Literal> {
        let payload = match (&self.payload, ty) {
            (Payload::F32(v), PrimitiveType::F32) => Payload::F32(v.clone()),
            (Payload::S32(v), PrimitiveType::S32) => Payload::S32(v.clone()),
            (Payload::F32(v), PrimitiveType::S32) => {
                Payload::S32(v.iter().map(|&x| x as i32).collect())
            }
            (Payload::S32(v), PrimitiveType::F32) => {
                Payload::F32(v.iter().map(|&x| x as f32).collect())
            }
            (Payload::Tuple(_), _) => {
                return Err(Error("convert on a tuple literal".into()));
            }
        };
        Ok(Literal { dims: self.dims.clone(), payload })
    }

    /// Decompose a tuple literal into its members.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.payload {
            Payload::Tuple(v) => Ok(v),
            _ => Err(Error("to_tuple on a non-tuple literal".into())),
        }
    }

    /// Build a tuple literal (test/mock helper; the real crate builds
    /// tuples on the device side only).
    pub fn tuple(members: Vec<Literal>) -> Literal {
        Literal { dims: vec![members.len() as i64], payload: Payload::Tuple(members) }
    }
}

/// Parsed HLO module (stub: never constructible at runtime).
pub struct HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation handle (stub).
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client handle (stub: `cpu()` reports the runtime as unavailable).
pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec1_reshape_roundtrip() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 3]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn scalars_and_types() {
        let f = Literal::scalar(2.5f32);
        assert_eq!(f.ty().unwrap(), ElementType::F32);
        assert_eq!(f.array_shape().unwrap().dims().len(), 0);
        let i = Literal::scalar(7i32);
        assert_eq!(i.ty().unwrap(), ElementType::S32);
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![7]);
        assert!(i.to_vec::<f32>().is_err());
    }

    #[test]
    fn convert_casts() {
        let l = Literal::vec1(&[1.9, -2.2]);
        let s = l.convert(PrimitiveType::S32).unwrap();
        assert_eq!(s.to_vec::<i32>().unwrap(), vec![1, -2]);
        let back = s.convert(PrimitiveType::F32).unwrap();
        assert_eq!(back.to_vec::<f32>().unwrap(), vec![1.0, -2.0]);
    }

    #[test]
    fn tuple_decomposes() {
        let t = Literal::tuple(vec![Literal::scalar(1i32), Literal::vec1(&[0.5])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(Literal::scalar(1i32).to_tuple().is_err());
    }

    #[test]
    fn device_side_is_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let msg = format!("{:?}", PjRtClient::cpu().unwrap_err());
        assert!(msg.contains("stub"));
    }
}
