//! Analyzer certification (DESIGN.md §14, EXPERIMENTS.md).
//!
//! Two gates:
//!
//! * **Fixture oracle** — every planted-violation / known-clean file
//!   under `rust/tests/fixtures/lint/` must produce *exactly* the
//!   `(lint-id, line)` pairs recorded in `EXPECTED.json`.  The Python
//!   validation mirror is certified against the same file by
//!   `python/tools/certify_fixtures.py`, so the two implementations
//!   cannot drift apart silently.
//! * **Clean tree** — `rust/src` must gate clean: zero unsuppressed
//!   findings.  This is the tier-1 test behind the `ci.sh` guarantee
//!   that introducing any planted-violation pattern fails CI.

use std::collections::BTreeSet;
use std::path::Path;

use dybit::analysis::{analyze_paths, Finding, LINT_IDS};
use dybit::util::json;

fn repo_path(rel: &str) -> String {
    format!("{}/{}", env!("CARGO_MANIFEST_DIR"), rel)
}

fn pairs(findings: &[Finding]) -> Vec<(String, u32)> {
    findings.iter().map(|f| (f.lint.to_string(), f.line)).collect()
}

fn expected_pairs(entry: &json::Json, key: &str) -> Vec<(String, u32)> {
    entry
        .get(key)
        .and_then(|v| v.as_arr())
        .expect("EXPECTED.json entry list")
        .iter()
        .map(|pair| {
            let lid = pair
                .idx(0)
                .and_then(|x| x.as_str())
                .expect("lint id")
                .to_string();
            let line = pair.idx(1).and_then(|x| x.as_usize()).expect("line") as u32;
            (lid, line)
        })
        .collect()
}

#[test]
fn fixtures_match_expected_oracle() {
    let dir = repo_path("rust/tests/fixtures/lint");
    let text = std::fs::read_to_string(format!("{dir}/EXPECTED.json"))
        .expect("EXPECTED.json readable");
    let doc = json::parse(&text).expect("EXPECTED.json parses");
    let files = doc
        .get("files")
        .and_then(|f| f.as_obj())
        .expect("files object");
    assert!(!files.is_empty(), "oracle lists no fixtures");

    for (rel, entry) in files {
        let path = format!("{dir}/{rel}");
        assert!(Path::new(&path).is_file(), "fixture {rel} missing on disk");
        let report = analyze_paths(&[path.as_str()])
            .unwrap_or_else(|e| panic!("analyzing {rel}: {e}"));
        assert_eq!(
            pairs(&report.unsuppressed),
            expected_pairs(entry, "unsuppressed"),
            "{rel}: unsuppressed findings diverge from EXPECTED.json"
        );
        assert_eq!(
            pairs(&report.suppressed),
            expected_pairs(entry, "suppressed"),
            "{rel}: suppressed findings diverge from EXPECTED.json"
        );
    }

    // every lint id must be certified by at least one planted finding
    // it catches somewhere in the fixture set
    let mut certified: BTreeSet<String> = BTreeSet::new();
    for entry in files.values() {
        for key in ["unsuppressed", "suppressed"] {
            for (lid, _) in expected_pairs(entry, key) {
                certified.insert(lid);
            }
        }
    }
    for id in LINT_IDS {
        assert!(
            certified.contains(*id),
            "lint '{id}' has no planted-violation fixture certifying it"
        );
    }
}

#[test]
fn fixture_directory_scan_matches_per_file_union() {
    // analyzing the whole fixture tree at once must agree with the
    // per-file runs (cross-file quota-touch collection is additive,
    // never subtractive)
    let dir = repo_path("rust/tests/fixtures/lint");
    let text = std::fs::read_to_string(format!("{dir}/EXPECTED.json"))
        .expect("EXPECTED.json readable");
    let doc = json::parse(&text).expect("EXPECTED.json parses");
    let files = doc
        .get("files")
        .and_then(|f| f.as_obj())
        .expect("files object");
    let expected_total: usize = files
        .values()
        .map(|e| expected_pairs(e, "unsuppressed").len())
        .sum();
    let report = analyze_paths(&[dir.as_str()]).expect("analyze fixture dir");
    assert_eq!(
        report.unsuppressed.len(),
        expected_total,
        "whole-directory scan disagrees with the per-file oracle"
    );
}

#[test]
fn lint_clean_tree() {
    let root = repo_path("rust/src");
    let report = analyze_paths(&[root.as_str()]).expect("analyze rust/src");
    let listing = report
        .unsuppressed
        .iter()
        .map(|f| f.to_string())
        .collect::<Vec<_>>()
        .join("\n");
    assert!(
        report.is_clean(),
        "rust/src has unsuppressed lint findings (fix them or add a \
         justified `// lint:allow(<id>): <why>`):\n{listing}"
    );
}

#[test]
fn suppressions_on_the_tree_stay_justified() {
    // the live tree's suppressed findings all carry justifications by
    // construction (unjustified allows surface as `suppression`
    // findings and fail lint_clean_tree); sanity-check the split is
    // actually exercised so a regression in the allow plumbing cannot
    // silently turn every suppression into a pass
    let root = repo_path("rust/src");
    let report = analyze_paths(&[root.as_str()]).expect("analyze rust/src");
    assert!(
        !report.suppressed.is_empty(),
        "expected at least one justified suppression on the live tree \
         (the batcher poison drill and server holding-slot expects)"
    );
}
