//! Integration tests over the real PJRT runtime + AOT artifacts.
//!
//! These exercise the full L3→L2→L1 composition: rust builds LUTs from its
//! format library, feeds them to the compiled HLO, and checks the results
//! against its own quantizer — i.e. the L1 Pallas kernel, the L2 model
//! fake-quant, and the L3 codecs must all agree.
//!
//! All tests skip gracefully when artifacts are missing (`make artifacts`).

use std::path::Path;

use dybit::formats::{quantizer, Format, LUT_SIZE};
use dybit::qat::{materialize_batch, QuantConfig, Session};
use dybit::runtime::{f32_scalar, tensor_to_literal, Executor, Manifest};
use dybit::tensor::Tensor;
use dybit::util::rng::Rng;

fn setup() -> Option<(Manifest, Executor)> {
    let dir = Path::new("artifacts");
    let m = Manifest::load(dir).ok()?;
    let e = Executor::new(dir).ok()?;
    Some((m, e))
}

#[test]
fn pallas_fake_quant_kernel_matches_rust_quantizer() {
    let Some((m, mut exec)) = setup() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let art = &m.kernels["fake_quant"];
    let shape: Vec<usize> = art.inputs[0].shape.clone();
    let n: usize = shape.iter().product();
    let mut rng = Rng::new(42);
    let x = Tensor::new(shape, rng.normal_vec(n)).unwrap();
    let lut = Tensor::from_vec(Format::DyBit.padded_lut(4));
    let scale = 0.37f32;

    let outs = exec
        .run_t(
            &art.file,
            &[
                tensor_to_literal(&x).unwrap(),
                tensor_to_literal(&lut).unwrap(),
                f32_scalar(scale),
            ],
        )
        .expect("kernel runs");
    let got = &outs[0];

    let grid = Format::DyBit.grid(4);
    let mut want = vec![0.0f32; n];
    quantizer::quantize_to_grid(&x.data, &grid, scale as f64, &mut want);
    let max_err = got
        .data
        .iter()
        .zip(want.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 1e-5, "pallas kernel vs rust quantizer: {max_err}");
}

#[test]
fn qgemm_kernel_decodes_dybit_codes() {
    let Some((m, mut exec)) = setup() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let art = &m.kernels["qgemm"];
    let xs = art.inputs[0].shape.clone(); // [M, K]
    let cs = art.inputs[1].shape.clone(); // [K, N]
    let mut rng = Rng::new(7);
    let x = Tensor::new(xs.clone(), rng.normal_vec(xs.iter().product()))
        .unwrap();
    // codes as f32 values 0..16 (i32 input: convert via literal)
    let ncodes: usize = cs.iter().product();
    let codes_f: Vec<f32> = (0..ncodes).map(|_| rng.below(16) as f32).collect();
    let codes = Tensor::new(cs.clone(), codes_f.clone()).unwrap();
    let lut_codes = Tensor::from_vec(code_lut4());
    let scale = 0.25f32;

    let code_lit = tensor_to_literal(&codes)
        .unwrap()
        .convert(xla::PrimitiveType::S32)
        .expect("convert codes to i32");
    let outs = exec
        .run_t(
            &art.file,
            &[
                tensor_to_literal(&x).unwrap(),
                code_lit,
                tensor_to_literal(&lut_codes).unwrap(),
                f32_scalar(scale),
            ],
        )
        .expect("qgemm runs");
    let got = &outs[0];

    // reference: y = x @ (scale * decode(codes))
    let (mdim, k) = (xs[0], xs[1]);
    let n = cs[1];
    let lut = code_lut4();
    let mut want = vec![0.0f32; mdim * n];
    for i in 0..mdim {
        for kk in 0..k {
            let xv = x.data[i * k + kk];
            for j in 0..n {
                let w = lut[codes_f[kk * n + j] as usize] * scale;
                want[i * n + j] += xv * w;
            }
        }
    }
    let max_err = got
        .data
        .iter()
        .zip(want.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 1e-3, "qgemm mismatch: {max_err}");
}

/// Code-indexed dybit4 LUT padded to 256 (the qgemm artifact contract).
fn code_lut4() -> Vec<f32> {
    dybit::formats::dybit::code_lut(4, LUT_SIZE)
}

#[test]
fn data_batch_is_deterministic_and_labelled() {
    let Some((m, mut exec)) = setup() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let (x1, y1) = materialize_batch(&mut exec, &m.dir, 5).unwrap();
    let (x2, y2) = materialize_batch(&mut exec, &m.dir, 5).unwrap();
    let (x3, _) = materialize_batch(&mut exec, &m.dir, 6).unwrap();
    assert_eq!(x1, x2, "same seed must give identical batches");
    assert_eq!(y1.data, y2.data);
    assert_ne!(x1.data, x3.data, "different seeds must differ");
    assert_eq!(x1.shape, vec![m.batch, m.img, m.img, 3]);
    assert!(y1.data.iter().all(|&c| c >= 0.0 && c < m.classes as f32));
}

#[test]
fn mlp_fwd_fp32_equals_disabled_quant_and_pallas_agrees() {
    let Some((m, mut exec)) = setup() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let mut session = Session::new(&m, "mlp").unwrap();
    let (x, _) = materialize_batch(&mut exec, &m.dir, 1).unwrap();
    let nl = session.model.n_quant_layers;

    let fp = QuantConfig::fp32(nl);
    let logits_ref = session.forward(&mut exec, &fp, &x, false).unwrap();
    assert_eq!(logits_ref.shape, vec![m.batch, m.classes]);

    // quantized (8/8 dybit) should be close to fp32, not equal
    let mut q8 = QuantConfig::uniform(nl, Format::DyBit, 8, 8);
    session.calibrate(&mut exec, &mut q8, 2).unwrap();
    let logits_q8 = session.forward(&mut exec, &q8, &x, false).unwrap();
    let diff = max_abs_diff(&logits_ref.data, &logits_q8.data);
    assert!(diff > 0.0, "8/8 quant must actually quantize");
    // untrained-net logits span several units; 8/8 must stay same-order
    let span = logits_ref.max_abs().max(1.0);
    assert!(diff < span, "8/8 quant drifted: diff {diff} vs span {span}");

    // the pallas-kernel fwd must match the ref fwd on identical config
    let logits_pallas = session.forward(&mut exec, &q8, &x, true).unwrap();
    let dp = max_abs_diff(&logits_q8.data, &logits_pallas.data);
    assert!(dp < 1e-3, "pallas fwd vs ref fwd: {dp}");
}

#[test]
fn train_step_reduces_loss_on_mlp() {
    let Some((m, mut exec)) = setup() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let mut session = Session::new(&m, "mlp").unwrap();
    let nl = session.model.n_quant_layers;
    let fp = QuantConfig::fp32(nl);
    let first = session.train(&mut exec, &fp, 8, 0.05, 0).unwrap();
    let before = first.first().unwrap().loss;
    let after = first.last().unwrap().loss;
    assert!(
        after < before,
        "loss should fall within 8 steps: {before} -> {after}"
    );
}

#[test]
fn lut_width_matches_manifest() {
    let Some((m, _)) = setup() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    assert_eq!(m.lut_size, LUT_SIZE);
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}
