//! Integration tests for the §12 overload-safety layer: SLA-aware
//! admission (`Server::submit_with`), per-tenant fair queuing, deadline
//! drops at assembly, and the closed-loop escalation-margin tuner — all
//! over the artifact-free [`SimBackend`].
//!
//! The extended accounting invariant under test: every submission ends
//! in exactly one of `requests`, `failed_requests`, `rejected`
//! (admission refusals + invalid payloads), or `deadline_drops`, and
//! every admitted receiver resolves exactly once — including under
//! forced overload and mid-drain shutdown.

use std::sync::Arc;
use std::time::Duration;

use dybit::coordinator::{
    router_from_spec, AdmissionCfg, Escalate, EscalationController, Policy, PoolConfig, Reject,
    ReplicaPrecision, Router, Server, SimBackend, SimBackendCfg, Snapshot, SubmitOpts,
};
use dybit::util::rng::Rng;

fn assert_accounted(snap: &Snapshot, submitted: u64) {
    assert_eq!(
        snap.requests + snap.failed_requests + snap.rejected + snap.deadline_drops,
        submitted,
        "accounting invariant violated: {snap:?}"
    );
    assert_eq!(snap.queue_depth, 0, "queues must drain: {snap:?}");
}

/// `tiny` sim config rescaled so one batch takes ~`batch_s` wall
/// seconds — slow enough that a submit burst outruns the pool.
fn timed_cfg(seed: u64, batch_s: f64) -> SimBackendCfg {
    let mut cfg = SimBackendCfg::tiny(seed);
    let probe = SimBackend::new(cfg.clone()).expect("probe backend");
    cfg.time_scale = batch_s / probe.sim_latency_s();
    cfg
}

/// Tentpole (a): a full shard refuses with a typed `QueueFull` instead
/// of blocking the submitter, and the refusals land in `rejected`.
#[test]
fn full_queue_rejects_typed_instead_of_blocking() {
    let cfg = timed_cfg(1, 0.05);
    let pool = PoolConfig {
        policy: Policy { max_batch: cfg.batch, max_wait: Duration::from_micros(200) },
        queue_cap: 2,
        replicas: 1,
        precisions: vec![ReplicaPrecision::uniform(8)],
        ..PoolConfig::default()
    };
    let server = Server::start_pool(pool, SimBackend::factory(cfg.clone())).unwrap();
    let mut rng = Rng::new(7);
    let mut rxs = Vec::new();
    let mut rejected = 0u64;
    for _ in 0..64 {
        match server.submit_with(rng.normal_vec(cfg.img_elems), SubmitOpts::default()) {
            Ok(rx) => rxs.push(rx),
            Err(Reject::QueueFull { cap, depth, .. }) => {
                assert_eq!(cap, 2);
                assert!(depth >= 2, "refused below capacity: depth {depth}");
                rejected += 1;
            }
            Err(e) => panic!("unexpected reject: {e}"),
        }
    }
    assert!(rejected > 0, "a 64-burst against a cap-2 queue on 50ms batches must overflow");
    for rx in &rxs {
        let class = rx.recv_timeout(Duration::from_secs(30)).expect("resolve");
        assert!(class.expect("admitted requests succeed") < 10);
    }
    let snap = server.shutdown().unwrap();
    assert_eq!(snap.rejected, rejected, "every QueueFull counts in rejected");
    assert_eq!(snap.deadline_drops, 0);
    assert_accounted(&snap, 64);
}

/// Tentpole (a): a deadline the projected queue delay already exceeds
/// is rejected at submit — typed, descriptive, and counted — while the
/// same payload without an SLA is served normally.
#[test]
fn infeasible_deadlines_reject_at_submit() {
    let cfg = SimBackendCfg::tiny(2);
    let pool = PoolConfig {
        queue_cap: 8,
        replicas: 1,
        // seed the cost estimate at one hour per batch: any ms-scale
        // deadline is deterministically infeasible
        admission: AdmissionCfg {
            batch_cost: vec![Duration::from_secs(3600)],
            ..AdmissionCfg::default()
        },
        ..PoolConfig::default()
    };
    let server = Server::start_pool(pool, SimBackend::factory(cfg.clone())).unwrap();
    let img = vec![0.5f32; cfg.img_elems];
    let e = server
        .submit_with(img.clone(), SubmitOpts::with_deadline(Duration::from_millis(10)))
        .unwrap_err();
    match e {
        Reject::DeadlineInfeasible { projected, deadline } => {
            assert!(projected >= Duration::from_secs(3600), "projected {projected:?}");
            assert_eq!(deadline, Duration::from_millis(10));
        }
        other => panic!("expected DeadlineInfeasible, got: {other}"),
    }
    assert!(e.to_string().contains("infeasible"), "{e}");
    // the same request without an SLA is admitted and served
    let rx = server.submit_with(img, SubmitOpts::default()).unwrap();
    assert!(rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap() < 10);
    let snap = server.shutdown().unwrap();
    assert_eq!(snap.rejected, 1);
    assert_eq!(snap.requests, 1);
    assert_accounted(&snap, 2);
}

/// Tentpole (a): an admitted request whose deadline expires while
/// queued is dropped at assembly — `Err` reply mentioning the
/// deadline, counted in `deadline_drops`, never executed as if live.
#[test]
fn expired_deadlines_drop_at_assembly_with_err() {
    let cfg = timed_cfg(3, 0.03);
    let pool = PoolConfig {
        policy: Policy { max_batch: cfg.batch, max_wait: Duration::from_micros(200) },
        queue_cap: 64,
        replicas: 1,
        precisions: vec![ReplicaPrecision::uniform(8)],
        ..PoolConfig::default()
    };
    let server = Server::start_pool(pool, SimBackend::factory(cfg.clone())).unwrap();
    // unseeded cost estimate: the projection is zero until the first
    // batch completes (~30ms), so this instant burst is all admitted —
    // the 5ms SLAs then expire in the queue behind the slow batches
    let opts = SubmitOpts::with_deadline(Duration::from_millis(5));
    let mut rng = Rng::new(9);
    let rxs: Vec<_> = (0..12)
        .map(|_| {
            server
                .submit_with(rng.normal_vec(cfg.img_elems), opts)
                .expect("unseeded projection admits the burst")
        })
        .collect();
    let mut served = 0u64;
    let mut dropped = 0u64;
    for rx in &rxs {
        match rx.recv_timeout(Duration::from_secs(30)).expect("every receiver resolves") {
            Ok(class) => {
                assert!(class < 10);
                served += 1;
            }
            Err(e) => {
                assert!(e.contains("deadline"), "drop reply must say why: {e}");
                dropped += 1;
            }
        }
    }
    let snap = server.shutdown().unwrap();
    assert!(dropped >= 1, "batches behind a 30ms head start must expire their 5ms SLA");
    assert_eq!(snap.deadline_drops, dropped);
    assert_eq!(snap.requests, served);
    assert_eq!(snap.rejected, 0);
    assert_accounted(&snap, 12);
}

/// Tentpole (b): the starvation regression.  A 95%-skewed hot tenant
/// is capped at its per-shard quota while the cold tenant's sparse
/// submissions are all admitted — and every accepted receiver still
/// resolves.
#[test]
fn hot_tenant_cannot_starve_the_cold_one() {
    let cfg = timed_cfg(4, 0.05);
    let pool = PoolConfig {
        policy: Policy { max_batch: cfg.batch, max_wait: Duration::from_micros(200) },
        queue_cap: 8,
        replicas: 1,
        precisions: vec![ReplicaPrecision::uniform(8)],
        admission: AdmissionCfg { tenants: 2, ..AdmissionCfg::default() },
        ..PoolConfig::default()
    };
    let server = Server::start_pool(pool, SimBackend::factory(cfg.clone())).unwrap();
    assert_eq!(server.admission().quota(), 4, "cap 8 over 2 tenants");
    let mut rng = Rng::new(11);
    let mut rxs = Vec::new();
    let mut cold_admitted = 0u64;
    let mut hot_throttled = 0u64;
    // 95% skew: tenant 0 sends 38 of 40 requests in one burst, the
    // cold tenant 1 interleaves two
    for i in 0..40u32 {
        let tenant = u32::from(i % 20 == 19);
        match server.submit_with(rng.normal_vec(cfg.img_elems),
                                 SubmitOpts { deadline: None, tenant }) {
            Ok(rx) => {
                if tenant == 1 {
                    cold_admitted += 1;
                }
                rxs.push(rx);
            }
            Err(Reject::TenantThrottled { tenant: t, held, quota, .. }) => {
                assert_eq!(t, 0, "the cold tenant must never be throttled");
                assert_eq!((held, quota), (4, 4));
                hot_throttled += 1;
            }
            Err(e) => panic!("unexpected reject: {e}"),
        }
    }
    // the hot tenant can only ever hold half the queue, so the cold
    // tenant always finds its own slots free
    assert_eq!(cold_admitted, 2, "both cold submissions must be admitted");
    assert!(hot_throttled > 0, "a 38-burst against a quota of 4 must throttle");
    for rx in &rxs {
        rx.recv_timeout(Duration::from_secs(30)).expect("resolve").expect("class");
    }
    let snap = server.shutdown().unwrap();
    assert_eq!(snap.rejected, hot_throttled);
    assert_accounted(&snap, 40);
}

/// Satellite 1: forced overload + shutdown mid-queue.  Every receiver
/// `submit_with` handed out resolves exactly once — answered, dropped
/// with an `Err`, or failed, never hung — even for items still queued
/// when `shutdown` starts the drain.
#[test]
fn every_receiver_resolves_under_overload_and_shutdown() {
    let mix = vec![ReplicaPrecision::uniform(4), ReplicaPrecision::uniform(8)];
    let cfg = timed_cfg(5, 0.04);
    let pool = PoolConfig {
        policy: Policy { max_batch: cfg.batch, max_wait: Duration::from_micros(200) },
        queue_cap: 32,
        replicas: 2,
        precisions: mix.clone(),
        admission: AdmissionCfg { tenants: 3, ..AdmissionCfg::default() },
        ..PoolConfig::default()
    };
    let server =
        Server::start_pool(pool, SimBackend::mixed_factory(cfg.clone(), mix)).unwrap();
    let mut rng = Rng::new(13);
    let mut rxs = Vec::new();
    let mut rejected = 0u64;
    for i in 0..24u32 {
        let opts = SubmitOpts { deadline: Some(Duration::from_millis(2)), tenant: i % 3 };
        match server.submit_with(rng.normal_vec(cfg.img_elems), opts) {
            Ok(rx) => rxs.push(rx),
            Err(_) => rejected += 1,
        }
    }
    // shut down while most of the burst is still queued: the drain must
    // answer (or deadline-drop) every one of them, never forget one
    let snap = server.shutdown().unwrap();
    for rx in &rxs {
        rx.recv_timeout(Duration::from_secs(5))
            .expect("a submitted receiver must resolve even across shutdown");
    }
    assert_eq!(snap.rejected, rejected);
    assert_accounted(&snap, 24);
}

/// Tentpole (c), wiring smoke: `escalate:auto` + an escalation budget
/// run the background PI tuner; the tuned margin stays finite and
/// inside the controller bounds, first-run decisions are counted, and
/// the accounting stays exact.  (Convergence to the budget is gated in
/// `benches/perf_route.rs`, where the load is long enough to measure.)
#[test]
fn margin_tuner_runs_and_stays_in_bounds() {
    let cfg = SimBackendCfg::tiny(6);
    let mix = vec![
        ReplicaPrecision::uniform(4),
        ReplicaPrecision::uniform(4),
        ReplicaPrecision::uniform(8),
    ];
    let router = Arc::new(Escalate::auto_tuned());
    let knob = router.margin_knob().expect("escalate:auto exposes its knob");
    let mut ctl = EscalationController::with_budget(0.3);
    ctl.interval = Duration::from_millis(2);
    ctl.min_samples = 4;
    let bounds = ctl.bounds;
    let pool = PoolConfig {
        queue_cap: 64,
        replicas: 3,
        precisions: mix.clone(),
        router,
        escalation: Some(ctl),
        ..PoolConfig::default()
    };
    let server =
        Server::start_pool(pool, SimBackend::mixed_factory(cfg.clone(), mix)).unwrap();
    dybit::coordinator::load_test(&server, 4, 100, cfg.img_elems).unwrap();
    // a few controller windows after the load, then a clean join
    std::thread::sleep(Duration::from_millis(20));
    let snap = server.shutdown().unwrap();
    let m = knob.get();
    assert!(
        m.is_finite() && m >= bounds.0 && m <= bounds.1,
        "tuned margin {m} escaped bounds {bounds:?}"
    );
    assert!(snap.first_runs > 0, "successful batches must count first-run decisions");
    assert!(
        snap.first_runs + snap.rejected + snap.deadline_drops + snap.failed_requests
            >= snap.requests,
        "first-run decisions cover every answered request: {snap:?}"
    );
    assert_accounted(&snap, 400);
}

/// Satellite 2: the `escalate:auto` spec wires end-to-end through
/// `start_pool`, and an escalation budget without a tunable router —
/// or with infinite margin bounds — fails the start descriptively.
#[test]
fn escalation_config_wiring_and_rejections() {
    let cfg = SimBackendCfg::tiny(8);
    let mix = vec![ReplicaPrecision::uniform(4), ReplicaPrecision::uniform(8)];
    let router = router_from_spec("escalate:auto").unwrap();
    assert!(router.margin_knob().is_some());
    let pool = PoolConfig {
        replicas: 2,
        precisions: mix.clone(),
        router,
        escalation: Some(EscalationController::with_budget(0.2)),
        ..PoolConfig::default()
    };
    let server =
        Server::start_pool(pool, SimBackend::mixed_factory(cfg.clone(), mix.clone())).unwrap();
    assert!(server.infer(vec![0.25; cfg.img_elems]).unwrap() < 10);
    let snap = server.shutdown().unwrap();
    assert_accounted(&snap, 1);

    // budget over a fixed-margin router: refused at start
    let pool = PoolConfig {
        replicas: 2,
        precisions: mix.clone(),
        router: router_from_spec("escalate:0.1").unwrap(),
        escalation: Some(EscalationController::with_budget(0.2)),
        ..PoolConfig::default()
    };
    let e = Server::start_pool(pool, SimBackend::mixed_factory(cfg.clone(), mix.clone()))
        .unwrap_err()
        .to_string();
    assert!(e.contains("escalate:auto"), "{e}");

    // inf bounds smuggled past the spec parser: refused by validate()
    let mut ctl = EscalationController::with_budget(0.2);
    ctl.bounds = (0.0, f32::INFINITY);
    let pool = PoolConfig {
        replicas: 2,
        precisions: mix.clone(),
        router: router_from_spec("escalate:auto").unwrap(),
        escalation: Some(ctl),
        ..PoolConfig::default()
    };
    let e = Server::start_pool(pool, SimBackend::mixed_factory(cfg, mix))
        .unwrap_err()
        .to_string();
    assert!(e.contains("finite"), "{e}");
}
