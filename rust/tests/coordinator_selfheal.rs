//! Self-healing pool tests (DESIGN.md §13) over seeded chaos injection:
//! death → respawn, watchdog supersession of a wedged replica, flapping
//! → retirement with degraded service, escalation failover down the
//! precision ladder when the accurate tier dies, and the EWMA reseed on
//! respawn.  All artifact-free over [`SimBackend`], all deterministic
//! fault points via [`ChaosSpec`].
//!
//! The §12 four-bucket invariant is asserted through every kill:
//! `requests + failed_requests + rejected + deadline_drops ==
//! submitted`, and every submit's receiver resolves — a supervisor that
//! loses requests while healing is worse than no supervisor.

use std::collections::HashSet;
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use dybit::coordinator::{
    AdmissionCfg, BackendFactory, ChaosBackend, ChaosSpec, Escalate, InferenceBackend,
    Policy, PoolConfig, ReplicaPrecision, ReplicaState, Server, SimBackend, SimBackendCfg,
    Snapshot, SupervisionCfg,
};
use dybit::util::rng::Rng;

type Reply = std::result::Result<usize, String>;

const IMG: usize = 64;

/// Tight supervision so tests heal in milliseconds, not the production
/// defaults' seconds.
fn fast_supervision(max_restarts: u32) -> SupervisionCfg {
    SupervisionCfg {
        heartbeat: Duration::from_millis(5),
        watchdog: Duration::from_millis(100),
        max_restarts,
        backoff: Duration::from_millis(5),
        backoff_cap: Duration::from_millis(40),
    }
}

fn pool(replicas: usize, sup: SupervisionCfg) -> PoolConfig {
    PoolConfig {
        policy: Policy { max_batch: 4, max_wait: Duration::from_millis(1) },
        queue_cap: 64,
        replicas,
        supervision: Some(sup),
        ..PoolConfig::default()
    }
}

/// Chaos only on each replica's *first* incarnation: respawns get the
/// bare backend, so a die/hang schedule produces one fault and then a
/// healthy pool (the unscoped wrapper would re-fault every incarnation
/// and flap — that mode gets its own test below).
fn first_spawn_chaos(spec: &str, inner: BackendFactory) -> BackendFactory {
    let spec = ChaosSpec::parse(spec).unwrap();
    let seen: Arc<Mutex<HashSet<usize>>> = Arc::new(Mutex::new(HashSet::new()));
    Arc::new(move |replica| {
        let backend = inner(replica)?;
        if dybit::util::lock(&seen).insert(replica) {
            Ok(Box::new(ChaosBackend::new(backend, &spec, replica))
                as Box<dyn InferenceBackend>)
        } else {
            Ok(backend)
        }
    })
}

fn must_reply(rx: &Receiver<Reply>) -> Reply {
    rx.recv_timeout(Duration::from_secs(10))
        .expect("client must receive a reply (lost during a kill/respawn?)")
}

fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !cond() {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "timed out waiting for {what}"
        );
        std::thread::sleep(Duration::from_millis(2));
    }
}

fn assert_accounted(snap: &Snapshot, submitted: u64) {
    assert_eq!(
        snap.requests + snap.failed_requests + snap.rejected + snap.deadline_drops,
        submitted,
        "accounting invariant violated: {snap:?}"
    );
    assert_eq!(snap.queue_depth, 0, "queue must drain: {snap:?}");
}

#[test]
fn dead_replica_respawns_and_the_pool_keeps_serving() {
    let factory =
        first_spawn_chaos("die@1:r0", SimBackend::factory(SimBackendCfg::tiny(7)));
    let server = Server::start_pool(pool(2, fast_supervision(3)), factory).unwrap();
    let mut rng = Rng::new(1);
    let rxs: Vec<_> = (0..24)
        .map(|_| server.submit(rng.normal_vec(IMG)).unwrap())
        .collect();
    for rx in &rxs {
        assert!(must_reply(rx).expect("healed pool answers") < 10);
    }
    wait_until("the supervisor to respawn replica 0", || {
        server.snapshot().restarts >= 1
    });
    let faults = server.fault_log();
    assert!(
        faults.iter().any(|l| l.contains("respawned")),
        "fault log must record the respawn: {faults:?}"
    );
    // the healed replica serves: its slot is live again, not retired
    assert_eq!(server.health().alive_count(), 2);
    assert!(server.infer(rng.normal_vec(IMG)).unwrap() < 10);
    let snap = server.shutdown().expect("supervised deaths must not fail shutdown");
    assert_accounted(&snap, 25);
    assert!(snap.restarts >= 1, "{snap:?}");
    assert_eq!(snap.retired, 0, "{snap:?}");
    assert_eq!(snap.per_replica[0].restarts, snap.restarts, "{snap:?}");
}

#[test]
fn watchdog_supersedes_a_wedged_replica() {
    // one replica, first forward wedges for far longer than the 100ms
    // watchdog: the supervisor must supersede it and respawn — the
    // replacement (not the zombie) drains the rest of the queue
    let factory =
        first_spawn_chaos("hang@1=700", SimBackend::factory(SimBackendCfg::tiny(3)));
    let server = Server::start_pool(pool(1, fast_supervision(3)), factory).unwrap();
    let mut rng = Rng::new(2);
    let rxs: Vec<_> = (0..12)
        .map(|_| server.submit(rng.normal_vec(IMG)).unwrap())
        .collect();
    wait_until("the watchdog to trip", || {
        server.fault_log().iter().any(|l| l.contains("watchdog tripped"))
    });
    wait_until("the replacement to spawn", || server.snapshot().restarts >= 1);
    // every receiver resolves: the zombie still answers the chunk it
    // was wedged on (its reply channels are alive), the replacement
    // answers everything behind it
    for rx in &rxs {
        assert!(must_reply(rx).expect("no request may be lost to the zombie") < 10);
    }
    let snap = server.shutdown().unwrap();
    assert_accounted(&snap, 12);
    assert!(snap.restarts >= 1, "{snap:?}");
}

#[test]
fn flapping_replica_is_retired_and_the_pool_degrades() {
    // unscoped wrapper: EVERY incarnation of replica 0 dies on its
    // first forward, so the restart budget burns down and the slot is
    // retired for good — the pool must keep serving on replica 1
    let spec = ChaosSpec::parse("die@1:r0").unwrap();
    let factory = spec.wrap(SimBackend::factory(SimBackendCfg::tiny(11)));
    let max_restarts = 2;
    let server =
        Server::start_pool(pool(2, fast_supervision(max_restarts)), factory).unwrap();
    let mut rng = Rng::new(4);
    let mut rxs = Vec::new();
    // keep traffic flowing so each fresh incarnation of replica 0
    // receives the batch that kills it
    let t0 = Instant::now();
    while server.snapshot().retired == 0 {
        assert!(t0.elapsed() < Duration::from_secs(10), "replica 0 never retired");
        for _ in 0..4 {
            rxs.push(server.submit(rng.normal_vec(IMG)).unwrap());
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    let submitted = rxs.len() as u64 + 1;
    // degraded, not down: the survivor still answers
    assert_eq!(server.health().state(0), ReplicaState::Retired);
    assert_eq!(server.health().alive_count(), 1);
    assert!(server.infer(rng.normal_vec(IMG)).unwrap() < 10);
    for rx in &rxs {
        let _ = must_reply(rx); // resolved — rehomed Oks and drained Errs both count
    }
    let faults = server.fault_log();
    assert!(
        faults.iter().any(|l| l.contains("retired")),
        "fault log must record the retirement: {faults:?}"
    );
    let snap = server.shutdown().unwrap();
    assert_accounted(&snap, submitted);
    assert_eq!(snap.retired, 1, "{snap:?}");
    assert_eq!(snap.restarts, max_restarts as u64, "{snap:?}");
}

#[test]
fn escalations_fail_over_down_the_ladder_when_the_accurate_tier_dies() {
    // regression (coordinator/server.rs pre-§13): escalation pushed to
    // a fixed most-accurate index with an unbounded blocking push — an
    // 8-bit replica dying under a 100%-escalation workload blackholed
    // every low-margin request.  Now the push walks the ladder of
    // *live* higher-precision replicas with a bounded wait per rung,
    // and an exhausted ladder answers with the fast prediction.
    let mix = vec![
        ReplicaPrecision::uniform(4),
        ReplicaPrecision::uniform(4),
        ReplicaPrecision::uniform(8),
    ];
    // die@1 scoped to the accurate replica + a zero restart budget:
    // the first escalated batch it serves kills it permanently
    let spec = ChaosSpec::parse("die@1:r2").unwrap();
    let factory = spec.wrap(SimBackend::mixed_factory(SimBackendCfg::tiny(21), mix.clone()));
    let cfg = PoolConfig {
        policy: Policy { max_batch: 4, max_wait: Duration::from_millis(1) },
        queue_cap: 64,
        replicas: 3,
        precisions: mix,
        router: Arc::new(Escalate::new(0.05)),
        work_stealing: false, // the accurate tier must not pre-steal
        supervision: Some(fast_supervision(0)),
        ..PoolConfig::default()
    };
    let server = Server::start_pool(cfg, factory).unwrap();
    // zero payloads ⇒ all-zero logits ⇒ margin 0 < 0.05: every request
    // wants escalation (the workload from coordinator_routing.rs)
    let wave1: Vec<_> = (0..16)
        .map(|_| server.submit(vec![0.0; IMG]).unwrap())
        .collect();
    for rx in &wave1 {
        assert!(must_reply(rx).expect("escalated or failed-over, never lost") < 10);
    }
    wait_until("the accurate replica to be retired", || {
        server.snapshot().retired >= 1
    });
    // with the whole upper ladder dead, escalations must resolve as
    // failovers (the fast answer stands) — not hang, not drop
    let wave2: Vec<_> = (0..16)
        .map(|_| server.submit(vec![0.0; IMG]).unwrap())
        .collect();
    for rx in &wave2 {
        assert!(must_reply(rx).expect("ladder-exhausted requests still answer") < 10);
    }
    let snap = server.shutdown().unwrap();
    assert_accounted(&snap, 32);
    assert!(snap.failovers >= 1, "{snap:?}");
    assert_eq!(snap.retired, 1, "{snap:?}");
}

#[test]
fn respawn_reseeds_the_admission_cost_estimate() {
    // regression (coordinator/admission.rs pre-§13): a respawned
    // replica inherited the EWMA its dead incarnation left behind —
    // a death mid-jitter-storm poisoned the §12 delay projection until
    // enough clean batches washed it out.  The supervisor now restores
    // the constructor seed on respawn.
    let seed = Duration::from_millis(50);
    // max_batch 1 makes chunk boundaries deterministic: 4 submits are
    // exactly forward calls 1..4, so die@4 answers everything first and
    // the respawned incarnation never observes a batch — whatever the
    // estimate reads after the respawn is exactly what reseeding left
    let factory =
        first_spawn_chaos("die@4", SimBackend::factory(SimBackendCfg::tiny(5)));
    let cfg = PoolConfig {
        policy: Policy { max_batch: 1, max_wait: Duration::from_millis(1) },
        queue_cap: 64,
        replicas: 1,
        admission: AdmissionCfg { batch_cost: vec![seed], ..AdmissionCfg::default() },
        supervision: Some(fast_supervision(3)),
        ..PoolConfig::default()
    };
    let server = Server::start_pool(cfg, factory).unwrap();
    assert!((server.admission().batch_cost_s(0) - 0.05).abs() < 1e-12);
    // four ~µs observations drag the EWMA well off the 50ms seed —
    // then the backend dies
    let rxs: Vec<_> = (0..4).map(|_| server.submit(vec![0.5; IMG]).unwrap()).collect();
    for rx in &rxs {
        assert!(must_reply(rx).unwrap() < 10);
    }
    wait_until("the respawn to reseed the estimate", || {
        server.snapshot().restarts >= 1
            && (server.admission().batch_cost_s(0) - 0.05).abs() < 1e-12
    });
    // no traffic after the respawn: the estimate must sit exactly on
    // the constructor seed, not on the dead incarnation's EWMA
    assert!((server.admission().batch_cost_s(0) - 0.05).abs() < 1e-12);
    let snap = server.shutdown().unwrap();
    assert_accounted(&snap, 4);
}

#[test]
fn supervision_off_preserves_the_error_propagating_shutdown() {
    // --no-supervise (supervision: None) keeps the pre-§13 contract:
    // a permanently failed backend is a *loud* worker error surfaced
    // by shutdown, and stranded items still resolve via the final
    // failover sweep
    let spec = ChaosSpec::parse("die@1").unwrap();
    let factory = spec.wrap(SimBackend::factory(SimBackendCfg::tiny(9)));
    let cfg = PoolConfig {
        policy: Policy { max_batch: 4, max_wait: Duration::from_millis(1) },
        queue_cap: 64,
        replicas: 1,
        supervision: None,
        ..PoolConfig::default()
    };
    let server = Server::start_pool(cfg, factory).unwrap();
    let rxs: Vec<_> = (0..8).map(|_| server.submit(vec![0.5; IMG]).unwrap()).collect();
    // the first submit is in the first popped chunk, which the dying
    // call still answers — blocking on it proves the worker got that
    // far before shutdown joins it
    assert!(must_reply(&rxs[0]).expect("the dying call still answers") < 10);
    let err = server.shutdown().unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("failed permanently"), "{msg}");
    // every receiver resolved: answered by the worker or Err-swept
    for rx in &rxs[1..] {
        let _ = rx
            .recv_timeout(Duration::from_secs(1))
            .expect("sweep must resolve stranded receivers");
    }
}
