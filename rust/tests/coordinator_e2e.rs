//! Coordinator end-to-end tests over the artifact-free [`SimBackend`]
//! (DESIGN.md §9): replica-pool behaviour, the panic/hang bug sweep, and
//! the metrics accounting invariant — all runnable in CI with no PJRT
//! artifacts.
//!
//! Accounting invariant under test: every submitted request ends in
//! exactly one of `requests` (success), `failed_requests` (slot of a
//! failed batch), `rejected` (invalid payload or admission refusal),
//! or `deadline_drops` (SLA expired in the queue), and every submit's
//! receiver observes exactly one reply — no hung clients, ever.

use std::sync::mpsc::Receiver;
use std::time::Duration;

use anyhow::{anyhow, Result};

use dybit::coordinator::{
    InferenceBackend, Policy, PoolConfig, Server, SimBackend, SimBackendCfg, Snapshot,
};
use dybit::tensor::Tensor;
use dybit::util::rng::Rng;

type Reply = std::result::Result<usize, String>;

const IMG: usize = 64;

fn pool(replicas: usize) -> PoolConfig {
    PoolConfig {
        policy: Policy { max_batch: 4, max_wait: Duration::from_millis(1) },
        queue_cap: 64,
        replicas,
        ..PoolConfig::default()
    }
}

/// Receive with a deadline: a hang here is exactly the bug class this
/// suite exists to catch, so fail loudly instead of wedging the test.
fn must_reply(rx: &Receiver<Reply>) -> Reply {
    rx.recv_timeout(Duration::from_secs(10))
        .expect("client must receive a reply (worker hung or died)")
}

fn assert_accounted(snap: &Snapshot, submitted: u64) {
    assert_eq!(
        snap.requests + snap.failed_requests + snap.rejected + snap.deadline_drops,
        submitted,
        "accounting invariant violated: {snap:?}"
    );
    assert_eq!(snap.queue_depth, 0, "queue must drain: {snap:?}");
    let b: u64 = snap.per_replica.iter().map(|r| r.batches).sum();
    let e: u64 = snap.per_replica.iter().map(|r| r.errors).sum();
    assert_eq!(b, snap.batches, "per-replica batches must sum to global");
    assert_eq!(e, snap.errors, "per-replica errors must sum to global");
}

#[test]
fn pool_answers_mixed_good_and_bad_payloads_under_load() {
    let server =
        Server::start_pool(pool(3), SimBackend::factory(SimBackendCfg::tiny(7))).unwrap();
    let (clients, per_client) = (6, 10);
    std::thread::scope(|s| {
        for c in 0..clients {
            let server = &server;
            s.spawn(move || {
                let mut rng = Rng::new(c as u64 + 1);
                for i in 0..per_client {
                    if i % 3 == 2 {
                        // wrong length: must get an Err reply, never a
                        // fabricated class from zero-padding
                        let rx = server.submit_unchecked(rng.normal_vec(IMG / 2)).unwrap();
                        let err = must_reply(&rx).unwrap_err();
                        assert!(err.contains("elements"), "{err}");
                    } else {
                        let rx = server.submit(rng.normal_vec(IMG)).unwrap();
                        let pred = must_reply(&rx).expect("valid payload must succeed");
                        assert!(pred < 10);
                    }
                }
            });
        }
    });
    let snap = server.shutdown().unwrap();
    let submitted = (clients * per_client) as u64;
    assert_accounted(&snap, submitted);
    assert_eq!(snap.rejected, (clients * 3) as u64); // i = 2, 5, 8 per client
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.per_replica.len(), 3);
}

#[test]
fn oversized_policy_is_clamped_and_assemblies_split() {
    // regression (coordinator/server.rs pre-§9): Policy::default() is
    // max_batch 32; against a model with a smaller static batch dim the
    // worker sliced `xdata[i * img_elems..]` out of bounds and
    // underflowed `batch - n`, killing the worker and hanging every
    // queued client.  The pool clamps at start and splits defensively.
    let cfg = SimBackendCfg::tiny(3); // backend batch = 4
    let p = PoolConfig {
        policy: Policy { max_batch: 32, max_wait: Duration::from_millis(20) },
        queue_cap: 64,
        replicas: 1,
        ..PoolConfig::default()
    };
    let server = Server::start_pool(p, SimBackend::factory(cfg)).unwrap();
    assert_eq!(server.max_batch(), 4, "start must reconcile policy with the model");
    let mut rng = Rng::new(9);
    let rxs: Vec<_> = (0..12)
        .map(|_| server.submit(rng.normal_vec(IMG)).unwrap())
        .collect();
    for rx in &rxs {
        let pred = must_reply(rx).expect("clamped batches must still answer");
        assert!(pred < 10);
    }
    let snap = server.shutdown().unwrap();
    assert_accounted(&snap, 12);
    assert!(snap.batches >= 3, "12 requests cannot fit fewer than 3 batches of 4");
    assert!(snap.mean_batch <= 4.0 + 1e-9, "no assembly may exceed the model batch");
}

#[test]
fn nan_payloads_still_answer_every_request() {
    // regression (tensor/mod.rs): argmax_rows used partial_cmp().unwrap()
    // — one NaN logit panicked the worker and every queued client hung
    // on a dead channel.  NaN inputs × seeded weights ⇒ NaN logits.
    let server =
        Server::start_pool(pool(2), SimBackend::factory(SimBackendCfg::tiny(5))).unwrap();
    let rxs: Vec<_> = (0..8)
        .map(|_| server.submit(vec![f32::NAN; IMG]).unwrap())
        .collect();
    for rx in &rxs {
        let pred = must_reply(rx).expect("NaN logits must still pick a class");
        assert!(pred < 10);
    }
    // the pool survives: ordinary traffic still flows afterwards
    let mut rng = Rng::new(2);
    assert!(server.infer(rng.normal_vec(IMG)).unwrap() < 10);
    let snap = server.shutdown().unwrap();
    assert_accounted(&snap, 9);
    assert_eq!(snap.errors, 0);
}

#[test]
fn startup_failure_surfaces_from_start() {
    // regression (coordinator/server.rs pre-§9): a failing worker
    // preamble returned Ok from Server::start and clients only ever saw
    // "server dropped request"; the readiness handshake surfaces it.
    let factory: dybit::coordinator::BackendFactory =
        std::sync::Arc::new(|id| Err(anyhow!("boom on replica {id}")));
    let err = Server::start_pool(pool(2), factory).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("boom on replica"), "{msg}");

    // one bad replica out of several still fails the whole start
    let factory: dybit::coordinator::BackendFactory = std::sync::Arc::new(|id| {
        if id == 1 {
            Err(anyhow!("replica 1 exploded"))
        } else {
            Ok(Box::new(SimBackend::new(SimBackendCfg::tiny(1))?)
                as Box<dyn InferenceBackend>)
        }
    });
    let err = Server::start_pool(pool(3), factory).unwrap_err();
    assert!(format!("{err:#}").contains("replica 1 exploded"));
}

#[test]
fn panicking_factory_does_not_deadlock_start() {
    let factory: dybit::coordinator::BackendFactory =
        std::sync::Arc::new(|_| panic!("constructor panic"));
    let err = Server::start_pool(pool(2), factory).unwrap_err();
    assert!(format!("{err:#}").contains("constructor panic"));
}

/// A backend that panics when the first payload element is a sentinel —
/// the "model code blows up mid-request" case.
struct PanickyBackend(SimBackend);

impl InferenceBackend for PanickyBackend {
    fn name(&self) -> &str {
        "panicky"
    }

    fn batch(&self) -> usize {
        self.0.batch()
    }

    fn img_elems(&self) -> usize {
        self.0.img_elems()
    }

    fn forward(&mut self, x: Tensor) -> Result<Tensor> {
        assert!(x.data[0] != 1234.5, "panicky backend tripped");
        self.0.forward(x)
    }
}

#[test]
fn backend_panic_fails_the_batch_not_the_replica() {
    let factory: dybit::coordinator::BackendFactory = std::sync::Arc::new(|_| {
        Ok(Box::new(PanickyBackend(SimBackend::new(SimBackendCfg::tiny(4))?))
            as Box<dyn InferenceBackend>)
    });
    let server = Server::start_pool(pool(1), factory).unwrap();
    let mut bad = vec![0.0f32; IMG];
    bad[0] = 1234.5;
    let rx = server.submit(bad).unwrap();
    let err = must_reply(&rx).unwrap_err();
    assert!(err.contains("panicked"), "{err}");
    // the replica survived the panic and keeps serving
    let mut rng = Rng::new(6);
    assert!(server.infer(rng.normal_vec(IMG)).unwrap() < 10);
    let snap = server.shutdown().unwrap();
    assert_accounted(&snap, 2);
    assert_eq!(snap.errors, 1);
    assert_eq!(snap.failed_requests, 1);
}

#[test]
fn injected_backend_errors_reply_err_and_count() {
    let mut cfg = SimBackendCfg::tiny(8);
    cfg.fail_on = Some(77.0);
    let server = Server::start_pool(pool(1), SimBackend::factory(cfg)).unwrap();
    // sequential so each failing payload forms its own batch
    let mut bad = vec![0.0f32; IMG];
    bad[10] = 77.0;
    let rx = server.submit(bad).unwrap();
    let err = must_reply(&rx).unwrap_err();
    assert!(err.contains("injected"), "{err}");
    // a clean payload right after the failed batch still succeeds
    assert!(server.infer(vec![0.5; IMG]).unwrap() < 10);
    let snap = server.shutdown().unwrap();
    assert_accounted(&snap, 2);
    assert_eq!(snap.errors, 1);
    assert_eq!(snap.requests, 1);
}

#[test]
fn shutdown_drains_a_full_queue() {
    // slow the backend down so the queue genuinely backs up, then shut
    // down with requests still queued: every receiver must get a reply
    let mut cfg = SimBackendCfg::tiny(2);
    let probe = SimBackend::new(cfg.clone()).unwrap();
    cfg.time_scale = 0.002 / probe.sim_latency_s(); // ~2ms per batch
    let server = Server::start_pool(pool(2), SimBackend::factory(cfg)).unwrap();
    let mut rng = Rng::new(3);
    let rxs: Vec<_> = (0..32)
        .map(|_| server.submit(rng.normal_vec(IMG)).unwrap())
        .collect();
    let snap = server.shutdown().unwrap(); // closes intake, drains, joins
    for rx in &rxs {
        // replies were produced during the drain; they sit in the
        // per-request channels even though the server is gone
        let pred = rx.try_recv().expect("drained request must have a reply");
        assert!(pred.expect("drained request must succeed") < 10);
    }
    assert_accounted(&snap, 32);
    assert_eq!(snap.requests, 32);
}

#[test]
fn replicas_share_one_seeded_scorer_and_agree() {
    let server =
        Server::start_pool(pool(4), SimBackend::factory(SimBackendCfg::tiny(21))).unwrap();
    let img: Vec<f32> = (0..IMG).map(|i| (i as f32 * 0.37).cos()).collect();
    // enough sequential repeats that several replicas serve the payload
    let first = server.infer(img.clone()).unwrap();
    for _ in 0..16 {
        assert_eq!(server.infer(img.clone()).unwrap(), first);
    }
    let snap = server.shutdown().unwrap();
    assert_accounted(&snap, 17);
}
