//! Heterogeneous-precision routing + work-stealing e2e tests over the
//! artifact-free [`SimBackend`] (DESIGN.md §10): skewed-load stealing,
//! the steal precision gate, router determinism, and escalation
//! accounting — all runnable in CI with no PJRT artifacts.
//!
//! The §9 accounting invariant still holds with two-execution requests:
//! an escalated request counts in `requests` only when its re-run
//! replies, so `requests + failed_requests + rejected + deadline_drops
//! == submitted` stays exact (asserted in every test here).
//!
//! The §15 tests at the bottom cover both escalation paths over the
//! nested-precision [`BitplaneBackend`]: refinement on (cached partial
//! sums + residual planes) and `refine: false` (the pre-§15 full
//! re-run) — with tier-invariant answers across both and the plain
//! [`SimBackend`].

use std::sync::Arc;
use std::time::Duration;

use dybit::coordinator::{
    AccuracyFloor, BitplaneBackend, Escalate, Policy, PoolConfig, ReplicaPrecision, Router,
    Server, SimBackend, SimBackendCfg, Snapshot,
};
use dybit::util::rng::Rng;

const IMG: usize = 64;

/// Test router that pins every request to one shard — the maximally
/// skewed workload the work-stealing satellite task calls for.
struct Pin(usize);

impl Router for Pin {
    fn name(&self) -> &str {
        "pin"
    }

    fn route(&self, _precisions: &[ReplicaPrecision]) -> usize {
        self.0
    }
}

fn assert_accounted(snap: &Snapshot, submitted: u64) {
    assert_eq!(
        snap.requests + snap.failed_requests + snap.rejected + snap.deadline_drops,
        submitted,
        "accounting invariant violated: {snap:?}"
    );
    assert_eq!(snap.queue_depth, 0, "queues must drain: {snap:?}");
    let b: u64 = snap.per_replica.iter().map(|r| r.batches).sum();
    assert_eq!(b, snap.batches, "per-replica batches must sum to global");
    let e: u64 = snap.per_replica.iter().map(|r| r.escalations).sum();
    assert_eq!(e, snap.escalations, "per-replica escalations must sum to global");
}

/// A pool whose batches take real wall time (~1 ms) so queues actually
/// build up and idle replicas get a chance to steal.
fn slow_cfg(seed: u64) -> SimBackendCfg {
    let mut cfg = SimBackendCfg::tiny(seed);
    let probe = SimBackend::new(cfg.clone()).unwrap();
    cfg.time_scale = 0.001 / probe.sim_latency_s();
    cfg
}

#[test]
fn skewed_routing_is_rescued_by_work_stealing() {
    // 100% of traffic pinned to replica 0's queue: the other replicas
    // only ever see work by stealing from its tail
    let pool = PoolConfig {
        policy: Policy { max_batch: 4, max_wait: Duration::from_millis(1) },
        queue_cap: 256,
        replicas: 4,
        router: Arc::new(Pin(0)),
        ..PoolConfig::default()
    };
    let server =
        Server::start_pool(pool, SimBackend::factory(slow_cfg(7))).unwrap();
    let mut rng = Rng::new(11);
    let n = 120;
    let rxs: Vec<_> = (0..n)
        .map(|_| server.submit(rng.normal_vec(IMG)).unwrap())
        .collect();
    for rx in &rxs {
        let pred = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("stolen requests must still be answered")
            .expect("valid payloads succeed");
        assert!(pred < 10);
    }
    let snap = server.shutdown().unwrap();
    assert_accounted(&snap, n as u64);
    // the router really was skewed…
    assert_eq!(snap.per_replica[0].routed, n as u64);
    for r in &snap.per_replica[1..] {
        assert_eq!(r.routed, 0);
    }
    // …and stealing kept the whole pool busy anyway
    for (i, r) in snap.per_replica.iter().enumerate() {
        assert!(r.batches > 0, "replica {i} idled under skewed load: {snap:?}");
    }
    let stolen: u64 = snap.per_replica.iter().map(|r| r.stolen).sum();
    assert!(stolen > 0, "siblings must have stolen from the hot queue");
    assert_eq!(snap.per_replica[0].stolen, 0, "the hot replica has nothing to steal");
}

#[test]
fn disabling_work_stealing_serializes_a_skewed_pool() {
    let pool = PoolConfig {
        policy: Policy { max_batch: 4, max_wait: Duration::from_millis(1) },
        queue_cap: 256,
        replicas: 3,
        router: Arc::new(Pin(0)),
        work_stealing: false,
        ..PoolConfig::default()
    };
    let server =
        Server::start_pool(pool, SimBackend::factory(SimBackendCfg::tiny(3))).unwrap();
    let mut rng = Rng::new(5);
    let n = 24;
    let rxs: Vec<_> = (0..n)
        .map(|_| server.submit(rng.normal_vec(IMG)).unwrap())
        .collect();
    for rx in &rxs {
        assert!(rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap() < 10);
    }
    let snap = server.shutdown().unwrap();
    assert_accounted(&snap, n as u64);
    assert_eq!(snap.per_replica[0].requests, n as u64);
    for r in &snap.per_replica[1..] {
        assert_eq!(r.batches, 0, "stealing is off: siblings must stay idle");
        assert_eq!(r.stolen, 0);
    }
}

/// Mixed 2-tier pool: one fast DyBit-4 replica, one accurate 8-bit one.
fn two_tier() -> Vec<ReplicaPrecision> {
    vec![ReplicaPrecision::uniform(4), ReplicaPrecision::uniform(8)]
}

#[test]
fn low_margin_replies_escalate_exactly_once_and_are_counted() {
    let mix = two_tier();
    let pool = PoolConfig {
        policy: Policy { max_batch: 4, max_wait: Duration::from_millis(1) },
        queue_cap: 256,
        replicas: 2,
        precisions: mix.clone(),
        router: Arc::new(Escalate::new(0.05)),
        work_stealing: false, // the accurate tier must not pre-steal the probe
        ..PoolConfig::default()
    };
    let server =
        Server::start_pool(pool, SimBackend::mixed_factory(SimBackendCfg::tiny(21), mix))
            .unwrap();
    // zero payloads ⇒ all-zero logits ⇒ margin exactly 0 < 0.05: every
    // request lands on the fast tier (escalate routes primary traffic
    // there) and must re-run on the accurate tier
    let n = 20;
    let rxs: Vec<_> = (0..n).map(|_| server.submit(vec![0.0; IMG]).unwrap()).collect();
    for rx in &rxs {
        let pred = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("escalated requests must still be answered")
            .expect("escalation is a re-run, not a failure");
        assert!(pred < 10);
    }
    let snap = server.shutdown().unwrap();
    assert_accounted(&snap, n as u64);
    assert_eq!(snap.escalations, n as u64, "every low-margin reply must escalate: {snap:?}");
    assert_eq!(snap.per_replica[0].escalations, n as u64);
    // the fast tier answered nothing; the accurate tier answered all
    assert_eq!(snap.per_replica[0].requests, 0);
    assert_eq!(snap.per_replica[1].requests, n as u64);
    assert!(snap.per_replica[0].batches > 0, "the fast tier did run first passes");
    assert_eq!(snap.per_replica[0].stolen, 0);
}

#[test]
fn high_margin_replies_do_not_escalate() {
    let mix = two_tier();
    let pool = PoolConfig {
        policy: Policy { max_batch: 4, max_wait: Duration::from_millis(1) },
        queue_cap: 256,
        replicas: 2,
        precisions: mix.clone(),
        router: Arc::new(Escalate::new(0.05)),
        work_stealing: false,
        ..PoolConfig::default()
    };
    let server =
        Server::start_pool(pool, SimBackend::mixed_factory(SimBackendCfg::tiny(21), mix))
            .unwrap();
    // huge-norm payloads ⇒ O(100)-margin logits ⇒ no escalations
    let mut rng = Rng::new(77);
    let n = 20;
    let rxs: Vec<_> = (0..n)
        .map(|_| {
            let img: Vec<f32> = rng.normal_vec(IMG).iter().map(|v| v * 100.0).collect();
            server.submit(img).unwrap()
        })
        .collect();
    for rx in &rxs {
        assert!(rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap() < 10);
    }
    let snap = server.shutdown().unwrap();
    assert_accounted(&snap, n as u64);
    assert_eq!(snap.escalations, 0, "{snap:?}");
    assert_eq!(snap.per_replica[0].requests, n as u64, "fast tier answers directly");
}

#[test]
fn accuracy_floor_routing_and_steal_gate_keep_fast_replicas_out() {
    let mix = two_tier();
    let pool = PoolConfig {
        policy: Policy { max_batch: 4, max_wait: Duration::from_millis(1) },
        queue_cap: 256,
        replicas: 2,
        precisions: mix.clone(),
        router: Arc::new(AccuracyFloor::new(8)),
        work_stealing: true, // stealing on: the gate, not the flag, must hold
        ..PoolConfig::default()
    };
    // slow backend so the accurate queue builds up while the fast
    // replica idles next to it, hungry to steal
    let server =
        Server::start_pool(pool, SimBackend::mixed_factory(slow_cfg(9), mix)).unwrap();
    let mut rng = Rng::new(13);
    let n = 40;
    let rxs: Vec<_> = (0..n)
        .map(|_| server.submit(rng.normal_vec(IMG)).unwrap())
        .collect();
    for rx in &rxs {
        assert!(rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap() < 10);
    }
    let snap = server.shutdown().unwrap();
    assert_accounted(&snap, n as u64);
    assert_eq!(snap.per_replica[1].routed, n as u64, "floor:8 routes to the 8-bit tier");
    assert_eq!(snap.per_replica[0].routed, 0);
    // the 4-bit replica may not serve floor-tagged items — not even by
    // stealing from the loaded queue beside it
    assert_eq!(snap.per_replica[0].batches, 0, "steal gate violated: {snap:?}");
    assert_eq!(snap.per_replica[0].stolen, 0);
    assert_eq!(snap.per_replica[1].requests, n as u64);
}

#[test]
fn unsatisfiable_floor_clamps_and_siblings_still_steal() {
    // regression: floor:8 over an all-4-bit pool routes everything to
    // replica 0 (the clamped fallback) — the steal tag must be clamped
    // to the pool's best floor too, or the equal-floor siblings are
    // gated out of stealing and the pool silently serializes
    let mix = vec![ReplicaPrecision::uniform(4); 3];
    let pool = PoolConfig {
        policy: Policy { max_batch: 4, max_wait: Duration::from_millis(1) },
        queue_cap: 256,
        replicas: 3,
        precisions: mix.clone(),
        router: Arc::new(AccuracyFloor::new(8)),
        ..PoolConfig::default()
    };
    let server =
        Server::start_pool(pool, SimBackend::mixed_factory(slow_cfg(17), mix)).unwrap();
    let mut rng = Rng::new(23);
    let n = 120;
    let rxs: Vec<_> = (0..n)
        .map(|_| server.submit(rng.normal_vec(IMG)).unwrap())
        .collect();
    for rx in &rxs {
        assert!(rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap() < 10);
    }
    let snap = server.shutdown().unwrap();
    assert_accounted(&snap, n as u64);
    assert_eq!(snap.per_replica[0].routed, n as u64, "clamped floor pins routing");
    let stolen: u64 = snap.per_replica.iter().map(|r| r.stolen).sum();
    assert!(stolen > 0, "equal-floor siblings must steal the clamped items: {snap:?}");
    for (i, r) in snap.per_replica.iter().enumerate() {
        assert!(r.batches > 0, "replica {i} idled despite the clamped tag: {snap:?}");
    }
}

#[test]
fn routing_and_escalations_are_deterministic_for_a_seeded_workload() {
    // same seed ⇒ identical per-replica assignment counts, identical
    // escalation counts, identical answers — across two fresh pools
    let run = || {
        let mix = vec![
            ReplicaPrecision::uniform(4),
            ReplicaPrecision::uniform(4),
            ReplicaPrecision::uniform(8),
        ];
        let pool = PoolConfig {
            policy: Policy { max_batch: 4, max_wait: Duration::from_millis(1) },
            queue_cap: 256,
            replicas: 3,
            precisions: mix.clone(),
            router: Arc::new(Escalate::new(0.3)),
            work_stealing: false, // stealing is load-dependent; routing is not
            ..PoolConfig::default()
        };
        let server = Server::start_pool(
            pool,
            SimBackend::mixed_factory(SimBackendCfg::tiny(2), mix),
        )
        .unwrap();
        let mut rng = Rng::new(31);
        let n = 60;
        let rxs: Vec<_> = (0..n)
            .map(|_| server.submit(rng.normal_vec(IMG)).unwrap())
            .collect();
        let answers: Vec<usize> = rxs
            .iter()
            .map(|rx| rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap())
            .collect();
        let snap = server.shutdown().unwrap();
        assert_accounted(&snap, n as u64);
        let routed: Vec<u64> = snap.per_replica.iter().map(|r| r.routed).collect();
        (routed, snap.escalations, answers)
    };
    let (routed_a, esc_a, answers_a) = run();
    let (routed_b, esc_b, answers_b) = run();
    assert_eq!(routed_a, routed_b, "same seed must reproduce assignment counts");
    assert_eq!(esc_a, esc_b, "same seed must reproduce escalation counts");
    assert_eq!(answers_a, answers_b, "same seed must reproduce answers");
    // the escalate router never routes primary traffic to the accurate tier
    assert_eq!(routed_a[2], 0);
    assert_eq!(routed_a.iter().sum::<u64>(), 60);
}

#[test]
fn precision_mix_length_must_match_replicas() {
    let pool = PoolConfig {
        replicas: 2,
        precisions: vec![ReplicaPrecision::uniform(4); 3],
        ..PoolConfig::default()
    };
    let err = Server::start_pool(pool, SimBackend::factory(SimBackendCfg::tiny(1)))
        .unwrap_err();
    assert!(format!("{err:#}").contains("precision mix"), "{err:#}");
}

#[test]
fn heterogeneous_pool_answers_identically_across_tiers() {
    // the scorer seed is shared: a request served by the fast tier and
    // one served by the accurate tier pick the same class, so routing
    // (and stealing, and escalation) never changes a deterministic
    // answer — SimBackend models the latency side of precision only
    let mix = two_tier();
    let pool = PoolConfig {
        policy: Policy { max_batch: 2, max_wait: Duration::from_millis(1) },
        queue_cap: 64,
        replicas: 2,
        precisions: mix.clone(),
        // stealing off so the WRR pick sequence alone decides who serves
        // what — with it on, an idle sibling may race the owner for a
        // sequential request and the per-replica split becomes racy
        work_stealing: false,
        ..PoolConfig::default()
    };
    let server =
        Server::start_pool(pool, SimBackend::mixed_factory(SimBackendCfg::tiny(17), mix))
            .unwrap();
    let img: Vec<f32> = (0..IMG).map(|i| (i as f32 * 0.37).cos()).collect();
    let first = server.infer(img.clone()).unwrap();
    // the weighted round-robin feeds both tiers within a few picks
    for _ in 0..8 {
        assert_eq!(server.infer(img.clone()).unwrap(), first);
    }
    let snap = server.shutdown().unwrap();
    assert_accounted(&snap, 9);
    assert!(snap.per_replica.iter().all(|r| r.requests > 0));
}

/// A two-tier bitplane pool with `refine` as requested; everything else
/// matches the escalation tests above.
fn bitplane_pool(margin: f32, refine: bool) -> Server {
    let mix = two_tier();
    let pool = PoolConfig {
        policy: Policy { max_batch: 4, max_wait: Duration::from_millis(1) },
        queue_cap: 256,
        replicas: 2,
        precisions: mix.clone(),
        router: Arc::new(Escalate::new(margin)),
        work_stealing: false, // the accurate tier must not pre-steal the probe
        refine,
        ..PoolConfig::default()
    };
    Server::start_pool(pool, BitplaneBackend::mixed_factory(SimBackendCfg::tiny(21), mix))
        .unwrap()
}

#[test]
fn bitplane_escalations_refine_from_cached_partials() {
    // zero payloads ⇒ margin exactly 0 < 0.05 ⇒ every request escalates
    // off the fast tier; on a bitplane pool with refinement on, every
    // one of them is served by adding residual planes to the cached
    // partial sums, never by a full re-run (DESIGN.md §15)
    let server = bitplane_pool(0.05, true);
    let n = 20;
    let rxs: Vec<_> = (0..n).map(|_| server.submit(vec![0.0; IMG]).unwrap()).collect();
    for rx in &rxs {
        let pred = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("refined requests must still be answered")
            .expect("refinement is a completion, not a failure");
        assert!(pred < 10);
    }
    let snap = server.shutdown().unwrap();
    assert_accounted(&snap, n as u64);
    assert_eq!(snap.escalations, n as u64, "every low-margin reply escalates: {snap:?}");
    assert_eq!(snap.refinements, n as u64, "every escalation must refine: {snap:?}");
    assert_eq!(snap.per_replica[1].refinements, n as u64,
               "refinement executes at the accurate tier");
    assert_eq!(snap.per_replica[0].refinements, 0);
    // the accurate tier answered everything, via refinement
    assert_eq!(snap.per_replica[0].requests, 0);
    assert_eq!(snap.per_replica[1].requests, n as u64);
}

#[test]
fn refine_off_preserves_the_full_rerun_escalation_path() {
    // same pool, same workload, `refine: false`: the pre-§15 behavior —
    // escalations re-run from scratch on the accurate tier, the
    // refinement counter stays untouched, and the accounting is
    // identical to the SimBackend escalation tests above
    let server = bitplane_pool(0.05, false);
    let n = 20;
    let rxs: Vec<_> = (0..n).map(|_| server.submit(vec![0.0; IMG]).unwrap()).collect();
    for rx in &rxs {
        assert!(rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap() < 10);
    }
    let snap = server.shutdown().unwrap();
    assert_accounted(&snap, n as u64);
    assert_eq!(snap.escalations, n as u64, "{snap:?}");
    assert_eq!(snap.refinements, 0, "refine:off must never touch the plane cache: {snap:?}");
    assert_eq!(snap.per_replica[1].requests, n as u64);
}

#[test]
fn tier_invariant_answers_hold_under_refinement() {
    // an absurd margin forces EVERY request onto the escalation path,
    // so every answer is produced at full plane depth — by refinement
    // (bitplane, refine on), by a full re-run (bitplane, refine off),
    // and by the plain SimBackend re-run.  All three pools share the
    // scorer seed, so the three answer streams must be identical: §15
    // refinement never changes a deterministic answer.
    let run = |server: Server| {
        let mut rng = Rng::new(31);
        let n = 24;
        let rxs: Vec<_> = (0..n)
            .map(|_| server.submit(rng.normal_vec(IMG)).unwrap())
            .collect();
        let answers: Vec<usize> = rxs
            .iter()
            .map(|rx| rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap())
            .collect();
        let snap = server.shutdown().unwrap();
        assert_accounted(&snap, n as u64);
        assert_eq!(snap.escalations, n as u64, "margin 1e9 escalates everything: {snap:?}");
        (answers, snap.refinements)
    };
    let (refined, refinements_on) = run(bitplane_pool(1e9, true));
    let (rerun, refinements_off) = run(bitplane_pool(1e9, false));
    assert_eq!(refinements_on, refined.len() as u64);
    assert_eq!(refinements_off, 0);
    assert_eq!(refined, rerun, "refinement must reproduce the full re-run bit-for-bit");

    let mix = two_tier();
    let pool = PoolConfig {
        policy: Policy { max_batch: 4, max_wait: Duration::from_millis(1) },
        queue_cap: 256,
        replicas: 2,
        precisions: mix.clone(),
        router: Arc::new(Escalate::new(1e9)),
        work_stealing: false,
        ..PoolConfig::default()
    };
    let sim =
        Server::start_pool(pool, SimBackend::mixed_factory(SimBackendCfg::tiny(21), mix))
            .unwrap();
    let (direct, refinements_sim) = run(sim);
    assert_eq!(refinements_sim, 0, "SimBackend advertises no planes, so nothing refines");
    assert_eq!(refined, direct, "refined answers must match the direct full-depth scorer");
}
