//! Clean: the util::lock / util::wait free-function helpers.
use std::sync::{Condvar, Mutex};

fn good_lock(m: &Mutex<u32>) -> u32 {
    let g = lock(m);
    *g
}

fn good_wait(cv: &Condvar, m: &Mutex<bool>) {
    let mut g = lock(m);
    while !*g {
        g = wait(cv, g);
    }
}
