//! Planted: bare Instant/Duration arithmetic (the PR 2 underflow
//! panic class).
use std::time::{Duration, Instant};

fn remaining(deadline: Instant, now: Instant) -> Duration {
    deadline - now
}

fn padded(timeout: Duration) -> Duration {
    timeout + Duration::from_millis(5)
}

fn drift(acc: Duration, step: Duration) -> Duration {
    let mut total = acc;
    total += step;
    total
}
