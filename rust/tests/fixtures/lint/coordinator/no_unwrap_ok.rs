//! Clean: let-else and ? keep coordinator code panic-free.
fn take(x: Option<u32>) -> Option<u32> {
    let Some(v) = x else { return None };
    Some(v)
}

fn must(x: Option<u32>) -> Result<u32, String> {
    x.ok_or_else(|| String::from("missing"))
}
