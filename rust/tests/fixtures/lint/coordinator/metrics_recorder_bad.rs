//! Planted: a raw atomic op on an accounting bucket outside
//! metrics.rs breaks the four-bucket invariant silently.
use std::sync::atomic::{AtomicU64, Ordering};

struct Counters {
    rejected: AtomicU64,
}

fn bump(c: &Counters) {
    c.rejected.fetch_add(1, Ordering::Relaxed);
}
