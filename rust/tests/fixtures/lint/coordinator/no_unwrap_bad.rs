//! Planted: unwrap/expect in coordinator code kills a worker.
fn take(x: Option<u32>) -> u32 {
    x.unwrap()
}

fn must(x: Option<u32>) -> u32 {
    x.expect("present")
}
