//! The recorder itself may touch the buckets: metrics.rs is the one
//! file allowed to mutate them (it maintains the invariant).
use std::sync::atomic::{AtomicU64, Ordering};

pub struct Metrics {
    rejected: AtomicU64,
}

impl Metrics {
    pub fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }
}
