//! Clean: shard -> board is the blessed order; drop() releases; a
//! transient snapshot never holds.
use std::sync::Mutex;

struct Shard {
    // lock-order: intake level 1
    state: Mutex<u32>,
    // lock-order: intake level 2
    board: Mutex<Vec<u32>>,
    // lock-order: intake level 3 alone
    park: Mutex<u32>,
}

fn shard_then_board(s: &Shard) {
    let g = lock(&s.state);
    let b = lock(&s.board);
    let _ = (g, b);
}

fn drop_then_park(s: &Shard) {
    let g = lock(&s.state);
    drop(g);
    let p = lock(&s.park);
    let _ = p;
}

fn transient_snapshot(s: &Shard) -> Vec<u32> {
    let snap = lock(&s.board).clone();
    let g = lock(&s.state);
    let _ = g;
    snap
}

fn scoped_release(s: &Shard) {
    {
        let b = lock(&s.board);
        let _ = b;
    }
    let g = lock(&s.state);
    let _ = g;
}
