//! A justified lint:allow silences exactly one finding.
fn sort_scores(xs: &mut [f64]) {
    // lint:allow(float-total-cmp): fixture demonstrating a justified suppression
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
}
