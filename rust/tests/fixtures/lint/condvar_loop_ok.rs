//! Clean: the wait sits in a while-predicate re-check.
use std::sync::{Condvar, Mutex};

fn good(cv: &Condvar, m: &Mutex<bool>) {
    let mut g = lock(m);
    while !*g {
        g = wait(cv, g);
    }
    let _ = g;
}

fn good_loop(cv: &Condvar, m: &Mutex<bool>) {
    let mut g = lock(m);
    loop {
        if *g {
            break;
        }
        g = wait(cv, g);
    }
    let _ = g;
}
