//! Clean: total_cmp gives a total order (NaN sorts deterministically).
fn sort_latencies(xs: &mut [f64]) {
    xs.sort_by(|a, b| a.total_cmp(b));
}
