//! Clean: contained, registered, justified, or scoped spawns.
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::thread;

fn contained() {
    thread::spawn(|| {
        let _ = catch_unwind(AssertUnwindSafe(run_once));
    });
}

fn registered(watch: &DeathWatch) {
    let w = watch.clone();
    thread::spawn(move || {
        let _guard = DeathWatch::register(w);
        run_once();
    });
}

fn justified() {
    // spawn-guard: owns no client state; joined on shutdown by the caller
    thread::spawn(run_once);
}

fn scoped() {
    std::thread::scope(|scope| {
        scope.spawn(run_once);
    });
}
