//! Clean: checked/saturating time arithmetic, and escapes out of the
//! time domain.
use std::time::{Duration, Instant};

fn remaining(deadline: Instant, now: Instant) -> Duration {
    deadline.saturating_duration_since(now)
}

fn padded(timeout: Duration) -> Option<Duration> {
    timeout.checked_add(Duration::from_millis(5))
}

fn elapsed_ms(start: Instant) -> u128 {
    let spent = start.elapsed().as_millis();
    spent + 5
}
