//! Planted: every shape of DESIGN.md §11/§12 lock-order violation.
use std::sync::Mutex;

struct Shard {
    // lock-order: intake level 1
    state: Mutex<u32>,
    // lock-order: intake level 2
    board: Mutex<u32>,
    // lock-order: intake level 3 alone
    park: Mutex<u32>,
}

struct Quota;

impl Quota {
    // lock-order: quota-touch
    fn try_charge_fixture(&self) -> bool {
        true
    }
}

fn board_then_shard(s: &Shard) {
    let b = lock(&s.board);
    let g = lock(&s.state);
    let _ = (b, g);
}

fn park_not_alone(s: &Shard) {
    let g = lock(&s.state);
    let p = lock(&s.park);
    let _ = (g, p);
}

fn quota_under_guard(s: &Shard, q: &Quota) {
    let g = lock(&s.state);
    if q.try_charge_fixture() {
        drop(g);
    }
}
