//! Planted: a detached thread with no panic containment and no
//! justification.
use std::thread;

fn detach() {
    thread::spawn(|| {
        run_forever();
    });
}
