//! Planted: a condvar wait guarded by `if` misses spurious wakeups.
use std::sync::{Condvar, Mutex};

fn bad(cv: &Condvar, m: &Mutex<bool>) {
    let mut g = lock(m);
    if !*g {
        g = wait(cv, g);
    }
    let _ = g;
}
