//! unwrap() is allowed outside coordinator paths (sim/qat/search own
//! their panics; only serving workers strand clients).
fn take(x: Option<u32>) -> u32 {
    x.unwrap()
}
