//! Violations under #[cfg(test)] / #[test] items are out of scope:
//! tests may unwrap, subtract Instants, and poke raw locks on purpose.
pub fn production() -> u32 {
    1
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_may_do_anything() {
        let deadline = std::time::Instant::now();
        let now = std::time::Instant::now();
        let _ = deadline - now;
        let mut xs = vec![1.0f64, 0.5];
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let m = std::sync::Mutex::new(0u32);
        let _g = m.lock().unwrap();
    }
}
