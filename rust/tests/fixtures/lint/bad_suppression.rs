//! Planted: malformed annotations are themselves findings, and an
//! invalid suppression does not silence the underlying lint.
fn no_why(xs: &mut [f64]) {
    // lint:allow(float-total-cmp)
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
}

fn unknown_lint(xs: &mut [f64]) {
    // lint:allow(made-up-lint): this lint id does not exist
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
}

struct S {
    // lock-order: intake levle 1
    state: u32,
}

fn short_guard() {
    // spawn-guard: nope
    std::thread::spawn(run_once);
}
