//! Planted: raw lock/wait calls bypass the poison-recovering helpers.
use std::sync::{Condvar, Mutex};
use std::time::Duration;

fn bad_lock(m: &Mutex<u32>) -> u32 {
    let g = m.lock().unwrap();
    *g
}

fn bad_wait(cv: &Condvar, m: &Mutex<bool>) {
    let mut g = m.lock().unwrap();
    while !*g {
        g = cv.wait(g).unwrap();
    }
}

fn bad_wait_timeout(cv: &Condvar, m: &Mutex<bool>) {
    let g = m.lock().unwrap();
    let _ = cv.wait_timeout(g, Duration::from_millis(5)).unwrap();
}
