//! The helper file itself is exempt from raw-lock and condvar-loop:
//! it implements the poison policy the lints steer everyone toward.
use std::sync::{Condvar, Mutex, MutexGuard};

pub fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

pub fn wait<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    match cv.wait(g) {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}
