//! Planted: NaN-unsafe float comparator (the PR 4 worker-kill class).
fn sort_latencies(xs: &mut [f64]) {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
}
