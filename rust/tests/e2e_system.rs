//! End-to-end system tests: search → quantize → serve, composed.
//! Skips gracefully without artifacts.

use std::path::Path;
use std::time::Duration;

use dybit::coordinator::{Policy, Server, ServerConfig};
use dybit::formats::Format;
use dybit::qat::{QuantConfig, Session};
use dybit::runtime::{Executor, Manifest};
use dybit::search::{run_search, Strategy};
use dybit::sim::{HwConfig, Simulator};
use dybit::util::rng::Rng;

fn setup() -> Option<Manifest> {
    Manifest::load(Path::new("artifacts")).ok()
}

#[test]
fn search_then_simulate_confirms_speedup() {
    let Some(m) = setup() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let mut exec = Executor::new(&m.dir).unwrap();
    let mut session = Session::new(&m, "miniresnet18").unwrap();
    let weights = session.layer_weights();
    let acts = session.layer_acts(&mut exec, 3).unwrap();
    let sim = Simulator::new(HwConfig::zcu102(), session.model.layers.clone(), 1);

    let r = run_search(
        &sim,
        &weights,
        &acts,
        Format::DyBit,
        Strategy::SpeedupConstrained { alpha: 3.0 },
        3,
    );
    assert!(r.satisfied, "{r:?}");
    // the assignment converts into a runnable quant config
    let mut q = QuantConfig::from_assignment(Format::DyBit, &r.assignment);
    session.calibrate(&mut exec, &mut q, 11).unwrap();
    let ev = session.evaluate(&mut exec, &q, 2).unwrap();
    assert!(ev.loss.is_finite());
}

#[test]
fn server_round_trip_under_load() {
    let Some(m) = setup() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let nl = m.models["mlp"].n_quant_layers;
    let cfg = ServerConfig {
        model: "mlp".into(),
        qcfg: QuantConfig::uniform(nl, Format::DyBit, 4, 8),
        policy: Policy { max_batch: m.models["mlp"].batch, max_wait: Duration::from_millis(3) },
        queue_cap: 64,
        pallas: false,
        replicas: 2,
    };
    let img_elems: usize = m.models["mlp"].input.iter().skip(1).product();
    let server = Server::start(&m, cfg).unwrap();

    // mixed sync requests from two client threads
    std::thread::scope(|s| {
        for c in 0..2 {
            let server = &server;
            s.spawn(move || {
                let mut rng = Rng::new(c as u64 + 1);
                for _ in 0..8 {
                    let img = rng.normal_vec(img_elems);
                    let pred = server.infer(img).expect("inference ok");
                    assert!(pred < 10);
                }
            });
        }
    });
    let snap = server.shutdown().expect("clean shutdown");
    assert_eq!(snap.requests, 16);
    assert!(snap.batches >= 1);
    assert!(snap.lat_p50_ms > 0.0);
}

#[test]
fn rejects_wrong_image_size() {
    let Some(m) = setup() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let nl = m.models["mlp"].n_quant_layers;
    let cfg = ServerConfig {
        model: "mlp".into(),
        qcfg: QuantConfig::fp32(nl),
        policy: Policy::default(),
        queue_cap: 8,
        pallas: false,
        replicas: 1,
    };
    let server = Server::start(&m, cfg).unwrap();
    assert!(server.infer(vec![0.0; 3]).is_err());
    drop(server);
}
