//! Bit-exact cross-check of the rust format library against the python
//! mirror via `artifacts/formats_golden.json` (written by `make artifacts`).
//! This is the contract that keeps the two halves of the system from
//! drifting: every grid, every DyBit code table, and Table I itself.

use std::path::Path;

use dybit::formats::dybit as dybit_codec;
use dybit::formats::Format;
use dybit::util::json::{parse, Json};

fn golden() -> Option<Json> {
    let p = Path::new("artifacts/formats_golden.json");
    let text = std::fs::read_to_string(p).ok()?;
    Some(parse(&text).expect("golden json parses"))
}

#[test]
fn all_grids_match_python_bit_exactly() {
    let Some(g) = golden() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let grids = g.get("grids").and_then(Json::as_obj).expect("grids");
    let mut checked = 0;
    for (key, vals) in grids {
        let (name, bits) = key.split_at(key.len() - 1);
        let bits: u32 = bits.parse().expect("bits suffix");
        let fmt = Format::from_name(name).expect("format name");
        if !fmt.supports(bits) {
            continue;
        }
        let want = vals.as_f64_vec().expect("numeric grid");
        let got = fmt.grid(bits);
        assert_eq!(got, want, "grid mismatch for {key}");
        checked += 1;
    }
    assert!(checked >= 30, "only {checked} grids checked");
}

#[test]
fn dybit_code_tables_match_python() {
    let Some(g) = golden() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let codes = g.get("dybit_codes").and_then(Json::as_obj).expect("codes");
    for (n, vals) in codes {
        let n: u32 = n.parse().unwrap();
        let want = vals.as_f64_vec().unwrap();
        for (c, &v) in want.iter().enumerate() {
            assert_eq!(
                dybit_codec::decode(c as u8, n),
                v,
                "dybit{n} code {c:#b} decode mismatch"
            );
        }
    }
}

#[test]
fn table1_matches_python_and_paper() {
    let Some(g) = golden() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let want = g
        .get("table1_unsigned4")
        .and_then(Json::as_f64_vec)
        .expect("table1");
    assert_eq!(dybit_codec::grid_unsigned(4), want);
    // and the paper's literal values once more, end to end
    assert_eq!(
        want,
        vec![0.0, 0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.875, 1.0, 1.25,
             1.5, 1.75, 2.0, 3.0, 4.0, 8.0]
    );
}
