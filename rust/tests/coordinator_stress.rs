//! Seeded concurrency stress suite for the intake queues (DESIGN.md
//! §11) — the correctness oracle behind the §11 `ShardedIntake`
//! rewrite.
//!
//! A seeded workload generator drives mixed push / pop / steal /
//! escalate / shutdown interleavings across 4–64 shards.  Thread
//! scheduling is of course nondeterministic, but the *workload* —
//! item ids, min-bits tags, escalation decisions, queue shapes — is
//! reproducible per seed, and every invariant is checked post-hoc over
//! the recorded trace, so a failure names the seed that produced it and
//! the violated invariant:
//!
//! 1. **Conservation** — every item whose push returned `Ok` is
//!    consumed exactly once (no lost, no duplicated items).
//! 2. **Owner FIFO** — per shard, the owner's non-stolen consumption of
//!    its dedicated pusher's items is in push order (tail stealing and
//!    interleaved escalation pushes must never reorder a replica's own
//!    FIFO).
//! 3. **Steal gate** — every stolen item satisfies
//!    `floor_bits[thief] >= item.min_bits`.
//! 4. **Shutdown** — `close()` with full queues and blocked pushers
//!    deadlocks nobody (a watchdog converts a hang into a failure) and
//!    drains every accepted item before poppers see `Closed`.
//! 5. **Accounting** — a live [`Metrics`] sink fed by the poppers ends
//!    with `requests + escalations + deadline_drops == consumed`,
//!    per-replica sums equal to the globals, and a zero queue-depth
//!    gauge.
//! 6. **Deadline-drop conservation** (§12, `overload` mode) — a seeded
//!    subset of items is pushed with an already-expired deadline and a
//!    seeded subset of pushes goes through the non-blocking `try_push`:
//!    every expired item must be consumed exactly once *as a drop*
//!    (never served), every live item served (never dropped), and every
//!    `try_push` refusal counted in `rejected` — the four-bucket
//!    accounting invariant under forced overload.
//! 7. **Restart/failover conservation** (§13, chaos mode) — a seeded
//!    kill schedule flaps one popper (dies mid-run, a replacement
//!    resumes its shard) and retires another for good (shard closed,
//!    backlog drained and re-homed through bounded `push_timeout`s):
//!    every re-homed item is consumed exactly once, never by the
//!    retired shard, and only by a shard whose floor honors its
//!    (clamped) `min_bits` tag — while the flapped shard's owner FIFO
//!    holds *across* the incarnation change.
//! 8. **Partial-sum ticket conservation** (§15, refinement mode) — the
//!    fast tiers park partials in the REAL [`PlaneCache`] on every
//!    escalation and the escalated item carries the ticket; after a
//!    seeded fast replica is superseded (incarnation bump), its parked
//!    tickets must be re-run, never refined; every other ticket is
//!    refined exactly once; and the cache is empty after the drain (no
//!    leaked entries).
//!
//! The harness runs against BOTH implementations: the pre-§11
//! [`CoarseIntake`] certifies the harness (if the reference fails, the
//! harness is wrong), then the §11 [`ShardedIntake`] must pass the same
//! sweep.  `checker_detects_planted_violations` certifies the oracle
//! itself against hand-corrupted traces.
//!
//! Tier-1 runs a small seed set so CI always exercises the
//! interleavings; `ci.sh --stress` sets `STRESS_FULL=1` for the full
//! ≥8-seed × {4, 16, 64}-shard sweep.  `STRESS_SEEDS=a,b,c` overrides
//! the seed list.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use dybit::coordinator::{Assembled, CoarseIntake, IntakeQueue, Item, Metrics, PlaneCache,
                         PlanePartial, Policy, PushRefused, Request, ShardedIntake};
use dybit::util::rng::Rng;

// ---------------------------------------------------------------------
// Probe ids: gen(8 bits) | src(8 bits) | seq(48 bits)
// ---------------------------------------------------------------------

fn pid(gen: u64, src: usize, seq: u64) -> u64 {
    assert!(src < 256 && seq < 1 << 48);
    gen << 56 | (src as u64) << 48 | seq
}

fn gen_of(id: u64) -> u64 {
    id >> 56
}

fn src_of(id: u64) -> usize {
    (id >> 48 & 0xFF) as usize
}

fn seq_of(id: u64) -> u64 {
    id & 0xFFFF_FFFF_FFFF
}

/// One consumption record, in per-popper consumption order.
#[derive(Clone, Copy, Debug)]
struct Consumed {
    id: u64,
    stolen: bool,
    min_bits: u32,
    /// The popper observed an expired deadline and dropped the item
    /// instead of serving it (§12).
    dropped: bool,
}

/// Deterministic per-item coin for the escalation decision (splitmix64
/// finalizer over id ⊕ seed, so the workload is seed-reproducible
/// regardless of which popper sees the item).
fn escalates(id: u64, seed: u64) -> bool {
    let mut x = id ^ seed.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    x = (x ^ x >> 30).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ x >> 27).wrapping_mul(0x94D0_49BB_1331_11EB);
    (x ^ x >> 31) % 10 == 0
}

/// Deterministic per-item coin for the overload mode's expired-deadline
/// tag (differently salted than [`escalates`] so the two subsets are
/// independent).
fn expires(id: u64, seed: u64) -> bool {
    let mut x = id ^ seed.wrapping_mul(0xD134_2543_DE82_EF95);
    x = (x ^ x >> 30).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ x >> 27).wrapping_mul(0x94D0_49BB_1331_11EB);
    (x ^ x >> 31) % 5 == 0
}

// ---------------------------------------------------------------------
// Post-hoc invariant checker (the oracle; certified below)
// ---------------------------------------------------------------------

/// Check conservation, owner FIFO, the steal gate, and deadline-drop
/// conservation over a recorded trace.  `consumed_by[s]` is popper
/// `s`'s consumption in order; `expired` is the set of ids pushed with
/// an already-expired deadline — each must be consumed exactly once *as
/// a drop*, and no live item may be dropped.
fn check_invariants(floors: &[u32], pushed_ok: &[u64], consumed_by: &[Vec<Consumed>],
                    expired: &HashSet<u64>) -> Result<(), String> {
    let pushed: HashSet<u64> = pushed_ok.iter().copied().collect();
    if pushed.len() != pushed_ok.len() {
        return Err("harness bug: duplicate pushed ids".into());
    }
    let mut seen: HashSet<u64> = HashSet::with_capacity(pushed.len());
    for (s, trace) in consumed_by.iter().enumerate() {
        let mut last_seq: Option<u64> = None;
        for c in trace {
            if !pushed.contains(&c.id) {
                return Err(format!("popper {s} consumed id {:#x} that was never pushed", c.id));
            }
            if !seen.insert(c.id) {
                return Err(format!("id {:#x} consumed twice (second time by popper {s})", c.id));
            }
            if c.dropped && !expired.contains(&c.id) {
                return Err(format!(
                    "id {:#x} dropped without an expired deadline (popper {s})",
                    c.id
                ));
            }
            if !c.dropped && expired.contains(&c.id) {
                return Err(format!(
                    "id {:#x} served instead of dropped: its deadline expired before push",
                    c.id
                ));
            }
            if c.stolen && floors[s] < c.min_bits {
                return Err(format!(
                    "steal gate violated: popper {s} (floor {}) stole id {:#x} with min_bits {}",
                    floors[s], c.id, c.min_bits
                ));
            }
            // owner FIFO over the dedicated pusher's (gen 0) items; the
            // interleaved escalation pushes (gen 1) are separate ids
            if !c.stolen && gen_of(c.id) == 0 && src_of(c.id) == s {
                let seq = seq_of(c.id);
                if let Some(prev) = last_seq {
                    if seq <= prev {
                        return Err(format!(
                            "owner FIFO violated on shard {s}: seq {seq} after {prev}"
                        ));
                    }
                }
                last_seq = Some(seq);
            }
        }
    }
    if seen.len() != pushed.len() {
        return Err(format!("{} item(s) lost (pushed Ok, never consumed)", pushed.len() - seen.len()));
    }
    Ok(())
}

/// §13 oracle extension: restart/failover conservation over a recorded
/// trace.  `rehomed` maps each drained-and-re-pushed id to its
/// (post-clamp) `min_bits`; `retired` names the shards whose backlog
/// was failed over.  Each re-homed item must be consumed exactly once,
/// never by a retired shard, and only by a shard whose floor covers the
/// tag — the same gate [`rehome_items`] enforces in the server.
fn check_selfheal_invariants(floors: &[u32], consumed_by: &[Vec<Consumed>],
                             rehomed: &HashMap<u64, u32>, retired: &HashSet<usize>)
                             -> Result<(), String> {
    let mut seen: HashSet<u64> = HashSet::with_capacity(rehomed.len());
    for (s, trace) in consumed_by.iter().enumerate() {
        for c in trace {
            let Some(&bits) = rehomed.get(&c.id) else { continue };
            if retired.contains(&s) {
                return Err(format!(
                    "failover conservation violated: retired shard {s} consumed \
                     re-homed id {:#x}",
                    c.id
                ));
            }
            if floors[s] < bits {
                return Err(format!(
                    "failover gate violated: shard {s} (floor {}) consumed re-homed \
                     id {:#x} tagged min_bits {bits}",
                    floors[s], c.id
                ));
            }
            if !seen.insert(c.id) {
                return Err(format!("re-homed id {:#x} consumed twice", c.id));
            }
        }
    }
    if seen.len() != rehomed.len() {
        return Err(format!(
            "{} re-homed item(s) lost after the failover drain",
            rehomed.len() - seen.len()
        ));
    }
    Ok(())
}

/// §15 oracle extension (invariant 8): partial-sum ticket conservation
/// over a recorded refinement trace.  `inserts` maps every cache ticket
/// to the `(source, incarnation)` that parked it; `refined` lists each
/// refined reply with the provenance of the entry it consumed;
/// `superseded` names `(replica, incarnation)` pairs fenced off by a
/// respawn before the drain; `leaked` is the cache population after the
/// drain.  A refined reply must consume a real ticket, with its true
/// provenance, at most once, never from a superseded incarnation — and
/// the drain must leave the cache empty (every ticket taken by its
/// consumer or reclaimed on a terminal path).
fn check_refinement_invariants(inserts: &HashMap<u64, (usize, u64)>,
                               refined: &[(u64, usize, u64)],
                               superseded: &HashSet<(usize, u64)>, leaked: usize)
                               -> Result<(), String> {
    let mut seen: HashSet<u64> = HashSet::with_capacity(refined.len());
    for &(ticket, source, inc) in refined {
        let Some(&(src, i)) = inserts.get(&ticket) else {
            return Err(format!("refined reply from ticket {ticket} that was never inserted"));
        };
        if (src, i) != (source, inc) {
            return Err(format!(
                "ticket {ticket} refined with forged provenance: claims replica {source} \
                 incarnation {inc}, was parked by replica {src} incarnation {i}"
            ));
        }
        if !seen.insert(ticket) {
            return Err(format!("ticket {ticket} refined twice"));
        }
        if superseded.contains(&(source, inc)) {
            return Err(format!(
                "stale refinement: ticket {ticket} used planes from superseded \
                 incarnation {inc} of replica {source}"
            ));
        }
    }
    if leaked != 0 {
        return Err(format!("{leaked} cache entry(ies) leaked past the drain"));
    }
    Ok(())
}

// ---------------------------------------------------------------------
// The seeded workload
// ---------------------------------------------------------------------

#[derive(Clone, Copy, Debug)]
struct StressCfg {
    shards: usize,
    cap: usize,
    per_pusher: u64,
    seed: u64,
    /// Close mid-flight with blocked pushers (tiny caps) instead of
    /// after the pushers finish.
    close_early: bool,
    /// §12 overload mode: poppers simulate slow batches, a seeded ~25%
    /// of pushes go through the non-blocking `try_push` (refusals
    /// counted in `rejected`), and a seeded ~20% of items carry an
    /// already-expired deadline the poppers must drop, never serve.
    overload: bool,
}

/// Heterogeneous floors with at least one accurate (8-bit) tier, like
/// the serve pools: every 4th replica floors at 8, the rest at 4.
fn floors(n: usize) -> Vec<u32> {
    (0..n).map(|i| if i % 4 == 3 || n < 4 { 8 } else { 4 }).collect()
}

fn probe_item(id: u64, min_bits: u32, escalated: bool) -> Item<u64, u64> {
    let (tx, _rx) = mpsc::channel();
    let mut it = Item::new(Request { payload: id, enqueued: Instant::now(), respond: tx });
    it.min_bits = min_bits;
    it.escalated = escalated;
    it
}

/// One full run: a dedicated pusher and popper per shard, escalation
/// re-pushes to the accurate tier, close, drain, then every invariant.
fn stress_once<I: IntakeQueue<u64, u64>>(q: &I, cfg: StressCfg) {
    let floors = floors(cfg.shards);
    let esc_target = (0..cfg.shards).rev().find(|&s| floors[s] == 8).unwrap();
    let metrics = Metrics::new(cfg.shards);
    let esc_seq = AtomicU64::new(0);
    let policy = Policy { max_batch: 4, max_wait: Duration::from_micros(200) };

    let (pushed, consumed, refused) = thread::scope(|scope| {
        // -- dedicated pushers: one per shard so owner FIFO is assertable
        let mut pushers = Vec::new();
        for s in 0..cfg.shards {
            let (q, metrics, floors) = (&q, &metrics, &floors);
            pushers.push(scope.spawn(move || {
                let mut rng = Rng::new(cfg.seed ^ (s as u64).wrapping_mul(0x9E37_79B9));
                let mut ok = Vec::new();
                let mut refused = 0u64;
                for seq in 0..cfg.per_pusher {
                    // ~30% of items carry the shard's own floor as an
                    // accuracy tag (what the router would do), gating
                    // who may steal them
                    let bits = if rng.below(10) < 3 { floors[s] } else { 0 };
                    let id = pid(0, s, seq);
                    let mut it = probe_item(id, bits, false);
                    // overload: a seeded subset arrives already expired
                    // (push-time deadline ⇒ any later pop observes it
                    // expired — deterministically droppable)
                    if cfg.overload && expires(id, cfg.seed) {
                        it.deadline = Some(Instant::now());
                    }
                    // overload: a seeded subset of pushes is admission-
                    // style (non-blocking); a Full refusal is counted
                    // like the server's Reject::QueueFull
                    if cfg.overload && rng.below(4) == 0 {
                        match q.try_push(s, it) {
                            Ok(()) => {
                                metrics.queue_push();
                                ok.push(id);
                            }
                            Err(PushRefused::Full(_)) => {
                                metrics.record_rejected();
                                refused += 1;
                            }
                            Err(PushRefused::Closed(_)) => break,
                        }
                    } else {
                        match q.push(s, it) {
                            Ok(()) => {
                                metrics.queue_push();
                                ok.push(id);
                            }
                            Err(_) => break, // closed under us (close_early)
                        }
                    }
                }
                (ok, refused)
            }));
        }

        // -- poppers: one per shard (the intake contract), recording
        //    every consumption and escalating a seeded ~10% of untagged
        //    first-run items from the fast tiers
        let mut poppers = Vec::new();
        for s in 0..cfg.shards {
            let (q, metrics, floors, esc_seq) = (&q, &metrics, &floors, &esc_seq);
            poppers.push(scope.spawn(move || {
                let mut trace: Vec<Consumed> = Vec::new();
                let mut esc_pushed: Vec<u64> = Vec::new();
                loop {
                    let batch = match q.pop_batch(s, policy) {
                        Assembled::Batch(b) => b,
                        Assembled::Closed => return (trace, esc_pushed),
                    };
                    metrics.queue_pop(batch.len());
                    let n = batch.len();
                    let stolen_n = batch.iter().filter(|i| i.stolen).count();
                    if stolen_n > 0 {
                        metrics.record_stolen(s, stolen_n);
                    }
                    let mut answered = 0;
                    let mut dropped_n = 0;
                    for it in batch {
                        let id = it.req.payload;
                        // §12: an expired deadline is observed at
                        // assembly and the item is dropped, never
                        // served or escalated
                        let dropped = it.deadline.map_or(false, |d| Instant::now() >= d);
                        trace.push(Consumed {
                            id,
                            stolen: it.stolen,
                            min_bits: it.min_bits,
                            dropped,
                        });
                        if dropped {
                            metrics.record_deadline_drops(s, 1);
                            dropped_n += 1;
                            continue;
                        }
                        // escalate strictly up (fast tier → accurate
                        // tier, never back), mirroring the server: an
                        // acyclic hand-off graph cannot deadlock on the
                        // bounded blocking pushes
                        let esc = !it.escalated
                            && floors[s] < 8
                            && it.min_bits == 0
                            && escalates(id, cfg.seed);
                        if esc {
                            let nid = pid(1, s, esc_seq.fetch_add(1, Ordering::Relaxed));
                            match q.push(esc_target, probe_item(nid, 8, true)) {
                                Ok(()) => {
                                    metrics.queue_push();
                                    metrics.record_escalated(s, 1);
                                    esc_pushed.push(nid);
                                }
                                // closed: answer directly instead of
                                // re-running, like the server does
                                Err(_) => answered += 1,
                            }
                        } else {
                            answered += 1;
                        }
                    }
                    if n > dropped_n {
                        metrics.record_batch_answered(s, n - dropped_n, answered, 1e-4, 0);
                    }
                    // overload mode: a slow simulated batch, so the
                    // bounded queues stay full and try_push refusals
                    // actually happen
                    if cfg.overload {
                        thread::sleep(Duration::from_micros(500));
                    }
                }
            }));
        }

        if cfg.close_early {
            thread::sleep(Duration::from_millis(15));
            q.close();
        }
        let mut pushed: Vec<u64> = Vec::new();
        let mut refused = 0u64;
        for h in pushers {
            let (ok, r) = h.join().expect("pusher panicked");
            pushed.extend(ok);
            refused += r;
        }
        if !cfg.close_early {
            q.close();
        }
        let mut consumed: Vec<Vec<Consumed>> = Vec::new();
        for h in poppers {
            let (trace, esc) = h.join().expect("popper panicked");
            pushed.extend(esc);
            consumed.push(trace);
        }
        (pushed, consumed, refused)
    });

    let label = format!(
        "seed {} shards {} close_early {} overload {}",
        cfg.seed, cfg.shards, cfg.close_early, cfg.overload
    );
    // which accepted items must be dropped is a pure function of the
    // id + seed (the pushers tag exactly these), so the oracle can
    // recompute the expected set post-hoc
    let expired: HashSet<u64> = match cfg.overload {
        true => pushed
            .iter()
            .copied()
            .filter(|&id| gen_of(id) == 0 && expires(id, cfg.seed))
            .collect(),
        false => HashSet::new(),
    };
    if let Err(e) = check_invariants(&floors, &pushed, &consumed, &expired) {
        panic!("[{label}] invariant violated: {e}");
    }
    assert_eq!(q.len(), 0, "[{label}] intake not drained");
    assert!(matches!(q.pop_batch(0, policy), Assembled::Closed));

    // exact accounting over the live sink the poppers fed: the §12
    // four-bucket split of every consumed item
    let total: u64 = consumed.iter().map(|t| t.len() as u64).sum();
    let snap = metrics.snapshot(1.0);
    assert_eq!(
        snap.requests + snap.escalations + snap.deadline_drops,
        total,
        "[{label}] answered + escalated-away + deadline-dropped"
    );
    assert_eq!(snap.rejected, refused, "[{label}] every try_push refusal counts as rejected");
    assert_eq!(snap.queue_depth, 0, "[{label}] queue gauge must return to zero");
    let per_req: u64 = snap.per_replica.iter().map(|r| r.requests).sum();
    let per_esc: u64 = snap.per_replica.iter().map(|r| r.escalations).sum();
    let per_stolen: u64 = snap.per_replica.iter().map(|r| r.stolen).sum();
    let per_drop: u64 = snap.per_replica.iter().map(|r| r.deadline_drops).sum();
    assert_eq!(per_req, snap.requests, "[{label}] per-replica requests sum");
    assert_eq!(per_esc, snap.escalations, "[{label}] per-replica escalations sum");
    assert_eq!(per_drop, snap.deadline_drops, "[{label}] per-replica deadline-drop sum");
    let stolen_total: u64 =
        consumed.iter().map(|t| t.iter().filter(|c| c.stolen).count() as u64).sum();
    assert_eq!(per_stolen, stolen_total, "[{label}] stolen counter");
    if cfg.overload {
        assert_eq!(
            snap.deadline_drops,
            expired.len() as u64,
            "[{label}] every accepted expired item is dropped exactly once"
        );
        if !cfg.close_early {
            // the scenario must actually exercise both §12 paths: a
            // cap-2 queue against slow poppers has to refuse some
            // try_pushes, and the ~20% expired coin has to land
            assert!(!expired.is_empty(), "[{label}] no expired items were pushed");
            assert!(refused > 0, "[{label}] overload never refused a try_push");
        }
    }
}

/// Run `f` under a watchdog: a hang (deadlock, lost wakeup) becomes a
/// named failure instead of a CI timeout with no diagnostics.
fn with_watchdog(label: &str, limit: Duration, f: impl FnOnce() + Send + 'static) {
    let (tx, rx) = mpsc::channel();
    let h = thread::spawn(move || {
        f();
        let _ = tx.send(());
    });
    match rx.recv_timeout(limit) {
        // Ok = finished; Disconnected = panicked — join() propagates it
        Ok(()) | Err(mpsc::RecvTimeoutError::Disconnected) => {
            if let Err(p) = h.join() {
                std::panic::resume_unwind(p);
            }
        }
        Err(mpsc::RecvTimeoutError::Timeout) => {
            panic!("[{label}] deadlock suspected: no completion within {limit:?}")
        }
    }
}

fn seed_list(default: &[u64]) -> Vec<u64> {
    match std::env::var("STRESS_SEEDS") {
        Ok(s) => s
            .split(',')
            .filter(|t| !t.trim().is_empty())
            .map(|t| t.trim().parse().expect("STRESS_SEEDS: comma-separated u64s"))
            .collect(),
        Err(_) => default.to_vec(),
    }
}

fn sweep<I: IntakeQueue<u64, u64> + 'static>(
    name: &'static str,
    make: fn(usize, Vec<u32>, bool) -> I,
    seeds: &[u64],
    shard_counts: &[usize],
) {
    for &seed in seeds {
        for &shards in shard_counts {
            let per_pusher = (2000 / shards as u64).max(40);
            for close_early in [false, true] {
                let cfg = StressCfg {
                    shards,
                    cap: 4,
                    per_pusher,
                    seed,
                    close_early,
                    overload: false,
                };
                let label = format!("{name} seed {seed} shards {shards} early {close_early}");
                with_watchdog(&label, Duration::from_secs(60), move || {
                    let q = make(cfg.cap, floors(cfg.shards), true);
                    stress_once(&q, cfg);
                });
            }
        }
    }
}

// ---------------------------------------------------------------------
// Tier-1: small seed set, both implementations
// ---------------------------------------------------------------------

/// The §11 intake under the default CI sweep.
#[test]
fn stress_sharded_intake_small_sweep() {
    let seeds = seed_list(&[1, 2, 3]);
    sweep("sharded", ShardedIntake::<u64, u64>::new, &seeds, &[4, 16]);
}

/// The pre-§11 reference under the same sweep — this run certifies the
/// harness: the coarse intake's single-lock implementation is trivially
/// linearizable, so a failure here indicts the harness, not the queue.
#[test]
fn stress_coarse_intake_certifies_harness() {
    let seeds = seed_list(&[1, 2, 3]);
    sweep("coarse", CoarseIntake::<u64, u64>::new, &seeds, &[4, 16]);
}

/// Full-queue shutdown: capacity 1, pushers blocked on backpressure
/// when `close()` lands.  Every `Ok` push must still be served.
#[test]
fn stress_shutdown_with_blocked_pushers() {
    for seed in seed_list(&[7, 8]) {
        for shards in [4usize, 8] {
            let cfg = StressCfg {
                shards,
                cap: 1,
                per_pusher: 1 << 40,
                seed,
                close_early: true,
                overload: false,
            };
            with_watchdog(&format!("tiny-cap sharded seed {seed}"), Duration::from_secs(60),
                          move || {
                let q = ShardedIntake::new(cfg.cap, floors(cfg.shards), true);
                stress_once(&q, cfg);
            });
            with_watchdog(&format!("tiny-cap coarse seed {seed}"), Duration::from_secs(60),
                          move || {
                let q = CoarseIntake::new(cfg.cap, floors(cfg.shards), true);
                stress_once(&q, cfg);
            });
        }
    }
}

/// §12 overload scenario: tiny caps, slow poppers, seeded `try_push`
/// admission and seeded expired deadlines — the deadline-drop
/// conservation oracle (invariant 6) plus the four-bucket accounting,
/// on BOTH intakes.
#[test]
fn stress_overload_admission_drop_conservation() {
    for seed in seed_list(&[21, 22]) {
        for shards in [4usize, 8] {
            let cfg = StressCfg {
                shards,
                cap: 2,
                per_pusher: 200,
                seed,
                close_early: false,
                overload: true,
            };
            with_watchdog(&format!("overload sharded seed {seed} shards {shards}"),
                          Duration::from_secs(60), move || {
                let q = ShardedIntake::new(cfg.cap, floors(cfg.shards), true);
                stress_once(&q, cfg);
            });
            with_watchdog(&format!("overload coarse seed {seed} shards {shards}"),
                          Duration::from_secs(60), move || {
                let q = CoarseIntake::new(cfg.cap, floors(cfg.shards), true);
                stress_once(&q, cfg);
            });
        }
    }
}

// ---------------------------------------------------------------------
// §13 chaos mode: seeded kill / flap / retire over the intake, with the
// restart/failover conservation oracle
// ---------------------------------------------------------------------

/// One chaos run (invariant 7).  A seeded kill plan takes two shards:
///
/// * the **flap** shard's popper dies after a seeded number of
///   consumptions and a replacement popper resumes the same shard
///   (sequentially, so the §11 one-popper contract holds) — its owner
///   FIFO must survive the incarnation change;
/// * the **retire** shard's popper dies for good: the shard is closed,
///   its backlog drained, and every drained item re-homed onto a live
///   floor-compatible shard through bounded `push_timeout`s, clamping
///   an unsatisfiable tag to the best live floor exactly like the
///   server's `rehome_items`.
///
/// On odd seeds the retired shard is the accurate (8-bit) escalation
/// target itself — escalation pushes then bounce off the closed shard
/// and resolve as direct answers, and for 4-shard pools the drained
/// 8-bit tags must clamp down to the fast tier (the ladder-exhausted
/// failover path).
fn stress_chaos_once<I: IntakeQueue<u64, u64>>(q: &I, cfg: StressCfg) {
    let floors = floors(cfg.shards);
    let esc_target = (0..cfg.shards).rev().find(|&s| floors[s] == 8).unwrap();
    let retire = if cfg.seed % 2 == 1 { esc_target } else { 0 };
    let flap = (0..cfg.shards)
        .find(|&s| s != retire && s != esc_target)
        .expect("chaos mode needs >= 3 shards");
    let kill_after = 10 + (cfg.seed % 20) as usize;
    let metrics = Metrics::new(cfg.shards);
    let esc_seq = AtomicU64::new(0);
    let policy = Policy { max_batch: 4, max_wait: Duration::from_micros(200) };

    let (pushed, consumed, rehomed) = thread::scope(|scope| {
        let mut pushers = Vec::new();
        for s in 0..cfg.shards {
            let (q, metrics, floors) = (&q, &metrics, &floors);
            pushers.push(scope.spawn(move || {
                let mut rng = Rng::new(cfg.seed ^ (s as u64).wrapping_mul(0x9E37_79B9));
                let mut ok = Vec::new();
                for seq in 0..cfg.per_pusher {
                    let bits = if rng.below(10) < 3 { floors[s] } else { 0 };
                    let it = probe_item(pid(0, s, seq), bits, false);
                    match q.push(s, it) {
                        Ok(()) => {
                            metrics.queue_push();
                            ok.push(pid(0, s, seq));
                        }
                        Err(_) => break, // shard closed by the retirement
                    }
                }
                ok
            }));
        }

        // poppers; `limit` = consumptions before this incarnation dies
        let run_popper = &|s: usize, limit: usize| -> (Vec<Consumed>, Vec<u64>) {
            let mut trace: Vec<Consumed> = Vec::new();
            let mut esc_pushed: Vec<u64> = Vec::new();
            while trace.len() < limit {
                let batch = match q.pop_batch(s, policy) {
                    Assembled::Batch(b) => b,
                    Assembled::Closed => break,
                };
                metrics.queue_pop(batch.len());
                let stolen_n = batch.iter().filter(|i| i.stolen).count();
                if stolen_n > 0 {
                    metrics.record_stolen(s, stolen_n);
                }
                let n = batch.len();
                let mut answered = 0;
                for it in batch {
                    let id = it.req.payload;
                    trace.push(Consumed {
                        id,
                        stolen: it.stolen,
                        min_bits: it.min_bits,
                        dropped: false,
                    });
                    let esc = !it.escalated
                        && floors[s] < 8
                        && it.min_bits == 0
                        && escalates(id, cfg.seed);
                    if esc {
                        let nid = pid(1, s, esc_seq.fetch_add(1, Ordering::Relaxed));
                        match q.push(esc_target, probe_item(nid, 8, true)) {
                            Ok(()) => {
                                metrics.queue_push();
                                metrics.record_escalated(s, 1);
                                esc_pushed.push(nid);
                            }
                            // the accurate shard is closed (retired):
                            // answer directly, like the server's
                            // exhausted-ladder failover
                            Err(_) => answered += 1,
                        }
                    } else {
                        answered += 1;
                    }
                }
                metrics.record_batch_answered(s, n, answered, 1e-4, 0);
            }
            (trace, esc_pushed)
        };
        let mut handles: Vec<Option<thread::ScopedJoinHandle<'_, _>>> = (0..cfg.shards)
            .map(|s| {
                let limit =
                    if s == retire || s == flap { kill_after } else { usize::MAX };
                Some(scope.spawn(move || run_popper(s, limit)))
            })
            .collect();

        // -- supervisor script, deterministic order.  Retire FIRST: if
        //    the retired shard is the escalation target, live poppers
        //    may be blocked pushing into it — close_shard is what wakes
        //    and refuses them, so it must not wait behind the flap join.
        let (retire_trace, retire_esc) =
            handles[retire].take().unwrap().join().expect("retired popper panicked");
        q.close_shard(retire);
        let drained = q.drain_shard(retire);
        let mut rehomed: HashMap<u64, u32> = HashMap::new();
        for mut it in drained {
            let mut targets: Vec<usize> = (0..cfg.shards)
                .filter(|&t| t != retire && floors[t] >= it.min_bits)
                .collect();
            if targets.is_empty() {
                let best =
                    (0..cfg.shards).filter(|&t| t != retire).map(|t| floors[t]).max();
                it.min_bits = it.min_bits.min(best.unwrap_or(0));
                targets = (0..cfg.shards)
                    .filter(|&t| t != retire && floors[t] >= it.min_bits)
                    .collect();
            }
            targets.sort_by_key(|&t| q.shard_len(t));
            let (id, bits) = (it.req.payload, it.min_bits);
            // live poppers keep draining, so cycling the bounded pushes
            // terminates; a true wedge is caught by the test watchdog
            let mut holding = Some(it);
            'land: loop {
                for &t in &targets {
                    let item = holding.take().expect("held item");
                    match q.push_timeout(t, item, Duration::from_millis(25)) {
                        Ok(()) => break 'land,
                        Err(PushRefused::Full(b)) | Err(PushRefused::Closed(b)) => {
                            holding = Some(b);
                        }
                    }
                }
            }
            rehomed.insert(id, bits);
        }

        // -- flap: reap the dead incarnation, resume the shard
        let (flap_trace1, flap_esc1) =
            handles[flap].take().unwrap().join().expect("flapped popper panicked");
        let respawn = scope.spawn(move || run_popper(flap, usize::MAX));

        let mut pushed: Vec<u64> = Vec::new();
        for h in pushers {
            pushed.extend(h.join().expect("pusher panicked"));
        }
        q.close();
        let (flap_trace2, flap_esc2) =
            respawn.join().expect("respawned popper panicked");
        let mut consumed: Vec<Vec<Consumed>> = Vec::new();
        for (s, h) in handles.into_iter().enumerate() {
            let (mut trace, esc) = match h {
                Some(h) => h.join().expect("popper panicked"),
                None if s == retire => (retire_trace.clone(), retire_esc.clone()),
                None => (flap_trace1.clone(), flap_esc1.clone()),
            };
            if s == flap {
                // both incarnations in order: owner FIFO must hold
                // *across* the restart, so the merged trace feeds the
                // same per-shard check as an unbroken popper's would
                trace.extend(flap_trace2.iter().copied());
                pushed.extend(flap_esc2.iter().copied());
            }
            pushed.extend(esc);
            consumed.push(trace);
        }
        (pushed, consumed, rehomed)
    });

    let label = format!("chaos seed {} shards {} retire {retire} flap {flap}", cfg.seed,
                        cfg.shards);
    let retired: HashSet<usize> = [retire].into_iter().collect();
    if let Err(e) = check_invariants(&floors, &pushed, &consumed, &HashSet::new()) {
        panic!("[{label}] invariant violated: {e}");
    }
    if let Err(e) = check_selfheal_invariants(&floors, &consumed, &rehomed, &retired) {
        panic!("[{label}] self-heal invariant violated: {e}");
    }
    assert_eq!(q.len(), 0, "[{label}] intake not drained");
    let total: u64 = consumed.iter().map(|t| t.len() as u64).sum();
    let snap = metrics.snapshot(1.0);
    assert_eq!(
        snap.requests + snap.escalations,
        total,
        "[{label}] answered + escalated-away must cover every consumption"
    );
    assert_eq!(snap.queue_depth, 0, "[{label}] queue gauge must return to zero");
}

/// Tier-1 chaos sweep on both intakes (the coarse run certifies the
/// chaos harness like it certifies the base one).
#[test]
fn stress_chaos_kill_flap_and_failover() {
    for seed in seed_list(&[31, 32]) {
        for shards in [4usize, 8] {
            let cfg = StressCfg {
                shards,
                cap: 4,
                per_pusher: 300,
                seed,
                close_early: false,
                overload: false,
            };
            with_watchdog(&format!("chaos sharded seed {seed} shards {shards}"),
                          Duration::from_secs(60), move || {
                let q = ShardedIntake::new(cfg.cap, floors(cfg.shards), true);
                stress_chaos_once(&q, cfg);
            });
            with_watchdog(&format!("chaos coarse seed {seed} shards {shards}"),
                          Duration::from_secs(60), move || {
                let q = CoarseIntake::new(cfg.cap, floors(cfg.shards), true);
                stress_chaos_once(&q, cfg);
            });
        }
    }
}

// ---------------------------------------------------------------------
// §15 refinement mode: ticket conservation + incarnation fencing over
// the REAL PlaneCache (invariant 8)
// ---------------------------------------------------------------------

/// One refinement run, escalation-heavy by construction.
///
/// **Phase 1** — concurrent pushers and fast-tier poppers (all at
/// incarnation 1): every seeded escalation parks a [`PlanePartial`] in
/// a real [`PlaneCache`], tags the escalated item with the returned
/// ticket, and pushes it onto the accurate shard (whose popper is not
/// running yet, so the backlog holds every in-flight ticket at once —
/// the worst case for leaks and eviction).
///
/// **The fence** — after phase 1 joins, one seeded fast replica is
/// superseded: its incarnation bumps, exactly like a §13 respawn, so
/// every ticket its dead incarnation parked is now refuse.
///
/// **Phase 2** — the accurate popper drains the escalation backlog: it
/// takes each item's ticket unconditionally (the server's contract),
/// refines when the entry's source incarnation is still current, and
/// falls back to a full re-run when it is not.  The §15 oracle then
/// checks the trace, and the cache must come out empty.
fn stress_refinement_once(shards: usize, per_pusher: u64, seed: u64) {
    let floors = floors(shards);
    let esc_target = (0..shards).rev().find(|&s| floors[s] == 8).unwrap();
    let flap = (0..shards).find(|&s| floors[s] < 8).expect("refinement mode needs a fast tier");
    // the accurate shard must hold every escalation while its popper
    // waits out phase 1; the cache is sized the same way the server
    // sizes it (queue capacity × replicas ⇒ no eviction in flight)
    let cap = shards * per_pusher as usize;
    // stealing off: every escalated item lands on the accurate shard
    // and nowhere else, so each ticket's terminal consumer is known
    let q = ShardedIntake::<u64, u64>::new(cap, floors.clone(), false);
    let cache = PlaneCache::new(cap);
    let inc_table: Vec<AtomicU64> = (0..shards).map(|_| AtomicU64::new(1)).collect();
    let metrics = Metrics::new(shards);
    let esc_seq = AtomicU64::new(0);
    let policy = Policy { max_batch: 4, max_wait: Duration::from_micros(200) };
    let partial = || PlanePartial { bits: 4, dots: vec![0], a_int: vec![0], a_scale: 0.0 };

    let fast: Vec<usize> = (0..shards).filter(|&s| s != esc_target).collect();
    let (pushed, mut consumed, inserts, flap_tickets) = thread::scope(|scope| {
        let mut pushers = Vec::new();
        for &s in &fast {
            let (q, metrics) = (&q, &metrics);
            pushers.push(scope.spawn(move || {
                let mut ok = Vec::new();
                for seq in 0..per_pusher {
                    let id = pid(0, s, seq);
                    match q.push(s, probe_item(id, 0, false)) {
                        Ok(()) => {
                            metrics.queue_push();
                            ok.push(id);
                        }
                        Err(_) => panic!("phase-1 pushes must never refuse (shard {s})"),
                    }
                }
                ok
            }));
        }
        let mut poppers = Vec::new();
        for &s in &fast {
            let (q, cache, inc_table, metrics, floors, esc_seq, partial) =
                (&q, &cache, &inc_table, &metrics, &floors, &esc_seq, &partial);
            poppers.push(scope.spawn(move || {
                let mut trace: Vec<Consumed> = Vec::new();
                let mut tickets: Vec<(u64, usize, u64)> = Vec::new();
                let mut esc_ids: Vec<u64> = Vec::new();
                while (trace.len() as u64) < per_pusher {
                    let batch = match q.pop_batch(s, policy) {
                        Assembled::Batch(b) => b,
                        Assembled::Closed => break,
                    };
                    metrics.queue_pop(batch.len());
                    let n = batch.len();
                    let mut answered = 0;
                    for it in batch {
                        let id = it.req.payload;
                        trace.push(Consumed {
                            id,
                            stolen: it.stolen,
                            min_bits: it.min_bits,
                            dropped: false,
                        });
                        if floors[s] < 8 && escalates(id, seed) {
                            // park the partial, carry the ticket — what
                            // execute_assembly does on a low margin
                            let inc = inc_table[s].load(Ordering::Relaxed);
                            let ticket = cache.insert(s, inc, partial());
                            let nid = pid(1, s, esc_seq.fetch_add(1, Ordering::Relaxed));
                            let mut item = probe_item(nid, 8, true);
                            item.refine_id = ticket;
                            match q.push(esc_target, item) {
                                Ok(()) => {
                                    metrics.queue_push();
                                    metrics.record_escalated(s, 1);
                                    tickets.push((ticket, s, inc));
                                    esc_ids.push(nid);
                                }
                                Err(_) => panic!(
                                    "accurate shard is sized for every escalation"
                                ),
                            }
                        } else {
                            answered += 1;
                        }
                    }
                    metrics.record_batch_answered(s, n, answered, 1e-4, 0);
                }
                (trace, tickets, esc_ids)
            }));
        }
        let mut pushed: Vec<u64> = Vec::new();
        for h in pushers {
            pushed.extend(h.join().expect("pusher panicked"));
        }
        let mut consumed: Vec<Vec<Consumed>> = vec![Vec::new(); shards];
        let mut inserts: HashMap<u64, (usize, u64)> = HashMap::new();
        let mut flap_tickets = 0usize;
        for (&s, h) in fast.iter().zip(poppers) {
            let (trace, tickets, esc_ids) = h.join().expect("popper panicked");
            consumed[s] = trace;
            pushed.extend(esc_ids);
            for (ticket, src, inc) in tickets {
                if src == flap {
                    flap_tickets += 1;
                }
                assert!(
                    inserts.insert(ticket, (src, inc)).is_none(),
                    "cache handed out ticket {ticket} twice"
                );
            }
        }
        (pushed, consumed, inserts, flap_tickets)
    });

    // -- the fence: the flapped fast replica respawns, superseding every
    //    partial its dead incarnation parked (§13 meets §15)
    inc_table[flap].store(2, Ordering::Relaxed);
    let superseded: HashSet<(usize, u64)> = [(flap, 1)].into_iter().collect();

    // -- phase 2: the accurate popper drains the escalation backlog
    let expected = inserts.len();
    let mut refined: Vec<(u64, usize, u64)> = Vec::new();
    let mut rerun = 0usize;
    let mut trace: Vec<Consumed> = Vec::new();
    while trace.len() < expected {
        let batch = match q.pop_batch(esc_target, policy) {
            Assembled::Batch(b) => b,
            Assembled::Closed => break,
        };
        metrics.queue_pop(batch.len());
        let n = batch.len();
        let mut refined_n = 0usize;
        for it in batch {
            trace.push(Consumed {
                id: it.req.payload,
                stolen: it.stolen,
                min_bits: it.min_bits,
                dropped: false,
            });
            // the ticket is consumed unconditionally (the server's
            // contract), then fenced by the source's live incarnation
            let entry = cache
                .take(it.refine_id)
                .expect("an in-flight ticket must never be evicted");
            if inc_table[entry.source].load(Ordering::Relaxed) == entry.incarnation {
                refined.push((it.refine_id, entry.source, entry.incarnation));
                refined_n += 1;
            } else {
                rerun += 1; // fenced: full re-run, entry discarded
            }
        }
        if refined_n > 0 {
            metrics.record_refined(esc_target, refined_n);
        }
        metrics.record_batch_answered(esc_target, n, n, 1e-4, 0);
    }
    consumed[esc_target] = trace;
    q.close();

    let label = format!("refinement seed {seed} shards {shards} flap {flap}");
    if let Err(e) = check_invariants(&floors, &pushed, &consumed, &HashSet::new()) {
        panic!("[{label}] invariant violated: {e}");
    }
    if let Err(e) = check_refinement_invariants(&inserts, &refined, &superseded, cache.len()) {
        panic!("[{label}] refinement invariant violated: {e}");
    }
    assert!(cache.is_empty(), "[{label}] plane cache must drain to empty");
    assert_eq!(q.len(), 0, "[{label}] intake not drained");
    // the scenario must exercise both §15 outcomes, and nothing else:
    // fenced tickets all re-run, every other ticket refined exactly once
    assert!(flap_tickets > 0, "[{label}] the superseded replica never escalated");
    assert!(!refined.is_empty(), "[{label}] nothing refined");
    assert_eq!(rerun, flap_tickets, "[{label}] exactly the fenced tickets re-run");
    assert_eq!(refined.len() + rerun, expected, "[{label}] every ticket reaches a terminal");
    let snap = metrics.snapshot(1.0);
    let total: u64 = consumed.iter().map(|t| t.len() as u64).sum();
    assert_eq!(
        snap.requests + snap.escalations,
        total,
        "[{label}] answered + escalated-away must cover every consumption"
    );
    assert_eq!(snap.refinements, refined.len() as u64, "[{label}] refinement counter");
    let per_ref: u64 = snap.per_replica.iter().map(|r| r.refinements).sum();
    assert_eq!(per_ref, snap.refinements, "[{label}] per-replica refinements sum");
    assert_eq!(snap.per_replica[esc_target].refinements, snap.refinements,
               "[{label}] only the accurate tier refines");
    assert_eq!(snap.queue_depth, 0, "[{label}] queue gauge must return to zero");
}

/// Tier-1 §15 refinement sweep (invariant 8) over seeded
/// escalation-heavy workloads.
#[test]
fn stress_refinement_ticket_conservation() {
    for seed in seed_list(&[41, 42]) {
        for shards in [4usize, 8] {
            let label = format!("refinement seed {seed} shards {shards}");
            with_watchdog(&label, Duration::from_secs(60), move || {
                stress_refinement_once(shards, 200, seed);
            });
        }
    }
}

/// The `ci.sh --stress` sweep: ≥8 seeds × {4, 16, 64} shards on the
/// §11 intake (plus the coarse reference at the smaller counts — its
/// single lock makes 64 coarse shards pointlessly slow), then the §12
/// overload scenario over a wider seed set.  A fast no-op unless
/// `STRESS_FULL=1`, so tier-1 cost stays flat.
#[test]
fn stress_full_sweep() {
    if std::env::var("STRESS_FULL").is_err() {
        eprintln!("stress_full_sweep: skipped (set STRESS_FULL=1 or run ci.sh --stress)");
        return;
    }
    let seeds = seed_list(&[1, 2, 3, 4, 5, 6, 7, 8]);
    sweep("sharded-full", ShardedIntake::<u64, u64>::new, &seeds, &[4, 16, 64]);
    sweep("coarse-full", CoarseIntake::<u64, u64>::new, &seeds, &[4, 16]);
    // §13 chaos schedules over the full seed set: alternating seeds
    // retire the accurate tier itself (clamped failover) vs a fast
    // shard, at every pool size
    for &seed in &seeds {
        for shards in [4usize, 16, 64] {
            let cfg = StressCfg {
                shards,
                cap: 4,
                per_pusher: (2000 / shards as u64).max(60),
                seed: seed.wrapping_add(200),
                close_early: false,
                overload: false,
            };
            let label = format!("chaos-full seed {} shards {shards}", cfg.seed);
            with_watchdog(&label, Duration::from_secs(60), move || {
                let q = ShardedIntake::new(cfg.cap, floors(cfg.shards), true);
                stress_chaos_once(&q, cfg);
            });
        }
    }
    for &seed in &seeds {
        for close_early in [false, true] {
            let cfg = StressCfg {
                shards: 8,
                cap: 2,
                per_pusher: 300,
                seed: seed.wrapping_add(100),
                close_early,
                overload: true,
            };
            let label = format!("overload-full seed {} early {close_early}", cfg.seed);
            with_watchdog(&label, Duration::from_secs(60), move || {
                let q = ShardedIntake::new(cfg.cap, floors(cfg.shards), true);
                stress_once(&q, cfg);
            });
        }
    }
    // §15 refinement conservation (invariant 8) over the full seed set
    // and wider pools
    for &seed in &seeds {
        for shards in [4usize, 8, 16] {
            let seed = seed.wrapping_add(300);
            let label = format!("refinement-full seed {seed} shards {shards}");
            with_watchdog(&label, Duration::from_secs(60), move || {
                stress_refinement_once(shards, 300, seed);
            });
        }
    }
}

// ---------------------------------------------------------------------
// Oracle certification: planted violations must be caught
// ---------------------------------------------------------------------

#[test]
fn checker_detects_planted_violations() {
    let floors = vec![4, 8];
    let c = |id, stolen, min_bits| Consumed { id, stolen, min_bits, dropped: false };
    let pushed = vec![pid(0, 0, 0), pid(0, 0, 1), pid(0, 1, 0)];
    let live = HashSet::new(); // no expired items in the classic plants

    // clean trace passes
    let clean = vec![vec![c(pid(0, 0, 0), false, 0), c(pid(0, 0, 1), false, 0)],
                     vec![c(pid(0, 1, 0), false, 0)]];
    check_invariants(&floors, &pushed, &clean, &live).expect("clean trace must pass");

    // lost item
    let lost = vec![vec![c(pid(0, 0, 0), false, 0)], vec![c(pid(0, 1, 0), false, 0)]];
    let e = check_invariants(&floors, &pushed, &lost, &live).unwrap_err();
    assert!(e.contains("lost"), "{e}");

    // duplicated item
    let dup = vec![vec![c(pid(0, 0, 0), false, 0), c(pid(0, 0, 1), false, 0)],
                   vec![c(pid(0, 1, 0), false, 0), c(pid(0, 0, 1), true, 0)]];
    let e = check_invariants(&floors, &pushed, &dup, &live).unwrap_err();
    assert!(e.contains("twice"), "{e}");

    // phantom item (consumed, never pushed)
    let phantom = vec![clean[0].clone(),
                       vec![c(pid(0, 1, 0), false, 0), c(pid(0, 1, 7), false, 0)]];
    let e = check_invariants(&floors, &pushed, &phantom, &live).unwrap_err();
    assert!(e.contains("never pushed"), "{e}");

    // owner FIFO inversion (seq 1 before seq 0, both non-stolen, gen 0)
    let inverted = vec![vec![c(pid(0, 0, 1), false, 0), c(pid(0, 0, 0), false, 0)],
                        vec![c(pid(0, 1, 0), false, 0)]];
    let e = check_invariants(&floors, &pushed, &inverted, &live).unwrap_err();
    assert!(e.contains("FIFO"), "{e}");

    // …but the same order IS legal when the older item was stolen away
    // and re-observed as stolen by a sibling (tail stealing reorders
    // global, never per-owner, order)
    let stolen_ok = vec![vec![c(pid(0, 0, 1), false, 0)],
                         vec![c(pid(0, 1, 0), false, 0), c(pid(0, 0, 0), true, 0)]];
    check_invariants(&floors, &pushed, &stolen_ok, &live).expect("steal reorder is legal");

    // steal-gate violation: popper 0 (floor 4) stole an 8-bit item
    let gated = vec![vec![c(pid(0, 0, 0), false, 0), c(pid(0, 1, 0), true, 8)],
                     vec![c(pid(0, 0, 1), true, 0)]];
    let e = check_invariants(&floors, &pushed, &gated, &live).unwrap_err();
    assert!(e.contains("gate"), "{e}");

    // ---- §12 deadline-drop conservation plants ----
    let cd = |id| Consumed { id, stolen: false, min_bits: 0, dropped: true };
    let expired: HashSet<u64> = [pid(0, 0, 1)].into_iter().collect();

    // matching trace passes: the expired item dropped, the rest served
    let good = vec![vec![c(pid(0, 0, 0), false, 0), cd(pid(0, 0, 1))],
                    vec![c(pid(0, 1, 0), false, 0)]];
    check_invariants(&floors, &pushed, &good, &expired).expect("matching drop trace passes");

    // planted: the expired item was served as if live
    let served = vec![vec![c(pid(0, 0, 0), false, 0), c(pid(0, 0, 1), false, 0)],
                      vec![c(pid(0, 1, 0), false, 0)]];
    let e = check_invariants(&floors, &pushed, &served, &expired).unwrap_err();
    assert!(e.contains("served instead of dropped"), "{e}");

    // planted: a live item was dropped with no expired deadline
    let overdrop = vec![vec![c(pid(0, 0, 0), false, 0), cd(pid(0, 0, 1))],
                        vec![cd(pid(0, 1, 0))]];
    let e = check_invariants(&floors, &pushed, &overdrop, &expired).unwrap_err();
    assert!(e.contains("without an expired deadline"), "{e}");

    // ---- §15 partial-sum ticket conservation plants ----
    // tickets 1 and 2 parked by replica 0's superseded incarnation 1,
    // ticket 3 by replica 1's still-current incarnation 2
    let inserts: HashMap<u64, (usize, u64)> =
        [(1, (0, 1)), (2, (0, 1)), (3, (1, 2))].into_iter().collect();
    let superseded: HashSet<(usize, u64)> = [(0, 1)].into_iter().collect();

    // clean: the current ticket refined once, the fenced tickets re-ran
    // (absent from `refined`), nothing left in the cache
    check_refinement_invariants(&inserts, &[(3, 1, 2)], &superseded, 0)
        .expect("clean refinement trace must pass");

    // planted: a reply refined from a superseded incarnation's planes
    // (the respawn fence was skipped)
    let e = check_refinement_invariants(&inserts, &[(1, 0, 1)], &superseded, 0).unwrap_err();
    assert!(e.contains("stale refinement"), "{e}");

    // planted: a cache entry outlived the drain (a consumer replied
    // without taking its ticket)
    let e = check_refinement_invariants(&inserts, &[(3, 1, 2)], &superseded, 1).unwrap_err();
    assert!(e.contains("leaked"), "{e}");

    // planted: one ticket refined two replies (take-once violated)
    let e = check_refinement_invariants(&inserts, &[(3, 1, 2), (3, 1, 2)], &superseded, 0)
        .unwrap_err();
    assert!(e.contains("twice"), "{e}");

    // planted: a refined reply from a ticket nobody ever inserted
    let e = check_refinement_invariants(&inserts, &[(9, 1, 2)], &superseded, 0).unwrap_err();
    assert!(e.contains("never inserted"), "{e}");

    // planted: provenance rewritten to dodge the supersede fence
    let e = check_refinement_invariants(&inserts, &[(1, 1, 2)], &superseded, 0).unwrap_err();
    assert!(e.contains("forged provenance"), "{e}");
}

/// The §13 oracle must catch corrupted failover traces, the same way
/// `checker_detects_planted_violations` certifies the base checker.
#[test]
fn checker_detects_planted_selfheal_violations() {
    let floors = vec![4, 4, 8];
    let retired: HashSet<usize> = [2].into_iter().collect();
    let c = |id, stolen, min_bits| Consumed { id, stolen, min_bits, dropped: false };
    // two items drained off retired shard 2: one tagged for the 8-bit
    // tier then clamped to 4 (nothing accurate left alive), one untagged
    let rehomed: HashMap<u64, u32> =
        [(pid(1, 2, 0), 4), (pid(0, 2, 5), 0)].into_iter().collect();

    // clean failover passes: both re-homed items consumed once, by live
    // shards whose floors cover the clamped tags
    let clean = vec![vec![c(pid(1, 2, 0), false, 4)], vec![c(pid(0, 2, 5), false, 0)],
                     vec![]];
    check_selfheal_invariants(&floors, &clean, &rehomed, &retired)
        .expect("clean failover trace must pass");

    // planted: the retired shard itself consumed a re-homed item (a
    // zombie popper outliving its retirement)
    let zombie = vec![vec![c(pid(1, 2, 0), false, 4)], vec![],
                      vec![c(pid(0, 2, 5), false, 0)]];
    let e = check_selfheal_invariants(&floors, &zombie, &rehomed, &retired).unwrap_err();
    assert!(e.contains("retired shard"), "{e}");

    // planted: a floor-4 shard consumed an item still tagged min_bits 8
    // (the drain forgot to clamp, or re-homed past the gate)
    let ungated: HashMap<u64, u32> = [(pid(1, 2, 0), 8)].into_iter().collect();
    let low = vec![vec![c(pid(1, 2, 0), false, 8)], vec![], vec![]];
    let e = check_selfheal_invariants(&floors, &low, &ungated, &retired).unwrap_err();
    assert!(e.contains("failover gate"), "{e}");

    // planted: a re-homed item consumed twice (drain + a stale steal)
    let twice = vec![vec![c(pid(1, 2, 0), false, 4), c(pid(0, 2, 5), false, 0)],
                     vec![c(pid(0, 2, 5), true, 0)], vec![]];
    let e = check_selfheal_invariants(&floors, &twice, &rehomed, &retired).unwrap_err();
    assert!(e.contains("twice"), "{e}");

    // planted: a re-homed item vanished (drained, never consumed)
    let lost = vec![vec![c(pid(1, 2, 0), false, 4)], vec![], vec![]];
    let e = check_selfheal_invariants(&floors, &lost, &rehomed, &retired).unwrap_err();
    assert!(e.contains("lost"), "{e}");
}

// ---------------------------------------------------------------------
// Metrics accounting fuzz (ISSUE 6 satellite): seeded multi-threaded
// op mix over the real sink, then the §9 invariant exactly
// ---------------------------------------------------------------------

#[test]
fn metrics_accounting_fuzz() {
    let replicas = 5;
    let accurate = replicas - 1;
    for seed in seed_list(&[11, 12, 13]) {
        let m = Metrics::new(replicas);
        let submitted = AtomicU64::new(0);
        thread::scope(|scope| {
            for t in 0..8u64 {
                let (m, submitted) = (&m, &submitted);
                scope.spawn(move || {
                    let mut rng = Rng::new(seed.wrapping_mul(0x0123_4567_89AB_CDEF) ^ t);
                    for _ in 0..400 {
                        let roll = rng.below(100);
                        if roll < 10 {
                            // invalid payload or admission refusal:
                            // rejected before execution
                            m.record_rejected();
                            submitted.fetch_add(1, Ordering::Relaxed);
                            continue;
                        }
                        let r = rng.below(replicas);
                        let size = 1 + rng.below(8);
                        for _ in 0..size {
                            m.queue_push();
                        }
                        m.queue_pop(size);
                        submitted.fetch_add(size as u64, Ordering::Relaxed);
                        if roll < 18 {
                            // admitted, but the SLA expired in the
                            // queue: dropped at assembly (§12)
                            m.record_deadline_drops(r, size);
                            continue;
                        }
                        if roll < 33 {
                            // the whole batch failed: every slot is a
                            // failed request
                            m.record_error(r, size, 1e-3);
                            continue;
                        }
                        // success, with a seeded share escalated away and
                        // answered by the accurate tier's re-run batch
                        let esc = if r == accurate { 0 } else { rng.below(size) };
                        m.record_batch_answered(r, size, size - esc, 1e-4, 0);
                        if esc > 0 {
                            m.record_escalated(r, esc);
                            m.record_batch_answered(accurate, esc, esc, 2e-4, 0);
                        }
                    }
                });
            }
        });
        let s = m.snapshot(1.0);
        assert_eq!(
            s.requests + s.failed_requests + s.rejected + s.deadline_drops,
            submitted.load(Ordering::Relaxed),
            "seed {seed}: §12 four-bucket accounting invariant"
        );
        assert_eq!(s.queue_depth, 0, "seed {seed}: gauge must drain");
        let (mut pb, mut pe, mut pr, mut pesc, mut pdrop) = (0, 0, 0, 0, 0);
        for r in &s.per_replica {
            pb += r.batches;
            pe += r.errors;
            pr += r.requests;
            pesc += r.escalations;
            pdrop += r.deadline_drops;
        }
        assert_eq!(pb, s.batches, "seed {seed}: per-replica batches sum");
        assert_eq!(pe, s.errors, "seed {seed}: per-replica errors sum");
        assert_eq!(pr, s.requests, "seed {seed}: per-replica requests sum");
        assert_eq!(pesc, s.escalations, "seed {seed}: per-replica escalations sum");
        assert_eq!(pdrop, s.deadline_drops, "seed {seed}: per-replica deadline-drop sum");
    }
}
