//! AdaptivFloat grid [Tambe et al., DAC'20] — baseline adaptive format.
//!
//! sign + e exponent bits + (n-1-e) mantissa bits, no subnormals; the
//! per-tensor exponent bias of the original is absorbed by the quantizer's
//! scale search (a power-of-two bias shift IS a scale), exactly as in the
//! python mirror.

/// Default exponent-bit allocation per total bitwidth (mirrors python).
pub fn default_exp_bits(n: u32) -> u32 {
    match n {
        2 | 3 => 1,
        4 | 5 => 2,
        _ => 3,
    }
}

/// Sorted signed grid at exponent bias 0.
pub fn grid(n: u32, e: Option<u32>) -> Vec<f64> {
    let e = e.unwrap_or_else(|| default_exp_bits(n));
    let mb = n - 1 - e;
    assert!(mb >= 1, "adaptivfloat needs >=1 mantissa bit (n={n}, e={e})");
    let mut pos = Vec::new();
    for exp in 0..(1u32 << e) {
        for f in 0..(1u32 << mb) {
            if exp == 0 && f == 0 {
                continue; // the all-zero code is sacrificed to represent 0
            }
            pos.push(2f64.powi(exp as i32) * (1.0 + f as f64 / (1u64 << mb) as f64));
        }
    }
    pos.sort_by(|a, b| a.total_cmp(b));
    pos.dedup();
    let mut g: Vec<f64> = pos.iter().rev().map(|v| -v).collect();
    g.push(0.0);
    g.extend_from_slice(&pos);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adafloat4_values() {
        // 1.0 (the E=0, f=0 code) is sacrificed for zero: 2^n - 1 values
        assert_eq!(
            grid(4, None),
            vec![-12.0, -8.0, -6.0, -4.0, -3.0, -2.0, -1.5, 0.0,
                 1.5, 2.0, 3.0, 4.0, 6.0, 8.0, 12.0]
        );
    }

    #[test]
    fn grid_cardinality_fits_codes() {
        for n in 3..=8u32 {
            assert_eq!(grid(n, None).len(), (1usize << n) - 1, "n={n}");
        }
    }

    #[test]
    fn tapered_spacing() {
        // relative step is constant per binade: |Δ|/v grows with v inside
        // the grid, i.e. absolute spacing increases monotonically
        let g = grid(6, None);
        let pos: Vec<f64> = g.into_iter().filter(|v| *v > 0.0).collect();
        let mut prev_step = 0.0;
        for w in pos.windows(2) {
            let step = w[1] - w[0];
            assert!(step >= prev_step - 1e-12);
            prev_step = step;
        }
    }

    #[test]
    fn symmetry() {
        for n in 3..=8u32 {
            let g = grid(n, None);
            for (a, b) in g.iter().zip(g.iter().rev()) {
                assert_eq!(*a, -b);
            }
        }
    }
}
