//! Posit(n, es) decode — baseline adaptive format [Langroudi et al., ALPS].
//!
//! Standard posit semantics: two's-complement negation, regime run-length
//! encoding, es exponent bits, remaining fraction bits with implicit 1.
//! The paper evaluates Posit(8,·) (Table II row Posit(8/8)); we keep es
//! configurable and default to es=1 like the python mirror.

/// Decode an n-bit posit code; None for NaR (the 1000…0 pattern).
pub fn value(code: u32, n: u32, es: u32) -> Option<f64> {
    debug_assert!(n >= 2 && n <= 16);
    let mask = (1u32 << n) - 1;
    if code == 0 {
        return Some(0.0);
    }
    if code == 1 << (n - 1) {
        return None; // NaR
    }
    let neg = (code >> (n - 1)) & 1 == 1;
    let c = if neg { (code.wrapping_neg()) & mask } else { code };
    let bits = c & ((1 << (n - 1)) - 1); // strip sign bit
    let nb = n - 1;
    let first = (bits >> (nb - 1)) & 1;
    let mut run = 0u32;
    for b in (0..nb).rev() {
        if (bits >> b) & 1 == first {
            run += 1;
        } else {
            break;
        }
    }
    let k: i32 = if first == 1 { run as i32 - 1 } else { -(run as i32) };
    let rest_len = nb.saturating_sub(run + 1); // regime terminator consumed
    let rest = if rest_len > 0 { bits & ((1 << rest_len) - 1) } else { 0 };
    let e_len = es.min(rest_len);
    let mut e = if e_len > 0 { rest >> (rest_len - e_len) } else { 0 };
    e <<= es - e_len; // pad truncated exponent with zeros
    let f_len = rest_len - e_len;
    let f = if f_len > 0 { rest & ((1 << f_len) - 1) } else { 0 };
    let frac = 1.0 + if f_len > 0 { f as f64 / (1u64 << f_len) as f64 } else { 0.0 };
    let useed = 2f64.powi(1 << es);
    let v = useed.powi(k) * 2f64.powi(e as i32) * frac;
    Some(if neg { -v } else { v })
}

/// Sorted grid of all finite posit(n, es) values.
pub fn grid(n: u32, es: u32) -> Vec<f64> {
    let mut vals: Vec<f64> = (0..(1u32 << n))
        .filter_map(|c| value(c, n, es))
        .collect();
    vals.sort_by(|a, b| a.total_cmp(b));
    vals.dedup();
    vals
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_posit4_es1_values() {
        // cross-checked against the python mirror / posit standard tables
        let g = grid(4, 1);
        assert_eq!(
            g,
            vec![-16.0, -4.0, -2.0, -1.0, -0.5, -0.25, -0.0625, 0.0,
                 0.0625, 0.25, 0.5, 1.0, 2.0, 4.0, 16.0]
        );
    }

    #[test]
    fn nar_excluded() {
        assert!(value(1 << 7, 8, 1).is_none());
        assert_eq!(grid(8, 1).len(), (1 << 8) - 1); // all codes distinct but NaR
    }

    #[test]
    fn negation_symmetry() {
        for n in [4u32, 6, 8] {
            let g = grid(n, 1);
            for (a, b) in g.iter().zip(g.iter().rev()) {
                assert_eq!(*a, -b, "n={n}");
            }
        }
    }

    #[test]
    fn monotone_in_code_for_positives() {
        // positive posits compare like integers — a defining property
        for es in [0u32, 1, 2] {
            let mut prev = 0.0;
            for c in 1..(1u32 << 7) {
                let v = value(c, 8, es).unwrap();
                assert!(v > prev, "es={es} c={c}");
                prev = v;
            }
        }
    }

    #[test]
    fn useed_scaling() {
        // regime k multiplies by useed = 2^(2^es)
        let one = value(0b0100_0000, 8, 1).unwrap();
        assert_eq!(one, 1.0);
        let next_regime = value(0b0110_0000, 8, 1).unwrap();
        assert_eq!(next_regime, 4.0); // useed = 4 for es=1
    }
}
