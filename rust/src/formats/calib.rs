//! Single-pass calibration engine: sorted prefix-sum RMSE ladder
//! (DESIGN.md §8).
//!
//! The Fig. 2 / Eqn. 2 scale search runs a fixed 54-candidate ladder and
//! keeps the RMSE-minimizing scale.  Before this module every candidate
//! was a full projection + RMSE pass over the tensor — O(54·n) per
//! `(tensor, format, bits)` query, rebuilt from scratch for every query
//! on the same tensor (the search engine's cost-table fill alone runs 6
//! such ladders per layer).  Following the restructuring idea of ANT
//! [Guo et al. 2022] and PrecisionBatching [Lam et al. 2020] — make
//! per-candidate work table-sized, not tensor-sized — a [`CalibView`]
//! preprocesses the tensor *once*:
//!
//! * sort the values (branchless LSB radix sort on the monotone `u32`
//!   key mapping, O(n) with 4 byte passes), and
//! * prefix sums of `x` and `x²` over the sorted order.
//!
//! Each ladder candidate then needs only the ≤255 scaled decision
//! boundaries located in the sorted data by binary search: every
//! quantization cell's exact squared error is `Σx² − 2vΣx + cnt·v²`
//! from two prefix-sum differences, so a candidate costs
//! O(codes·log n) instead of O(n), and the whole ladder is one sort
//! plus 54 table-sized evaluations.  The view depends only on the
//! tensor, so repeated queries at different `(format, bits)` — the
//! cost-table fill, the format-sweep benches — reuse it for free.
//!
//! **Equivalence & the tie rule.**  The per-cell error terms are the
//! same `f64` quantities the reference ladder
//! ([`quantizer::calibrate_scale`]) sums per element; only the summation
//! *grouping* changes, so the two ladders agree whenever candidates are
//! separated by more than f64 rounding noise (randomized-tensor margins
//! are ≥1e-4 relative; grouping noise is bounded by ~n·ε of the summed
//! magnitude — prefix-sum rounding accumulates with tensor length, so
//! the tie band scales with n).  Exact ties are real, not hypothetical:
//! tensors
//! whose values sit on grid points or decision midpoints (where rounding
//! up and down give equal |error|) make many candidates bit-equal under
//! the reference sum, and the reference's strict `<` keeps the earliest.
//! The grouped sums round those ties differently, so candidates within
//! the noise tolerance of the incumbent are re-decided by an exact
//! per-element pass over the sorted data: bit-equal on the tie class
//! (identical per-position error terms), hence the earlier candidate
//! keeps — the reference's rule.  Fuzzed across all formats × bitwidths
//! on random, heavy-tail, snapped-to-grid and snapped-to-midpoint
//! tensors (see the property tests below and `benches/perf_calib.rs`).
//!
//! Non-finite tensors short-circuit: any NaN/±∞ element makes every
//! reference candidate's RMSE non-finite, so its strict `<` never
//! replaces the initial `(base, ∞)` and the max-abs base scale is
//! returned — [`CalibView::calibrate_grid`] reproduces that directly.

use super::quantizer::{self, sigma_of};
use super::Format;

/// Floor of the tie band: candidates within `noise-band × term-magnitude`
/// of the incumbent are re-decided exactly.  The band itself scales with
/// the tensor (see [`CalibView::noise_rel`]): sequential prefix-sum
/// rounding accumulates as ~n·ε of the summed magnitude, so a fixed
/// relative band would let reference-tied candidates escape on large
/// tensors.  An over-wide band only costs O(n) exact passes for the few
/// best-competitive candidates (never worse than the old 54-pass
/// ladder); an under-wide band would mis-resolve ties, so the bound is
/// deliberately generous.
const TIE_REL: f64 = 1e-12;

/// Sorted + prefix-summed read-only view of one tensor, reusable across
/// every `(format, bits)` calibration query on that tensor.
///
/// Construction is O(n) (radix sort + two prefix passes); each
/// [`calibrate`](CalibView::calibrate) ladder is then O(codes·log n)
/// per candidate.  σ (the Eqn. 2 normalizer, with the σ=1 fallback for
/// constant/empty tensors) is computed once at construction in the
/// original element order, bit-identical to [`sigma_of`].
pub struct CalibView {
    /// Element count of the viewed tensor (kept even when `sorted` is
    /// empty on the non-finite path).
    n: usize,
    /// Ascending values; empty when the tensor has non-finite elements.
    sorted: Vec<f32>,
    /// `pfx_x[i]` = Σ of the first `i` sorted values (f64), len n+1.
    pfx_x: Vec<f64>,
    /// `pfx_xx[i]` = Σ of the first `i` sorted squares (f64), len n+1.
    pfx_xx: Vec<f64>,
    sigma: f64,
    /// f32 max-abs fold (the reference `maxabs_scale` numerator; NaNs
    /// are ignored by `f32::max` exactly like the reference fold).
    xm: f32,
    all_finite: bool,
}

impl CalibView {
    /// Preprocess `x`: one radix sort + prefix sums of `x` and `x²`.
    pub fn new(x: &[f32]) -> CalibView {
        let sigma = sigma_of(x);
        let mut xm = 0.0f32;
        let mut all_finite = true;
        for &v in x {
            xm = xm.max(v.abs());
            all_finite &= v.is_finite();
        }
        let sorted = if all_finite { radix_sort_f32(x) } else { Vec::new() };
        let mut pfx_x = Vec::with_capacity(sorted.len() + 1);
        let mut pfx_xx = Vec::with_capacity(sorted.len() + 1);
        pfx_x.push(0.0);
        pfx_xx.push(0.0);
        let (mut sx, mut sxx) = (0.0f64, 0.0f64);
        for &v in &sorted {
            let v = v as f64;
            sx += v;
            sxx += v * v;
            pfx_x.push(sx);
            pfx_xx.push(sxx);
        }
        CalibView { n: x.len(), sorted, pfx_x, pfx_xx, sigma, xm, all_finite }
    }

    /// Element count of the viewed tensor.
    pub fn len(&self) -> usize {
        self.n
    }

    /// True for the empty tensor.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Eqn. 2 normalizer, bit-identical to [`sigma_of`] on the viewed
    /// tensor (σ=1 fallback for constant/empty tensors included).
    pub fn sigma(&self) -> f64 {
        self.sigma
    }

    /// RMSE-optimal scale for `(fmt, bits)` — the ladder of
    /// [`quantizer::calibrate_scale`] evaluated through the prefix sums;
    /// selects the identical scale (see the module docs for the tie
    /// rule).
    pub fn calibrate(&self, fmt: Format, bits: u32) -> f64 {
        self.calibrate_grid(&fmt.grid(bits))
    }

    /// [`calibrate`](CalibView::calibrate) over a raw ascending grid.
    pub fn calibrate_grid(&self, grid: &[f64]) -> f64 {
        let gm = grid.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
        let xm = self.xm as f64;
        // mirror of quantizer::maxabs_scale (incl. its 1.0 fallbacks)
        let base = if xm > 0.0 && gm > 0.0 { xm / gm } else { 1.0 };
        if base == 0.0 {
            return 1.0;
        }
        if !self.all_finite {
            // every reference candidate's RMSE is NaN/∞: strict `<`
            // never replaces the (base, ∞) init, so base is selected
            return base;
        }
        let mut best_s = base;
        let mut best_sse = f64::INFINITY;
        let mut best_mag = 0.0f64;
        let mut best_exact: Option<f64> = None;
        for j in quantizer::LADDER_EXPS {
            for mult in quantizer::LADDER_MULTS {
                let s = base * mult * 2f64.powi(-j);
                let (sse, mag) = self.cell_sse(grid, s);
                if sse.to_bits() == best_sse.to_bits() {
                    // bit-equal grouped sums: the common structural tie
                    // (e.g. every large-scale candidate collapsing the
                    // tensor into the zero cell sums the same prefix
                    // total) — the earlier candidate keeps, no exact
                    // pass needed
                    continue;
                }
                if best_sse.is_finite() {
                    let tol = self.noise_rel() * mag.max(best_mag);
                    let gap = (sse - best_sse).abs();
                    // NaN gaps (overflowed candidate cells) take the tie
                    // path too: the exact per-element pass gives them a
                    // well-defined (infinite) error to lose with
                    if gap <= tol || gap.is_nan() {
                        // within grouping noise of the incumbent: decide
                        // by the exact per-element sums (bit-equal on
                        // the reference's tie class -> incumbent keeps)
                        let be = *best_exact
                            .get_or_insert_with(|| self.exact_sse(grid, best_s));
                        let ce = self.exact_sse(grid, s);
                        if ce < be {
                            best_s = s;
                            best_sse = sse;
                            best_mag = mag;
                            best_exact = Some(ce);
                        }
                        continue;
                    }
                }
                if sse < best_sse {
                    best_s = s;
                    best_sse = sse;
                    best_mag = mag;
                    best_exact = None;
                }
            }
        }
        best_s
    }

    /// Relative width of the tie band: sequential summation error of an
    /// n-term prefix is bounded by ~n·ε of the summed magnitude; the
    /// difference of two prefixes and the ≤255-cell accumulation stay
    /// within a small multiple of that, covered by the 8× margin.
    /// `TIE_REL` floors the small-n case.
    fn noise_rel(&self) -> f64 {
        TIE_REL.max(8.0 * self.sorted.len() as f64 * f64::EPSILON)
    }

    /// Walk the quantization cells of `scale * grid` over the sorted
    /// data, calling `f(code, lo, hi)` for every non-empty cell
    /// (`sorted[lo..hi]`).  The single boundary definition both the
    /// grouped and the exact-tie evaluations run on: boundaries use the
    /// reference's midpoint arithmetic, elements exactly on a boundary
    /// land in the upper cell, and since mids ascend each search narrows
    /// to the remaining suffix.
    fn for_each_cell<F: FnMut(usize, usize, usize)>(&self, grid: &[f64],
                                                    scale: f64, mut f: F) {
        let n = self.sorted.len();
        let mut lo = 0usize;
        for c in 0..grid.len() {
            let hi = if c + 1 < grid.len() {
                let mid = (grid[c] + grid[c + 1]) * 0.5 * scale;
                lo + lower_bound_f32(&self.sorted[lo..], mid)
            } else {
                n
            };
            if hi > lo {
                f(c, lo, hi);
            }
            lo = hi;
        }
    }

    /// Grouped sum of squared errors at `scale`, plus the magnitude of
    /// the terms entering it (the cancellation-noise scale for the tie
    /// tolerance).  Each cell `[bounds(c-1), bounds(c))` of the sorted
    /// data contributes `Σx² − 2vΣx + cnt·v²` with `v` the f32-rounded
    /// scaled grid value — the exact per-cell error mass.
    fn cell_sse(&self, grid: &[f64], scale: f64) -> (f64, f64) {
        let mut sse = 0.0f64;
        let mut mag = 0.0f64;
        self.for_each_cell(grid, scale, |c, lo, hi| {
            let v = (grid[c] * scale) as f32 as f64;
            let s1 = self.pfx_x[hi] - self.pfx_x[lo];
            let s2 = self.pfx_xx[hi] - self.pfx_xx[lo];
            let cnt = (hi - lo) as f64;
            sse += s2 - 2.0 * v * s1 + cnt * v * v;
            mag += s2.abs() + 2.0 * v.abs() * s1.abs() + cnt * v * v;
        });
        (sse, mag)
    }

    /// Per-element squared error at `scale` over the *sorted* data —
    /// the tie-resolution slow path, on the same cell walk as
    /// [`CalibView::cell_sse`].  On the reference's tie class the
    /// per-position terms of two tied candidates are identical, so the
    /// sums are bit-equal and strict `<` keeps the earlier candidate.
    fn exact_sse(&self, grid: &[f64], scale: f64) -> f64 {
        let mut sse = 0.0f64;
        self.for_each_cell(grid, scale, |c, lo, hi| {
            let v = (grid[c] * scale) as f32 as f64;
            for &x in &self.sorted[lo..hi] {
                let d = x as f64 - v;
                sse += d * d;
            }
        });
        sse
    }
}

/// First index in ascending `sorted` whose value (widened to f64) is
/// ≥ `t` — i.e. the count of elements `< t`.  Elements exactly on a
/// decision boundary therefore land in the upper cell, matching the
/// reference's `searchsorted(side="right")` on the midpoints.
fn lower_bound_f32(sorted: &[f32], t: f64) -> usize {
    let mut lo = 0usize;
    let mut hi = sorted.len();
    while lo < hi {
        let mid = (lo + hi) / 2;
        if (sorted[mid] as f64) < t {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

/// Ascending sort of finite f32s: LSB-first counting sort on the
/// monotone `u32` key map (negatives bit-flipped, positives
/// sign-flipped), 4 byte passes, branch-free inner loops.  Equivalent
/// to `sort_unstable_by(f32::total_cmp)` on finite data (−0.0 orders
/// before +0.0; both sum identically in the prefix arrays), which small
/// inputs use directly — the histogram passes only pay off once the
/// tensor outgrows them.
fn radix_sort_f32(x: &[f32]) -> Vec<f32> {
    const CUTOFF: usize = 512;
    if x.len() < CUTOFF {
        let mut v = x.to_vec();
        v.sort_unstable_by(f32::total_cmp);
        return v;
    }
    let mut keys: Vec<u32> = x
        .iter()
        .map(|&f| {
            let b = f.to_bits();
            if b & 0x8000_0000 != 0 {
                !b
            } else {
                b ^ 0x8000_0000
            }
        })
        .collect();
    let mut tmp = vec![0u32; keys.len()];
    for shift in [0u32, 8, 16, 24] {
        let mut hist = [0usize; 256];
        for &k in &keys {
            hist[((k >> shift) & 0xFF) as usize] += 1;
        }
        if hist.iter().any(|&h| h == keys.len()) {
            continue; // single bucket: this pass is the identity
        }
        let mut sum = 0usize;
        for h in hist.iter_mut() {
            let c = *h;
            *h = sum;
            sum += c;
        }
        for &k in &keys {
            let b = ((k >> shift) & 0xFF) as usize;
            tmp[hist[b]] = k;
            hist[b] += 1;
        }
        std::mem::swap(&mut keys, &mut tmp);
    }
    keys.into_iter()
        .map(|k| {
            let b = if k & 0x8000_0000 != 0 { k ^ 0x8000_0000 } else { !k };
            f32::from_bits(b)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::quantizer::{calibrate_scale, calibrate_scale_projected};
    use crate::util::proptest::{check, gen::heavy_tail};
    use crate::util::rng::Rng;

    fn all_fmt_bits() -> Vec<(Format, u32)> {
        let mut out = Vec::new();
        for fmt in Format::ALL {
            for bits in 2..=8u32 {
                if fmt.supports(bits) {
                    out.push((fmt, bits));
                }
            }
        }
        out
    }

    /// Both oracles: the per-element reference ladder and the pre-§8
    /// batched projected ladder must agree with the view on every query.
    fn assert_scales_match(name: &str, x: &[f32]) {
        let view = CalibView::new(x);
        let mut buf = Vec::new();
        for (fmt, bits) in all_fmt_bits() {
            let grid = fmt.grid(bits);
            let s_ref = calibrate_scale(x, &grid);
            let s_view = view.calibrate(fmt, bits);
            assert!(
                s_ref == s_view || (s_ref.is_nan() && s_view.is_nan()),
                "{name} {fmt:?} b{bits}: ref {s_ref} view {s_view}"
            );
            let s_proj = calibrate_scale_projected(x, fmt, bits, &mut buf);
            assert!(
                s_proj == s_view || (s_proj.is_nan() && s_view.is_nan()),
                "{name} {fmt:?} b{bits}: proj {s_proj} view {s_view}"
            );
        }
    }

    #[test]
    fn radix_sort_matches_total_cmp_sort() {
        let mut rng = Rng::new(31);
        for n in [0usize, 1, 5, 511, 512, 513, 4096] {
            let mut x: Vec<f32> = heavy_tail(&mut rng, n);
            // salt with signed zeros, denormals, and exact dupes
            if n > 8 {
                x[0] = -0.0;
                x[1] = 0.0;
                x[2] = 1.0e-41;
                x[3] = -1.0e-41;
                x[4] = x[5];
            }
            let got = radix_sort_f32(&x);
            let mut want = x.clone();
            want.sort_unstable_by(f32::total_cmp);
            assert_eq!(
                got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "n={n}"
            );
        }
    }

    #[test]
    fn prefix_sums_are_consistent() {
        let mut rng = Rng::new(8);
        let x = heavy_tail(&mut rng, 700);
        let view = CalibView::new(&x);
        assert_eq!(view.len(), 700);
        assert_eq!(view.pfx_x.len(), 701);
        let total: f64 = view.sorted.iter().map(|&v| v as f64 * v as f64).sum();
        assert!((view.pfx_xx[700] - total).abs() <= 1e-9 * total.abs().max(1.0));
        assert!(view.sorted.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn view_matches_reference_on_heavy_tails() {
        let mut rng = Rng::new(77);
        for n in [1usize, 3, 130, 1200] {
            let x = heavy_tail(&mut rng, n);
            assert_scales_match(&format!("ht{n}"), &x);
        }
    }

    #[test]
    fn view_matches_reference_on_edge_tensors() {
        // satellite: NaN/±∞, all-zero, constant (σ=1 fallback), single
        let cases: Vec<(&str, Vec<f32>)> = vec![
            ("empty", vec![]),
            ("all-zero", vec![0.0; 64]),
            ("signed-zeros", vec![-0.0, 0.0, 1.0, -1.0]),
            ("single", vec![0.7]),
            ("single-neg", vec![-3.2]),
            ("constant", vec![2.5; 100]),
            ("constant-neg", vec![-0.7; 33]),
            ("denormal", vec![1.0e-40, -1.0e-41, 3.0e-39]),
            ("huge", vec![1.0e30, -2.0e32, 3.0e28]),
            ("near-f32-max", vec![3.0e38, -3.3e38, 1.0e37]),
            ("nan", vec![1.0, f32::NAN, -2.0]),
            ("pos-inf", vec![1.0, f32::INFINITY, -2.0]),
            ("neg-inf", vec![f32::NEG_INFINITY, 0.5]),
            ("both-inf", vec![f32::INFINITY, f32::NEG_INFINITY, 2.0]),
            ("all-nan", vec![f32::NAN, f32::NAN]),
        ];
        for (name, x) in &cases {
            assert_scales_match(name, x);
        }
        // σ=1 fallback is preserved by the view
        assert_eq!(CalibView::new(&[2.5; 100]).sigma(), 1.0);
        assert_eq!(CalibView::new(&[]).sigma(), 1.0);
    }

    #[test]
    fn prop_view_matches_reference_all_formats_bits() {
        // tentpole acceptance: randomized heavy-tail tensors across all
        // supported formats × bitwidths select identical scales
        check(
            "calibview-scale-equivalence",
            25,
            |r, s| {
                let n = 1 + (s * 900.0) as usize;
                heavy_tail(r, n)
            },
            |x| {
                let view = CalibView::new(x);
                all_fmt_bits().iter().all(|&(fmt, bits)| {
                    view.calibrate(fmt, bits) == calibrate_scale(x, &fmt.grid(bits))
                })
            },
        );
    }

    #[test]
    fn prop_view_matches_reference_on_knife_edge_tensors() {
        // adversarial tie class: values snapped exactly onto grid points
        // and decision midpoints, where many ladder candidates are
        // bit-equal under the reference sum and its first-wins rule must
        // be reproduced (module docs: tie rule)
        check(
            "calibview-knife-edge-ties",
            20,
            |r, s| {
                let (fmt, bits) = {
                    let all = all_fmt_bits();
                    all[r.below(all.len())]
                };
                let grid = fmt.grid(bits);
                let scale = [1.0, 0.5, 2.0, 0.37, 0.75][r.below(5)];
                let mut pool: Vec<f64> = grid.iter().map(|&g| g * scale).collect();
                pool.extend(
                    grid.windows(2).map(|w| (w[0] + w[1]) * 0.5 * scale),
                );
                let n = 8 + (s * 600.0) as usize;
                (0..n)
                    .map(|_| pool[r.below(pool.len())] as f32)
                    .collect::<Vec<f32>>()
            },
            |x| {
                let view = CalibView::new(x);
                all_fmt_bits().iter().all(|&(fmt, bits)| {
                    view.calibrate(fmt, bits) == calibrate_scale(x, &fmt.grid(bits))
                })
            },
        );
    }

    #[test]
    fn shared_view_is_query_order_independent() {
        let mut rng = Rng::new(4);
        let x = heavy_tail(&mut rng, 800);
        let view = CalibView::new(&x);
        let a: Vec<f64> = all_fmt_bits()
            .iter()
            .map(|&(f, b)| view.calibrate(f, b))
            .collect();
        let b: Vec<f64> = all_fmt_bits()
            .iter()
            .rev()
            .map(|&(f, b)| view.calibrate(f, b))
            .collect();
        let b: Vec<f64> = b.into_iter().rev().collect();
        assert_eq!(a, b);
    }
}
