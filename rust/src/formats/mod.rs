//! Numeric formats: DyBit (the paper's contribution) + every baseline it
//! is compared against, reduced to sorted value grids + per-tensor scale
//! adaptation.  Bit-exact mirror of `python/compile/formats.py`; verified
//! against `artifacts/formats_golden.json` in `tests/golden.rs`.

pub mod adaptivfloat;
pub mod calib;
pub mod dybit;
pub mod flint;
pub mod gridlut;
pub mod intq;
pub mod posit;
pub mod quantizer;

pub use calib::CalibView;
pub use gridlut::GridLut;

/// The LUT interchange width shared with the HLO artifacts (aot.py).
pub const LUT_SIZE: usize = 256;

/// Supported numeric formats (paper Tables II/III row families).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Format {
    DyBit,
    Int,
    Posit,
    AdaptivFloat,
    Flint,
}

impl Format {
    pub const ALL: [Format; 5] = [
        Format::DyBit,
        Format::Int,
        Format::Posit,
        Format::AdaptivFloat,
        Format::Flint,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Format::DyBit => "dybit",
            Format::Int => "int",
            Format::Posit => "posit",
            Format::AdaptivFloat => "adaptivfloat",
            Format::Flint => "flint",
        }
    }

    pub fn from_name(s: &str) -> Option<Format> {
        Format::ALL.iter().copied().find(|f| f.name() == s)
    }

    /// Sorted signed value grid at scale 1.0.
    ///
    /// Panics on unsupported (format, bits) combos — AdaptivFloat/Flint
    /// need n >= 3; everything else supports 2..=8 (same as python).
    pub fn grid(&self, bits: u32) -> Vec<f64> {
        assert!((2..=8).contains(&bits), "bits={bits}");
        match self {
            Format::DyBit => dybit::grid(bits),
            Format::Int => intq::grid(bits),
            Format::Posit => posit::grid(bits, 1),
            Format::AdaptivFloat => adaptivfloat::grid(bits, None),
            Format::Flint => flint::grid(bits),
        }
    }

    /// Does this (format, bits) combination exist?
    pub fn supports(&self, bits: u32) -> bool {
        match self {
            Format::AdaptivFloat | Format::Flint => (3..=8).contains(&bits),
            _ => (2..=8).contains(&bits),
        }
    }

    /// Fixed-size ascending LUT (edge-padded) — the runtime unit fed to the
    /// HLO fake-quant inputs; mirrors formats.padded_lut.
    ///
    /// Served from the shared [`GridLut`] cache so repeated qcfg builds
    /// (one per layer per batch of config tensors) reuse the same tables
    /// as the quantizer and the search engine.
    pub fn padded_lut(&self, bits: u32) -> Vec<f32> {
        let lut = GridLut::from_format(*self, bits, 1.0);
        assert!(lut.len() <= LUT_SIZE);
        let mut out = lut.values().to_vec();
        let last = *out.last().expect("non-empty grid");
        out.resize(LUT_SIZE, last);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for f in Format::ALL {
            assert_eq!(Format::from_name(f.name()), Some(f));
        }
        assert_eq!(Format::from_name("nope"), None);
    }

    #[test]
    fn grids_fit_lut() {
        for f in Format::ALL {
            for bits in 2..=8u32 {
                if !f.supports(bits) {
                    continue;
                }
                let g = f.grid(bits);
                assert!(g.len() <= LUT_SIZE, "{f:?} {bits}: {}", g.len());
                assert!(g.windows(2).all(|w| w[0] < w[1]), "{f:?} {bits}");
            }
        }
    }

    #[test]
    fn padded_lut_is_monotone_nondecreasing() {
        let lut = Format::DyBit.padded_lut(4);
        assert_eq!(lut.len(), LUT_SIZE);
        assert!(lut.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(lut[LUT_SIZE - 1], 4.0); // dybit4 max
    }

    #[test]
    fn dybit_int_coincide_at_2_bits() {
        // both are ternary {-1, 0, 1}: documented identity (DESIGN.md §5)
        assert_eq!(Format::DyBit.grid(2), Format::Int.grid(2));
    }
}
