//! Symmetric uniform INT grid — the conventional fixed-point baseline the
//! paper compares against (INT4/INT8 rows of Tables II/III), also standing
//! in for PACT/DSQ when combined with the quantizer's RMSE-optimal clip
//! search (DESIGN.md §6).

/// {-(2^(n-1)-1) .. 2^(n-1)-1} at scale 1.0 (symmetric, no -2^(n-1)).
pub fn grid(n: u32) -> Vec<f64> {
    let q = (1i64 << (n - 1)) - 1;
    (-q..=q).map(|x| x as f64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_and_symmetry() {
        for n in 2..=8u32 {
            let g = grid(n);
            assert_eq!(g.len(), (1usize << n) - 1);
            for (a, b) in g.iter().zip(g.iter().rev()) {
                assert_eq!(*a, -b);
            }
        }
    }

    #[test]
    fn uniform_spacing() {
        let g = grid(4);
        for w in g.windows(2) {
            assert_eq!(w[1] - w[0], 1.0);
        }
    }

    #[test]
    fn int2_is_ternary() {
        assert_eq!(grid(2), vec![-1.0, 0.0, 1.0]);
    }
}
