//! Batched grid quantization through a precomputed bucket LUT.
//!
//! The original hot path (`quantizer::quantize_to_grid`) rebuilt the
//! midpoint table on every call and ran a per-element binary search —
//! ~log2(255) ≈ 8 unpredictable branches per element.  Following the
//! table-driven inner loops of ANT [Guo et al. 2022] and Bit Fusion
//! [Sharma et al. 2018], a [`GridLut`] precomputes, once per
//! `(format, bits, scale)`:
//!
//! * the scaled decision boundaries (`mids`, identical arithmetic to the
//!   baseline, so outputs are bit-exact with the python mirror),
//! * the scaled code→value table (`values`),
//! * a uniform bucket table `start` mapping a value's bucket to the first
//!   candidate code, so encoding is O(1): one multiply, one clamp, and on
//!   average ~1 boundary comparison instead of a full binary search.
//!
//! Batch entry points ([`GridLut::encode_batch`],
//! [`GridLut::dequantize_batch`], [`GridLut::quantize_batch`]) operate
//! slice-at-a-time; [`GridLut::from_format`] memoizes instances in a
//! process-wide cache so fake-quant, the runtime LUT builder
//! (`Format::padded_lut` → `qat::luts`) and the search engine share the
//! same tables (the calibration ladder builds its 54 candidate tables
//! locally — data-dependent scales would only pollute the cache).
//! Measured against the per-element baseline in
//! `benches/perf_hotpath.rs`; the before/after is recorded in
//! EXPERIMENTS.md §Perf.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use super::quantizer::upper_bound;
use super::Format;

/// Bound on cached instances; the cache is cleared wholesale when full —
/// a backstop for long-running processes that settle on many distinct
/// data-dependent scales (one per quantized tensor).
const CACHE_CAP: usize = 4096;

/// Precomputed quantization tables for one `(grid, scale)` pair.
///
/// Construction is O(codes + buckets); each encoded element then costs
/// O(1) expected time.  All comparisons use the same f64 arithmetic as the
/// per-element baseline, so `quantize_batch` is bit-exact with
/// `quantizer::quantize_to_grid` on every input (including ties, which
/// resolve to the upper cell exactly like `searchsorted(side="right")`).
pub struct GridLut {
    scale: f64,
    /// Code-indexed scaled values, ascending (`code -> grid[code] * scale`).
    values: Vec<f32>,
    /// Decision boundaries between adjacent codes, scaled; `len = codes-1`.
    mids: Vec<f64>,
    /// Left edge of the bucket table (= `mids[0]`).
    lo: f64,
    /// Buckets per unit value (0 when the boundary span is degenerate).
    inv_step: f64,
    /// First candidate code per bucket.
    start: Vec<u16>,
}

impl GridLut {
    /// Build tables for an ascending `grid` at `scale`.
    ///
    /// Panics if the grid has fewer than 2 values, is not strictly
    /// ascending, or exceeds the `u8` code space used by the batch APIs.
    pub fn new(grid: &[f64], scale: f64) -> Self {
        assert!(grid.len() >= 2, "grid needs >= 2 values");
        assert!(grid.len() <= 256, "grid exceeds u8 code space");
        assert!(grid.windows(2).all(|w| w[0] < w[1]), "grid must ascend");
        debug_assert!(scale > 0.0, "scale must be positive");

        let values: Vec<f32> = grid.iter().map(|&g| (g * scale) as f32).collect();
        // identical arithmetic to the per-element baseline: bit-exact cells
        let mids: Vec<f64> = grid
            .windows(2)
            .map(|w| (w[0] + w[1]) * 0.5 * scale)
            .collect();

        let nbuckets = (mids.len() * 16).clamp(64, 4096);
        let lo = mids[0];
        let span = mids[mids.len() - 1] - lo;
        let inv_step = if span > 0.0 { nbuckets as f64 / span } else { 0.0 };
        let step = if span > 0.0 { span / nbuckets as f64 } else { 0.0 };

        let mut start = Vec::with_capacity(nbuckets);
        let mut idx = 0usize;
        for b in 0..nbuckets {
            let edge = lo + b as f64 * step;
            while idx < mids.len() && mids[idx] < edge {
                idx += 1;
            }
            start.push(idx as u16);
        }

        GridLut { scale, values, mids, lo, inv_step, start }
    }

    /// Cached instance for `(format, bits, scale)`.
    ///
    /// The same `Arc` is returned for repeated keys, so `fake_quant`,
    /// `Format::padded_lut`, `qat::luts` and `search::engine` share
    /// tables.  (The calibration ladder builds its candidate tables
    /// locally instead — 54 data-dependent scales per tensor would only
    /// pollute the cache.  *Settled* calibrated scales are worth caching:
    /// repeated sweeps over the same tensors — e.g. the fig5 bench runs
    /// several searches per session — re-derive identical scales, and
    /// `CACHE_CAP` bounds the pathological many-distinct-scales case.)
    /// Construction happens *outside* the lock, so a
    /// panicking grid (unsupported bits) cannot poison the cache and
    /// builders do not serialize each other; a poisoned lock is recovered
    /// rather than propagated.
    pub fn from_format(fmt: Format, bits: u32, scale: f64) -> Arc<GridLut> {
        type Key = (Format, u32, u64);
        static CACHE: OnceLock<Mutex<HashMap<Key, Arc<GridLut>>>> = OnceLock::new();
        use crate::util::lock;
        let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
        let key = (fmt, bits, scale.to_bits());
        if let Some(lut) = lock(cache).get(&key) {
            return Arc::clone(lut);
        }
        let lut = Arc::new(GridLut::new(&fmt.grid(bits), scale));
        let mut map = lock(cache);
        if map.len() >= CACHE_CAP {
            map.clear();
        }
        // double-checked: keep whichever instance landed first
        Arc::clone(map.entry(key).or_insert(lut))
    }

    /// Number of codes (grid points).
    pub fn len(&self) -> usize {
        self.values.len()
    }

    /// True when the table holds no codes (cannot occur for valid grids).
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// The scale the tables were built at.
    pub fn scale(&self) -> f64 {
        self.scale
    }

    /// Scaled value of `code` (codes past the end clamp to the maximum,
    /// matching the edge-padded runtime LUT convention).
    pub fn value(&self, code: u8) -> f32 {
        self.values[(code as usize).min(self.values.len() - 1)]
    }

    /// Code-indexed scaled value table.
    pub fn values(&self) -> &[f32] {
        &self.values
    }

    /// Nearest-code index of one value (ties to the upper cell, matching
    /// `searchsorted(side="right")` on the midpoints).
    ///
    /// Typical cost is ~1 boundary comparison (uniformly-spaced grids put
    /// 0–2 midpoints per bucket).  Exponentially-spaced grids (posit,
    /// high-bit DyBit) can concentrate many midpoints into the buckets
    /// near zero, so the forward scan is capped at `SCAN_CAP` steps and
    /// falls back to a binary search over the remaining suffix — bounding
    /// the worst case at `SCAN_CAP + log2(codes)` comparisons, i.e. never
    /// asymptotically worse than the per-element baseline.
    #[inline]
    fn code_of(&self, v: f64) -> usize {
        const SCAN_CAP: u32 = 8;
        // negative / NaN offsets saturate to bucket 0, huge ones clamp high
        let b = ((v - self.lo) * self.inv_step) as usize;
        let b = b.min(self.start.len() - 1);
        let mut idx = self.start[b] as usize;
        let mut steps = 0u32;
        while idx < self.mids.len() && self.mids[idx] <= v {
            idx += 1;
            steps += 1;
            if steps == SCAN_CAP {
                // dense bucket: the prefix is all <= v, so the global
                // upper bound is idx + upper_bound(suffix)
                idx += upper_bound(&self.mids[idx..], v);
                break;
            }
        }
        // guard against bucket-edge rounding: restore exact upper-bound
        while idx > 0 && self.mids[idx - 1] > v {
            idx -= 1;
        }
        idx
    }

    /// Nearest code for one value.
    #[inline]
    pub fn encode(&self, v: f32) -> u8 {
        self.code_of(v as f64) as u8
    }

    /// Encode a slice of values into codes.
    pub fn encode_batch(&self, x: &[f32], out: &mut [u8]) {
        debug_assert_eq!(x.len(), out.len());
        for (o, &v) in out.iter_mut().zip(x.iter()) {
            *o = self.code_of(v as f64) as u8;
        }
    }

    /// Decode a slice of codes back into scaled values.
    pub fn dequantize_batch(&self, codes: &[u8], out: &mut [f32]) {
        debug_assert_eq!(codes.len(), out.len());
        let top = self.values.len() - 1;
        for (o, &c) in out.iter_mut().zip(codes.iter()) {
            *o = self.values[(c as usize).min(top)];
        }
    }

    /// Fused nearest-value projection (encode + decode in one pass) —
    /// the batched replacement for `quantizer::quantize_to_grid`.
    pub fn quantize_batch(&self, x: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), out.len());
        for (o, &v) in out.iter_mut().zip(x.iter()) {
            *o = self.values[self.code_of(v as f64)];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::quantizer;
    use crate::util::proptest::gen::heavy_tail;
    use crate::util::rng::Rng;

    #[test]
    fn matches_baseline_bit_exactly_all_formats() {
        let mut rng = Rng::new(41);
        for fmt in Format::ALL {
            for bits in [2u32, 3, 4, 8] {
                if !fmt.supports(bits) {
                    continue;
                }
                for scale in [0.03, 0.5, 1.0, 7.25] {
                    let grid = fmt.grid(bits);
                    let x = heavy_tail(&mut rng, 1500);
                    let mut base = vec![0.0f32; x.len()];
                    quantizer::quantize_to_grid(&x, &grid, scale, &mut base);
                    let lut = GridLut::new(&grid, scale);
                    let mut got = vec![0.0f32; x.len()];
                    lut.quantize_batch(&x, &mut got);
                    assert_eq!(got, base, "{fmt:?} bits={bits} scale={scale}");
                }
            }
        }
    }

    #[test]
    fn ties_resolve_to_upper_cell_like_baseline() {
        let grid = Format::DyBit.grid(4);
        let scale = 0.5;
        let lut = GridLut::new(&grid, scale);
        // probe exactly on every decision boundary
        let mids: Vec<f32> = grid
            .windows(2)
            .map(|w| ((w[0] + w[1]) * 0.5 * scale) as f32)
            .collect();
        let mut base = vec![0.0f32; mids.len()];
        quantizer::quantize_to_grid(&mids, &grid, scale, &mut base);
        let mut got = vec![0.0f32; mids.len()];
        lut.quantize_batch(&mids, &mut got);
        assert_eq!(got, base);
    }

    #[test]
    fn outliers_clamp_to_extremes() {
        let lut = GridLut::new(&Format::DyBit.grid(4), 1.0);
        let x = vec![-1e30f32, -9.0, 9.0, 1e30, f32::NEG_INFINITY, f32::INFINITY];
        let mut codes = vec![0u8; x.len()];
        lut.encode_batch(&x, &mut codes);
        assert_eq!(codes[0], 0);
        assert_eq!(codes[1], 0);
        assert_eq!(codes[2] as usize, lut.len() - 1);
        assert_eq!(codes[3] as usize, lut.len() - 1);
        assert_eq!(codes[4], 0);
        assert_eq!(codes[5] as usize, lut.len() - 1);
    }

    #[test]
    fn encode_then_dequantize_equals_fused() {
        let mut rng = Rng::new(9);
        let x = heavy_tail(&mut rng, 4096);
        let lut = GridLut::from_format(Format::Flint, 4, 0.75);
        let mut codes = vec![0u8; x.len()];
        lut.encode_batch(&x, &mut codes);
        let mut via_codes = vec![0.0f32; x.len()];
        lut.dequantize_batch(&codes, &mut via_codes);
        let mut fused = vec![0.0f32; x.len()];
        lut.quantize_batch(&x, &mut fused);
        assert_eq!(via_codes, fused);
    }

    #[test]
    fn quantize_is_idempotent() {
        let mut rng = Rng::new(3);
        let x = heavy_tail(&mut rng, 512);
        let lut = GridLut::from_format(Format::DyBit, 4, 0.37);
        let mut q1 = vec![0.0f32; x.len()];
        lut.quantize_batch(&x, &mut q1);
        let mut q2 = vec![0.0f32; x.len()];
        lut.quantize_batch(&q1, &mut q2);
        assert_eq!(q1, q2);
    }

    #[test]
    fn encode_is_monotone_in_value() {
        let lut = GridLut::from_format(Format::AdaptivFloat, 5, 1.3);
        let mut prev = 0u8;
        let mut v = -40.0f32;
        while v < 40.0 {
            let c = lut.encode(v);
            assert!(c >= prev, "v={v}: code {c} < {prev}");
            prev = c;
            v += 0.01;
        }
        assert_eq!(prev as usize, lut.len() - 1);
    }

    #[test]
    fn cache_shares_instances() {
        let a = GridLut::from_format(Format::Int, 4, 0.125);
        let b = GridLut::from_format(Format::Int, 4, 0.125);
        assert!(Arc::ptr_eq(&a, &b));
        let c = GridLut::from_format(Format::Int, 4, 0.25);
        assert!(!Arc::ptr_eq(&a, &c));
    }

    #[test]
    fn tiny_grid_works() {
        let lut = GridLut::new(&[-1.0, 0.0, 1.0], 2.0);
        assert_eq!(lut.len(), 3);
        let x = vec![-5.0f32, -0.9, 0.9, 5.0, 0.0];
        let mut out = vec![0.0f32; x.len()];
        lut.quantize_batch(&x, &mut out);
        assert_eq!(out, vec![-2.0, 0.0, 0.0, 2.0, 0.0]);
        assert_eq!(lut.value(200), 2.0); // out-of-range code clamps
    }
}
