//! DyBit codec — the paper's contribution (Eqn. 1, Table I, Fig. 1).
//!
//! An n-bit signed DyBit is 1 sign bit + an m = n-1 bit magnitude field
//! with a *variable-length* exponent: the count `i` of leading 1s
//! (terminated by the first 0, which is consumed, or the end of the field)
//! selects the binade; the remaining k = m-i-1 bits are the fraction.
//!
//! * all-zero field          -> 0
//! * i = 0 (leads with 0)    -> subnormal: value = x / 2^(m-1), linear [0,1)
//! * i >= 1                  -> value = 2^(i-1) * (1 + x / 2^k)
//! * all-ones field          -> 2^(m-1)   (Eqn. 1's "max")
//!
//! This file is the bit-exact mirror of `python/compile/formats.py`; the
//! integration test `tests/golden.rs` compares every grid and code table
//! against `artifacts/formats_golden.json`.

/// Decode an m-bit DyBit magnitude field (m in 1..=7 for 2..=8-bit signed;
/// m=8 covers the paper's unsigned 8-bit decoder example).
pub fn magnitude(code: u8, m: u32) -> f64 {
    debug_assert!(m >= 1 && m <= 8 && (code as u32) < (1u32 << m));
    if code == 0 {
        return 0.0;
    }
    // i = number of leading ones in the m-bit field (hardware: LOD, Fig. 3b)
    let mut i = 0u32;
    for b in (0..m).rev() {
        if (code >> b) & 1 == 1 {
            i += 1;
        } else {
            break;
        }
    }
    if i == 0 {
        // subnormal: low m-1 bits over 2^(m-1)
        let x = (code & ((1 << (m - 1)) - 1)) as f64;
        return x / (1u64 << (m - 1)) as f64;
    }
    if i == m {
        return (1u64 << (m - 1)) as f64; // all-ones: max = 2^(m-1)
    }
    let k = m - i - 1; // fraction bits after the consumed terminating zero
    let x = (code & ((1u8 << k) - 1)) as f64;
    let frac = if k > 0 { x / (1u64 << k) as f64 } else { 0.0 };
    2f64.powi(i as i32 - 1) * (1.0 + frac)
}

/// Decode a signed n-bit DyBit code (MSB = sign).
///
/// The negative-zero code (sign=1, magnitude=0) is remapped to
/// -2^(m-1) = -max so all 2^n codes carry information (DESIGN.md §5).
pub fn decode(code: u8, n: u32) -> f64 {
    debug_assert!(n >= 2 && n <= 8 && (code as u32) < (1u32 << n));
    let m = n - 1;
    let sign = (code >> m) & 1;
    let mag = code & ((1 << m) - 1);
    if sign == 1 && mag == 0 {
        return -((1u64 << (m - 1)) as f64);
    }
    let v = magnitude(mag, m);
    if sign == 1 {
        -v
    } else {
        v
    }
}

/// Nearest-value encode into a signed n-bit code (ties -> lower code,
/// matching the python mirror).
pub fn encode(value: f64, n: u32) -> u8 {
    let mut best_code = 0u8;
    let mut best_err = f64::INFINITY;
    for c in 0..(1u32 << n) {
        let err = (decode(c as u8, n) - value).abs();
        if err < best_err {
            best_err = err;
            best_code = c as u8;
        }
    }
    best_code
}

/// Sorted signed grid at scale 1.0 (2^n - 1 distinct values).
pub fn grid(n: u32) -> Vec<f64> {
    let m = n - 1;
    let mut pos: Vec<f64> = (1..(1u32 << m))
        .map(|c| magnitude(c as u8, m))
        .collect();
    pos.sort_by(|a, b| a.total_cmp(b));
    pos.dedup();
    let mut g: Vec<f64> = pos.iter().rev().map(|v| -v).collect();
    g.push(0.0);
    g.extend_from_slice(&pos);
    g
}

/// Unsigned m-bit grid (the paper's Table I uses m = 4).
pub fn grid_unsigned(m: u32) -> Vec<f64> {
    let mut g: Vec<f64> = (0..(1u32 << m)).map(|c| magnitude(c as u8, m)).collect();
    g.sort_by(|a, b| a.total_cmp(b));
    g
}

/// Code-indexed value table (code -> value) for the fused decode-GEMM
/// kernel; length 2^n, padded to `len` by repeating the last entry.
pub fn code_lut(n: u32, len: usize) -> Vec<f32> {
    let mut lut: Vec<f32> = (0..(1u32 << n)).map(|c| decode(c as u8, n) as f32).collect();
    lut.resize(len, *lut.last().unwrap());
    lut
}

/// Decoded (exponent, mantissa-style) split used by the MP decoder model
/// in the simulator: returns (i-1 exponent, normalized fraction in [1,2)),
/// or None for zero/subnormal (which decode via the linear path).
pub fn decode_fields(code: u8, m: u32) -> Option<(i32, f64)> {
    if code == 0 {
        return None;
    }
    let mut i = 0u32;
    for b in (0..m).rev() {
        if (code >> b) & 1 == 1 {
            i += 1;
        } else {
            break;
        }
    }
    if i == 0 {
        return None;
    }
    let v = magnitude(code, m);
    let e = i as i32 - 1;
    Some((e, v / 2f64.powi(e)))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper Table I, verbatim.
    #[test]
    fn table1_exact() {
        let expect = [
            0.0, 0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.875, 1.0, 1.25,
            1.5, 1.75, 2.0, 3.0, 4.0, 8.0,
        ];
        assert_eq!(grid_unsigned(4), expect);
    }

    /// Paper Sec. III-B2 decoder example: 11001010 -> exp 001, man 10101000.
    #[test]
    fn decoder_example_8bit() {
        // i=2 -> exponent i-1 = 1; fraction 01010 over 2^5
        let v = magnitude(0b1100_1010, 8 /* unsigned example */);
        assert_eq!(v, 2.0 * (1.0 + 10.0 / 32.0));
        let (e, f) = decode_fields(0b1100_1010, 8).unwrap();
        assert_eq!(e, 1);
        assert!((f - (1.0 + 10.0 / 32.0)).abs() < 1e-12);
    }

    #[test]
    fn encode_decode_roundtrip_all_codes() {
        for n in 2..=8u32 {
            for c in 0..(1u32 << n) {
                let v = decode(c as u8, n);
                let c2 = encode(v, n);
                // distinct codes may share a value (only ±0); require value eq
                assert_eq!(
                    decode(c2, n),
                    v,
                    "n={n} c={c:#010b} v={v} re-encoded {c2:#010b}"
                );
            }
        }
    }

    #[test]
    fn encode_is_nearest() {
        // scan fine values, check returned code minimizes |err|
        for n in [2u32, 4, 8] {
            let g = grid(n);
            let top = *g.last().unwrap();
            let mut v = -top * 1.2;
            while v < top * 1.2 {
                let c = encode(v, n);
                let got = (decode(c, n) - v).abs();
                let best = g
                    .iter()
                    .map(|x| (x - v).abs())
                    .fold(f64::INFINITY, f64::min);
                assert!(
                    (got - best).abs() < 1e-12,
                    "n={n} v={v}: got err {got}, best {best}"
                );
                v += top / 57.3;
            }
        }
    }

    #[test]
    fn grid_sizes() {
        // 2^n codes, ±0 collapse, neg-zero remapped to -max duplicate:
        // distinct values = 2^n - 1
        for n in 2..=8u32 {
            assert_eq!(grid(n).len(), (1usize << n) - 1, "n={n}");
        }
    }

    #[test]
    fn grid_symmetric_and_monotone() {
        for n in 2..=8u32 {
            let g = grid(n);
            for w in g.windows(2) {
                assert!(w[0] < w[1]);
            }
            for (a, b) in g.iter().zip(g.iter().rev()) {
                assert_eq!(*a, -b);
            }
        }
    }

    #[test]
    fn subnormal_region_is_linear() {
        // codes 0..2^(m-1) decode to x / 2^(m-1): uniform spacing near zero,
        // the property that lets DyBit track bell-shaped tensors (Fig. 2)
        for m in 2..=7u32 {
            let step = 1.0 / (1u64 << (m - 1)) as f64;
            for x in 0..(1u32 << (m - 1)) {
                assert_eq!(magnitude(x as u8, m), x as f64 * step);
            }
        }
    }

    #[test]
    fn max_is_pow2_of_m_minus_1() {
        for m in 1..=7u32 {
            let all_ones = ((1u32 << m) - 1) as u8;
            assert_eq!(magnitude(all_ones, m), (1u64 << (m - 1)) as f64);
        }
    }

    /// Eqn. 1 edge: the all-ones magnitude field decodes to the format
    /// maximum 2^(m-1) for every signed width, and its signed code pair
    /// covers ±max.
    #[test]
    fn all_ones_code_is_max_at_every_width() {
        for n in 2..=8u32 {
            let m = n - 1;
            let max = (1u64 << (m - 1)) as f64;
            let all_ones_mag = ((1u32 << m) - 1) as u8;
            assert_eq!(magnitude(all_ones_mag, m), max, "n={n}");
            // positive signed code (sign=0, mag=all-ones)
            assert_eq!(decode(all_ones_mag, n), max, "n={n}");
            // negative signed code (sign=1, mag=all-ones)
            let neg = (1u8 << m) | all_ones_mag;
            assert_eq!(decode(neg, n), -max, "n={n}");
            // and it is the grid's extreme
            assert_eq!(*grid(n).last().unwrap(), max, "n={n}");
        }
    }

    /// DESIGN.md §5: the otherwise-wasted negative-zero code (sign=1,
    /// magnitude=0) is remapped to -2^(m-1) so all 2^n codes carry
    /// information.
    #[test]
    fn negative_zero_remaps_to_negative_max() {
        for n in 2..=8u32 {
            let m = n - 1;
            let neg_zero = 1u8 << m; // sign bit set, magnitude field 0
            let want = -((1u64 << (m - 1)) as f64);
            assert_eq!(decode(neg_zero, n), want, "n={n}");
            // it duplicates the all-ones negative value, never a new one
            assert_eq!(decode(neg_zero, n), *grid(n).first().unwrap(), "n={n}");
        }
    }

    /// Subnormal boundary: the largest i=0 (leading-zero) code decodes
    /// linearly to (2^(m-1)-1)/2^(m-1), and the next code up (i=1, the
    /// first normal) lands exactly on 1.0 — no gap and no overlap at the
    /// subnormal/normal seam.
    #[test]
    fn subnormal_to_normal_boundary_is_seamless() {
        for m in 2..=7u32 {
            let top_sub = (1u8 << (m - 1)) - 1; // 0111…1: largest subnormal
            let denom = (1u64 << (m - 1)) as f64;
            assert_eq!(magnitude(top_sub, m), (denom - 1.0) / denom, "m={m}");
            let first_normal = 1u8 << (m - 1); // 1000…0: i=1, fraction 0
            assert_eq!(magnitude(first_normal, m), 1.0, "m={m}");
        }
        // m=1 degenerate field: the single non-zero code is the max
        assert_eq!(magnitude(1, 1), 1.0);
    }

    /// Every decodable value re-encodes to a code with the same value, for
    /// every code of every width (2..=8) — the full-codebook roundtrip.
    #[test]
    fn roundtrip_value_identity_over_full_codebook() {
        for n in 2..=8u32 {
            let g = grid(n);
            for c in 0..(1u32 << n) {
                let v = decode(c as u8, n);
                // decoded values all lie on the signed grid
                assert!(
                    g.iter().any(|&gv| gv == v),
                    "n={n} c={c:#010b}: {v} not on grid"
                );
                let c2 = encode(v, n);
                assert_eq!(decode(c2, n), v, "n={n} c={c:#010b}");
                // encoding is stable: re-encoding the roundtripped code's
                // value yields the same code
                assert_eq!(encode(decode(c2, n), n), c2, "n={n} c={c:#010b}");
            }
        }
    }

    #[test]
    fn code_lut_padding() {
        let lut = code_lut(4, 256);
        assert_eq!(lut.len(), 256);
        assert_eq!(lut[15], lut[255]);
        assert_eq!(lut[0], 0.0);
    }
}
