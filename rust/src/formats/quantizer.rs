//! Per-tensor quantizer: scale calibration, fake-quant, RMSE (paper Eqn. 2).
//!
//! This is the tensor-level adaptation of Fig. 2: the format grid is fixed,
//! the per-tensor scale `s` is searched to minimize the σ-normalized RMSE.
//! The candidate ladder (powers of two under the max-abs scale × fine
//! multipliers) mirrors `python/compile/formats.py::calibrate_scale` so the
//! two sides pick identical scales on identical data.

use super::Format;

/// Nearest-value projection of `x` onto `scale * grid` (grid ascending).
pub fn quantize_to_grid(x: &[f32], grid: &[f64], scale: f64, out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    // midpoints once per call; binary search per element
    let mids: Vec<f64> = grid.windows(2).map(|w| (w[0] + w[1]) * 0.5 * scale).collect();
    for (o, &v) in out.iter_mut().zip(x.iter()) {
        let idx = upper_bound(&mids, v as f64);
        *o = (grid[idx] * scale) as f32;
    }
}

/// First index whose value is > x (searchsorted side="right").
#[inline]
pub fn upper_bound(sorted: &[f64], x: f64) -> usize {
    let mut lo = 0usize;
    let mut hi = sorted.len();
    while lo < hi {
        let mid = (lo + hi) / 2;
        if sorted[mid] <= x {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

/// Paper Eqn. 2: sqrt(mean(((x - x̂)/σ)²)) with σ = std(x).
pub fn rmse(x: &[f32], xq: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), xq.len());
    if x.is_empty() {
        return 0.0;
    }
    let n = x.len() as f64;
    let mean = x.iter().map(|&v| v as f64).sum::<f64>() / n;
    let var = x.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n;
    let sigma = if var > 0.0 { var.sqrt() } else { 1.0 };
    let se = x
        .iter()
        .zip(xq.iter())
        .map(|(&a, &b)| ((a as f64 - b as f64) / sigma).powi(2))
        .sum::<f64>()
        / n;
    se.sqrt()
}

/// Max-abs scale: maps the tensor's max magnitude to the grid max.
pub fn maxabs_scale(x: &[f32], grid: &[f64]) -> f64 {
    let gm = grid.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
    let xm = x.iter().fold(0.0f32, |m, &v| m.max(v.abs())) as f64;
    if xm > 0.0 && gm > 0.0 {
        xm / gm
    } else {
        1.0
    }
}

/// RMSE-optimal scale search (bit-exact mirror of the python ladder).
///
/// Scans power-of-two multiples of the max-abs scale in BOTH directions:
/// tapered grids like DyBit often prefer scales *above* max-abs, trading a
/// coarser far tail for a finer dense region near zero.
pub fn calibrate_scale(x: &[f32], grid: &[f64]) -> f64 {
    let base = maxabs_scale(x, grid);
    if base == 0.0 {
        return 1.0;
    }
    let mut buf = vec![0.0f32; x.len()];
    let mut best = (base, f64::INFINITY);
    for j in -6i32..12 {
        for mult in [1.0f64, 0.75, 0.5] {
            let s = base * mult * 2f64.powi(-j);
            quantize_to_grid(x, grid, s, &mut buf);
            let e = rmse(x, &buf);
            if e < best.1 {
                best = (s, e);
            }
        }
    }
    best.0
}

/// Result of quantizing one tensor.
#[derive(Clone, Debug)]
pub struct QuantResult {
    pub scale: f64,
    pub rmse: f64,
}

/// Fake-quantize in place-ish: returns quantized copy + (scale, rmse).
pub fn fake_quant(x: &[f32], fmt: Format, bits: u32,
                  scale: Option<f64>) -> (Vec<f32>, QuantResult) {
    let grid = fmt.grid(bits);
    let s = scale.unwrap_or_else(|| calibrate_scale(x, &grid));
    let mut out = vec![0.0f32; x.len()];
    quantize_to_grid(x, &grid, s, &mut out);
    let e = rmse(x, &out);
    (out, QuantResult { scale: s, rmse: e })
}

/// Per-layer RMSE of a tensor at (fmt, bits) without keeping the output.
pub fn quant_rmse(x: &[f32], fmt: Format, bits: u32) -> f64 {
    fake_quant(x, fmt, bits, None).1.rmse
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, gen};
    use crate::util::rng::Rng;

    #[test]
    fn upper_bound_matches_linear_scan() {
        let v = vec![-1.0, 0.0, 0.5, 0.5, 2.0];
        for x in [-2.0, -1.0, 0.2, 0.5, 1.0, 3.0] {
            let want = v.iter().filter(|&&m| m <= x).count();
            assert_eq!(upper_bound(&v, x), want, "x={x}");
        }
    }

    #[test]
    fn quantize_idempotent() {
        let g = Format::DyBit.grid(4);
        let x: Vec<f32> = vec![0.3, -1.7, 0.0, 2.5, -0.01];
        let mut q1 = vec![0.0; x.len()];
        quantize_to_grid(&x, &g, 0.5, &mut q1);
        let mut q2 = vec![0.0; x.len()];
        quantize_to_grid(&q1, &g, 0.5, &mut q2);
        assert_eq!(q1, q2);
    }

    #[test]
    fn rmse_zero_for_exact() {
        let x = vec![1.0f32, -2.0, 0.0];
        assert_eq!(rmse(&x, &x), 0.0);
    }

    #[test]
    fn calibrated_beats_or_ties_maxabs() {
        let mut rng = Rng::new(11);
        let x = rng.normal_vec(2000);
        for fmt in Format::ALL {
            let g = fmt.grid(4);
            let s_cal = calibrate_scale(&x, &g);
            let s_max = maxabs_scale(&x, &g);
            let mut a = vec![0.0; x.len()];
            let mut b = vec![0.0; x.len()];
            quantize_to_grid(&x, &g, s_cal, &mut a);
            quantize_to_grid(&x, &g, s_max, &mut b);
            assert!(rmse(&x, &a) <= rmse(&x, &b) + 1e-12, "{fmt:?}");
        }
    }

    #[test]
    fn more_bits_never_hurt_rmse() {
        let mut rng = Rng::new(5);
        let x = rng.normal_vec(1500);
        for fmt in [Format::DyBit, Format::Int, Format::Flint] {
            let e4 = quant_rmse(&x, fmt, 4);
            let e8 = quant_rmse(&x, fmt, 8);
            assert!(e8 <= e4 + 1e-9, "{fmt:?}: e8={e8} e4={e4}");
        }
    }

    #[test]
    fn prop_quantized_values_on_grid() {
        check("quantized-on-grid", 60, |r, s| {
            (gen::tensor(r, s), gen::bitwidth(r))
        }, |(x, bits)| {
            let (q, res) = fake_quant(x, Format::DyBit, *bits as u32, None);
            let g = Format::DyBit.grid(*bits as u32);
            q.iter().all(|&v| {
                g.iter().any(|&gv| ((gv * res.scale) as f32 - v).abs() < 1e-30
                    || (gv * res.scale) as f32 == v)
            })
        });
    }

    #[test]
    fn prop_quantization_is_nearest() {
        check("nearest-projection", 40, |r, s| gen::tensor(r, s), |x| {
            let g = Format::DyBit.grid(4);
            let s = 0.37f64;
            let mut q = vec![0.0; x.len()];
            quantize_to_grid(x, &g, s, &mut q);
            x.iter().zip(q.iter()).all(|(&xi, &qi)| {
                let best = g
                    .iter()
                    .map(|&gv| (gv * s - xi as f64).abs())
                    .fold(f64::INFINITY, f64::min);
                ((qi as f64 - xi as f64).abs() - best) < 1e-6
            })
        });
    }

    #[test]
    fn dybit_beats_int_on_heavy_tails() {
        // the paper's core claim at the metric level (Fig. 2 narrative)
        let mut rng = Rng::new(2024);
        let x: Vec<f32> = (0..4000)
            .map(|_| {
                let v = rng.normal();
                (v * (1.0 + 2.0 * rng.uniform().powi(4) * 5.0)) as f32
            })
            .collect();
        let d = quant_rmse(&x, Format::DyBit, 4);
        let i = quant_rmse(&x, Format::Int, 4);
        assert!(d < i, "dybit {d} vs int {i}");
    }
}
