//! Per-tensor quantizer: scale calibration, fake-quant, RMSE (paper Eqn. 2).
//!
//! This is the tensor-level adaptation of Fig. 2: the format grid is fixed,
//! the per-tensor scale `s` is searched to minimize the σ-normalized RMSE.
//! The candidate ladder (powers of two under the max-abs scale × fine
//! multipliers) mirrors `python/compile/formats.py::calibrate_scale` so the
//! two sides pick identical scales on identical data.
//!
//! Three calibration/projection tiers exist (DESIGN.md §5, §8):
//! * [`quantize_to_grid`] / [`calibrate_scale`] — the per-element reference
//!   (midpoints rebuilt per call, binary search per element), kept as the
//!   correctness oracle and bench baseline;
//! * [`calibrate_scale_projected`] — the pre-§8 batched ladder (every
//!   candidate projected through a [`GridLut`](super::GridLut)),
//!   bit-exact with the reference; kept as the second oracle and the
//!   "old" side of `benches/perf_calib.rs`;
//! * [`CalibView`]-backed [`calibrate_scale_lut`] / [`quant_rmse_into`]
//!   — the production path: sort + prefix sums once per tensor, each
//!   ladder candidate evaluated from table-sized cell sums
//!   (DESIGN.md §8; acceptance floor 4× on the 1M-element DyBit-4
//!   ladder, before/after in EXPERIMENTS.md §Perf).  Projections at the
//!   *settled* scale still run through the batched `GridLut`, so
//!   quantized outputs and final RMSE values are bit-exact with the
//!   reference chain.

use super::calib::CalibView;
use super::gridlut::GridLut;
use super::Format;

/// Power-of-two exponents the calibration ladder scans (`2^-j` for `j`
/// in this range) — one definition shared by the reference, projected,
/// and [`CalibView`] ladders so the candidate set cannot drift.
pub(crate) const LADDER_EXPS: std::ops::Range<i32> = -6i32..12;

/// Fine multipliers the ladder applies at every exponent step.
pub(crate) const LADDER_MULTS: [f64; 3] = [1.0, 0.75, 0.5];

/// Nearest-value projection of `x` onto `scale * grid` (grid ascending).
///
/// Per-element reference implementation: rebuilds the midpoint table every
/// call and binary-searches per element.  Kept as the correctness oracle
/// and the bench baseline; the production path is the batched
/// [`GridLut`] (`quantize_batch`), which is bit-exact with this function
/// and benchmarked against it in `benches/perf_hotpath.rs` (acceptance
/// floor 2×; measured before/after in EXPERIMENTS.md §Perf).
pub fn quantize_to_grid(x: &[f32], grid: &[f64], scale: f64, out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    // midpoints once per call; binary search per element
    let mids: Vec<f64> = grid.windows(2).map(|w| (w[0] + w[1]) * 0.5 * scale).collect();
    for (o, &v) in out.iter_mut().zip(x.iter()) {
        let idx = upper_bound(&mids, v as f64);
        *o = (grid[idx] * scale) as f32;
    }
}

/// First index whose value is > x (searchsorted side="right").
#[inline]
pub fn upper_bound(sorted: &[f64], x: f64) -> usize {
    let mut lo = 0usize;
    let mut hi = sorted.len();
    while lo < hi {
        let mid = (lo + hi) / 2;
        if sorted[mid] <= x {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

/// σ = std(x) with the σ=1 fallback for constant/empty tensors (the
/// normalizer of Eqn. 2).  Hoisted out of [`rmse`] so the calibration
/// ladder computes it once instead of once per candidate scale.
pub fn sigma_of(x: &[f32]) -> f64 {
    if x.is_empty() {
        return 1.0;
    }
    let n = x.len() as f64;
    let mean = x.iter().map(|&v| v as f64).sum::<f64>() / n;
    let var = x.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n;
    if var > 0.0 {
        var.sqrt()
    } else {
        1.0
    }
}

/// Eqn. 2 with a precomputed normalizer (see [`sigma_of`]).
pub fn rmse_with_sigma(x: &[f32], xq: &[f32], sigma: f64) -> f64 {
    debug_assert_eq!(x.len(), xq.len());
    if x.is_empty() {
        return 0.0;
    }
    let n = x.len() as f64;
    let se = x
        .iter()
        .zip(xq.iter())
        .map(|(&a, &b)| ((a as f64 - b as f64) / sigma).powi(2))
        .sum::<f64>()
        / n;
    se.sqrt()
}

/// Paper Eqn. 2: sqrt(mean(((x - x̂)/σ)²)) with σ = std(x).
pub fn rmse(x: &[f32], xq: &[f32]) -> f64 {
    rmse_with_sigma(x, xq, sigma_of(x))
}

/// Max-abs scale: maps the tensor's max magnitude to the grid max.
pub fn maxabs_scale(x: &[f32], grid: &[f64]) -> f64 {
    let gm = grid.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
    let xm = x.iter().fold(0.0f32, |m, &v| m.max(v.abs())) as f64;
    if xm > 0.0 && gm > 0.0 {
        xm / gm
    } else {
        1.0
    }
}

/// The single 54-candidate ladder both calibration paths run (bit-exact
/// mirror of the python ladder): power-of-two multiples of `base` in BOTH
/// directions × {1, 0.75, 0.5} fine multipliers, keeping the
/// RMSE-minimizing scale.  Parameterizing over the projection keeps the
/// candidate set and tie rule in exactly one place, so the reference and
/// batched paths cannot drift apart.
fn scale_ladder<F>(x: &[f32], base: f64, sigma: f64, out: &mut [f32],
                   mut project: F) -> f64
where
    F: FnMut(f64, &[f32], &mut [f32]),
{
    // σ depends only on x: callers compute it once, not once per candidate
    let mut best = (base, f64::INFINITY);
    for j in LADDER_EXPS {
        for mult in LADDER_MULTS {
            let s = base * mult * 2f64.powi(-j);
            project(s, x, &mut *out);
            let e = rmse_with_sigma(x, out, sigma);
            if e < best.1 {
                best = (s, e);
            }
        }
    }
    best.0
}

/// RMSE-optimal scale search (bit-exact mirror of the python ladder).
///
/// Scans power-of-two multiples of the max-abs scale in BOTH directions:
/// tapered grids like DyBit often prefer scales *above* max-abs, trading a
/// coarser far tail for a finer dense region near zero.
///
/// Per-element reference path over a raw grid; prefer
/// [`calibrate_scale_lut`] when the `(format, bits)` pair is known — it
/// selects the identical scale through the batched tables.
pub fn calibrate_scale(x: &[f32], grid: &[f64]) -> f64 {
    let base = maxabs_scale(x, grid);
    if base == 0.0 {
        return 1.0;
    }
    let mut buf = vec![0.0f32; x.len()];
    scale_ladder(x, base, sigma_of(x), &mut buf,
                 |s, xs, out| quantize_to_grid(xs, grid, s, out))
}

/// Production [`calibrate_scale`]: the identical ladder evaluated
/// through a freshly built [`CalibView`] — one sort + prefix-sum pass
/// over the tensor, then 54 table-sized candidate evaluations instead
/// of 54 full projection+RMSE passes (DESIGN.md §8; scale selection
/// equivalence incl. the knife-edge tie rule is documented and
/// property-tested in [`super::calib`]).
///
/// When the same tensor is calibrated at several `(format, bits)` —
/// the search engine's cost-table fill, the format-sweep benches —
/// build the [`CalibView`] once and query it directly instead.
pub fn calibrate_scale_lut(x: &[f32], fmt: Format, bits: u32) -> f64 {
    CalibView::new(x).calibrate(fmt, bits)
}

/// Pre-§8 batched ladder: every candidate projected through a locally
/// built [`GridLut`] (bit-exact with [`quantize_to_grid`], so the
/// selected scale is identical to [`calibrate_scale`]'s).  Superseded as
/// the production path by the [`CalibView`] ladder; kept as the second
/// correctness oracle and the "old" side of `benches/perf_calib.rs`.
/// The caller supplies the projection buffer (grown as needed, never
/// shrunk) so repeated oracle runs can reuse one allocation.
pub fn calibrate_scale_projected(x: &[f32], fmt: Format, bits: u32,
                                 buf: &mut Vec<f32>) -> f64 {
    let grid = fmt.grid(bits);
    let base = maxabs_scale(x, &grid);
    if base == 0.0 {
        return 1.0;
    }
    if buf.len() < x.len() {
        buf.resize(x.len(), 0.0);
    }
    scale_ladder(x, base, sigma_of(x), &mut buf[..x.len()], |s, xs, out| {
        GridLut::new(&grid, s).quantize_batch(xs, out)
    })
}

/// Result of quantizing one tensor.
#[derive(Clone, Debug)]
pub struct QuantResult {
    pub scale: f64,
    pub rmse: f64,
}

/// Fake-quantize in place-ish: returns quantized copy + (scale, rmse).
///
/// Runs on the batched [`GridLut`] path (calibration ladder included);
/// output is bit-exact with the per-element reference.
pub fn fake_quant(x: &[f32], fmt: Format, bits: u32,
                  scale: Option<f64>) -> (Vec<f32>, QuantResult) {
    let s = scale.unwrap_or_else(|| calibrate_scale_lut(x, fmt, bits));
    let lut = GridLut::from_format(fmt, bits, s);
    let mut out = vec![0.0f32; x.len()];
    lut.quantize_batch(x, &mut out);
    let e = rmse(x, &out);
    (out, QuantResult { scale: s, rmse: e })
}

/// Per-layer RMSE of a tensor at (fmt, bits) without keeping the output.
pub fn quant_rmse(x: &[f32], fmt: Format, bits: u32) -> f64 {
    quant_rmse_into(x, fmt, bits, &mut Vec::new())
}

/// Allocation-free [`quant_rmse`]: calibrate → project (through the
/// settled-scale cached table) → Eqn. 2, with σ computed exactly once
/// and every projection written into the caller's buffer.  This is the
/// single calibrate-project-score pipeline; the search engine's ranking
/// oracle calls it rather than reimplementing the chain.
///
/// Builds a throwaway [`CalibView`] for the §8 ladder; callers that
/// score the same tensor at several bitwidths (the cost-table fill)
/// should build the view once and use [`quant_rmse_view`].
pub fn quant_rmse_into(x: &[f32], fmt: Format, bits: u32,
                       buf: &mut Vec<f32>) -> f64 {
    quant_rmse_view(x, &CalibView::new(x), fmt, bits, buf)
}

/// [`quant_rmse_into`] with a caller-held [`CalibView`] of `x`, so one
/// sort + prefix-sum pass serves every `(format, bits)` scored on the
/// tensor.  The settled-scale projection and the final Eqn. 2 pass run
/// per-element over `x` in its original order — bit-exact with the
/// reference chain (`engine::tests` asserts this), the ladder only
/// *selects* the scale through the view.
pub fn quant_rmse_view(x: &[f32], view: &CalibView, fmt: Format, bits: u32,
                       buf: &mut Vec<f32>) -> f64 {
    debug_assert_eq!(view.len(), x.len(), "view built from a different tensor");
    let s = view.calibrate(fmt, bits);
    let lut = GridLut::from_format(fmt, bits, s);
    if buf.len() < x.len() {
        buf.resize(x.len(), 0.0);
    }
    let out = &mut buf[..x.len()];
    lut.quantize_batch(x, out);
    rmse_with_sigma(x, out, view.sigma())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, gen};
    use crate::util::rng::Rng;

    #[test]
    fn upper_bound_matches_linear_scan() {
        let v = vec![-1.0, 0.0, 0.5, 0.5, 2.0];
        for x in [-2.0, -1.0, 0.2, 0.5, 1.0, 3.0] {
            let want = v.iter().filter(|&&m| m <= x).count();
            assert_eq!(upper_bound(&v, x), want, "x={x}");
        }
    }

    #[test]
    fn quantize_idempotent() {
        let g = Format::DyBit.grid(4);
        let x: Vec<f32> = vec![0.3, -1.7, 0.0, 2.5, -0.01];
        let mut q1 = vec![0.0; x.len()];
        quantize_to_grid(&x, &g, 0.5, &mut q1);
        let mut q2 = vec![0.0; x.len()];
        quantize_to_grid(&q1, &g, 0.5, &mut q2);
        assert_eq!(q1, q2);
    }

    #[test]
    fn rmse_zero_for_exact() {
        let x = vec![1.0f32, -2.0, 0.0];
        assert_eq!(rmse(&x, &x), 0.0);
    }

    #[test]
    fn calibrated_beats_or_ties_maxabs() {
        let mut rng = Rng::new(11);
        let x = rng.normal_vec(2000);
        for fmt in Format::ALL {
            let g = fmt.grid(4);
            let s_cal = calibrate_scale(&x, &g);
            let s_max = maxabs_scale(&x, &g);
            let mut a = vec![0.0; x.len()];
            let mut b = vec![0.0; x.len()];
            quantize_to_grid(&x, &g, s_cal, &mut a);
            quantize_to_grid(&x, &g, s_max, &mut b);
            assert!(rmse(&x, &a) <= rmse(&x, &b) + 1e-12, "{fmt:?}");
        }
    }

    #[test]
    fn more_bits_never_hurt_rmse() {
        let mut rng = Rng::new(5);
        let x = rng.normal_vec(1500);
        for fmt in [Format::DyBit, Format::Int, Format::Flint] {
            let e4 = quant_rmse(&x, fmt, 4);
            let e8 = quant_rmse(&x, fmt, 8);
            assert!(e8 <= e4 + 1e-9, "{fmt:?}: e8={e8} e4={e4}");
        }
    }

    #[test]
    fn prop_quantized_values_on_grid() {
        check("quantized-on-grid", 60, |r, s| {
            (gen::tensor(r, s), gen::bitwidth(r))
        }, |(x, bits)| {
            let (q, res) = fake_quant(x, Format::DyBit, *bits as u32, None);
            let g = Format::DyBit.grid(*bits as u32);
            q.iter().all(|&v| {
                g.iter().any(|&gv| ((gv * res.scale) as f32 - v).abs() < 1e-30
                    || (gv * res.scale) as f32 == v)
            })
        });
    }

    #[test]
    fn prop_quantization_is_nearest() {
        check("nearest-projection", 40, |r, s| gen::tensor(r, s), |x| {
            let g = Format::DyBit.grid(4);
            let s = 0.37f64;
            let mut q = vec![0.0; x.len()];
            quantize_to_grid(x, &g, s, &mut q);
            x.iter().zip(q.iter()).all(|(&xi, &qi)| {
                let best = g
                    .iter()
                    .map(|&gv| (gv * s - xi as f64).abs())
                    .fold(f64::INFINITY, f64::min);
                ((qi as f64 - xi as f64).abs() - best) < 1e-6
            })
        });
    }

    #[test]
    fn lut_ladder_picks_identical_scale() {
        let mut rng = Rng::new(77);
        let x = rng.normal_vec(1200);
        let mut buf = Vec::new();
        for fmt in Format::ALL {
            for bits in [3u32, 4, 8] {
                if !fmt.supports(bits) {
                    continue;
                }
                let grid = fmt.grid(bits);
                let s_ref = calibrate_scale(&x, &grid);
                let s_lut = calibrate_scale_lut(&x, fmt, bits);
                assert_eq!(s_ref, s_lut, "{fmt:?} bits={bits}");
                let s_proj = calibrate_scale_projected(&x, fmt, bits, &mut buf);
                assert_eq!(s_ref, s_proj, "{fmt:?} bits={bits} (projected)");
            }
        }
    }

    #[test]
    fn fake_quant_matches_reference_path() {
        let mut rng = Rng::new(123);
        let x = rng.normal_vec(2000);
        for fmt in [Format::DyBit, Format::Int, Format::Posit] {
            let grid = fmt.grid(4);
            let (q, res) = fake_quant(&x, fmt, 4, None);
            let mut want = vec![0.0f32; x.len()];
            quantize_to_grid(&x, &grid, res.scale, &mut want);
            assert_eq!(q, want, "{fmt:?}");
            assert_eq!(res.scale, calibrate_scale(&x, &grid), "{fmt:?}");
        }
    }

    #[test]
    fn dybit_beats_int_on_heavy_tails() {
        // the paper's core claim at the metric level (Fig. 2 narrative)
        let mut rng = Rng::new(2024);
        let x: Vec<f32> = (0..4000)
            .map(|_| {
                let v = rng.normal();
                (v * (1.0 + 2.0 * rng.uniform().powi(4) * 5.0)) as f32
            })
            .collect();
        let d = quant_rmse(&x, Format::DyBit, 4);
        let i = quant_rmse(&x, Format::Int, 4);
        assert!(d < i, "dybit {d} vs int {i}");
    }
}
