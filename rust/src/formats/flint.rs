//! Flint grid — reconstruction of ANT's float-int hybrid [Guo et al. 2022],
//! the paper's closest competitor (Table II row Flint(4/4)).
//!
//! A literal leading-zero unary-exponent reading of flint degenerates to a
//! *uniform* grid at 4 bits (contradicting ANT's own results), so we
//! reconstruct it as the nearest well-defined member of the same tapered
//! family: a minifloat with subnormals, es = ceil((n-1)/2) exponent bits
//! and n-1-es mantissa bits.  Bit-exact mirror of python formats.py;
//! rationale documented in DESIGN.md §6.

/// Positive magnitudes (bias 0).
fn magnitudes(n: u32) -> Vec<f64> {
    let es = n / 2; // == ceil((n-1)/2) for n >= 2
    let mb = n - 1 - es;
    assert!(mb >= 1, "flint reconstruction needs >=1 mantissa bit");
    let mut vals = Vec::new();
    for f in 1..(1u32 << mb) {
        // subnormals: (f / 2^mb) * 2^1  (E = 0 shares the first binade)
        vals.push(f as f64 / (1u64 << mb) as f64 * 2.0);
    }
    for exp in 1..(1u32 << es) {
        for f in 0..(1u32 << mb) {
            vals.push(2f64.powi(exp as i32) * (1.0 + f as f64 / (1u64 << mb) as f64));
        }
    }
    vals
}

/// Sorted signed grid at scale 1.0.
pub fn grid(n: u32) -> Vec<f64> {
    let mut pos = magnitudes(n);
    pos.sort_by(|a, b| a.total_cmp(b));
    pos.dedup();
    let mut g: Vec<f64> = pos.iter().rev().map(|v| -v).collect();
    g.push(0.0);
    g.extend_from_slice(&pos);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flint4_values() {
        assert_eq!(
            grid(4),
            vec![-12.0, -8.0, -6.0, -4.0, -3.0, -2.0, -1.0, 0.0,
                 1.0, 2.0, 3.0, 4.0, 6.0, 8.0, 12.0]
        );
    }

    #[test]
    fn tapered_not_uniform_at_4bit() {
        // the defining fix vs the degenerate literal reading
        let g = grid(4);
        let steps: Vec<f64> = g.windows(2).map(|w| w[1] - w[0]).collect();
        let uniform = steps.iter().all(|s| (*s - steps[0]).abs() < 1e-12);
        assert!(!uniform);
    }

    #[test]
    fn symmetric_monotone() {
        for n in 3..=8u32 {
            let g = grid(n);
            for w in g.windows(2) {
                assert!(w[0] < w[1], "n={n}");
            }
            for (a, b) in g.iter().zip(g.iter().rev()) {
                assert_eq!(*a, -b);
            }
        }
    }
}
