//! Layer descriptors consumed by the simulator and the search engine.
//!
//! One descriptor per quantizable layer, in model order — the same order
//! the HLO qcfg inputs (wluts/aluts/…) use, so search results map 1:1 to
//! runtime configs.  Descriptors are read from `artifacts/manifest.json`
//! (emitted by the python build pass from the very same model definitions
//! that were lowered — python and rust cannot disagree).

use anyhow::{anyhow, Result};

use crate::util::json::Json;

/// Layer kind; determines GEMM mapping efficiency on the systolic array.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    /// Dense conv (im2col GEMM, fully efficient).
    Conv,
    /// Depthwise conv: block-diagonal weights densified by the GEMM
    /// dataflow — the reason MobileNet speedup saturates (paper Fig. 6).
    DwConv,
    /// Grouped conv: G sequential sub-GEMMs.
    GConv,
    /// Fully-connected / attention projection.
    Dense,
}

impl LayerKind {
    pub fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "conv" => LayerKind::Conv,
            "dwconv" => LayerKind::DwConv,
            "gconv" => LayerKind::GConv,
            "dense" => LayerKind::Dense,
            other => return Err(anyhow!("unknown layer kind '{other}'")),
        })
    }
}

/// GEMM-shaped layer (post-im2col geometry, per image).
#[derive(Clone, Debug)]
pub struct LayerShape {
    pub name: String,
    pub kind: LayerKind,
    /// GEMM rows per image (OH·OW for convs, token count or 1 for dense).
    pub m: usize,
    /// Reduction length (kh·kw·cin/groups).
    pub k: usize,
    /// Output channels.
    pub n: usize,
    pub groups: usize,
    /// Per-image MACs.
    pub macs: u64,
    /// Per-image input activation element count (memory traffic).
    pub act_elems: usize,
}

impl LayerShape {
    /// Parse one entry of the manifest's `layers` array.
    pub fn from_json(j: &Json) -> Result<Self> {
        let field = |k: &str| {
            j.get(k)
                .ok_or_else(|| anyhow!("layer json missing '{k}'"))
        };
        Ok(LayerShape {
            name: field("name")?
                .as_str()
                .ok_or_else(|| anyhow!("name not a string"))?
                .to_string(),
            kind: LayerKind::from_str(
                field("kind")?.as_str().ok_or_else(|| anyhow!("kind"))?,
            )?,
            m: field("m")?.as_usize().ok_or_else(|| anyhow!("m"))?,
            k: field("k")?.as_usize().ok_or_else(|| anyhow!("k"))?,
            n: field("n")?.as_usize().ok_or_else(|| anyhow!("n"))?,
            groups: field("groups")?.as_usize().ok_or_else(|| anyhow!("groups"))?,
            macs: field("macs")?.as_i64().ok_or_else(|| anyhow!("macs"))? as u64,
            act_elems: field("act_elems")?
                .as_usize()
                .ok_or_else(|| anyhow!("act_elems"))?,
        })
    }

    /// Convenience constructor for tests/benches.
    pub fn gemm(name: &str, m: usize, k: usize, n: usize) -> Self {
        LayerShape {
            name: name.to_string(),
            kind: LayerKind::Dense,
            m,
            k,
            n,
            groups: 1,
            macs: (m * k * n) as u64,
            act_elems: m * k,
        }
    }

    /// The GEMM(s) the systolic dataflow actually executes.
    ///
    /// Depthwise/grouped convs run as `groups` sequential sub-GEMMs of
    /// (m, k, n/groups) — the GEMM dataflow cannot batch independent
    /// channel groups across the array, so a depthwise layer becomes C
    /// tiny (m × 9 × 1) GEMMs whose cost is dominated by streaming and
    /// fill/drain, NOT by MACs.  Lowering precision therefore barely helps
    /// them, which is exactly why MobileNetV2's end-to-end speedup
    /// saturates in the paper ("depth-wise operations are not efficient
    /// based on our current GEMM systolic array", Sec. IV-C).
    pub fn executed_gemms(&self) -> (usize, (usize, usize, usize)) {
        match self.kind {
            LayerKind::DwConv | LayerKind::GConv => {
                (self.groups, (self.m, self.k, self.n / self.groups))
            }
            _ => (1, (self.m, self.k, self.n)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    #[test]
    fn parse_roundtrip() {
        let j = parse(
            r#"{"name":"s0b0.c1","kind":"conv","m":576,"k":144,"n":16,
                "groups":1,"macs":1327104,"act_elems":9216}"#,
        )
        .unwrap();
        let l = LayerShape::from_json(&j).unwrap();
        assert_eq!(l.name, "s0b0.c1");
        assert_eq!(l.kind, LayerKind::Conv);
        assert_eq!((l.m, l.k, l.n), (576, 144, 16));
    }

    #[test]
    fn missing_field_is_error() {
        let j = parse(r#"{"name":"x"}"#).unwrap();
        assert!(LayerShape::from_json(&j).is_err());
    }

    #[test]
    fn dwconv_densifies() {
        let l = LayerShape {
            name: "dw".into(),
            kind: LayerKind::DwConv,
            m: 100,
            k: 9,
            n: 64,
            groups: 64,
            macs: 100 * 9 * 64,
            act_elems: 100 * 64,
        };
        let (count, (m, k, n)) = l.executed_gemms();
        assert_eq!(count, 64); // one tiny GEMM per channel
        assert_eq!((m, k, n), (100, 9, 1));
    }

    #[test]
    fn gconv_splits() {
        let l = LayerShape {
            name: "g".into(),
            kind: LayerKind::GConv,
            m: 64,
            k: 18,
            n: 48,
            groups: 8,
            macs: 0,
            act_elems: 0,
        };
        let (count, (_, _, n)) = l.executed_gemms();
        assert_eq!(count, 8);
        assert_eq!(n, 6);
    }
}
