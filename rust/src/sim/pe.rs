//! Mixed-precision processing element model (paper Fig. 3c).
//!
//! The PE fuses a BitFusion-style composable mantissa multiplier: an 8×8
//! unit decomposes into sixteen 2×2 units, so a (Pw, Pa) mode executes
//! 64/(Pw·Pa) multiplies per cycle per PE.  At the array level the paper
//! states the equivalent scaling: an N×N array in P1×P2 mode behaves like
//! an (8/P1)N × (8/P2)N array.  The exponent adder reuses the carry chain
//! across widths (Sec. III-B3) and does not change throughput.

/// Supported operand precisions (Sec. III-C3: 8/4/2 only, to avoid
/// off-chip alignment overhead of non-power-of-2 widths).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Prec {
    B2 = 2,
    B4 = 4,
    B8 = 8,
}

impl Prec {
    pub const ALL: [Prec; 3] = [Prec::B8, Prec::B4, Prec::B2];

    pub fn bits(&self) -> u32 {
        *self as u32
    }

    pub fn from_bits(b: u32) -> Option<Prec> {
        match b {
            2 => Some(Prec::B2),
            4 => Some(Prec::B4),
            8 => Some(Prec::B8),
            _ => None,
        }
    }

    /// Next lower precision (Algorithm 1's DEGRADE_LEVEL: 8 -> 4 -> 2).
    pub fn degrade(&self) -> Option<Prec> {
        match self {
            Prec::B8 => Some(Prec::B4),
            Prec::B4 => Some(Prec::B2),
            Prec::B2 => None,
        }
    }
}

/// Per-PE multiply throughput multiplier in (pw, pa) mode.
pub fn fusion_factor(base_bits: u32, pw: Prec, pa: Prec) -> u64 {
    ((base_bits / pw.bits()) * (base_bits / pa.bits())) as u64
}

/// Effective array dimensions for an n×n array in (pw, pa) mode:
/// (rows scale with the activation precision, cols with the weight
/// precision — matching "(8/P1)N × (8/P2)N" in Sec. III-B3).
pub fn effective_array(n: usize, base_bits: u32, pw: Prec, pa: Prec) -> (usize, usize) {
    (
        n * (base_bits / pa.bits()) as usize,
        n * (base_bits / pw.bits()) as usize,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fusion_factors_match_bitfusion() {
        assert_eq!(fusion_factor(8, Prec::B8, Prec::B8), 1);
        assert_eq!(fusion_factor(8, Prec::B4, Prec::B8), 2);
        assert_eq!(fusion_factor(8, Prec::B4, Prec::B4), 4);
        assert_eq!(fusion_factor(8, Prec::B2, Prec::B4), 8);
        assert_eq!(fusion_factor(8, Prec::B2, Prec::B2), 16);
    }

    #[test]
    fn effective_array_scaling() {
        // paper: N×N in P1×P2 mode == (8/P1)N × (8/P2)N
        let (r, c) = effective_array(16, 8, Prec::B4, Prec::B2);
        assert_eq!((r, c), (64, 32));
        let (r, c) = effective_array(16, 8, Prec::B8, Prec::B8);
        assert_eq!((r, c), (16, 16));
    }

    #[test]
    fn degrade_chain() {
        assert_eq!(Prec::B8.degrade(), Some(Prec::B4));
        assert_eq!(Prec::B4.degrade(), Some(Prec::B2));
        assert_eq!(Prec::B2.degrade(), None);
    }

    #[test]
    fn prec_roundtrip() {
        for p in Prec::ALL {
            assert_eq!(Prec::from_bits(p.bits()), Some(p));
        }
        assert_eq!(Prec::from_bits(6), None);
    }
}
