//! Hardware configuration for the mixed-precision accelerator simulator.
//!
//! The paper implements on a Xilinx ZCU102 (Sec. IV-A3); `HwConfig::zcu102`
//! is the default preset.  `from_resources` reproduces the framework's
//! first step (Fig. 4): "estimate the maximum hardware resource utilization
//! based on the DNN models and given hardware constraints (e.g., LUTs and
//! BRAMs in FPGAs)" — it sizes the largest array + buffers that fit.

/// Static accelerator parameters (all sizes in the 8-bit baseline mode).
#[derive(Clone, Debug)]
pub struct HwConfig {
    /// Systolic array is `array_n` × `array_n` fused PEs (8-bit mode).
    pub array_n: usize,
    /// Clock in MHz (latency reporting only; ratios are clock-free).
    pub freq_mhz: f64,
    /// Input-feature buffer bytes.
    pub if_bytes: usize,
    /// Weight buffer bytes.
    pub w_bytes: usize,
    /// Output-feature buffer bytes (FP32 partial sums, Fig. 3a).
    pub of_bytes: usize,
    /// External memory bandwidth, bytes per cycle.
    pub dram_bytes_per_cycle: f64,
    /// Pipeline latency of the shared MP decoder (cycles; Fig. 3b).
    pub decoder_lat: u64,
    /// Pipeline latency of the output encoder (cycles).
    pub encoder_lat: u64,
    /// Fixed per-layer setup cycles (instruction dispatch, mode switch).
    pub layer_setup: u64,
    /// Baseline operand precision the PE fuses from (8 = four 2-bit units).
    pub base_bits: u32,
}

impl HwConfig {
    /// ZCU102 preset: 16×16 fused PEs @ 200 MHz, 1 MiB IF / 1 MiB W /
    /// 512 KiB OF buffers out of the part's ~4 MiB BRAM, DDR4 ~19.2 GB/s.
    pub fn zcu102() -> Self {
        HwConfig {
            array_n: 16,
            freq_mhz: 200.0,
            if_bytes: 1 << 20,
            w_bytes: 1 << 20,
            of_bytes: 512 << 10,
            dram_bytes_per_cycle: 19.2e9 / 200.0e6, // 96 B/cycle
            decoder_lat: 2,
            encoder_lat: 2,
            layer_setup: 64,
            base_bits: 8,
        }
    }

    /// Size the maximum architecture from FPGA resource constraints
    /// (the estimator stage of Fig. 4).  `luts_per_pe` covers the fused
    /// multiplier + exponent adder; BRAM is split 2:2:1 IF:W:OF.
    pub fn from_resources(luts: usize, bram_bytes: usize) -> Self {
        const LUTS_PER_PE: usize = 900; // fused 8x8 MP multiplier + adders
        let mut n = 2;
        while (n * 2) * (n * 2) * LUTS_PER_PE <= luts {
            n *= 2;
        }
        let b = bram_bytes / 5;
        HwConfig {
            array_n: n,
            if_bytes: 2 * b,
            w_bytes: 2 * b,
            of_bytes: b,
            ..HwConfig::zcu102()
        }
    }

    /// Seconds per cycle.
    pub fn cycle_time(&self) -> f64 {
        1.0 / (self.freq_mhz * 1e6)
    }
}

impl Default for HwConfig {
    fn default() -> Self {
        HwConfig::zcu102()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zcu102_sane() {
        let c = HwConfig::zcu102();
        assert_eq!(c.array_n, 16);
        assert!((c.dram_bytes_per_cycle - 96.0).abs() < 1e-9);
        assert!(c.cycle_time() > 0.0);
    }

    #[test]
    fn from_resources_scales_array() {
        // ZCU102-class: ~274k LUTs -> 16x16; a small part -> smaller array
        let big = HwConfig::from_resources(274_000, 4 << 20);
        assert_eq!(big.array_n, 16);
        let small = HwConfig::from_resources(40_000, 1 << 20);
        assert!(small.array_n < big.array_n);
        assert!(small.if_bytes < big.if_bytes);
    }

    #[test]
    fn resource_estimator_monotone() {
        let mut prev = 0;
        for luts in [10_000, 60_000, 250_000, 1_000_000] {
            let c = HwConfig::from_resources(luts, 4 << 20);
            assert!(c.array_n >= prev);
            prev = c.array_n;
        }
    }
}
