//! Whole-model simulator: per-layer and end-to-end latency at a given
//! layer-wise precision assignment (the simulator block of Fig. 4).
//!
//! Results are memoized per (layer, pw, pa) for ad-hoc queries.  The
//! search engine no longer re-queries cells while degrading bitwidths:
//! it batch-fills the whole cost surface up front through the pure
//! [`cell_cycles`] / [`Simulator::fill_cell_table`] API, which bypasses
//! the per-call HashMap hash entirely (§Perf, DESIGN.md §7).

use std::collections::HashMap;

use super::config::HwConfig;
use super::layer::{LayerKind, LayerShape};
use super::pe::Prec;
use super::systolic::{gemm_cycles, Cycles};

/// Per-layer precision assignment (weights, activations) in layer order.
pub type Assignment = Vec<(Prec, Prec)>;

/// All-8-bit baseline assignment (the paper's latency/RMSE reference).
pub fn baseline_assignment(n_layers: usize) -> Assignment {
    vec![(Prec::B8, Prec::B8); n_layers]
}

/// Simulator with memoized per-layer results.
pub struct Simulator {
    pub cfg: HwConfig,
    pub layers: Vec<LayerShape>,
    /// Images per inference request (M scales with batch).
    pub batch: usize,
    cache: HashMap<(usize, Prec, Prec), Cycles>,
}

/// End-to-end simulation result.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub per_layer: Vec<Cycles>,
    pub total_cycles: u64,
    pub total_bytes: u64,
    pub latency_s: f64,
}

impl Simulator {
    pub fn new(cfg: HwConfig, layers: Vec<LayerShape>, batch: usize) -> Self {
        Simulator { cfg, layers, batch, cache: HashMap::new() }
    }

    /// Cycles for one layer at (pw, pa); memoized.
    pub fn layer_cycles(&mut self, idx: usize, pw: Prec, pa: Prec) -> Cycles {
        if let Some(c) = self.cache.get(&(idx, pw, pa)) {
            return *c;
        }
        let c = cell_cycles(&self.cfg, &self.layers[idx], self.batch, pw, pa);
        self.cache.insert((idx, pw, pa), c);
        c
    }

    /// Batch cell-fill (DESIGN.md §7): the dense `layers × |Prec|²` cost
    /// surface in layer-major, [`Prec::ALL`] × [`Prec::ALL`] cell order,
    /// computed without touching the per-call memoization HashMap.
    pub fn fill_cell_table(&self) -> Vec<Cycles> {
        self.layers
            .iter()
            .flat_map(|l| cell_row(&self.cfg, l, self.batch))
            .collect()
    }

    /// Full-model simulation under a layer-wise assignment.
    pub fn run(&mut self, assign: &Assignment) -> SimResult {
        assert_eq!(assign.len(), self.layers.len());
        let per_layer: Vec<Cycles> = assign
            .iter()
            .enumerate()
            .map(|(i, &(pw, pa))| self.layer_cycles(i, pw, pa))
            .collect();
        let total_cycles: u64 = per_layer.iter().map(|c| c.total).sum();
        let total_bytes: u64 = per_layer.iter().map(|c| c.bytes).sum();
        SimResult {
            latency_s: total_cycles as f64 * self.cfg.cycle_time(),
            per_layer,
            total_cycles,
            total_bytes,
        }
    }

    /// Speedup of `assign` over the all-8-bit baseline (the paper's
    /// headline metric; Sec. III-C2 "8-bit DyBit as the baseline").
    pub fn speedup(&mut self, assign: &Assignment) -> f64 {
        let base = self.run(&baseline_assignment(self.layers.len()));
        let got = self.run(assign);
        base.total_cycles as f64 / got.total_cycles as f64
    }

    /// True if this layer kind wastes array slots (dw densification).
    pub fn layer_is_dw(&self, idx: usize) -> bool {
        self.layers[idx].kind == LayerKind::DwConv
    }
}

/// One layer's dense |Prec|² cost row in [`Prec::ALL`] × [`Prec::ALL`]
/// cell order — the single source of truth for the cost-table cell
/// layout (DESIGN.md §7): [`Simulator::fill_cell_table`] and the search
/// engine's parallel per-layer fill both go through it.
pub fn cell_row(cfg: &HwConfig, layer: &LayerShape, batch: usize) -> Vec<Cycles> {
    let mut out = Vec::with_capacity(Prec::ALL.len() * Prec::ALL.len());
    for pw in Prec::ALL {
        for pa in Prec::ALL {
            out.push(cell_cycles(cfg, layer, batch, pw, pa));
        }
    }
    out
}

/// Pure per-cell cycle computation — [`Simulator::layer_cycles`] minus
/// the memoization.  Takes no `&mut`, so the search's cost-table fill
/// (DESIGN.md §7) can evaluate independent cells from parallel worker
/// threads and skip the per-call HashMap hash entirely.
pub fn cell_cycles(cfg: &HwConfig, layer: &LayerShape, batch: usize,
                   pw: Prec, pa: Prec) -> Cycles {
    let (count, (m, k, n)) = layer.executed_gemms();
    let m = m * batch;
    let one = gemm_cycles(cfg, m, k, n, pw, pa);
    if count == 1 {
        one
    } else {
        // grouped conv: sequential sub-GEMMs, setup amortized once
        let count = count as u64;
        Cycles {
            compute: one.compute * count,
            dram: one.dram * count,
            overhead: one.overhead,
            total: (one.total - one.overhead) * count + one.overhead,
            utilization: one.utilization,
            bytes: one.bytes * count,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layers() -> Vec<LayerShape> {
        vec![
            LayerShape::gemm("a", 576, 144, 64),
            LayerShape::gemm("b", 576, 576, 128),
            LayerShape::gemm("c", 1, 128, 10),
        ]
    }

    #[test]
    fn baseline_speedup_is_one() {
        let mut sim = Simulator::new(HwConfig::zcu102(), layers(), 1);
        let a = baseline_assignment(3);
        assert!((sim.speedup(&a) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn lower_bits_speed_up_e2e() {
        let mut sim = Simulator::new(HwConfig::zcu102(), layers(), 1);
        let all4 = vec![(Prec::B4, Prec::B4); 3];
        let s = sim.speedup(&all4);
        assert!(s > 1.5, "speedup {s}");
        let all2 = vec![(Prec::B2, Prec::B2); 3];
        assert!(sim.speedup(&all2) > s);
    }

    #[test]
    fn batch_cell_fill_matches_memoized_path() {
        let mut sim = Simulator::new(HwConfig::zcu102(), layers(), 1);
        let table = sim.fill_cell_table();
        assert_eq!(table.len(), 3 * Prec::ALL.len() * Prec::ALL.len());
        let mut k = 0;
        for i in 0..3 {
            for pw in Prec::ALL {
                for pa in Prec::ALL {
                    let c = sim.layer_cycles(i, pw, pa);
                    assert_eq!(c.total, table[k].total, "{i} {pw:?} {pa:?}");
                    assert_eq!(c.bytes, table[k].bytes);
                    k += 1;
                }
            }
        }
    }

    #[test]
    fn memoization_consistent() {
        let mut sim = Simulator::new(HwConfig::zcu102(), layers(), 1);
        let c1 = sim.layer_cycles(0, Prec::B4, Prec::B8);
        let c2 = sim.layer_cycles(0, Prec::B4, Prec::B8);
        assert_eq!(c1.total, c2.total);
    }

    #[test]
    fn batch_scales_latency() {
        let mut s1 = Simulator::new(HwConfig::zcu102(), layers(), 1);
        let mut s8 = Simulator::new(HwConfig::zcu102(), layers(), 8);
        let a = baseline_assignment(3);
        let r1 = s1.run(&a);
        let r8 = s8.run(&a);
        assert!(r8.total_cycles > 4 * r1.total_cycles);
        assert!(r8.total_cycles < 16 * r1.total_cycles);
    }

    #[test]
    fn dwconv_gains_less_than_conv() {
        // the Fig. 6 phenomenon: depthwise densification caps the benefit
        let dw = LayerShape {
            name: "dw".into(),
            kind: LayerKind::DwConv,
            m: 576,
            k: 9,
            n: 64,
            groups: 64,
            macs: (576 * 9 * 64) as u64,
            act_elems: 576 * 64,
        };
        let conv = LayerShape::gemm("conv", 576, 9 * 64, 64);
        let mut sim = Simulator::new(HwConfig::zcu102(), vec![dw, conv], 1);
        let dw8 = sim.layer_cycles(0, Prec::B8, Prec::B8);
        let dw4 = sim.layer_cycles(0, Prec::B4, Prec::B4);
        let cv8 = sim.layer_cycles(1, Prec::B8, Prec::B8);
        let cv4 = sim.layer_cycles(1, Prec::B4, Prec::B4);
        let dw_gain = dw8.total as f64 / dw4.total as f64;
        let cv_gain = cv8.total as f64 / cv4.total as f64;
        assert!(dw_gain <= cv_gain + 1e-9, "dw {dw_gain} vs conv {cv_gain}");
        // and the dw layer wastes utilization
        assert!(dw4.utilization <= cv4.utilization);
    }
}
