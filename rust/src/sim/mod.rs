//! Cycle-accurate simulator of the paper's run-time configurable
//! mixed-precision systolic accelerator (Fig. 3): fused BitFusion-style
//! PEs, shared MP decoders/encoders, double-buffered tiling over IF/W/OF
//! buffers, DRAM bandwidth model.  Drives the hardware-aware search
//! (Fig. 4) and regenerates the speedup axes of Fig. 5/6.

pub mod config;
pub mod layer;
pub mod pe;
pub mod simulator;
pub mod systolic;

pub use config::HwConfig;
pub use layer::{LayerKind, LayerShape};
pub use pe::Prec;
pub use simulator::{baseline_assignment, cell_cycles, cell_row, Assignment, SimResult,
                    Simulator};
pub use systolic::Cycles;
