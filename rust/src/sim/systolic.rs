//! Cycle model of one GEMM on the mixed-precision systolic array.
//!
//! Weight-stationary dataflow (paper Fig. 3a): a (Tk × Tn) weight tile is
//! loaded into the effective array, activations stream row by row through
//! the shared MP decoders, FP partial sums accumulate in the OF buffer,
//! and outputs are re-encoded to DyBit on writeback.  Double buffering
//! overlaps DRAM traffic with compute: per-layer latency is
//! `max(compute_cycles, dram_cycles) + pipeline constants`.
//!
//! The tiling loop enumerates every schedule (M-tile size × loop order)
//! that fits the buffers and keeps the best — reproducing Sec. III-C4:
//! "obtains the optimal latency by calculating the latencies corresponding
//! to all possible tiling schedules of the current layer".

use super::config::HwConfig;
use super::pe::{effective_array, Prec};

/// Cycle breakdown of one layer at one (pw, pa) mode.
#[derive(Clone, Copy, Debug, Default)]
pub struct Cycles {
    pub compute: u64,
    pub dram: u64,
    pub overhead: u64,
    pub total: u64,
    /// MAC-slot utilization of the effective array in [0, 1].
    pub utilization: f64,
    /// DRAM bytes moved (weights + activations + writeback).
    pub bytes: u64,
}

/// Loop orders the schedule enumerator considers.
///
/// * `WeightStationary`: weights fetched once; activations re-streamed
///   once per N-tile unless the IF buffer holds the whole input.
/// * `OutputStationary`: activations fetched once; weights re-streamed
///   once per M-tile pass unless the W buffer holds the whole layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LoopOrder {
    WeightStationary,
    OutputStationary,
}

/// Latency of a dense (m, k, n) GEMM in (pw, pa) mode under the best
/// tiling schedule.  `m` already includes the batch dimension.
pub fn gemm_cycles(cfg: &HwConfig, m: usize, k: usize, n: usize,
                   pw: Prec, pa: Prec) -> Cycles {
    let (rows_eff, cols_eff) = effective_array(cfg.array_n, cfg.base_bits, pw, pa);
    let kt = k.div_ceil(rows_eff); // K tiles (array rows)
    let nt = n.div_ceil(cols_eff); // N tiles (array cols)

    let mut best = Cycles { total: u64::MAX, ..Default::default() };
    for order in [LoopOrder::WeightStationary, LoopOrder::OutputStationary] {
        // Enumerate M-tile sizes (powers of two + exact m).
        let mut tm = 8usize;
        loop {
            let tm_eff = tm.min(m);
            if fits_buffers(cfg, tm_eff, rows_eff, cols_eff, pw, pa) {
                let c = schedule_cycles(
                    cfg, m, k, n, pw, pa, rows_eff, cols_eff, kt, nt, tm_eff, order,
                );
                if c.total < best.total {
                    best = c;
                }
            }
            if tm >= m {
                break;
            }
            tm *= 2;
        }
    }
    best
}

fn fits_buffers(cfg: &HwConfig, tm: usize, rows_eff: usize, cols_eff: usize,
                pw: Prec, pa: Prec) -> bool {
    // IF tile: tm × rows_eff activations at pa bits (double-buffered ×2)
    let if_need = 2 * tm * rows_eff * pa.bits() as usize / 8;
    // W tile: rows_eff × cols_eff weights at pw bits (double-buffered ×2)
    let w_need = 2 * rows_eff * cols_eff * pw.bits() as usize / 8;
    // OF tile: tm × cols_eff FP32 partial sums
    let of_need = tm * cols_eff * 4;
    if_need <= cfg.if_bytes && w_need <= cfg.w_bytes && of_need <= cfg.of_bytes
}

#[allow(clippy::too_many_arguments)]
fn schedule_cycles(cfg: &HwConfig, m: usize, k: usize, n: usize,
                   pw: Prec, pa: Prec, rows_eff: usize, cols_eff: usize,
                   kt: usize, nt: usize, tm: usize,
                   order: LoopOrder) -> Cycles {
    let mt = m.div_ceil(tm);

    // --- compute: per (K,N,M) tile pass --------------------------------
    // load weight tile into the array (one row per cycle, cols parallel),
    // then stream tm activation rows; fill+drain = rows+cols pipeline.
    // Edge tiles occupy fewer rows/cols: use the average tile extent so a
    // K=9 depthwise channel does not pay for 16 weight-load cycles.
    let row_ext = k.div_ceil(kt).min(rows_eff) as u64;
    let col_ext = n.div_ceil(nt).min(cols_eff) as u64;
    let w_load = row_ext;
    let stream = tm as u64;
    let fill_drain = row_ext + col_ext;
    let per_pass = w_load + stream + fill_drain + cfg.decoder_lat + cfg.encoder_lat;
    let passes = (kt * nt * mt) as u64;
    let compute = per_pass * passes;

    // --- DRAM traffic ----------------------------------------------------
    let wbits = pw.bits() as u64;
    let abits = pa.bits() as u64;
    let w_bytes_once = (k * n) as u64 * wbits / 8;
    let a_bytes_once = (m * k) as u64 * abits / 8;
    // writeback re-encoded at 8-bit DyBit (next layer may read any width)
    let o_bytes = (m * n) as u64;

    let (w_bytes, a_bytes) = match order {
        LoopOrder::WeightStationary => {
            // weights once; activations re-fetched per N tile unless the
            // whole input fits the IF buffer
            let whole_input = (m * k) as u64 * abits / 8;
            let refetch = if whole_input <= cfg.if_bytes as u64 { 1 } else { nt as u64 };
            (w_bytes_once, a_bytes_once * refetch)
        }
        LoopOrder::OutputStationary => {
            // activations once; weights re-fetched per M tile pass unless
            // the whole layer fits the W buffer
            let whole_w = (k * n) as u64 * wbits / 8;
            let refetch = if whole_w <= cfg.w_bytes as u64 { 1 } else { mt as u64 };
            (w_bytes_once * refetch, a_bytes_once)
        }
    };
    let bytes = w_bytes + a_bytes + o_bytes;
    let dram = (bytes as f64 / cfg.dram_bytes_per_cycle).ceil() as u64;

    // --- total: double-buffered overlap + per-layer setup ----------------
    let overhead = cfg.layer_setup;
    let total = compute.max(dram) + overhead;

    let ideal_macs = (m * k * n) as u64;
    let slots = compute.max(1) * (rows_eff * cols_eff) as u64;
    Cycles {
        compute,
        dram,
        overhead,
        total,
        utilization: (ideal_macs as f64 / slots as f64).min(1.0),
        bytes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> HwConfig {
        HwConfig::zcu102()
    }

    #[test]
    fn lower_precision_is_faster_compute_bound() {
        // big GEMM -> compute-bound; 4/4 should approach 4x over 8/8
        let c = cfg();
        let c88 = gemm_cycles(&c, 4096, 1024, 1024, Prec::B8, Prec::B8);
        let c44 = gemm_cycles(&c, 4096, 1024, 1024, Prec::B4, Prec::B4);
        let c22 = gemm_cycles(&c, 4096, 1024, 1024, Prec::B2, Prec::B2);
        let s44 = c88.total as f64 / c44.total as f64;
        let s22 = c88.total as f64 / c22.total as f64;
        assert!(s44 > 2.5 && s44 <= 4.5, "4/4 speedup {s44}");
        assert!(s22 > s44, "2/2 ({s22}) should beat 4/4 ({s44})");
    }

    #[test]
    fn asymmetric_modes_scale_one_axis() {
        let c = cfg();
        let c88 = gemm_cycles(&c, 2048, 2048, 2048, Prec::B8, Prec::B8);
        let c48 = gemm_cycles(&c, 2048, 2048, 2048, Prec::B4, Prec::B8);
        let s = c88.total as f64 / c48.total as f64;
        assert!(s > 1.4 && s < 2.6, "4W8A speedup {s}");
    }

    #[test]
    fn tiny_gemm_dominated_by_overhead() {
        let c = cfg();
        let t = gemm_cycles(&c, 1, 8, 8, Prec::B8, Prec::B8);
        assert!(t.overhead > 0);
        assert!(t.utilization < 0.05);
    }

    #[test]
    fn memory_bound_layer_weight_bits_cut_traffic() {
        // FC layer: m small, k·n big -> weight traffic dominates bytes;
        // lowering weight bits shrinks traffic ~proportionally and helps
        // the end-to-end latency.
        let c = cfg();
        let w8 = gemm_cycles(&c, 8, 4096, 4096, Prec::B8, Prec::B8);
        let w2 = gemm_cycles(&c, 8, 4096, 4096, Prec::B2, Prec::B8);
        assert!(w2.bytes < w8.bytes / 3, "{} vs {}", w2.bytes, w8.bytes);
        assert!(w2.total < w8.total);
        assert!(w2.dram < w8.dram / 3);
    }

    #[test]
    fn cycles_monotone_in_problem_size() {
        let c = cfg();
        let small = gemm_cycles(&c, 64, 64, 64, Prec::B8, Prec::B8);
        let big = gemm_cycles(&c, 128, 128, 128, Prec::B8, Prec::B8);
        assert!(big.total > small.total);
    }

    #[test]
    fn utilization_bounded() {
        let c = cfg();
        for (m, k, n) in [(1, 1, 1), (100, 3, 1000), (4096, 4096, 4096)] {
            let r = gemm_cycles(&c, m, k, n, Prec::B4, Prec::B4);
            assert!(r.utilization >= 0.0 && r.utilization <= 1.0);
        }
    }
}
