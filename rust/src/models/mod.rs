//! Model descriptors for the simulator/search when artifacts are present
//! (manifest-backed) or absent (built-in synthetic stand-ins for tests and
//! sim-only benches).

use crate::runtime::Manifest;
use crate::sim::{LayerKind, LayerShape};

/// Layer descriptors for `model` from the manifest (authoritative: these
/// are emitted by the same python pass that lowered the HLO).
pub fn from_manifest(manifest: &Manifest, model: &str) -> Option<Vec<LayerShape>> {
    manifest.models.get(model).map(|e| e.layers.clone())
}

/// A synthetic ResNet-like layer stack for simulator tests/benches that
/// must run without artifacts: `depth` conv layers with stage-wise widths.
pub fn synthetic_resnet(depth: usize) -> Vec<LayerShape> {
    let mut layers = Vec::new();
    let mut hw = 24usize;
    let mut c = 16usize;
    layers.push(conv("stem", hw, 3, c, 3));
    for i in 0..depth {
        if i > 0 && i % (depth / 3).max(1) == 0 {
            hw /= 2;
            c *= 2;
        }
        layers.push(conv(&format!("conv{i}"), hw, c, c, 3));
    }
    layers.push(LayerShape {
        name: "head".into(),
        kind: LayerKind::Dense,
        m: 1,
        k: c,
        n: 10,
        groups: 1,
        macs: (c * 10) as u64,
        act_elems: c,
    });
    layers
}

/// A synthetic MobileNet-like stack (alternating pointwise + depthwise) to
/// exercise the depthwise saturation effect without artifacts.
pub fn synthetic_mobilenet(blocks: usize) -> Vec<LayerShape> {
    let mut layers = Vec::new();
    let hw = 24usize;
    let mut c = 16usize;
    layers.push(conv("stem", hw, 3, c, 3));
    for i in 0..blocks {
        let cmid = c * 4;
        layers.push(conv(&format!("b{i}.exp"), hw, c, cmid, 1));
        layers.push(LayerShape {
            name: format!("b{i}.dw"),
            kind: LayerKind::DwConv,
            m: hw * hw,
            k: 9,
            n: cmid,
            groups: cmid,
            macs: (hw * hw * 9 * cmid) as u64,
            act_elems: hw * hw * cmid,
        });
        layers.push(conv(&format!("b{i}.proj"), hw, cmid, c, 1));
        if i == blocks / 2 {
            c *= 2;
        }
    }
    layers
}

fn conv(name: &str, hw: usize, cin: usize, cout: usize, k: usize) -> LayerShape {
    LayerShape {
        name: name.into(),
        kind: LayerKind::Conv,
        m: hw * hw,
        k: k * k * cin,
        n: cout,
        groups: 1,
        macs: (hw * hw * k * k * cin * cout) as u64,
        act_elems: hw * hw * cin,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{HwConfig, Prec, Simulator};

    #[test]
    fn synthetic_resnet_shape() {
        let l = synthetic_resnet(6);
        assert_eq!(l.len(), 8); // stem + 6 + head
        assert!(l.iter().all(|x| x.m > 0 && x.k > 0 && x.n > 0));
    }

    #[test]
    fn mobilenet_has_dw_layers() {
        let l = synthetic_mobilenet(4);
        assert!(l.iter().any(|x| x.kind == LayerKind::DwConv));
    }

    #[test]
    fn mobilenet_speedup_saturates_vs_resnet() {
        // Fig. 6's qualitative claim, reproduced on synthetic stacks
        let mut rn = Simulator::new(HwConfig::zcu102(), synthetic_resnet(8), 1);
        let mut mb = Simulator::new(HwConfig::zcu102(), synthetic_mobilenet(4), 1);
        let rn_assign = vec![(Prec::B2, Prec::B2); rn.layers.len()];
        let mb_assign = vec![(Prec::B2, Prec::B2); mb.layers.len()];
        let s_rn = rn.speedup(&rn_assign);
        let s_mb = mb.speedup(&mb_assign);
        assert!(s_rn > s_mb, "resnet {s_rn} vs mobilenet {s_mb}");
    }
}
