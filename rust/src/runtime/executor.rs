//! PJRT executor: load HLO-text artifacts, compile once, execute from the
//! request path.  Adapted from /opt/xla-example/load_hlo (HLO text is the
//! interchange format; lowered with return_tuple=True so every result is a
//! tuple literal we decompose).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::tensor::Tensor;

/// Compiled-executable cache over one PJRT CPU client.
pub struct Executor {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Executor {
    /// Create a CPU PJRT client rooted at the artifact directory.
    pub fn new(dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Executor { client, dir: dir.to_path_buf(), cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the artifact `file`.
    pub fn load(&mut self, file: &str) -> Result<()> {
        if self.cache.contains_key(file) {
            return Ok(());
        }
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
        self.cache.insert(file.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact with f32/i32 inputs; returns output literals.
    pub fn run(&mut self, file: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.load(file)?;
        let exe = self.cache.get(file).expect("just loaded");
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {file}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result of {file}: {e:?}"))?;
        // aot.py lowers with return_tuple=True: always a tuple
        lit.to_tuple().map_err(|e| anyhow!("untuple {file}: {e:?}"))
    }

    /// Execute and convert every output to a [`Tensor`].
    pub fn run_t(&mut self, file: &str, inputs: &[xla::Literal]) -> Result<Vec<Tensor>> {
        self.run(file, inputs)?
            .iter()
            .map(literal_to_tensor)
            .collect()
    }

    pub fn loaded_count(&self) -> usize {
        self.cache.len()
    }
}

/// f32 Tensor -> Literal (row-major, reshaped to the tensor's dims).
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let flat = xla::Literal::vec1(&t.data);
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    flat.reshape(&dims)
        .map_err(|e| anyhow!("reshape literal to {:?}: {e:?}", t.shape))
}

/// Scalar i32 literal (seeds).
pub fn i32_scalar(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Scalar f32 literal (lr, scales).
pub fn f32_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Literal -> f32 Tensor (converts from any numeric element type).
pub fn literal_to_tensor(l: &xla::Literal) -> Result<Tensor> {
    let shape = l
        .array_shape()
        .map_err(|e| anyhow!("literal shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data: Vec<f32> = match l.ty().map_err(|e| anyhow!("{e:?}"))? {
        xla::ElementType::F32 => l.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
        _ => {
            let conv = l
                .convert(xla::PrimitiveType::F32)
                .map_err(|e| anyhow!("convert literal: {e:?}"))?;
            conv.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?
        }
    };
    Tensor::new(dims, data).context("literal to tensor")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_literal_roundtrip() {
        let t = Tensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        let l = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&l).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn scalar_literals() {
        let l = f32_scalar(2.5);
        let t = literal_to_tensor(&l).unwrap();
        assert_eq!(t.shape, Vec::<usize>::new());
        assert_eq!(t.data, vec![2.5]);
    }

    // full executor integration lives in tests/runtime_integration.rs
    // (needs artifacts + the PJRT plugin, exercised by `make test`)
}
