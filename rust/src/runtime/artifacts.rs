//! Artifact manifest: the contract between `python/compile/aot.py` (which
//! writes it at build time) and the rust runtime (which is the only thing
//! that runs afterwards).  Everything the runtime knows about a model —
//! parameter leaves, HLO I/O signatures, layer geometry — comes from here.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::sim::LayerShape;
use crate::tensor::io::read_f32_slice;
use crate::tensor::Tensor;
use crate::util::json::{parse, Json};

/// One input/output tensor signature of an HLO artifact.
#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One lowered HLO computation.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<String>,
}

/// One parameter leaf inside `<model>_params.bin`.
#[derive(Clone, Debug)]
pub struct ParamLeaf {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub nelems: usize,
}

/// Everything the runtime knows about one model.
#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub name: String,
    pub stands_for: String,
    pub batch: usize,
    pub input: Vec<usize>,
    pub classes: usize,
    pub n_quant_layers: usize,
    pub layers: Vec<LayerShape>,
    pub params: Vec<ParamLeaf>,
    pub params_file: String,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

/// The whole `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub lut_size: usize,
    pub batch: usize,
    pub img: usize,
    pub classes: usize,
    pub eval_seed_base: i64,
    pub models: BTreeMap<String, ModelEntry>,
    pub kernels: BTreeMap<String, ArtifactMeta>,
}

fn io_spec(j: &Json) -> Result<IoSpec> {
    Ok(IoSpec {
        name: j.get("name").and_then(Json::as_str).unwrap_or("?").to_string(),
        shape: j
            .get("shape")
            .and_then(Json::as_usize_vec)
            .ok_or_else(|| anyhow!("io spec missing shape"))?,
        dtype: j.get("dtype").and_then(Json::as_str).unwrap_or("float32").to_string(),
    })
}

fn artifact_meta(j: &Json) -> Result<ArtifactMeta> {
    Ok(ArtifactMeta {
        file: j
            .get("file")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("artifact missing file"))?
            .to_string(),
        inputs: j
            .get("inputs")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("artifact missing inputs"))?
            .iter()
            .map(io_spec)
            .collect::<Result<_>>()?,
        outputs: j
            .get("outputs")
            .and_then(Json::as_arr)
            .map(|a| {
                a.iter()
                    .filter_map(Json::as_str)
                    .map(str::to_string)
                    .collect()
            })
            .unwrap_or_default(),
    })
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts` first)", path.display()))?;
        let j = parse(&text).map_err(|e| anyhow!("parse manifest: {e}"))?;
        let mut models = BTreeMap::new();
        for (name, mj) in j
            .get("models")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing models"))?
        {
            models.insert(name.clone(), Self::model_entry(name, mj)?);
        }
        let mut kernels = BTreeMap::new();
        if let Some(ks) = j.get("kernels").and_then(Json::as_obj) {
            for (name, kj) in ks {
                kernels.insert(name.clone(), artifact_meta(kj)?);
            }
        }
        let field = |k: &str| j.get(k).and_then(Json::as_usize).unwrap_or(0);
        Ok(Manifest {
            dir: dir.to_path_buf(),
            lut_size: field("lut_size"),
            batch: field("batch"),
            img: field("img"),
            classes: field("classes"),
            eval_seed_base: j
                .get("eval_seed_base")
                .and_then(Json::as_i64)
                .unwrap_or(1 << 30),
            models,
            kernels,
        })
    }

    /// The entry for `name`, or a listing of known models on miss (the
    /// lookup every serving/CLI path repeats).
    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models.get(name).ok_or_else(|| {
            anyhow!(
                "unknown model '{name}' (manifest has: {})",
                self.models.keys().cloned().collect::<Vec<_>>().join(", ")
            )
        })
    }

    fn model_entry(name: &str, j: &Json) -> Result<ModelEntry> {
        let layers = j
            .get("layers")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("{name}: missing layers"))?
            .iter()
            .map(LayerShape::from_json)
            .collect::<Result<Vec<_>>>()?;
        let params = j
            .get("params")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("{name}: missing params"))?
            .iter()
            .map(|p| {
                Ok(ParamLeaf {
                    name: p
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("param name"))?
                        .to_string(),
                    shape: p
                        .get("shape")
                        .and_then(Json::as_usize_vec)
                        .ok_or_else(|| anyhow!("param shape"))?,
                    offset: p.get("offset").and_then(Json::as_usize).unwrap_or(0),
                    nelems: p.get("nelems").and_then(Json::as_usize).unwrap_or(0),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let mut artifacts = BTreeMap::new();
        for (tag, aj) in j
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("{name}: missing artifacts"))?
        {
            artifacts.insert(tag.clone(), artifact_meta(aj)?);
        }
        Ok(ModelEntry {
            name: name.to_string(),
            stands_for: j
                .get("stands_for")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
            batch: j.get("batch").and_then(Json::as_usize).unwrap_or(32),
            input: j
                .get("input")
                .and_then(Json::as_usize_vec)
                .unwrap_or_default(),
            classes: j.get("classes").and_then(Json::as_usize).unwrap_or(10),
            n_quant_layers: j
                .get("n_quant_layers")
                .and_then(Json::as_usize)
                .unwrap_or(layers.len()),
            layers,
            params,
            params_file: j
                .get("params_file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("{name}: missing params_file"))?
                .to_string(),
            artifacts,
        })
    }
}

impl ModelEntry {
    /// Load the initial parameters written by aot.py, in leaf order.
    pub fn load_params(&self, dir: &Path) -> Result<Vec<Tensor>> {
        let path = dir.join(&self.params_file);
        self.params
            .iter()
            .map(|leaf| {
                let data = read_f32_slice(&path, leaf.offset, leaf.nelems)?;
                Tensor::new(leaf.shape.clone(), data)
            })
            .collect()
    }

    /// Index of the weight leaf belonging to quantizable layer `i`
    /// (layer "name" owns leaf "name.w" — the nn.py convention).
    pub fn weight_leaf_idx(&self, layer_idx: usize) -> Option<usize> {
        let want = format!("{}.w", self.layers[layer_idx].name);
        self.params.iter().position(|p| p.name == want)
    }

    pub fn artifact(&self, tag: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(tag)
            .ok_or_else(|| anyhow!("{}: no artifact '{tag}'", self.name))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// These tests require `make artifacts` to have run; they are the
    /// python⇄rust contract check.
    fn manifest() -> Option<Manifest> {
        let dir = Path::new(crate::ARTIFACTS_DIR);
        Manifest::load(dir).ok()
    }

    #[test]
    fn manifest_loads_and_has_models() {
        let Some(m) = manifest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        assert_eq!(m.lut_size, 256);
        assert!(m.models.contains_key("mlp"));
        let mlp = &m.models["mlp"];
        assert_eq!(mlp.n_quant_layers, mlp.layers.len());
        assert!(mlp.artifacts.contains_key("fwd"));
        assert!(mlp.artifacts.contains_key("train"));
    }

    #[test]
    fn params_load_and_match_shapes() {
        let Some(m) = manifest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mlp = &m.models["mlp"];
        let params = mlp.load_params(&m.dir).unwrap();
        assert_eq!(params.len(), mlp.params.len());
        for (t, leaf) in params.iter().zip(mlp.params.iter()) {
            assert_eq!(t.shape, leaf.shape);
            assert_eq!(t.numel(), leaf.nelems);
        }
    }

    #[test]
    fn weight_leaves_resolve_for_every_layer() {
        let Some(m) = manifest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        for entry in m.models.values() {
            for i in 0..entry.layers.len() {
                assert!(
                    entry.weight_leaf_idx(i).is_some(),
                    "{}: layer {} '{}' has no weight leaf",
                    entry.name,
                    i,
                    entry.layers[i].name
                );
            }
        }
    }
}
