//! PJRT runtime: artifact manifest + compiled-executable cache.  The only
//! bridge between the rust coordinator and the AOT-compiled JAX/Pallas
//! compute (python never runs after `make artifacts`).

pub mod artifacts;
pub mod executor;

pub use artifacts::{ArtifactMeta, IoSpec, Manifest, ModelEntry, ParamLeaf};
pub use executor::{f32_scalar, i32_scalar, literal_to_tensor, tensor_to_literal, Executor};
