//! Self-built substrates: RNG, JSON, CLI args, stats/bench, thread pool,
//! property-test harness.  The offline vendor set lacks rand/serde/clap/
//! criterion/tokio/proptest, so these live in-crate (DESIGN.md §2).

pub mod argparse;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod threadpool;

/// Poison-recovering mutex lock, the crate-wide policy (DESIGN.md §9):
/// a thread that panicked while holding a lock can at worst leave a
/// half-recorded update behind, which every consumer here (metrics
/// sinks, LUT caches, intake queues, router credits) prefers over
/// poisoning all later calls.
pub fn lock<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}
