//! Self-built substrates: RNG, JSON, CLI args, stats/bench, thread pool,
//! property-test harness.  The offline vendor set lacks rand/serde/clap/
//! criterion/tokio/proptest, so these live in-crate (DESIGN.md §2).

pub mod argparse;
pub mod json;
pub mod loadheap;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod threadpool;

/// Poison-recovering mutex lock, the crate-wide policy (DESIGN.md §9):
/// a thread that panicked while holding a lock can at worst leave a
/// half-recorded update behind, which every consumer here (metrics
/// sinks, LUT caches, intake queues, router credits) prefers over
/// poisoning all later calls.
#[allow(clippy::disallowed_methods)] // the one sanctioned raw-lock site
pub fn lock<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Poison-recovering condvar wait — the companion of [`lock`] for code
/// that blocks on a [`std::sync::Condvar`] (the intake queues,
/// DESIGN.md §11).  Pre-§11 this `unwrap_or_else(PoisonError::
/// into_inner)` dance was copy-pasted at every wait site in the batcher.
#[allow(clippy::disallowed_methods)] // the one sanctioned raw-wait site
pub fn wait<'a, T>(cv: &std::sync::Condvar, g: std::sync::MutexGuard<'a, T>)
                   -> std::sync::MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Poison-recovering bounded condvar wait; returns the guard and
/// whether the wait timed out (see [`wait`]).
#[allow(clippy::disallowed_methods)] // the one sanctioned raw-wait site
pub fn wait_timeout<'a, T>(cv: &std::sync::Condvar, g: std::sync::MutexGuard<'a, T>,
                           dur: std::time::Duration)
                           -> (std::sync::MutexGuard<'a, T>, bool) {
    let (g, to) = cv
        .wait_timeout(g, dur)
        .unwrap_or_else(std::sync::PoisonError::into_inner);
    (g, to.timed_out())
}

#[cfg(test)]
mod tests {
    use std::sync::{Arc, Condvar, Mutex};
    use std::time::Duration;

    /// Regression (DESIGN.md §11): a thread that panics while holding a
    /// mutex poisons it; `lock`/`wait`/`wait_timeout` must keep working
    /// on the poisoned primitives instead of propagating the poison to
    /// every later caller (the serving pool keeps serving).
    #[test]
    #[allow(clippy::disallowed_methods)] // raw lock() IS the poison drill
    fn lock_and_waits_recover_from_poison() {
        let m = Arc::new(Mutex::new(7u32));
        let cv = Arc::new(Condvar::new());
        let m2 = Arc::clone(&m);
        let poisoner = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the lock on purpose");
        });
        assert!(poisoner.join().is_err());
        assert!(m.is_poisoned());
        let g = super::lock(&m);
        assert_eq!(*g, 7);
        let (g, timed_out) = super::wait_timeout(&cv, g, Duration::from_millis(1));
        assert!(timed_out);
        drop(g);
        // a waiter on the poisoned pair still gets woken
        let (m3, cv3) = (Arc::clone(&m), Arc::clone(&cv));
        let waiter = std::thread::spawn(move || {
            let mut g = super::lock(&m3);
            while *g != 42 {
                g = super::wait(&cv3, g);
            }
            *g
        });
        std::thread::sleep(Duration::from_millis(10));
        *super::lock(&m) = 42;
        cv.notify_all();
        assert_eq!(waiter.join().unwrap(), 42);
    }
}
