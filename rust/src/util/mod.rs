//! Self-built substrates: RNG, JSON, CLI args, stats/bench, thread pool,
//! property-test harness.  The offline vendor set lacks rand/serde/clap/
//! criterion/tokio/proptest, so these live in-crate (DESIGN.md §2).

pub mod argparse;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod threadpool;
