//! Minimal JSON parser/writer (the offline vendor set has no serde).
//!
//! Supports the full JSON grammar we produce/consume: objects, arrays,
//! strings (with \u escapes), numbers, booleans, null.  Used to read
//! `artifacts/manifest.json` + `formats_golden.json` and to write
//! machine-readable bench reports.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // -- accessors (panic-free; callers use anyhow contexts) --------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Array of numbers -> Vec<f64> (common manifest pattern).
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(|j| j.as_f64()).collect()
    }

    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?
            .iter()
            .map(|j| j.as_f64().map(|x| x as usize))
            .collect()
    }

    // -- construction helpers ---------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    // -- serialization ------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null"); // JSON has no inf/nan
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Parse a JSON document. Returns Err with byte position on malformed input.
pub fn parse(text: &str) -> Result<Json, String> {
    let bytes = text.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(format!("trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("short \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain chars (fast path for big arrays)
                    let start = self.i;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "hi\nthere", "c": null, "d": true}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64_vec().unwrap(), vec![1.0, 2.5, -300.0]);
        assert_eq!(v.get("b").unwrap().as_str().unwrap(), "hi\nthere");
        let back = parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn nested() {
        let v = parse(r#"[[{"x":[[]]}], []]"#).unwrap();
        assert!(v.idx(0).unwrap().idx(0).unwrap().get("x").is_some());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("01x").is_err());
        assert!(parse(r#"{"a":1} extra"#).is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn writer_escapes() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn numbers_precise() {
        let v = parse("[0.125, 1e-5, 123456789]").unwrap();
        let xs = v.as_f64_vec().unwrap();
        assert_eq!(xs, vec![0.125, 1e-5, 123456789.0]);
    }
}
