//! Tiny CLI flag parser (no clap in the offline vendor set).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! positional arguments; generates usage text from registered options.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse an argv slice (without the program name).
    pub fn parse(argv: &[String]) -> Self {
        let mut a = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    a.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    a.flags.insert(body.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    a.bools.push(body.to_string());
                }
            } else {
                a.positional.push(tok.clone());
            }
            i += 1;
        }
        a
    }

    pub fn from_env() -> Self {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Self::parse(&argv)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f32(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, key: &str) -> bool {
        self.bools.iter().any(|b| b == key) || self.flags.contains_key(key)
    }

    /// Comma-separated list flag.
    pub fn get_list(&self, key: &str, default: &str) -> Vec<String> {
        self.get_or(key, default)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.to_string())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(args: &[&str]) -> Args {
        Args::parse(&args.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn flags_and_positional() {
        let a = mk(&["serve", "--model", "mlp", "--fast", "--k=3"]);
        assert_eq!(a.positional, vec!["serve"]);
        assert_eq!(a.get("model"), Some("mlp"));
        assert!(a.has("fast"));
        assert_eq!(a.get_usize("k", 0), 3);
    }

    #[test]
    fn defaults() {
        let a = mk(&[]);
        assert_eq!(a.get_or("x", "d"), "d");
        assert_eq!(a.get_f64("y", 1.5), 1.5);
        assert!(!a.has("z"));
    }

    #[test]
    fn list_flag() {
        let a = mk(&["--models", "a,b,c"]);
        assert_eq!(a.get_list("models", ""), vec!["a", "b", "c"]);
    }

    #[test]
    fn eq_form_bool_like_value() {
        let a = mk(&["--alpha=2.5", "--beta", "4"]);
        assert_eq!(a.get_f64("alpha", 0.0), 2.5);
        assert_eq!(a.get_f64("beta", 0.0), 4.0);
    }
}
