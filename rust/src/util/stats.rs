//! Timing + descriptive statistics for the bench harness.
//!
//! The offline vendor set has no criterion; `Bench` provides the same core
//! loop (warmup, timed iterations, robust summary) with deterministic
//! output formatting shared by every `benches/*.rs` binary.

use std::time::Instant;

/// Descriptive statistics of a sample.
#[derive(Clone, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

pub fn summarize(xs: &[f64]) -> Summary {
    assert!(!xs.is_empty());
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    let mut s = xs.to_vec();
    // total_cmp: a NaN sample (e.g. a bad latency reading) must not
    // panic the summary path; NaNs sort last and surface in max/mean
    s.sort_unstable_by(f64::total_cmp);
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: s[0],
        p50: percentile(&s, 50.0),
        p95: percentile(&s, 95.0),
        max: s[n - 1],
    }
}

/// Percentile of a pre-sorted slice (linear interpolation).
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let w = rank - lo as f64;
    sorted[lo] * (1.0 - w) + sorted[hi] * w
}

/// Criterion-lite measurement loop.
pub struct Bench {
    pub warmup_iters: usize,
    pub iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup_iters: 3, iters: 20 }
    }
}

impl Bench {
    pub fn new(warmup_iters: usize, iters: usize) -> Self {
        Bench { warmup_iters, iters }
    }

    /// Time `f` and return per-iteration seconds summary.
    pub fn run<F: FnMut()>(&self, mut f: F) -> Summary {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        summarize(&samples)
    }
}

/// Pretty time formatting (ns/µs/ms/s).
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.1}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.2}s", secs)
    }
}

/// Fixed-width table printer used by every bench binary so tables are
/// grep-able from bench_output.txt.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("| {:width$} ", c, width = w[i]));
            }
            s.push('|');
            s
        };
        println!("{}", line(&self.headers));
        let dashes: Vec<String> = w.iter().map(|n| "-".repeat(*n)).collect();
        println!("{}", line(&dashes));
        for r in &self.rows {
            println!("{}", line(r));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_sane() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.p50 - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_interp() {
        let s = vec![0.0, 10.0];
        assert!((percentile(&s, 50.0) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&s, 0.0), 0.0);
        assert_eq!(percentile(&s, 100.0), 10.0);
    }

    #[test]
    fn bench_runs() {
        let mut count = 0;
        let s = Bench::new(1, 5).run(|| count += 1);
        assert_eq!(count, 6);
        assert_eq!(s.n, 5);
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(2e-9).ends_with("ns"));
        assert!(fmt_time(2e-6).ends_with("µs"));
        assert!(fmt_time(2e-3).ends_with("ms"));
        assert!(fmt_time(2.0).ends_with('s'));
    }
}
