//! Deterministic xorshift64* PRNG + distribution samplers.
//!
//! The offline vendor set has no `rand`; this is the single RNG used by the
//! whole crate (simulator jitter, property tests, synthetic payloads).
//! xorshift64* passes BigCrush on the low 32 bits and is trivially
//! reproducible from a seed, which the benches rely on.

/// xorshift64* PRNG. `Rng::new(seed)` with any seed (0 is remapped).
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng {
            state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed },
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Standard Laplace (the distribution DNN weights resemble; used by the
    /// format benches to reproduce the paper's Fig. 2 setting).
    pub fn laplace(&mut self) -> f64 {
        let u = self.uniform() - 0.5;
        -u.signum() * (1.0 - 2.0 * u.abs()).ln() / std::f64::consts::SQRT_2
            * std::f64::consts::SQRT_2
    }

    /// Vector of standard normals as f32.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(42);
        let xs: Vec<f64> = (0..50_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
