//! Mini property-test harness (proptest is not in the offline vendor set).
//!
//! `check(name, cases, gen, prop)` draws `cases` random inputs from `gen`
//! and asserts `prop`; on failure it performs a bounded greedy shrink using
//! the generator's own re-draws at decreasing sizes, then panics with the
//! smallest counterexample's debug print.  Deterministic per test name.

use super::rng::Rng;

/// Run a property over `cases` generated inputs.
///
/// `gen(rng, size)` should produce inputs whose "complexity" grows with
/// `size` in [0, 1]; the shrinker re-draws at smaller sizes looking for a
/// smaller counterexample.
pub fn check<T, G, P>(name: &str, cases: usize, gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: Fn(&mut Rng, f64) -> T,
    P: FnMut(&T) -> bool,
{
    // deterministic seed from the test name
    let seed = name.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    });
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let size = (case + 1) as f64 / cases as f64;
        let input = gen(&mut rng, size);
        if !prop(&input) {
            // greedy shrink: re-draw at smaller sizes
            let mut smallest = input;
            let mut s = size;
            for _ in 0..200 {
                s *= 0.7;
                let cand = gen(&mut rng, s);
                if !prop(&cand) {
                    smallest = cand;
                }
                if s < 1e-3 {
                    break;
                }
            }
            panic!(
                "property '{name}' failed (case {case}/{cases}).\n\
                 smallest counterexample found:\n{smallest:#?}"
            );
        }
    }
}

/// Generator helpers shared by property tests across the crate.
pub mod gen {
    use super::super::rng::Rng;

    /// f32 vector with magnitudes spanning ~size decades, incl. negatives.
    pub fn tensor(rng: &mut Rng, size: f64) -> Vec<f32> {
        let n = 1 + (size * 512.0) as usize;
        (0..n)
            .map(|_| {
                let scale = 10f64.powf(rng.uniform() * 4.0 * size - 2.0);
                (rng.normal() * scale) as f32
            })
            .collect()
    }

    /// Random bitwidth in {2, 4, 8} (the paper's supported set).
    pub fn bitwidth(rng: &mut Rng) -> usize {
        [2usize, 4, 8][rng.below(3)]
    }

    /// Heavy-tailed tensor of exactly `n` elements — the shared
    /// quantization-stress distribution (normal body, occasional ~6×
    /// outliers) used by the format/calibration tests and benches, in
    /// one place so they keep exercising the same tails.
    pub fn heavy_tail(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n)
            .map(|_| (rng.normal() * (1.0 + 5.0 * rng.uniform().powi(5))) as f32)
            .collect()
    }

    /// GEMM dims up to ~size * 512.
    pub fn gemm_dims(rng: &mut Rng, size: f64) -> (usize, usize, usize) {
        let top = 2.0 + size * 510.0;
        (
            1 + rng.below(top as usize),
            1 + rng.below(top as usize),
            1 + rng.below(top as usize),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("sum-commutes", 100, |r, s| {
            (r.uniform_in(-1.0, 1.0), (s * 10.0) as i32)
        }, |(a, b)| a + *b as f32 == *b as f32 + a);
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn fails_and_reports() {
        check("always-false", 10, |r, _| r.below(5), |_| false);
    }

    #[test]
    fn deterministic_across_runs() {
        let mut a = Vec::new();
        let mut b = Vec::new();
        check("det", 5, |r, _| r.next_u64(), |x| {
            a.push(*x);
            true
        });
        check("det", 5, |r, _| r.next_u64(), |x| {
            b.push(*x);
            true
        });
        assert_eq!(a, b);
    }
}
