//! Indexed max-heap over a fixed id set — the top-K load board behind
//! the sharded intake's victim selection (DESIGN.md §11).
//!
//! The pre-§11 thief walked every sibling queue to find the most loaded
//! one: O(shards) per steal, under the one global intake lock.  This
//! heap keeps the shard → depth map *indexed* (each id knows its heap
//! position), so a push/pop-side depth change is one O(log n) sift, and
//! a thief asks for "the deepest shard that passes my filter" with a
//! best-first descent that usually terminates at the root — the
//! `min_max_heap` top-K shape carmen-core's coalesce uses for grid
//! contexts, specialized to a dense id universe.
//!
//! Ordering is total and deterministic: ids compare by key descending,
//! then id ascending — equal-depth victims resolve to the lowest index,
//! matching the pre-§11 linear scan exactly (asserted by the property
//! test below).

/// Indexed max-heap over ids `0..n` with `u64` keys (tie → lowest id).
#[derive(Clone, Debug)]
pub struct LoadHeap {
    /// key per id (dense).
    key: Vec<u64>,
    /// heap of ids, max at `heap[0]` under [`LoadHeap::before`].
    heap: Vec<u32>,
    /// id → its index in `heap`.
    pos: Vec<u32>,
}

impl LoadHeap {
    /// Heap over ids `0..n`, all keys 0.
    pub fn new(n: usize) -> Self {
        assert!(n <= u32::MAX as usize, "load heap id space overflow");
        LoadHeap {
            key: vec![0; n],
            heap: (0..n as u32).collect(),
            pos: (0..n as u32).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.key.len()
    }

    pub fn is_empty(&self) -> bool {
        self.key.is_empty()
    }

    pub fn key(&self, id: usize) -> u64 {
        self.key[id]
    }

    /// Largest key in the heap (0 for an empty id set).
    pub fn max_key(&self) -> u64 {
        self.heap.first().map_or(0, |&id| self.key[id as usize])
    }

    /// Sum of all keys (the intake's `len()` gauge reads this).
    pub fn total(&self) -> u64 {
        self.key.iter().sum()
    }

    /// Strict ordering: `a` before `b` ⇔ larger key, tie → lower id.
    #[inline]
    fn before(&self, a: u32, b: u32) -> bool {
        let (ka, kb) = (self.key[a as usize], self.key[b as usize]);
        ka > kb || (ka == kb && a < b)
    }

    /// Set `id`'s key and restore the heap in O(log n).
    pub fn update(&mut self, id: usize, key: u64) {
        let old = self.key[id];
        self.key[id] = key;
        let i = self.pos[id] as usize;
        // key rose, or same key with... ordering vs parent can only be
        // disturbed in one direction; sift the right way (equal keys
        // keep the node in place: `before` is strict and ties are on the
        // immutable id)
        if key > old {
            self.sift_up(i);
        } else if key < old {
            self.sift_down(i);
        }
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let p = (i - 1) / 2;
            if self.before(self.heap[i], self.heap[p]) {
                self.swap(i, p);
                i = p;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.heap.len();
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut best = i;
            if l < n && self.before(self.heap[l], self.heap[best]) {
                best = l;
            }
            if r < n && self.before(self.heap[r], self.heap[best]) {
                best = r;
            }
            if best == i {
                break;
            }
            self.swap(i, best);
            i = best;
        }
    }

    fn swap(&mut self, i: usize, j: usize) {
        self.heap.swap(i, j);
        self.pos[self.heap[i] as usize] = i as u32;
        self.pos[self.heap[j] as usize] = j as u32;
    }

    /// Best-first top-K walk: the id with the largest key (tie → lowest
    /// id) among those with `key > 0` that satisfy `keep`, or `None`.
    ///
    /// Descends the heap lazily with a small frontier: each rejected
    /// candidate opens its two children, so the cost is O(rejections ·
    /// log(frontier)) and the common case (root passes) touches one
    /// node.  Zero-key subtrees are pruned — a child's key never
    /// exceeds its parent's.
    pub fn select(&self, keep: impl Fn(usize) -> bool) -> Option<usize> {
        // frontier of heap indices; linear selection is fine — it only
        // grows past a handful when many deep shards are filtered out
        let mut frontier: Vec<usize> = Vec::with_capacity(8);
        if !self.heap.is_empty() {
            frontier.push(0);
        }
        while !frontier.is_empty() {
            // take the frontier's best node under the same total order
            let mut bi = 0;
            for i in 1..frontier.len() {
                if self.before(self.heap[frontier[i]], self.heap[frontier[bi]]) {
                    bi = i;
                }
            }
            let hi = frontier.swap_remove(bi);
            let id = self.heap[hi] as usize;
            if self.key[id] == 0 {
                // max of the remaining frontier is below every positive
                // key already rejected; nothing with key > 0 is left
                return None;
            }
            if keep(id) {
                return Some(id);
            }
            for c in [2 * hi + 1, 2 * hi + 2] {
                if c < self.heap.len() && self.key[self.heap[c] as usize] > 0 {
                    frontier.push(c);
                }
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    /// The oracle the heap replaces: linear scan, max key, tie → lowest
    /// id, zero keys and filtered ids excluded.
    fn naive_select(keys: &[u64], keep: impl Fn(usize) -> bool) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, &k) in keys.iter().enumerate() {
            if k == 0 || !keep(i) {
                continue;
            }
            if best.map_or(true, |b| k > keys[b]) {
                best = Some(i);
            }
        }
        best
    }

    #[test]
    fn update_and_select_basics() {
        let mut h = LoadHeap::new(4);
        assert_eq!(h.max_key(), 0);
        assert_eq!(h.select(|_| true), None, "all-zero heap has no victim");
        h.update(2, 5);
        h.update(1, 7);
        h.update(3, 7);
        assert_eq!(h.max_key(), 7);
        assert_eq!(h.total(), 19);
        assert_eq!(h.select(|_| true), Some(1), "tie resolves to the lowest id");
        assert_eq!(h.select(|i| i != 1), Some(3));
        assert_eq!(h.select(|i| i != 1 && i != 3), Some(2));
        h.update(1, 0);
        assert_eq!(h.select(|_| true), Some(3));
        assert_eq!(h.select(|i| i % 2 == 0), Some(2));
        assert_eq!(h.select(|_| false), None);
    }

    #[test]
    fn zero_key_subtrees_are_pruned_not_returned() {
        let mut h = LoadHeap::new(8);
        h.update(6, 3);
        assert_eq!(h.select(|_| true), Some(6));
        assert_eq!(h.select(|i| i != 6), None, "every other key is 0");
    }

    #[test]
    fn matches_naive_scan_under_random_updates_and_filters() {
        check(
            "loadheap-vs-scan",
            300,
            |rng: &mut Rng, size| {
                let n = 1 + rng.below(1 + (size * 64.0) as usize);
                let ops: Vec<(usize, u64)> = (0..rng.below(200) + 1)
                    .map(|_| (rng.below(n), rng.next_u64() % 5))
                    .collect();
                let mask: u64 = rng.next_u64();
                (n, ops, mask)
            },
            |(n, ops, mask)| {
                let mut h = LoadHeap::new(*n);
                let mut keys = vec![0u64; *n];
                for &(id, k) in ops {
                    h.update(id, k);
                    keys[id] = k;
                    let keep = |i: usize| mask >> (i % 64) & 1 == 1;
                    if h.select(keep) != naive_select(&keys, keep) {
                        return false;
                    }
                    if h.select(|_| true) != naive_select(&keys, |_| true) {
                        return false;
                    }
                    if h.max_key() != keys.iter().copied().max().unwrap_or(0) {
                        return false;
                    }
                }
                h.total() == keys.iter().sum::<u64>()
            },
        );
    }
}
