//! Scoped thread pool + mpsc work queue.
//!
//! tokio is not in the offline vendor set; the coordinator and the search
//! engine use this std-thread pool instead (same architecture — bounded
//! queue, worker loop — without async syntax).  On the 1-core CI box the
//! pool degenerates gracefully to near-serial execution.
//!
//! [`parallel_map_on`] borrows a caller-owned pool — its main compute
//! consumer is the search's cost-table fill (DESIGN.md §7) — and catches
//! job panics with `catch_unwind`, so a panicking job surfaces as an
//! `Err` naming the job instead of killing a worker and producing a
//! follow-on "worker died" panic at collection time.

use std::any::Any;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

use anyhow::{anyhow, Result};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool. Jobs run FIFO; `join` blocks until the queue
/// drains and all workers exit.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|_| {
                let rx = Arc::clone(&rx);
                // spawn-guard: every job is catch_unwind-wrapped at the submission boundary (parallel_map_on), so the worker body cannot unwind
                thread::spawn(move || loop {
                    let job = { crate::util::lock(&rx).recv() };
                    match job {
                        Ok(job) => job(),
                        Err(_) => break, // channel closed
                    }
                })
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool joined")
            .send(Box::new(f))
            .expect("worker hung up");
    }

    /// Drop the sender and wait for all workers to finish the queue.
    pub fn join(mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Map `f` over `items` in parallel on a freshly spawned pool of
/// `nthreads` workers, preserving order.  See [`parallel_map_on`] for
/// the borrowed-pool variant and the panic contract.
pub fn parallel_map<T, R, F>(items: Vec<T>, nthreads: usize, f: F) -> Result<Vec<R>>
where
    T: Send + 'static,
    R: Send + 'static,
    F: Fn(T) -> R + Send + Sync + 'static,
{
    let pool = ThreadPool::new(nthreads);
    let out = parallel_map_on(&pool, items, f);
    pool.join();
    out
}

/// Map `f` over `items` in parallel on a borrowed [`ThreadPool`],
/// preserving order.
///
/// Borrowing keeps pool ownership with the caller, so one pool can be
/// reused across several maps (its workers already serve all of a
/// map's jobs, e.g. the cost-table fill's per-layer jobs —
/// DESIGN.md §7 — without per-job spawns).  A job
/// that panics is caught with `catch_unwind` and reported as an `Err`
/// naming the item index and panic payload — the worker survives and
/// the remaining jobs still run, so one poisoned item cannot take down
/// the pool or trigger a follow-on panic at collection time.
pub fn parallel_map_on<T, R, F>(pool: &ThreadPool, items: Vec<T>, f: F) -> Result<Vec<R>>
where
    T: Send + 'static,
    R: Send + 'static,
    F: Fn(T) -> R + Send + Sync + 'static,
{
    let n = items.len();
    let f = Arc::new(f);
    let (tx, rx) = mpsc::channel();
    for (i, item) in items.into_iter().enumerate() {
        let tx = tx.clone();
        let f = Arc::clone(&f);
        pool.execute(move || {
            let r = catch_unwind(AssertUnwindSafe(|| f(item)));
            let _ = tx.send((i, r));
        });
    }
    drop(tx);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let mut panics: Vec<String> = Vec::new();
    for (i, r) in rx {
        match r {
            Ok(v) => out[i] = Some(v),
            Err(payload) => {
                panics.push(format!("job {i} panicked: {}", payload_msg(&*payload)));
            }
        }
    }
    if !panics.is_empty() {
        return Err(anyhow!("parallel_map: {}", panics.join("; ")));
    }
    out.into_iter()
        .enumerate()
        .map(|(i, o)| o.ok_or_else(|| anyhow!("parallel_map: job {i} result missing")))
        .collect()
}

/// Best-effort human-readable panic payload (`panic!` with a literal or
/// with format args; anything else is opaque).  Shared with the serving
/// pool's panic containment (DESIGN.md §9).
pub fn payload_msg(p: &(dyn Any + Send)) -> &str {
    if let Some(s) = p.downcast_ref::<&'static str>() {
        s
    } else if let Some(s) = p.downcast_ref::<String>() {
        s
    } else {
        "<non-string payload>"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let counter = Arc::new(AtomicUsize::new(0));
        let pool = ThreadPool::new(4);
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map((0..50).collect::<Vec<_>>(), 4, |x| x * 2).unwrap();
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn borrowed_pool_is_reusable_across_maps() {
        let pool = ThreadPool::new(3);
        let a = parallel_map_on(&pool, (0..20).collect::<Vec<_>>(), |x| x + 1).unwrap();
        let b = parallel_map_on(&pool, (0..20).collect::<Vec<_>>(), |x| x * 3).unwrap();
        assert_eq!(a, (1..21).collect::<Vec<_>>());
        assert_eq!(b, (0..20).map(|x| x * 3).collect::<Vec<_>>());
        pool.join();
    }

    #[test]
    fn panicked_job_surfaces_as_error_not_panic() {
        let pool = ThreadPool::new(2);
        let err = parallel_map_on(&pool, vec![1, 2, 3, 4], |x| {
            if x == 3 {
                panic!("boom on {x}");
            }
            x
        })
        .unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("job 2") && msg.contains("boom"), "{msg}");
        // the pool survives the panicked job and keeps serving
        let ok = parallel_map_on(&pool, vec![10, 20], |x| x / 2).unwrap();
        assert_eq!(ok, vec![5, 10]);
        pool.join();
    }

    #[test]
    fn drop_joins() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        } // drop waits
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }
}
