//! Scoped thread pool + mpsc work queue.
//!
//! tokio is not in the offline vendor set; the coordinator and the search
//! engine use this std-thread pool instead (same architecture — bounded
//! queue, worker loop — without async syntax).  On the 1-core CI box the
//! pool degenerates gracefully to near-serial execution.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool. Jobs run FIFO; `join` blocks until the queue
/// drains and all workers exit.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|_| {
                let rx = Arc::clone(&rx);
                thread::spawn(move || loop {
                    let job = { rx.lock().unwrap().recv() };
                    match job {
                        Ok(job) => job(),
                        Err(_) => break, // channel closed
                    }
                })
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool joined")
            .send(Box::new(f))
            .expect("worker hung up");
    }

    /// Drop the sender and wait for all workers to finish the queue.
    pub fn join(mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Map `f` over `items` in parallel, preserving order.
pub fn parallel_map<T, R, F>(items: Vec<T>, nthreads: usize, f: F) -> Vec<R>
where
    T: Send + 'static,
    R: Send + 'static,
    F: Fn(T) -> R + Send + Sync + 'static,
{
    let n = items.len();
    let f = Arc::new(f);
    let (tx, rx) = mpsc::channel();
    let pool = ThreadPool::new(nthreads);
    for (i, item) in items.into_iter().enumerate() {
        let tx = tx.clone();
        let f = Arc::clone(&f);
        pool.execute(move || {
            let r = f(item);
            let _ = tx.send((i, r));
        });
    }
    drop(tx);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    for (i, r) in rx {
        out[i] = Some(r);
    }
    pool.join();
    out.into_iter().map(|o| o.expect("worker died")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let counter = Arc::new(AtomicUsize::new(0));
        let pool = ThreadPool::new(4);
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map((0..50).collect::<Vec<_>>(), 4, |x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn drop_joins() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        } // drop waits
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }
}
