//! Dense row-major f32 tensors + the python⇄rust binary interchange.
//!
//! Deliberately minimal: the heavy math lives in the AOT-compiled HLO; the
//! rust side only needs shape-carrying buffers for marshalling, metric
//! computation (RMSE, top-1) and the format codecs.

pub mod io;

use anyhow::{bail, Result};

/// Row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn from_vec(data: Vec<f32>) -> Self {
        Tensor { shape: vec![data.len()], data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Reshape in place (must preserve element count).
    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("cannot reshape {:?} -> {:?}", self.shape, shape);
        }
        self.shape = shape;
        Ok(self)
    }

    /// Row `i` of a rank-2 tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.rank(), 2);
        let c = self.shape[1];
        &self.data[i * c..(i + 1) * c]
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    pub fn mean(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().map(|&x| x as f64).sum::<f64>() / self.data.len() as f64
    }

    pub fn std(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        let m = self.mean();
        (self
            .data
            .iter()
            .map(|&x| (x as f64 - m) * (x as f64 - m))
            .sum::<f64>()
            / self.data.len() as f64)
            .sqrt()
    }

    /// argmax over the last axis of a rank-2 tensor -> per-row indices.
    ///
    /// Total order (`f32::total_cmp`), so NaN logits pick a
    /// deterministic index instead of panicking — a serving worker must
    /// answer every request even when a model emits NaNs (NaN sorts
    /// above +∞ in the total order, so a NaN slot wins its row).
    pub fn argmax_rows(&self) -> Vec<usize> {
        assert_eq!(self.rank(), 2);
        (0..self.shape[0])
            .map(|i| {
                let r = self.row(i);
                r.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(j, _)| j)
                    .unwrap_or(0)
            })
            .collect()
    }

    /// Per-row `(argmax, margin)` over the last axis of a rank-2 tensor,
    /// where margin = winner minus runner-up — the confidence signal the
    /// serving escalation router thresholds on (DESIGN.md §10).
    ///
    /// The winner is chosen under the same total order as
    /// [`Tensor::argmax_rows`] (ties → last maximal index, NaN above
    /// +∞), so both paths always agree on the predicted class.  A
    /// single-column row has no runner-up and reports +∞ (maximally
    /// confident); a NaN winner or runner-up yields a NaN margin, and
    /// NaN compares false against any threshold — NaN logits never look
    /// "low-confidence" to an escalation policy.
    pub fn argmax_margin_rows(&self) -> Vec<(usize, f32)> {
        assert_eq!(self.rank(), 2);
        (0..self.shape[0])
            .map(|i| {
                let r = self.row(i);
                let mut best = 0usize;
                for (j, v) in r.iter().enumerate().skip(1) {
                    // `!= Less` keeps the LAST maximal index, matching
                    // max_by in argmax_rows
                    if v.total_cmp(&r[best]) != std::cmp::Ordering::Less {
                        best = j;
                    }
                }
                let mut second: Option<f32> = None;
                for (j, &v) in r.iter().enumerate() {
                    if j == best {
                        continue;
                    }
                    let wins = match second {
                        None => true,
                        Some(s) => v.total_cmp(&s) == std::cmp::Ordering::Greater,
                    };
                    if wins {
                        second = Some(v);
                    }
                }
                match second {
                    Some(s) => (best, r[best] - s),
                    None => (best, f32::INFINITY),
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_shape() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn reshape_roundtrip() {
        let t = Tensor::zeros(&[4, 3]).reshape(vec![2, 6]).unwrap();
        assert_eq!(t.shape, vec![2, 6]);
        assert!(Tensor::zeros(&[4]).reshape(vec![5]).is_err());
    }

    #[test]
    fn stats() {
        let t = Tensor::from_vec(vec![1.0, -3.0, 2.0]);
        assert_eq!(t.max_abs(), 3.0);
        assert!((t.mean() - 0.0).abs() < 1e-12);
    }

    #[test]
    fn argmax() {
        let t = Tensor::new(vec![2, 3], vec![0.1, 0.9, 0.0, 1.0, -1.0, 0.5]).unwrap();
        assert_eq!(t.argmax_rows(), vec![1, 0]);
    }

    #[test]
    fn argmax_survives_nan_logits() {
        // regression: partial_cmp().unwrap() panicked on NaN, killing
        // the serving worker and hanging every queued client
        let t = Tensor::new(
            vec![3, 3],
            vec![0.1, f32::NAN, 0.0, 1.0, -1.0, 0.5, f32::NAN, f32::NAN, f32::NAN],
        )
        .unwrap();
        let idx = t.argmax_rows();
        assert_eq!(idx.len(), 3);
        assert_eq!(idx[0], 1); // NaN sorts above every finite value
        assert_eq!(idx[1], 0); // finite rows unaffected
        assert!(idx[2] < 3);
    }

    #[test]
    fn argmax_margin_matches_argmax_and_measures_the_gap() {
        let t = Tensor::new(vec![2, 3], vec![0.1, 0.9, 0.0, 1.0, -1.0, 0.5]).unwrap();
        let pm = t.argmax_margin_rows();
        assert_eq!(
            pm.iter().map(|&(p, _)| p).collect::<Vec<_>>(),
            t.argmax_rows()
        );
        assert!((pm[0].1 - 0.8).abs() < 1e-6);
        assert!((pm[1].1 - 0.5).abs() < 1e-6);
    }

    #[test]
    fn argmax_margin_ties_nan_and_single_class() {
        // exact tie: winner matches argmax_rows (last maximal index) and
        // the margin is zero
        let tie = Tensor::new(vec![1, 3], vec![0.5, 0.7, 0.7]).unwrap();
        let pm = tie.argmax_margin_rows();
        assert_eq!(pm[0].0, tie.argmax_rows()[0]);
        assert_eq!(pm[0].1, 0.0);
        // NaN rows agree with argmax_rows and report NaN margins, which
        // compare false against any escalation threshold
        let nan = Tensor::new(
            vec![3, 3],
            vec![0.1, f32::NAN, 0.0, 1.0, -1.0, 0.5, f32::NAN, f32::NAN, f32::NAN],
        )
        .unwrap();
        let pm = nan.argmax_margin_rows();
        assert_eq!(
            pm.iter().map(|&(p, _)| p).collect::<Vec<_>>(),
            nan.argmax_rows()
        );
        assert!(pm[0].1.is_nan());
        assert!(!(pm[0].1 < 0.5), "NaN margin must not look low-confidence");
        assert!((pm[1].1 - 0.5).abs() < 1e-6);
        assert!(pm[2].1.is_nan());
        // one class: no runner-up, maximally confident
        let one = Tensor::new(vec![2, 1], vec![3.0, -1.0]).unwrap();
        for (p, m) in one.argmax_margin_rows() {
            assert_eq!(p, 0);
            assert_eq!(m, f32::INFINITY);
        }
    }

    /// Satellite of the §11 PR: the escalation trigger had only
    /// example-based coverage.  Naive per-row reference (independent
    /// construction: lexicographic (value, index) max + max-over-rest),
    /// randomized tensors with NaN/±∞ logits, signed zeros, and exact
    /// ties mixed in.
    #[test]
    fn argmax_margin_matches_naive_reference_property() {
        use crate::util::proptest::check;

        fn naive_row(r: &[f32]) -> (usize, f32) {
            let best = (0..r.len())
                .max_by(|&a, &b| r[a].total_cmp(&r[b]).then(a.cmp(&b)))
                .unwrap();
            let second = (0..r.len())
                .filter(|&j| j != best)
                .map(|j| r[j])
                .max_by(|a, b| a.total_cmp(b));
            match second {
                Some(s) => (best, r[best] - s),
                None => (best, f32::INFINITY),
            }
        }

        check(
            "argmax-margin-vs-naive",
            300,
            |rng, size| {
                let rows = 1 + rng.below(1 + (size * 6.0) as usize);
                let cols = 1 + rng.below(1 + (size * 10.0) as usize);
                let specials =
                    [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 0.0, -0.0, 1.0, -1.0];
                let data: Vec<f32> = (0..rows * cols)
                    .map(|_| match rng.below(3) {
                        0 => specials[rng.below(specials.len())],
                        // tiny integer palette: forces exact ties
                        1 => rng.below(5) as f32 - 2.0,
                        _ => rng.normal() as f32,
                    })
                    .collect();
                (rows, cols, data)
            },
            |(rows, cols, data)| {
                let t = Tensor::new(vec![*rows, *cols], data.clone()).unwrap();
                let got = t.argmax_margin_rows();
                let idx = t.argmax_rows();
                (0..*rows).all(|i| {
                    let (bi, bm) = naive_row(&data[i * cols..(i + 1) * cols]);
                    let (gi, gm) = got[i];
                    // both paths must agree with each other AND the
                    // reference on the class; margins bit-agree except
                    // that any NaN margin matches any NaN
                    gi == bi
                        && gi == idx[i]
                        && (gm == bm || (gm.is_nan() && bm.is_nan()))
                })
            },
        );
    }

    #[test]
    fn scalar_and_row() {
        assert_eq!(Tensor::scalar(2.5).numel(), 1);
        let t = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(t.row(1), &[3.0, 4.0]);
    }
}
