//! Binary interchange with the python build path.
//!
//! `aot.py` writes `<model>_params.bin` as raw little-endian f32 in
//! manifest leaf order; this module reads/writes that format plus generic
//! f32 blobs used to checkpoint trained parameters from the rust QAT loop.

use std::fs;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Tensor;

/// Read `n` little-endian f32 values starting at element offset `off`.
pub fn read_f32_slice(path: &Path, off: usize, n: usize) -> Result<Vec<f32>> {
    let mut f = fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let meta = f.metadata()?;
    let need = (off + n) * 4;
    if (meta.len() as usize) < need {
        bail!(
            "{} too short: {} bytes, need {}",
            path.display(),
            meta.len(),
            need
        );
    }
    let mut buf = vec![0u8; n * 4];
    use std::io::Seek;
    f.seek(std::io::SeekFrom::Start((off * 4) as u64))?;
    f.read_exact(&mut buf)?;
    Ok(bytes_to_f32(&buf))
}

/// Whole-file read as f32 vector.
pub fn read_f32_file(path: &Path) -> Result<Vec<f32>> {
    let bytes = fs::read(path)
        .with_context(|| format!("read {}", path.display()))?;
    if bytes.len() % 4 != 0 {
        bail!("{} length {} not a multiple of 4", path.display(), bytes.len());
    }
    Ok(bytes_to_f32(&bytes))
}

/// Write tensors back-to-back as raw f32 LE (checkpoint format).
pub fn write_f32_file(path: &Path, tensors: &[&Tensor]) -> Result<()> {
    let mut f = fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    for t in tensors {
        f.write_all(&f32_to_bytes(&t.data))?;
    }
    Ok(())
}

pub fn bytes_to_f32(bytes: &[u8]) -> Vec<f32> {
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

pub fn f32_to_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_bytes() {
        let xs = vec![0.0f32, -1.5, 3.25e7, f32::MIN_POSITIVE];
        assert_eq!(bytes_to_f32(&f32_to_bytes(&xs)), xs);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("dybit_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.bin");
        let a = Tensor::from_vec(vec![1.0, 2.0]);
        let b = Tensor::from_vec(vec![3.0]);
        write_f32_file(&p, &[&a, &b]).unwrap();
        assert_eq!(read_f32_file(&p).unwrap(), vec![1.0, 2.0, 3.0]);
        assert_eq!(read_f32_slice(&p, 1, 2).unwrap(), vec![2.0, 3.0]);
        assert!(read_f32_slice(&p, 2, 2).is_err());
    }
}
