//! `dybit` CLI — the leader entrypoint for the whole system.
//!
//! Subcommands:
//!   formats                 print format grids + Table I
//!   simulate  --model M     per-layer cycle report at a uniform precision
//!   search    --model M     run Algorithm 1 (either strategy)
//!   train     --model M     FP32 pre-train via the AOT train-step
//!   qat       --model M     QAT fine-tune at a (format, W/A) config + eval
//!   serve     --model M     start the replica pool and run a load test
//!                           (--replicas N; --sim serves the artifact-free
//!                           simulator backend; --precision-mix 4,4,4,8
//!                           makes the pool heterogeneous and --router
//!                           fastest|floor:<bits>|escalate[:margin|:auto]
//!                           picks the scheduling policy, DESIGN.md §10;
//!                           --deadline-ms D attaches a per-request SLA,
//!                           --tenants T fair-queues the load across T
//!                           tenant buckets, and --escalation-budget B
//!                           PI-tunes the escalate:auto margin onto a
//!                           target escalation rate, DESIGN.md §12;
//!                           --chaos "die@3:r0,jitter=2" injects seeded
//!                           faults, --heartbeat-ms / --max-restarts
//!                           tune the self-healing supervisor and
//!                           --no-supervise disables it, DESIGN.md §13;
//!                           --bitplane serves the nested-precision
//!                           backend where escalations refine cached
//!                           partial sums, and --refine on|off (or a
//!                           +refine:off router suffix) toggles that
//!                           path, DESIGN.md §15)
//!   report                  dump manifest summary
//!
//! Everything executes from compiled artifacts; run `make artifacts` once.

use std::path::Path;

use anyhow::{anyhow, Result};

use dybit::coordinator::{
    parse_precision_mix, resolve_precision_mix, router_and_refine_from_spec, AdmissionCfg,
    BackendFactory, ChaosSpec, EscalationController, InferenceBackend, LoadOpts,
    PjrtBackend, Policy, PoolConfig, ReplicaPrecision, Server, SimBackend, SimBackendCfg,
    Snapshot, SupervisionCfg,
};
use dybit::formats::dybit as dybit_fmt;
use dybit::formats::Format;
use dybit::qat::{QuantConfig, Session};
use dybit::runtime::{Executor, Manifest};
use dybit::search::{run_search, Strategy};
use dybit::sim::{HwConfig, Prec, Simulator};
use dybit::util::argparse::Args;
use dybit::util::stats::Table;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().cloned().unwrap_or_default();
    let r = match cmd.as_str() {
        "formats" => cmd_formats(&args),
        "simulate" => cmd_simulate(&args),
        "search" => cmd_search(&args),
        "train" => cmd_train(&args, false),
        "qat" => cmd_train(&args, true),
        "serve" => cmd_serve(&args),
        "report" => cmd_report(&args),
        _ => {
            eprintln!(
                "usage: dybit <formats|simulate|search|train|qat|serve|report> [--flags]\n\
                 common flags: --artifacts DIR --model NAME --format dybit --wbits 4 --abits 4\n\
                 search: --strategy speedup|rmse --alpha 4.0 --beta 2.0 --topk 3\n\
                 train/qat: --steps N --lr 0.05 --eval-batches 16\n\
                 serve: --clients 4 --requests 64 --max-wait-ms 5 --max-batch N \
                 --replicas 1 [--sim] [--precision-mix 4,4,4,8] \
                 [--router fastest|floor:<bits>|escalate[:margin|:auto][+refine:on|off]] \
                 [--no-steal] [--deadline-ms D] [--tenants T] [--escalation-budget B] \
                 [--chaos SPEC] [--heartbeat-ms MS] [--max-restarts N] [--no-supervise] \
                 [--bitplane] [--refine on|off]"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn manifest(args: &Args) -> Result<Manifest> {
    let dir = args.get_or("artifacts", dybit::ARTIFACTS_DIR);
    Manifest::load(Path::new(&dir))
}

fn parse_format(args: &Args) -> Result<Format> {
    let name = args.get_or("format", "dybit");
    Format::from_name(&name).ok_or_else(|| anyhow!("unknown format '{name}'"))
}

fn cmd_formats(args: &Args) -> Result<()> {
    let bits = args.get_usize("bits", 4) as u32;
    println!("Table I — 4-bit unsigned DyBit value table:");
    let t1 = dybit_fmt::grid_unsigned(4);
    for (c, v) in t1.iter().enumerate() {
        print!("{c:04b}:{v:<6} ");
        if c % 4 == 3 {
            println!();
        }
    }
    println!("\nsigned grids at {bits} bits (scale 1.0):");
    for f in Format::ALL {
        if !f.supports(bits) {
            continue;
        }
        let g = f.grid(bits);
        println!("{:>13} ({:3} values): {:?}", f.name(), g.len(), g);
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let m = manifest(args)?;
    let name = args.get_or("model", "miniresnet18");
    let layers = dybit::models::from_manifest(&m, &name)
        .ok_or_else(|| anyhow!("model '{name}' not in manifest"))?;
    let wbits = args.get_usize("wbits", 8) as u32;
    let abits = args.get_usize("abits", 8) as u32;
    let pw = Prec::from_bits(wbits).ok_or_else(|| anyhow!("wbits must be 2/4/8"))?;
    let pa = Prec::from_bits(abits).ok_or_else(|| anyhow!("abits must be 2/4/8"))?;
    let batch = args.get_usize("batch", 1);
    let mut sim = Simulator::new(HwConfig::zcu102(), layers, batch);

    let mut table = Table::new(&["layer", "kind", "M", "K", "N", "cycles", "util", "KB moved"]);
    let assign = vec![(pw, pa); sim.layers.len()];
    let res = sim.run(&assign);
    for (l, c) in sim.layers.clone().iter().zip(res.per_layer.iter()) {
        table.row(vec![
            l.name.clone(),
            format!("{:?}", l.kind),
            l.m.to_string(),
            l.k.to_string(),
            l.n.to_string(),
            c.total.to_string(),
            format!("{:.2}", c.utilization),
            format!("{:.1}", c.bytes as f64 / 1024.0),
        ]);
    }
    table.print();
    println!(
        "total: {} cycles = {:.3} ms @ {} MHz  (batch={batch}, {}W{}A)",
        res.total_cycles,
        res.latency_s * 1e3,
        sim.cfg.freq_mhz,
        wbits,
        abits
    );
    let base = sim.speedup(&assign);
    println!("speedup vs 8/8 baseline: {base:.2}x");
    Ok(())
}

fn cmd_search(args: &Args) -> Result<()> {
    let m = manifest(args)?;
    let name = args.get_or("model", "miniresnet18");
    let fmt = parse_format(args)?;
    let strategy = match args.get_or("strategy", "speedup").as_str() {
        "speedup" => Strategy::SpeedupConstrained { alpha: args.get_f64("alpha", 4.0) },
        "rmse" => Strategy::RmseConstrained { beta: args.get_f64("beta", 2.0) },
        s => return Err(anyhow!("strategy must be speedup|rmse, got {s}")),
    };
    let top_k = args.get_usize("topk", 3);

    let mut exec = Executor::new(&m.dir)?;
    let mut session = Session::new(&m, &name)?;
    let weights = session.layer_weights();
    let acts = session.layer_acts(&mut exec, 7)?;
    let layers = session.model.layers.clone();
    let sim = Simulator::new(HwConfig::zcu102(), layers, 1);

    let r = run_search(&sim, &weights, &acts, fmt, strategy, top_k);
    println!("strategy: {strategy:?} (top-k {top_k}), format {}", fmt.name());
    println!(
        "result: speedup {:.2}x, rmse ratio {:.3}, satisfied={}, {} iters",
        r.speedup, r.rmse_ratio, r.satisfied, r.iterations
    );
    let mut table = Table::new(&["layer", "W bits", "A bits"]);
    for (l, (pw, pa)) in session.model.layers.iter().zip(r.assignment.iter()) {
        table.row(vec![l.name.clone(), pw.bits().to_string(), pa.bits().to_string()]);
    }
    table.print();
    Ok(())
}

fn cmd_train(args: &Args, qat: bool) -> Result<()> {
    let m = manifest(args)?;
    let name = args.get_or("model", "mlp");
    let steps = args.get_usize("steps", 200);
    let lr = args.get_f32("lr", 0.05);
    let eval_batches = args.get_usize("eval-batches", 16);

    let mut exec = Executor::new(&m.dir)?;
    let mut session = Session::new(&m, &name)?;
    let nl = session.model.n_quant_layers;

    let mut q = if qat {
        let fmt = parse_format(args)?;
        let wbits = args.get_usize("wbits", 4) as u32;
        let abits = args.get_usize("abits", 4) as u32;
        QuantConfig::uniform(nl, fmt, wbits, abits)
    } else {
        QuantConfig::fp32(nl)
    };
    if qat {
        session.calibrate(&mut exec, &mut q, 99)?;
    }

    println!(
        "{} {name}: {steps} steps, lr {lr} ({} artifacts from {})",
        if qat { "QAT" } else { "train" },
        exec.platform(),
        m.dir.display()
    );
    let t0 = std::time::Instant::now();
    for chunk in 0..steps.div_ceil(25) {
        let s0 = chunk * 25;
        let n = 25.min(steps - s0);
        let ms = session.train(&mut exec, &q, n, lr, s0 as i32)?;
        let last = ms.last().unwrap();
        println!(
            "step {:4}: loss {:.4} acc {:.3} ({:.1}s)",
            s0 + n,
            last.loss,
            last.acc,
            t0.elapsed().as_secs_f64()
        );
    }
    let ev = session.evaluate(&mut exec, &q, eval_batches)?;
    println!("eval: loss {:.4} top-1 {:.4}", ev.loss, ev.acc);
    Ok(())
}

/// The serve metrics printout shared by both backends (the README's
/// worked example shows this shape).
fn print_serve_snapshot(snap: &Snapshot, precisions: &[ReplicaPrecision]) {
    println!(
        "requests {}  batches {}  errors {}  rejected {}  deadline drops {}  \
         escalations {}  refined {}  mean batch {:.1}  p50 {:.1}ms  p95 {:.1}ms  \
         {:.1} req/s  (queue depth {})",
        snap.requests, snap.batches, snap.errors, snap.rejected, snap.deadline_drops,
        snap.escalations, snap.refinements, snap.mean_batch, snap.lat_p50_ms,
        snap.lat_p95_ms, snap.throughput_rps, snap.queue_depth
    );
    print!("{}", snap.replica_report(precisions));
}

fn cmd_serve(args: &Args) -> Result<()> {
    let wbits = args.get_usize("wbits", 4) as u32;
    let abits = args.get_usize("abits", 8) as u32;
    // --precision-mix makes the pool heterogeneous (DESIGN.md §10): one
    // entry per replica, overriding --replicas with the mix length; no
    // mix means --replicas uniform (wbits, abits) tiers
    let mix: Vec<ReplicaPrecision> = match args.get("precision-mix") {
        Some(s) => parse_precision_mix(s)?,
        None => Vec::new(),
    };
    let precisions =
        resolve_precision_mix(mix, wbits, abits, args.get_usize("replicas", 1));
    let replicas = precisions.len();
    // --escalation-budget needs a tunable margin, so it flips the
    // *default* router to escalate:auto; an explicit --router still
    // wins (and start_pool rejects incompatible combinations)
    let escalation = match args.get("escalation-budget") {
        Some(s) => {
            let budget: f64 =
                s.parse().map_err(|_| anyhow!("--escalation-budget must be a number"))?;
            Some(EscalationController::with_budget(budget))
        }
        None => None,
    };
    let default_router = if escalation.is_some() { "escalate:auto" } else { "fastest" };
    // §15 refinement is on by default; turn it off with either the
    // `+refine:off` router-spec suffix or the standalone --refine off
    // flag (the flag wins when both are present)
    let (router, refine_spec) =
        router_and_refine_from_spec(&args.get_or("router", default_router))?;
    let refine = match args.get("refine") {
        Some("on") => true,
        Some("off") => false,
        Some(other) => return Err(anyhow!("--refine must be on|off, got '{other}'")),
        None => refine_spec,
    };
    let margin_knob = router.margin_knob();
    let deadline = match args.get("deadline-ms") {
        Some(s) => {
            let ms: f64 = s.parse().map_err(|_| anyhow!("--deadline-ms must be a number"))?;
            Some(std::time::Duration::from_secs_f64(ms.max(0.0) / 1e3))
        }
        None => None,
    };
    let tenants = args.get_usize("tenants", 1) as u32;
    let work_stealing = !args.has("no-steal");
    // --chaos injects seeded faults through a backend decorator; the
    // supervisor (on by default, DESIGN.md §13) detects and heals them.
    // --heartbeat-ms / --max-restarts tune it; --no-supervise restores
    // the pre-§13 die-loudly behavior.
    let chaos = match args.get("chaos") {
        Some(s) => Some(ChaosSpec::parse(s)?),
        None => None,
    };
    let supervision = if args.has("no-supervise") {
        None
    } else {
        let mut sup = SupervisionCfg::default();
        if let Some(s) = args.get("heartbeat-ms") {
            let ms: u64 = s.parse().map_err(|_| anyhow!("--heartbeat-ms must be an integer"))?;
            sup.heartbeat = std::time::Duration::from_millis(ms);
        }
        if let Some(s) = args.get("max-restarts") {
            sup.max_restarts =
                s.parse().map_err(|_| anyhow!("--max-restarts must be an integer"))?;
        }
        Some(sup)
    };
    // default max-batch is "the backend's static batch dim": the pool
    // clamps per replica, so MAX means "fill whatever the model takes"
    let policy = Policy {
        max_batch: args.get_usize("max-batch", usize::MAX),
        max_wait: std::time::Duration::from_millis(args.get_usize("max-wait-ms", 5) as u64),
    };
    let queue_cap = args.get_usize("queue-cap", 256);
    let clients = args.get_usize("clients", 4);
    let requests = args.get_usize("requests", 64);
    let router_name = router.name().to_string();

    let server = if args.has("sim") {
        // artifact-free serving over the simulator-costed backend
        // (DESIGN.md §9): cycle-costed batches, seeded linear scorer
        let cfg = SimBackendCfg {
            batch: args.get_usize("batch", 8),
            wbits,
            abits,
            time_scale: args.get_f64("time-scale", 0.0),
            ..SimBackendCfg::tiny(17)
        };
        let tiers: Vec<String> = precisions.iter().map(|p| p.to_string()).collect();
        println!(
            "serving sim backend (mix [{}], batch {}, {replicas} replica(s), \
             router {router_name}), load test: {clients} clients x {requests} reqs",
            tiers.join(", "),
            cfg.batch
        );
        // mixed_factory with a uniform mix IS the homogeneous pool, so
        // one factory path serves both (and the per-replica printout +
        // steal floors always reflect the backend's real bits)
        // seed the admission cost table from the cycle simulator so the
        // very first SLA projection is already per-precision (§12); the
        // EWMA refines it from observed batches either way
        let admission = AdmissionCfg {
            batch_cost: cfg.projected_batch_costs(&precisions)?,
            tenants,
            ..AdmissionCfg::default()
        };
        // --bitplane serves the §15 nested-precision backend: same
        // logits at full depth, but escalations refine from cached
        // partial sums instead of re-running (pair with --refine off /
        // +refine:off to measure the difference)
        let factory = if args.has("bitplane") {
            dybit::coordinator::BitplaneBackend::mixed_factory(cfg, precisions.clone())
        } else {
            SimBackend::mixed_factory(cfg, precisions.clone())
        };
        let factory = match chaos.clone() {
            Some(spec) => spec.wrap(factory),
            None => factory,
        };
        Server::start_pool(
            PoolConfig {
                policy,
                queue_cap,
                replicas,
                precisions,
                router,
                work_stealing,
                admission,
                escalation,
                supervision: supervision.clone(),
                refine,
            },
            factory,
        )?
    } else {
        let m = manifest(args)?;
        let name = args.get_or("model", "mlp");
        let entry = m.model(&name)?;
        let fmt = parse_format(args)?;
        // honor an explicit --max-batch below the model's batch dim; the
        // pool clamps the upper bound to entry.batch
        let policy = Policy {
            max_batch: policy.max_batch.clamp(1, entry.batch.max(1)),
            ..policy
        };
        let tiers: Vec<String> = precisions.iter().map(|p| p.to_string()).collect();
        println!(
            "serving {name} (mix [{}] {}, {replicas} replica(s), router \
             {router_name}), load test: {clients} clients x {requests} reqs",
            tiers.join(", "),
            fmt.name()
        );
        // a homogeneous pool is just a mix of identical tiers, so one
        // start_pool path serves both — this also keeps --router and
        // --no-steal honored without --precision-mix (Server::start
        // would silently fall back to the defaults).  Precision is an
        // *input* of the compiled graph (DESIGN.md §2), so one artifact
        // serves every tier — each replica just gets its own uniform
        // QuantConfig
        let nl = entry.n_quant_layers;
        let pallas = args.has("pallas");
        let fmix = precisions.clone();
        let (m2, name2) = (m.clone(), name.clone());
        let factory: BackendFactory = std::sync::Arc::new(move |id| {
            let p = fmix[id % fmix.len()];
            let qcfg = QuantConfig::uniform(nl, fmt, p.wbits, p.abits);
            Ok(Box::new(PjrtBackend::new(&m2, &name2, qcfg, pallas)?)
                as Box<dyn InferenceBackend>)
        });
        let factory = match chaos.clone() {
            Some(spec) => spec.wrap(factory),
            None => factory,
        };
        // no cycle simulator for compiled artifacts: leave the cost
        // table empty and let the EWMA adopt the first observed batch
        let admission = AdmissionCfg { tenants, ..AdmissionCfg::default() };
        Server::start_pool(
            PoolConfig {
                policy,
                queue_cap,
                replicas,
                precisions,
                router,
                work_stealing,
                admission,
                escalation,
                supervision: supervision.clone(),
                refine,
            },
            factory,
        )?
    };

    let img_elems = server.img_elems();
    let precisions = server.precisions().to_vec();
    if deadline.is_some() || tenants > 1 {
        let report = dybit::coordinator::load_test_opts(
            &server,
            clients,
            requests,
            img_elems,
            LoadOpts { deadline, tenants },
        )?;
        println!(
            "admission: {} accepted, {} rejected at submit{}",
            report.accepted,
            report.rejected,
            deadline.map_or(String::new(), |d| format!(" ({:.1}ms SLA)",
                                                       d.as_secs_f64() * 1e3))
        );
    } else {
        dybit::coordinator::load_test(&server, clients, requests, img_elems)?;
    }
    if let Some(knob) = &margin_knob {
        println!("tuned escalation margin: {:.4}", knob.get());
    }
    // surface what the supervisor saw (deaths, respawns, retirements,
    // §13) — silence here means the pool ran clean end to end
    let faults = server.fault_log();
    let snap = server.shutdown()?;
    print_serve_snapshot(&snap, &precisions);
    for line in &faults {
        println!("fault: {line}");
    }
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let m = manifest(args)?;
    let mut table = Table::new(&["model", "stands for", "layers", "params", "artifacts"]);
    for (name, e) in &m.models {
        table.row(vec![
            name.clone(),
            e.stands_for.clone(),
            e.layers.len().to_string(),
            e.params.iter().map(|p| p.nelems).sum::<usize>().to_string(),
            e.artifacts.keys().cloned().collect::<Vec<_>>().join(","),
        ]);
    }
    table.print();
    Ok(())
}
