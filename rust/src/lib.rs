//! # DyBit — dynamic bit-precision quantized inference, full-system repro
//!
//! Reproduction of *DyBit: Dynamic Bit-Precision Numbers for Efficient
//! Quantized Neural Network Inference* (IEEE TCAD 2023) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * [`formats`] — the DyBit codec (Eqn. 1 / Table I) and every baseline
//!   format, with per-tensor scale adaptation and the Eqn. 2 RMSE metric.
//! * [`sim`] — cycle-accurate model of the paper's run-time configurable
//!   mixed-precision systolic accelerator (Fig. 3), ZCU102 preset.
//! * [`search`] — the hardware-aware quantization framework (Fig. 4,
//!   Algorithm 1): speedup-constrained and RMSE-constrained strategies.
//! * [`runtime`] — PJRT loader/executor for the AOT-compiled HLO artifacts
//!   produced by `python/compile/aot.py` (build-time only python).
//! * [`qat`] — quantization-aware training driver + top-1 evaluation.
//! * [`coordinator`] — inference service: precision-aware router +
//!   per-replica queues with work stealing + dynamic batcher + a
//!   (possibly heterogeneous-precision) replica pool over pluggable
//!   backends (PJRT artifacts or the artifact-free simulator backend;
//!   DESIGN.md §9–§10).
//! * [`models`] — per-model layer descriptors for the simulator.
//! * [`tensor`], [`util`] — substrates (tensors, IO, JSON, RNG, stats…).
//! * [`analysis`] — the in-tree `dybit-lint` static analyzer that
//!   mechanically enforces the DESIGN.md §11–§13 concurrency
//!   invariants and past-PR bug classes (lint catalog: DESIGN.md §14).
//!
//! The quantization hot path shared by [`formats`], [`qat`] and [`search`]
//! is the batched, cached [`formats::GridLut`] for projection and the
//! sorted prefix-sum [`formats::CalibView`] for scale calibration
//! (DESIGN.md §8; see EXPERIMENTS.md §Perf for the before/afters
//! against the per-element baselines).
//!
//! See DESIGN.md for the architecture and EXPERIMENTS.md for measured
//! reproductions of every table/figure in the paper.

#![forbid(unsafe_code)]
#![deny(rustdoc::broken_intra_doc_links)]

pub mod analysis;
pub mod coordinator;
pub mod formats;
pub mod models;
pub mod qat;
pub mod runtime;
pub mod search;
pub mod sim;
pub mod tensor;
pub mod util;

/// Default artifact directory (relative to the repo root / cwd).
pub const ARTIFACTS_DIR: &str = "artifacts";
