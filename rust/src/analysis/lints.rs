//! The lint passes: per-file token scans plus per-function
//! scope-tracking passes.  Each lint guards a written DESIGN.md
//! invariant or a bug class a past PR actually shipped; the catalog
//! with rationale lives in DESIGN.md §14.
//!
//! Two layers:
//!
//! * **token scans** ([`lint_file`]) — `raw-lock`, `float-total-cmp`,
//!   `no-unwrap`, `metrics-recorder`, `spawn-guard`: local patterns a
//!   sliding window over the comment-free token stream can decide.
//! * **function passes** (`lock-order`, `condvar-loop`,
//!   `time-checked`) — walk each `fn` body tracking lexical block
//!   depth, held lock guards, and time-typed variables.
//!
//! Known limitation (documented in DESIGN.md §14): lock-order
//! tracking is *lexical and per-function* — a guard passed into a
//! callee that then acquires a second lock is not seen.  The §11
//! cross-function nesting (`board_update` under a shard guard) is
//! covered by the stress suite and the `--sanitize` TSan tier, not by
//! this lint.
//!
//! All lints skip `#[cfg(test)]` / `#[test]` item spans: tests may
//! unwrap, sleep-subtract, and poke raw locks on purpose.

use std::collections::HashSet;

use super::annotations::{collect_annotations, FileAnnotations};
use super::lexer::{code_tokens, tokenize, Token, TokenKind};
use super::report::Finding;

/// The four accounting buckets of the DESIGN.md §12 invariant
/// (`requests + failed_requests + rejected + deadline_drops ==
/// submitted`); raw atomic ops on idents with these names outside
/// `metrics.rs` are flagged.
const BUCKETS: &[&str] = &["requests", "failed_requests", "rejected", "deadline_drops"];

/// Mutating atomic methods that count as "touching" a bucket.
const ATOMIC_OPS: &[&str] = &[
    "fetch_add",
    "fetch_sub",
    "fetch_update",
    "store",
    "swap",
    "compare_exchange",
    "compare_exchange_weak",
];

/// Callees whose result is time-typed (`Instant`/`Duration`).
const TIME_CALLEES: &[&str] = &[
    "elapsed",
    "duration_since",
    "saturating_duration_since",
    "from_secs",
    "from_millis",
    "from_micros",
    "from_nanos",
    "from_secs_f64",
    "from_secs_f32",
];

/// Callees whose result *leaves* the time domain: a `let` binding
/// routed through one of these does not produce a time-typed var.
const TIME_ESCAPES: &[&str] = &[
    "as_secs",
    "as_secs_f64",
    "as_secs_f32",
    "as_millis",
    "as_micros",
    "as_nanos",
    "subsec_nanos",
    "subsec_millis",
    "subsec_micros",
    "len",
    "is_empty",
    "count",
    "partition",
    "map_or",
    "position",
];

/// Idents whose presence in a `let` statement marks the binding as
/// time-typed (unless a [`TIME_ESCAPES`] call intervenes).
const TIME_MARKERS: &[&str] = &["Instant", "Duration", "elapsed", "duration_since"];

fn in_list(list: &[&str], s: &str) -> bool {
    list.contains(&s)
}

fn is_open(t: &str) -> bool {
    matches!(t, "(" | "[" | "{")
}

fn is_close(t: &str) -> bool {
    matches!(t, ")" | "]" | "}")
}

/// Index of the token closing the bracket at `ct[i]` (any of
/// `([{`/`)]}`); the last index when unmatched.
fn match_forward(ct: &[Token], mut i: usize) -> usize {
    let mut depth = 0i32;
    while i < ct.len() {
        if is_open(&ct[i].text) {
            depth += 1;
        } else if is_close(&ct[i].text) {
            depth -= 1;
            if depth == 0 {
                return i;
            }
        }
        i += 1;
    }
    ct.len().saturating_sub(1)
}

/// Like [`match_forward`] but counting only `{`/`}` — used to span an
/// `fn` body whose signature may contain unbalanced-looking tokens.
fn match_brace_forward(ct: &[Token], mut i: usize) -> usize {
    let mut depth = 0i32;
    while i < ct.len() {
        if ct[i].text == "{" {
            depth += 1;
        } else if ct[i].text == "}" {
            depth -= 1;
            if depth == 0 {
                return i;
            }
        }
        i += 1;
    }
    ct.len().saturating_sub(1)
}

/// Index of the token opening the bracket closed at `ct[i]`.
fn match_back(ct: &[Token], i: usize) -> usize {
    let mut depth = 0i32;
    let mut j = i as isize;
    while j >= 0 {
        let t = &ct[j as usize].text;
        if is_close(t) {
            depth += 1;
        } else if is_open(t) {
            depth -= 1;
            if depth == 0 {
                return j as usize;
            }
        }
        j -= 1;
    }
    0
}

/// Lines covered by items under `#[cfg(test)]`-ish or `#[test]`
/// attributes (the attribute line through the item body's close).
pub fn test_lines(toks: &[Token]) -> HashSet<u32> {
    let mut lines = HashSet::new();
    let ct = code_tokens(toks);
    let mut i = 0usize;
    while i < ct.len() {
        if ct[i].text == "#" && i + 1 < ct.len() && ct[i + 1].text == "[" {
            // span the attribute, noting any `test` ident inside it
            let mut depth = 0i32;
            let mut j = i + 1;
            let mut has_test = false;
            while j < ct.len() {
                if ct[j].text == "[" {
                    depth += 1;
                } else if ct[j].text == "]" {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                } else if ct[j].kind == TokenKind::Ident && ct[j].text == "test" {
                    has_test = true;
                }
                j += 1;
            }
            let attr_end = j;
            if has_test {
                let start_line = ct[i].line;
                // skip any further attributes to the item head
                let mut k = attr_end + 1;
                while k + 1 < ct.len() && ct[k].text == "#" && ct[k + 1].text == "[" {
                    let mut d = 0i32;
                    while k < ct.len() {
                        if ct[k].text == "[" {
                            d += 1;
                        } else if ct[k].text == "]" {
                            d -= 1;
                            if d == 0 {
                                break;
                            }
                        }
                        k += 1;
                    }
                    k += 1;
                }
                // item body: first top-level '{' .. matching '}', or ';'
                let mut d = 0i32;
                let mut end_line = start_line;
                while k < ct.len() {
                    let t = &ct[k];
                    if t.text == ";" && d == 0 {
                        end_line = t.line;
                        break;
                    }
                    if is_open(&t.text) {
                        d += 1;
                    } else if is_close(&t.text) {
                        d -= 1;
                        if d == 0 && t.text == "}" {
                            end_line = t.line;
                            break;
                        }
                    }
                    k += 1;
                }
                for ln in start_line..=end_line {
                    lines.insert(ln);
                }
                i = k + 1;
                continue;
            }
            i = attr_end + 1;
            continue;
        }
        i += 1;
    }
    lines
}

fn is_coordinator(path: &str) -> bool {
    path.replace('\\', "/").split('/').any(|p| p == "coordinator")
}

fn is_util_helpers(path: &str) -> bool {
    path.replace('\\', "/").ends_with("util/mod.rs")
}

fn basename(path: &str) -> &str {
    path.rsplit(|c: char| c == '/' || c == '\\').next().unwrap_or(path)
}

fn emit(
    out: &mut Vec<Finding>,
    tlines: &HashSet<u32>,
    path: &str,
    line: u32,
    lint: &'static str,
    msg: String,
) {
    if !tlines.contains(&line) {
        out.push(Finding::new(path, line, lint, msg));
    }
}

/// Run every pass over one file.  Returns
/// `(unsuppressed, suppressed)` findings; well-formed `quota-touch`
/// annotations are accumulated into the cross-file `quota_methods`
/// set (the driver pre-populates it in a first pass over all files).
pub fn lint_file(
    path: &str,
    src: &str,
    quota_methods: &mut HashSet<String>,
) -> (Vec<Finding>, Vec<Finding>) {
    let toks = tokenize(src);
    let tlines = test_lines(&toks);
    let ann = collect_annotations(path, &toks, quota_methods);
    let ct = code_tokens(&toks);
    let mut findings: Vec<Finding> = ann.findings.clone();

    // ---- raw-lock + simple token scans -----------------------------
    let fname = basename(path);
    for i in 0..ct.len() {
        let t = &ct[i];
        if tlines.contains(&t.line) {
            continue;
        }
        let nxt = ct.get(i + 1);
        let prv = if i > 0 { ct.get(i - 1) } else { None };
        let nxt_is = |s: &str| nxt.is_some_and(|u| u.text == s);
        let prv_is = |s: &str| prv.is_some_and(|u| u.text == s);
        // raw-lock: method-call forms of lock/wait/wait_timeout
        if t.kind == TokenKind::Ident
            && matches!(t.text.as_str(), "lock" | "wait" | "wait_timeout")
            && prv_is(".")
            && nxt_is("(")
            && !is_util_helpers(path)
        {
            emit(
                &mut findings,
                &tlines,
                path,
                t.line,
                "raw-lock",
                format!(
                    ".{0}() bypasses the poison-recovering util::{0} helper (DESIGN.md §9/§11)",
                    t.text
                ),
            );
        }
        // float-total-cmp
        if t.kind == TokenKind::Ident && t.text == "partial_cmp" {
            emit(
                &mut findings,
                &tlines,
                path,
                t.line,
                "float-total-cmp",
                "partial_cmp in a sort/max position hangs or panics on NaN — use total_cmp \
                 (DESIGN.md §14, PR 4 bug class)"
                    .to_string(),
            );
        }
        // no-unwrap (coordinator only)
        if is_coordinator(path)
            && t.kind == TokenKind::Ident
            && matches!(t.text.as_str(), "unwrap" | "expect")
            && prv_is(".")
            && nxt_is("(")
        {
            emit(
                &mut findings,
                &tlines,
                path,
                t.line,
                "no-unwrap",
                format!(
                    ".{}() in non-test coordinator code can kill a worker and strand its \
                     clients — return an Err",
                    t.text
                ),
            );
        }
        // metrics-recorder
        if t.kind == TokenKind::Ident
            && in_list(BUCKETS, &t.text)
            && fname != "metrics.rs"
            && nxt_is(".")
            && i + 2 < ct.len()
            && in_list(ATOMIC_OPS, &ct[i + 2].text)
            && i + 3 < ct.len()
            && ct[i + 3].text == "("
        {
            emit(
                &mut findings,
                &tlines,
                path,
                t.line,
                "metrics-recorder",
                format!(
                    "raw {} on accounting bucket '{}' — the four-bucket invariant is \
                     maintained only by Metrics recorder methods (DESIGN.md §12)",
                    ct[i + 2].text,
                    t.text
                ),
            );
        }
        // spawn-guard: detached thread::spawn bodies
        let is_spawn = t.text == "spawn"
            && nxt_is("(")
            && prv_is("::")
            && i >= 2
            && ct[i - 2].text == "thread";
        if is_spawn {
            let close = match_forward(&ct, i + 1);
            let body = &ct[i + 1..=close.min(ct.len() - 1)];
            let guarded = body.iter().any(|u| {
                u.kind == TokenKind::Ident
                    && matches!(u.text.as_str(), "catch_unwind" | "DeathWatch")
            });
            if !guarded {
                let last_line = body.last().map(|u| u.line).unwrap_or(t.line);
                let near = (t.line.saturating_sub(3)..=last_line)
                    .any(|ln| ann.spawn_guard_lines.contains(&ln));
                if !near {
                    emit(
                        &mut findings,
                        &tlines,
                        path,
                        t.line,
                        "spawn-guard",
                        "detached thread body has no catch_unwind/DeathWatch guard and no \
                         `// spawn-guard:` justification (DESIGN.md §13)"
                            .to_string(),
                    );
                }
            }
        }
    }

    // ---- per-function passes ---------------------------------------
    function_passes(path, &ct, &tlines, &ann, quota_methods, &mut findings);

    // ---- split suppressed / unsuppressed ---------------------------
    let mut unsuppressed = Vec::new();
    let mut suppressed = Vec::new();
    for f in findings {
        let allowed = f.lint != "suppression"
            && ann.allow.get(&f.line).is_some_and(|ids| ids.contains(f.lint));
        if allowed {
            suppressed.push(f);
        } else {
            unsuppressed.push(f);
        }
    }
    (unsuppressed, suppressed)
}

/// `lock-order`, `condvar-loop`, `time-checked`: walk each `fn` body.
fn function_passes(
    path: &str,
    ct: &[Token],
    tlines: &HashSet<u32>,
    ann: &FileAnnotations,
    quota_methods: &HashSet<String>,
    out: &mut Vec<Finding>,
) {
    let mut i = 0usize;
    while i < ct.len() {
        if ct[i].kind == TokenKind::Ident && ct[i].text == "fn" && i + 1 < ct.len() {
            // signature: up to the body '{' (or ';' for trait decls)
            let mut j = i + 1;
            while j < ct.len() && ct[j].text != "{" && ct[j].text != ";" {
                j += 1;
            }
            if j >= ct.len() || ct[j].text == ";" {
                i = j + 1;
                continue;
            }
            let sig = &ct[i + 1..j];
            let body_open = j;
            let body_close = match_brace_forward(ct, body_open);
            analyze_fn(path, ct, sig, body_open, body_close, ann, quota_methods, tlines, out);
            // nested fns/closures are analyzed as part of the
            // enclosing body (same held-guard scope rules)
            i = body_close + 1;
        } else {
            i += 1;
        }
    }
}

/// Tokens of the statement starting at `ct[i]` (through `;` or a
/// closing bracket at depth 0).
fn stmt_tokens(ct: &[Token], i: usize) -> Vec<&Token> {
    let mut depth = 0i32;
    let mut j = i;
    let mut stmt = Vec::new();
    while j < ct.len() {
        let t = &ct[j];
        if is_open(&t.text) {
            depth += 1;
        } else if is_close(&t.text) {
            if depth == 0 {
                break;
            }
            depth -= 1;
        } else if t.text == ";" && depth == 0 {
            break;
        }
        stmt.push(t);
        j += 1;
    }
    stmt
}

/// One held, *named* lock guard (transient guards — method chains on
/// the lock call — never enter this list).
struct Held {
    name: String,
    group: String,
    level: u32,
    alone: bool,
    depth: i32,
}

#[allow(clippy::too_many_arguments)]
fn analyze_fn(
    path: &str,
    ct: &[Token],
    sig: &[Token],
    body_open: usize,
    body_close: usize,
    ann: &FileAnnotations,
    quota_methods: &HashSet<String>,
    tlines: &HashSet<u32>,
    out: &mut Vec<Finding>,
) {
    let lock_fields = &ann.lock_fields;

    // --- time-typed vars from the signature -------------------------
    let mut time_vars: HashSet<String> = HashSet::new();
    if let Some(p0) = sig.iter().position(|t| t.text == "(") {
        let mut depth = 0i32;
        let mut pend = sig.len().saturating_sub(1);
        for (px, t) in sig.iter().enumerate().skip(p0) {
            if t.text == "(" {
                depth += 1;
            } else if t.text == ")" {
                depth -= 1;
                if depth == 0 {
                    pend = px;
                    break;
                }
            }
        }
        let params = &sig[p0 + 1..pend.max(p0 + 1)];
        // split on top-level commas; mark `name: ...Instant/Duration...`
        let mut groups: Vec<Vec<&Token>> = Vec::new();
        let mut cur: Vec<&Token> = Vec::new();
        let mut d = 0i32;
        for t in params {
            if matches!(t.text.as_str(), "(" | "[" | "{" | "<") {
                d += 1;
            } else if matches!(t.text.as_str(), ")" | "]" | "}" | ">") {
                d -= 1;
            }
            if t.text == "," && d == 0 {
                groups.push(std::mem::take(&mut cur));
            } else {
                cur.push(t);
            }
        }
        if !cur.is_empty() {
            groups.push(cur);
        }
        for g in &groups {
            let Some(first) = g.first() else { continue };
            let has_time = g.iter().any(|t| t.text == "Instant" || t.text == "Duration");
            if has_time && first.kind == TokenKind::Ident {
                time_vars.insert(first.text.clone());
            }
        }
    }

    // --- walk the body ----------------------------------------------
    let mut held: Vec<Held> = Vec::new();
    let mut depth = 0i32;
    let mut block_kinds: Vec<&'static str> = Vec::new();
    let mut pending_kind: Option<&'static str> = None;
    let mut match_time_depths: Vec<i32> = Vec::new();

    let mut i = body_open;
    while i <= body_close {
        let t = &ct[i];
        let txt = t.text.as_str();

        if t.kind == TokenKind::Ident
            && matches!(txt, "loop" | "while" | "for" | "if" | "else" | "match" | "unsafe" | "move")
        {
            if txt == "match" {
                // time-typed scrutinee? tokens up to the match '{'
                let mut j = i + 1;
                let mut d2 = 0i32;
                let mut scrut_time = false;
                while j <= body_close {
                    let u = &ct[j];
                    if matches!(u.text.as_str(), "(" | "[") {
                        d2 += 1;
                    } else if matches!(u.text.as_str(), ")" | "]") {
                        d2 -= 1;
                    } else if u.text == "{" && d2 == 0 {
                        break;
                    }
                    if u.kind == TokenKind::Ident
                        && (time_vars.contains(&u.text)
                            || u.text == "Instant"
                            || u.text == "Duration")
                    {
                        scrut_time = true;
                    }
                    j += 1;
                }
                if scrut_time {
                    match_time_depths.push(depth + 1);
                }
            }
            pending_kind = match txt {
                "move" => pending_kind,
                "loop" => Some("loop"),
                "while" => Some("while"),
                "for" => Some("for"),
                "if" => Some("if"),
                "else" => Some("else"),
                "match" => Some("match"),
                _ => Some("unsafe"),
            };
            i += 1;
            continue;
        }

        if txt == "{" {
            depth += 1;
            block_kinds.push(pending_kind.unwrap_or("block"));
            pending_kind = None;
            i += 1;
            continue;
        }
        if txt == "}" {
            held.retain(|h| h.depth < depth);
            if match_time_depths.last() == Some(&depth) {
                match_time_depths.pop();
            }
            block_kinds.pop();
            depth -= 1;
            i += 1;
            continue;
        }
        if txt == ";" {
            pending_kind = None;
            i += 1;
            continue;
        }

        // Some(x)/Ok(x) arm bindings inside a time-typed match
        if t.kind == TokenKind::Ident
            && matches!(txt, "Some" | "Ok")
            && match_time_depths.last().is_some_and(|&d| depth >= d)
            && i + 2 <= body_close
            && ct[i + 1].text == "("
            && ct[i + 2].kind == TokenKind::Ident
        {
            // only when this is an arm pattern: ')' then '=>' follows
            let j = match_forward(ct, i + 1);
            if j + 1 <= body_close && ct[j + 1].text == "=>" {
                time_vars.insert(ct[i + 2].text.clone());
            }
        }

        // let statements: collect time-typed bindings
        if t.kind == TokenKind::Ident && txt == "let" {
            let stmt = stmt_tokens(ct, i);
            let marker = stmt.iter().any(|u| {
                u.kind == TokenKind::Ident
                    && (in_list(TIME_MARKERS, &u.text) || time_vars.contains(&u.text))
            });
            let escape = stmt
                .iter()
                .any(|u| u.kind == TokenKind::Ident && in_list(TIME_ESCAPES, &u.text));
            if marker && !escape {
                // pattern ident: first ident between `let` and `=`
                for u in stmt.iter().skip(1) {
                    if u.text == "=" {
                        break;
                    }
                    if u.kind == TokenKind::Ident && u.text != "mut" && u.text != "ref" {
                        time_vars.insert(u.text.clone());
                        break;
                    }
                }
            }
            // fall through: the lock()-acquisition scan below still
            // sees this statement's tokens
        }

        // drop(guard) releases
        if t.kind == TokenKind::Ident
            && txt == "drop"
            && i + 2 <= body_close
            && ct[i + 1].text == "("
            && ct[i + 2].kind == TokenKind::Ident
        {
            let name = &ct[i + 2].text;
            held.retain(|h| &h.name != name);
        }

        // quota-touch call under any annotated guard
        if t.kind == TokenKind::Ident
            && quota_methods.contains(txt)
            && i + 1 <= body_close
            && ct[i + 1].text == "("
            && i > 0
            && matches!(ct[i - 1].text.as_str(), "." | "::")
            && !held.is_empty()
        {
            emit(
                out,
                tlines,
                path,
                t.line,
                "lock-order",
                format!(
                    "tenant-occupancy touch '{txt}()' while holding an intake guard — the \
                     quota table must never nest inside intake locks (DESIGN.md §12)"
                ),
            );
        }

        // lock acquisitions: free `lock(&...field)` or raw `.lock()`
        let mut acquired: Option<String> = None;
        if t.kind == TokenKind::Ident
            && txt == "lock"
            && i + 1 <= body_close
            && ct[i + 1].text == "("
            && (i == 0 || ct[i - 1].text != ".")
        {
            let close = match_forward(ct, i + 1);
            acquired = ct[i + 2..close.max(i + 2)]
                .iter()
                .filter(|u| u.kind == TokenKind::Ident)
                .next_back()
                .map(|u| u.text.clone());
        } else if t.kind == TokenKind::Ident
            && txt == "lock"
            && i > 0
            && ct[i - 1].text == "."
            && i + 1 <= body_close
            && ct[i + 1].text == "("
        {
            acquired = ct[i.saturating_sub(8)..i - 1]
                .iter()
                .filter(|u| u.kind == TokenKind::Ident)
                .next_back()
                .map(|u| u.text.clone());
        }
        if let Some(field) = acquired.as_ref() {
            if let Some(spec) = lock_fields.get(field) {
                for h in &held {
                    if spec.alone || h.alone {
                        emit(
                            out,
                            tlines,
                            path,
                            t.line,
                            "lock-order",
                            format!(
                                "'{field}' and '{}' held together but one is annotated \
                                 `alone` (DESIGN.md §11: the park lock is only ever held \
                                 alone)",
                                h.name
                            ),
                        );
                        break;
                    }
                    if h.group == spec.group && spec.level <= h.level {
                        emit(
                            out,
                            tlines,
                            path,
                            t.line,
                            "lock-order",
                            format!(
                                "acquiring '{field}' (level {}) while holding '{}' (level \
                                 {}) violates the {} lock order (DESIGN.md §11: shard → \
                                 board only)",
                                spec.level, h.name, h.level, spec.group
                            ),
                        );
                        break;
                    }
                }
                // bound or transient?  A guard binding is
                // `<ident> = lock(..);` — a method chain after the call
                // (`lock(..).clone()`) is a temporary dropped at
                // statement end and never enters `held`.
                if i >= 2 && ct[i - 1].text == "=" && ct[i - 2].kind == TokenKind::Ident {
                    let close = match_forward(ct, i + 1);
                    if ct.get(close + 1).is_some_and(|u| u.text == ";") {
                        held.push(Held {
                            name: ct[i - 2].text.clone(),
                            group: spec.group.clone(),
                            level: spec.level,
                            alone: spec.alone,
                            depth,
                        });
                    }
                }
            }
        }

        // condvar-loop: free wait()/wait_timeout() calls
        if t.kind == TokenKind::Ident
            && matches!(txt, "wait" | "wait_timeout")
            && i + 1 <= body_close
            && ct[i + 1].text == "("
            && (i == 0 || ct[i - 1].text != ".")
            && !is_util_helpers(path)
            && !block_kinds.iter().any(|k| matches!(*k, "loop" | "while"))
        {
            emit(
                out,
                tlines,
                path,
                t.line,
                "condvar-loop",
                format!(
                    "condvar {txt}() outside a while/loop predicate re-check — spurious \
                     wakeups break an `if` guard (DESIGN.md §14)"
                ),
            );
        }

        // time-checked: binary +/- or +=/-= with a time-typed operand
        if matches!(txt, "+" | "-" | "+=" | "-=") && i > 0 {
            let prv = &ct[i - 1];
            let binary = matches!(
                prv.kind,
                TokenKind::Ident | TokenKind::Num | TokenKind::Str | TokenKind::Char
            ) || prv.text == ")"
                || prv.text == "]";
            if binary {
                let left_time = operand_is_time_back(ct, i - 1, &time_vars);
                let right_time = operand_is_time_fwd(ct, i + 1, &time_vars);
                if left_time || right_time {
                    emit(
                        out,
                        tlines,
                        path,
                        t.line,
                        "time-checked",
                        format!(
                            "bare `{txt}` on Instant/Duration can panic on \
                             underflow/overflow — use checked_add/checked_sub/\
                             saturating_duration_since (DESIGN.md §9, PR 2 bug class)"
                        ),
                    );
                }
            }
        }
        i += 1;
    }
}

/// Is the operand *ending* at `ct[i]` time-typed?  An ident in the
/// time-var set, a call of a [`TIME_CALLEES`] method, or
/// `Instant::now(..)`.
fn operand_is_time_back(ct: &[Token], i: usize, time_vars: &HashSet<String>) -> bool {
    let Some(t) = ct.get(i) else { return false };
    if t.kind == TokenKind::Ident {
        return time_vars.contains(&t.text);
    }
    if t.text == ")" {
        let op = match_back(ct, i);
        if op >= 1 {
            let callee = &ct[op - 1];
            if callee.kind == TokenKind::Ident {
                if callee.text == "now"
                    && op >= 3
                    && ct[op - 2].text == "::"
                    && ct[op - 3].text == "Instant"
                {
                    return true;
                }
                return in_list(TIME_CALLEES, &callee.text);
            }
        }
    }
    false
}

/// Is the operand *starting* at `ct[i]` time-typed?  A time var, or a
/// leading `Instant::now` / `Duration::from_*` path.
fn operand_is_time_fwd(ct: &[Token], i: usize, time_vars: &HashSet<String>) -> bool {
    let Some(t) = ct.get(i) else { return false };
    if t.kind == TokenKind::Ident {
        if time_vars.contains(&t.text) {
            return true;
        }
        if (t.text == "Instant" || t.text == "Duration")
            && i + 2 < ct.len()
            && ct[i + 1].text == "::"
        {
            let nxt = &ct[i + 2];
            return nxt.text == "now" || in_list(TIME_CALLEES, &nxt.text);
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(path: &str, src: &str) -> (Vec<Finding>, Vec<Finding>) {
        let mut quota = HashSet::new();
        lint_file(path, src, &mut quota)
    }

    fn lints(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.lint).collect()
    }

    #[test]
    fn raw_lock_flags_method_call_form() {
        let (unsup, _) = run("x/a.rs", "fn f(m: &Mutex<u32>) { let g = m.lock().unwrap(); }");
        assert!(lints(&unsup).contains(&"raw-lock"));
    }

    #[test]
    fn free_lock_helper_is_clean() {
        let (unsup, _) = run("x/a.rs", "fn f(m: &Mutex<u32>) { let g = lock(m); }");
        assert!(unsup.is_empty(), "{unsup:?}");
    }

    #[test]
    fn test_items_are_skipped() {
        let src = "#[test]\nfn t() { v.sort_by(|a, b| a.partial_cmp(b).unwrap()); }\n";
        let (unsup, _) = run("x/a.rs", src);
        assert!(unsup.is_empty(), "{unsup:?}");
    }

    #[test]
    fn strings_never_fire_lints() {
        let src = "fn f() { let s = \"call .lock() and partial_cmp here\"; }";
        let (unsup, _) = run("x/a.rs", src);
        assert!(unsup.is_empty(), "{unsup:?}");
    }

    #[test]
    fn lock_order_violation_and_release() {
        let src = "struct S {\n\
                   // lock-order: intake level 1\n\
                   state: Mutex<u32>,\n\
                   // lock-order: intake level 2\n\
                   board: Mutex<u32>,\n\
                   }\n\
                   fn bad(s: &S) {\n\
                   let b = lock(&s.board);\n\
                   let g = lock(&s.state);\n\
                   }\n\
                   fn good(s: &S) {\n\
                   let g = lock(&s.state);\n\
                   let b = lock(&s.board);\n\
                   }\n\
                   fn dropped(s: &S) {\n\
                   let b = lock(&s.board);\n\
                   drop(b);\n\
                   let g = lock(&s.state);\n\
                   }\n";
        let (unsup, _) = run("x/a.rs", src);
        assert_eq!(lints(&unsup), ["lock-order"]);
        assert_eq!(unsup[0].line, 9);
    }

    #[test]
    fn transient_chain_does_not_hold() {
        let src = "struct S {\n\
                   // lock-order: m level 1\n\
                   a: Mutex<u32>,\n\
                   // lock-order: m level 2\n\
                   b: Mutex<u32>,\n\
                   }\n\
                   fn f(s: &S) {\n\
                   let snap = lock(&s.b).clone();\n\
                   let g = lock(&s.a);\n\
                   }\n";
        let (unsup, _) = run("x/a.rs", src);
        assert!(unsup.is_empty(), "{unsup:?}");
    }

    #[test]
    fn condvar_wait_needs_a_loop() {
        let bad = "fn f() { if ready { g = wait(&cv, g); } }";
        let good = "fn f() { while !ready { g = wait(&cv, g); } }";
        assert_eq!(lints(&run("x/a.rs", bad).0), ["condvar-loop"]);
        assert!(run("x/a.rs", good).0.is_empty());
    }

    #[test]
    fn time_sub_flagged_saturating_clean() {
        let bad = "fn f(deadline: Instant, now: Instant) { let left = deadline - now; }";
        let good = "fn f(deadline: Instant, now: Instant) { \
                    let left = deadline.saturating_duration_since(now); }";
        assert_eq!(lints(&run("x/a.rs", bad).0), ["time-checked"]);
        assert!(run("x/a.rs", good).0.is_empty());
    }

    #[test]
    fn no_unwrap_only_in_coordinator() {
        let src = "fn f(x: Option<u32>) { let v = x.unwrap(); }";
        assert_eq!(lints(&run("rust/src/coordinator/a.rs", src).0), ["no-unwrap"]);
        assert!(run("rust/src/formats/a.rs", src).0.is_empty());
    }

    #[test]
    fn suppression_silences_exactly_one_site() {
        let src = "fn f(x: Option<u32>) {\n\
                   // lint:allow(no-unwrap): checked Some two lines up\n\
                   let v = x.unwrap();\n\
                   let w = x.unwrap();\n\
                   }";
        let (unsup, sup) = run("rust/src/coordinator/a.rs", src);
        assert_eq!(lints(&unsup), ["no-unwrap"]);
        assert_eq!(unsup[0].line, 4);
        assert_eq!(lints(&sup), ["no-unwrap"]);
        assert_eq!(sup[0].line, 3);
    }
}
