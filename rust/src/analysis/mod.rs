//! In-tree static analyzer (`dybit-lint`) for the repo's concurrency
//! and accounting invariants.
//!
//! The coordinator carries hand-proved protocols — the §11 shard→board
//! lock order, the §12 quota-table-never-under-intake-lock rule, the
//! four-bucket request accounting — and history shows those invariants
//! are exactly where real bugs landed (the PR 2 `Instant` underflow,
//! the PR 4 NaN `partial_cmp` worker kills, the PR 6 park-after-close
//! deadlock).  The stress suite catches interleavings at runtime;
//! this module stops the bug *classes* from re-entering statically.
//!
//! The build environment is offline, so the analyzer is dependency
//! free: a small Rust [`lexer`], an [`annotations`] layer for the
//! `// lock-order:` / `// spawn-guard:` / `// lint:allow(..)` comment
//! grammars, the [`lints`] passes, and a [`report`] type the
//! `dybit-lint` bin prints.  The lint catalog — ids, the invariant
//! each guards, grammar, and known limitations — is DESIGN.md §14.
//!
//! A 1:1 Python transliteration lives at
//! `python/tools/lint_mirror.py` so the gate can run on boxes without
//! a Rust toolchain; rule changes land here first and are mirrored
//! there, and the fixture suite under `rust/tests/fixtures/lint/`
//! certifies both (see EXPERIMENTS.md).

pub mod annotations;
pub mod lexer;
pub mod lints;
pub mod report;

use std::collections::HashSet;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

pub use report::{Finding, Report};

/// Every lint id the analyzer can emit.  `suppression` is the
/// meta-lint for malformed or unjustified annotations and cannot
/// itself be suppressed.
pub const LINT_IDS: &[&str] = &[
    "raw-lock",
    "lock-order",
    "condvar-loop",
    "time-checked",
    "float-total-cmp",
    "no-unwrap",
    "metrics-recorder",
    "spawn-guard",
    "suppression",
];

/// All `.rs` files under the given paths (files are taken as-is,
/// directories walked recursively), sorted for deterministic output.
pub fn rust_files(paths: &[&str]) -> Result<Vec<PathBuf>> {
    let mut files: Vec<PathBuf> = Vec::new();
    for p in paths {
        let path = Path::new(p);
        if path.is_file() {
            files.push(path.to_path_buf());
            continue;
        }
        walk(path, &mut files)
            .with_context(|| format!("walking {p}"))?;
    }
    files.sort();
    Ok(files)
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
        .with_context(|| format!("read_dir {}", dir.display()))?
        .map(|e| e.map(|e| e.path()))
        .collect::<std::io::Result<_>>()?;
    entries.sort();
    for path in entries {
        if path.is_dir() {
            walk(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Run the full analyzer over the given paths.
///
/// Two passes, because `// lock-order: quota-touch` annotations are
/// cross-file (the annotated fn lives in `admission.rs`, the callers
/// it flags in `batcher.rs`/`server.rs`): pass A collects annotations
/// from every file, pass B lints each file against the complete set.
pub fn analyze_paths(paths: &[&str]) -> Result<Report> {
    let files = rust_files(paths)?;
    let mut sources: Vec<(String, String)> = Vec::with_capacity(files.len());
    for f in &files {
        let src = std::fs::read_to_string(f)
            .with_context(|| format!("reading {}", f.display()))?;
        sources.push((f.display().to_string(), src));
    }
    let mut quota_methods: HashSet<String> = HashSet::new();
    for (path, src) in &sources {
        annotations::collect_annotations(path, &lexer::tokenize(src), &mut quota_methods);
    }
    let mut report = Report::default();
    for (path, src) in &sources {
        let (unsup, sup) = lints::lint_file(path, src, &mut quota_methods);
        report.unsuppressed.extend(unsup);
        report.suppressed.extend(sup);
    }
    report.unsuppressed.sort_by_key(|f| f.sort_key());
    report.suppressed.sort_by_key(|f| f.sort_key());
    Ok(report)
}
