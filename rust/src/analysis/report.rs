//! Finding representation and report formatting for `dybit-lint`.

use std::collections::BTreeMap;
use std::fmt;

use super::LINT_IDS;

/// One analyzer finding: a file:line span, a machine-readable lint id,
/// and a human-facing message naming the invariant violated.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Path of the offending file, as given to the analyzer.
    pub path: String,
    /// 1-based line of the offending token.
    pub line: u32,
    /// Machine-readable lint id (one of [`LINT_IDS`]).
    pub lint: &'static str,
    /// Human-facing explanation.
    pub msg: String,
}

impl Finding {
    /// Construct a finding.
    pub fn new(path: &str, line: u32, lint: &'static str, msg: String) -> Self {
        Finding { path: path.to_string(), line, lint, msg }
    }

    /// Sort key matching the CLI's output order.
    pub fn sort_key(&self) -> (String, u32, &'static str, String) {
        (self.path.clone(), self.line, self.lint, self.msg.clone())
    }
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path, self.line, self.lint, self.msg)
    }
}

/// Result of an analyzer run over a file set.
#[derive(Debug, Default)]
pub struct Report {
    /// Findings that gate CI (sorted by path, line, lint, message).
    pub unsuppressed: Vec<Finding>,
    /// Findings silenced by a justified `// lint:allow(..)` (sorted).
    pub suppressed: Vec<Finding>,
}

impl Report {
    /// Per-lint unsuppressed counts, every lint id present (0 when
    /// clean) — the `--analyze`/`--verbose` summary table.
    pub fn counts(&self) -> BTreeMap<&'static str, usize> {
        let mut counts: BTreeMap<&'static str, usize> =
            LINT_IDS.iter().map(|&id| (id, 0)).collect();
        for f in &self.unsuppressed {
            *counts.entry(f.lint).or_insert(0) += 1;
        }
        counts
    }

    /// True when the tree gates clean (no unsuppressed findings).
    pub fn is_clean(&self) -> bool {
        self.unsuppressed.is_empty()
    }

    /// The verbose trailer: totals, per-lint counts, suppressed list.
    pub fn verbose_summary(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "-- {} unsuppressed finding(s), {} suppressed --\n",
            self.unsuppressed.len(),
            self.suppressed.len()
        ));
        for (id, n) in self.counts() {
            out.push_str(&format!("   {id}: {n}\n"));
        }
        for f in &self.suppressed {
            out.push_str(&format!("   suppressed {f}\n"));
        }
        out
    }
}
