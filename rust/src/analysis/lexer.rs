//! Minimal Rust tokenizer for the in-tree static analyzer.
//!
//! The build environment is offline (no `syn`, no clippy internals —
//! DESIGN.md §2), so `dybit-lint` carries its own lexer, the same way
//! `util::proptest` carries its own shrinking harness.  It is a
//! *token*-level view, not a parse tree: enough to distinguish
//! identifiers, string/char literals (so `lock` inside a string never
//! fires a lint), lifetimes vs. char literals, nested block comments,
//! and multi-character operators — and deliberately nothing more.
//! Comments are kept as tokens because the annotation layer
//! ([`crate::analysis::annotations`]) reads `// lock-order:` /
//! `// lint:allow(..)` / `// spawn-guard:` markers out of them.
//!
//! The Python validation mirror (`python/tools/lint_mirror.py`) must
//! tokenize identically; the fixture suite under
//! `rust/tests/fixtures/lint/` certifies both.

/// Token classes produced by [`tokenize`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokenKind {
    /// Identifier or keyword (`fn`, `lock`, `while`, …).
    Ident,
    /// Lifetime (`'a`, `'static`) — distinguished from char literals.
    Lifetime,
    /// Char literal (`'x'`, `'\n'`, `'\x41'`).
    Char,
    /// String literal, including raw (`r#".."#`) and byte (`b".."`).
    Str,
    /// Numeric literal (underscores, suffixes, floats, exponents).
    Num,
    /// Line or block comment (block comments nest, as in Rust).
    Comment,
    /// Operator / punctuation, multi-character ops as one token.
    Punct,
}

/// One lexed token with its 1-based source line.
#[derive(Debug, Clone)]
pub struct Token {
    /// Token class.
    pub kind: TokenKind,
    /// Exact source text of the token.
    pub text: String,
    /// 1-based line of the token's first character.
    pub line: u32,
}

/// Multi-character operators, longest-match-first.
const MULTI_PUNCT: &[&str] = &[
    "<<=", ">>=", "..=", "...", "::", "->", "=>", "==", "!=", "<=", ">=",
    "&&", "||", "+=", "-=", "*=", "/=", "%=", "^=", "&=", "|=", "<<",
    ">>", "..",
];

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Does `s[i..]` start with the literal `pat`?
fn starts_with_at(s: &[char], i: usize, pat: &str) -> bool {
    let p: Vec<char> = pat.chars().collect();
    i + p.len() <= s.len() && s[i..i + p.len()].iter().copied().eq(p)
}

/// First index `>= from` where `pat` occurs in `s`, if any.
fn find_from(s: &[char], from: usize, pat: &[char]) -> Option<usize> {
    if pat.is_empty() || pat.len() > s.len() {
        return None;
    }
    (from..=s.len() - pat.len()).find(|&j| s[j..j + pat.len()] == *pat)
}

fn collect_text(s: &[char], a: usize, b: usize) -> String {
    s[a..b.min(s.len())].iter().collect()
}

/// Tokenize Rust source.  Unterminated literals/comments run to end of
/// input rather than erroring — the analyzer lints real, compiling
/// source, so graceful truncation is the right failure mode.
pub fn tokenize(src: &str) -> Vec<Token> {
    let s: Vec<char> = src.chars().collect();
    let n = s.len();
    let mut toks: Vec<Token> = Vec::new();
    let mut i = 0usize;
    let mut line: u32 = 1;
    let peek = |j: usize| if j < n { s[j] } else { '\0' };

    while i < n {
        let c = s[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == ' ' || c == '\t' || c == '\r' {
            i += 1;
            continue;
        }
        // line comment
        if c == '/' && peek(i + 1) == '/' {
            let j = find_from(&s, i, &['\n']).unwrap_or(n);
            toks.push(Token { kind: TokenKind::Comment, text: collect_text(&s, i, j), line });
            i = j;
            continue;
        }
        // block comment (nesting)
        if c == '/' && peek(i + 1) == '*' {
            let (start, startline) = (i, line);
            let mut depth = 1u32;
            i += 2;
            while i < n && depth > 0 {
                if s[i] == '/' && peek(i + 1) == '*' {
                    depth += 1;
                    i += 2;
                } else if s[i] == '*' && peek(i + 1) == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    if s[i] == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            toks.push(Token {
                kind: TokenKind::Comment,
                text: collect_text(&s, start, i),
                line: startline,
            });
            continue;
        }
        // raw / byte strings: r"", r#""#, b"", br#""#
        if c == 'r' || c == 'b' {
            let mut j = i;
            if s[j] == 'b' {
                j += 1;
            }
            let mut raw_open = None;
            if j < n && s[j] == 'r' {
                let mut h = j + 1;
                while h < n && s[h] == '#' {
                    h += 1;
                }
                if h < n && s[h] == '"' {
                    raw_open = Some((h, h - (j + 1))); // (quote index, #hashes)
                }
            }
            if let Some((q, hashes)) = raw_open {
                let close: Vec<char> =
                    std::iter::once('"').chain(std::iter::repeat('#').take(hashes)).collect();
                let end = match find_from(&s, q + 1, &close) {
                    Some(k) => k + close.len(),
                    None => n,
                };
                let text = collect_text(&s, i, end);
                let newlines = text.matches('\n').count() as u32;
                toks.push(Token { kind: TokenKind::Str, text, line });
                line += newlines;
                i = end;
                continue;
            }
            if c == 'b' && peek(i + 1) == '"' {
                let mut j2 = i + 2;
                while j2 < n && s[j2] != '"' {
                    j2 += if s[j2] == '\\' { 2 } else { 1 };
                }
                let end = (j2 + 1).min(n);
                let text = collect_text(&s, i, end);
                let newlines = text.matches('\n').count() as u32;
                toks.push(Token { kind: TokenKind::Str, text, line });
                line += newlines;
                i = end;
                continue;
            }
            // plain identifier starting with r/b — fall through below
        }
        if c == '"' {
            let mut j = i + 1;
            while j < n && s[j] != '"' {
                j += if s[j] == '\\' { 2 } else { 1 };
            }
            let end = (j + 1).min(n);
            let text = collect_text(&s, i, end);
            let newlines = text.matches('\n').count() as u32;
            toks.push(Token { kind: TokenKind::Str, text, line });
            line += newlines;
            i = end;
            continue;
        }
        // char literal vs lifetime
        if c == '\'' {
            if peek(i + 1) == '\\' {
                let mut j = i + 2;
                if matches!(peek(i + 2), 'x' | 'u' | 'U') {
                    while j < n && s[j] != '\'' {
                        j += 1;
                    }
                } else {
                    j += 1;
                }
                let end = (j + 1).min(n);
                toks.push(Token { kind: TokenKind::Char, text: collect_text(&s, i, end), line });
                i = end;
                continue;
            }
            if (peek(i + 1).is_alphabetic() || peek(i + 1) == '_') && peek(i + 2) != '\'' {
                let mut j = i + 1;
                while j < n && is_ident_continue(s[j]) {
                    j += 1;
                }
                toks.push(Token { kind: TokenKind::Lifetime, text: collect_text(&s, i, j), line });
                i = j;
                continue;
            }
            // 'a' style single-char literal
            let mut j = i + 2;
            if j < n && s[j] == '\'' {
                j += 1;
            }
            toks.push(Token { kind: TokenKind::Char, text: collect_text(&s, i, j), line });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i + 1;
            while j < n && is_ident_continue(s[j]) {
                j += 1;
            }
            // float part: '.' only when followed by a digit (never eat ..)
            if j < n && s[j] == '.' && j + 1 < n && s[j + 1].is_ascii_digit() {
                j += 1;
                while j < n && is_ident_continue(s[j]) {
                    j += 1;
                }
                if j < n && matches!(s[j - 1], 'e' | 'E') && matches!(s[j], '+' | '-') {
                    j += 1;
                    while j < n && is_ident_continue(s[j]) {
                        j += 1;
                    }
                }
            } else if j < n
                && matches!(s[j - 1], 'e' | 'E')
                && matches!(s[j], '+' | '-')
                && !collect_text(&s, i, j).contains("0x")
            {
                j += 1;
                while j < n && is_ident_continue(s[j]) {
                    j += 1;
                }
            }
            toks.push(Token { kind: TokenKind::Num, text: collect_text(&s, i, j), line });
            i = j;
            continue;
        }
        if c.is_alphabetic() || c == '_' {
            let mut j = i + 1;
            while j < n && is_ident_continue(s[j]) {
                j += 1;
            }
            toks.push(Token { kind: TokenKind::Ident, text: collect_text(&s, i, j), line });
            i = j;
            continue;
        }
        let mut matched = false;
        for op in MULTI_PUNCT {
            if starts_with_at(&s, i, op) {
                toks.push(Token { kind: TokenKind::Punct, text: (*op).to_string(), line });
                i += op.chars().count();
                matched = true;
                break;
            }
        }
        if !matched {
            toks.push(Token { kind: TokenKind::Punct, text: c.to_string(), line });
            i += 1;
        }
    }
    toks
}

/// The comment-free view most lints run on.
pub fn code_tokens(toks: &[Token]) -> Vec<Token> {
    toks.iter().filter(|t| t.kind != TokenKind::Comment).cloned().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds_texts(src: &str) -> Vec<(TokenKind, String)> {
        tokenize(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn raw_strings_swallow_quotes_and_hashes() {
        let toks = kinds_texts(r##"let s = r#"he said "lock()""#;"##);
        assert!(toks
            .iter()
            .any(|(k, t)| *k == TokenKind::Str && t.contains("lock()")));
        // the `lock` inside the raw string must NOT surface as an ident
        assert!(!toks.iter().any(|(k, t)| *k == TokenKind::Ident && t == "lock"));
    }

    #[test]
    fn byte_and_raw_byte_strings() {
        let toks = kinds_texts("let a = b\"abc\"; let b2 = br#\"x\"y\"#;");
        let strs: Vec<_> =
            toks.iter().filter(|(k, _)| *k == TokenKind::Str).collect();
        assert_eq!(strs.len(), 2);
        assert_eq!(strs[0].1, "b\"abc\"");
        assert_eq!(strs[1].1, "br#\"x\"y\"#");
    }

    #[test]
    fn idents_starting_with_r_or_b_are_not_strings() {
        let toks = kinds_texts("let rx = board; let b = r + 1;");
        assert!(toks.iter().all(|(k, _)| *k != TokenKind::Str));
        assert!(toks
            .iter()
            .any(|(k, t)| *k == TokenKind::Ident && t == "rx"));
        assert!(toks
            .iter()
            .any(|(k, t)| *k == TokenKind::Ident && t == "board"));
    }

    #[test]
    fn nested_block_comments_stay_one_token() {
        let src = "a /* outer /* inner */ still comment */ b";
        let toks = kinds_texts(src);
        assert_eq!(toks.len(), 3);
        assert_eq!(toks[0].1, "a");
        assert_eq!(toks[1].0, TokenKind::Comment);
        assert!(toks[1].1.contains("inner"));
        assert_eq!(toks[2].1, "b");
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let toks = kinds_texts("fn f<'a>(x: &'a str) { let c = 'a'; let nl = '\\n'; }");
        let lifetimes: Vec<_> =
            toks.iter().filter(|(k, _)| *k == TokenKind::Lifetime).collect();
        let chars: Vec<_> =
            toks.iter().filter(|(k, _)| *k == TokenKind::Char).collect();
        assert_eq!(lifetimes.len(), 2);
        assert!(lifetimes.iter().all(|(_, t)| t == "'a"));
        assert_eq!(chars.len(), 2);
        assert_eq!(chars[0].1, "'a'");
        assert_eq!(chars[1].1, "'\\n'");
    }

    #[test]
    fn numeric_literals_with_underscores_and_exponents() {
        let toks = kinds_texts("1_000 0xFF_u32 1.5e-3 2e6 3..4");
        let nums: Vec<_> = toks
            .iter()
            .filter(|(k, _)| *k == TokenKind::Num)
            .map(|(_, t)| t.as_str())
            .collect();
        assert_eq!(nums, ["1_000", "0xFF_u32", "1.5e-3", "2e6", "3", "4"]);
        // the range operator must survive as one punct token
        assert!(toks
            .iter()
            .any(|(k, t)| *k == TokenKind::Punct && t == ".."));
    }

    #[test]
    fn multi_char_operators_are_single_tokens() {
        let toks = kinds_texts("a <<= b; c ..= d; e :: f -> g => h");
        for op in ["<<=", "..=", "::", "->", "=>"] {
            assert!(
                toks.iter().any(|(k, t)| *k == TokenKind::Punct && t == op),
                "missing operator token {op}"
            );
        }
    }

    #[test]
    fn line_numbers_track_strings_and_comments() {
        let src = "a\n\"two\nline\"\n/* c\nc */ b";
        let toks = tokenize(src);
        assert_eq!(toks[0].line, 1); // a
        assert_eq!(toks[1].line, 2); // the string starts on line 2
        assert_eq!(toks[2].line, 4); // the comment starts on line 4
        assert_eq!(toks[3].line, 5); // b lands after the comment's newline
    }

    #[test]
    fn escaped_quotes_do_not_end_strings() {
        let toks = kinds_texts(r#"let s = "a \" b"; done"#);
        let strs: Vec<_> =
            toks.iter().filter(|(k, _)| *k == TokenKind::Str).collect();
        assert_eq!(strs.len(), 1);
        assert_eq!(strs[0].1, r#""a \" b""#);
        assert!(toks
            .iter()
            .any(|(k, t)| *k == TokenKind::Ident && t == "done"));
    }
}
