//! Annotation and suppression comments the analyzer understands.
//!
//! Three comment grammars (DESIGN.md §14):
//!
//! * `// lock-order: <group> level <n> [alone]` — on the line above a
//!   mutex field declaration; feeds the `lock-order` lint (§11).
//! * `// lock-order: quota-touch` — on the line above an `fn` whose
//!   body touches the tenant-occupancy table; calling it while holding
//!   any annotated guard is flagged (§12).
//! * `// spawn-guard: <justification>` — within three lines above a
//!   `thread::spawn` (or anywhere in its body) to vouch for a detached
//!   thread that is neither `catch_unwind`-guarded nor
//!   DeathWatch-registered.
//! * `// lint:allow(<id>): <justification>` — suppresses one finding
//!   of lint `<id>` on the same line or the next code line.
//!
//! Justifications are mandatory (≥ [`MIN_JUSTIFICATION`] chars) —
//! a suppression without a *why* is itself a finding (`suppression`),
//! which cannot be suppressed.

use std::collections::{HashMap, HashSet};

use super::lexer::{code_tokens, Token, TokenKind};
use super::report::Finding;
use super::LINT_IDS;

/// Minimum justification length for `lint:allow` / `spawn-guard`.
pub const MIN_JUSTIFICATION: usize = 8;

/// A `// lock-order:` field annotation: acquisition group, level
/// within the group (higher may be taken while holding lower), and
/// whether the lock must only ever be held alone.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LockSpec {
    /// Acquisition group name (`intake`, `metrics`, …).
    pub group: String,
    /// Level within the group; acquiring `level <= held level` flags.
    pub level: u32,
    /// `alone` locks may never be held together with any other
    /// annotated lock (the §11 park-lock rule).
    pub alone: bool,
}

/// Everything the annotation pass extracts from one file.
#[derive(Debug, Default)]
pub struct FileAnnotations {
    /// Mutex field name → its lock-order spec.
    pub lock_fields: HashMap<String, LockSpec>,
    /// Lines carrying a well-formed `// spawn-guard:` justification.
    pub spawn_guard_lines: HashSet<u32>,
    /// Line → lint ids suppressed on that line.
    pub allow: HashMap<u32, HashSet<&'static str>>,
    /// Malformed-annotation findings (lint id `suppression`).
    pub findings: Vec<Finding>,
}

fn is_group_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_' || c == '-'
}

/// `// lint:allow(<id>)[: justification]` — returns `(id, just)`;
/// `None` when the comment is not an allow at all.
fn parse_allow(text: &str) -> Option<(String, String)> {
    let rest = text.strip_prefix("//")?.trim_start();
    let rest = rest.strip_prefix("lint:allow(")?;
    let close = rest.find(')')?;
    let id = &rest[..close];
    if id.is_empty() || !id.chars().all(|c| c.is_ascii_lowercase() || c == '-') {
        return None;
    }
    let after = &rest[close + 1..];
    if after.is_empty() {
        return Some((id.to_string(), String::new()));
    }
    let just = after.strip_prefix(':')?;
    Some((id.to_string(), just.trim().to_string()))
}

/// Parsed `// lock-order:` annotation payload.
enum LockOrderAnn {
    /// `quota-touch` — the following fn touches the occupancy table.
    Quota,
    /// `<group> level <n> [alone]` — the following field is a lock.
    Field(LockSpec),
}

fn parse_lock_order(text: &str) -> Option<LockOrderAnn> {
    let rest = text.strip_prefix("//")?.trim_start();
    let rest = rest.strip_prefix("lock-order:")?.trim_start();
    if let Some(after) = rest.strip_prefix("quota-touch") {
        if after.trim().is_empty() {
            return Some(LockOrderAnn::Quota);
        }
        // else: fall through — `quota-touch2 level 1` is a field group
    }
    // `<group> level <n> [alone]`: group is [A-Za-z_][A-Za-z0-9_-]*
    let mut chars = rest.char_indices();
    let (_, first) = chars.next()?;
    if !(first.is_alphabetic() || first == '_') {
        return None;
    }
    let gend = rest
        .char_indices()
        .find(|&(_, c)| !is_group_char(c))
        .map(|(ix, _)| ix)
        .unwrap_or(rest.len());
    let group = &rest[..gend];
    let after_group = &rest[gend..];
    let trimmed = after_group.trim_start();
    if trimmed.len() == after_group.len() {
        return None; // need >= 1 whitespace before `level`
    }
    let after_level = trimmed.strip_prefix("level")?;
    let digits_part = after_level.trim_start();
    if digits_part.len() == after_level.len() {
        return None; // need >= 1 whitespace before the number
    }
    let dend = digits_part
        .char_indices()
        .find(|&(_, c)| !c.is_ascii_digit())
        .map(|(ix, _)| ix)
        .unwrap_or(digits_part.len());
    if dend == 0 {
        return None;
    }
    let level: u32 = digits_part[..dend].parse().ok()?;
    let tail = &digits_part[dend..];
    let alone = if tail.trim().is_empty() {
        false
    } else {
        let stripped = tail.trim_start();
        if stripped.len() == tail.len() || stripped.trim_end() != "alone" {
            return None;
        }
        true
    };
    Some(LockOrderAnn::Field(LockSpec { group: group.to_string(), level, alone }))
}

/// `// spawn-guard: <justification>` — returns the justification.
fn parse_spawn_guard(text: &str) -> Option<String> {
    let rest = text.strip_prefix("//")?.trim_start();
    let rest = rest.strip_prefix("spawn-guard:")?;
    Some(rest.trim().to_string())
}

/// Code tokens on the first line with code strictly after `after_line`.
pub fn next_code_line_tokens<'a>(ct: &'a [Token], after_line: u32) -> Vec<&'a Token> {
    for (idx, t) in ct.iter().enumerate() {
        if t.line > after_line {
            let ln = t.line;
            return ct[idx..].iter().take_while(|u| u.line == ln).collect();
        }
    }
    Vec::new()
}

fn known_lint_id(id: &str) -> Option<&'static str> {
    LINT_IDS.iter().find(|&&k| k == id).copied()
}

/// Parse every annotation comment in `toks`.  Well-formed
/// `quota-touch` fn names are added to the cross-file `quota_methods`
/// set; malformed annotations become `suppression` findings.
pub fn collect_annotations(
    path: &str,
    toks: &[Token],
    quota_methods: &mut HashSet<String>,
) -> FileAnnotations {
    let mut ann = FileAnnotations::default();
    let ct = code_tokens(toks);
    for t in toks {
        if t.kind != TokenKind::Comment || !t.text.starts_with("//") {
            continue;
        }
        let text = t.text.trim();
        if let Some((id, just)) = parse_allow(text) {
            let Some(id) = known_lint_id(&id) else {
                ann.findings.push(Finding::new(
                    path,
                    t.line,
                    "suppression",
                    format!("lint:allow names unknown lint '{id}'"),
                ));
                continue;
            };
            if just.chars().count() < MIN_JUSTIFICATION {
                ann.findings.push(Finding::new(
                    path,
                    t.line,
                    "suppression",
                    format!(
                        "lint:allow({id}) needs a justification \
                         (>= {MIN_JUSTIFICATION} chars after a colon)"
                    ),
                ));
                continue;
            }
            ann.allow.entry(t.line).or_default().insert(id);
            let nxt = next_code_line_tokens(&ct, t.line);
            if let Some(first) = nxt.first() {
                ann.allow.entry(first.line).or_default().insert(id);
            }
            continue;
        }
        if let Some(parsed) = parse_lock_order(text) {
            let nxt = next_code_line_tokens(&ct, t.line);
            match parsed {
                LockOrderAnn::Quota => {
                    let mut name = None;
                    for (k, u) in nxt.iter().enumerate() {
                        if u.kind == TokenKind::Ident && u.text == "fn" && k + 1 < nxt.len() {
                            name = Some(nxt[k + 1].text.clone());
                            break;
                        }
                    }
                    match name {
                        Some(name) => {
                            quota_methods.insert(name);
                        }
                        None => ann.findings.push(Finding::new(
                            path,
                            t.line,
                            "suppression",
                            "lock-order: quota-touch must precede an fn".to_string(),
                        )),
                    }
                }
                LockOrderAnn::Field(spec) => {
                    let field = nxt
                        .first()
                        .filter(|u| u.kind == TokenKind::Ident)
                        .map(|u| u.text.clone());
                    match field {
                        None => ann.findings.push(Finding::new(
                            path,
                            t.line,
                            "suppression",
                            "lock-order annotation must precede a field".to_string(),
                        )),
                        Some(field) => {
                            if let Some(prev) = ann.lock_fields.get(&field) {
                                if *prev != spec {
                                    ann.findings.push(Finding::new(
                                        path,
                                        t.line,
                                        "suppression",
                                        format!(
                                            "conflicting lock-order annotations \
                                             for field '{field}'"
                                        ),
                                    ));
                                }
                            }
                            ann.lock_fields.insert(field, spec);
                        }
                    }
                }
            }
            continue;
        } else if text.starts_with("// lock-order:") || text.starts_with("//lock-order:") {
            ann.findings.push(Finding::new(
                path,
                t.line,
                "suppression",
                "malformed lock-order annotation (want '<group> level <n> \
                 [alone]' or 'quota-touch')"
                    .to_string(),
            ));
            continue;
        }
        if let Some(just) = parse_spawn_guard(text) {
            if just.chars().count() < MIN_JUSTIFICATION {
                ann.findings.push(Finding::new(
                    path,
                    t.line,
                    "suppression",
                    format!("spawn-guard needs a justification (>= {MIN_JUSTIFICATION} chars)"),
                ));
            } else {
                ann.spawn_guard_lines.insert(t.line);
            }
        }
    }
    ann
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::tokenize;

    fn collect(src: &str) -> (FileAnnotations, HashSet<String>) {
        let mut quota = HashSet::new();
        let ann = collect_annotations("t.rs", &tokenize(src), &mut quota);
        (ann, quota)
    }

    #[test]
    fn lock_order_field_annotation_parses() {
        let (ann, _) = collect("struct S {\n// lock-order: intake level 2 alone\nboard: Mutex<u32>,\n}");
        let spec = ann.lock_fields.get("board").expect("field recorded");
        assert_eq!(spec.group, "intake");
        assert_eq!(spec.level, 2);
        assert!(spec.alone);
        assert!(ann.findings.is_empty());
    }

    #[test]
    fn quota_touch_collects_fn_name() {
        let (ann, quota) = collect("// lock-order: quota-touch\npub fn try_charge(&self) {}\n");
        assert!(quota.contains("try_charge"));
        assert!(ann.findings.is_empty());
    }

    #[test]
    fn malformed_lock_order_is_a_finding() {
        let (ann, _) = collect("// lock-order: intake levle 1\nx: Mutex<u32>,\n");
        assert_eq!(ann.findings.len(), 1);
        assert_eq!(ann.findings[0].lint, "suppression");
    }

    #[test]
    fn allow_requires_justification() {
        let (ann, _) = collect("// lint:allow(no-unwrap)\nfoo();\n");
        assert_eq!(ann.findings.len(), 1);
        let (ann, _) = collect("// lint:allow(no-unwrap): short\nfoo();\n");
        assert_eq!(ann.findings.len(), 1);
        let (ann, _) = collect("// lint:allow(no-unwrap): a real justification\nfoo();\n");
        assert!(ann.findings.is_empty());
        // suppression applies to the comment line AND the next code line
        assert!(ann.allow.get(&1).is_some_and(|s| s.contains("no-unwrap")));
        assert!(ann.allow.get(&2).is_some_and(|s| s.contains("no-unwrap")));
    }

    #[test]
    fn allow_unknown_lint_is_a_finding() {
        let (ann, _) = collect("// lint:allow(made-up): some justification\nfoo();\n");
        assert_eq!(ann.findings.len(), 1);
        assert!(ann.findings[0].msg.contains("unknown lint"));
    }

    #[test]
    fn spawn_guard_needs_a_why() {
        let (ann, _) = collect("// spawn-guard: ok\nthread::spawn(|| {});\n");
        assert_eq!(ann.findings.len(), 1);
        let (ann, _) = collect("// spawn-guard: joined on shutdown\nthread::spawn(|| {});\n");
        assert!(ann.findings.is_empty());
        assert!(ann.spawn_guard_lines.contains(&1));
    }
}
