//! Concrete search engine: Algorithm 1 wired to the cycle-accurate
//! simulator (latency) and the quantizer (RMSE on real weight tensors +
//! activation calibration taps) — the full Fig. 4 loop minus QAT, which
//! the qat module applies to the found assignment afterwards.

use std::collections::HashMap;

use crate::formats::{quantizer, Format};
use crate::sim::{Prec, Simulator};

use super::strategy::{search, Metrics, SearchResult, Strategy};

/// Metrics backed by real tensors + the simulator; memoizes both.
pub struct EngineMetrics<'a> {
    sim: &'a mut Simulator,
    /// Per-layer weight subsample (strided ≤2048 of the params tensor).
    weights: Vec<Vec<f32>>,
    /// Per-layer activation subsample (fwd_acts taps, calibration batch).
    acts: Vec<Vec<f32>>,
    fmt: Format,
    rmse_cache: HashMap<(usize, u32, u32), f64>,
    /// Reused projection buffer for `quant_rmse_into` (no per-query
    /// allocation on the search hot path).
    scratch: Vec<f32>,
}

/// Strided ≤2048-element subsample used for the ranking RMSE (§Perf).
fn subsample(x: &[f32]) -> Vec<f32> {
    const N: usize = 2048;
    if x.len() <= N {
        return x.to_vec();
    }
    let stride = x.len() / N;
    x.iter().step_by(stride).take(N).copied().collect()
}

impl<'a> EngineMetrics<'a> {
    pub fn new(sim: &'a mut Simulator, weights: &'a [Vec<f32>],
               acts: &'a [Vec<f32>], fmt: Format) -> Self {
        assert_eq!(sim.layers.len(), weights.len());
        assert_eq!(weights.len(), acts.len());
        EngineMetrics {
            sim,
            weights: weights.iter().map(|w| subsample(w)).collect(),
            acts: acts.iter().map(|a| subsample(a)).collect(),
            fmt,
            rmse_cache: HashMap::new(),
            scratch: Vec::new(),
        }
    }
}

impl Metrics for EngineMetrics<'_> {
    fn n_layers(&self) -> usize {
        self.weights.len()
    }

    fn latency(&mut self, i: usize, pw: Prec, pa: Prec) -> f64 {
        self.sim.layer_cycles(i, pw, pa).total as f64
    }

    /// RMSE_i(a, w): σ-normalized RMSE of the layer's weight tensor at pw
    /// plus its activation tensor at pa (both per-tensor-scale calibrated).
    ///
    /// §Perf: the ranking metric is computed on a strided ≤2048-element
    /// subsample — Eqn. 2 is a mean, so a 2k sample estimates it within
    /// ~2% (σ/√n), while the full-tensor calibrate ladder dominated the
    /// search wall time.  Scoring runs through the quantizer's single
    /// batched calibrate-project-score pipeline (`quant_rmse_into`) with
    /// a reused scratch buffer (see EXPERIMENTS.md §Perf, before/after).
    fn rmse(&mut self, i: usize, pw: Prec, pa: Prec) -> f64 {
        let key = (i, pw.bits(), pa.bits());
        if let Some(&e) = self.rmse_cache.get(&key) {
            return e;
        }
        let ew = quantizer::quant_rmse_into(&self.weights[i], self.fmt, pw.bits(),
                                            &mut self.scratch);
        let ea = quantizer::quant_rmse_into(&self.acts[i], self.fmt, pa.bits(),
                                            &mut self.scratch);
        let e = ew + ea;
        self.rmse_cache.insert(key, e);
        e
    }
}

/// One-call wrapper: run Algorithm 1 over real data.
pub fn run_search(sim: &mut Simulator, weights: &[Vec<f32>],
                  acts: &[Vec<f32>], fmt: Format, strategy: Strategy,
                  top_k: usize) -> SearchResult {
    let mut metrics = EngineMetrics::new(sim, weights, acts, fmt);
    search(&mut metrics, strategy, top_k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{HwConfig, LayerShape};
    use crate::util::rng::Rng;

    fn setup() -> (Simulator, Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let layers = vec![
            LayerShape::gemm("big", 1024, 512, 256),
            LayerShape::gemm("mid", 256, 256, 128),
            LayerShape::gemm("small", 16, 64, 10),
        ];
        let sim = Simulator::new(HwConfig::zcu102(), layers, 1);
        let mut rng = Rng::new(3);
        let weights: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec(2000)).collect();
        let acts: Vec<Vec<f32>> = (0..3)
            .map(|_| rng.normal_vec(2048).iter().map(|x| x.abs()).collect())
            .collect();
        (sim, weights, acts)
    }

    #[test]
    fn speedup_search_on_real_metrics() {
        let (mut sim, w, a) = setup();
        let r = run_search(&mut sim, &w, &a, Format::DyBit,
                           Strategy::SpeedupConstrained { alpha: 2.0 }, 2);
        assert!(r.satisfied, "{r:?}");
        assert!(r.speedup >= 2.0);
        // speedup must be confirmed by the simulator itself
        let s = sim.speedup(&r.assignment);
        assert!((s - r.speedup).abs() / s < 1e-9);
    }

    #[test]
    fn rmse_search_keeps_budget() {
        let (mut sim, w, a) = setup();
        let r = run_search(&mut sim, &w, &a, Format::DyBit,
                           Strategy::RmseConstrained { beta: 4.0 }, 2);
        assert!(r.rmse_ratio <= 4.0 + 1e-9);
        assert!(r.speedup > 1.0); // some degrade always fits a 4x budget
    }

    #[test]
    fn batched_rmse_matches_per_element_reference_chain() {
        // true oracle: the per-element baseline ladder + projection, NOT
        // quant_rmse (which itself runs on the batched path)
        let mut rng = Rng::new(17);
        let x = rng.normal_vec(1024);
        let mut scratch = Vec::new();
        for fmt in [Format::DyBit, Format::Int, Format::Flint] {
            for bits in [4u32, 8] {
                let got = quantizer::quant_rmse_into(&x, fmt, bits, &mut scratch);
                let grid = fmt.grid(bits);
                let s = quantizer::calibrate_scale(&x, &grid);
                let mut buf = vec![0.0f32; x.len()];
                quantizer::quantize_to_grid(&x, &grid, s, &mut buf);
                let want = quantizer::rmse(&x, &buf);
                assert_eq!(got, want, "{fmt:?} bits={bits}");
            }
        }
    }

    #[test]
    fn rmse_memoization_hits() {
        let (mut sim, w, a) = setup();
        let mut m = EngineMetrics::new(&mut sim, &w, &a, Format::DyBit);
        let e1 = m.rmse(0, Prec::B4, Prec::B4);
        let e2 = m.rmse(0, Prec::B4, Prec::B4);
        assert_eq!(e1, e2);
        assert_eq!(m.rmse_cache.len(), 1);
    }
}
