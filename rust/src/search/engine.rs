//! Concrete search engine: Algorithm 1 wired to the cycle-accurate
//! simulator (latency) and the quantizer (RMSE on real weight tensors +
//! activation calibration taps) — the full Fig. 4 loop minus QAT, which
//! the qat module applies to the found assignment afterwards.
//!
//! §Perf (DESIGN.md §7): [`run_search`] materializes the dense
//! [`CostTable`] first — [`build_cost_table`] fills one row per layer in
//! parallel on the thread pool — then runs the table-driven
//! [`search_table`].  The oracle-driven [`EngineMetrics`] is kept as the
//! backing of [`super::strategy::reference`] (equivalence tests + the
//! "old" side of `benches/perf_search.rs`).

use std::collections::HashMap;

use crate::formats::{quantizer, CalibView, Format};
use crate::sim::{cell_row, LayerShape, Prec, Simulator};
use crate::util::threadpool::parallel_map;

use super::costs::{self, CostTable};
use super::strategy::{search_table, Metrics, SearchResult, Strategy};

/// Metrics backed by real tensors + the simulator; memoizes both.
pub struct EngineMetrics<'a> {
    sim: &'a mut Simulator,
    /// Per-layer weight subsample (strided ≤2048 of the params tensor).
    weights: Vec<Vec<f32>>,
    /// Per-layer activation subsample (fwd_acts taps, calibration batch).
    acts: Vec<Vec<f32>>,
    fmt: Format,
    rmse_cache: HashMap<(usize, u32, u32), f64>,
    /// Reused projection buffer for `quant_rmse_into`.  (Since §8 the
    /// dominant per-query cost of an uncached rmse() is the throwaway
    /// `CalibView` each `quant_rmse_into` builds — this oracle path is
    /// kept simple because it is the *reference* side; the production
    /// fill, `build_cost_table`, shares one view per tensor.)
    scratch: Vec<f32>,
}

/// Strided ≤2048-element subsample used for the ranking RMSE (§Perf).
fn subsample(x: &[f32]) -> Vec<f32> {
    const N: usize = 2048;
    if x.len() <= N {
        return x.to_vec();
    }
    let stride = x.len() / N;
    x.iter().step_by(stride).take(N).copied().collect()
}

impl<'a> EngineMetrics<'a> {
    pub fn new(sim: &'a mut Simulator, weights: &'a [Vec<f32>],
               acts: &'a [Vec<f32>], fmt: Format) -> Self {
        assert_eq!(sim.layers.len(), weights.len());
        assert_eq!(weights.len(), acts.len());
        EngineMetrics {
            sim,
            weights: weights.iter().map(|w| subsample(w)).collect(),
            acts: acts.iter().map(|a| subsample(a)).collect(),
            fmt,
            rmse_cache: HashMap::new(),
            scratch: Vec::new(),
        }
    }
}

impl Metrics for EngineMetrics<'_> {
    fn n_layers(&self) -> usize {
        self.weights.len()
    }

    fn latency(&mut self, i: usize, pw: Prec, pa: Prec) -> f64 {
        self.sim.layer_cycles(i, pw, pa).total as f64
    }

    /// RMSE_i(a, w): σ-normalized RMSE of the layer's weight tensor at pw
    /// plus its activation tensor at pa (both per-tensor-scale calibrated).
    ///
    /// §Perf: the ranking metric is computed on a strided ≤2048-element
    /// subsample — Eqn. 2 is a mean, so a 2k sample estimates it within
    /// ~2% (σ/√n), while the full-tensor calibrate ladder dominated the
    /// search wall time.  Scoring runs through the quantizer's single
    /// calibrate-project-score pipeline (`quant_rmse_into`, §8
    /// CalibView ladder inside) with a reused scratch buffer (see
    /// EXPERIMENTS.md §Perf, before/after).
    fn rmse(&mut self, i: usize, pw: Prec, pa: Prec) -> f64 {
        let key = (i, pw.bits(), pa.bits());
        if let Some(&e) = self.rmse_cache.get(&key) {
            return e;
        }
        let ew = quantizer::quant_rmse_into(&self.weights[i], self.fmt, pw.bits(),
                                            &mut self.scratch);
        let ea = quantizer::quant_rmse_into(&self.acts[i], self.fmt, pa.bits(),
                                            &mut self.scratch);
        let e = ew + ea;
        self.rmse_cache.insert(key, e);
        e
    }
}

/// Fill the dense cost table, one parallel job per layer (DESIGN.md §7).
///
/// Latency cells run through the pure [`cell_row`] — bypassing the
/// simulator's per-call memoization HashMap entirely — and RMSE cells
/// are assembled from the 2·|Prec| per-tensor halves (`ew(pw) + ea(pa)`
/// via [`quantizer::quant_rmse_view`]): 6 calibration-ladder runs per
/// layer instead of up to 2 per *touched* (pw, pa) combo on the oracle
/// path, and since §8 each layer builds ONE [`CalibView`] per tensor
/// (inside its parallel fill job) and shares the sorted prefix sums
/// across its |Prec| ladder runs, so the per-layer calibration cost is
/// one sort + 2·|Prec| table-sized ladders instead of 6 full-tensor
/// ladder sweeps.  Every cell is bit-identical to what
/// [`EngineMetrics`] returns for the same query (its
/// `quant_rmse_into` builds an identical throwaway view), so the
/// table-driven search matches the oracle-driven reference decision
/// for decision.
///
/// A fill job that panics surfaces as an `Err` (see
/// [`parallel_map`], which routes through the borrowed-pool
/// `parallel_map_on`) instead of a follow-on panic; [`run_search`]
/// converts that `Err` back into a panic with context, so callers who
/// want to recover should call this function directly.
pub fn build_cost_table(sim: &Simulator, weights: &[Vec<f32>], acts: &[Vec<f32>],
                        fmt: Format) -> anyhow::Result<CostTable> {
    assert_eq!(sim.layers.len(), weights.len());
    assert_eq!(weights.len(), acts.len());
    let n = weights.len();
    let cfg = sim.cfg.clone();
    let batch = sim.batch;
    let jobs: Vec<(LayerShape, Vec<f32>, Vec<f32>)> = sim
        .layers
        .iter()
        .zip(weights)
        .zip(acts)
        .map(|((l, w), a)| (l.clone(), subsample(w), subsample(a)))
        .collect();
    let threads = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1)
        .min(n.max(1));
    let rows = parallel_map(jobs, threads, move |(layer, w, a)| {
        let mut scratch = Vec::new();
        // §8: one CalibView per tensor, shared across the per-precision
        // ladder runs (view construction itself rides the per-layer
        // parallel_map jobs)
        let vw = CalibView::new(&w);
        let va = CalibView::new(&a);
        let ew: Vec<f64> = Prec::ALL
            .iter()
            .map(|p| quantizer::quant_rmse_view(&w, &vw, fmt, p.bits(), &mut scratch))
            .collect();
        let ea: Vec<f64> = Prec::ALL
            .iter()
            .map(|p| quantizer::quant_rmse_view(&a, &va, fmt, p.bits(), &mut scratch))
            .collect();
        // cell_row is the single source of truth for the cell order;
        // k decomposes as (wi, ai) in the same Prec::ALL × Prec::ALL walk
        let cells = cell_row(&cfg, &layer, batch);
        let lat: Vec<f64> = cells.iter().map(|c| c.total as f64).collect();
        let rmse: Vec<f64> = (0..cells.len())
            .map(|k| ew[k / costs::N_PREC] + ea[k % costs::N_PREC])
            .collect();
        (lat, rmse)
    })?;
    let mut lat = Vec::with_capacity(n * costs::MODES);
    let mut rmse = Vec::with_capacity(n * costs::MODES);
    for (l, r) in rows {
        lat.extend(l);
        rmse.extend(r);
    }
    Ok(CostTable::from_parts(lat, rmse))
}

/// One-call wrapper: run Algorithm 1 over real data — parallel cost-table
/// fill + incremental table-driven search (DESIGN.md §7).
///
/// Panics (with the failed job's context) if a fill job panicked; use
/// [`build_cost_table`] + [`search_table`] directly to handle that as
/// an `Err` instead.
pub fn run_search(sim: &Simulator, weights: &[Vec<f32>],
                  acts: &[Vec<f32>], fmt: Format, strategy: Strategy,
                  top_k: usize) -> SearchResult {
    let table = build_cost_table(sim, weights, acts, fmt)
        .expect("cost-table fill failed");
    search_table(&table, strategy, top_k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::strategy::reference;
    use crate::sim::{HwConfig, LayerShape};
    use crate::util::rng::Rng;

    fn setup() -> (Simulator, Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let layers = vec![
            LayerShape::gemm("big", 1024, 512, 256),
            LayerShape::gemm("mid", 256, 256, 128),
            LayerShape::gemm("small", 16, 64, 10),
        ];
        let sim = Simulator::new(HwConfig::zcu102(), layers, 1);
        let mut rng = Rng::new(3);
        let weights: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec(2000)).collect();
        let acts: Vec<Vec<f32>> = (0..3)
            .map(|_| rng.normal_vec(2048).iter().map(|x| x.abs()).collect())
            .collect();
        (sim, weights, acts)
    }

    #[test]
    fn speedup_search_on_real_metrics() {
        let (mut sim, w, a) = setup();
        let r = run_search(&sim, &w, &a, Format::DyBit,
                           Strategy::SpeedupConstrained { alpha: 2.0 }, 2);
        assert!(r.satisfied, "{r:?}");
        assert!(r.speedup >= 2.0);
        // speedup must be confirmed by the simulator itself
        let s = sim.speedup(&r.assignment);
        assert!((s - r.speedup).abs() / s < 1e-9);
    }

    #[test]
    fn rmse_search_keeps_budget() {
        let (sim, w, a) = setup();
        let r = run_search(&sim, &w, &a, Format::DyBit,
                           Strategy::RmseConstrained { beta: 4.0 }, 2);
        assert!(r.rmse_ratio <= 4.0 + 1e-9);
        assert!(r.speedup > 1.0); // some degrade always fits a 4x budget
    }

    #[test]
    fn batched_rmse_matches_per_element_reference_chain() {
        // true oracle: the per-element baseline ladder + projection, NOT
        // quant_rmse (which itself runs on the batched path)
        let mut rng = Rng::new(17);
        let x = rng.normal_vec(1024);
        let mut scratch = Vec::new();
        for fmt in [Format::DyBit, Format::Int, Format::Flint] {
            for bits in [4u32, 8] {
                let got = quantizer::quant_rmse_into(&x, fmt, bits, &mut scratch);
                let grid = fmt.grid(bits);
                let s = quantizer::calibrate_scale(&x, &grid);
                let mut buf = vec![0.0f32; x.len()];
                quantizer::quantize_to_grid(&x, &grid, s, &mut buf);
                let want = quantizer::rmse(&x, &buf);
                assert_eq!(got, want, "{fmt:?} bits={bits}");
            }
        }
    }

    #[test]
    fn rmse_memoization_hits() {
        let (mut sim, w, a) = setup();
        let mut m = EngineMetrics::new(&mut sim, &w, &a, Format::DyBit);
        let e1 = m.rmse(0, Prec::B4, Prec::B4);
        let e2 = m.rmse(0, Prec::B4, Prec::B4);
        assert_eq!(e1, e2);
        assert_eq!(m.rmse_cache.len(), 1);
    }

    #[test]
    fn cost_table_cells_are_bit_identical_to_engine_metrics() {
        let (mut sim, w, a) = setup();
        let table = build_cost_table(&sim, &w, &a, Format::DyBit).unwrap();
        let mut m = EngineMetrics::new(&mut sim, &w, &a, Format::DyBit);
        assert_eq!(table.n_layers(), 3);
        for i in 0..3 {
            for pw in Prec::ALL {
                for pa in Prec::ALL {
                    assert_eq!(table.lat(i, pw, pa), m.latency(i, pw, pa),
                               "lat {i} {pw:?} {pa:?}");
                    assert_eq!(table.rmse(i, pw, pa), m.rmse(i, pw, pa),
                               "rmse {i} {pw:?} {pa:?}");
                }
            }
        }
    }

    #[test]
    fn prop_table_search_matches_reference_on_real_metrics() {
        use crate::util::proptest::check;
        check(
            "engine-search-equivalence",
            12,
            |r, _| {
                let strategy = if r.below(2) == 0 {
                    Strategy::SpeedupConstrained { alpha: 1.0 + 7.0 * r.uniform() }
                } else {
                    Strategy::RmseConstrained { beta: 1.0 + 15.0 * r.uniform() }
                };
                (strategy, 1 + r.below(3))
            },
            |&(strategy, top_k)| {
                let (sim, w, a) = setup();
                let r_new = run_search(&sim, &w, &a, Format::DyBit, strategy, top_k);
                let (mut sim2, w2, a2) = setup();
                let mut m = EngineMetrics::new(&mut sim2, &w2, &a2, Format::DyBit);
                let r_old = reference::search(&mut m, strategy, top_k);
                r_new.assignment == r_old.assignment
                    && r_new.iterations == r_old.iterations
                    && r_new.satisfied == r_old.satisfied
            },
        );
    }
}
