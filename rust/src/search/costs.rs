//! Dense precomputed cost table for the Algorithm-1 search (DESIGN.md §7).
//!
//! One latency and one RMSE cell per (layer, pw, pa) mode, layer-major,
//! cell order [`Prec::ALL`] × [`Prec::ALL`].  The table is materialized
//! exactly once per search — serially here through a [`Metrics`] oracle,
//! or in parallel by [`build_cost_table`](super::engine::build_cost_table)
//! — and [`search_table`](super::strategy::search_table) then runs on
//! O(1) array reads instead of oracle calls: no per-query HashMap hash,
//! no trait dispatch inside sort comparators, no full-model re-walk per
//! degrade step.

use crate::sim::Prec;

use super::strategy::Metrics;

/// Number of supported precisions (8/4/2; Sec. III-C3).
pub const N_PREC: usize = Prec::ALL.len();

/// Number of (pw, pa) modes per layer.
pub const MODES: usize = N_PREC * N_PREC;

/// Index of `p` within [`Prec::ALL`] (8 → 0, 4 → 1, 2 → 2).
#[inline]
fn pidx(p: Prec) -> usize {
    match p {
        Prec::B8 => 0,
        Prec::B4 => 1,
        Prec::B2 => 2,
    }
}

// Compile-time tie between `pidx` and the `Prec::ALL` iteration order the
// fills walk (`CostTable::from_metrics`, `sim::cell_row`): reordering ALL
// without updating `pidx` fails the build instead of silently decoding
// the wrong cells.
const _: () = {
    assert!(matches!(Prec::ALL[0], Prec::B8));
    assert!(matches!(Prec::ALL[1], Prec::B4));
    assert!(matches!(Prec::ALL[2], Prec::B2));
};

/// Dense `[layer][pw][pa]` latency + RMSE cost surface (DESIGN.md §7).
pub struct CostTable {
    n: usize,
    /// Latency cells (simulator cycle totals — integer-valued f64s).
    lat: Vec<f64>,
    /// RMSE cells (Eqn. 2, weight half at pw + activation half at pa).
    rmse: Vec<f64>,
}

impl CostTable {
    /// Assemble from dense arrays (layer-major, [`Prec::ALL`]² cell
    /// order — the order [`Simulator::fill_cell_table`] and the parallel
    /// fill emit).
    ///
    /// [`Simulator::fill_cell_table`]: crate::sim::Simulator::fill_cell_table
    pub fn from_parts(lat: Vec<f64>, rmse: Vec<f64>) -> CostTable {
        assert_eq!(lat.len(), rmse.len());
        assert_eq!(lat.len() % MODES, 0, "dense table must be n × {MODES}");
        CostTable { n: lat.len() / MODES, lat, rmse }
    }

    /// Serial fill through a [`Metrics`] oracle: exactly [`MODES`]·n
    /// oracle queries up front, after which the search never invokes the
    /// oracle again (DESIGN.md §7).
    pub fn from_metrics<M: Metrics>(m: &mut M) -> CostTable {
        let n = m.n_layers();
        let mut lat = Vec::with_capacity(n * MODES);
        let mut rmse = Vec::with_capacity(n * MODES);
        for i in 0..n {
            for pw in Prec::ALL {
                for pa in Prec::ALL {
                    lat.push(m.latency(i, pw, pa));
                    rmse.push(m.rmse(i, pw, pa));
                }
            }
        }
        CostTable { n, lat, rmse }
    }

    pub fn n_layers(&self) -> usize {
        self.n
    }

    #[inline]
    fn cell(&self, i: usize, pw: Prec, pa: Prec) -> usize {
        debug_assert!(i < self.n);
        (i * N_PREC + pidx(pw)) * N_PREC + pidx(pa)
    }

    /// Latency (cycles) of layer `i` at (pw, pa).
    #[inline]
    pub fn lat(&self, i: usize, pw: Prec, pa: Prec) -> f64 {
        self.lat[self.cell(i, pw, pa)]
    }

    /// RMSE_i(a, w): combined quantization error of layer `i` at (pw, pa).
    #[inline]
    pub fn rmse(&self, i: usize, pw: Prec, pa: Prec) -> f64 {
        self.rmse[self.cell(i, pw, pa)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Oracle whose cells encode their own coordinates, so reads can be
    /// checked against the query that produced them.
    struct Coord {
        n: usize,
        calls: usize,
    }

    impl Metrics for Coord {
        fn n_layers(&self) -> usize {
            self.n
        }
        fn latency(&mut self, i: usize, pw: Prec, pa: Prec) -> f64 {
            self.calls += 1;
            (i * 10_000 + pw.bits() as usize * 100 + pa.bits() as usize) as f64
        }
        fn rmse(&mut self, i: usize, pw: Prec, pa: Prec) -> f64 {
            self.calls += 1;
            (i * 10_000 + pw.bits() as usize * 100 + pa.bits() as usize) as f64 / 7.0
        }
    }

    #[test]
    fn fill_reads_back_every_cell() {
        let mut m = Coord { n: 4, calls: 0 };
        let t = CostTable::from_metrics(&mut m);
        assert_eq!(t.n_layers(), 4);
        for i in 0..4 {
            for pw in Prec::ALL {
                for pa in Prec::ALL {
                    let want = (i * 10_000 + pw.bits() as usize * 100 + pa.bits() as usize) as f64;
                    assert_eq!(t.lat(i, pw, pa), want);
                    assert_eq!(t.rmse(i, pw, pa), want / 7.0);
                }
            }
        }
    }

    #[test]
    fn fill_costs_exactly_modes_by_n_oracle_queries() {
        let mut m = Coord { n: 6, calls: 0 };
        let _t = CostTable::from_metrics(&mut m);
        // one latency + one rmse query per cell, nothing else
        assert_eq!(m.calls, 2 * MODES * 6);
    }

    #[test]
    fn from_parts_roundtrip() {
        let lat: Vec<f64> = (0..2 * MODES).map(|x| x as f64).collect();
        let rmse: Vec<f64> = (0..2 * MODES).map(|x| x as f64 * 0.5).collect();
        let t = CostTable::from_parts(lat, rmse);
        assert_eq!(t.n_layers(), 2);
        assert_eq!(t.lat(0, Prec::B8, Prec::B8), 0.0);
        assert_eq!(t.lat(1, Prec::B2, Prec::B2), (2 * MODES - 1) as f64);
        assert_eq!(t.rmse(1, Prec::B2, Prec::B2), (2 * MODES - 1) as f64 * 0.5);
    }

    #[test]
    #[should_panic(expected = "dense table")]
    fn from_parts_rejects_ragged_input() {
        let _ = CostTable::from_parts(vec![0.0; MODES + 1], vec![0.0; MODES + 1]);
    }
}
