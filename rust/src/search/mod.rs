//! Hardware-aware mixed-precision quantization framework (paper Fig. 4):
//! Algorithm 1 over the cycle-accurate simulator + Eqn. 2 RMSE metrics.

pub mod engine;
pub mod strategy;

pub use engine::{run_search, EngineMetrics};
pub use strategy::{search, Metrics, SearchResult, Strategy};
