//! Hardware-aware mixed-precision quantization framework (paper Fig. 4):
//! Algorithm 1 over a dense precomputed cost table (DESIGN.md §7) filled
//! from the cycle-accurate simulator + Eqn. 2 RMSE metrics.

pub mod costs;
pub mod engine;
pub mod strategy;

pub use costs::CostTable;
pub use engine::{build_cost_table, run_search, EngineMetrics};
pub use strategy::{reference, search, search_table, Metrics, SearchResult, Strategy};
