//! Algorithm 1: heuristic layer-wise bitwidth search, both strategies.
//!
//! Faithful to the paper's pseudocode:
//!
//! * start from all-(8,8);
//! * each iteration ranks layers by the strategy's primary metric over the
//!   top-k candidates (speedup mode: largest latency first — "quantize the
//!   slowest layer first"; RMSE mode: smallest quantization error first),
//!   re-ranks by the secondary metric, then `DEGRADE_LEVEL`s weights and
//!   activations of the candidates one step (8→4→2), re-checking the
//!   constraint ratio after every single degrade;
//! * speedup-constrained (Eqn. 3): stop once `base_lat / lat >= alpha`,
//!   minimizing ΣRMSE along the way;
//! * RMSE-constrained (Eqn. 4): keep minimizing latency while
//!   `Σrmse <= beta × Σrmse(8,8)`; a degrade that would break the budget
//!   is rolled back and the layer is frozen.
//!
//! §Perf (DESIGN.md §7): the search is table-driven.  [`search`] first
//! materializes the whole cost surface as a [`CostTable`] (one latency
//! and one RMSE cell per (layer, pw, pa) mode), and [`search_table`]
//! then maintains *incremental running sums*: a degrade updates
//! Σlat/Σrmse by the table delta in O(1) instead of re-walking all n
//! layers, a rollback restores the saved sums (exactly the same delta
//! removed), and the rank/re-rank sorts read table cells instead of
//! invoking [`Metrics`] oracles inside comparators.  The pre-refactor
//! oracle-driven implementation is preserved verbatim in [`reference`]
//! as the equivalence oracle (property-tested below and in `engine.rs`)
//! and as the "old" side of `benches/perf_search.rs`.

use crate::sim::{Assignment, Prec};

use super::costs::CostTable;

/// Per-layer cost oracle: latency from the cycle-accurate simulator,
/// RMSE (paper Eqn. 2, summed over the layer's weight + activation
/// tensors) from the quantizer.
pub trait Metrics {
    fn n_layers(&self) -> usize;
    /// Latency (cycles) of layer `i` at (pw, pa).
    fn latency(&mut self, i: usize, pw: Prec, pa: Prec) -> f64;
    /// RMSE_i(a, w): combined quantization error of layer `i`.
    fn rmse(&mut self, i: usize, pw: Prec, pa: Prec) -> f64;
}

/// Which constraint drives the search (Sec. III-C2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Strategy {
    /// Eqn. 3: reach speedup ≥ alpha over the 8/8 baseline, min ΣRMSE.
    SpeedupConstrained { alpha: f64 },
    /// Eqn. 4: stay under ΣRMSE ≤ beta × baseline, min latency.
    RmseConstrained { beta: f64 },
}

/// Search outcome + bookkeeping for EXPERIMENTS.md.
#[derive(Clone, Debug)]
pub struct SearchResult {
    pub assignment: Assignment,
    /// Achieved speedup over the all-8/8 baseline.
    pub speedup: f64,
    /// Achieved Σrmse / Σrmse(8,8).
    pub rmse_ratio: f64,
    /// Outer iterations executed.
    pub iterations: usize,
    /// True if the constraint was met (false = hit the 2-bit floor).
    pub satisfied: bool,
}

/// Run Algorithm 1 against a [`Metrics`] oracle.
///
/// Fills a [`CostTable`] up front — exactly |Prec|²·n oracle queries —
/// and runs the table-driven [`search_table`].  Decision-for-decision
/// the algorithm documented above; equivalence with the pre-refactor
/// [`reference::search`] is property-tested in this module and in
/// `engine.rs`.
pub fn search<M: Metrics>(metrics: &mut M, strategy: Strategy, top_k: usize) -> SearchResult {
    search_table(&CostTable::from_metrics(metrics), strategy, top_k)
}

/// Run Algorithm 1 on a precomputed [`CostTable`] (DESIGN.md §7).
///
/// O(1) work per degrade step:
///
/// * the running Σlat/Σrmse start as layer-order folds over the table —
///   bit-identical to the reference implementation's full walks — and
///   each degrade applies the cell delta instead of re-walking all n
///   layers;
/// * an over-budget degrade in RMSE mode restores the saved pre-degrade
///   sums (subtracting exactly the delta it added, with no rounding
///   drift) and freezes the layer;
/// * the rank/re-rank sorts read table cells in their comparators.
///
/// Latency cells are integer-valued cycle counts whose partial sums stay
/// far below 2^53, so Σlat is *exact* under incremental updates; Σrmse
/// can differ from a full re-sum in the last ulps, which cannot flip a
/// constraint comparison except on measure-zero knife-edge inputs.
/// Equivalence (assignment, iterations, satisfied) with
/// [`reference::search`] is property-tested below and in `engine.rs`.
pub fn search_table(t: &CostTable, strategy: Strategy, top_k: usize) -> SearchResult {
    let n = t.n_layers();
    let mut assign: Assignment = vec![(Prec::B8, Prec::B8); n];
    // layer-order folds: bit-identical to the reference's naive walks
    let base_lat: f64 = (0..n).map(|i| t.lat(i, Prec::B8, Prec::B8)).sum();
    let full_rmse: f64 = (0..n).map(|i| t.rmse(i, Prec::B8, Prec::B8)).sum();
    let base_rmse = full_rmse.max(1e-12);
    // incremental running sums (DESIGN.md §7) — the only totals the
    // search ever consults; never re-walked after this point
    let mut sum_lat = base_lat;
    let mut sum_rmse = full_rmse;
    // layers whose degrade was rolled back under the RMSE budget
    let mut frozen = vec![false; n];
    let mut iterations = 0;

    let met = |lat: f64, rmse: f64| -> bool {
        match strategy {
            Strategy::SpeedupConstrained { alpha } => base_lat / lat >= alpha,
            // RMSE mode keeps going while under budget; "met" = budget
            // exhausted (any further degrade rolled back) — handled below.
            Strategy::RmseConstrained { beta } => rmse > beta * base_rmse,
        }
    };

    'outer: loop {
        iterations += 1;
        if let Strategy::SpeedupConstrained { .. } = strategy {
            if met(sum_lat, sum_rmse) {
                break;
            }
        }

        // candidates: layers that can still degrade (and aren't frozen)
        let mut ranked: Vec<usize> = (0..n)
            .filter(|&i| {
                !frozen[i]
                    && (assign[i].0.degrade().is_some() || assign[i].1.degrade().is_some())
            })
            .collect();
        if ranked.is_empty() {
            break;
        }

        // ---- rank: primary metric, then secondary re-rank (Alg. 1 l.5-11)
        // — pure table reads in the comparators, no oracle calls
        match strategy {
            Strategy::SpeedupConstrained { .. } => {
                // Lat_Rank: k largest by current latency
                ranked.sort_by(|&a, &b| {
                    let la = t.lat(a, assign[a].0, assign[a].1);
                    let lb = t.lat(b, assign[b].0, assign[b].1);
                    lb.total_cmp(&la)
                });
                ranked.truncate(top_k);
                // RMSE_RERANK: ascending RMSE at the *next* level so the
                // cheapest-error layers are degraded first
                ranked.sort_by(|&a, &b| {
                    let ra = next_level_rmse(t, &assign, a);
                    let rb = next_level_rmse(t, &assign, b);
                    ra.total_cmp(&rb)
                });
            }
            Strategy::RmseConstrained { .. } => {
                // RMSE_RANK: k smallest by next-level RMSE
                ranked.sort_by(|&a, &b| {
                    let ra = next_level_rmse(t, &assign, a);
                    let rb = next_level_rmse(t, &assign, b);
                    ra.total_cmp(&rb)
                });
                ranked.truncate(top_k);
                // Lat_rerank: descending latency — degrade slowest first
                ranked.sort_by(|&a, &b| {
                    let la = t.lat(a, assign[a].0, assign[a].1);
                    let lb = t.lat(b, assign[b].0, assign[b].1);
                    lb.total_cmp(&la)
                });
            }
        }

        // ---- DEGRADE_LEVEL over weights, then activations (Alg. 1 l.12-13)
        let mut progressed = false;
        for pass in 0..2 {
            for &l in &ranked {
                let old = assign[l];
                let newp = if pass == 0 {
                    old.0.degrade().map(|p| (p, old.1))
                } else {
                    old.1.degrade().map(|p| (old.0, p))
                };
                let Some(newp) = newp else { continue };
                // O(1) incremental accounting (DESIGN.md §7): apply the
                // table delta; keep the pre-degrade sums so a rollback
                // can subtract exactly the same delta.
                let (prev_lat, prev_rmse) = (sum_lat, sum_rmse);
                sum_lat += t.lat(l, newp.0, newp.1) - t.lat(l, old.0, old.1);
                sum_rmse += t.rmse(l, newp.0, newp.1) - t.rmse(l, old.0, old.1);
                assign[l] = newp;
                progressed = true;
                match strategy {
                    Strategy::SpeedupConstrained { .. } => {
                        if met(sum_lat, sum_rmse) {
                            break 'outer;
                        }
                    }
                    Strategy::RmseConstrained { .. } => {
                        if met(sum_lat, sum_rmse) {
                            // over budget: roll back and freeze this layer
                            assign[l] = old;
                            sum_lat = prev_lat;
                            sum_rmse = prev_rmse;
                            frozen[l] = true;
                        }
                    }
                }
            }
        }
        if !progressed {
            break;
        }
        if iterations > 64 * n {
            break; // safety net; cannot trigger with monotone degrades
        }
    }

    let speedup = base_lat / sum_lat;
    let rmse_ratio = sum_rmse / base_rmse;
    let satisfied = match strategy {
        Strategy::SpeedupConstrained { alpha } => speedup >= alpha,
        Strategy::RmseConstrained { beta } => rmse_ratio <= beta,
    };
    SearchResult { assignment: assign, speedup, rmse_ratio, iterations, satisfied }
}

/// RMSE of layer `l` if its weights were degraded one level (the ranking
/// key used by both strategies), read from the table.
fn next_level_rmse(t: &CostTable, assign: &Assignment, l: usize) -> f64 {
    let (pw, pa) = assign[l];
    let pw2 = pw.degrade().unwrap_or(pw);
    t.rmse(l, pw2, pa)
}

pub mod reference {
    //! Pre-refactor, oracle-driven Algorithm 1 — preserved verbatim as
    //! the equivalence oracle for the table-driven path (DESIGN.md §7).
    //!
    //! Per degrade step it pays two full-model oracle walks
    //! ([`total_latency`] / [`total_rmse`]) and it invokes the
    //! [`Metrics`] oracles inside its sort comparators — the
    //! O(n²·levels·top_k) query profile the cost table removes.  Not
    //! `#[cfg(test)]`-gated because `benches/perf_search.rs` times it as
    //! the "old" side of the before/after comparison; the equivalence
    //! property tests live in this file's test module and in
    //! `engine.rs`.

    use super::{Assignment, Metrics, Prec, SearchResult, Strategy};

    /// Naive full-model latency walk: one oracle query per layer.
    pub fn total_latency<M: Metrics>(m: &mut M, a: &Assignment) -> f64 {
        (0..a.len()).map(|i| m.latency(i, a[i].0, a[i].1)).sum()
    }

    /// Naive full-model RMSE walk: one oracle query per layer.
    pub fn total_rmse<M: Metrics>(m: &mut M, a: &Assignment) -> f64 {
        (0..a.len()).map(|i| m.rmse(i, a[i].0, a[i].1)).sum()
    }

    /// Run Algorithm 1, re-walking all n layers after every degrade.
    pub fn search<M: Metrics>(metrics: &mut M, strategy: Strategy, top_k: usize) -> SearchResult {
        let n = metrics.n_layers();
        let mut assign: Assignment = vec![(Prec::B8, Prec::B8); n];
        let base_lat = total_latency(metrics, &assign);
        let base_rmse = total_rmse(metrics, &assign).max(1e-12);
        // layers whose degrade was rolled back under the RMSE budget
        let mut frozen = vec![false; n];
        let mut iterations = 0;

        let met = |lat: f64, rmse: f64| -> bool {
            match strategy {
                Strategy::SpeedupConstrained { alpha } => base_lat / lat >= alpha,
                Strategy::RmseConstrained { beta } => rmse > beta * base_rmse,
            }
        };

        'outer: loop {
            iterations += 1;
            let cur_lat = total_latency(metrics, &assign);
            let cur_rmse = total_rmse(metrics, &assign);
            if let Strategy::SpeedupConstrained { .. } = strategy {
                if met(cur_lat, cur_rmse) {
                    break;
                }
            }

            // candidates: layers that can still degrade (and aren't frozen)
            let cand: Vec<usize> = (0..n)
                .filter(|&i| {
                    !frozen[i]
                        && (assign[i].0.degrade().is_some() || assign[i].1.degrade().is_some())
                })
                .collect();
            if cand.is_empty() {
                break;
            }

            // ---- rank: primary metric, then secondary re-rank
            let mut ranked = cand.clone();
            match strategy {
                Strategy::SpeedupConstrained { .. } => {
                    ranked.sort_by(|&a, &b| {
                        let la = metrics.latency(a, assign[a].0, assign[a].1);
                        let lb = metrics.latency(b, assign[b].0, assign[b].1);
                        lb.total_cmp(&la)
                    });
                    ranked.truncate(top_k);
                    ranked.sort_by(|&a, &b| {
                        let ra = next_level_rmse(metrics, &assign, a);
                        let rb = next_level_rmse(metrics, &assign, b);
                        ra.total_cmp(&rb)
                    });
                }
                Strategy::RmseConstrained { .. } => {
                    ranked.sort_by(|&a, &b| {
                        let ra = next_level_rmse(metrics, &assign, a);
                        let rb = next_level_rmse(metrics, &assign, b);
                        ra.total_cmp(&rb)
                    });
                    ranked.truncate(top_k);
                    ranked.sort_by(|&a, &b| {
                        let la = metrics.latency(a, assign[a].0, assign[a].1);
                        let lb = metrics.latency(b, assign[b].0, assign[b].1);
                        lb.total_cmp(&la)
                    });
                }
            }

            // ---- DEGRADE_LEVEL over weights, then activations
            let mut progressed = false;
            for pass in 0..2 {
                for &l in &ranked {
                    let old = assign[l];
                    let newp = if pass == 0 {
                        assign[l].0.degrade().map(|p| (p, assign[l].1))
                    } else {
                        assign[l].1.degrade().map(|p| (assign[l].0, p))
                    };
                    let Some(newp) = newp else { continue };
                    assign[l] = newp;
                    progressed = true;
                    let lat = total_latency(metrics, &assign);
                    let rmse = total_rmse(metrics, &assign);
                    match strategy {
                        Strategy::SpeedupConstrained { .. } => {
                            if met(lat, rmse) {
                                break 'outer;
                            }
                        }
                        Strategy::RmseConstrained { .. } => {
                            if met(lat, rmse) {
                                // over budget: roll back and freeze
                                assign[l] = old;
                                frozen[l] = true;
                            }
                        }
                    }
                }
            }
            if !progressed {
                break;
            }
            if iterations > 64 * n {
                break; // safety net; cannot trigger with monotone degrades
            }
        }

        let lat = total_latency(metrics, &assign);
        let rmse = total_rmse(metrics, &assign);
        let speedup = base_lat / lat;
        let rmse_ratio = rmse / base_rmse;
        let satisfied = match strategy {
            Strategy::SpeedupConstrained { alpha } => speedup >= alpha,
            Strategy::RmseConstrained { beta } => rmse_ratio <= beta,
        };
        SearchResult { assignment: assign, speedup, rmse_ratio, iterations, satisfied }
    }

    /// RMSE of layer `l` if its weights were degraded one level.
    fn next_level_rmse<M: Metrics>(m: &mut M, assign: &Assignment, l: usize) -> f64 {
        let (pw, pa) = assign[l];
        let pw2 = pw.degrade().unwrap_or(pw);
        m.rmse(l, pw2, pa)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthetic cost model: latency proportional to size × bits,
    /// rmse grows as bits shrink, scaled per layer.
    struct Fake {
        sizes: Vec<f64>,
        err_scale: Vec<f64>,
    }

    impl Metrics for Fake {
        fn n_layers(&self) -> usize {
            self.sizes.len()
        }
        fn latency(&mut self, i: usize, pw: Prec, pa: Prec) -> f64 {
            self.sizes[i] * (pw.bits() * pa.bits()) as f64 / 64.0
        }
        fn rmse(&mut self, i: usize, pw: Prec, pa: Prec) -> f64 {
            let e = |b: u32| match b {
                8 => 0.01,
                4 => 0.1,
                _ => 0.6,
            };
            self.err_scale[i] * (e(pw.bits()) + e(pa.bits()))
        }
    }

    fn fake() -> Fake {
        Fake {
            sizes: vec![100.0, 50.0, 10.0, 200.0],
            err_scale: vec![1.0, 2.0, 0.5, 1.5],
        }
    }

    #[test]
    fn speedup_constraint_satisfied_on_exit() {
        for alpha in [1.5, 2.0, 3.0] {
            let mut m = fake();
            let r = search(&mut m, Strategy::SpeedupConstrained { alpha }, 2);
            assert!(r.satisfied, "alpha={alpha}: {r:?}");
            assert!(r.speedup >= alpha);
        }
    }

    #[test]
    fn rmse_constraint_never_violated() {
        for beta in [1.5, 3.0, 10.0, 40.0] {
            let mut m = fake();
            let r = search(&mut m, Strategy::RmseConstrained { beta }, 2);
            assert!(r.rmse_ratio <= beta + 1e-9, "beta={beta}: {r:?}");
        }
    }

    #[test]
    fn bitwidths_only_degrade() {
        let mut m = fake();
        let r = search(&mut m, Strategy::SpeedupConstrained { alpha: 2.5 }, 2);
        for (pw, pa) in r.assignment {
            assert!(pw.bits() <= 8 && pa.bits() <= 8);
        }
    }

    #[test]
    fn unreachable_alpha_reports_unsatisfied() {
        let mut m = fake();
        // max speedup is 16x (all 2/2); 100x is unreachable
        let r = search(&mut m, Strategy::SpeedupConstrained { alpha: 100.0 }, 2);
        assert!(!r.satisfied);
        // everything hit the floor
        assert!(r.assignment.iter().all(|&(w, a)| w == Prec::B2 && a == Prec::B2));
    }

    #[test]
    fn larger_beta_gives_no_less_speedup() {
        let mut prev = 0.0;
        for beta in [1.2, 2.0, 8.0, 60.0] {
            let mut m = fake();
            let r = search(&mut m, Strategy::RmseConstrained { beta }, 2);
            assert!(r.speedup >= prev - 1e-9, "beta={beta}");
            prev = r.speedup;
        }
    }

    #[test]
    fn slowest_layer_quantized_first_in_speedup_mode() {
        // with alpha just above 1, only the first degrade happens; it must
        // hit one of the largest layers (idx 3 or 0)
        let mut m = fake();
        let r = search(&mut m, Strategy::SpeedupConstrained { alpha: 1.05 }, 2);
        let changed: Vec<usize> = r
            .assignment
            .iter()
            .enumerate()
            .filter(|(_, &(w, a))| w != Prec::B8 || a != Prec::B8)
            .map(|(i, _)| i)
            .collect();
        assert!(!changed.is_empty());
        assert!(changed.iter().all(|&i| i == 3 || i == 0), "{changed:?}");
    }

    #[test]
    fn prop_monotone_alpha_means_more_degrading() {
        use crate::util::proptest::check;
        check("alpha-monotone", 25, |r, _| 1.0 + 3.0 * r.uniform(), |&alpha| {
            let mut m1 = fake();
            let mut m2 = fake();
            let r1 = search(&mut m1, Strategy::SpeedupConstrained { alpha }, 2);
            let r2 = search(&mut m2,
                Strategy::SpeedupConstrained { alpha: alpha + 0.5 }, 2);
            r2.speedup >= r1.speedup - 1e-9
        });
    }

    // ---- table-driven vs reference equivalence ---------------------------

    /// Dense random cost model driven directly by its own table (the
    /// equivalence tests' randomized synthetic models).
    #[derive(Clone, Debug)]
    struct TableModel {
        n: usize,
        lat: Vec<f64>,
        rmse: Vec<f64>,
    }

    impl TableModel {
        fn cell(&self, i: usize, pw: Prec, pa: Prec) -> usize {
            let pidx = |p: Prec| match p {
                Prec::B8 => 0usize,
                Prec::B4 => 1,
                Prec::B2 => 2,
            };
            (i * 3 + pidx(pw)) * 3 + pidx(pa)
        }
    }

    impl Metrics for TableModel {
        fn n_layers(&self) -> usize {
            self.n
        }
        fn latency(&mut self, i: usize, pw: Prec, pa: Prec) -> f64 {
            self.lat[self.cell(i, pw, pa)]
        }
        fn rmse(&mut self, i: usize, pw: Prec, pa: Prec) -> f64 {
            self.rmse[self.cell(i, pw, pa)]
        }
    }

    fn same_outcome(a: &SearchResult, b: &SearchResult) -> bool {
        a.assignment == b.assignment && a.iterations == b.iterations && a.satisfied == b.satisfied
    }

    #[test]
    fn table_search_matches_reference_on_fake_model_grid() {
        for top_k in [1, 2, 4] {
            for strategy in [
                Strategy::SpeedupConstrained { alpha: 1.05 },
                Strategy::SpeedupConstrained { alpha: 2.0 },
                Strategy::SpeedupConstrained { alpha: 100.0 },
                Strategy::RmseConstrained { beta: 1.2 },
                Strategy::RmseConstrained { beta: 4.0 },
                Strategy::RmseConstrained { beta: 60.0 },
            ] {
                let r_new = search(&mut fake(), strategy, top_k);
                let r_old = reference::search(&mut fake(), strategy, top_k);
                assert!(
                    same_outcome(&r_new, &r_old),
                    "k={top_k} {strategy:?}:\n new {r_new:?}\n old {r_old:?}"
                );
            }
        }
    }

    #[test]
    fn prop_table_search_matches_reference_on_random_models() {
        use crate::util::proptest::check;
        check(
            "table-vs-reference-search",
            40,
            |r, size| {
                let n = 1 + r.below(2 + (size * 10.0) as usize);
                let cells = n * 9;
                // half the cases use dyadic (exactly representable, exactly
                // summable) costs to probe knife-edge comparisons; the rest
                // use arbitrary positive floats
                let dyadic = r.below(2) == 0;
                let mut draw = |lo: f64, hi: f64| {
                    let v = lo + (hi - lo) * r.uniform();
                    if dyadic { (v * 64.0).round() / 64.0 } else { v }
                };
                let lat: Vec<f64> = (0..cells).map(|_| draw(1.0, 1000.0)).collect();
                let rmse: Vec<f64> = (0..cells).map(|_| draw(0.0, 10.0)).collect();
                let strategy = if r.below(2) == 0 {
                    Strategy::SpeedupConstrained { alpha: 1.0 + 7.0 * r.uniform() }
                } else {
                    Strategy::RmseConstrained { beta: 1.0 + 15.0 * r.uniform() }
                };
                let top_k = 1 + r.below(4);
                (TableModel { n, lat, rmse }, strategy, top_k)
            },
            |(model, strategy, top_k)| {
                let r_new = search(&mut model.clone(), *strategy, *top_k);
                let r_old = reference::search(&mut model.clone(), *strategy, *top_k);
                same_outcome(&r_new, &r_old)
            },
        );
    }
}
