//! Quantization configs: per-layer (format, bits) assignments and their
//! encoding as the qcfg tensors the HLO artifacts consume.
//!
//! This is the run-time half of the "precision is data" design (DESIGN.md
//! §2): one HLO serves every format × bitwidth because rust feeds the
//! value-grid LUTs, activation scales, and enable flags as inputs —
//! mirroring the paper's run-time configurable PE modes.

use anyhow::{ensure, Result};

use crate::formats::{quantizer, Format, LUT_SIZE};
use crate::sim::{Assignment, Prec};
use crate::tensor::Tensor;

/// Per-layer quantization choice.
#[derive(Clone, Copy, Debug)]
pub struct LayerQuant {
    pub wfmt: Format,
    pub wbits: u32,
    pub afmt: Format,
    pub abits: u32,
    pub w_en: bool,
    pub a_en: bool,
}

impl LayerQuant {
    pub fn fp32() -> Self {
        LayerQuant {
            wfmt: Format::DyBit,
            wbits: 8,
            afmt: Format::DyBit,
            abits: 8,
            w_en: false,
            a_en: false,
        }
    }

    pub fn uniform(fmt: Format, wbits: u32, abits: u32) -> Self {
        LayerQuant { wfmt: fmt, wbits, afmt: fmt, abits, w_en: true, a_en: true }
    }
}

/// Whole-model quantization config + calibrated activation scales.
#[derive(Clone, Debug)]
pub struct QuantConfig {
    pub layers: Vec<LayerQuant>,
    /// Per-layer activation scale (1.0 until calibrated).
    pub ascales: Vec<f32>,
}

impl QuantConfig {
    /// All layers FP32 (quantization disabled) — the baseline config.
    pub fn fp32(n_layers: usize) -> Self {
        QuantConfig {
            layers: vec![LayerQuant::fp32(); n_layers],
            ascales: vec![1.0; n_layers],
        }
    }

    /// Same (format, W, A) everywhere — the Table II/III configs.
    pub fn uniform(n_layers: usize, fmt: Format, wbits: u32, abits: u32) -> Self {
        QuantConfig {
            layers: vec![LayerQuant::uniform(fmt, wbits, abits); n_layers],
            ascales: vec![1.0; n_layers],
        }
    }

    /// From an Algorithm-1 assignment (mixed per-layer bitwidths).
    pub fn from_assignment(fmt: Format, assign: &Assignment) -> Self {
        QuantConfig {
            layers: assign
                .iter()
                .map(|&(pw, pa)| LayerQuant::uniform(fmt, pw.bits(), pa.bits()))
                .collect(),
            ascales: vec![1.0; assign.len()],
        }
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// The simulator-facing view (precisions only).
    pub fn assignment(&self) -> Assignment {
        self.layers
            .iter()
            .map(|l| {
                (
                    Prec::from_bits(l.wbits).unwrap_or(Prec::B8),
                    Prec::from_bits(l.abits).unwrap_or(Prec::B8),
                )
            })
            .collect()
    }

    /// Calibrate per-layer activation scales from fwd_acts taps
    /// (RMSE-optimal search on each layer's sample, Fig. 2 adaptation).
    ///
    /// Runs the single-pass ladder (`calibrate_scale_lut`, DESIGN.md
    /// §8): each layer's tap row is sorted + prefix-summed once and all
    /// 54 candidate scales are scored from table-sized cell sums —
    /// selection identical to the per-element reference ladder (each
    /// row is calibrated at exactly one `(format, bits)`, so there is
    /// no cross-query view reuse to exploit here).
    pub fn calibrate(&mut self, taps: &Tensor) -> Result<()> {
        ensure!(taps.rank() == 2, "taps must be [L, S]");
        ensure!(taps.shape[0] == self.layers.len(), "taps rows != layers");
        for (i, lq) in self.layers.iter().enumerate() {
            if !lq.a_en {
                continue;
            }
            self.ascales[i] =
                quantizer::calibrate_scale_lut(taps.row(i), lq.afmt, lq.abits) as f32;
        }
        Ok(())
    }

    /// Build the five qcfg tensors in the canonical artifact input order:
    /// wluts [L,256], aluts [L,256], ascales [L], wq_en [L], aq_en [L].
    pub fn to_tensors(&self) -> [Tensor; 5] {
        let l = self.layers.len();
        let mut wluts = Vec::with_capacity(l * LUT_SIZE);
        let mut aluts = Vec::with_capacity(l * LUT_SIZE);
        let mut wq_en = Vec::with_capacity(l);
        let mut aq_en = Vec::with_capacity(l);
        for lq in &self.layers {
            wluts.extend_from_slice(&lq.wfmt.padded_lut(lq.wbits));
            aluts.extend_from_slice(&lq.afmt.padded_lut(lq.abits));
            wq_en.push(if lq.w_en { 1.0 } else { 0.0 });
            aq_en.push(if lq.a_en { 1.0 } else { 0.0 });
        }
        [
            Tensor::new(vec![l, LUT_SIZE], wluts).expect("wluts"),
            Tensor::new(vec![l, LUT_SIZE], aluts).expect("aluts"),
            Tensor::from_vec(self.ascales.clone()),
            Tensor::from_vec(wq_en),
            Tensor::from_vec(aq_en),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp32_config_disables_everything() {
        let q = QuantConfig::fp32(4);
        let [_, _, ascales, wq_en, aq_en] = q.to_tensors();
        assert!(wq_en.data.iter().all(|&x| x == 0.0));
        assert!(aq_en.data.iter().all(|&x| x == 0.0));
        assert!(ascales.data.iter().all(|&x| x == 1.0));
    }

    #[test]
    fn uniform_shapes() {
        let q = QuantConfig::uniform(3, Format::DyBit, 4, 8);
        let [wluts, aluts, ..] = q.to_tensors();
        assert_eq!(wluts.shape, vec![3, LUT_SIZE]);
        assert_eq!(aluts.shape, vec![3, LUT_SIZE]);
        // row content = padded dybit4 / dybit8 luts
        assert_eq!(wluts.row(0), &Format::DyBit.padded_lut(4)[..]);
        assert_eq!(aluts.row(2), &Format::DyBit.padded_lut(8)[..]);
    }

    #[test]
    fn from_assignment_roundtrip() {
        use crate::sim::Prec;
        let assign = vec![(Prec::B4, Prec::B8), (Prec::B2, Prec::B4)];
        let q = QuantConfig::from_assignment(Format::DyBit, &assign);
        assert_eq!(q.assignment(), assign);
    }

    #[test]
    fn calibrate_sets_scales() {
        let mut q = QuantConfig::uniform(2, Format::DyBit, 4, 4);
        let taps = Tensor::new(
            vec![2, 4],
            vec![0.1, -0.2, 0.3, -0.1, 10.0, -20.0, 5.0, -8.0],
        )
        .unwrap();
        q.calibrate(&taps).unwrap();
        assert!(q.ascales[1] > q.ascales[0] * 10.0);
    }

    #[test]
    fn calibrate_shape_mismatch_errors() {
        let mut q = QuantConfig::uniform(2, Format::DyBit, 4, 4);
        let taps = Tensor::zeros(&[3, 4]);
        assert!(q.calibrate(&taps).is_err());
    }
}
