//! QAT driver: owns a model's runtime state (params + momenta) and drives
//! the AOT-compiled train/eval/fwd computations — the "quantization-aware
//! training" stage of Fig. 4, running entirely from rust.
//!
//! The synthetic dataset lives *inside* the HLO (train/eval steps generate
//! their batch from an i32 seed; `data_batch` materializes one for
//! calibration/serving), so training here is bit-identical to what the
//! python tests see.

use std::path::Path;

use anyhow::{anyhow, ensure, Context, Result};

use crate::runtime::{
    f32_scalar, i32_scalar, literal_to_tensor, tensor_to_literal, Executor, Manifest, ModelEntry,
};
use crate::tensor::Tensor;

use super::luts::QuantConfig;

/// Scalar metrics of one step.
#[derive(Clone, Copy, Debug)]
pub struct StepMetrics {
    pub loss: f32,
    pub acc: f32,
}

/// A live model: manifest entry + parameters + optimizer state.
pub struct Session {
    pub model: ModelEntry,
    pub params: Vec<Tensor>,
    pub moms: Vec<Tensor>,
    dir: std::path::PathBuf,
}

impl Session {
    /// Load initial (python-initialized) parameters for `model`.
    pub fn new(manifest: &Manifest, model: &str) -> Result<Self> {
        let entry = manifest
            .models
            .get(model)
            .ok_or_else(|| anyhow!("model '{model}' not in manifest"))?
            .clone();
        let params = entry.load_params(&manifest.dir)?;
        let moms = params.iter().map(|p| Tensor::zeros(&p.shape)).collect();
        Ok(Session { model: entry, params, moms, dir: manifest.dir.clone() })
    }

    /// Reset optimizer momenta (between FP pre-train and QAT fine-tune).
    pub fn reset_momentum(&mut self) {
        for m in &mut self.moms {
            *m = Tensor::zeros(&m.shape);
        }
    }

    /// Snapshot / restore parameters (used by the bench sweeps so every
    /// format starts QAT from the same FP32 checkpoint).
    pub fn snapshot(&self) -> Vec<Tensor> {
        self.params.clone()
    }

    pub fn restore(&mut self, snap: &[Tensor]) {
        self.params = snap.to_vec();
        self.reset_momentum();
    }

    fn qcfg_literals(&self, q: &QuantConfig) -> Result<Vec<xla::Literal>> {
        ensure!(
            q.n_layers() == self.model.n_quant_layers,
            "qcfg layers {} != model {}",
            q.n_layers(),
            self.model.n_quant_layers
        );
        q.to_tensors().iter().map(tensor_to_literal).collect()
    }

    /// One SGD-momentum step on the batch derived from `seed`.
    pub fn train_step(&mut self, exec: &mut Executor, q: &QuantConfig, seed: i32,
                      lr: f32) -> Result<StepMetrics> {
        let art = self.model.artifact("train")?.file.clone();
        let np = self.params.len();
        let mut inputs = Vec::with_capacity(2 * np + 7);
        for p in &self.params {
            inputs.push(tensor_to_literal(p)?);
        }
        for m in &self.moms {
            inputs.push(tensor_to_literal(m)?);
        }
        inputs.push(i32_scalar(seed));
        inputs.extend(self.qcfg_literals(q)?);
        inputs.push(f32_scalar(lr));

        let outs = exec.run(&art, &inputs)?;
        ensure!(outs.len() == 2 * np + 2, "train outputs {}", outs.len());
        for (i, o) in outs[..np].iter().enumerate() {
            self.params[i] = literal_to_tensor(o)?;
        }
        for (i, o) in outs[np..2 * np].iter().enumerate() {
            self.moms[i] = literal_to_tensor(o)?;
        }
        let loss = literal_to_tensor(&outs[2 * np])?.data[0];
        let acc = literal_to_tensor(&outs[2 * np + 1])?.data[0];
        Ok(StepMetrics { loss, acc })
    }

    /// Run `steps` training steps; returns the per-step metrics.
    pub fn train(&mut self, exec: &mut Executor, q: &QuantConfig, steps: usize,
                 lr: f32, seed_start: i32) -> Result<Vec<StepMetrics>> {
        (0..steps)
            .map(|i| self.train_step(exec, q, seed_start + i as i32, lr))
            .collect()
    }

    /// Average loss/accuracy over `n_batches` held-out eval batches.
    pub fn evaluate(&mut self, exec: &mut Executor, q: &QuantConfig,
                    n_batches: usize) -> Result<StepMetrics> {
        let art = self.model.artifact("eval")?.file.clone();
        let mut inputs: Vec<xla::Literal> = Vec::new();
        for p in &self.params {
            inputs.push(tensor_to_literal(p)?);
        }
        inputs.push(i32_scalar(0)); // placeholder, replaced per batch
        inputs.extend(self.qcfg_literals(q)?);
        let seed_pos = self.params.len();

        let (mut loss, mut acc) = (0.0f64, 0.0f64);
        for b in 0..n_batches {
            inputs[seed_pos] = i32_scalar(b as i32);
            let outs = exec.run(&art, &inputs)?;
            loss += literal_to_tensor(&outs[0])?.data[0] as f64;
            acc += literal_to_tensor(&outs[1])?.data[0] as f64;
        }
        Ok(StepMetrics {
            loss: (loss / n_batches as f64) as f32,
            acc: (acc / n_batches as f64) as f32,
        })
    }

    /// Forward pass on an explicit input batch -> logits.
    pub fn forward(&mut self, exec: &mut Executor, q: &QuantConfig, x: &Tensor,
                   pallas: bool) -> Result<Tensor> {
        let tag = if pallas { "fwd_pallas" } else { "fwd" };
        let art = self.model.artifact(tag)?.file.clone();
        let mut inputs: Vec<xla::Literal> = Vec::new();
        for p in &self.params {
            inputs.push(tensor_to_literal(p)?);
        }
        inputs.push(tensor_to_literal(x)?);
        inputs.extend(self.qcfg_literals(q)?);
        let outs = exec.run(&art, &inputs)?;
        literal_to_tensor(&outs[0])
    }

    /// Forward with activation taps: returns (logits, taps [L, 2048]).
    pub fn forward_acts(&mut self, exec: &mut Executor, q: &QuantConfig,
                        x: &Tensor) -> Result<(Tensor, Tensor)> {
        let art = self.model.artifact("fwd_acts")?.file.clone();
        let mut inputs: Vec<xla::Literal> = Vec::new();
        for p in &self.params {
            inputs.push(tensor_to_literal(p)?);
        }
        inputs.push(tensor_to_literal(x)?);
        inputs.extend(self.qcfg_literals(q)?);
        let outs = exec.run(&art, &inputs)?;
        Ok((literal_to_tensor(&outs[0])?, literal_to_tensor(&outs[1])?))
    }

    /// Calibrate a config's activation scales on one synthetic batch
    /// (taps are collected with quantization disabled).
    pub fn calibrate(&mut self, exec: &mut Executor, q: &mut QuantConfig,
                     seed: i32) -> Result<()> {
        let (x, _) = materialize_batch(exec, &self.dir, seed)?;
        let fp = QuantConfig::fp32(q.n_layers());
        let (_, taps) = self.forward_acts(exec, &fp, &x)?;
        q.calibrate(&taps)
    }

    /// Save current parameters as a raw f32 checkpoint (leaf order).
    pub fn save_checkpoint(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let refs: Vec<&Tensor> = self.params.iter().collect();
        crate::tensor::io::write_f32_file(path, &refs)
    }

    /// Load parameters from a checkpoint written by `save_checkpoint`.
    pub fn load_checkpoint(&mut self, path: &Path) -> Result<()> {
        let flat = crate::tensor::io::read_f32_file(path)?;
        let want: usize = self.params.iter().map(|p| p.numel()).sum();
        ensure!(flat.len() == want, "checkpoint has {} elems, want {want}", flat.len());
        let mut off = 0;
        for p in &mut self.params {
            let n = p.numel();
            p.data.copy_from_slice(&flat[off..off + n]);
            off += n;
        }
        self.reset_momentum();
        Ok(())
    }

    /// Flattened weight tensor of each quantizable layer (search input).
    pub fn layer_weights(&self) -> Vec<Vec<f32>> {
        (0..self.model.layers.len())
            .map(|i| {
                self.model
                    .weight_leaf_idx(i)
                    .map(|pi| self.params[pi].data.clone())
                    .unwrap_or_default()
            })
            .collect()
    }

    /// Per-layer activation samples (taps rows) for the search engine.
    pub fn layer_acts(&mut self, exec: &mut Executor, seed: i32) -> Result<Vec<Vec<f32>>> {
        let (x, _) = materialize_batch(exec, &self.dir, seed)?;
        let fp = QuantConfig::fp32(self.model.n_quant_layers);
        let (_, taps) = self.forward_acts(exec, &fp, &x)?;
        Ok((0..taps.shape[0]).map(|i| taps.row(i).to_vec()).collect())
    }
}

/// Materialize one synthetic batch (x, y) from the data_batch artifact.
pub fn materialize_batch(exec: &mut Executor, _dir: &Path, seed: i32)
                         -> Result<(Tensor, Tensor)> {
    let outs = exec
        .run("data_batch.hlo.txt", &[i32_scalar(seed)])
        .context("data_batch artifact (re-run `make artifacts`?)")?;
    Ok((literal_to_tensor(&outs[0])?, literal_to_tensor(&outs[1])?))
}

/// Top-1 accuracy of logits against integer labels.
pub fn top1(logits: &Tensor, y: &Tensor) -> f64 {
    let pred = logits.argmax_rows();
    let correct = pred
        .iter()
        .zip(y.data.iter())
        .filter(|(&p, &t)| p == t as usize)
        .count();
    correct as f64 / pred.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top1_counts() {
        let logits = Tensor::new(vec![2, 3], vec![0.0, 1.0, 0.0, 1.0, 0.0, 0.0]).unwrap();
        let y = Tensor::from_vec(vec![1.0, 2.0]);
        assert!((top1(&logits, &y) - 0.5).abs() < 1e-12);
    }

    // Session integration (real PJRT execution) lives in
    // tests/runtime_integration.rs, gated on built artifacts.
}
