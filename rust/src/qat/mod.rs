//! Quantization-aware training driver + runtime quantization configs
//! (the QAT stage of the paper's Fig. 4 framework, run from rust over the
//! AOT-compiled train/eval computations).

pub mod luts;
pub mod trainer;

pub use luts::{LayerQuant, QuantConfig};
pub use trainer::{materialize_batch, top1, Session, StepMetrics};
