//! `dybit-lint` — the in-tree static analyzer CLI.
//!
//! ```text
//! dybit-lint [--verbose] [paths...]
//! ```
//!
//! Default path: `rust/src` (relative to the repo root / cwd).  Exits
//! 1 if any unsuppressed finding is reported, 0 otherwise — the
//! contract `ci.sh` relies on.  `--verbose` (what `ci.sh --analyze`
//! passes) appends per-lint counts and the justified-suppression
//! list.  See DESIGN.md §14 for the lint catalog.

use anyhow::Result;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let verbose = args.iter().any(|a| a == "--verbose");
    let mut paths: Vec<&str> = args
        .iter()
        .filter(|a| !a.starts_with("--"))
        .map(|a| a.as_str())
        .collect();
    if paths.is_empty() {
        paths.push("rust/src");
    }
    let report = dybit::analysis::analyze_paths(&paths)?;
    for f in &report.unsuppressed {
        println!("{f}");
    }
    if verbose {
        print!("{}", report.verbose_summary());
    }
    std::process::exit(if report.is_clean() { 0 } else { 1 });
}
