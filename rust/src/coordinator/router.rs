//! Request routing over heterogeneous-precision replicas (DESIGN.md §10).
//!
//! PR 4 gave the pool N *identical* replicas behind one shared intake;
//! the paper's accuracy/latency trade-off (Fig. 6) stopped at the model
//! boundary.  PrecisionBatching (arXiv 2003.00822) and Bit Fusion
//! (arXiv 1712.01507) both treat precision as a *scheduling* dimension —
//! this module does the same at serving time: each replica carries a
//! [`ReplicaPrecision`], each has its own intake queue
//! ([`super::batcher::ShardedIntake`]), and a [`Router`] picks the queue
//! per request.
//!
//! Built-in policies ([`router_from_spec`] parses their CLI names):
//!
//! * [`Fastest`] — deterministic weighted round-robin, share ∝
//!   1/(wbits·abits) (the BitFusion throughput model: a (Pw, Pa) PE mode
//!   executes 64/(Pw·Pa) multiplies per cycle, DESIGN.md §3).  Memory-
//!   bound layers compress the true ratio below that proxy; work
//!   stealing absorbs the error (DESIGN.md §10).
//! * [`AccuracyFloor`] — only replicas whose precision floor
//!   (min(wbits, abits)) meets `min_bits` receive traffic; routed items
//!   are tagged so lower-precision replicas cannot *steal* them either.
//! * [`Escalate`] — primary traffic goes to the fast (below-max-floor)
//!   replicas; a reply whose argmax margin (winner − runner-up logit)
//!   falls under the threshold is re-enqueued once on the most accurate
//!   replica, which answers instead — the serving-time analogue of the
//!   paper's "fall back to higher precision where the distribution
//!   demands it".
//!
//! All built-ins are deterministic: the routed shard is a pure function
//! of the pick count (stride scheduling under a mutex), never of wall
//! clock or queue races, so a seeded workload reproduces its per-replica
//! assignment counts exactly (`rust/tests/coordinator_routing.rs`).

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, ensure, Result};

use crate::util::lock;

/// Default [`Escalate`] margin threshold: logits gaps under this re-run
/// on the accurate replica.
pub const DEFAULT_ESCALATE_MARGIN: f32 = 0.1;

/// Shared escalation-margin knob: an `f32` in atomic bits, so the §12
/// PI controller (`coordinator::admission`) can retune a live
/// [`Escalate`] router without a lock on the routing hot path.
pub struct MarginKnob(AtomicU32);

impl MarginKnob {
    /// A knob initialised to `margin`.
    pub fn new(margin: f32) -> Self {
        MarginKnob(AtomicU32::new(margin.to_bits()))
    }

    /// The current margin (lock-free read).
    pub fn get(&self) -> f32 {
        f32::from_bits(self.0.load(Ordering::Relaxed))
    }

    /// Store a new margin; non-finite or negative values are ignored
    /// (the escalation predicate `margin < knob` must stay meaningful —
    /// everything compares below `inf`, nothing below `NaN`).
    pub fn set(&self, margin: f32) {
        if margin.is_finite() && margin >= 0.0 {
            self.0.store(margin.to_bits(), Ordering::Relaxed);
        }
    }
}

/// One replica's serving precision: the (weights, activations) bitwidths
/// its backend quantizes to.  Routing metadata — the backend factory is
/// built from the same list (`SimBackend::mixed_factory`, or a
/// per-replica `QuantConfig` for PJRT pools).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ReplicaPrecision {
    /// Weight bitwidth.
    pub wbits: u32,
    /// Activation bitwidth.
    pub abits: u32,
}

impl ReplicaPrecision {
    /// Explicit (weights, activations) bitwidths.
    pub fn new(wbits: u32, abits: u32) -> Self {
        ReplicaPrecision { wbits, abits }
    }

    /// Same bitwidth for weights and activations.
    pub fn uniform(bits: u32) -> Self {
        ReplicaPrecision { wbits: bits, abits: bits }
    }

    /// The replica's accuracy floor: min(wbits, abits).  Accuracy is
    /// limited by the weaker operand, so floor comparisons gate both
    /// [`AccuracyFloor`] routing and queue stealing.
    pub fn floor_bits(&self) -> u32 {
        self.wbits.min(self.abits)
    }

    /// Stride-scheduler charge per routed request: wbits·abits, i.e. the
    /// inverse of the BitFusion per-cycle multiply count (DESIGN.md §3),
    /// so shares come out ∝ 1/(wbits·abits).
    pub fn stride(&self) -> u64 {
        (self.wbits as u64) * (self.abits as u64)
    }
}

impl Default for ReplicaPrecision {
    /// The 8/8 baseline — homogeneous pools degrade to plain round-robin.
    fn default() -> Self {
        ReplicaPrecision { wbits: 8, abits: 8 }
    }
}

impl std::fmt::Display for ReplicaPrecision {
    /// The `4W8A` tier label every banner and report uses.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}W{}A", self.wbits, self.abits)
    }
}

/// Parse a `--precision-mix` CLI value: comma-separated per-replica
/// entries, each `B` (uniform) or `W:A`, e.g. `4,4,4,8` or `4:8,8:8`.
pub fn parse_precision_mix(s: &str) -> Result<Vec<ReplicaPrecision>> {
    let mut mix = Vec::new();
    for tok in s.split(',').filter(|t| !t.trim().is_empty()) {
        let tok = tok.trim();
        let p = match tok.split_once(':') {
            Some((w, a)) => ReplicaPrecision::new(
                w.trim().parse().map_err(|_| anyhow!("bad wbits '{w}' in '{tok}'"))?,
                a.trim().parse().map_err(|_| anyhow!("bad abits '{a}' in '{tok}'"))?,
            ),
            None => ReplicaPrecision::uniform(
                tok.parse().map_err(|_| anyhow!("bad bits '{tok}' in precision mix"))?,
            ),
        };
        ensure!(p.wbits >= 1 && p.abits >= 1, "precision bits must be >= 1, got '{tok}'");
        mix.push(p);
    }
    ensure!(!mix.is_empty(), "empty precision mix");
    Ok(mix)
}

/// Resolve a CLI `--precision-mix` against the homogeneous fallback:
/// an empty mix means `replicas` copies of `(wbits, abits)`; otherwise
/// the mix itself (whose length is the pool's replica count).  Shared
/// by `dybit serve` and the serve example so the fallback cannot drift
/// between them.
pub fn resolve_precision_mix(mix: Vec<ReplicaPrecision>, wbits: u32, abits: u32,
                             replicas: usize) -> Vec<ReplicaPrecision> {
    if mix.is_empty() {
        vec![ReplicaPrecision::new(wbits, abits); replicas.max(1)]
    } else {
        mix
    }
}

/// Per-request routing policy over the per-replica queues
/// (DESIGN.md §10).  Implementations must be deterministic in the pick
/// count (no wall clock, no queue-depth races) so seeded workloads
/// reproduce their assignment counts.
pub trait Router: Send + Sync {
    /// Policy name for logs and `Debug` output.
    fn name(&self) -> &str;

    /// Queue index for the next accepted request.  `precisions` has one
    /// entry per replica; the server clamps out-of-range returns.
    fn route(&self, precisions: &[ReplicaPrecision]) -> usize;

    /// Accuracy-floor tag stamped on routed items: replicas whose
    /// [`ReplicaPrecision::floor_bits`] is below this may not *steal*
    /// them (the owning queue serves its items regardless — routing
    /// already honored the floor).
    fn min_bits(&self) -> u32 {
        0
    }

    /// Health-aware routing (DESIGN.md §13): like [`route`], but
    /// `alive(i)` says whether replica `i` is currently routable (not
    /// dead or retired).  The default ignores health — external policy
    /// implementations keep compiling and behave as before; the
    /// built-ins override to skip unhealthy replicas and fall back to
    /// *any* live one when the policy's preferred set is all down.
    /// With nothing alive this degrades to [`route`]'s pick (the
    /// server answers the closed-queue error path either way).
    ///
    /// [`route`]: Router::route
    fn route_healthy(&self, precisions: &[ReplicaPrecision],
                     _alive: &dyn Fn(usize) -> bool) -> usize {
        self.route(precisions)
    }

    /// Post-inference escalation decision: given the replica that served
    /// the request and the argmax margin of its reply, return the
    /// replica to re-run on (strictly higher floor than `served`), or
    /// `None` to reply as-is.  Called only for first runs — escalated
    /// re-runs always reply.
    fn escalate(&self, _served: usize, _margin: f32,
                _precisions: &[ReplicaPrecision]) -> Option<usize> {
        None
    }

    /// The live margin knob of a controller-tunable policy
    /// (`escalate:auto`, DESIGN.md §12); `None` for fixed policies —
    /// `PoolConfig::escalation` requires `Some` so a controller can
    /// never silently tune a router that ignores it.
    fn margin_knob(&self) -> Option<Arc<MarginKnob>> {
        None
    }
}

/// First replica with the maximal precision floor (deterministic
/// tie-break: lowest index).
fn most_accurate(precisions: &[ReplicaPrecision]) -> usize {
    let mut best = 0;
    for (i, p) in precisions.iter().enumerate().skip(1) {
        if p.floor_bits() > precisions[best].floor_bits() {
            best = i;
        }
    }
    best
}

/// Deterministic stride scheduler (weighted round-robin): pick the
/// eligible replica with minimal accumulated credit (ties → lowest
/// index), then charge it its [`ReplicaPrecision::stride`].  The pick
/// sequence is a pure function of the pick count, so concurrent
/// submitters change interleaving but never the counts after N picks.
struct Wrr {
    // lock-order: router level 1
    credits: Mutex<Vec<u64>>,
}

impl Wrr {
    fn new() -> Self {
        Wrr { credits: Mutex::new(Vec::new()) }
    }

    fn pick(&self, precisions: &[ReplicaPrecision],
            eligible: impl Fn(usize) -> bool) -> usize {
        self.try_pick(precisions, eligible).unwrap_or(0)
    }

    /// Like [`pick`], but reports an empty eligible set as `None`
    /// instead of defaulting to replica 0, so health-aware callers can
    /// widen the set and retry (DESIGN.md §13).  Credit is only charged
    /// on a successful pick.
    ///
    /// [`pick`]: Wrr::pick
    fn try_pick(&self, precisions: &[ReplicaPrecision],
                eligible: impl Fn(usize) -> bool) -> Option<usize> {
        let mut c = lock(&self.credits);
        if c.len() != precisions.len() {
            // lazily (re)sized: routers are built before the pool, so the
            // replica count is first known here
            *c = vec![0; precisions.len()];
        }
        let mut best: Option<usize> = None;
        for i in 0..precisions.len() {
            if !eligible(i) {
                continue;
            }
            let better = match best {
                None => true,
                Some(b) => c[i] < c[b],
            };
            if better {
                best = Some(i);
            }
        }
        let i = best?;
        c[i] = c[i].saturating_add(precisions[i].stride().max(1));
        Some(i)
    }
}

/// Escalation fallback ladder (DESIGN.md §13): every *live* replica
/// whose precision floor is strictly above `served`'s, ordered
/// most-accurate first (floor descending, then faster stride, then
/// lower index).  The server tries each rung with a bounded-wait push
/// and answers with the fast result when the ladder is exhausted —
/// a single dead accurate replica must never blackhole an escalation.
pub fn escalation_ladder(served: usize, precisions: &[ReplicaPrecision],
                         alive: &dyn Fn(usize) -> bool) -> Vec<usize> {
    let Some(base) = precisions.get(served) else { return Vec::new() };
    let base_floor = base.floor_bits();
    let mut ladder: Vec<usize> = (0..precisions.len())
        .filter(|&i| {
            i != served && alive(i) && precisions[i].floor_bits() > base_floor
        })
        .collect();
    ladder.sort_by(|&a, &b| {
        precisions[b]
            .floor_bits()
            .cmp(&precisions[a].floor_bits())
            .then(precisions[a].stride().cmp(&precisions[b].stride()))
            .then(a.cmp(&b))
    });
    ladder
}

/// Weighted round-robin by replica speed: share ∝ 1/(wbits·abits).  On a
/// homogeneous pool this is plain round-robin.
pub struct Fastest {
    wrr: Wrr,
}

impl Fastest {
    /// A fresh weighted-round-robin cursor.
    pub fn new() -> Self {
        Fastest { wrr: Wrr::new() }
    }
}

impl Default for Fastest {
    fn default() -> Self {
        Fastest::new()
    }
}

impl Router for Fastest {
    fn name(&self) -> &str {
        "fastest"
    }

    fn route(&self, precisions: &[ReplicaPrecision]) -> usize {
        if precisions.is_empty() {
            return 0;
        }
        self.wrr.pick(precisions, |_| true)
    }

    fn route_healthy(&self, precisions: &[ReplicaPrecision],
                     alive: &dyn Fn(usize) -> bool) -> usize {
        if precisions.is_empty() {
            return 0;
        }
        self.wrr
            .try_pick(precisions, alive)
            .unwrap_or_else(|| self.route(precisions))
    }
}

/// Route only to replicas whose precision floor meets `min_bits`
/// (weighted round-robin among them); items are tagged so lower-floor
/// replicas cannot steal them.  If no replica satisfies the floor, the
/// most accurate replica takes everything (a clamped floor beats a dead
/// pool).
pub struct AccuracyFloor {
    /// The accuracy floor: minimum acceptable min(wbits, abits).
    pub min_bits: u32,
    wrr: Wrr,
    name: String,
}

impl AccuracyFloor {
    /// A floor router requiring `min(wbits, abits) >= min_bits`.
    pub fn new(min_bits: u32) -> Self {
        AccuracyFloor { min_bits, wrr: Wrr::new(), name: format!("floor:{min_bits}") }
    }
}

impl Router for AccuracyFloor {
    fn name(&self) -> &str {
        &self.name
    }

    fn route(&self, precisions: &[ReplicaPrecision]) -> usize {
        if precisions.is_empty() {
            return 0;
        }
        if precisions.iter().any(|p| p.floor_bits() >= self.min_bits) {
            self.wrr.pick(precisions, |i| precisions[i].floor_bits() >= self.min_bits)
        } else {
            most_accurate(precisions)
        }
    }

    fn min_bits(&self) -> u32 {
        self.min_bits
    }

    fn route_healthy(&self, precisions: &[ReplicaPrecision],
                     alive: &dyn Fn(usize) -> bool) -> usize {
        if precisions.is_empty() {
            return 0;
        }
        // prefer floor-satisfying live replicas; with the whole floor
        // tier down, the most accurate *live* replica takes the traffic
        // (a clamped floor beats a dead pool, same as `route`); with
        // nothing alive at all, fall back to the health-blind pick.
        self.wrr
            .try_pick(precisions, |i| {
                alive(i) && precisions[i].floor_bits() >= self.min_bits
            })
            .or_else(|| {
                let mut best: Option<usize> = None;
                for (i, p) in precisions.iter().enumerate() {
                    if !alive(i) {
                        continue;
                    }
                    let better = best
                        .map_or(true, |b| p.floor_bits() > precisions[b].floor_bits());
                    if better {
                        best = Some(i);
                    }
                }
                best
            })
            .unwrap_or_else(|| self.route(precisions))
    }
}

/// Confidence escalation (DESIGN.md §10): primary traffic runs on the
/// fast (below-max-floor) replicas; replies whose argmax margin falls
/// under `margin` re-run once on the most accurate replica, which
/// answers instead.  NaN margins (NaN logits) never escalate — the
/// backends are deterministic, so a re-run cannot help.
pub struct Escalate {
    /// Threshold behind a shared knob so the §12 controller can retune
    /// it live; fixed-margin instances simply never share it.
    margin: Arc<MarginKnob>,
    /// Built via [`Escalate::auto_tuned`]: expose the knob through
    /// [`Router::margin_knob`] for a `PoolConfig::escalation`
    /// controller.
    auto: bool,
    wrr: Wrr,
    name: String,
}

impl Escalate {
    /// Fixed-margin escalation (the pre-§12 behavior).
    pub fn new(margin: f32) -> Self {
        Escalate {
            margin: Arc::new(MarginKnob::new(margin)),
            auto: false,
            wrr: Wrr::new(),
            name: format!("escalate:{margin}"),
        }
    }

    /// Controller-tunable escalation (`escalate:auto`): starts at
    /// [`DEFAULT_ESCALATE_MARGIN`] and exposes its knob so a
    /// `PoolConfig::escalation` PI controller can steer it
    /// (DESIGN.md §12).
    pub fn auto_tuned() -> Self {
        Escalate {
            margin: Arc::new(MarginKnob::new(DEFAULT_ESCALATE_MARGIN)),
            auto: true,
            wrr: Wrr::new(),
            name: "escalate:auto".to_string(),
        }
    }

    /// The current margin threshold.
    pub fn margin(&self) -> f32 {
        self.margin.get()
    }
}

impl Router for Escalate {
    fn name(&self) -> &str {
        &self.name
    }

    fn route(&self, precisions: &[ReplicaPrecision]) -> usize {
        if precisions.is_empty() {
            return 0;
        }
        let max = most_accurate(precisions);
        let max_floor = precisions[max].floor_bits();
        if precisions.iter().any(|p| p.floor_bits() < max_floor) {
            self.wrr.pick(precisions, |i| precisions[i].floor_bits() < max_floor)
        } else {
            // homogeneous pool: no accurate tier to hold back
            self.wrr.pick(precisions, |_| true)
        }
    }

    fn route_healthy(&self, precisions: &[ReplicaPrecision],
                     alive: &dyn Fn(usize) -> bool) -> usize {
        if precisions.is_empty() {
            return 0;
        }
        let max = most_accurate(precisions);
        let max_floor = precisions[max].floor_bits();
        // live fast tier first; with every fast replica down, the live
        // accurate tier absorbs primary traffic (degraded but correct —
        // escalation then becomes a no-op); with nothing alive, fall
        // back to the health-blind pick.
        self.wrr
            .try_pick(precisions, |i| {
                alive(i) && precisions[i].floor_bits() < max_floor
            })
            .or_else(|| self.wrr.try_pick(precisions, alive))
            .unwrap_or_else(|| self.route(precisions))
    }

    fn escalate(&self, served: usize, margin: f32,
                precisions: &[ReplicaPrecision]) -> Option<usize> {
        if precisions.is_empty() || served >= precisions.len() {
            return None;
        }
        let target = most_accurate(precisions);
        if precisions[served].floor_bits() >= precisions[target].floor_bits() {
            return None; // already served at the accurate tier
        }
        // NaN < margin is false, so NaN margins fall through to None
        if margin < self.margin.get() {
            Some(target)
        } else {
            None
        }
    }

    fn margin_knob(&self) -> Option<Arc<MarginKnob>> {
        if self.auto {
            Some(Arc::clone(&self.margin))
        } else {
            None
        }
    }
}

/// Parse a `--router` CLI value: `fastest`, `floor:<bits>` (alias
/// `accuracy-floor:<bits>`), `escalate[:<margin>]` (default margin
/// [`DEFAULT_ESCALATE_MARGIN`]), or `escalate:auto` (controller-tuned
/// margin for a `PoolConfig::escalation` PI loop, DESIGN.md §12).
pub fn router_from_spec(spec: &str) -> Result<Arc<dyn Router>> {
    let (head, arg) = match spec.split_once(':') {
        Some((h, a)) => (h, Some(a)),
        None => (spec, None),
    };
    match head {
        "fastest" => {
            ensure!(arg.is_none(), "router 'fastest' takes no argument");
            Ok(Arc::new(Fastest::new()))
        }
        "floor" | "accuracy-floor" => {
            let bits: u32 = arg
                .ok_or_else(|| anyhow!("router 'floor' needs bits, e.g. floor:8"))?
                .parse()
                .map_err(|_| anyhow!("bad floor bits in '{spec}'"))?;
            ensure!(bits >= 1, "floor bits must be >= 1");
            Ok(Arc::new(AccuracyFloor::new(bits)))
        }
        "escalate" => {
            if arg == Some("auto") {
                return Ok(Arc::new(Escalate::auto_tuned()));
            }
            let margin: f32 = match arg {
                Some(a) => a.parse().map_err(|_| anyhow!("bad margin in '{spec}'"))?,
                None => DEFAULT_ESCALATE_MARGIN,
            };
            ensure!(margin.is_finite() && margin >= 0.0, "margin must be finite and >= 0");
            Ok(Arc::new(Escalate::new(margin)))
        }
        other => Err(anyhow!(
            "unknown router '{other}' (fastest|floor:<bits>|escalate[:m]|escalate:auto)"
        )),
    }
}

/// Parse a `--router` CLI value with an optional `+refine:on|off`
/// suffix (DESIGN.md §15), e.g. `escalate:auto+refine:off`.  Returns
/// the router plus the refinement toggle for `PoolConfig::refine`;
/// without a suffix refinement defaults to on — the pre-§15 full
/// re-run path stays reachable as `+refine:off`.
pub fn router_and_refine_from_spec(spec: &str) -> Result<(Arc<dyn Router>, bool)> {
    let (router_spec, refine) = match spec.split_once("+refine:") {
        Some((head, "on")) => (head, true),
        Some((head, "off")) => (head, false),
        Some((_, other)) => {
            return Err(anyhow!("bad refine toggle '{other}' in '{spec}' (on|off)"))
        }
        None => (spec, true),
    };
    Ok((router_from_spec(router_spec)?, refine))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mix(specs: &[(u32, u32)]) -> Vec<ReplicaPrecision> {
        specs.iter().map(|&(w, a)| ReplicaPrecision::new(w, a)).collect()
    }

    /// Route `n` requests and return per-replica counts.
    fn counts(r: &dyn Router, p: &[ReplicaPrecision], n: usize) -> Vec<usize> {
        let mut c = vec![0usize; p.len()];
        for _ in 0..n {
            c[r.route(p).min(p.len() - 1)] += 1;
        }
        c
    }

    #[test]
    fn fastest_is_round_robin_on_homogeneous_pools() {
        let p = mix(&[(8, 8), (8, 8), (8, 8)]);
        let r = Fastest::new();
        assert_eq!(counts(&r, &p, 9), vec![3, 3, 3]);
    }

    #[test]
    fn fastest_weights_by_inverse_bit_product() {
        // strides 16 vs 64: the (4,4) replica gets 4x the (8,8) share
        let p = mix(&[(4, 4), (8, 8)]);
        let r = Fastest::new();
        let c = counts(&r, &p, 100);
        assert_eq!(c.iter().sum::<usize>(), 100);
        assert_eq!(c[0], 80, "got {c:?}");
        assert_eq!(c[1], 20, "got {c:?}");
    }

    #[test]
    fn fastest_is_deterministic_across_instances() {
        let p = mix(&[(4, 4), (4, 8), (8, 8)]);
        let a = counts(&Fastest::new(), &p, 77);
        let b = counts(&Fastest::new(), &p, 77);
        assert_eq!(a, b);
    }

    #[test]
    fn accuracy_floor_excludes_fast_replicas() {
        let p = mix(&[(4, 4), (8, 8), (8, 8)]);
        let r = AccuracyFloor::new(8);
        let c = counts(&r, &p, 10);
        assert_eq!(c, vec![0, 5, 5]);
        assert_eq!(r.min_bits(), 8);
    }

    #[test]
    fn accuracy_floor_uses_min_of_w_and_a() {
        // (4,8) floors at 4: ineligible under floor:8
        let p = mix(&[(4, 8), (8, 8)]);
        let c = counts(&AccuracyFloor::new(8), &p, 6);
        assert_eq!(c, vec![0, 6]);
    }

    #[test]
    fn unsatisfiable_floor_clamps_to_most_accurate() {
        let p = mix(&[(2, 2), (4, 4)]);
        let c = counts(&AccuracyFloor::new(8), &p, 5);
        assert_eq!(c, vec![0, 5]);
    }

    #[test]
    fn escalate_routes_primary_traffic_to_fast_set() {
        let p = mix(&[(4, 4), (4, 4), (8, 8)]);
        let r = Escalate::new(0.1);
        let c = counts(&r, &p, 10);
        assert_eq!(c[2], 0, "accurate tier must not take primary traffic: {c:?}");
        assert_eq!(c[0] + c[1], 10);
    }

    #[test]
    fn escalate_decision_thresholds_on_margin() {
        let p = mix(&[(4, 4), (8, 8)]);
        let r = Escalate::new(0.1);
        assert_eq!(r.escalate(0, 0.05, &p), Some(1));
        assert_eq!(r.escalate(0, 0.0, &p), Some(1));
        assert_eq!(r.escalate(0, 0.5, &p), None);
        // the accurate replica never escalates its own replies
        assert_eq!(r.escalate(1, 0.0, &p), None);
        // NaN and +inf margins never escalate
        assert_eq!(r.escalate(0, f32::NAN, &p), None);
        assert_eq!(r.escalate(0, f32::INFINITY, &p), None);
    }

    #[test]
    fn escalate_on_homogeneous_pool_is_round_robin_no_escalation() {
        let p = mix(&[(8, 8), (8, 8)]);
        let r = Escalate::new(0.1);
        assert_eq!(counts(&r, &p, 4), vec![2, 2]);
        assert_eq!(r.escalate(0, 0.0, &p), None);
    }

    #[test]
    fn precision_mix_parses_both_forms() {
        let m = parse_precision_mix("4,4,4,8").unwrap();
        assert_eq!(m.len(), 4);
        assert_eq!(m[0], ReplicaPrecision::uniform(4));
        assert_eq!(m[3], ReplicaPrecision::uniform(8));
        let m = parse_precision_mix("4:8, 8:8").unwrap();
        assert_eq!(m[0], ReplicaPrecision::new(4, 8));
        assert_eq!(m[0].floor_bits(), 4);
        assert_eq!(m[1], ReplicaPrecision::new(8, 8));
        assert!(parse_precision_mix("").is_err());
        assert!(parse_precision_mix("4,x").is_err());
        assert!(parse_precision_mix("0").is_err());
    }

    #[test]
    fn resolve_mix_falls_back_to_uniform_tiers() {
        let r = resolve_precision_mix(Vec::new(), 4, 8, 3);
        assert_eq!(r, vec![ReplicaPrecision::new(4, 8); 3]);
        assert_eq!(resolve_precision_mix(Vec::new(), 8, 8, 0).len(), 1);
        let m = vec![ReplicaPrecision::uniform(4), ReplicaPrecision::uniform(8)];
        assert_eq!(resolve_precision_mix(m.clone(), 2, 2, 9), m);
    }

    #[test]
    fn router_specs_parse() {
        assert_eq!(router_from_spec("fastest").unwrap().name(), "fastest");
        let f = router_from_spec("floor:8").unwrap();
        assert_eq!(f.name(), "floor:8");
        assert_eq!(f.min_bits(), 8);
        assert_eq!(router_from_spec("accuracy-floor:4").unwrap().min_bits(), 4);
        assert_eq!(router_from_spec("escalate").unwrap().name(), "escalate:0.1");
        assert_eq!(router_from_spec("escalate:0.25").unwrap().name(), "escalate:0.25");
        assert_eq!(router_from_spec("escalate:auto").unwrap().name(), "escalate:auto");
        assert!(router_from_spec("bogus").is_err());
        assert!(router_from_spec("floor").is_err());
        assert!(router_from_spec("escalate:nope").is_err());
        assert!(router_from_spec("fastest:1").is_err());
    }

    /// Satellite of the §11 PR: every malformed spec must come back as
    /// a descriptive `Err` — never a panic, never a silently-defaulted
    /// router — because these strings arrive straight from the CLI.
    #[test]
    fn precision_mix_error_paths_are_descriptive_not_panics() {
        // empty / whitespace-only specs
        for s in ["", " ", ",", " , ,  "] {
            let e = parse_precision_mix(s).unwrap_err().to_string();
            assert!(e.contains("empty"), "spec {s:?}: {e}");
        }
        // non-numeric tokens name the offending token
        let e = parse_precision_mix("4,eight").unwrap_err().to_string();
        assert!(e.contains("eight"), "{e}");
        let e = parse_precision_mix("4:a").unwrap_err().to_string();
        assert!(e.contains('a'), "{e}");
        // half-formed W:A pairs
        assert!(parse_precision_mix("4:").is_err());
        assert!(parse_precision_mix(":8").is_err());
        assert!(parse_precision_mix("4:8:2").is_err());
        // zero bits rejected in either position
        let e = parse_precision_mix("0:8").unwrap_err().to_string();
        assert!(e.contains(">= 1"), "{e}");
        assert!(parse_precision_mix("8:0").is_err());
        assert!(parse_precision_mix("4,0,8").is_err());
        // negative and overflowing numbers are parse errors, not wraps
        assert!(parse_precision_mix("-4").is_err());
        assert!(parse_precision_mix("99999999999999999999").is_err());
    }

    #[test]
    fn router_spec_error_paths_are_descriptive_not_panics() {
        // unknown router names the candidate and the grammar
        let e = router_from_spec("bogus").unwrap_err().to_string();
        assert!(e.contains("bogus") && e.contains("fastest"), "{e}");
        assert!(router_from_spec("").is_err());
        // floor: missing, empty, non-numeric, zero, negative bits
        let e = router_from_spec("floor").unwrap_err().to_string();
        assert!(e.contains("floor:8"), "suggest the fix: {e}");
        assert!(router_from_spec("floor:").is_err());
        assert!(router_from_spec("floor:x").is_err());
        let e = router_from_spec("floor:0").unwrap_err().to_string();
        assert!(e.contains(">= 1"), "{e}");
        assert!(router_from_spec("floor:-8").is_err());
        // escalate: non-numeric, non-finite, negative margins
        assert!(router_from_spec("escalate:nope").is_err());
        let e = router_from_spec("escalate:inf").unwrap_err().to_string();
        assert!(e.contains("finite"), "{e}");
        assert!(router_from_spec("escalate:nan").is_err());
        assert!(router_from_spec("escalate:-0.5").is_err());
        // extra argument where none is allowed
        let e = router_from_spec("fastest:1").unwrap_err().to_string();
        assert!(e.contains("no argument"), "{e}");
    }

    #[test]
    fn refine_suffix_parses_and_defaults_on() {
        // no suffix: refinement on, same router as the plain spec
        let (r, on) = router_and_refine_from_spec("escalate:auto").unwrap();
        assert!(on && r.margin_knob().is_some());
        let (r, on) = router_and_refine_from_spec("fastest").unwrap();
        assert!(on && r.margin_knob().is_none());
        // explicit toggles, on any router head
        let (_, on) = router_and_refine_from_spec("escalate:0.1+refine:off").unwrap();
        assert!(!on);
        let (_, on) = router_and_refine_from_spec("floor:8+refine:on").unwrap();
        assert!(on);
        // bad toggle values and bad heads both fail descriptively
        let e = router_and_refine_from_spec("fastest+refine:maybe")
            .unwrap_err()
            .to_string();
        assert!(e.contains("maybe") && e.contains("on|off"), "{e}");
        assert!(router_and_refine_from_spec("fastest+refine:").is_err());
        assert!(router_and_refine_from_spec("bogus+refine:on").is_err());
    }

    #[test]
    fn auto_escalate_exposes_a_live_knob_fixed_does_not() {
        // only the auto-tuned router hands its margin to a controller
        assert!(router_from_spec("escalate:auto").unwrap().margin_knob().is_some());
        assert!(router_from_spec("escalate:0.25").unwrap().margin_knob().is_none());
        assert!(router_from_spec("escalate").unwrap().margin_knob().is_none());
        assert!(router_from_spec("fastest").unwrap().margin_knob().is_none());

        // the knob retunes a live escalation decision
        let r = Escalate::auto_tuned();
        let knob = r.margin_knob().unwrap();
        assert_eq!(r.margin(), DEFAULT_ESCALATE_MARGIN);
        let p = mix(&[(4, 4), (8, 8)]);
        assert_eq!(r.escalate(0, 0.3, &p), None);
        knob.set(0.5);
        assert_eq!(r.escalate(0, 0.3, &p), Some(1));
        knob.set(0.0);
        assert_eq!(r.escalate(0, 0.3, &p), None);
        // garbage stores are ignored, not adopted
        knob.set(0.25);
        knob.set(f32::INFINITY);
        knob.set(f32::NAN);
        knob.set(-1.0);
        assert_eq!(knob.get(), 0.25);
    }

    #[test]
    fn most_accurate_breaks_ties_to_lowest_index() {
        let p = mix(&[(4, 4), (8, 8), (8, 8)]);
        assert_eq!(most_accurate(&p), 1);
        let p = mix(&[(8, 8)]);
        assert_eq!(most_accurate(&p), 0);
    }

    /// Route `n` requests through the health-aware path.
    fn healthy_counts(r: &dyn Router, p: &[ReplicaPrecision],
                      alive: &dyn Fn(usize) -> bool, n: usize) -> Vec<usize> {
        let mut c = vec![0usize; p.len()];
        for _ in 0..n {
            c[r.route_healthy(p, alive).min(p.len() - 1)] += 1;
        }
        c
    }

    #[test]
    fn route_healthy_skips_dead_replicas() {
        let p = mix(&[(8, 8), (8, 8), (8, 8)]);
        let r = Fastest::new();
        let c = healthy_counts(&r, &p, &|i| i != 1, 9);
        assert_eq!(c[1], 0, "dead replica drew traffic: {c:?}");
        assert_eq!(c[0] + c[2], 9);
        // everything dead degrades to the health-blind pick, never panics
        let c = healthy_counts(&r, &p, &|_| false, 3);
        assert_eq!(c.iter().sum::<usize>(), 3);
    }

    #[test]
    fn route_healthy_floor_falls_to_live_most_accurate() {
        let p = mix(&[(2, 2), (4, 4), (8, 8)]);
        let r = AccuracyFloor::new(8);
        // floor tier alive: it takes everything
        let c = healthy_counts(&r, &p, &|_| true, 6);
        assert_eq!(c, vec![0, 0, 6]);
        // floor tier dead: the most accurate *live* replica clamps
        let c = healthy_counts(&r, &p, &|i| i != 2, 6);
        assert_eq!(c, vec![0, 6, 0]);
    }

    #[test]
    fn route_healthy_escalate_degrades_to_accurate_tier() {
        let p = mix(&[(4, 4), (4, 4), (8, 8)]);
        let r = Escalate::new(0.1);
        // fast tier alive: accurate replica takes no primary traffic
        let c = healthy_counts(&r, &p, &|i| i != 1, 8);
        assert_eq!(c, vec![8, 0, 0]);
        // whole fast tier dead: the accurate tier absorbs the load
        let c = healthy_counts(&r, &p, &|i| i == 2, 5);
        assert_eq!(c, vec![0, 0, 5]);
    }

    #[test]
    fn external_router_impls_get_a_working_default_route_healthy() {
        // a minimal impl (only name + route, like the routing tests'
        // Pin router) must keep compiling and behave like `route`
        struct Two;
        impl Router for Two {
            fn name(&self) -> &str { "two" }
            fn route(&self, _p: &[ReplicaPrecision]) -> usize { 2 }
        }
        let p = mix(&[(4, 4), (8, 8), (8, 8)]);
        assert_eq!(Two.route_healthy(&p, &|_| false), 2);
    }

    #[test]
    fn escalation_ladder_orders_live_higher_floors_accurate_first() {
        // served = replica 0 (2W2A); floors above 2: 4, 8, 8, 4:8->4
        let p = mix(&[(2, 2), (4, 4), (8, 8), (8, 8), (4, 8)]);
        let all = |_: usize| true;
        // floor desc (8,8 first), then stride asc, then index asc;
        // (4,8) floors at 4 and strides 32 > (4,4)'s 16
        assert_eq!(escalation_ladder(0, &p, &all), vec![2, 3, 1, 4]);
        // dead rungs drop out
        assert_eq!(escalation_ladder(0, &p, &|i| i != 2 && i != 1), vec![3, 4]);
        // served at the top floor: no ladder
        assert!(escalation_ladder(2, &p, &all).is_empty());
        // nothing alive: no ladder (caller answers with the fast result)
        assert!(escalation_ladder(0, &p, &|_| false).is_empty());
        // out-of-range served: empty, not a panic
        assert!(escalation_ladder(9, &p, &all).is_empty());
    }

    #[test]
    fn try_pick_charges_credit_only_on_success() {
        let p = mix(&[(8, 8), (8, 8)]);
        let w = Wrr::new();
        assert_eq!(w.try_pick(&p, |_| false), None);
        // failed picks left the credits untouched: round-robin starts at 0
        assert_eq!(w.try_pick(&p, |_| true), Some(0));
        assert_eq!(w.try_pick(&p, |_| true), Some(1));
    }
}
