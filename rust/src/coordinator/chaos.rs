//! Seeded fault injection for the serving pool (DESIGN.md §13).
//!
//! A [`ChaosBackend`] decorates any [`InferenceBackend`] and injects
//! the failure modes the supervision layer must survive — contained
//! panics, permanent deaths, wedged forwards, transient error bursts,
//! and slow-batch jitter — at *deterministic* points: every fault is
//! keyed to a forward-call ordinal and every random choice comes from a
//! seeded [`Rng`], so a failing test or bench run replays exactly.
//!
//! Fault grammar (comma-separated clauses, `ChaosSpec::parse`):
//!
//! | clause | effect on the wrapped backend |
//! |---|---|
//! | `panic@N` | forward call `N` panics (caught per-chunk → batch `Err`) |
//! | `die@N` | serve call `N` normally, then report [`fatal`] — the worker exits *between* batches and the supervisor respawns it |
//! | `hang@N=MS` | forward call `N` sleeps `MS` ms first (trips the watchdog) |
//! | `err@N+K` | forward calls `N..N+K` return `Err` (transient burst) |
//! | `jitter=MS` | every forward sleeps a seeded `0..MS` ms first |
//! | `seed=S` | seed of the jitter stream (default 0) |
//!
//! Any clause may carry a `:rI` suffix to scope it to replica `I`
//! (e.g. `die@3:r0,jitter=2`); unscoped clauses apply to every
//! replica.  Call ordinals are 1-based and count *forward calls* (one
//! per assembled chunk), the same unit the heartbeat epoch advances in.
//!
//! [`fatal`]: InferenceBackend::fatal

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, ensure, Result};

use crate::tensor::Tensor;
use crate::util::rng::Rng;

use super::backend::{BackendFactory, InferenceBackend};

/// One parsed fault clause.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// Forward call `at` panics (contained by the worker's per-chunk
    /// `catch_unwind`; the batch gets an `Err` reply).
    Panic { at: u64 },
    /// Call `at` executes normally, after which the backend reports
    /// [`InferenceBackend::fatal`] — a clean death between batches.
    Die { at: u64 },
    /// Forward call `at` sleeps `for_ms` before executing.
    Hang { at: u64, for_ms: u64 },
    /// Forward calls `at..at+count` return `Err`.
    Err { at: u64, count: u64 },
    /// Every forward sleeps a seeded `0..max_ms` ms first.
    Jitter { max_ms: u64 },
}

/// A fault scoped to one replica (`replica: None` = every replica).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ScopedFault {
    /// What goes wrong.
    pub fault: Fault,
    /// Which replica it targets (`None` = all).
    pub replica: Option<usize>,
}

/// A parsed chaos schedule: which faults fire where, plus the jitter
/// seed.  Cheap to clone into factory closures.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ChaosSpec {
    /// The scheduled faults, in spec order.
    pub faults: Vec<ScopedFault>,
    /// Seed for the jitter RNG (deterministic chaos runs).
    pub seed: u64,
}

impl ChaosSpec {
    /// Parse the `--chaos` grammar (module docs).  Empty spec = no
    /// faults (the decorator becomes a pass-through with a counter).
    pub fn parse(spec: &str) -> Result<Self> {
        let mut out = ChaosSpec::default();
        for raw in spec.split(',') {
            let raw = raw.trim();
            if raw.is_empty() {
                continue;
            }
            // split a trailing `:rI` replica scope off the clause
            let (clause, replica) = match raw.rsplit_once(":r") {
                Some((c, r)) => {
                    let id = r
                        .parse::<usize>()
                        .map_err(|_| anyhow!("chaos: bad replica scope in '{raw}'"))?;
                    (c, Some(id))
                }
                None => (raw, None),
            };
            if let Some(s) = clause.strip_prefix("seed=") {
                ensure!(replica.is_none(), "chaos: seed cannot be replica-scoped");
                out.seed = s.parse().map_err(|_| anyhow!("chaos: bad seed in '{raw}'"))?;
                continue;
            }
            let fault = if let Some(s) = clause.strip_prefix("panic@") {
                Fault::Panic { at: parse_at(s, raw)? }
            } else if let Some(s) = clause.strip_prefix("die@") {
                Fault::Die { at: parse_at(s, raw)? }
            } else if let Some(s) = clause.strip_prefix("hang@") {
                let (at, ms) = s
                    .split_once('=')
                    .ok_or_else(|| anyhow!("chaos: hang needs '@N=MS', got '{raw}'"))?;
                Fault::Hang { at: parse_at(at, raw)?, for_ms: parse_ms(ms, raw)? }
            } else if let Some(s) = clause.strip_prefix("err@") {
                let (at, count) = match s.split_once('+') {
                    Some((a, c)) => (
                        parse_at(a, raw)?,
                        c.parse::<u64>()
                            .ok()
                            .filter(|&c| c >= 1)
                            .ok_or_else(|| anyhow!("chaos: bad burst count in '{raw}'"))?,
                    ),
                    None => (parse_at(s, raw)?, 1),
                };
                Fault::Err { at, count }
            } else if let Some(s) = clause.strip_prefix("jitter=") {
                Fault::Jitter { max_ms: parse_ms(s, raw)? }
            } else {
                bail!(
                    "chaos: unknown clause '{raw}' (want panic@N | die@N | hang@N=MS | \
                     err@N+K | jitter=MS | seed=S, each with optional ':rI' scope)"
                );
            };
            out.faults.push(ScopedFault { fault, replica });
        }
        Ok(out)
    }

    /// Faults that apply to `replica`.
    pub fn faults_for(&self, replica: usize) -> Vec<Fault> {
        self.faults
            .iter()
            .filter(|f| f.replica.map_or(true, |r| r == replica))
            .map(|f| f.fault)
            .collect()
    }

    /// Decorate `inner` so every replica it builds is wrapped in a
    /// [`ChaosBackend`] carrying this schedule.  A respawned replica
    /// gets a *fresh* wrapper (call counter back to 1), so `die@N`
    /// kills each incarnation at the same point — a flapping replica —
    /// unless the schedule scopes it away.
    pub fn wrap(self, inner: BackendFactory) -> BackendFactory {
        Arc::new(move |replica| {
            let backend = inner(replica)?;
            Ok(Box::new(ChaosBackend::new(backend, &self, replica))
                as Box<dyn InferenceBackend>)
        })
    }
}

fn parse_at(s: &str, raw: &str) -> Result<u64> {
    s.parse::<u64>()
        .ok()
        .filter(|&n| n >= 1)
        .ok_or_else(|| anyhow!("chaos: call ordinal must be >= 1 in '{raw}'"))
}

fn parse_ms(s: &str, raw: &str) -> Result<u64> {
    s.parse::<u64>().map_err(|_| anyhow!("chaos: bad millisecond value in '{raw}'"))
}

/// The decorator: forwards to `inner`, injecting this replica's faults
/// at their scheduled call ordinals.  The call counter advances on
/// every `forward`, including ones that fault — ordinals are positions
/// in the call stream, not in the success stream.
pub struct ChaosBackend {
    inner: Box<dyn InferenceBackend>,
    faults: Vec<Fault>,
    calls: u64,
    rng: Rng,
    dead: Arc<AtomicBool>,
    name: String,
}

impl ChaosBackend {
    /// Wrap `inner` with the faults `spec` schedules for `replica`.
    pub fn new(inner: Box<dyn InferenceBackend>, spec: &ChaosSpec, replica: usize) -> Self {
        let name = format!("chaos({})", inner.name());
        ChaosBackend {
            faults: spec.faults_for(replica),
            calls: 0,
            // decorrelate replicas' jitter streams without extra config
            rng: Rng::new(spec.seed ^ (replica as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15)),
            dead: Arc::new(AtomicBool::new(false)),
            inner,
            name,
        }
    }

    /// Shared handle to the fatal flag (tests flip it to force a death
    /// at an exact moment instead of a call ordinal).
    pub fn dead_handle(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.dead)
    }
}

impl InferenceBackend for ChaosBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn batch(&self) -> usize {
        self.inner.batch()
    }

    fn img_elems(&self) -> usize {
        self.inner.img_elems()
    }

    fn forward(&mut self, x: Tensor) -> Result<Tensor> {
        self.calls += 1;
        let n = self.calls;
        let mut jitter = 0u64;
        for &f in &self.faults {
            match f {
                Fault::Panic { at } if at == n => {
                    panic!("chaos: injected panic (call {n})");
                }
                Fault::Hang { at, for_ms } if at == n => {
                    std::thread::sleep(Duration::from_millis(for_ms));
                }
                Fault::Err { at, count } if n >= at && n < at + count => {
                    bail!("chaos: injected transient error (call {n})");
                }
                Fault::Jitter { max_ms } if max_ms > 0 => {
                    jitter = jitter.max(self.rng.next_u64() % max_ms);
                }
                _ => {}
            }
        }
        if jitter > 0 {
            std::thread::sleep(Duration::from_millis(jitter));
        }
        let out = self.inner.forward(x);
        // die *after* serving call `at`: the worker answers this batch,
        // then sees fatal() and exits cleanly between batches
        if self.faults.iter().any(|&f| matches!(f, Fault::Die { at } if at == n)) {
            self.dead.store(true, Ordering::Release);
        }
        out
    }

    fn fatal(&self) -> bool {
        self.dead.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::super::backend::{SimBackend, SimBackendCfg};
    use super::*;

    fn wrapped(spec: &str, replica: usize) -> ChaosBackend {
        let inner = Box::new(SimBackend::new(SimBackendCfg::tiny(1)).unwrap());
        ChaosBackend::new(inner, &ChaosSpec::parse(spec).unwrap(), replica)
    }

    fn batch() -> Tensor {
        Tensor::zeros(&[4, 64])
    }

    #[test]
    fn parse_accepts_the_full_grammar() {
        let s = ChaosSpec::parse("panic@3,die@5:r1, hang@2=40 ,err@4+3:r0,jitter=7,seed=99")
            .unwrap();
        assert_eq!(s.seed, 99);
        assert_eq!(s.faults.len(), 5);
        assert_eq!(
            s.faults[0],
            ScopedFault { fault: Fault::Panic { at: 3 }, replica: None }
        );
        assert_eq!(
            s.faults[1],
            ScopedFault { fault: Fault::Die { at: 5 }, replica: Some(1) }
        );
        assert_eq!(
            s.faults[3],
            ScopedFault { fault: Fault::Err { at: 4, count: 3 }, replica: Some(0) }
        );
        // scoping filters per replica; unscoped faults reach everyone
        assert_eq!(s.faults_for(0).len(), 4);
        assert_eq!(s.faults_for(1).len(), 4);
        assert_eq!(s.faults_for(7).len(), 3);
        // bare err@N is a burst of one; empty spec is no faults
        assert_eq!(
            ChaosSpec::parse("err@2").unwrap().faults[0].fault,
            Fault::Err { at: 2, count: 1 }
        );
        assert!(ChaosSpec::parse("").unwrap().faults.is_empty());
    }

    #[test]
    fn parse_rejects_garbage_descriptively() {
        for (bad, needle) in [
            ("explode@3", "unknown clause"),
            ("panic@0", "ordinal"),
            ("panic@x", "ordinal"),
            ("hang@3", "hang needs"),
            ("err@2+0", "burst count"),
            ("die@2:rX", "replica scope"),
            ("seed=1:r0", "replica-scoped"),
            ("jitter=abc", "millisecond"),
        ] {
            let e = ChaosSpec::parse(bad).unwrap_err().to_string();
            assert!(e.contains(needle), "'{bad}' → {e}");
        }
    }

    #[test]
    fn err_burst_is_transient_and_positional() {
        let mut b = wrapped("err@2+2", 0);
        assert!(b.forward(batch()).is_ok()); // call 1
        assert!(b.forward(batch()).is_err()); // 2
        assert!(b.forward(batch()).is_err()); // 3
        assert!(b.forward(batch()).is_ok()); // 4: burst over
        assert!(!b.fatal());
    }

    #[test]
    fn die_serves_the_fatal_call_then_trips() {
        let mut b = wrapped("die@2", 0);
        assert!(b.forward(batch()).is_ok());
        assert!(!b.fatal());
        assert!(b.forward(batch()).is_ok(), "the dying call still answers");
        assert!(b.fatal(), "…then the backend reports fatal");
        // scoped to another replica: never trips here
        let mut other = wrapped("die@1:r3", 0);
        assert!(other.forward(batch()).is_ok());
        assert!(!other.fatal());
    }

    #[test]
    fn panic_fires_at_the_exact_ordinal() {
        let mut b = wrapped("panic@2", 1);
        assert!(b.forward(batch()).is_ok());
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = b.forward(batch());
        }));
        assert!(r.is_err(), "call 2 must panic");
    }

    #[test]
    fn hang_delays_the_scheduled_call() {
        let mut b = wrapped("hang@1=30", 0);
        let t0 = std::time::Instant::now();
        assert!(b.forward(batch()).is_ok());
        assert!(t0.elapsed() >= Duration::from_millis(30));
        let t1 = std::time::Instant::now();
        assert!(b.forward(batch()).is_ok());
        assert!(t1.elapsed() < Duration::from_millis(30), "only call 1 hangs");
    }

    #[test]
    fn jitter_is_seeded_and_bounded() {
        // same seed + replica ⇒ identical delay schedule (replayable)
        let mk = || wrapped("jitter=5,seed=7", 2);
        let (mut a, mut c) = (mk(), mk());
        for _ in 0..4 {
            let ta = std::time::Instant::now();
            a.forward(batch()).unwrap();
            let da = ta.elapsed();
            let tc = std::time::Instant::now();
            c.forward(batch()).unwrap();
            let dc = tc.elapsed();
            assert!(da < Duration::from_millis(50) && dc < Duration::from_millis(50));
        }
    }

    #[test]
    fn wrap_decorates_a_factory_per_replica() {
        let spec = ChaosSpec::parse("die@1:r0").unwrap();
        let f = spec.wrap(SimBackend::factory(SimBackendCfg::tiny(1)));
        let mut r0 = f(0).unwrap();
        let mut r1 = f(1).unwrap();
        assert_eq!(r0.name(), "chaos(sim)");
        assert_eq!(r0.batch(), 4);
        r0.forward(batch()).unwrap();
        r1.forward(batch()).unwrap();
        assert!(r0.fatal());
        assert!(!r1.fatal());
    }
}
