//! Dynamic batcher: size + deadline policy over a bounded request queue.
//!
//! The compiled fwd HLO has a static batch dimension (32); the batcher
//! fills a batch up to that size or until the oldest request has waited
//! `max_wait`, then pads the remainder with zero images.  The assembly
//! logic is pure (no threads) so it is unit-testable; the server wraps it
//! in a worker loop.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::{Mutex, PoisonError};
use std::time::{Duration, Instant};

/// One enqueued inference request.
pub struct Request<T, R> {
    pub payload: T,
    pub enqueued: Instant,
    /// Per-request response channel (std mpsc as a oneshot).
    pub respond: std::sync::mpsc::Sender<R>,
}

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct Policy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for Policy {
    fn default() -> Self {
        Policy { max_batch: 32, max_wait: Duration::from_millis(5) }
    }
}

/// Outcome of one assembly round.
pub enum Assembled<T, R> {
    /// A batch ready to execute (1..=max_batch requests).
    Batch(Vec<Request<T, R>>),
    /// Queue closed and drained — worker should exit.
    Closed,
}

/// Block until a batch is ready per the policy (or the channel closes).
pub fn assemble<T, R>(rx: &Receiver<Request<T, R>>, policy: Policy) -> Assembled<T, R> {
    // block for the first request
    let first = match rx.recv() {
        Ok(r) => r,
        Err(_) => return Assembled::Closed,
    };
    // Window end: effectively (enqueued ⌄ (now − max_wait)) + max_wait.
    // `Instant::now() - max_wait` can panic early in process life on
    // platforms where Instant's epoch is process start (and everywhere
    // for huge waits like Duration::MAX), and `+ max_wait` can overflow
    // Instant's range — use checked arithmetic with safe fallbacks
    // instead: an unrepresentable deadline means "no deadline"
    // (regression tests below).
    let anchor = match Instant::now().checked_sub(policy.max_wait) {
        Some(floor) => first.enqueued.max(floor),
        None => first.enqueued,
    };
    let deadline = anchor.checked_add(policy.max_wait);
    let mut batch = vec![first];
    while batch.len() < policy.max_batch {
        let recvd = match deadline {
            Some(d) => {
                let now = Instant::now();
                if now >= d {
                    break;
                }
                rx.recv_timeout(d - now)
            }
            // no finite deadline: wait until the batch fills or the
            // queue closes
            None => rx.recv().map_err(|_| RecvTimeoutError::Disconnected),
        };
        match recvd {
            Ok(r) => batch.push(r),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Assembled::Batch(batch)
}

/// Multi-consumer assembly over one shared intake (DESIGN.md §9): std
/// mpsc receivers are single-consumer, so pool replicas share the queue
/// through a mutex.  Exactly one replica assembles at a time — holding
/// the lock until the first request arrives (unbounded on an idle
/// queue, where siblings could not have received anything anyway) plus
/// at most one batch window — and then executes *outside* the lock, so
/// batch formation pipelines with execution across replicas.  The lock
/// is poison-recovering like the metrics lock: a replica that panicked
/// elsewhere must not wedge the others.
pub fn assemble_shared<T, R>(rx: &Mutex<Receiver<Request<T, R>>>,
                             policy: Policy) -> Assembled<T, R> {
    let rx = rx.lock().unwrap_or_else(PoisonError::into_inner);
    assemble(&rx, policy)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::thread;

    fn req(v: u32) -> (Request<u32, u32>, mpsc::Receiver<u32>) {
        let (tx, rx) = mpsc::channel();
        (Request { payload: v, enqueued: Instant::now(), respond: tx }, rx)
    }

    #[test]
    fn fills_to_max_batch() {
        let (tx, rx) = mpsc::channel();
        for i in 0..5 {
            tx.send(req(i).0).unwrap();
        }
        let policy = Policy { max_batch: 3, max_wait: Duration::from_secs(5) };
        match assemble(&rx, policy) {
            Assembled::Batch(b) => {
                assert_eq!(b.len(), 3);
                assert_eq!(b[0].payload, 0);
            }
            _ => panic!("expected batch"),
        }
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let (tx, rx) = mpsc::channel::<Request<u32, u32>>();
        tx.send(req(7).0).unwrap();
        let policy = Policy { max_batch: 32, max_wait: Duration::from_millis(10) };
        let t0 = Instant::now();
        match assemble(&rx, policy) {
            Assembled::Batch(b) => {
                assert_eq!(b.len(), 1);
                assert!(t0.elapsed() < Duration::from_secs(1));
            }
            _ => panic!("expected batch"),
        }
    }

    #[test]
    fn closed_channel_reports_closed() {
        let (tx, rx) = mpsc::channel::<Request<u32, u32>>();
        drop(tx);
        assert!(matches!(assemble(&rx, Policy::default()), Assembled::Closed));
    }

    #[test]
    fn huge_max_wait_does_not_panic() {
        // regression: the old deadline math did `Instant::now() - max_wait`
        // unchecked, which panics whenever max_wait exceeds the Instant
        // epoch (early process life on some platforms; Duration::MAX
        // everywhere) — and the `+ max_wait` side can overflow too.
        let (tx, rx) = mpsc::channel();
        tx.send(req(1).0).unwrap();
        tx.send(req(2).0).unwrap();
        let policy = Policy { max_batch: 2, max_wait: Duration::MAX };
        match assemble(&rx, policy) {
            Assembled::Batch(b) => assert_eq!(b.len(), 2),
            _ => panic!("expected batch"),
        }
    }

    #[test]
    fn huge_max_wait_still_flushes_when_queue_closes() {
        let (tx, rx) = mpsc::channel();
        tx.send(req(7).0).unwrap();
        drop(tx); // queue closes with a partial batch pending
        let policy = Policy { max_batch: 8, max_wait: Duration::MAX };
        match assemble(&rx, policy) {
            Assembled::Batch(b) => assert_eq!(b.len(), 1),
            _ => panic!("expected batch"),
        }
    }

    #[test]
    fn shared_receiver_splits_load_across_consumers() {
        let (tx, rx) = mpsc::channel();
        for i in 0..6 {
            tx.send(req(i).0).unwrap();
        }
        drop(tx);
        let rx = Mutex::new(rx);
        let policy = Policy { max_batch: 2, max_wait: Duration::from_millis(1) };
        let mut seen = Vec::new();
        loop {
            match assemble_shared(&rx, policy) {
                Assembled::Batch(b) => {
                    assert!(b.len() <= 2);
                    seen.extend(b.iter().map(|r| r.payload));
                }
                Assembled::Closed => break,
            }
        }
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn late_arrivals_join_within_deadline() {
        let (tx, rx) = mpsc::channel();
        tx.send(req(1).0).unwrap();
        let h = thread::spawn(move || {
            thread::sleep(Duration::from_millis(5));
            tx.send(req(2).0).unwrap();
        });
        let policy = Policy { max_batch: 8, max_wait: Duration::from_millis(200) };
        match assemble(&rx, policy) {
            Assembled::Batch(b) => assert!(b.len() >= 1), // 2 on a fast box
            _ => panic!(),
        }
        h.join().unwrap();
    }
}
