//! Dynamic batcher over per-replica intake queues with tail stealing
//! (DESIGN.md §9–§11).
//!
//! Pre-§10 the pool shared one mpsc intake behind a mutex; routing was
//! impossible (whoever locked first took the oldest request) and a
//! precision-aware scheduler had nowhere to stand.  [`ShardedIntake`]
//! gives every replica its own bounded FIFO: the [`super::Router`]
//! (DESIGN.md §10) picks the shard per request, the owning replica
//! assembles batches from its queue front under the same size+deadline
//! policy as before, and an *idle* replica steals from the tail of the
//! most loaded sibling so skewed routing cannot idle half the pool.
//!
//! §11 rescaled the intake for big pools.  The §10 implementation —
//! kept here as [`CoarseIntake`], the reference that certifies the
//! stress harness (`rust/tests/coordinator_stress.rs`) — serialized
//! every queue on one mutex and `notify_all`ed one shared condvar on
//! every push *and* pop, waking every blocked pusher and popper per
//! item: O(threads) spurious wakeups, quadratic wakeup traffic on a
//! saturated 16–64-replica pool.  [`ShardedIntake`] splits the state:
//!
//! * **Per-shard mutex + `not_full` condvar.**  A pusher blocks on its
//!   own shard's capacity only; each pop from that shard `notify_one`s
//!   exactly one blocked pusher.
//! * **Parked-popper registry (the `not_empty` side).**  An idle
//!   replica parks on its own condvar; a push wakes exactly one popper —
//!   the shard's owner if parked, else one parked thief whose precision
//!   floor admits the pushed item.  An epoch counter bumped inside the
//!   push critical section closes the check-then-park race (§11 walks
//!   the interleavings).
//! * **Top-K load board.**  Victim selection reads a
//!   [`crate::util::loadheap::LoadHeap`] maintained O(log n) from
//!   push/pop-side depth updates instead of walking every sibling.
//!
//! Queue invariants (asserted by the unit tests here, by
//! `rust/tests/coordinator_routing.rs`, and under seeded concurrent
//! load by `rust/tests/coordinator_stress.rs` against BOTH
//! implementations):
//!
//! * **Owner order.**  A replica serves its own queue strictly FIFO
//!   (front pops).  Thieves take from the *tail* only, so the relative
//!   order of everything left in the victim's queue is preserved —
//!   stealing never reorders a replica's own FIFO.
//! * **Steal gate.**  An [`Item`] tagged `min_bits > 0` (accuracy-floor
//!   routing, escalation re-runs) is only stolen by replicas whose
//!   precision floor meets it.  The owner serves its queue regardless of
//!   tags — routing already honored the floor when it picked the shard.
//! * **Bounded, blocking.**  Each shard holds at most `cap` items;
//!   `push` blocks until space or the intake closes (the same
//!   backpressure the old `sync_channel` gave `submit`).  Every pop
//!   notifies, so a blocked pusher never outlives the capacity it waits
//!   for (regression test `bounded_push_blocks_until_a_pop_frees_space`).
//! * **No lost items.**  Every `push` that returns `Ok` is served by
//!   some replica before the poppers see [`Assembled::Closed`] — the
//!   close/push/park interleavings are epoch-guarded (DESIGN.md §11).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::util::loadheap::LoadHeap;
use crate::util::{lock, wait, wait_timeout};

/// One enqueued inference request.
pub struct Request<T, R> {
    /// The request body handed to the backend.
    pub payload: T,
    /// Arrival timestamp (deadline and latency accounting).
    pub enqueued: Instant,
    /// Per-request response channel (std mpsc as a oneshot).
    pub respond: std::sync::mpsc::Sender<R>,
}

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct Policy {
    /// Upper bound on requests assembled into one batch.
    pub max_batch: usize,
    /// How long the assembler waits for stragglers past the first item.
    pub max_wait: std::time::Duration,
}

impl Default for Policy {
    fn default() -> Self {
        Policy { max_batch: 32, max_wait: std::time::Duration::from_millis(5) }
    }
}

/// A [`Request`] plus its routing tags (DESIGN.md §10).
pub struct Item<T, R> {
    /// The wrapped request.
    pub req: Request<T, R>,
    /// Accuracy floor: replicas with a lower precision floor may not
    /// steal this item ([`super::Router::min_bits`], escalation
    /// re-runs).  `0` = anyone.
    pub min_bits: u32,
    /// Set on escalation re-runs: reply with the result, never
    /// re-escalate (bounds every request to at most two executions).
    pub escalated: bool,
    /// Set by `pop_batch` when the item was taken from a sibling's
    /// tail — feeds the per-replica `stolen` counter.
    pub stolen: bool,
    /// Absolute SLA deadline stamped by admission
    /// (`Server::submit_with`, DESIGN.md §12).  An item that expires
    /// while queued is answered `Err` at assembly time and counted in
    /// `deadline_drops` — never executed.  `None` = no SLA.
    pub deadline: Option<Instant>,
    /// Tenant id for fair-queue accounting (DESIGN.md §12); `0` is the
    /// default tenant.
    pub tenant: u32,
    /// Shard whose per-tenant occupancy slot this item holds
    /// ([`Item::TENANT_UNCHARGED`] = none).  Charged by admission at
    /// submit, released by the worker the moment the item leaves the
    /// queue; the sentinel keeps escalation re-pushes from releasing
    /// twice.
    pub tenant_shard: u32,
    /// Partial-sum cache ticket for §15 refinement escalations: the id
    /// of the [`super::PlaneCache`] entry holding this request's
    /// accumulated bitplane dots.  The receiving replica takes the
    /// entry and adds only the residual planes; `0` (no ticket) means
    /// a plain full re-run.  Reclaimed on every terminal path (reply,
    /// expiry, rejection, failed rehome) so entries never outlive
    /// their request.
    pub refine_id: u64,
}

impl<T, R> Item<T, R> {
    /// `tenant_shard` sentinel: this item holds no occupancy slot.
    pub const TENANT_UNCHARGED: u32 = u32::MAX;

    /// An untagged item (stealable by anyone, first run, no SLA, the
    /// default tenant, no occupancy charge).
    pub fn new(req: Request<T, R>) -> Self {
        Item {
            req,
            min_bits: 0,
            escalated: false,
            stolen: false,
            deadline: None,
            tenant: 0,
            tenant_shard: Self::TENANT_UNCHARGED,
            refine_id: 0,
        }
    }
}

/// Outcome of one assembly round.
pub enum Assembled<T, R> {
    /// A batch ready to execute (1..=max_batch items).
    Batch(Vec<Item<T, R>>),
    /// Intake closed and fully drained — worker should exit.
    Closed,
}

/// Why [`IntakeQueue::try_push`] refused an item — the item always
/// comes back so the caller can answer its reply channel (the
/// no-dead-`Receiver` contract, DESIGN.md §12).
pub enum PushRefused<T, R> {
    /// The shard is at capacity; a blocking `push` would have waited.
    Full(Item<T, R>),
    /// The intake is closed.
    Closed(Item<T, R>),
}

impl<T, R> PushRefused<T, R> {
    /// Recover the refused item regardless of reason.
    pub fn into_item(self) -> Item<T, R> {
        match self {
            PushRefused::Full(it) | PushRefused::Closed(it) => it,
        }
    }
}

/// The intake contract shared by [`ShardedIntake`] and the pre-§11
/// [`CoarseIntake`] reference — what the stress harness
/// (`rust/tests/coordinator_stress.rs`) drives so the old
/// implementation certifies the harness before the new one must pass
/// it (DESIGN.md §11).
pub trait IntakeQueue<T, R>: Send + Sync {
    /// Number of per-replica shards.
    fn shards(&self) -> usize;

    /// Blocking bounded push onto `shard`'s tail.  Returns the item
    /// back if the intake is closed (caller decides how to answer it).
    fn push(&self, shard: usize, item: Item<T, R>)
            -> std::result::Result<(), Item<T, R>>;

    /// Non-blocking push: refuse with [`PushRefused::Full`] when the
    /// shard is at capacity instead of waiting — the admission layer's
    /// reject-don't-block primitive (DESIGN.md §12).
    fn try_push(&self, shard: usize, item: Item<T, R>)
                -> std::result::Result<(), PushRefused<T, R>>;

    /// Stop accepting pushes; replicas drain what is queued and then
    /// see [`Assembled::Closed`].
    fn close(&self);

    /// Close a single shard (its owner died or was retired, DESIGN.md
    /// §13): pushes routed at it refuse with the item back while the
    /// rest of the intake keeps serving.  Items already queued stay
    /// until stolen or drained — closing loses nothing.
    fn close_shard(&self, shard: usize);

    /// Remove and return everything queued on `shard` — the failover
    /// drain primitive (DESIGN.md §13).  The caller owns re-homing or
    /// answering every returned item (no-dead-`Receiver` contract).
    fn drain_shard(&self, shard: usize) -> Vec<Item<T, R>>;

    /// Bounded-wait push: like [`push`] but gives up with
    /// [`PushRefused::Full`] after `timeout` instead of blocking
    /// indefinitely — the escalation ladder's per-candidate attempt
    /// (DESIGN.md §13).
    ///
    /// [`push`]: IntakeQueue::push
    fn push_timeout(&self, shard: usize, item: Item<T, R>, timeout: Duration)
                    -> std::result::Result<(), PushRefused<T, R>>;

    /// Items currently queued across all shards (diagnostics).
    fn len(&self) -> usize;

    /// Current depth of one shard — admission's live load signal for
    /// the queue-delay projection (DESIGN.md §12).
    fn shard_len(&self, shard: usize) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Assemble one batch for `shard`: block for a first item (own
    /// front first, else a sibling tail if stealing is on), then fill
    /// from the same sources until `max_batch` or the deadline.
    fn pop_batch(&self, shard: usize, policy: Policy) -> Assembled<T, R>;
}

/// Window end for one assembly: effectively
/// `(enqueued ⌄ (now − max_wait)) + max_wait`.
/// `Instant::now() - max_wait` can panic early in process life on
/// platforms where Instant's epoch is process start (and everywhere for
/// huge waits like `Duration::MAX`), and `+ max_wait` can overflow
/// Instant's range — checked arithmetic with safe fallbacks instead: an
/// unrepresentable deadline means "no deadline" (§9 regression, shared
/// by both intakes).
fn batch_deadline(enqueued: Instant, max_wait: Duration) -> Option<Instant> {
    let anchor = match Instant::now().checked_sub(max_wait) {
        Some(floor) => enqueued.max(floor),
        None => enqueued,
    };
    anchor.checked_add(max_wait)
}

// ---------------------------------------------------------------------
// §11 ShardedIntake: split locks, targeted wakeups, load-board stealing
// ---------------------------------------------------------------------

/// One shard's queue behind its own lock.
struct ShardQ<T, R> {
    q: VecDeque<Item<T, R>>,
    /// Set under this shard's lock by `close()`, so a push and a close
    /// serialize per shard — the drain proof (DESIGN.md §11) needs a
    /// successful push to strictly precede the shard's closure.
    closed: bool,
}

struct Shard<T, R> {
    // lock-order: intake level 1
    state: Mutex<ShardQ<T, R>>,
    /// Pushers blocked on THIS shard's capacity; each pop from the
    /// shard `notify_one`s it — one free slot, one woken pusher.
    not_full: Condvar,
}

/// Shard depths + tail tags, exactly maintained under `shard lock →
/// board lock` (the only nested lock order in the intake), so victim
/// selection and the closed-drain check read consistent state.
struct Board {
    /// shard → queue depth, indexed max-heap (tie → lowest shard).
    heap: LoadHeap,
    /// `min_bits` of each shard's tail item (meaningful when depth>0);
    /// lets `select` apply the steal gate without touching shard locks.
    tail_bits: Vec<u32>,
}

/// Parked-popper registry: `parked[r]` means replica `r` is blocked on
/// its bell with nothing to serve and no wakeup targeted at it yet.
struct ParkState {
    parked: Vec<bool>,
    /// Debug contract check: at most one concurrent `pop_batch` per
    /// shard id (the pool runs one worker per shard; a second popper on
    /// the same bell could sleep through its wakeup).
    active: Vec<bool>,
}

/// Per-replica bounded FIFO queues with tail stealing, scaled for big
/// pools (DESIGN.md §11): per-shard mutexes, split `not_full`/parked-
/// popper condvars with targeted `notify_one`, and an O(log n) load
/// board for victim selection.  See the module docs for the invariants
/// and `rust/tests/coordinator_stress.rs` for the seeded certification.
pub struct ShardedIntake<T, R> {
    shards: Vec<Shard<T, R>>,
    // lock-order: intake level 2
    board: Mutex<Board>,
    // lock-order: intake level 3 alone
    park: Mutex<ParkState>,
    /// One bell per replica, all paired with `park` — a push rings
    /// exactly one.
    bells: Vec<Condvar>,
    /// Bumped inside the push critical section (before the shard lock
    /// is released).  A popper records the epoch before scanning and
    /// parks (or returns Closed) only if it is unchanged under the park
    /// lock — any push it might have missed forces a rescan, so no
    /// check-then-park lost wakeup and no stranded item on close
    /// (DESIGN.md §11).
    epoch: AtomicU64,
    /// Mirror of the per-shard `closed` flags, stored after ALL shards
    /// are closed — by then no further push can bump the epoch, which
    /// is what makes the epoch-stable Closed decision sound.
    closed: AtomicBool,
    cap: usize,
    /// Per-replica precision floor (min(wbits, abits)); gates stealing.
    floor_bits: Vec<u32>,
    steal: bool,
}

impl<T, R> ShardedIntake<T, R> {
    /// `floor_bits` has one entry per shard/replica; `cap` bounds each
    /// shard; `steal` enables tail stealing between shards.
    pub fn new(cap: usize, floor_bits: Vec<u32>, steal: bool) -> Self {
        assert!(!floor_bits.is_empty(), "intake needs at least one shard");
        assert!(cap >= 1, "intake needs a non-zero capacity");
        let n = floor_bits.len();
        ShardedIntake {
            shards: (0..n)
                .map(|_| Shard {
                    state: Mutex::new(ShardQ { q: VecDeque::new(), closed: false }),
                    not_full: Condvar::new(),
                })
                .collect(),
            board: Mutex::new(Board { heap: LoadHeap::new(n), tail_bits: vec![0; n] }),
            park: Mutex::new(ParkState { parked: vec![false; n], active: vec![false; n] }),
            bells: (0..n).map(|_| Condvar::new()).collect(),
            epoch: AtomicU64::new(0),
            closed: AtomicBool::new(false),
            cap,
            floor_bits,
            steal,
        }
    }

    /// Number of per-replica shards this intake was built with.
    pub fn shards(&self) -> usize {
        self.floor_bits.len()
    }

    /// Blocking bounded push onto `shard`'s tail.  Returns the item back
    /// if the intake is closed (caller decides how to answer it).
    pub fn push(&self, shard: usize, item: Item<T, R>)
                -> std::result::Result<(), Item<T, R>> {
        let shard = shard.min(self.floor_bits.len() - 1);
        let slot = &self.shards[shard];
        let mut g = lock(&slot.state);
        loop {
            if g.closed {
                return Err(item);
            }
            if g.q.len() < self.cap {
                break;
            }
            g = wait(&slot.not_full, g);
        }
        let bits = item.min_bits;
        g.q.push_back(item);
        self.board_update(shard, &g.q);
        // bump inside the critical section: close() sets this shard's
        // flag only after we release the lock, so the bump is ordered
        // before the intake reads as closed — an exiting popper either
        // saw this item or sees the epoch change and rescans (§11)
        self.epoch.fetch_add(1, Ordering::SeqCst);
        drop(g);
        self.ring_one_bell(shard, bits);
        Ok(())
    }

    /// Non-blocking push (DESIGN.md §12): same commit path as [`push`]
    /// (board update + epoch bump inside the critical section, one
    /// bell rung after), but a full shard refuses immediately instead
    /// of waiting on `not_full`.
    ///
    /// [`push`]: ShardedIntake::push
    pub fn try_push(&self, shard: usize, item: Item<T, R>)
                    -> std::result::Result<(), PushRefused<T, R>> {
        let shard = shard.min(self.floor_bits.len() - 1);
        let slot = &self.shards[shard];
        let mut g = lock(&slot.state);
        if g.closed {
            return Err(PushRefused::Closed(item));
        }
        if g.q.len() >= self.cap {
            return Err(PushRefused::Full(item));
        }
        let bits = item.min_bits;
        g.q.push_back(item);
        self.board_update(shard, &g.q);
        self.epoch.fetch_add(1, Ordering::SeqCst);
        drop(g);
        self.ring_one_bell(shard, bits);
        Ok(())
    }

    /// Bounded-wait push onto `shard`: the same commit path as
    /// [`push`], but a shard still full after `timeout` refuses with
    /// [`PushRefused::Full`] instead of waiting forever (DESIGN.md
    /// §13).  An unrepresentable deadline degrades to a plain blocking
    /// push.
    ///
    /// [`push`]: ShardedIntake::push
    pub fn push_timeout(&self, shard: usize, item: Item<T, R>, timeout: Duration)
                        -> std::result::Result<(), PushRefused<T, R>> {
        let shard = shard.min(self.floor_bits.len() - 1);
        let deadline = Instant::now().checked_add(timeout);
        let slot = &self.shards[shard];
        let mut g = lock(&slot.state);
        loop {
            if g.closed {
                return Err(PushRefused::Closed(item));
            }
            if g.q.len() < self.cap {
                break;
            }
            match deadline {
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        return Err(PushRefused::Full(item));
                    }
                    g = wait_timeout(&slot.not_full, g, d.saturating_duration_since(now)).0;
                }
                None => g = wait(&slot.not_full, g),
            }
        }
        let bits = item.min_bits;
        g.q.push_back(item);
        self.board_update(shard, &g.q);
        self.epoch.fetch_add(1, Ordering::SeqCst);
        drop(g);
        self.ring_one_bell(shard, bits);
        Ok(())
    }

    /// Close one shard only (DESIGN.md §13): its pushes start refusing
    /// while the sibling shards — and steals *from* this shard's
    /// remaining queue — keep working.  Blocked pushers wake, re-check,
    /// and get their item back.
    pub fn close_shard(&self, shard: usize) {
        let shard = shard.min(self.floor_bits.len() - 1);
        let slot = &self.shards[shard];
        let mut g = lock(&slot.state);
        g.closed = true;
        slot.not_full.notify_all();
    }

    /// Remove and return everything queued on `shard` (the §13
    /// failover drain).  The board is zeroed under the shard lock so
    /// thieves stop selecting the emptied victim immediately.
    pub fn drain_shard(&self, shard: usize) -> Vec<Item<T, R>> {
        let shard = shard.min(self.floor_bits.len() - 1);
        let slot = &self.shards[shard];
        let mut g = lock(&slot.state);
        let items: Vec<Item<T, R>> = g.q.drain(..).collect();
        self.board_update(shard, &g.q);
        drop(g);
        // freed capacity: blocked pushers wake (and re-check `closed`)
        slot.not_full.notify_all();
        items
    }

    /// Stop accepting pushes; replicas drain what is queued and then see
    /// [`Assembled::Closed`].
    pub fn close(&self) {
        // close every shard under its own lock first (serializing with
        // in-flight pushes), THEN publish the global flag poppers use
        // for their epoch-stable exit decision
        for slot in &self.shards {
            let mut g = lock(&slot.state);
            g.closed = true;
            // blocked pushers wake, re-check `closed`, and get their
            // item back
            slot.not_full.notify_all();
        }
        self.closed.store(true, Ordering::SeqCst);
        let mut p = lock(&self.park);
        for (r, bell) in self.bells.iter().enumerate() {
            if p.parked[r] {
                p.parked[r] = false;
                bell.notify_one();
            }
        }
    }

    /// Items currently queued across all shards (diagnostics; one board
    /// read instead of n queue locks).
    pub fn len(&self) -> usize {
        lock(&self.board).heap.total() as usize
    }

    /// One shard's depth off the load board (one lock, no queue walk).
    pub fn shard_len(&self, shard: usize) -> usize {
        let shard = shard.min(self.floor_bits.len() - 1);
        lock(&self.board).heap.key(shard) as usize
    }

    /// Whether every shard queue is empty right now (racy, advisory).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Assemble one batch for `shard`: block for a first item (own front
    /// first, else a sibling tail if stealing is on), then fill from the
    /// same sources until `max_batch` or the deadline.  Returns
    /// [`Assembled::Closed`] once the intake is closed and nothing this
    /// replica may serve remains.
    ///
    /// Contract: at most one concurrent `pop_batch` per shard id (the
    /// pool runs one worker per shard).  Violations are caught by a
    /// debug assertion; in release they cost latency, never items.
    pub fn pop_batch(&self, shard: usize, policy: Policy) -> Assembled<T, R> {
        let shard = shard.min(self.floor_bits.len() - 1);
        let _active = PopGuard::enter(self, shard);
        let max_batch = policy.max_batch.max(1);
        // -- first item: block until work arrives or the intake is
        //    provably drained for this replica
        let first = loop {
            let e = self.epoch.load(Ordering::SeqCst);
            if let Some(it) = self.take(shard) {
                break it;
            }
            let mut p = lock(&self.park);
            // order matters: read `closed` BEFORE re-reading the epoch.
            // close() publishes `closed` after the last possible push
            // bump, so `epoch stable ∧ closed` proves the scan above
            // saw every item this replica may serve (§11)
            let closed = self.closed.load(Ordering::SeqCst);
            if self.epoch.load(Ordering::SeqCst) != e {
                continue; // a push landed mid-scan; rescan
            }
            if closed {
                return Assembled::Closed;
            }
            p.parked[shard] = true;
            let mut p = wait(&self.bells[shard], p);
            p.parked[shard] = false;
        };
        let deadline = batch_deadline(first.req.enqueued, policy.max_wait);
        let mut batch = vec![first];
        while batch.len() < max_batch {
            let e = self.epoch.load(Ordering::SeqCst);
            if let Some(it) = self.take(shard) {
                batch.push(it);
                continue;
            }
            if self.closed.load(Ordering::SeqCst) {
                break; // flush the partial batch on close
            }
            let wait_for = match deadline {
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        break;
                    }
                    Some(d.saturating_duration_since(now))
                }
                // no finite deadline: wait until the batch fills or the
                // intake closes
                None => None,
            };
            let mut p = lock(&self.park);
            // same closed-before-epoch order as the first-item loop: a
            // close() landing after the check above would find us
            // unparked and never ring our bell — without this re-check
            // a deadline-less fill (max_wait unrepresentable) would
            // park forever instead of flushing
            let closed = self.closed.load(Ordering::SeqCst);
            if self.epoch.load(Ordering::SeqCst) != e {
                continue;
            }
            if closed {
                break; // flush the partial batch
            }
            p.parked[shard] = true;
            let mut p = match wait_for {
                Some(dur) => wait_timeout(&self.bells[shard], p, dur).0,
                None => wait(&self.bells[shard], p),
            };
            p.parked[shard] = false;
        }
        // hand the baton on: a push may have targeted its one wakeup at
        // this replica right as the deadline expired — if queued work
        // remains, ring a parked sibling so it is not delayed by a full
        // batch execution
        self.rewake(shard);
        Assembled::Batch(batch)
    }

    /// One item for `shard`: its own front, else — with stealing on —
    /// the tail of the most loaded sibling whose tail item this
    /// replica's precision floor may serve (ties → lowest index, via
    /// the load board).  Pops `notify_one` the shard's `not_full` so a
    /// blocked pusher wakes per freed slot.
    fn take(&self, shard: usize) -> Option<Item<T, R>> {
        if let Some(it) = self.take_own(shard) {
            return Some(it);
        }
        self.try_steal(shard)
    }

    fn take_own(&self, shard: usize) -> Option<Item<T, R>> {
        let slot = &self.shards[shard];
        let mut g = lock(&slot.state);
        let it = g.q.pop_front()?;
        self.board_update(shard, &g.q);
        drop(g);
        slot.not_full.notify_one();
        Some(it)
    }

    fn try_steal(&self, shard: usize) -> Option<Item<T, R>> {
        if !self.steal {
            return None;
        }
        let my_floor = self.floor_bits[shard];
        loop {
            let victim = {
                let b = lock(&self.board);
                let Board { heap, tail_bits } = &*b;
                heap.select(|s| s != shard && tail_bits[s] <= my_floor)
            };
            let v = victim?;
            let slot = &self.shards[v];
            let mut g = lock(&slot.state);
            // the board is read without the victim's lock, so re-check
            // under it; a mismatch means someone pushed/popped in
            // between — their progress, our retry
            let steal_ok = g.q.back().map_or(false, |t| t.min_bits <= my_floor);
            if !steal_ok {
                continue;
            }
            // lint:allow(no-unwrap): the steal gate just observed a Some
            // tail under this same shard lock — pop_back cannot be None
            let mut it = g.q.pop_back().expect("non-empty: tail just checked");
            self.board_update(v, &g.q);
            drop(g);
            slot.not_full.notify_one();
            it.stolen = true;
            return Some(it);
        }
    }

    /// Refresh the board for `shard` from its queue; caller holds the
    /// shard lock (lock order: shard → board, the only nesting here).
    fn board_update(&self, shard: usize, q: &VecDeque<Item<T, R>>) {
        let mut b = lock(&self.board);
        b.tail_bits[shard] = q.back().map_or(0, |t| t.min_bits);
        b.heap.update(shard, q.len() as u64);
    }

    /// Wake exactly one parked popper for a push onto `shard` carrying
    /// `bits`: the owner if parked, else one parked thief whose floor
    /// admits the item.  Nobody parked means every replica is busy and
    /// will rescan when it finishes — the item cannot be lost.
    fn ring_one_bell(&self, shard: usize, bits: u32) {
        let mut p = lock(&self.park);
        if p.parked[shard] {
            p.parked[shard] = false;
            self.bells[shard].notify_one();
            return;
        }
        if !self.steal {
            return;
        }
        for r in 0..self.floor_bits.len() {
            if r != shard && p.parked[r] && self.floor_bits[r] >= bits {
                p.parked[r] = false;
                self.bells[r].notify_one();
                return;
            }
        }
    }

    /// Best-effort baton pass after a batch returns: for every shard
    /// that still has queued work, wake its owner or one eligible
    /// parked thief.  O(shards) at batch granularity, not per item.
    fn rewake(&self, me: usize) {
        let (depths, tails): (Vec<u64>, Vec<u32>) = {
            let b = lock(&self.board);
            ((0..b.heap.len()).map(|s| b.heap.key(s)).collect(), b.tail_bits.clone())
        };
        let mut p = lock(&self.park);
        for s in 0..depths.len() {
            if depths[s] == 0 {
                continue;
            }
            if p.parked[s] {
                p.parked[s] = false;
                self.bells[s].notify_one();
                continue;
            }
            if !self.steal {
                continue;
            }
            for r in 0..self.floor_bits.len() {
                if r != s && r != me && p.parked[r] && self.floor_bits[r] >= tails[s] {
                    p.parked[r] = false;
                    self.bells[r].notify_one();
                    break;
                }
            }
        }
    }
}

impl<T: Send, R: Send> ShardedIntake<T, R> {
    /// Test hook (DESIGN.md §11 poison regression): panic a thread
    /// while it holds every intake lock in turn, poisoning them all.
    /// The pool must keep serving through `util::{lock, wait}` — a
    /// panicked worker must not wedge its siblings.
    #[doc(hidden)]
    pub fn poison_locks_for_test(&self, shard: usize) {
        std::thread::scope(|scope| {
            let h = scope.spawn(move || {
                // util::lock on not-yet-poisoned mutexes; the panic
                // below is what poisons them.  shard → board respects
                // the §11 order; park is deliberately NOT taken alone
                // here because this drill must poison all three in one
                // panic — hence the justified suppression.
                let _s = lock(&self.shards[shard].state);
                let _b = lock(&self.board);
                // lint:allow(lock-order): poison drill holds park with shard+board on purpose — one panic must poison all three locks
                let _p = lock(&self.park);
                panic!("poisoning intake locks on purpose (test)");
            });
            assert!(h.join().is_err(), "poisoner must panic");
        });
        assert!(self.shards[shard].state.is_poisoned());
        assert!(self.board.is_poisoned());
        assert!(self.park.is_poisoned());
    }
}

/// RAII guard for the one-popper-per-shard debug contract.
struct PopGuard<'a, T, R> {
    intake: &'a ShardedIntake<T, R>,
    shard: usize,
}

impl<'a, T, R> PopGuard<'a, T, R> {
    fn enter(intake: &'a ShardedIntake<T, R>, shard: usize) -> Self {
        let mut p = lock(&intake.park);
        debug_assert!(
            !p.active[shard],
            "concurrent pop_batch on shard {shard}: one popper per shard"
        );
        p.active[shard] = true;
        PopGuard { intake, shard }
    }
}

impl<T, R> Drop for PopGuard<'_, T, R> {
    fn drop(&mut self) {
        lock(&self.intake.park).active[self.shard] = false;
    }
}

impl<T: Send, R: Send> IntakeQueue<T, R> for ShardedIntake<T, R> {
    fn shards(&self) -> usize {
        ShardedIntake::shards(self)
    }

    fn push(&self, shard: usize, item: Item<T, R>)
            -> std::result::Result<(), Item<T, R>> {
        ShardedIntake::push(self, shard, item)
    }

    fn try_push(&self, shard: usize, item: Item<T, R>)
                -> std::result::Result<(), PushRefused<T, R>> {
        ShardedIntake::try_push(self, shard, item)
    }

    fn close(&self) {
        ShardedIntake::close(self)
    }

    fn close_shard(&self, shard: usize) {
        ShardedIntake::close_shard(self, shard)
    }

    fn drain_shard(&self, shard: usize) -> Vec<Item<T, R>> {
        ShardedIntake::drain_shard(self, shard)
    }

    fn push_timeout(&self, shard: usize, item: Item<T, R>, timeout: Duration)
                    -> std::result::Result<(), PushRefused<T, R>> {
        ShardedIntake::push_timeout(self, shard, item, timeout)
    }

    fn len(&self) -> usize {
        ShardedIntake::len(self)
    }

    fn shard_len(&self, shard: usize) -> usize {
        ShardedIntake::shard_len(self, shard)
    }

    fn pop_batch(&self, shard: usize, policy: Policy) -> Assembled<T, R> {
        ShardedIntake::pop_batch(self, shard, policy)
    }
}

// ---------------------------------------------------------------------
// Pre-§11 reference: one mutex, one condvar, notify_all everywhere
// ---------------------------------------------------------------------

struct Shards<T, R> {
    queues: Vec<VecDeque<Item<T, R>>>,
    closed: bool,
    /// Per-shard closure (§13 `close_shard`): pushes at a closed shard
    /// refuse while the rest keep serving.
    closed_shards: Vec<bool>,
}

/// The §10 intake, verbatim: one mutex + one shared condvar over all
/// shards, every push/pop `notify_all`.  Correct but O(threads)
/// wakeups per item — kept as the reference implementation that
/// certifies the stress harness (`rust/tests/coordinator_stress.rs`)
/// before [`ShardedIntake`] must pass it, exactly like
/// `search::reference` and `calibrate_scale_projected` anchor the §7/§8
/// rewrites (DESIGN.md §11).
pub struct CoarseIntake<T, R> {
    // lock-order: intake level 1
    state: Mutex<Shards<T, R>>,
    cv: Condvar,
    cap: usize,
    /// Per-replica precision floor (min(wbits, abits)); gates stealing.
    floor_bits: Vec<u32>,
    steal: bool,
}

impl<T, R> CoarseIntake<T, R> {
    /// Same constructor contract as [`ShardedIntake::new`].
    pub fn new(cap: usize, floor_bits: Vec<u32>, steal: bool) -> Self {
        assert!(!floor_bits.is_empty(), "intake needs at least one shard");
        assert!(cap >= 1, "intake needs a non-zero capacity");
        let queues = floor_bits.iter().map(|_| VecDeque::new()).collect();
        let closed_shards = vec![false; floor_bits.len()];
        CoarseIntake {
            state: Mutex::new(Shards { queues, closed: false, closed_shards }),
            cv: Condvar::new(),
            cap,
            floor_bits,
            steal,
        }
    }

    /// Number of per-replica shards this intake was built with.
    pub fn shards(&self) -> usize {
        self.floor_bits.len()
    }

    /// Blocking bounded push; returns the item back if closed.
    pub fn push(&self, shard: usize, item: Item<T, R>)
                -> std::result::Result<(), Item<T, R>> {
        let shard = shard.min(self.floor_bits.len() - 1);
        let mut g = lock(&self.state);
        loop {
            if g.closed || g.closed_shards[shard] {
                return Err(item);
            }
            if g.queues[shard].len() < self.cap {
                g.queues[shard].push_back(item);
                self.cv.notify_all();
                return Ok(());
            }
            g = wait(&self.cv, g);
        }
    }

    /// Non-blocking push: same single-lock body as [`push`], refusing
    /// a full shard instead of waiting.
    ///
    /// [`push`]: CoarseIntake::push
    pub fn try_push(&self, shard: usize, item: Item<T, R>)
                    -> std::result::Result<(), PushRefused<T, R>> {
        let shard = shard.min(self.floor_bits.len() - 1);
        let mut g = lock(&self.state);
        if g.closed || g.closed_shards[shard] {
            return Err(PushRefused::Closed(item));
        }
        if g.queues[shard].len() >= self.cap {
            return Err(PushRefused::Full(item));
        }
        g.queues[shard].push_back(item);
        self.cv.notify_all();
        Ok(())
    }

    /// Bounded-wait push (§13): same single-lock body as [`push`],
    /// giving up with [`PushRefused::Full`] after `timeout`.
    ///
    /// [`push`]: CoarseIntake::push
    pub fn push_timeout(&self, shard: usize, item: Item<T, R>, timeout: Duration)
                        -> std::result::Result<(), PushRefused<T, R>> {
        let shard = shard.min(self.floor_bits.len() - 1);
        let deadline = Instant::now().checked_add(timeout);
        let mut g = lock(&self.state);
        loop {
            if g.closed || g.closed_shards[shard] {
                return Err(PushRefused::Closed(item));
            }
            if g.queues[shard].len() < self.cap {
                g.queues[shard].push_back(item);
                self.cv.notify_all();
                return Ok(());
            }
            match deadline {
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        return Err(PushRefused::Full(item));
                    }
                    g = wait_timeout(&self.cv, g, d.saturating_duration_since(now)).0;
                }
                None => g = wait(&self.cv, g),
            }
        }
    }

    /// Close every shard: pushes refuse, waiters wake.
    pub fn close(&self) {
        lock(&self.state).closed = true;
        self.cv.notify_all();
    }

    /// Close one shard only (§13): its pushes refuse while siblings —
    /// and steals from its remaining queue — keep working.
    pub fn close_shard(&self, shard: usize) {
        let shard = shard.min(self.floor_bits.len() - 1);
        lock(&self.state).closed_shards[shard] = true;
        self.cv.notify_all();
    }

    /// Remove and return everything queued on `shard` (the §13
    /// failover drain).
    pub fn drain_shard(&self, shard: usize) -> Vec<Item<T, R>> {
        let shard = shard.min(self.floor_bits.len() - 1);
        let items: Vec<Item<T, R>> = lock(&self.state).queues[shard].drain(..).collect();
        self.cv.notify_all();
        items
    }

    /// Total queued items across all shards (racy, advisory).
    pub fn len(&self) -> usize {
        lock(&self.state).queues.iter().map(|q| q.len()).sum()
    }

    /// One shard's depth.
    pub fn shard_len(&self, shard: usize) -> usize {
        let shard = shard.min(self.floor_bits.len() - 1);
        lock(&self.state).queues[shard].len()
    }

    /// Whether every shard queue is empty right now (racy, advisory).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Assemble one batch for `shard` under the single global lock
    /// (the baseline [`IntakeQueue::pop_batch`] is measured against).
    pub fn pop_batch(&self, shard: usize, policy: Policy) -> Assembled<T, R> {
        let shard = shard.min(self.floor_bits.len() - 1);
        let max_batch = policy.max_batch.max(1);
        let mut g = lock(&self.state);
        let first = loop {
            if let Some(it) = self.take(&mut g, shard) {
                break it;
            }
            if g.closed {
                return Assembled::Closed;
            }
            g = wait(&self.cv, g);
        };
        let deadline = batch_deadline(first.req.enqueued, policy.max_wait);
        let mut batch = vec![first];
        while batch.len() < max_batch {
            if let Some(it) = self.take(&mut g, shard) {
                batch.push(it);
                continue;
            }
            if g.closed {
                break;
            }
            match deadline {
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        break;
                    }
                    g = wait_timeout(&self.cv, g, d.saturating_duration_since(now)).0;
                }
                None => g = wait(&self.cv, g),
            }
        }
        drop(g);
        self.cv.notify_all();
        Assembled::Batch(batch)
    }

    /// One item for `shard`: own front, else the most loaded sibling's
    /// tail (linear scan — the walk the §11 load board replaces).
    fn take(&self, g: &mut MutexGuard<'_, Shards<T, R>>, shard: usize)
            -> Option<Item<T, R>> {
        if let Some(it) = g.queues[shard].pop_front() {
            self.cv.notify_all();
            return Some(it);
        }
        if !self.steal {
            return None;
        }
        let my_floor = self.floor_bits[shard];
        let mut victim: Option<(usize, usize)> = None;
        for (i, q) in g.queues.iter().enumerate() {
            if i == shard {
                continue;
            }
            let Some(tail) = q.back() else { continue };
            if tail.min_bits > my_floor {
                continue;
            }
            if victim.map_or(true, |(_, best)| q.len() > best) {
                victim = Some((i, q.len()));
            }
        }
        let (v, _) = victim?;
        let mut it = g.queues[v].pop_back()?;
        it.stolen = true;
        self.cv.notify_all();
        Some(it)
    }
}

impl<T: Send, R: Send> IntakeQueue<T, R> for CoarseIntake<T, R> {
    fn shards(&self) -> usize {
        CoarseIntake::shards(self)
    }

    fn push(&self, shard: usize, item: Item<T, R>)
            -> std::result::Result<(), Item<T, R>> {
        CoarseIntake::push(self, shard, item)
    }

    fn try_push(&self, shard: usize, item: Item<T, R>)
                -> std::result::Result<(), PushRefused<T, R>> {
        CoarseIntake::try_push(self, shard, item)
    }

    fn close(&self) {
        CoarseIntake::close(self)
    }

    fn close_shard(&self, shard: usize) {
        CoarseIntake::close_shard(self, shard)
    }

    fn drain_shard(&self, shard: usize) -> Vec<Item<T, R>> {
        CoarseIntake::drain_shard(self, shard)
    }

    fn push_timeout(&self, shard: usize, item: Item<T, R>, timeout: Duration)
                    -> std::result::Result<(), PushRefused<T, R>> {
        CoarseIntake::push_timeout(self, shard, item, timeout)
    }

    fn len(&self) -> usize {
        CoarseIntake::len(self)
    }

    fn shard_len(&self, shard: usize) -> usize {
        CoarseIntake::shard_len(self, shard)
    }

    fn pop_batch(&self, shard: usize, policy: Policy) -> Assembled<T, R> {
        CoarseIntake::pop_batch(self, shard, policy)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The behavioral contract both intakes must satisfy — every test
    /// here runs against [`ShardedIntake`] AND [`CoarseIntake`], so the
    /// §11 rewrite cannot drift from the reference on the single-
    /// threaded interleavings (the concurrent ones live in
    /// `rust/tests/coordinator_stress.rs`).
    macro_rules! intake_contract_tests {
        ($m:ident, $I:ident) => {
            mod $m {
                use super::super::*;
                use std::sync::{mpsc, Arc};
                use std::thread;
                use std::time::{Duration, Instant};

                fn req(v: u32) -> (Request<u32, u32>, mpsc::Receiver<u32>) {
                    let (tx, rx) = mpsc::channel();
                    (Request { payload: v, enqueued: Instant::now(), respond: tx }, rx)
                }

                fn item(v: u32) -> Item<u32, u32> {
                    Item::new(req(v).0)
                }

                fn single(cap: usize) -> $I<u32, u32> {
                    $I::new(cap, vec![8], true)
                }

                fn payloads(b: &[Item<u32, u32>]) -> Vec<u32> {
                    b.iter().map(|i| i.req.payload).collect()
                }

                #[test]
                fn fills_to_max_batch_in_fifo_order() {
                    let q = single(64);
                    for i in 0..5 {
                        q.push(0, item(i)).ok().unwrap();
                    }
                    let policy = Policy { max_batch: 3, max_wait: Duration::from_secs(5) };
                    match q.pop_batch(0, policy) {
                        Assembled::Batch(b) => {
                            assert_eq!(payloads(&b), vec![0, 1, 2]);
                            assert!(b.iter().all(|i| !i.stolen));
                        }
                        _ => panic!("expected batch"),
                    }
                    assert_eq!(q.len(), 2);
                }

                #[test]
                fn deadline_flushes_partial_batch() {
                    let q = single(64);
                    q.push(0, item(7)).ok().unwrap();
                    let policy = Policy { max_batch: 32, max_wait: Duration::from_millis(10) };
                    let t0 = Instant::now();
                    match q.pop_batch(0, policy) {
                        Assembled::Batch(b) => {
                            assert_eq!(b.len(), 1);
                            assert!(t0.elapsed() < Duration::from_secs(1));
                        }
                        _ => panic!("expected batch"),
                    }
                }

                #[test]
                fn closed_intake_drains_then_reports_closed() {
                    let q = single(64);
                    q.push(0, item(1)).ok().unwrap();
                    q.close();
                    assert!(q.push(0, item(2)).is_err(), "push after close must fail");
                    match q.pop_batch(0, Policy::default()) {
                        Assembled::Batch(b) => assert_eq!(payloads(&b), vec![1]),
                        _ => panic!("expected the drain batch"),
                    }
                    assert!(matches!(q.pop_batch(0, Policy::default()), Assembled::Closed));
                }

                #[test]
                fn huge_max_wait_does_not_panic() {
                    // regression: unchecked `Instant::now() - max_wait` panics
                    // when max_wait exceeds the Instant epoch (early process
                    // life on some platforms; Duration::MAX everywhere), and
                    // `+ max_wait` can overflow — the checked-math fallback
                    // treats both as "no deadline"
                    let q = single(64);
                    q.push(0, item(1)).ok().unwrap();
                    q.push(0, item(2)).ok().unwrap();
                    let policy = Policy { max_batch: 2, max_wait: Duration::MAX };
                    match q.pop_batch(0, policy) {
                        Assembled::Batch(b) => assert_eq!(b.len(), 2),
                        _ => panic!("expected batch"),
                    }
                }

                #[test]
                fn huge_max_wait_still_flushes_when_intake_closes() {
                    let q = single(64);
                    q.push(0, item(7)).ok().unwrap();
                    q.close(); // closes with a partial batch pending
                    let policy = Policy { max_batch: 8, max_wait: Duration::MAX };
                    match q.pop_batch(0, policy) {
                        Assembled::Batch(b) => assert_eq!(b.len(), 1),
                        _ => panic!("expected batch"),
                    }
                }

                #[test]
                fn close_wakes_a_parked_deadline_less_fill() {
                    // regression (§11): a popper filling with no finite
                    // deadline (max_wait unrepresentable) parks between
                    // items; a concurrent close() must wake it and flush
                    // the partial batch, not strand it
                    let q = Arc::new(single(64));
                    q.push(0, item(1)).ok().unwrap();
                    let q2 = Arc::clone(&q);
                    let popper = thread::spawn(move || {
                        let policy = Policy { max_batch: 8, max_wait: Duration::MAX };
                        match q2.pop_batch(0, policy) {
                            Assembled::Batch(b) => payloads(&b),
                            _ => panic!("expected the flushed batch"),
                        }
                    });
                    thread::sleep(Duration::from_millis(20)); // let it park mid-fill
                    q.close();
                    assert_eq!(popper.join().unwrap(), vec![1]);
                }

                #[test]
                fn thief_takes_the_tail_owner_keeps_fifo_order() {
                    let q = $I::new(64, vec![8, 8], true);
                    for i in 0..3 {
                        q.push(0, item(i)).ok().unwrap();
                    }
                    let policy = Policy { max_batch: 1, max_wait: Duration::from_millis(1) };
                    // shard 1 is empty: it steals shard 0's *newest* item
                    match q.pop_batch(1, policy) {
                        Assembled::Batch(b) => {
                            assert_eq!(payloads(&b), vec![2]);
                            assert!(b[0].stolen);
                        }
                        _ => panic!("expected stolen batch"),
                    }
                    // the victim's remaining FIFO is untouched and in order
                    let policy = Policy { max_batch: 4, max_wait: Duration::from_millis(1) };
                    match q.pop_batch(0, policy) {
                        Assembled::Batch(b) => {
                            assert_eq!(payloads(&b), vec![0, 1]);
                            assert!(b.iter().all(|i| !i.stolen));
                        }
                        _ => panic!("expected owner batch"),
                    }
                }

                #[test]
                fn thief_fills_a_whole_batch_from_the_victim_tail() {
                    let q = $I::new(64, vec![8, 8], true);
                    for i in 0..6 {
                        q.push(0, item(i)).ok().unwrap();
                    }
                    let policy = Policy { max_batch: 4, max_wait: Duration::from_millis(1) };
                    match q.pop_batch(1, policy) {
                        Assembled::Batch(b) => {
                            // tail-first, one steal per take
                            assert_eq!(payloads(&b), vec![5, 4, 3, 2]);
                            assert!(b.iter().all(|i| i.stolen));
                        }
                        _ => panic!("expected stolen batch"),
                    }
                    assert_eq!(q.len(), 2);
                }

                #[test]
                fn thief_prefers_the_deepest_sibling_ties_to_lowest_index() {
                    let q = $I::new(64, vec![8, 8, 8, 8], true);
                    q.push(1, item(10)).ok().unwrap();
                    q.push(2, item(20)).ok().unwrap();
                    q.push(2, item(21)).ok().unwrap();
                    let policy = Policy { max_batch: 1, max_wait: Duration::from_millis(1) };
                    // shard 2 is deepest: its tail goes first
                    match q.pop_batch(0, policy) {
                        Assembled::Batch(b) => assert_eq!(payloads(&b), vec![21]),
                        _ => panic!("expected stolen batch"),
                    }
                    // now depths tie at 1: the lowest-index sibling wins
                    match q.pop_batch(0, policy) {
                        Assembled::Batch(b) => assert_eq!(payloads(&b), vec![10]),
                        _ => panic!("expected stolen batch"),
                    }
                    match q.pop_batch(0, policy) {
                        Assembled::Batch(b) => assert_eq!(payloads(&b), vec![20]),
                        _ => panic!("expected stolen batch"),
                    }
                }

                #[test]
                fn steal_respects_the_min_bits_gate() {
                    // shard 0 floors at 8 bits, shard 1 at 4
                    let q = $I::new(64, vec![8, 4], true);
                    let mut it = item(9);
                    it.min_bits = 8;
                    q.push(0, it).ok().unwrap();
                    q.close();
                    // the 4-bit replica may not steal an 8-bit-floor item…
                    assert!(matches!(q.pop_batch(1, Policy::default()), Assembled::Closed));
                    // …but the owner serves its own queue regardless of tags
                    match q.pop_batch(0, Policy::default()) {
                        Assembled::Batch(b) => assert_eq!(payloads(&b), vec![9]),
                        _ => panic!("owner must serve its own queue"),
                    }
                }

                #[test]
                fn stealing_disabled_leaves_siblings_idle() {
                    let q = $I::new(64, vec![8, 8], false);
                    q.push(0, item(1)).ok().unwrap();
                    q.close();
                    assert!(matches!(q.pop_batch(1, Policy::default()), Assembled::Closed));
                    assert!(matches!(q.pop_batch(0, Policy::default()), Assembled::Batch(_)));
                }

                #[test]
                fn bounded_push_blocks_until_a_pop_frees_space() {
                    let q = Arc::new(single(2));
                    q.push(0, item(0)).ok().unwrap();
                    q.push(0, item(1)).ok().unwrap();
                    let q2 = Arc::clone(&q);
                    let pusher = thread::spawn(move || q2.push(0, item(2)).is_ok());
                    thread::sleep(Duration::from_millis(20)); // let the pusher block
                    // regression (deadlock): with an unbounded window the
                    // assembler must wake the blocked pusher the moment a pop
                    // frees capacity, or both sides wait forever
                    let policy = Policy { max_batch: 3, max_wait: Duration::MAX };
                    match q.pop_batch(0, policy) {
                        Assembled::Batch(b) => assert_eq!(payloads(&b), vec![0, 1, 2]),
                        _ => panic!("expected batch"),
                    }
                    assert!(pusher.join().unwrap(), "blocked pusher must complete");
                }

                #[test]
                fn try_push_refuses_full_and_closed_with_the_item_back() {
                    let q = single(2);
                    assert!(q.try_push(0, item(0)).is_ok());
                    assert!(q.try_push(0, item(1)).is_ok());
                    // full: refused without blocking, item recoverable
                    match q.try_push(0, item(2)) {
                        Err(PushRefused::Full(it)) => assert_eq!(it.req.payload, 2),
                        _ => panic!("expected Full refusal"),
                    }
                    assert_eq!(q.shard_len(0), 2);
                    q.close();
                    match q.try_push(0, item(3)) {
                        Err(PushRefused::Closed(it)) => assert_eq!(it.req.payload, 3),
                        _ => panic!("expected Closed refusal"),
                    }
                    // the accepted items still drain
                    let policy = Policy { max_batch: 4, max_wait: Duration::from_millis(1) };
                    match q.pop_batch(0, policy) {
                        Assembled::Batch(b) => assert_eq!(payloads(&b), vec![0, 1]),
                        _ => panic!("expected drain batch"),
                    }
                }

                #[test]
                fn try_push_wakes_a_parked_popper_like_push() {
                    let q = Arc::new(single(4));
                    let q2 = Arc::clone(&q);
                    let popper = thread::spawn(move || {
                        match q2.pop_batch(0, Policy { max_batch: 1, max_wait: Duration::ZERO }) {
                            Assembled::Batch(b) => b[0].req.payload,
                            _ => panic!("expected batch"),
                        }
                    });
                    thread::sleep(Duration::from_millis(20)); // let it park
                    q.try_push(0, item(5)).ok().unwrap();
                    assert_eq!(popper.join().unwrap(), 5);
                }

                #[test]
                fn close_shard_refuses_locally_keeps_siblings_serving() {
                    let q = $I::new(64, vec![8, 8], true);
                    q.push(0, item(1)).ok().unwrap();
                    q.close_shard(0);
                    // the closed shard refuses both push flavors, item back
                    assert!(q.push(0, item(2)).is_err());
                    match q.try_push(0, item(3)) {
                        Err(PushRefused::Closed(it)) => assert_eq!(it.req.payload, 3),
                        _ => panic!("expected Closed refusal"),
                    }
                    match q.push_timeout(0, item(4), Duration::from_millis(5)) {
                        Err(PushRefused::Closed(it)) => assert_eq!(it.req.payload, 4),
                        _ => panic!("expected Closed refusal"),
                    }
                    // the closed shard's backlog is still stealable…
                    let policy = Policy { max_batch: 1, max_wait: Duration::from_millis(1) };
                    match q.pop_batch(1, policy) {
                        Assembled::Batch(b) => {
                            assert_eq!(b[0].req.payload, 1);
                            assert!(b[0].stolen);
                        }
                        _ => panic!("expected stolen batch"),
                    }
                    // …and the sibling shard keeps accepting and serving
                    q.push(1, item(5)).ok().unwrap();
                    match q.pop_batch(1, policy) {
                        Assembled::Batch(b) => assert_eq!(payloads(&b), vec![5]),
                        _ => panic!("expected sibling batch"),
                    }
                }

                #[test]
                fn drain_shard_empties_exactly_one_shard() {
                    let q = $I::new(64, vec![8, 8], true);
                    for i in 0..3 {
                        q.push(0, item(i)).ok().unwrap();
                    }
                    q.push(1, item(9)).ok().unwrap();
                    let drained = q.drain_shard(0);
                    assert_eq!(payloads(&drained), vec![0, 1, 2], "FIFO order preserved");
                    assert_eq!(q.shard_len(0), 0);
                    assert_eq!(q.shard_len(1), 1);
                    assert_eq!(q.len(), 1);
                    assert!(q.drain_shard(0).is_empty(), "second drain finds nothing");
                    // a drained-but-open shard accepts again
                    q.push(0, item(7)).ok().unwrap();
                    assert_eq!(q.shard_len(0), 1);
                }

                #[test]
                fn push_timeout_gives_up_on_a_full_shard_with_the_item_back() {
                    let q = single(1);
                    q.push(0, item(0)).ok().unwrap();
                    let t0 = Instant::now();
                    match q.push_timeout(0, item(1), Duration::from_millis(20)) {
                        Err(PushRefused::Full(it)) => assert_eq!(it.req.payload, 1),
                        _ => panic!("expected Full after the timeout"),
                    }
                    assert!(t0.elapsed() >= Duration::from_millis(20));
                    // with capacity, it lands on the same commit path as push
                    let policy = Policy { max_batch: 1, max_wait: Duration::from_millis(1) };
                    assert!(matches!(q.pop_batch(0, policy), Assembled::Batch(_)));
                    assert!(q.push_timeout(0, item(2), Duration::from_millis(20)).is_ok());
                    assert_eq!(q.shard_len(0), 1);
                }

                #[test]
                fn push_timeout_succeeds_when_a_pop_frees_space_in_time() {
                    let q = Arc::new(single(1));
                    q.push(0, item(0)).ok().unwrap();
                    let q2 = Arc::clone(&q);
                    let popper = thread::spawn(move || {
                        thread::sleep(Duration::from_millis(10));
                        let policy = Policy { max_batch: 1, max_wait: Duration::from_millis(1) };
                        matches!(q2.pop_batch(0, policy), Assembled::Batch(_))
                    });
                    assert!(
                        q.push_timeout(0, item(1), Duration::from_secs(5)).is_ok(),
                        "freed capacity within the wait must admit the item"
                    );
                    assert!(popper.join().unwrap());
                }

                #[test]
                fn shard_len_tracks_per_shard_depth() {
                    let q = $I::new(64, vec![8, 8], true);
                    q.push(0, item(1)).ok().unwrap();
                    q.push(0, item(2)).ok().unwrap();
                    q.push(1, item(3)).ok().unwrap();
                    assert_eq!(q.shard_len(0), 2);
                    assert_eq!(q.shard_len(1), 1);
                    let policy = Policy { max_batch: 1, max_wait: Duration::from_millis(1) };
                    assert!(matches!(q.pop_batch(0, policy), Assembled::Batch(_)));
                    assert_eq!(q.shard_len(0), 1);
                }

                #[test]
                fn late_arrivals_join_within_deadline() {
                    let q = Arc::new(single(64));
                    q.push(0, item(1)).ok().unwrap();
                    let q2 = Arc::clone(&q);
                    let h = thread::spawn(move || {
                        thread::sleep(Duration::from_millis(5));
                        q2.push(0, item(2)).ok().unwrap();
                    });
                    let policy = Policy { max_batch: 8, max_wait: Duration::from_millis(200) };
                    match q.pop_batch(0, policy) {
                        Assembled::Batch(b) => assert!(!b.is_empty()), // 2 on a fast box
                        _ => panic!(),
                    }
                    h.join().unwrap();
                }

                #[test]
                fn skewed_pushes_drain_across_thieving_consumers() {
                    let q = $I::new(64, vec![8, 8, 8], true);
                    for i in 0..9 {
                        q.push(0, item(i)).ok().unwrap();
                    }
                    q.close();
                    let policy = Policy { max_batch: 2, max_wait: Duration::from_millis(1) };
                    let mut seen = Vec::new();
                    for shard in [1, 2, 0, 1, 2, 0] {
                        if let Assembled::Batch(b) = q.pop_batch(shard, policy) {
                            seen.extend(payloads(&b));
                        }
                    }
                    seen.sort_unstable();
                    assert_eq!(seen, (0..9).collect::<Vec<_>>(), "no item lost or duplicated");
                    assert!(q.is_empty());
                }
            }
        };
    }

    intake_contract_tests!(sharded_contract, ShardedIntake);
    intake_contract_tests!(coarse_contract, CoarseIntake);

    mod sharded_only {
        use super::super::*;
        use std::sync::{mpsc, Arc};
        use std::thread;
        use std::time::{Duration, Instant};

        fn item(v: u32) -> Item<u32, u32> {
            let (tx, _rx) = mpsc::channel();
            Item::new(Request { payload: v, enqueued: Instant::now(), respond: tx })
        }

        #[test]
        fn poisoned_locks_keep_serving() {
            // regression (DESIGN.md §11): a worker that panics while
            // holding an intake lock poisons it; every later push/pop
            // must recover via util::{lock, wait} instead of cascading
            // the poison through the pool
            let q = Arc::new(ShardedIntake::<u32, u32>::new(8, vec![8, 8], true));
            q.poison_locks_for_test(0);
            q.push(0, item(1)).ok().unwrap();
            q.push(1, item(2)).ok().unwrap();
            let policy = Policy { max_batch: 2, max_wait: Duration::from_millis(1) };
            let mut seen = Vec::new();
            for shard in [0, 1] {
                if let Assembled::Batch(b) = q.pop_batch(shard, policy) {
                    seen.extend(b.iter().map(|i| i.req.payload));
                }
            }
            seen.sort_unstable();
            assert_eq!(seen, vec![1, 2], "poisoned intake must keep serving");
            q.close();
            assert!(matches!(q.pop_batch(0, policy), Assembled::Closed));
        }

        #[test]
        fn parked_owner_wakes_on_push() {
            let q = Arc::new(ShardedIntake::<u32, u32>::new(8, vec![8, 8], true));
            let q2 = Arc::clone(&q);
            let popper = thread::spawn(move || {
                match q2.pop_batch(1, Policy { max_batch: 1, max_wait: Duration::ZERO }) {
                    Assembled::Batch(b) => b[0].req.payload,
                    _ => panic!("expected batch"),
                }
            });
            thread::sleep(Duration::from_millis(20)); // let it park
            q.push(1, item(7)).ok().unwrap();
            assert_eq!(popper.join().unwrap(), 7);
        }

        #[test]
        fn parked_thief_wakes_on_sibling_push() {
            let q = Arc::new(ShardedIntake::<u32, u32>::new(8, vec![8, 8], true));
            let q2 = Arc::clone(&q);
            let thief = thread::spawn(move || {
                match q2.pop_batch(1, Policy { max_batch: 1, max_wait: Duration::ZERO }) {
                    Assembled::Batch(b) => (b[0].req.payload, b[0].stolen),
                    _ => panic!("expected batch"),
                }
            });
            thread::sleep(Duration::from_millis(20)); // let it park
            q.push(0, item(9)).ok().unwrap();
            let (v, stolen) = thief.join().unwrap();
            assert_eq!(v, 9);
            assert!(stolen);
        }

        #[test]
        fn gated_push_does_not_wake_an_ineligible_thief() {
            // a parked 4-bit thief must sleep through an 8-bit-floor push
            // it could never serve; close() is what finally wakes it
            let q = Arc::new(ShardedIntake::<u32, u32>::new(8, vec![8, 4], true));
            let q2 = Arc::clone(&q);
            let thief = thread::spawn(move || {
                matches!(q2.pop_batch(1, Policy::default()), Assembled::Closed)
            });
            thread::sleep(Duration::from_millis(20)); // let it park
            let mut it = item(3);
            it.min_bits = 8;
            q.push(0, it).ok().unwrap();
            thread::sleep(Duration::from_millis(20));
            assert_eq!(q.len(), 1, "gated item must stay queued for its owner");
            q.close();
            assert!(thief.join().unwrap(), "close must wake the gated thief");
            // the owner drains its queue regardless of tags
            match q.pop_batch(0, Policy::default()) {
                Assembled::Batch(b) => assert_eq!(b[0].req.payload, 3),
                _ => panic!("owner must drain"),
            }
        }

        #[test]
        fn concurrent_push_pop_conserves_items() {
            // a miniature of the stress suite, cheap enough for tier-1
            // unit runs: 3 shards, 3 poppers, 2 pushers, every item
            // consumed exactly once
            let q = Arc::new(ShardedIntake::<u32, u32>::new(4, vec![8, 8, 8], true));
            let total = 300u32;
            let mut handles = Vec::new();
            for p in 0..2u32 {
                let q = Arc::clone(&q);
                handles.push(thread::spawn(move || {
                    for i in 0..total / 2 {
                        let v = p * (total / 2) + i;
                        q.push((v as usize) % 3, item(v)).ok().unwrap();
                    }
                }));
            }
            let mut poppers = Vec::new();
            for shard in 0..3usize {
                let q = Arc::clone(&q);
                poppers.push(thread::spawn(move || {
                    let mut got = Vec::new();
                    let policy = Policy { max_batch: 4, max_wait: Duration::from_micros(200) };
                    loop {
                        match q.pop_batch(shard, policy) {
                            Assembled::Batch(b) => {
                                got.extend(b.iter().map(|i| i.req.payload))
                            }
                            Assembled::Closed => return got,
                        }
                    }
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
            q.close();
            let mut seen: Vec<u32> =
                poppers.into_iter().flat_map(|h| h.join().unwrap()).collect();
            seen.sort_unstable();
            assert_eq!(seen, (0..total).collect::<Vec<_>>(), "lost or duplicated items");
            assert!(q.is_empty());
        }
    }
}
