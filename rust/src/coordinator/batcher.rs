//! Dynamic batcher over per-replica intake queues with tail stealing
//! (DESIGN.md §9–§10).
//!
//! Pre-§10 the pool shared one mpsc intake behind a mutex; routing was
//! impossible (whoever locked first took the oldest request) and a
//! precision-aware scheduler had nowhere to stand.  [`ShardedIntake`]
//! gives every replica its own bounded FIFO: the [`super::Router`]
//! (DESIGN.md §10) picks the shard per request, the owning replica
//! assembles batches from its queue front under the same size+deadline
//! policy as before, and an *idle* replica steals from the tail of the
//! most loaded sibling so skewed routing cannot idle half the pool.
//!
//! Queue invariants (asserted by the tests here and in
//! `rust/tests/coordinator_routing.rs`):
//!
//! * **Owner order.**  A replica serves its own queue strictly FIFO
//!   (front pops).  Thieves take from the *tail* only, so the relative
//!   order of everything left in the victim's queue is preserved —
//!   stealing never reorders a replica's own FIFO.
//! * **Steal gate.**  An [`Item`] tagged `min_bits > 0` (accuracy-floor
//!   routing, escalation re-runs) is only stolen by replicas whose
//!   precision floor meets it.  The owner serves its queue regardless of
//!   tags — routing already honored the floor when it picked the shard.
//! * **Bounded, blocking.**  Each shard holds at most `cap` items;
//!   `push` blocks until space or the intake closes (the same
//!   backpressure the old `sync_channel` gave `submit`).  Every pop
//!   notifies, so a blocked pusher never outlives the capacity it waits
//!   for (regression test `blocked_pusher_wakes_on_pop`).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};
use std::time::Instant;

use crate::util::lock;

/// One enqueued inference request.
pub struct Request<T, R> {
    pub payload: T,
    pub enqueued: Instant,
    /// Per-request response channel (std mpsc as a oneshot).
    pub respond: std::sync::mpsc::Sender<R>,
}

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct Policy {
    pub max_batch: usize,
    pub max_wait: std::time::Duration,
}

impl Default for Policy {
    fn default() -> Self {
        Policy { max_batch: 32, max_wait: std::time::Duration::from_millis(5) }
    }
}

/// A [`Request`] plus its routing tags (DESIGN.md §10).
pub struct Item<T, R> {
    pub req: Request<T, R>,
    /// Accuracy floor: replicas with a lower precision floor may not
    /// steal this item ([`super::Router::min_bits`], escalation
    /// re-runs).  `0` = anyone.
    pub min_bits: u32,
    /// Set on escalation re-runs: reply with the result, never
    /// re-escalate (bounds every request to at most two executions).
    pub escalated: bool,
    /// Set by [`ShardedIntake::pop_batch`] when the item was taken from
    /// a sibling's tail — feeds the per-replica `stolen` counter.
    pub stolen: bool,
}

impl<T, R> Item<T, R> {
    /// An untagged item (stealable by anyone, first run).
    pub fn new(req: Request<T, R>) -> Self {
        Item { req, min_bits: 0, escalated: false, stolen: false }
    }
}

/// Outcome of one assembly round.
pub enum Assembled<T, R> {
    /// A batch ready to execute (1..=max_batch items).
    Batch(Vec<Item<T, R>>),
    /// Intake closed and fully drained — worker should exit.
    Closed,
}

struct Shards<T, R> {
    queues: Vec<VecDeque<Item<T, R>>>,
    closed: bool,
}

/// Per-replica bounded FIFO queues with tail stealing (DESIGN.md §10).
///
/// One mutex + condvar pair guards all shards: assembly holds the lock
/// for pointer moves only (execution happens outside), and a shared
/// condvar is what lets an idle replica wake on a *sibling's* push —
/// per-shard condvars would strand thieves.  Pushers and poppers share
/// the condvar too, so every state change `notify_all`s.
pub struct ShardedIntake<T, R> {
    state: Mutex<Shards<T, R>>,
    cv: Condvar,
    cap: usize,
    /// Per-replica precision floor (min(wbits, abits)); gates stealing.
    floor_bits: Vec<u32>,
    steal: bool,
}

impl<T, R> ShardedIntake<T, R> {
    /// `floor_bits` has one entry per shard/replica; `cap` bounds each
    /// shard; `steal` enables tail stealing between shards.
    pub fn new(cap: usize, floor_bits: Vec<u32>, steal: bool) -> Self {
        assert!(!floor_bits.is_empty(), "intake needs at least one shard");
        assert!(cap >= 1, "intake needs a non-zero capacity");
        let queues = floor_bits.iter().map(|_| VecDeque::new()).collect();
        ShardedIntake {
            state: Mutex::new(Shards { queues, closed: false }),
            cv: Condvar::new(),
            cap,
            floor_bits,
            steal,
        }
    }

    pub fn shards(&self) -> usize {
        self.floor_bits.len()
    }

    /// Blocking bounded push onto `shard`'s tail.  Returns the item back
    /// if the intake is closed (caller decides how to answer it).
    pub fn push(&self, shard: usize, item: Item<T, R>)
                -> std::result::Result<(), Item<T, R>> {
        let shard = shard.min(self.floor_bits.len() - 1);
        let mut g = lock(&self.state);
        loop {
            if g.closed {
                return Err(item);
            }
            if g.queues[shard].len() < self.cap {
                g.queues[shard].push_back(item);
                self.cv.notify_all();
                return Ok(());
            }
            g = self.cv.wait(g).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Stop accepting pushes; replicas drain what is queued and then see
    /// [`Assembled::Closed`].
    pub fn close(&self) {
        lock(&self.state).closed = true;
        self.cv.notify_all();
    }

    /// Items currently queued across all shards (diagnostics).
    pub fn len(&self) -> usize {
        lock(&self.state).queues.iter().map(|q| q.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Assemble one batch for `shard`: block for a first item (own front
    /// first, else a sibling tail if stealing is on), then fill from the
    /// same sources until `max_batch` or the deadline.  Returns
    /// [`Assembled::Closed`] once the intake is closed and nothing this
    /// replica may serve remains.
    pub fn pop_batch(&self, shard: usize, policy: Policy) -> Assembled<T, R> {
        let shard = shard.min(self.floor_bits.len() - 1);
        let max_batch = policy.max_batch.max(1);
        let mut g = lock(&self.state);
        let first = loop {
            if let Some(it) = self.take(&mut g, shard) {
                break it;
            }
            if g.closed {
                return Assembled::Closed;
            }
            g = self.cv.wait(g).unwrap_or_else(PoisonError::into_inner);
        };
        // Window end: effectively (enqueued ⌄ (now − max_wait)) + max_wait.
        // `Instant::now() - max_wait` can panic early in process life on
        // platforms where Instant's epoch is process start (and everywhere
        // for huge waits like Duration::MAX), and `+ max_wait` can
        // overflow Instant's range — checked arithmetic with safe
        // fallbacks instead: an unrepresentable deadline means "no
        // deadline" (regression tests below).
        let anchor = match Instant::now().checked_sub(policy.max_wait) {
            Some(floor) => first.req.enqueued.max(floor),
            None => first.req.enqueued,
        };
        let deadline = anchor.checked_add(policy.max_wait);
        let mut batch = vec![first];
        while batch.len() < max_batch {
            if let Some(it) = self.take(&mut g, shard) {
                batch.push(it);
                continue;
            }
            if g.closed {
                break;
            }
            match deadline {
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        break;
                    }
                    g = self
                        .cv
                        .wait_timeout(g, d - now)
                        .unwrap_or_else(PoisonError::into_inner)
                        .0;
                }
                // no finite deadline: wait until the batch fills or the
                // intake closes
                None => g = self.cv.wait(g).unwrap_or_else(PoisonError::into_inner),
            }
        }
        drop(g);
        self.cv.notify_all();
        Assembled::Batch(batch)
    }

    /// One item for `shard`: its own front, else — with stealing on —
    /// the tail of the most loaded sibling whose tail item this
    /// replica's precision floor may serve (ties → lowest index).
    /// Notifies on success so a pusher blocked on the freed capacity
    /// wakes even while this replica keeps assembling.
    fn take(&self, g: &mut MutexGuard<'_, Shards<T, R>>, shard: usize)
            -> Option<Item<T, R>> {
        if let Some(it) = g.queues[shard].pop_front() {
            self.cv.notify_all();
            return Some(it);
        }
        if !self.steal {
            return None;
        }
        let my_floor = self.floor_bits[shard];
        let mut victim: Option<(usize, usize)> = None;
        for (i, q) in g.queues.iter().enumerate() {
            if i == shard {
                continue;
            }
            let Some(tail) = q.back() else { continue };
            if tail.min_bits > my_floor {
                continue;
            }
            if victim.map_or(true, |(_, best)| q.len() > best) {
                victim = Some((i, q.len()));
            }
        }
        let (v, _) = victim?;
        let mut it = g.queues[v].pop_back()?;
        it.stolen = true;
        self.cv.notify_all();
        Some(it)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::thread;
    use std::time::Duration;

    fn req(v: u32) -> (Request<u32, u32>, mpsc::Receiver<u32>) {
        let (tx, rx) = mpsc::channel();
        (Request { payload: v, enqueued: Instant::now(), respond: tx }, rx)
    }

    fn item(v: u32) -> Item<u32, u32> {
        Item::new(req(v).0)
    }

    fn single(cap: usize) -> ShardedIntake<u32, u32> {
        ShardedIntake::new(cap, vec![8], true)
    }

    fn payloads(b: &[Item<u32, u32>]) -> Vec<u32> {
        b.iter().map(|i| i.req.payload).collect()
    }

    #[test]
    fn fills_to_max_batch_in_fifo_order() {
        let q = single(64);
        for i in 0..5 {
            q.push(0, item(i)).ok().unwrap();
        }
        let policy = Policy { max_batch: 3, max_wait: Duration::from_secs(5) };
        match q.pop_batch(0, policy) {
            Assembled::Batch(b) => {
                assert_eq!(payloads(&b), vec![0, 1, 2]);
                assert!(b.iter().all(|i| !i.stolen));
            }
            _ => panic!("expected batch"),
        }
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let q = single(64);
        q.push(0, item(7)).ok().unwrap();
        let policy = Policy { max_batch: 32, max_wait: Duration::from_millis(10) };
        let t0 = Instant::now();
        match q.pop_batch(0, policy) {
            Assembled::Batch(b) => {
                assert_eq!(b.len(), 1);
                assert!(t0.elapsed() < Duration::from_secs(1));
            }
            _ => panic!("expected batch"),
        }
    }

    #[test]
    fn closed_intake_drains_then_reports_closed() {
        let q = single(64);
        q.push(0, item(1)).ok().unwrap();
        q.close();
        assert!(q.push(0, item(2)).is_err(), "push after close must fail");
        match q.pop_batch(0, Policy::default()) {
            Assembled::Batch(b) => assert_eq!(payloads(&b), vec![1]),
            _ => panic!("expected the drain batch"),
        }
        assert!(matches!(q.pop_batch(0, Policy::default()), Assembled::Closed));
    }

    #[test]
    fn huge_max_wait_does_not_panic() {
        // regression: unchecked `Instant::now() - max_wait` panics when
        // max_wait exceeds the Instant epoch (early process life on some
        // platforms; Duration::MAX everywhere), and `+ max_wait` can
        // overflow — the checked-math fallback treats both as "no
        // deadline"
        let q = single(64);
        q.push(0, item(1)).ok().unwrap();
        q.push(0, item(2)).ok().unwrap();
        let policy = Policy { max_batch: 2, max_wait: Duration::MAX };
        match q.pop_batch(0, policy) {
            Assembled::Batch(b) => assert_eq!(b.len(), 2),
            _ => panic!("expected batch"),
        }
    }

    #[test]
    fn huge_max_wait_still_flushes_when_intake_closes() {
        let q = single(64);
        q.push(0, item(7)).ok().unwrap();
        q.close(); // closes with a partial batch pending
        let policy = Policy { max_batch: 8, max_wait: Duration::MAX };
        match q.pop_batch(0, policy) {
            Assembled::Batch(b) => assert_eq!(b.len(), 1),
            _ => panic!("expected batch"),
        }
    }

    #[test]
    fn thief_takes_the_tail_owner_keeps_fifo_order() {
        let q = ShardedIntake::new(64, vec![8, 8], true);
        for i in 0..3 {
            q.push(0, item(i)).ok().unwrap();
        }
        let policy = Policy { max_batch: 1, max_wait: Duration::from_millis(1) };
        // shard 1 is empty: it steals shard 0's *newest* item
        match q.pop_batch(1, policy) {
            Assembled::Batch(b) => {
                assert_eq!(payloads(&b), vec![2]);
                assert!(b[0].stolen);
            }
            _ => panic!("expected stolen batch"),
        }
        // the victim's remaining FIFO is untouched and in order
        let policy = Policy { max_batch: 4, max_wait: Duration::from_millis(1) };
        match q.pop_batch(0, policy) {
            Assembled::Batch(b) => {
                assert_eq!(payloads(&b), vec![0, 1]);
                assert!(b.iter().all(|i| !i.stolen));
            }
            _ => panic!("expected owner batch"),
        }
    }

    #[test]
    fn thief_fills_a_whole_batch_from_the_victim_tail() {
        let q = ShardedIntake::new(64, vec![8, 8], true);
        for i in 0..6 {
            q.push(0, item(i)).ok().unwrap();
        }
        let policy = Policy { max_batch: 4, max_wait: Duration::from_millis(1) };
        match q.pop_batch(1, policy) {
            Assembled::Batch(b) => {
                // tail-first, one steal per take
                assert_eq!(payloads(&b), vec![5, 4, 3, 2]);
                assert!(b.iter().all(|i| i.stolen));
            }
            _ => panic!("expected stolen batch"),
        }
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn steal_respects_the_min_bits_gate() {
        // shard 0 floors at 8 bits, shard 1 at 4
        let q = ShardedIntake::new(64, vec![8, 4], true);
        let mut it = item(9);
        it.min_bits = 8;
        q.push(0, it).ok().unwrap();
        q.close();
        // the 4-bit replica may not steal an 8-bit-floor item…
        assert!(matches!(q.pop_batch(1, Policy::default()), Assembled::Closed));
        // …but the owner serves its own queue regardless of tags
        match q.pop_batch(0, Policy::default()) {
            Assembled::Batch(b) => assert_eq!(payloads(&b), vec![9]),
            _ => panic!("owner must serve its own queue"),
        }
    }

    #[test]
    fn stealing_disabled_leaves_siblings_idle() {
        let q = ShardedIntake::new(64, vec![8, 8], false);
        q.push(0, item(1)).ok().unwrap();
        q.close();
        assert!(matches!(q.pop_batch(1, Policy::default()), Assembled::Closed));
        assert!(matches!(q.pop_batch(0, Policy::default()), Assembled::Batch(_)));
    }

    #[test]
    fn bounded_push_blocks_until_a_pop_frees_space() {
        let q = std::sync::Arc::new(single(2));
        q.push(0, item(0)).ok().unwrap();
        q.push(0, item(1)).ok().unwrap();
        let q2 = std::sync::Arc::clone(&q);
        let pusher = thread::spawn(move || q2.push(0, item(2)).is_ok());
        thread::sleep(Duration::from_millis(20)); // let the pusher block
        // regression (deadlock): with an unbounded window the assembler
        // must wake the blocked pusher the moment a pop frees capacity,
        // or both sides wait on the same condvar forever
        let policy = Policy { max_batch: 3, max_wait: Duration::MAX };
        match q.pop_batch(0, policy) {
            Assembled::Batch(b) => assert_eq!(payloads(&b), vec![0, 1, 2]),
            _ => panic!("expected batch"),
        }
        assert!(pusher.join().unwrap(), "blocked pusher must complete");
    }

    #[test]
    fn late_arrivals_join_within_deadline() {
        let q = std::sync::Arc::new(single(64));
        q.push(0, item(1)).ok().unwrap();
        let q2 = std::sync::Arc::clone(&q);
        let h = thread::spawn(move || {
            thread::sleep(Duration::from_millis(5));
            q2.push(0, item(2)).ok().unwrap();
        });
        let policy = Policy { max_batch: 8, max_wait: Duration::from_millis(200) };
        match q.pop_batch(0, policy) {
            Assembled::Batch(b) => assert!(!b.is_empty()), // 2 on a fast box
            _ => panic!(),
        }
        h.join().unwrap();
    }

    #[test]
    fn skewed_pushes_drain_across_thieving_consumers() {
        let q = ShardedIntake::new(64, vec![8, 8, 8], true);
        for i in 0..9 {
            q.push(0, item(i)).ok().unwrap();
        }
        q.close();
        let policy = Policy { max_batch: 2, max_wait: Duration::from_millis(1) };
        let mut seen = Vec::new();
        for shard in [1, 2, 0, 1, 2, 0] {
            if let Assembled::Batch(b) = q.pop_batch(shard, policy) {
                seen.extend(payloads(&b));
            }
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..9).collect::<Vec<_>>(), "no item lost or duplicated");
        assert!(q.is_empty());
    }
}
