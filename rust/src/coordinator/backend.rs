//! Pluggable inference backends for the serving pool (DESIGN.md §9).
//!
//! The worker's execute step — pad / forward / argmax over a compiled
//! model — is abstracted behind [`InferenceBackend`] so the coordinator
//! no longer hard-codes the PJRT artifact path.  Two implementations:
//!
//! * [`PjrtBackend`] — the original deployment shape: a `qat::Session` +
//!   `runtime::Executor` pair executing the AOT-compiled fwd HLO.  Needs
//!   built artifacts and a real PJRT runtime.
//! * [`SimBackend`] — a deterministic stand-in that costs each batch with
//!   the cycle-accurate [`crate::sim::Simulator`] (scaled into wall time)
//!   and scores it with a seeded linear projection, so the whole serving
//!   stack is buildable, testable, and benchable with **no artifacts**.
//! * [`BitplaneBackend`] — the §15 nested-precision variant of the
//!   simulator backend: the same seeded scorer, stored as MSB-first
//!   bitplane contributions, answering at precision `p` by accumulating
//!   the top `p` planes at `p/8` of the full-precision cycle cost — and
//!   completing a sibling's cached partial sums
//!   ([`InferenceBackend::refine`]) for the cost of the residual planes
//!   only.
//!
//! Backends are constructed *on the replica's own worker thread* through
//! a factory closure ([`BackendFactory`]): PJRT handles must not cross
//! threads, and the factory pattern preserves that invariant for every
//! backend while letting [`super::Server`] own N independent replicas.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, bail, ensure, Result};

use crate::qat::{QuantConfig, Session};
use crate::runtime::{Executor, Manifest};
use crate::sim::{cell_cycles, HwConfig, LayerShape, Prec, Simulator};
use crate::tensor::Tensor;
use crate::util::lock;
use crate::util::rng::Rng;

use super::router::ReplicaPrecision;

/// One replica's model executor: takes a padded `[batch, img_elems]`
/// input tensor, returns `[batch, classes]` logits.  The worker loop
/// (pad → forward → argmax → reply) lives in [`super::Server`]; a
/// backend only supplies the forward pass and its static geometry.
pub trait InferenceBackend {
    /// Human-readable backend name (logs, error messages).
    fn name(&self) -> &str;
    /// Static batch dimension of the compiled/simulated model.
    fn batch(&self) -> usize;
    /// Flattened elements per image.
    fn img_elems(&self) -> usize;
    /// Forward a padded `[batch, img_elems]` batch to `[batch, classes]`
    /// logits.  Takes the tensor by value (the worker builds a fresh one
    /// per chunk, so backends can reshape without copying).  An `Err`
    /// fails the whole batch (every request in it gets an error reply);
    /// it must not kill the replica.
    fn forward(&mut self, x: Tensor) -> Result<Tensor>;
    /// `true` when the backend has failed permanently and the worker
    /// should exit *between* batches (after the current batch's replies
    /// are sent) so the supervisor can respawn it (DESIGN.md §13).  The
    /// default — a healthy backend — never trips.
    fn fatal(&self) -> bool {
        false
    }
    /// Number of weight bitplanes this backend's scorer decomposes into,
    /// `0` (the default) for backends that cannot refine.  The pool only
    /// attempts §15 partial-sum refinement on backends reporting a
    /// non-zero depth; everything else keeps the §10 full re-run on
    /// escalation.
    fn planes(&self) -> u32 {
        0
    }
    /// Per-row partial sums of the most recent successful
    /// [`InferenceBackend::forward`], for caching low-margin replies
    /// (DESIGN.md §15).  Taking them transfers ownership — a second call
    /// before the next forward returns `None`, as does any backend that
    /// does not decompose into planes (the default).
    fn take_partials(&mut self) -> Option<Vec<PlanePartial>> {
        None
    }
    /// Complete each cached partial to this backend's full plane depth
    /// and return `[partials.len(), classes]` logits, bit-identical to a
    /// full-precision forward of the same rows, for the cost of the
    /// residual planes only (DESIGN.md §15).  `None` (the default) means
    /// the backend cannot refine and the caller must fall back to a full
    /// re-run.
    fn refine(&mut self, partials: &[PlanePartial]) -> Option<Result<Tensor>> {
        let _ = partials;
        None
    }
}

/// Constructs one backend per replica, invoked with the replica id on
/// that replica's own thread (PJRT handles are not shared across
/// threads; `Send`/`Sync` is required of the *factory*, not the
/// backend).
pub type BackendFactory =
    Arc<dyn Fn(usize) -> Result<Box<dyn InferenceBackend>> + Send + Sync>;

// ---------------------------------------------------------------------------
// PJRT-artifact backend
// ---------------------------------------------------------------------------

/// The artifact-backed backend: `Session` + `Executor` executing the
/// quantized fwd HLO, exactly the worker preamble the pre-§9 server
/// inlined.
pub struct PjrtBackend {
    exec: Executor,
    session: Session,
    qcfg: QuantConfig,
    pallas: bool,
    batch: usize,
    img_elems: usize,
    input_shape: Vec<usize>,
}

impl PjrtBackend {
    /// Build and warm one backend: creates the PJRT client, loads the
    /// model's parameters, and compiles the fwd artifact so the first
    /// request isn't a stall.  Every failure here is a *startup* error —
    /// the server surfaces it from `Server::start` via the readiness
    /// handshake (DESIGN.md §9).
    pub fn new(manifest: &Manifest, model: &str, qcfg: QuantConfig,
               pallas: bool) -> Result<Self> {
        let entry = manifest.model(model)?;
        let batch = entry.batch;
        let input_shape = entry.input.clone();
        let img_elems: usize = input_shape.iter().skip(1).product();
        ensure!(batch >= 1, "{model}: batch dim must be >= 1");
        ensure!(img_elems >= 1, "{model}: empty input shape");
        let mut exec = Executor::new(&manifest.dir)?;
        let session = Session::new(manifest, model)?;
        let tag = if pallas { "fwd_pallas" } else { "fwd" };
        let art = session.model.artifact(tag)?.file.clone();
        exec.load(&art)?;
        Ok(PjrtBackend { exec, session, qcfg, pallas, batch, img_elems, input_shape })
    }

    /// A [`BackendFactory`] giving each replica its own client/session
    /// over a shared manifest.
    pub fn factory(manifest: Manifest, model: String, qcfg: QuantConfig,
                   pallas: bool) -> BackendFactory {
        Arc::new(move |_replica| {
            Ok(Box::new(PjrtBackend::new(&manifest, &model, qcfg.clone(), pallas)?)
                as Box<dyn InferenceBackend>)
        })
    }
}

impl InferenceBackend for PjrtBackend {
    fn name(&self) -> &str {
        "pjrt"
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn img_elems(&self) -> usize {
        self.img_elems
    }

    fn forward(&mut self, x: Tensor) -> Result<Tensor> {
        // the worker pads to [batch, img_elems]; the HLO wants the
        // model's full input shape (e.g. NHWC) — reshape in place
        let x = x.reshape(self.input_shape.clone())?;
        self.session.forward(&mut self.exec, &self.qcfg, &x, self.pallas)
    }
}

// ---------------------------------------------------------------------------
// Simulator-costed deterministic backend
// ---------------------------------------------------------------------------

/// Configuration of a [`SimBackend`].
#[derive(Clone, Debug)]
pub struct SimBackendCfg {
    /// Layer stack fed to the cycle-accurate simulator (e.g.
    /// [`crate::models::synthetic_resnet`]).
    pub layers: Vec<LayerShape>,
    /// Static batch dimension (the simulator's M scales with it).
    pub batch: usize,
    /// Flattened elements per image.
    pub img_elems: usize,
    /// Number of output classes.
    pub classes: usize,
    /// Uniform weight/activation bitwidths for the cycle cost (2/4/8).
    pub wbits: u32,
    /// See `wbits`.
    pub abits: u32,
    /// Seed of the linear scorer; equal seeds ⇒ bit-identical logits,
    /// so every replica of a pool answers identically.
    pub seed: u64,
    /// Wall-seconds slept per simulated second: each `forward` sleeps
    /// `sim_latency × time_scale`.  `0.0` disables sleeping (unit
    /// tests); benches pick a scale that makes a batch a few ms so
    /// replica scaling is measurable.
    pub time_scale: f64,
    /// Fault injection: if any input element is bit-equal to this
    /// sentinel, `forward` fails the whole batch.  Lets tests and
    /// benches exercise the coordinator's error path deterministically.
    pub fail_on: Option<f32>,
}

impl SimBackendCfg {
    /// A small artifact-free serving model: 6-layer synthetic ResNet
    /// geometry, batch 4, 64-element images, 10 classes, no sleeping.
    pub fn tiny(seed: u64) -> Self {
        SimBackendCfg {
            layers: crate::models::synthetic_resnet(4),
            batch: 4,
            img_elems: 64,
            classes: 10,
            wbits: 4,
            abits: 8,
            seed,
            time_scale: 0.0,
            fail_on: None,
        }
    }

    /// Projected wall cost of one batch at precision `p`: the §3
    /// cycle-accurate simulator's latency for this layer stack at
    /// `(p.wbits, p.abits)`, scaled by `time_scale` — the same per-batch
    /// cycle estimate the §7 cost table is built from, here feeding the
    /// §12 admission layer's queue-delay projection.  Runs only the
    /// simulator (no scorer weights), so probing a pool mix is cheap.
    pub fn projected_batch_cost(&self, p: ReplicaPrecision) -> Result<Duration> {
        let pw = Prec::from_bits(p.wbits)
            .ok_or_else(|| anyhow!("batch cost: wbits must be 2/4/8, got {}", p.wbits))?;
        let pa = Prec::from_bits(p.abits)
            .ok_or_else(|| anyhow!("batch cost: abits must be 2/4/8, got {}", p.abits))?;
        ensure!(!self.layers.is_empty(), "batch cost: empty layer stack");
        ensure!(
            self.time_scale.is_finite() && self.time_scale >= 0.0,
            "batch cost: time_scale must be finite and >= 0"
        );
        let mut sim = Simulator::new(HwConfig::zcu102(), self.layers.clone(), self.batch.max(1));
        let assign = vec![(pw, pa); sim.layers.len()];
        Ok(Duration::from_secs_f64(sim.run(&assign).latency_s * self.time_scale))
    }

    /// Per-replica batch-cost projections for a pool mix — the seed for
    /// `AdmissionCfg::batch_cost` (replica `i` at `mix[i]`'s precision,
    /// matching [`SimBackend::mixed_factory`]'s assignment).
    pub fn projected_batch_costs(&self, mix: &[ReplicaPrecision]) -> Result<Vec<Duration>> {
        mix.iter().map(|&p| self.projected_batch_cost(p)).collect()
    }
}

// ---------------------------------------------------------------------------
// Nested integer scorer (DESIGN.md §15)
// ---------------------------------------------------------------------------

/// Bitplane depth of the nested scorer: an 8-bit sign-magnitude weight
/// grid whose top-`p` planes are exactly a native `p`-bit quantization
/// (DQT-style nesting), so partial accumulations are reusable across
/// precisions.
pub const SCORER_PLANES: u32 = 8;

/// The shared seeded scorer behind [`SimBackend`] and
/// [`BitplaneBackend`]: the §9 random linear projection, quantized once
/// to 8-bit integers.  Every dot product is exact integer arithmetic in
/// `i64` (the only rounding is one deterministic `i64 → f32` cast at
/// dequantization), so plane-accumulated, refined, and direct answers
/// are bit-identical — the property the §15 tests certify.
struct NestedScorer {
    classes: usize,
    img_elems: usize,
    /// `classes × img_elems` signed 8-bit weights, row-major.
    w_int: Vec<i8>,
    /// Dequantization scale: `w ≈ w_int · w_scale`.
    w_scale: f32,
}

impl NestedScorer {
    /// Quantize the same seeded stream the pre-§15 float scorer drew,
    /// so replica answers stay a pure function of `(seed, payload)`.
    fn new(classes: usize, img_elems: usize, seed: u64) -> Self {
        // ~unit-variance logits regardless of img_elems
        let mut rng = Rng::new(seed);
        let norm = 1.0 / (img_elems as f32).sqrt();
        let w: Vec<f32> =
            (0..classes * img_elems).map(|_| rng.normal() as f32 * norm).collect();
        let w_max = w.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let w_scale = if w_max > 0.0 { w_max / 127.0 } else { 0.0 };
        let w_int = w.iter().map(|&v| quant_i8(v, w_scale)).collect();
        NestedScorer { classes, img_elems, w_int, w_scale }
    }

    /// Symmetric per-row activation quantization (`|a_int| ≤ 127`).
    fn quantize_row(&self, row: &[f32]) -> (Vec<i8>, f32) {
        let a_max = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let a_scale = if a_max > 0.0 && a_max.is_finite() { a_max / 127.0 } else { 0.0 };
        (row.iter().map(|&v| quant_i8(v, a_scale)).collect(), a_scale)
    }

    /// Integer dot of a quantized row against class `k`'s weights
    /// truncated to their top `bits` planes (`SCORER_PLANES` = the full
    /// grid).
    fn dot_truncated(&self, a: &[i8], k: usize, bits: u32) -> i64 {
        let w = &self.w_int[k * self.img_elems..(k + 1) * self.img_elems];
        a.iter()
            .zip(w)
            .map(|(&a, &w)| a as i64 * truncate_msb(w, bits) as i64)
            .sum()
    }

    /// Dequantize an integer dot into a logit.  Forward, plane
    /// accumulation, and refinement all funnel through this one
    /// expression, so equal dots give bit-equal logits everywhere.
    fn logit(&self, dot: i64, a_scale: f32) -> f32 {
        (self.w_scale * a_scale) * dot as f32
    }
}

/// Round-to-nearest symmetric quantization to `[-127, 127]`.  NaN maps
/// to 0 (the saturating cast), keeping malformed payloads deterministic.
fn quant_i8(v: f32, scale: f32) -> i8 {
    if scale <= 0.0 || !scale.is_finite() {
        return 0;
    }
    (v / scale).round().clamp(-127.0, 127.0) as i8
}

/// Keep the top `bits` magnitude planes of an 8-bit sign-magnitude
/// value: `q_p(n) = sign(n)·((|n| >> (8−p)) << (8−p))`, `q_0 = 0`.
/// Nesting is exact — `q_p` is a bit-prefix of `q_{p+1}`, so the plane
/// contributions `q_p − q_{p−1}` telescope back to the full value.
fn truncate_msb(n: i8, bits: u32) -> i32 {
    if bits == 0 {
        return 0;
    }
    let shift = SCORER_PLANES.saturating_sub(bits.min(SCORER_PLANES));
    let mag = ((n as i32).abs() >> shift) << shift;
    if n < 0 {
        -mag
    } else {
        mag
    }
}

/// Deterministic simulator-costed backend (DESIGN.md §9): latency from
/// the cycle-accurate ZCU102 model at the configured uniform precision,
/// logits from a seeded (integer-quantized, §15) random linear
/// projection of the input.
pub struct SimBackend {
    cfg: SimBackendCfg,
    /// Seeded integer scorer, always evaluated at full depth — the
    /// configured precision affects the cycle cost only, so every tier
    /// of a shared-seed pool answers identically (DESIGN.md §10).
    scorer: NestedScorer,
    /// Wall-clock cost per batch (already `time_scale`-d).
    cost: Duration,
    /// Unscaled simulated latency of one batch, for reporting.
    sim_latency_s: f64,
}

impl SimBackend {
    /// Build a simulator backend from `cfg` (validates shapes and
    /// pre-computes the per-batch cost model).
    pub fn new(cfg: SimBackendCfg) -> Result<Self> {
        ensure!(cfg.batch >= 1, "sim backend: batch must be >= 1");
        ensure!(cfg.img_elems >= 1, "sim backend: img_elems must be >= 1");
        ensure!(cfg.classes >= 1, "sim backend: classes must be >= 1");
        ensure!(!cfg.layers.is_empty(), "sim backend: empty layer stack");
        ensure!(
            cfg.time_scale.is_finite() && cfg.time_scale >= 0.0,
            "sim backend: time_scale must be finite and >= 0"
        );
        let pw = Prec::from_bits(cfg.wbits)
            .ok_or_else(|| anyhow!("sim backend: wbits must be 2/4/8, got {}", cfg.wbits))?;
        let pa = Prec::from_bits(cfg.abits)
            .ok_or_else(|| anyhow!("sim backend: abits must be 2/4/8, got {}", cfg.abits))?;
        let mut sim = Simulator::new(HwConfig::zcu102(), cfg.layers.clone(), cfg.batch);
        let assign = vec![(pw, pa); sim.layers.len()];
        let sim_latency_s = sim.run(&assign).latency_s;
        let cost = Duration::from_secs_f64(sim_latency_s * cfg.time_scale);
        let scorer = NestedScorer::new(cfg.classes, cfg.img_elems, cfg.seed);
        Ok(SimBackend { cfg, scorer, cost, sim_latency_s })
    }

    /// A [`BackendFactory`] whose replicas share one config (and thus
    /// one scorer seed — all replicas answer identically).
    pub fn factory(cfg: SimBackendCfg) -> BackendFactory {
        Arc::new(move |_replica| {
            Ok(Box::new(SimBackend::new(cfg.clone())?) as Box<dyn InferenceBackend>)
        })
    }

    /// A heterogeneous-pool [`BackendFactory`] (DESIGN.md §10): replica
    /// `i` runs `base` at `mix[i]`'s bitwidths, so its batch cost is the
    /// §3 simulator's cycle count *at that precision* — a DyBit-4
    /// replica really is ~2.6× faster per batch than an 8-bit one on the
    /// ResNet-like stack, making routing effects measurable with no
    /// artifacts.  The scorer seed stays shared, so every replica (fast
    /// or accurate) answers a given payload identically; SimBackend
    /// models the *latency* side of precision — the accuracy side is the
    /// paper's Fig. 6, not simulated.
    pub fn mixed_factory(base: SimBackendCfg, mix: Vec<ReplicaPrecision>) -> BackendFactory {
        Arc::new(move |replica| {
            let p = match mix.is_empty() {
                true => ReplicaPrecision::default(),
                false => mix[replica % mix.len()],
            };
            let cfg = SimBackendCfg { wbits: p.wbits, abits: p.abits, ..base.clone() };
            Ok(Box::new(SimBackend::new(cfg)?) as Box<dyn InferenceBackend>)
        })
    }

    /// Simulated (unscaled) latency of one batch in seconds.
    pub fn sim_latency_s(&self) -> f64 {
        self.sim_latency_s
    }

    /// Wall-clock sleep applied per batch after `time_scale`.
    pub fn batch_cost(&self) -> Duration {
        self.cost
    }
}

impl InferenceBackend for SimBackend {
    fn name(&self) -> &str {
        "sim"
    }

    fn batch(&self) -> usize {
        self.cfg.batch
    }

    fn img_elems(&self) -> usize {
        self.cfg.img_elems
    }

    fn forward(&mut self, x: Tensor) -> Result<Tensor> {
        ensure!(
            x.shape == [self.cfg.batch, self.cfg.img_elems],
            "sim backend: input shape {:?}, want [{}, {}]",
            x.shape,
            self.cfg.batch,
            self.cfg.img_elems
        );
        if let Some(s) = self.cfg.fail_on {
            if x.data.iter().any(|v| v.to_bits() == s.to_bits()) {
                bail!("sim backend: injected failure (sentinel {s} in batch)");
            }
        }
        if !self.cost.is_zero() {
            std::thread::sleep(self.cost);
        }
        let (b, d, c) = (self.cfg.batch, self.cfg.img_elems, self.cfg.classes);
        let mut logits = vec![0.0f32; b * c];
        for r in 0..b {
            let (a_int, a_scale) = self.scorer.quantize_row(&x.data[r * d..(r + 1) * d]);
            for k in 0..c {
                let dot = self.scorer.dot_truncated(&a_int, k, SCORER_PLANES);
                logits[r * c + k] = self.scorer.logit(dot, a_scale);
            }
        }
        Tensor::new(vec![b, c], logits)
    }
}

// ---------------------------------------------------------------------------
// Bitplane-decomposed backend (DESIGN.md §15)
// ---------------------------------------------------------------------------

/// One row's cached partial accumulation (DESIGN.md §15): everything a
/// *different* replica needs to complete the answer by adding the
/// residual planes.  All-integer state — the quantized activations and
/// the exact `i64` dots — so the hand-off loses nothing to float
/// rounding.
#[derive(Clone, Debug)]
pub struct PlanePartial {
    /// Planes already accumulated into `dots` (MSB-first, `1..=8`).
    pub bits: u32,
    /// Per-class integer dot products of `a_int` against the top-`bits`
    /// truncated weights.
    pub dots: Vec<i64>,
    /// The row's quantized activations — what "send the residual
    /// planes" ships instead of the full `f32` payload (4× smaller).
    pub a_int: Vec<i8>,
    /// The row's activation dequantization scale.
    pub a_scale: f32,
}

/// Lock-free accumulator of simulated (unscaled) seconds across a
/// pool's backends: the §3 cost model's answer to "how much compute did
/// this serving strategy spend", independent of the wall-clock
/// `time_scale`.  The `perf_route` refinement gate compares two pools'
/// meters instead of racing sleeps.
#[derive(Debug, Default)]
pub struct SimCostMeter {
    /// `f64` bit pattern, CAS-updated.
    bits: AtomicU64,
}

impl SimCostMeter {
    /// A fresh zeroed meter.
    pub fn new() -> SimCostMeter {
        SimCostMeter::default()
    }

    /// Add `s` simulated seconds.
    pub fn add(&self, s: f64) {
        let mut cur = self.bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + s).to_bits();
            match self
                .bits
                .compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => return,
                Err(c) => cur = c,
            }
        }
    }

    /// Total simulated seconds accumulated so far.
    pub fn total_s(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Bitplane-decomposed simulator backend (DESIGN.md §15, ROADMAP
/// item 1): the same cycle-costed replica as [`SimBackend`], but the
/// scorer weights are stored as [`SCORER_PLANES`] MSB-first plane
/// contribution grids and a forward accumulates only the top `wbits`
/// planes — a native `wbits`-bit answer at `wbits/8` of the
/// full-precision cycle cost (per-plane latency = the §3
/// [`cell_cycles`] total at 8-bit weights, divided by the plane count).
///
/// Because the encoding nests (a low-bit value is a bit-prefix of the
/// high-bit one, DQT-style), the partial sums of a low-margin reply can
/// be completed to full depth by *any* replica built from the same
/// seed, by adding the residual planes ([`InferenceBackend::refine`]) —
/// escalation costs ~(extra-bits/total-bits) of a batch instead of a
/// full re-run, collapsing fixed per-replica precision into one
/// homogeneous pool serving an arbitrary precision mix.
pub struct BitplaneBackend {
    cfg: SimBackendCfg,
    scorer: NestedScorer,
    /// Plane contribution grids: `planes[j]` holds `q_{j+1} − q_j` of
    /// every weight (row-major, like the scorer grid).  Summing grids
    /// `0..p` telescopes to the top-`p` truncated weights exactly.
    planes: Vec<Vec<i8>>,
    /// Wall-clock sleep per accumulated plane (already `time_scale`-d).
    plane_cost: Duration,
    /// Unscaled simulated seconds per plane per batch.
    plane_latency_s: f64,
    /// Partials of the most recent forward, until taken.
    last: Option<Vec<PlanePartial>>,
    /// Optional shared simulated-cost meter (benches).
    meter: Option<Arc<SimCostMeter>>,
}

impl BitplaneBackend {
    /// Build a bitplane backend from `cfg` (validates shapes, requires
    /// `wbits ∈ 1..=8` — the first-pass plane depth — and a 2/4/8
    /// `abits` for the cycle model).
    pub fn new(cfg: SimBackendCfg) -> Result<Self> {
        Self::with_meter(cfg, None)
    }

    /// Like [`BitplaneBackend::new`] with a shared [`SimCostMeter`]
    /// attached: every forward/refine adds its simulated seconds, so
    /// benches can compare refinement against full re-run on the §3
    /// cost model without wall-clock sleeping.
    pub fn with_meter(cfg: SimBackendCfg, meter: Option<Arc<SimCostMeter>>) -> Result<Self> {
        ensure!(cfg.batch >= 1, "bitplane backend: batch must be >= 1");
        ensure!(cfg.img_elems >= 1, "bitplane backend: img_elems must be >= 1");
        ensure!(cfg.classes >= 1, "bitplane backend: classes must be >= 1");
        ensure!(!cfg.layers.is_empty(), "bitplane backend: empty layer stack");
        ensure!(
            cfg.time_scale.is_finite() && cfg.time_scale >= 0.0,
            "bitplane backend: time_scale must be finite and >= 0"
        );
        ensure!(
            cfg.wbits >= 1 && cfg.wbits <= SCORER_PLANES,
            "bitplane backend: wbits (first-pass planes) must be 1..={SCORER_PLANES}, got {}",
            cfg.wbits
        );
        let pa = Prec::from_bits(cfg.abits)
            .ok_or_else(|| anyhow!("bitplane backend: abits must be 2/4/8, got {}", cfg.abits))?;
        // §3 cycle model: one plane costs 1/8 of the full 8-bit-weight
        // batch — the planes of a bit-serial GEMM run back to back, so
        // the full accumulation reproduces the B8 latency exactly
        let hw = HwConfig::zcu102();
        let full8: u64 = cfg
            .layers
            .iter()
            .map(|l| cell_cycles(&hw, l, cfg.batch.max(1), Prec::B8, pa).total)
            .sum();
        let plane_latency_s = full8 as f64 * hw.cycle_time() / SCORER_PLANES as f64;
        let plane_cost = Duration::from_secs_f64(plane_latency_s * cfg.time_scale);
        let scorer = NestedScorer::new(cfg.classes, cfg.img_elems, cfg.seed);
        let planes = (0..SCORER_PLANES)
            .map(|j| {
                scorer
                    .w_int
                    .iter()
                    .map(|&w| (truncate_msb(w, j + 1) - truncate_msb(w, j)) as i8)
                    .collect()
            })
            .collect();
        Ok(BitplaneBackend { cfg, scorer, planes, plane_cost, plane_latency_s, last: None,
                             meter })
    }

    /// A [`BackendFactory`] whose replicas share one config (one seed,
    /// one first-pass depth).
    pub fn factory(cfg: SimBackendCfg) -> BackendFactory {
        Arc::new(move |_replica| {
            Ok(Box::new(BitplaneBackend::new(cfg.clone())?) as Box<dyn InferenceBackend>)
        })
    }

    /// A mixed-pool [`BackendFactory`] like [`SimBackend::mixed_factory`]:
    /// replica `i` first-passes at `mix[i]`'s wbits worth of planes.
    /// Unlike the §10 mixed pool, the precision here is only the
    /// *first-pass depth* — every replica holds the full plane stack, so
    /// any of them can refine any partial to full depth.
    pub fn mixed_factory(base: SimBackendCfg, mix: Vec<ReplicaPrecision>) -> BackendFactory {
        Self::metered_mixed_factory(base, mix, None)
    }

    /// [`BitplaneBackend::mixed_factory`] with an optional shared
    /// [`SimCostMeter`] across every replica.
    pub fn metered_mixed_factory(base: SimBackendCfg, mix: Vec<ReplicaPrecision>,
                                 meter: Option<Arc<SimCostMeter>>) -> BackendFactory {
        Arc::new(move |replica| {
            let p = match mix.is_empty() {
                true => ReplicaPrecision::default(),
                false => mix[replica % mix.len()],
            };
            let cfg = SimBackendCfg { wbits: p.wbits, abits: p.abits, ..base.clone() };
            Ok(Box::new(BitplaneBackend::with_meter(cfg, meter.clone())?)
                as Box<dyn InferenceBackend>)
        })
    }

    /// Unscaled simulated seconds per plane per batch.
    pub fn plane_latency_s(&self) -> f64 {
        self.plane_latency_s
    }

    /// Wall-clock sleep per accumulated plane after `time_scale`.
    pub fn plane_cost(&self) -> Duration {
        self.plane_cost
    }

    /// Spend `planes` planes of simulated time: meter first, then the
    /// scaled sleep.
    fn spend(&self, planes: u32) {
        if let Some(m) = &self.meter {
            m.add(planes as f64 * self.plane_latency_s);
        }
        let cost = self.plane_cost * planes;
        if !cost.is_zero() {
            std::thread::sleep(cost);
        }
    }

    fn refine_impl(&mut self, partials: &[PlanePartial]) -> Result<Tensor> {
        ensure!(!partials.is_empty(), "refine: empty partial batch");
        let (d, c) = (self.cfg.img_elems, self.cfg.classes);
        let mut residual_max = 0u32;
        for p in partials {
            ensure!(
                p.bits >= 1 && p.bits <= SCORER_PLANES,
                "refine: partial claims {} accumulated planes, scorer holds {SCORER_PLANES}",
                p.bits
            );
            ensure!(
                p.a_int.len() == d,
                "refine: partial row has {} elements, model wants {d}",
                p.a_int.len()
            );
            ensure!(
                p.dots.len() == c,
                "refine: partial has {} classes, model has {c}",
                p.dots.len()
            );
            residual_max = residual_max.max(SCORER_PLANES - p.bits);
        }
        // the group accumulates residual planes in lockstep, so its cost
        // is the deepest residual — ~(extra-bits/total-bits) of a batch
        self.spend(residual_max);
        let mut logits = vec![0.0f32; partials.len() * c];
        for (r, p) in partials.iter().enumerate() {
            for k in 0..c {
                let mut dot = p.dots[k];
                for grid in &self.planes[p.bits as usize..SCORER_PLANES as usize] {
                    let w = &grid[k * d..(k + 1) * d];
                    dot += p
                        .a_int
                        .iter()
                        .zip(w)
                        .map(|(&a, &w)| a as i64 * w as i64)
                        .sum::<i64>();
                }
                logits[r * c + k] = self.scorer.logit(dot, p.a_scale);
            }
        }
        Tensor::new(vec![partials.len(), c], logits)
    }
}

impl InferenceBackend for BitplaneBackend {
    fn name(&self) -> &str {
        "bitplane"
    }

    fn batch(&self) -> usize {
        self.cfg.batch
    }

    fn img_elems(&self) -> usize {
        self.cfg.img_elems
    }

    fn forward(&mut self, x: Tensor) -> Result<Tensor> {
        ensure!(
            x.shape == [self.cfg.batch, self.cfg.img_elems],
            "bitplane backend: input shape {:?}, want [{}, {}]",
            x.shape,
            self.cfg.batch,
            self.cfg.img_elems
        );
        if let Some(s) = self.cfg.fail_on {
            if x.data.iter().any(|v| v.to_bits() == s.to_bits()) {
                bail!("bitplane backend: injected failure (sentinel {s} in batch)");
            }
        }
        let p = self.cfg.wbits;
        self.spend(p);
        let (b, d, c) = (self.cfg.batch, self.cfg.img_elems, self.cfg.classes);
        let mut logits = vec![0.0f32; b * c];
        let mut partials = Vec::with_capacity(b);
        for r in 0..b {
            let (a_int, a_scale) = self.scorer.quantize_row(&x.data[r * d..(r + 1) * d]);
            let mut dots = vec![0i64; c];
            // honest plane accumulation (not a truncated dot): grid by
            // grid, MSB first — what the property tests pin against the
            // direct SimBackend product
            for grid in &self.planes[..p as usize] {
                for (k, dot) in dots.iter_mut().enumerate() {
                    let w = &grid[k * d..(k + 1) * d];
                    *dot += a_int
                        .iter()
                        .zip(w)
                        .map(|(&a, &w)| a as i64 * w as i64)
                        .sum::<i64>();
                }
            }
            for k in 0..c {
                logits[r * c + k] = self.scorer.logit(dots[k], a_scale);
            }
            partials.push(PlanePartial { bits: p, dots, a_int, a_scale });
        }
        self.last = Some(partials);
        Tensor::new(vec![b, c], logits)
    }

    fn planes(&self) -> u32 {
        SCORER_PLANES
    }

    fn take_partials(&mut self) -> Option<Vec<PlanePartial>> {
        self.last.take()
    }

    fn refine(&mut self, partials: &[PlanePartial]) -> Option<Result<Tensor>> {
        Some(self.refine_impl(partials))
    }
}

// ---------------------------------------------------------------------------
// Partial-sum cache (DESIGN.md §15)
// ---------------------------------------------------------------------------

/// One cached partial plus its §13 fence.
#[derive(Clone, Debug)]
pub struct PlaneEntry {
    /// Replica that produced the partial.
    pub source: usize,
    /// `source`'s incarnation when the partial was produced: a partial
    /// from a superseded incarnation is never completed into a reply.
    pub incarnation: u64,
    /// The partial itself.
    pub partial: PlanePartial,
}

/// Bounded pool-global cache of low-margin partial sums awaiting
/// refinement (DESIGN.md §15).  Keyed by a fresh per-request id (stamped
/// into the escalated item), evicted on reply, FIFO-evicted at
/// capacity.  Dropping an entry is always safe: a missing entry just
/// means the escalation target falls back to the §10 full re-run, so
/// the cache can never wedge or corrupt a request — only save work.
pub struct PlaneCache {
    /// Entries + FIFO eviction order.  Leaf lock: held only inside this
    /// type's methods, never across another acquisition.
    // lock-order: planecache level 1
    inner: Mutex<PlaneCacheInner>,
    /// Monotonic id source; `0` is reserved for "no cached partial".
    next_id: AtomicU64,
    cap: usize,
}

struct PlaneCacheInner {
    entries: HashMap<u64, PlaneEntry>,
    fifo: VecDeque<u64>,
}

impl PlaneCache {
    /// Cache bounded at `cap` entries (clamped to ≥ 1).
    pub fn new(cap: usize) -> PlaneCache {
        PlaneCache {
            inner: Mutex::new(PlaneCacheInner {
                entries: HashMap::new(),
                fifo: VecDeque::new(),
            }),
            next_id: AtomicU64::new(1),
            cap: cap.max(1),
        }
    }

    /// Insert a partial, returning its id (never 0).  At capacity the
    /// oldest live entry is evicted first — its item will full-re-run.
    pub fn insert(&self, source: usize, incarnation: u64, partial: PlanePartial) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut g = lock(&self.inner);
        while g.entries.len() >= self.cap {
            match g.fifo.pop_front() {
                Some(old) => {
                    g.entries.remove(&old);
                }
                None => break,
            }
        }
        g.fifo.push_back(id);
        g.entries.insert(id, PlaneEntry { source, incarnation, partial });
        id
    }

    /// Remove and return entry `id`: evicted-on-reply, so a second take
    /// — or a take after FIFO eviction — returns `None` and the caller
    /// falls back to the full re-run.
    pub fn take(&self, id: u64) -> Option<PlaneEntry> {
        let mut g = lock(&self.inner);
        let e = g.entries.remove(&id);
        if e.is_some() {
            g.fifo.retain(|&x| x != id);
        }
        e
    }

    /// Live entries.
    pub fn len(&self) -> usize {
        lock(&self.inner).entries.len()
    }

    /// `true` when no entry is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every entry (the shutdown sweep); returns how many were
    /// swept.
    pub fn clear(&self) -> usize {
        let mut g = lock(&self.inner);
        g.fifo.clear();
        let n = g.entries.len();
        g.entries.clear();
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_backend_is_deterministic_across_instances() {
        let cfg = SimBackendCfg::tiny(11);
        let mut a = SimBackend::new(cfg.clone()).unwrap();
        let mut b = SimBackend::new(cfg).unwrap();
        let mut rng = Rng::new(5);
        let x = Tensor::new(vec![4, 64], rng.normal_vec(4 * 64)).unwrap();
        let la = a.forward(x.clone()).unwrap();
        let lb = b.forward(x).unwrap();
        assert_eq!(la, lb);
        assert_eq!(la.shape, vec![4, 10]);
        assert_eq!(la.argmax_rows(), lb.argmax_rows());
    }

    #[test]
    fn sim_backend_costs_batches_with_the_simulator() {
        let sb = SimBackend::new(SimBackendCfg::tiny(1)).unwrap();
        assert!(sb.sim_latency_s() > 0.0);
        assert!(sb.batch_cost().is_zero()); // tiny() has time_scale 0
        let mut cfg = SimBackendCfg::tiny(1);
        cfg.time_scale = 2.0;
        let sb2 = SimBackend::new(cfg).unwrap();
        let want = Duration::from_secs_f64(sb.sim_latency_s() * 2.0);
        let got = sb2.batch_cost();
        let delta = if got > want { got - want } else { want - got };
        assert!(delta < Duration::from_micros(1), "{got:?} vs {want:?}");
    }

    #[test]
    fn lower_precision_costs_fewer_simulated_seconds() {
        let mut lo = SimBackendCfg::tiny(1);
        lo.wbits = 2;
        lo.abits = 2;
        let mut hi = SimBackendCfg::tiny(1);
        hi.wbits = 8;
        hi.abits = 8;
        let lo = SimBackend::new(lo).unwrap();
        let hi = SimBackend::new(hi).unwrap();
        assert!(lo.sim_latency_s() < hi.sim_latency_s());
    }

    #[test]
    fn sim_backend_rejects_bad_shapes_and_bits() {
        let mut b = SimBackend::new(SimBackendCfg::tiny(1)).unwrap();
        assert!(b.forward(Tensor::zeros(&[4, 63])).is_err());
        let mut cfg = SimBackendCfg::tiny(1);
        cfg.wbits = 3;
        assert!(SimBackend::new(cfg).is_err());
    }

    #[test]
    fn fail_sentinel_fails_the_batch() {
        let mut cfg = SimBackendCfg::tiny(1);
        cfg.fail_on = Some(42.5);
        let mut b = SimBackend::new(cfg).unwrap();
        let mut x = Tensor::zeros(&[4, 64]);
        assert!(b.forward(x.clone()).is_ok());
        x.data[100] = 42.5;
        let err = b.forward(x).unwrap_err();
        assert!(format!("{err:#}").contains("injected"));
    }

    #[test]
    fn mixed_factory_costs_by_replica_precision_but_answers_identically() {
        let mut base = SimBackendCfg::tiny(9);
        base.time_scale = 1.0; // expose the per-precision cost difference
        let mix = vec![
            ReplicaPrecision::uniform(4),
            ReplicaPrecision::uniform(4),
            ReplicaPrecision::uniform(8),
        ];
        let fast = SimBackend::new(SimBackendCfg { wbits: 4, abits: 4, ..base.clone() }).unwrap();
        let slow = SimBackend::new(SimBackendCfg { wbits: 8, abits: 8, ..base.clone() }).unwrap();
        assert!(
            fast.batch_cost() < slow.batch_cost(),
            "per-precision cycle costs must separate the tiers"
        );
        let f = SimBackend::mixed_factory(base, mix);
        let mut r0 = f(0).unwrap();
        let mut r2 = f(2).unwrap();
        // same seed ⇒ identical logits across the precision tiers, so an
        // escalation re-run cannot change a deterministic answer
        let mut rng = Rng::new(3);
        let x = Tensor::new(vec![4, 64], rng.normal_vec(4 * 64)).unwrap();
        assert_eq!(r0.forward(x.clone()).unwrap(), r2.forward(x).unwrap());
    }

    #[test]
    fn projected_batch_cost_matches_the_backend_and_orders_tiers() {
        let mut cfg = SimBackendCfg::tiny(1);
        cfg.time_scale = 1.5;
        // the projection is exactly what a built backend would cost…
        let built = SimBackend::new(cfg.clone()).unwrap().batch_cost();
        let projected = cfg
            .projected_batch_cost(ReplicaPrecision::new(cfg.wbits, cfg.abits))
            .unwrap();
        assert_eq!(projected, built);
        // …and a mix projects per replica, faster tiers costing less
        let mix = vec![ReplicaPrecision::uniform(4), ReplicaPrecision::uniform(8)];
        let costs = cfg.projected_batch_costs(&mix).unwrap();
        assert_eq!(costs.len(), 2);
        assert!(costs[0] < costs[1], "{costs:?}");
        // bad bits are a descriptive Err, mirroring SimBackend::new
        assert!(cfg.projected_batch_cost(ReplicaPrecision::uniform(3)).is_err());
    }

    #[test]
    fn factory_builds_per_replica_instances() {
        let f = SimBackend::factory(SimBackendCfg::tiny(3));
        let mut a = f(0).unwrap();
        let mut b = f(1).unwrap();
        assert_eq!(a.batch(), 4);
        assert_eq!(a.img_elems(), 64);
        assert_eq!(a.name(), "sim");
        let x = Tensor::zeros(&[4, 64]);
        assert_eq!(a.forward(x.clone()).unwrap(), b.forward(x).unwrap());
    }

    // ---- §15 bitplane bit-exactness oracles (ISSUE 10 satellite) ----

    /// Accumulating all [`SCORER_PLANES`] planes must reproduce the
    /// direct [`SimBackend`] logits bit-for-bit, across seeds and for
    /// both full and short (zero-padded) batches — the §15 analogue of
    /// the GridLut/CalibView bit-exactness oracles.
    #[test]
    fn all_planes_accumulated_match_simbackend_bit_for_bit() {
        for seed in [1u64, 7, 13] {
            let mut cfg = SimBackendCfg::tiny(seed);
            cfg.wbits = 8;
            let mut sim = SimBackend::new(cfg.clone()).unwrap();
            let mut bp = BitplaneBackend::new(cfg).unwrap();
            let mut rng = Rng::new(seed ^ 0xABCD);
            for rows in [4usize, 2, 1] {
                // short batches arrive zero-padded to the static dim,
                // exactly like the worker's padding path
                let mut data = vec![0.0f32; 4 * 64];
                let payload = rng.normal_vec(rows * 64);
                data[..rows * 64].copy_from_slice(&payload);
                let x = Tensor::new(vec![4, 64], data).unwrap();
                let a = sim.forward(x.clone()).unwrap();
                let b = bp.forward(x).unwrap();
                assert_eq!(a, b, "seed {seed} rows {rows}");
            }
        }
    }

    /// Prefix property: a `p`-plane accumulation equals a native
    /// `p`-bit run (a direct dot against the top-`p` truncated weight
    /// grid) bitwise, for every precision tier.
    #[test]
    fn plane_prefix_matches_native_truncated_run() {
        let base = SimBackendCfg::tiny(21);
        let scorer = NestedScorer::new(base.classes, base.img_elems, base.seed);
        let mut rng = Rng::new(99);
        let x = Tensor::new(vec![4, 64], rng.normal_vec(4 * 64)).unwrap();
        for p in [2u32, 4, 8] {
            let mut cfg = base.clone();
            cfg.wbits = p;
            let mut bp = BitplaneBackend::new(cfg).unwrap();
            let got = bp.forward(x.clone()).unwrap();
            let mut want = vec![0.0f32; 4 * 10];
            for r in 0..4 {
                let (a_int, a_scale) = scorer.quantize_row(&x.data[r * 64..(r + 1) * 64]);
                for (k, w) in want[r * 10..(r + 1) * 10].iter_mut().enumerate() {
                    *w = scorer.logit(scorer.dot_truncated(&a_int, k, p), a_scale);
                }
            }
            assert_eq!(got.data, want, "p = {p}");
        }
    }

    /// Refinement property: cached partials + residual planes equal the
    /// native full-depth forward bitwise — on the producing replica or
    /// any sibling, including one whose own first pass is low-bit.
    #[test]
    fn refine_completes_partials_to_full_depth_exactly() {
        let mut lo = SimBackendCfg::tiny(5);
        lo.wbits = 4;
        let mut hi = lo.clone();
        hi.wbits = 8;
        let mut fast = BitplaneBackend::new(lo).unwrap();
        let mut full = BitplaneBackend::new(hi).unwrap();
        let mut rng = Rng::new(17);
        let x = Tensor::new(vec![4, 64], rng.normal_vec(4 * 64)).unwrap();
        let low = fast.forward(x.clone()).unwrap();
        let parts = fast.take_partials().expect("partials after forward");
        assert!(fast.take_partials().is_none(), "partials are take-once");
        assert_eq!(parts.len(), 4);
        assert!(parts.iter().all(|p| p.bits == 4));
        let refined = full.refine(&parts).expect("bitplane refines").unwrap();
        let direct = full.forward(x).unwrap();
        assert_eq!(refined, direct, "partial + residual planes == native 8-bit run");
        assert_ne!(low, direct, "4- and 8-plane logits must differ on random payloads");
        let refined_by_fast = fast.refine(&parts).expect("any sibling refines").unwrap();
        assert_eq!(refined_by_fast, direct);
    }

    #[test]
    fn refine_validates_partial_shapes() {
        let mut bp = BitplaneBackend::new(SimBackendCfg::tiny(3)).unwrap();
        let ok =
            PlanePartial { bits: 4, dots: vec![0; 10], a_int: vec![0; 64], a_scale: 0.0 };
        assert!(bp.refine(std::slice::from_ref(&ok)).expect("supported").is_ok());
        let bad_bits = PlanePartial { bits: 9, ..ok.clone() };
        assert!(bp.refine(&[bad_bits]).expect("supported").is_err());
        let bad_row = PlanePartial { a_int: vec![0; 63], ..ok.clone() };
        assert!(bp.refine(&[bad_row]).expect("supported").is_err());
        let bad_classes = PlanePartial { dots: vec![0; 9], ..ok };
        assert!(bp.refine(&[bad_classes]).expect("supported").is_err());
        assert!(bp.refine(&[]).expect("supported").is_err());
    }

    /// §3 cost model: eight planes cost exactly one 8-bit-weight batch,
    /// so a `wbits`-plane first pass is `wbits/8` of it.
    #[test]
    fn plane_cost_is_an_eighth_of_the_full_precision_batch() {
        let mut cfg = SimBackendCfg::tiny(1);
        cfg.wbits = 8;
        cfg.abits = 8;
        let bp = BitplaneBackend::new(cfg.clone()).unwrap();
        let sim = SimBackend::new(cfg).unwrap();
        let full = bp.plane_latency_s() * SCORER_PLANES as f64;
        let rel = (full - sim.sim_latency_s()).abs() / sim.sim_latency_s();
        assert!(rel < 1e-9, "8 planes must cost one B8 batch: {full} vs {}",
                sim.sim_latency_s());
        // plane depth drives the scaled sleep linearly
        let mut scaled = SimBackendCfg::tiny(1);
        scaled.time_scale = 2.0;
        scaled.wbits = 4;
        let b = BitplaneBackend::new(scaled).unwrap();
        let want = Duration::from_secs_f64(b.plane_latency_s() * 2.0);
        let got = b.plane_cost();
        let delta = if got > want { got - want } else { want - got };
        assert!(delta < Duration::from_micros(1), "{got:?} vs {want:?}");
    }

    #[test]
    fn sim_cost_meter_accumulates_forward_and_refine() {
        let meter = Arc::new(SimCostMeter::new());
        let mut cfg = SimBackendCfg::tiny(2);
        cfg.wbits = 4;
        let mut bp = BitplaneBackend::with_meter(cfg, Some(Arc::clone(&meter))).unwrap();
        assert_eq!(meter.total_s(), 0.0);
        bp.forward(Tensor::zeros(&[4, 64])).unwrap();
        let after_fwd = meter.total_s();
        let want = 4.0 * bp.plane_latency_s();
        assert!((after_fwd - want).abs() < 1e-12, "{after_fwd} vs {want}");
        let parts = bp.take_partials().expect("partials");
        bp.refine(&parts).expect("supported").unwrap();
        let want2 = want + 4.0 * bp.plane_latency_s(); // residual 8−4
        assert!((meter.total_s() - want2).abs() < 1e-12);
    }

    #[test]
    fn bitplane_rejects_bad_configs_and_shapes() {
        let mut cfg = SimBackendCfg::tiny(1);
        cfg.wbits = 9;
        assert!(BitplaneBackend::new(cfg).is_err());
        let mut cfg = SimBackendCfg::tiny(1);
        cfg.abits = 3;
        assert!(BitplaneBackend::new(cfg).is_err());
        let mut b = BitplaneBackend::new(SimBackendCfg::tiny(1)).unwrap();
        assert!(b.forward(Tensor::zeros(&[4, 63])).is_err());
        let mut cfg = SimBackendCfg::tiny(1);
        cfg.fail_on = Some(42.5);
        let mut b = BitplaneBackend::new(cfg).unwrap();
        let mut x = Tensor::zeros(&[4, 64]);
        assert!(b.forward(x.clone()).is_ok());
        x.data[100] = 42.5;
        assert!(format!("{:#}", b.forward(x).unwrap_err()).contains("injected"));
    }

    #[test]
    fn plane_cache_inserts_takes_evicts_and_clears() {
        let part =
            PlanePartial { bits: 4, dots: vec![1; 10], a_int: vec![2; 64], a_scale: 1.0 };
        let cache = PlaneCache::new(2);
        let a = cache.insert(0, 0, part.clone());
        let b = cache.insert(1, 3, part.clone());
        assert!(a != 0 && b != 0 && a != b, "ids are fresh and never 0");
        assert_eq!(cache.len(), 2);
        // at capacity the oldest entry goes, never the newest
        let c = cache.insert(2, 0, part.clone());
        assert_ne!(c, 0);
        assert_eq!(cache.len(), 2);
        assert!(cache.take(a).is_none(), "oldest entry evicted at capacity");
        let got = cache.take(b).expect("live entry");
        assert_eq!((got.source, got.incarnation), (1, 3));
        assert_eq!(got.partial.bits, 4);
        assert!(cache.take(b).is_none(), "evicted on reply: take is once");
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.clear(), 1);
        assert!(cache.is_empty());
    }

    /// The default trait surface keeps non-plane backends inert: the
    /// server's refinement path must see "unsupported" and fall back.
    #[test]
    fn simbackend_does_not_advertise_planes() {
        let mut sb = SimBackend::new(SimBackendCfg::tiny(1)).unwrap();
        assert_eq!(InferenceBackend::planes(&sb), 0);
        sb.forward(Tensor::zeros(&[4, 64])).unwrap();
        assert!(sb.take_partials().is_none());
        let p = PlanePartial { bits: 4, dots: vec![0; 10], a_int: vec![0; 64], a_scale: 0.0 };
        assert!(sb.refine(&[p]).is_none());
    }
}
