//! Pluggable inference backends for the serving pool (DESIGN.md §9).
//!
//! The worker's execute step — pad / forward / argmax over a compiled
//! model — is abstracted behind [`InferenceBackend`] so the coordinator
//! no longer hard-codes the PJRT artifact path.  Two implementations:
//!
//! * [`PjrtBackend`] — the original deployment shape: a `qat::Session` +
//!   `runtime::Executor` pair executing the AOT-compiled fwd HLO.  Needs
//!   built artifacts and a real PJRT runtime.
//! * [`SimBackend`] — a deterministic stand-in that costs each batch with
//!   the cycle-accurate [`crate::sim::Simulator`] (scaled into wall time)
//!   and scores it with a seeded linear projection, so the whole serving
//!   stack is buildable, testable, and benchable with **no artifacts**.
//!
//! Backends are constructed *on the replica's own worker thread* through
//! a factory closure ([`BackendFactory`]): PJRT handles must not cross
//! threads, and the factory pattern preserves that invariant for every
//! backend while letting [`super::Server`] own N independent replicas.

use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, ensure, Result};

use crate::qat::{QuantConfig, Session};
use crate::runtime::{Executor, Manifest};
use crate::sim::{HwConfig, LayerShape, Prec, Simulator};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

use super::router::ReplicaPrecision;

/// One replica's model executor: takes a padded `[batch, img_elems]`
/// input tensor, returns `[batch, classes]` logits.  The worker loop
/// (pad → forward → argmax → reply) lives in [`super::Server`]; a
/// backend only supplies the forward pass and its static geometry.
pub trait InferenceBackend {
    /// Human-readable backend name (logs, error messages).
    fn name(&self) -> &str;
    /// Static batch dimension of the compiled/simulated model.
    fn batch(&self) -> usize;
    /// Flattened elements per image.
    fn img_elems(&self) -> usize;
    /// Forward a padded `[batch, img_elems]` batch to `[batch, classes]`
    /// logits.  Takes the tensor by value (the worker builds a fresh one
    /// per chunk, so backends can reshape without copying).  An `Err`
    /// fails the whole batch (every request in it gets an error reply);
    /// it must not kill the replica.
    fn forward(&mut self, x: Tensor) -> Result<Tensor>;
    /// `true` when the backend has failed permanently and the worker
    /// should exit *between* batches (after the current batch's replies
    /// are sent) so the supervisor can respawn it (DESIGN.md §13).  The
    /// default — a healthy backend — never trips.
    fn fatal(&self) -> bool {
        false
    }
}

/// Constructs one backend per replica, invoked with the replica id on
/// that replica's own thread (PJRT handles are not shared across
/// threads; `Send`/`Sync` is required of the *factory*, not the
/// backend).
pub type BackendFactory =
    Arc<dyn Fn(usize) -> Result<Box<dyn InferenceBackend>> + Send + Sync>;

// ---------------------------------------------------------------------------
// PJRT-artifact backend
// ---------------------------------------------------------------------------

/// The artifact-backed backend: `Session` + `Executor` executing the
/// quantized fwd HLO, exactly the worker preamble the pre-§9 server
/// inlined.
pub struct PjrtBackend {
    exec: Executor,
    session: Session,
    qcfg: QuantConfig,
    pallas: bool,
    batch: usize,
    img_elems: usize,
    input_shape: Vec<usize>,
}

impl PjrtBackend {
    /// Build and warm one backend: creates the PJRT client, loads the
    /// model's parameters, and compiles the fwd artifact so the first
    /// request isn't a stall.  Every failure here is a *startup* error —
    /// the server surfaces it from `Server::start` via the readiness
    /// handshake (DESIGN.md §9).
    pub fn new(manifest: &Manifest, model: &str, qcfg: QuantConfig,
               pallas: bool) -> Result<Self> {
        let entry = manifest.model(model)?;
        let batch = entry.batch;
        let input_shape = entry.input.clone();
        let img_elems: usize = input_shape.iter().skip(1).product();
        ensure!(batch >= 1, "{model}: batch dim must be >= 1");
        ensure!(img_elems >= 1, "{model}: empty input shape");
        let mut exec = Executor::new(&manifest.dir)?;
        let session = Session::new(manifest, model)?;
        let tag = if pallas { "fwd_pallas" } else { "fwd" };
        let art = session.model.artifact(tag)?.file.clone();
        exec.load(&art)?;
        Ok(PjrtBackend { exec, session, qcfg, pallas, batch, img_elems, input_shape })
    }

    /// A [`BackendFactory`] giving each replica its own client/session
    /// over a shared manifest.
    pub fn factory(manifest: Manifest, model: String, qcfg: QuantConfig,
                   pallas: bool) -> BackendFactory {
        Arc::new(move |_replica| {
            Ok(Box::new(PjrtBackend::new(&manifest, &model, qcfg.clone(), pallas)?)
                as Box<dyn InferenceBackend>)
        })
    }
}

impl InferenceBackend for PjrtBackend {
    fn name(&self) -> &str {
        "pjrt"
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn img_elems(&self) -> usize {
        self.img_elems
    }

    fn forward(&mut self, x: Tensor) -> Result<Tensor> {
        // the worker pads to [batch, img_elems]; the HLO wants the
        // model's full input shape (e.g. NHWC) — reshape in place
        let x = x.reshape(self.input_shape.clone())?;
        self.session.forward(&mut self.exec, &self.qcfg, &x, self.pallas)
    }
}

// ---------------------------------------------------------------------------
// Simulator-costed deterministic backend
// ---------------------------------------------------------------------------

/// Configuration of a [`SimBackend`].
#[derive(Clone, Debug)]
pub struct SimBackendCfg {
    /// Layer stack fed to the cycle-accurate simulator (e.g.
    /// [`crate::models::synthetic_resnet`]).
    pub layers: Vec<LayerShape>,
    /// Static batch dimension (the simulator's M scales with it).
    pub batch: usize,
    /// Flattened elements per image.
    pub img_elems: usize,
    /// Number of output classes.
    pub classes: usize,
    /// Uniform weight/activation bitwidths for the cycle cost (2/4/8).
    pub wbits: u32,
    /// See `wbits`.
    pub abits: u32,
    /// Seed of the linear scorer; equal seeds ⇒ bit-identical logits,
    /// so every replica of a pool answers identically.
    pub seed: u64,
    /// Wall-seconds slept per simulated second: each `forward` sleeps
    /// `sim_latency × time_scale`.  `0.0` disables sleeping (unit
    /// tests); benches pick a scale that makes a batch a few ms so
    /// replica scaling is measurable.
    pub time_scale: f64,
    /// Fault injection: if any input element is bit-equal to this
    /// sentinel, `forward` fails the whole batch.  Lets tests and
    /// benches exercise the coordinator's error path deterministically.
    pub fail_on: Option<f32>,
}

impl SimBackendCfg {
    /// A small artifact-free serving model: 6-layer synthetic ResNet
    /// geometry, batch 4, 64-element images, 10 classes, no sleeping.
    pub fn tiny(seed: u64) -> Self {
        SimBackendCfg {
            layers: crate::models::synthetic_resnet(4),
            batch: 4,
            img_elems: 64,
            classes: 10,
            wbits: 4,
            abits: 8,
            seed,
            time_scale: 0.0,
            fail_on: None,
        }
    }

    /// Projected wall cost of one batch at precision `p`: the §3
    /// cycle-accurate simulator's latency for this layer stack at
    /// `(p.wbits, p.abits)`, scaled by `time_scale` — the same per-batch
    /// cycle estimate the §7 cost table is built from, here feeding the
    /// §12 admission layer's queue-delay projection.  Runs only the
    /// simulator (no scorer weights), so probing a pool mix is cheap.
    pub fn projected_batch_cost(&self, p: ReplicaPrecision) -> Result<Duration> {
        let pw = Prec::from_bits(p.wbits)
            .ok_or_else(|| anyhow!("batch cost: wbits must be 2/4/8, got {}", p.wbits))?;
        let pa = Prec::from_bits(p.abits)
            .ok_or_else(|| anyhow!("batch cost: abits must be 2/4/8, got {}", p.abits))?;
        ensure!(!self.layers.is_empty(), "batch cost: empty layer stack");
        ensure!(
            self.time_scale.is_finite() && self.time_scale >= 0.0,
            "batch cost: time_scale must be finite and >= 0"
        );
        let mut sim = Simulator::new(HwConfig::zcu102(), self.layers.clone(), self.batch.max(1));
        let assign = vec![(pw, pa); sim.layers.len()];
        Ok(Duration::from_secs_f64(sim.run(&assign).latency_s * self.time_scale))
    }

    /// Per-replica batch-cost projections for a pool mix — the seed for
    /// `AdmissionCfg::batch_cost` (replica `i` at `mix[i]`'s precision,
    /// matching [`SimBackend::mixed_factory`]'s assignment).
    pub fn projected_batch_costs(&self, mix: &[ReplicaPrecision]) -> Result<Vec<Duration>> {
        mix.iter().map(|&p| self.projected_batch_cost(p)).collect()
    }
}

/// Deterministic simulator-costed backend (DESIGN.md §9): latency from
/// the cycle-accurate ZCU102 model at the configured uniform precision,
/// logits from a seeded random linear projection of the input.
pub struct SimBackend {
    cfg: SimBackendCfg,
    /// `classes × img_elems` scorer weights, row-major.
    weights: Vec<f32>,
    /// Wall-clock cost per batch (already `time_scale`-d).
    cost: Duration,
    /// Unscaled simulated latency of one batch, for reporting.
    sim_latency_s: f64,
}

impl SimBackend {
    /// Build a simulator backend from `cfg` (validates shapes and
    /// pre-computes the per-batch cost model).
    pub fn new(cfg: SimBackendCfg) -> Result<Self> {
        ensure!(cfg.batch >= 1, "sim backend: batch must be >= 1");
        ensure!(cfg.img_elems >= 1, "sim backend: img_elems must be >= 1");
        ensure!(cfg.classes >= 1, "sim backend: classes must be >= 1");
        ensure!(!cfg.layers.is_empty(), "sim backend: empty layer stack");
        ensure!(
            cfg.time_scale.is_finite() && cfg.time_scale >= 0.0,
            "sim backend: time_scale must be finite and >= 0"
        );
        let pw = Prec::from_bits(cfg.wbits)
            .ok_or_else(|| anyhow!("sim backend: wbits must be 2/4/8, got {}", cfg.wbits))?;
        let pa = Prec::from_bits(cfg.abits)
            .ok_or_else(|| anyhow!("sim backend: abits must be 2/4/8, got {}", cfg.abits))?;
        let mut sim = Simulator::new(HwConfig::zcu102(), cfg.layers.clone(), cfg.batch);
        let assign = vec![(pw, pa); sim.layers.len()];
        let sim_latency_s = sim.run(&assign).latency_s;
        let cost = Duration::from_secs_f64(sim_latency_s * cfg.time_scale);
        // ~unit-variance logits regardless of img_elems
        let mut rng = Rng::new(cfg.seed);
        let norm = 1.0 / (cfg.img_elems as f32).sqrt();
        let weights = (0..cfg.classes * cfg.img_elems)
            .map(|_| rng.normal() as f32 * norm)
            .collect();
        Ok(SimBackend { cfg, weights, cost, sim_latency_s })
    }

    /// A [`BackendFactory`] whose replicas share one config (and thus
    /// one scorer seed — all replicas answer identically).
    pub fn factory(cfg: SimBackendCfg) -> BackendFactory {
        Arc::new(move |_replica| {
            Ok(Box::new(SimBackend::new(cfg.clone())?) as Box<dyn InferenceBackend>)
        })
    }

    /// A heterogeneous-pool [`BackendFactory`] (DESIGN.md §10): replica
    /// `i` runs `base` at `mix[i]`'s bitwidths, so its batch cost is the
    /// §3 simulator's cycle count *at that precision* — a DyBit-4
    /// replica really is ~2.6× faster per batch than an 8-bit one on the
    /// ResNet-like stack, making routing effects measurable with no
    /// artifacts.  The scorer seed stays shared, so every replica (fast
    /// or accurate) answers a given payload identically; SimBackend
    /// models the *latency* side of precision — the accuracy side is the
    /// paper's Fig. 6, not simulated.
    pub fn mixed_factory(base: SimBackendCfg, mix: Vec<ReplicaPrecision>) -> BackendFactory {
        Arc::new(move |replica| {
            let p = match mix.is_empty() {
                true => ReplicaPrecision::default(),
                false => mix[replica % mix.len()],
            };
            let cfg = SimBackendCfg { wbits: p.wbits, abits: p.abits, ..base.clone() };
            Ok(Box::new(SimBackend::new(cfg)?) as Box<dyn InferenceBackend>)
        })
    }

    /// Simulated (unscaled) latency of one batch in seconds.
    pub fn sim_latency_s(&self) -> f64 {
        self.sim_latency_s
    }

    /// Wall-clock sleep applied per batch after `time_scale`.
    pub fn batch_cost(&self) -> Duration {
        self.cost
    }
}

impl InferenceBackend for SimBackend {
    fn name(&self) -> &str {
        "sim"
    }

    fn batch(&self) -> usize {
        self.cfg.batch
    }

    fn img_elems(&self) -> usize {
        self.cfg.img_elems
    }

    fn forward(&mut self, x: Tensor) -> Result<Tensor> {
        ensure!(
            x.shape == [self.cfg.batch, self.cfg.img_elems],
            "sim backend: input shape {:?}, want [{}, {}]",
            x.shape,
            self.cfg.batch,
            self.cfg.img_elems
        );
        if let Some(s) = self.cfg.fail_on {
            if x.data.iter().any(|v| v.to_bits() == s.to_bits()) {
                bail!("sim backend: injected failure (sentinel {s} in batch)");
            }
        }
        if !self.cost.is_zero() {
            std::thread::sleep(self.cost);
        }
        let (b, d, c) = (self.cfg.batch, self.cfg.img_elems, self.cfg.classes);
        let mut logits = vec![0.0f32; b * c];
        for r in 0..b {
            let row = &x.data[r * d..(r + 1) * d];
            for k in 0..c {
                let w = &self.weights[k * d..(k + 1) * d];
                logits[r * c + k] = row.iter().zip(w).map(|(a, b)| a * b).sum();
            }
        }
        Tensor::new(vec![b, c], logits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_backend_is_deterministic_across_instances() {
        let cfg = SimBackendCfg::tiny(11);
        let mut a = SimBackend::new(cfg.clone()).unwrap();
        let mut b = SimBackend::new(cfg).unwrap();
        let mut rng = Rng::new(5);
        let x = Tensor::new(vec![4, 64], rng.normal_vec(4 * 64)).unwrap();
        let la = a.forward(x.clone()).unwrap();
        let lb = b.forward(x).unwrap();
        assert_eq!(la, lb);
        assert_eq!(la.shape, vec![4, 10]);
        assert_eq!(la.argmax_rows(), lb.argmax_rows());
    }

    #[test]
    fn sim_backend_costs_batches_with_the_simulator() {
        let sb = SimBackend::new(SimBackendCfg::tiny(1)).unwrap();
        assert!(sb.sim_latency_s() > 0.0);
        assert!(sb.batch_cost().is_zero()); // tiny() has time_scale 0
        let mut cfg = SimBackendCfg::tiny(1);
        cfg.time_scale = 2.0;
        let sb2 = SimBackend::new(cfg).unwrap();
        let want = Duration::from_secs_f64(sb.sim_latency_s() * 2.0);
        let got = sb2.batch_cost();
        let delta = if got > want { got - want } else { want - got };
        assert!(delta < Duration::from_micros(1), "{got:?} vs {want:?}");
    }

    #[test]
    fn lower_precision_costs_fewer_simulated_seconds() {
        let mut lo = SimBackendCfg::tiny(1);
        lo.wbits = 2;
        lo.abits = 2;
        let mut hi = SimBackendCfg::tiny(1);
        hi.wbits = 8;
        hi.abits = 8;
        let lo = SimBackend::new(lo).unwrap();
        let hi = SimBackend::new(hi).unwrap();
        assert!(lo.sim_latency_s() < hi.sim_latency_s());
    }

    #[test]
    fn sim_backend_rejects_bad_shapes_and_bits() {
        let mut b = SimBackend::new(SimBackendCfg::tiny(1)).unwrap();
        assert!(b.forward(Tensor::zeros(&[4, 63])).is_err());
        let mut cfg = SimBackendCfg::tiny(1);
        cfg.wbits = 3;
        assert!(SimBackend::new(cfg).is_err());
    }

    #[test]
    fn fail_sentinel_fails_the_batch() {
        let mut cfg = SimBackendCfg::tiny(1);
        cfg.fail_on = Some(42.5);
        let mut b = SimBackend::new(cfg).unwrap();
        let mut x = Tensor::zeros(&[4, 64]);
        assert!(b.forward(x.clone()).is_ok());
        x.data[100] = 42.5;
        let err = b.forward(x).unwrap_err();
        assert!(format!("{err:#}").contains("injected"));
    }

    #[test]
    fn mixed_factory_costs_by_replica_precision_but_answers_identically() {
        let mut base = SimBackendCfg::tiny(9);
        base.time_scale = 1.0; // expose the per-precision cost difference
        let mix = vec![
            ReplicaPrecision::uniform(4),
            ReplicaPrecision::uniform(4),
            ReplicaPrecision::uniform(8),
        ];
        let fast = SimBackend::new(SimBackendCfg { wbits: 4, abits: 4, ..base.clone() }).unwrap();
        let slow = SimBackend::new(SimBackendCfg { wbits: 8, abits: 8, ..base.clone() }).unwrap();
        assert!(
            fast.batch_cost() < slow.batch_cost(),
            "per-precision cycle costs must separate the tiers"
        );
        let f = SimBackend::mixed_factory(base, mix);
        let mut r0 = f(0).unwrap();
        let mut r2 = f(2).unwrap();
        // same seed ⇒ identical logits across the precision tiers, so an
        // escalation re-run cannot change a deterministic answer
        let mut rng = Rng::new(3);
        let x = Tensor::new(vec![4, 64], rng.normal_vec(4 * 64)).unwrap();
        assert_eq!(r0.forward(x.clone()).unwrap(), r2.forward(x).unwrap());
    }

    #[test]
    fn projected_batch_cost_matches_the_backend_and_orders_tiers() {
        let mut cfg = SimBackendCfg::tiny(1);
        cfg.time_scale = 1.5;
        // the projection is exactly what a built backend would cost…
        let built = SimBackend::new(cfg.clone()).unwrap().batch_cost();
        let projected = cfg
            .projected_batch_cost(ReplicaPrecision::new(cfg.wbits, cfg.abits))
            .unwrap();
        assert_eq!(projected, built);
        // …and a mix projects per replica, faster tiers costing less
        let mix = vec![ReplicaPrecision::uniform(4), ReplicaPrecision::uniform(8)];
        let costs = cfg.projected_batch_costs(&mix).unwrap();
        assert_eq!(costs.len(), 2);
        assert!(costs[0] < costs[1], "{costs:?}");
        // bad bits are a descriptive Err, mirroring SimBackend::new
        assert!(cfg.projected_batch_cost(ReplicaPrecision::uniform(3)).is_err());
    }

    #[test]
    fn factory_builds_per_replica_instances() {
        let f = SimBackend::factory(SimBackendCfg::tiny(3));
        let mut a = f(0).unwrap();
        let mut b = f(1).unwrap();
        assert_eq!(a.batch(), 4);
        assert_eq!(a.img_elems(), 64);
        assert_eq!(a.name(), "sim");
        let x = Tensor::zeros(&[4, 64]);
        assert_eq!(a.forward(x.clone()).unwrap(), b.forward(x).unwrap());
    }
}
